//! Calibration — paper Listing 4 in MoleDSL v2: generational NSGA-II
//! (mu=10, lambda=10, 100 generations, reevaluate=0.01) minimising the
//! median first-empty tick of each food source over
//! (diffusion-rate, evaporation-rate) in (0, 99)², as one declarative
//! [`Experiment`] over the [`Nsga2Evolution`] method.
//!
//!     cargo run --release --example calibrate_nsga2 [-- --generations 100]
//!
//! Results are saved to /tmp/ants/ (SavePopulationHook analogue).

use std::sync::Arc;

use molers::cli::Args;
use molers::evolution::{Nsga2Config, PooledEvaluator, ReplicatedEvaluator};
use molers::prelude::*;
use molers::runtime::best_available_evaluator;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let args = Args::parse(std::env::args().skip(1))?;
    let generations = args.usize("generations", 100)? as u32;
    let replications = args.usize("replications", 5)?;
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);

    let (base, kind) = best_available_evaluator(2);
    // replicateModel: 5-seed median fitness (Listing 3 feeding Listing 4).
    // The replication wrapper flattens genomes × seeds into one batch, and
    // the pooled layer fans that batch out over the machine's cores.
    let evaluator = Arc::new(PooledEvaluator::with_threads(
        Arc::new(ReplicatedEvaluator::new(base, replications)),
        threads,
    ));

    let g_diffusion = val_f64("gDiffusionRate");
    let g_evaporation = val_f64("gEvaporationRate");
    let med1 = val_f64("medNumberFood1");
    let med2 = val_f64("medNumberFood2");
    let med3 = val_f64("medNumberFood3");

    // NSGA2(mu=10, inputs=bounds (0,99), objectives=3 medians, reevaluate=0.01)
    let evolution = Nsga2Config::new(
        10,
        &[(&g_diffusion, 0.0, 99.0), (&g_evaporation, 0.0, 99.0)],
        &[&med1, &med2, &med3],
        0.01,
    )?;

    // SavePopulationHook("/tmp/ants/") + DisplayHook("Generation ...")
    let csv = CsvHook::new(
        "/tmp/ants/population.csv",
        &["generation", "gDiffusionRate", "gEvaporationRate", "f1", "f2", "f3"],
    );
    let on_generation = Arc::new(move |generation: u32, population: &molers::evolution::PopMatrix| {
        println!("Generation {generation}");
        for i in 0..population.len() {
            let genome = population.genome(i);
            let objectives = population.objectives_row(i);
            let mut ctx = Context::new();
            ctx.set(&val_f64("generation"), f64::from(generation));
            ctx.set(&val_f64("gDiffusionRate"), genome[0]);
            ctx.set(&val_f64("gEvaporationRate"), genome[1]);
            ctx.set(&val_f64("f1"), objectives[0]);
            ctx.set(&val_f64("f2"), objectives[1]);
            ctx.set(&val_f64("f3"), objectives[2]);
            let _ = csv.process(&ctx);
        }
    });

    // GenerationalGA(evolution)(replicateModel, lambda = 10), declaratively:
    // eval_chunk packs each generation's wave through evaluate_batch, so
    // the pooled evaluator sees the whole lambda at once (§Perf tentpole)
    let experiment = Experiment::new(Box::new(Nsga2Evolution {
        config: evolution,
        lambda: 10,
        generations,
        eval_chunk: 10,
        evaluator,
        kind: kind.to_string(),
        on_generation: Some(on_generation),
    }))
    .env(EnvSpec::Single {
        name: "local".into(),
        nodes: threads,
    })
    .seed(42);

    let report = experiment.run()?;
    let result = &report.outcome;
    println!(
        "\n{} evaluations; final Pareto front ({} points):",
        result.evaluations,
        result.pareto_front.len()
    );
    println!("  diffusion  evaporation |   f1      f2      f3");
    let mut front = result.pareto_front.clone();
    front.sort_by(|a, b| a.objectives[0].total_cmp(&b.objectives[0]));
    for ind in &front {
        println!(
            "  {:9.2}  {:11.2} | {:6.1} {:7.1} {:7.1}",
            ind.genome[0],
            ind.genome[1],
            ind.objectives[0],
            ind.objectives[1],
            ind.objectives[2]
        );
    }
    println!("\npopulation log: /tmp/ants/population.csv");
    Ok(())
}
