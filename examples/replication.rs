//! Replication — paper Listing 3: run the stochastic ant model under five
//! independent seeds and aggregate each objective with a median
//! (`StatisticTask`), all through the workflow engine's explore/aggregate
//! transitions.
//!
//!     cargo run --release --example replication

use std::sync::Arc;

use molers::prelude::*;
use molers::runtime::best_available_evaluator;

fn main() -> molers::Result<()> {
    let seed = val_u32("seed");
    let food = [val_f64("food1"), val_f64("food2"), val_f64("food3")];
    let med = [
        val_f64("medNumberFood1"),
        val_f64("medNumberFood2"),
        val_f64("medNumberFood3"),
    ];

    let (evaluator, kind) = best_available_evaluator(1);
    println!("model backend: {kind}");

    // model capsule (parameters fixed at Listing 2's defaults)
    let model = {
        let (s, f) = (seed.clone(), food.clone());
        ClosureTask::new("ants", move |ctx: &Context| {
            let fit = evaluator.evaluate(&[125.0, 50.0, 50.0], ctx.get(&s)?)?;
            let mut out = Context::new();
            for (fv, v) in f.iter().zip(fit) {
                out.set(fv, v);
            }
            Ok(out)
        })
        .input(&seed)
        .output(&food[0])
        .output(&food[1])
        .output(&food[2])
    };

    // StatisticTask: three medians, as in Listing 3
    let mut statistic = StatisticTask::new();
    for (f, m) in food.iter().zip(&med) {
        statistic = statistic.statistic(f, m, Descriptor::Median);
    }

    // Replicate(modelCapsule, seedFactor take 5, statisticCapsule)
    let mut puzzle = Puzzle::new();
    let (_, model_c, stat_c) = replicate(
        &mut puzzle,
        Arc::new(model),
        &seed,
        5,
        Arc::new(statistic),
    );
    // displayOutputs / displayMedians hooks
    puzzle.hook(model_c, Arc::new(ToStringHook::new(&["food1", "food2", "food3"])));
    puzzle.hook(
        stat_c,
        Arc::new(ToStringHook::new(&[
            "medNumberFood1",
            "medNumberFood2",
            "medNumberFood3",
        ])),
    );

    let env: Arc<dyn Environment> = Arc::new(LocalEnvironment::new(4));
    let result = MoleExecution::new(puzzle, env, 42).start()?;
    println!(
        "replication workflow: {} jobs (1 entry + 5 models + 1 statistic) in {:?}",
        result.report.jobs, result.report.wall
    );
    Ok(())
}
