//! Replication — paper Listing 3 in MoleDSL v2: run the stochastic ant
//! model under five independent seeds and aggregate each objective with a
//! median (`StatisticTask`), as one declarative [`Experiment`] over the
//! [`Replication`] exploration method.
//!
//!     cargo run --release --example replication

use std::sync::Arc;

use molers::prelude::*;
use molers::runtime::best_available_evaluator;

fn main() -> molers::Result<()> {
    let seed = val_u32("seed");
    let food = [val_f64("food1"), val_f64("food2"), val_f64("food3")];
    let med = [
        val_f64("medNumberFood1"),
        val_f64("medNumberFood2"),
        val_f64("medNumberFood3"),
    ];

    let (evaluator, kind) = best_available_evaluator(1);

    // model capsule (parameters fixed at Listing 2's defaults)
    let model = {
        let (s, f) = (seed.clone(), food.clone());
        ClosureTask::new("ants", move |ctx: &Context| {
            let fit = evaluator.evaluate(&[125.0, 50.0, 50.0], ctx.get(&s)?)?;
            let mut out = Context::new();
            for (fv, v) in f.iter().zip(fit) {
                out.set(fv, v);
            }
            Ok(out)
        })
        .input(&seed)
        .output(&food[0])
        .output(&food[1])
        .output(&food[2])
    };

    // StatisticTask: three medians, as in Listing 3
    let mut statistic = StatisticTask::new();
    for (f, m) in food.iter().zip(&med) {
        statistic = statistic.statistic(f, m, Descriptor::Median);
    }

    // Replicate(modelCapsule, seedFactor take 5, statisticCapsule) — the
    // experiment wires `entry -< model >- statistic`, validates the typed
    // dataflow (seed: u32 from the sampling, food arrays into the
    // statistic) and runs it on the chosen environment
    let experiment = Experiment::new(Box::new(Replication {
        model: Arc::new(model),
        seed_val: seed,
        replications: 5,
        statistic: Arc::new(statistic),
        kind: kind.to_string(),
        // displayOutputs / displayMedians hooks
        model_hooks: vec![Arc::new(ToStringHook::new(&["food1", "food2", "food3"]))],
        statistic_hooks: vec![Arc::new(ToStringHook::new(&[
            "medNumberFood1",
            "medNumberFood2",
            "medNumberFood3",
        ]))],
    }))
    .env(EnvSpec::Single {
        name: "local".into(),
        nodes: 4,
    })
    .seed(42);

    let report = experiment.run()?;
    println!(
        "replication workflow: {} jobs (1 entry + 5 models + 1 statistic) in {:?}",
        report.outcome.jobs, report.wall
    );
    Ok(())
}
