//! End-to-end driver — paper Listing 5 + §4.6 headline in MoleDSL v2:
//! island-model NSGA-II on the (simulated) European Grid Infrastructure,
//! as one declarative [`Experiment`] over the [`IslandEvolution`] method.
//!
//! "The example shows how an initialisation of the GA with a population of
//! 200,000 individuals can be evaluated in one hour on the European Grid
//! Infrastructure." — 2,000 concurrent islands, mu=200, 50-individual
//! island samples.
//!
//! This driver proves all layers compose: the L1 Pallas kernel inside the
//! L2 JAX model, AOT-compiled and served by the L3 PJRT runtime, driven by
//! the island coordinator over the discrete-event EGI simulation. Real
//! evaluations are scaled down (`--islands`, `--evals-per-island`); the
//! virtual-time throughput is reported in the paper's units and
//! extrapolated to the 2,000-island configuration. Run it as:
//!
//!     cargo run --release --example island_egi
//!     cargo run --release --example island_egi -- --islands 128 --evals-per-island 50
//!
//! Results land in EXPERIMENTS.md §E4.

use std::sync::Arc;

use molers::cli::Args;
use molers::evolution::{IslandConfig, Nsga2Config};
use molers::metrics::throughput_per_hour;
use molers::prelude::*;
use molers::runtime::best_available_evaluator;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let args = Args::parse(std::env::args().skip(1))?;
    let islands = args.usize("islands", 64)?;
    let per_island = args.u64("evals-per-island", 25)?;
    let total = args.u64("total-evals", islands as u64 * per_island)?;
    let mu = args.usize("mu", 200)?;

    let (evaluator, kind) = best_available_evaluator(2);
    println!(
        "model backend: {kind}; {islands} concurrent islands x {per_island} \
         evaluations, {total} total"
    );

    let g_diffusion = val_f64("gDiffusionRate");
    let g_evaporation = val_f64("gEvaporationRate");
    let med1 = val_f64("medNumberFood1");
    let med2 = val_f64("medNumberFood2");
    let med3 = val_f64("medNumberFood3");

    // NSGA2(mu = 200, termination = Timed(1 hour), ...)
    let evolution = Nsga2Config::new(
        mu,
        &[(&g_diffusion, 0.0, 99.0), (&g_evaporation, 0.0, 99.0)],
        &[&med1, &med2, &med3],
        0.01,
    )?;

    // IslandSteadyGA(evolution, replicateModel)(islands, totalEvals, 50)
    // on EGIEnvironment("biomed", ...) — the experiment builds the grid
    let experiment = Experiment::new(Box::new(IslandEvolution {
        config: evolution,
        islands: IslandConfig {
            concurrent_islands: islands,
            total_evaluations: total,
            island_sample: 50,
            evals_per_island: per_island,
        },
        evaluator,
        kind: kind.to_string(),
        on_island: Some(Arc::new(move |done, evals| {
            if done % 16 == 0 || done == islands as u64 {
                println!("Generation {done} islands merged ({evals} evaluations)");
            }
        })),
    }))
    .env(EnvSpec::Single {
        name: "egi".into(),
        nodes: islands,
    })
    .seed(42);

    let report = experiment.run()?;
    let result = &report.outcome;
    let stats = &report.env_stats;

    // --- the paper's headline, in its own units ----------------------------
    let per_hour = throughput_per_hour(result.evaluations, result.virtual_makespan);
    let scale = 2000.0 / islands as f64;
    println!("\n=== E4: island model on simulated EGI ===");
    println!("real wall-clock            : {:?}", report.wall);
    println!("virtual makespan           : {:.0} s", result.virtual_makespan);
    println!("evaluations                : {}", result.evaluations);
    println!("throughput                 : {per_hour:.0} evaluations/virtual-hour");
    println!(
        "extrapolated to 2000 islands: {:.0} evaluations/hour (paper: 200,000/h)",
        per_hour * scale
    );
    println!(
        "grid behaviour             : {} submissions, {} failures resubmitted",
        stats.submitted, stats.resubmissions
    );

    println!("\nfinal archive Pareto front ({} points):", result.pareto_front.len());
    let mut front = result.pareto_front.clone();
    front.sort_by(|a, b| a.objectives[0].total_cmp(&b.objectives[0]));
    for ind in front.iter().take(12) {
        println!(
            "  diffusion={:6.2} evaporation={:6.2} -> [{:6.1} {:6.1} {:6.1}]",
            ind.genome[0],
            ind.genome[1],
            ind.objectives[0],
            ind.objectives[1],
            ind.objectives[2]
        );
    }
    Ok(())
}
