//! Quickstart — paper Listing 2 in MoleDSL v2: embed the ant model as a
//! task, run it once with explicit parameters, observe the outputs
//! through a hook.
//!
//!     cargo run --release --example quickstart [-- --render]
//!
//! Uses the PJRT-compiled JAX+Pallas model if `make artifacts` was run,
//! else the pure-Rust twin. The puzzle is built with [`PuzzleBuilder`],
//! so the wiring (inputs supplied, types compatible) is *proven* at
//! `build()` — before any job runs.

use std::sync::Arc;

use molers::prelude::*;
use molers::runtime::best_available_evaluator;
use molers::sim::{render, AntParams, AntSim};

fn main() -> molers::Result<()> {
    let render_world = std::env::args().any(|a| a == "--render");

    // --- Listing 2's prototypes -------------------------------------------
    let g_population = val_f64("gPopulation");
    let g_diffusion = val_f64("gDiffusionRate");
    let g_evaporation = val_f64("gEvaporationRate");
    let seed = val_u32("seed");
    let food1 = val_f64("food1");
    let food2 = val_f64("food2");
    let food3 = val_f64("food3");

    // --- the NetLogo task (backed by the AOT JAX+Pallas model) -------------
    let (evaluator, kind) = best_available_evaluator(1);
    println!("model backend: {kind}");
    let ants = {
        let (gp, gd, ge, s) = (
            g_population.clone(),
            g_diffusion.clone(),
            g_evaporation.clone(),
            seed.clone(),
        );
        let (f1, f2, f3) = (food1.clone(), food2.clone(), food3.clone());
        ClosureTask::new("ants", move |ctx: &Context| {
            let fit = evaluator.evaluate(
                &[ctx.get(&gp)?, ctx.get(&gd)?, ctx.get(&ge)?],
                ctx.get(&s)?,
            )?;
            Ok(Context::new()
                .with(&f1, fit[0])
                .with(&f2, fit[1])
                .with(&f3, fit[2]))
        })
        // inputs + defaults exactly as in Listing 2
        .input(&g_population)
        .input(&g_diffusion)
        .input(&g_evaporation)
        .input(&seed)
        .default(&seed, 42)
        .default(&g_population, 125.0)
        .default(&g_diffusion, 50.0)
        .default(&g_evaporation, 50.0)
        .output(&food1)
        .output(&food2)
        .output(&food3)
    };

    // --- MoleDSL v2: one capsule, one hook, validated at build() -----------
    let builder = PuzzleBuilder::new();
    let capsule = builder.task(ants);
    capsule.hook(Arc::new(ToStringHook::new(&["food1", "food2", "food3"])));
    let puzzle = builder.build()?; // typed wiring proven here

    let env: Arc<dyn Environment> = Arc::new(LocalEnvironment::new(1));
    let result = MoleExecution::new(puzzle, env, 1).start()?;
    println!(
        "workflow finished: {} job(s) in {:?}",
        result.report.jobs, result.report.wall
    );

    // --- Figures 1–2: visual representation of the model -------------------
    if render_world {
        let mut sim = AntSim::new(
            AntParams {
                population: 125.0,
                diffusion_rate: 50.0,
                evaporation_rate: 10.0,
            },
            42,
        );
        for _ in 0..300 {
            sim.step();
        }
        println!("{}", render::ascii(&sim));
        std::fs::write("ants_world.ppm", render::ppm(&sim, 4))?;
        println!("wrote ants_world.ppm (Figure 1/2 analogue)");
    }
    Ok(())
}
