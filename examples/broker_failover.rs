//! Distribution-broker failover demo (§tentpole): one calibration, three
//! heterogeneous backends, injected failures, a mid-run "kill", and a
//! journaled resume that lands on the exact same Pareto front.
//!
//! The fleet:
//!
//!   * `local`        — this machine (always healthy);
//!   * `flaky pbs`    — a simulated PBS cluster that silently loses 60%
//!                      of submissions (the broker must re-route);
//!   * `slow ssh`     — a two-slot server whose queue makes stragglers
//!                      (the broker speculatively clones them).
//!
//! Run it as:
//!
//!     cargo run --release --example broker_failover
//!     cargo run --release --example broker_failover -- --generations 8

use std::sync::Arc;

use molers::broker::{
    journal, Broker, FlakyEnv, Journal, SpeculationConfig,
};
use molers::cli::Args;
use molers::environment::cluster::BatchEnvironment;
use molers::environment::local::LocalEnvironment;
use molers::environment::ssh::SshEnvironment;
use molers::environment::Environment;
use molers::evolution::{GenerationalGA, Nsga2Config, Zdt1Evaluator};
use molers::exec::ThreadPool;
use molers::prelude::*;

fn fleet(pool: &Arc<ThreadPool>, seed: u64) -> Result<Broker, molers::Error> {
    let flaky_pbs: Arc<dyn Environment> = Arc::new(FlakyEnv::new(
        Arc::new(BatchEnvironment::pbs(8, Arc::clone(pool), seed)),
        0.6,
        seed ^ 0xBAD,
    ));
    Broker::builder("demo-fleet")
        .backend(
            Arc::new(LocalEnvironment::with_pool(Arc::clone(pool))),
            4,
        )
        .backend(flaky_pbs, 8)
        .backend(
            Arc::new(SshEnvironment::new("slow", 2, Arc::clone(pool), seed)),
            2,
        )
        .speculation(SpeculationConfig {
            quantile: 0.9,
            min_samples: 16,
        })
        .build()
}

fn report(tag: &str, broker: &Broker) {
    let s = broker.stats();
    let c = broker.counters();
    println!(
        "[{tag}] jobs: {} submitted, {} completed, {} terminally failed; \
         {} failed attempts re-routed {} times; speculation: {} launched, \
         {} won the race; breaker trips: {}",
        s.submitted,
        s.completed,
        s.failed_jobs,
        s.failed_attempts,
        c.reroutes,
        c.speculative_launched,
        c.speculative_wins,
        broker.quarantine_trips()
    );
    for b in broker.backend_snapshots() {
        println!(
            "    {:<28} completed={:<5} failed={:<4} ewma={:.2}s{}",
            b.name,
            b.completed,
            b.failed,
            b.ewma_duration_s,
            if b.quarantined { "  [quarantined]" } else { "" }
        );
    }
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let args = Args::parse(std::env::args().skip(1))?;
    let generations = args.usize("generations", 6)? as u32;
    let kill_after = (generations / 2).max(1);
    let seed = args.u64("seed", 29)?;
    let pool = Arc::new(ThreadPool::default_size());

    let x0 = val_f64("x0");
    let x1 = val_f64("x1");
    let x2 = val_f64("x2");
    let f1 = val_f64("f1");
    let f2 = val_f64("f2");
    let config = Nsga2Config::new(
        16,
        &[(&x0, 0.0, 1.0), (&x1, 0.0, 1.0), (&x2, 0.0, 1.0)],
        &[&f1, &f2],
        0.1,
    )?;
    let ga = || {
        GenerationalGA::new(
            config.clone(),
            Arc::new(Zdt1Evaluator { dim: 3 }),
            16,
        )
    };
    let journal_dir = std::env::temp_dir();
    let path_full = journal_dir.join("broker_failover_full.jsonl");
    let path_cut = journal_dir.join("broker_failover_cut.jsonl");

    // 1. the reference: an uninterrupted run over the faulty fleet
    println!("== uninterrupted run ({generations} generations) ==");
    let broker = fleet(&pool, 1)?;
    let full = ga()
        .journal(Arc::new(Journal::create(&path_full)?))
        .run(&broker, generations, seed)?;
    report("uninterrupted", &broker);

    // 2. the same run, "killed" after kill_after generations
    println!("\n== journaled run killed after generation {kill_after} ==");
    let broker2 = fleet(&pool, 2)?;
    ga().journal(Arc::new(Journal::create(&path_cut)?))
        .run(&broker2, kill_after, seed)?;
    report("killed", &broker2);

    // 3. resume from the journal on a fresh fleet and finish
    println!("\n== --resume from {} ==", path_cut.display());
    let resume = journal::load_resume(&path_cut)?
        .expect("journal holds a generation checkpoint");
    println!(
        "resuming at generation {} with {} evaluations done",
        resume.generation + 1,
        resume.evaluations
    );
    let broker3 = fleet(&pool, 3)?;
    let resumed = ga()
        .journal(Arc::new(Journal::append_to(&path_cut)?))
        .run_resumable(&broker3, generations, seed, Some(resume))?;
    report("resumed", &broker3);

    // 4. the punchline: bit-identical Pareto fronts
    let front = |r: &molers::evolution::EvolutionResult| -> Vec<Vec<f64>> {
        r.pareto_front.iter().map(|i| i.objectives.clone()).collect()
    };
    assert_eq!(
        front(&full),
        front(&resumed),
        "resume diverged from the uninterrupted run"
    );
    println!(
        "\nkill + resume reproduced the uninterrupted Pareto front exactly \
         ({} points, {} evaluations) despite 60% injected submission loss.",
        full.pareto_front.len(),
        resumed.evaluations
    );
    let _ = std::fs::remove_file(&path_full);
    let _ = std::fs::remove_file(&path_cut);
    Ok(())
}
