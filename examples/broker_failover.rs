//! Distribution-broker failover demo in MoleDSL v2: one calibration,
//! three heterogeneous backends, injected failures, a mid-run "kill", and
//! a journaled resume that lands on the exact same Pareto front — all
//! three runs declared as [`Experiment`]s over the same custom fleet.
//!
//! The fleet:
//!
//!   * `local`        — this machine (always healthy);
//!   * `flaky pbs`    — a simulated PBS cluster that silently loses 60%
//!                      of submissions (the broker must re-route);
//!   * `slow ssh`     — a two-slot server whose queue makes stragglers
//!                      (the broker speculatively clones them).
//!
//! Run it as:
//!
//!     cargo run --release --example broker_failover
//!     cargo run --release --example broker_failover -- --generations 8

use std::sync::Arc;

use molers::broker::{Broker, FlakyEnv, SpeculationConfig};
use molers::cli::Args;
use molers::environment::cluster::BatchEnvironment;
use molers::environment::local::LocalEnvironment;
use molers::environment::ssh::SshEnvironment;
use molers::environment::Environment;
use molers::evolution::{Nsga2Config, Zdt1Evaluator};
use molers::exec::ThreadPool;
use molers::prelude::*;

fn fleet(pool: &Arc<ThreadPool>, seed: u64) -> Result<Arc<Broker>, molers::Error> {
    let flaky_pbs: Arc<dyn Environment> = Arc::new(FlakyEnv::new(
        Arc::new(BatchEnvironment::pbs(8, Arc::clone(pool), seed)),
        0.6,
        seed ^ 0xBAD,
    ));
    Ok(Arc::new(
        Broker::builder("demo-fleet")
            .backend(
                Arc::new(LocalEnvironment::with_pool(Arc::clone(pool))),
                4,
            )
            .backend(flaky_pbs, 8)
            .backend(
                Arc::new(SshEnvironment::new("slow", 2, Arc::clone(pool), seed)),
                2,
            )
            .speculation(SpeculationConfig {
                quantile: 0.9,
                min_samples: 16,
            })
            .build()?,
    ))
}

fn report(tag: &str, broker: &Broker) {
    let s = broker.stats();
    let c = broker.counters();
    println!(
        "[{tag}] jobs: {} submitted, {} completed, {} terminally failed; \
         {} failed attempts re-routed {} times; speculation: {} launched, \
         {} won the race; breaker trips: {}",
        s.submitted,
        s.completed,
        s.failed_jobs,
        s.failed_attempts,
        c.reroutes,
        c.speculative_launched,
        c.speculative_wins,
        broker.quarantine_trips()
    );
    for b in broker.backend_snapshots() {
        println!(
            "    {:<28} completed={:<5} failed={:<4} ewma={:.2}s{}",
            b.name,
            b.completed,
            b.failed,
            b.ewma_duration_s,
            if b.quarantined { "  [quarantined]" } else { "" }
        );
    }
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let args = Args::parse(std::env::args().skip(1))?;
    let generations = args.usize("generations", 6)? as u32;
    let kill_after = (generations / 2).max(1);
    let seed = args.u64("seed", 29)?;
    let pool = Arc::new(ThreadPool::default_size());

    let x0 = val_f64("x0");
    let x1 = val_f64("x1");
    let x2 = val_f64("x2");
    let f1 = val_f64("f1");
    let f2 = val_f64("f2");
    let config = Nsga2Config::new(
        16,
        &[(&x0, 0.0, 1.0), (&x1, 0.0, 1.0), (&x2, 0.0, 1.0)],
        &[&f1, &f2],
        0.1,
    )?;
    // the same declarative calibration, parameterised by generation budget
    let calibrate = |generations: u32| Nsga2Evolution {
        config: config.clone(),
        lambda: 16,
        generations,
        eval_chunk: 1,
        evaluator: Arc::new(Zdt1Evaluator { dim: 3 }),
        kind: "zdt1".into(),
        on_generation: None,
    };
    let journal_dir = std::env::temp_dir();
    let path_full = journal_dir.join("broker_failover_full.jsonl");
    let path_cut = journal_dir.join("broker_failover_cut.jsonl");

    // 1. the reference: an uninterrupted run over the faulty fleet
    println!("== uninterrupted run ({generations} generations) ==");
    let broker = fleet(&pool, 1)?;
    let full = Experiment::new(Box::new(calibrate(generations)))
        .on(Arc::clone(&broker) as Arc<dyn Environment>)
        .journal(path_full.to_string_lossy().into_owned())
        .seed(seed)
        .run()?;
    report("uninterrupted", &broker);

    // 2. the same run, "killed" after kill_after generations
    println!("\n== journaled run killed after generation {kill_after} ==");
    let broker2 = fleet(&pool, 2)?;
    Experiment::new(Box::new(calibrate(kill_after)))
        .on(Arc::clone(&broker2) as Arc<dyn Environment>)
        .journal(path_cut.to_string_lossy().into_owned())
        .seed(seed)
        .run()?;
    report("killed", &broker2);

    // 3. resume from the journal on a fresh fleet and finish — the
    //    experiment validates the journal's configuration, restores the
    //    checkpoint and continues
    println!("\n== --resume from {} ==", path_cut.display());
    let broker3 = fleet(&pool, 3)?;
    let resumed = Experiment::new(Box::new(calibrate(generations)))
        .on(Arc::clone(&broker3) as Arc<dyn Environment>)
        .resume(path_cut.to_string_lossy().into_owned())
        .seed(seed)
        .run()?;
    report("resumed", &broker3);

    // 4. the punchline: bit-identical Pareto fronts
    let front = |r: &molers::workflow::ExperimentReport| -> Vec<Vec<f64>> {
        r.outcome
            .pareto_front
            .iter()
            .map(|i| i.objectives.clone())
            .collect()
    };
    assert_eq!(
        front(&full),
        front(&resumed),
        "resume diverged from the uninterrupted run"
    );
    println!(
        "\nkill + resume reproduced the uninterrupted Pareto front exactly \
         ({} points, {} evaluations) despite 60% injected submission loss.",
        full.outcome.pareto_front.len(),
        resumed.outcome.evaluations
    );
    let _ = std::fs::remove_file(&path_full);
    let _ = std::fs::remove_file(&path_cut);
    Ok(())
}
