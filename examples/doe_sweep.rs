//! Design of experiments — §2's "generic tools to explore large parameter
//! sets" in MoleDSL v2: a full-factorial sweep of (diffusion-rate,
//! evaporation-rate) delegated to a simulated PBS cluster through the
//! paper's combinators — `entry -< model >- collect`, `model on env`,
//! `collect hook csv` — each a chainable method on a typed capsule handle.
//!
//!     cargo run --release --example doe_sweep [-- --env slurm --step 24.75]

use std::sync::Arc;

use molers::cli::Args;
use molers::exec::ThreadPool;
use molers::prelude::*;
use molers::runtime::best_available_evaluator;
use molers::workflow::single_environment;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let args = Args::parse(std::env::args().skip(1))?;
    let step = args.f64("step", 24.75)?;
    let env_name = args.get_or("env", "pbs").to_string();

    let g_diffusion = val_f64("gDiffusionRate");
    let g_evaporation = val_f64("gEvaporationRate");
    let seed = val_u32("seed");
    let food = [val_f64("food1"), val_f64("food2"), val_f64("food3")];

    let (evaluator, kind) = best_available_evaluator(2);

    let model = {
        let (gd, ge, s, f) = (
            g_diffusion.clone(),
            g_evaporation.clone(),
            seed.clone(),
            food.clone(),
        );
        ClosureTask::new("ants", move |ctx: &Context| {
            let fit =
                evaluator.evaluate(&[125.0, ctx.get(&gd)?, ctx.get(&ge)?], ctx.get(&s)?)?;
            let mut out = Context::new();
            for (fv, v) in f.iter().zip(fit) {
                out.set(fv, v);
            }
            Ok(out)
        })
        .input(&g_diffusion)
        .input(&g_evaporation)
        .input(&seed)
        .default(&seed, 42)
        .output(&food[0])
        .output(&food[1])
        .output(&food[2])
        .cost(36.0)
    };

    // DirectSampling: gDiffusionRate x gEvaporationRate grid
    let sampling = FullFactorial::new(vec![
        Factor::new(&g_diffusion, 0.0, 99.0, step),
        Factor::new(&g_evaporation, 0.0, 99.0, step),
    ]);
    println!(
        "model backend: {kind}; sweeping {} points on --env {env_name}",
        sampling.size()
    );

    // the one-line environment switch (a typo'd name is a hard error)
    let pool = Arc::new(ThreadPool::default_size());
    let env = single_environment(&env_name, 16, pool, 7)?;

    // --- the paper's combinators, as chainable methods ---------------------
    let b = PuzzleBuilder::new();
    let entry = b.task(IdentityTask::new("entry"));
    let model_c = b.task(model);
    let collect = b.task(IdentityTask::new("collect"));
    entry.explore(Arc::new(sampling), &model_c); // entry -< model
    model_c.aggregate(&collect); //                 model >- collect
    model_c.on(Arc::clone(&env)); //                model on env
    collect.hook(Arc::new(CsvHook::new(
        //                                          collect hook csv
        "/tmp/ants/doe.csv",
        &["gDiffusionRate", "gEvaporationRate", "food1", "food2", "food3"],
    )));
    let puzzle = b.build()?; // typed wiring proven before any submission

    let result = MoleExecution::new(puzzle, Arc::new(LocalEnvironment::new(2)), 7)
        .start()?;

    // report the sweep as a table ordered by total foraging time
    let out = &result.outputs[0];
    let ds: Vec<f64> = out.get(&g_diffusion.array())?;
    let es: Vec<f64> = out.get(&g_evaporation.array())?;
    let f1: Vec<f64> = out.get(&food[0].array())?;
    let f2: Vec<f64> = out.get(&food[1].array())?;
    let f3: Vec<f64> = out.get(&food[2].array())?;
    let mut rows: Vec<(f64, f64, f64, f64, f64)> = (0..ds.len())
        .map(|i| (ds[i], es[i], f1[i], f2[i], f3[i]))
        .collect();
    rows.sort_by(|a, b| (a.2 + a.3 + a.4).total_cmp(&(b.2 + b.3 + b.4)));
    println!("\n diffusion evaporation |    f1     f2     f3   (best first)");
    for (d, e, a, b, c) in rows.iter().take(10) {
        println!(" {d:9.2} {e:11.2} | {a:6.1} {b:6.1} {c:6.1}");
    }
    println!(
        "\n{} jobs, virtual makespan {:.0} s on {}",
        result.report.jobs,
        result.report.virtual_makespan,
        env.name()
    );
    Ok(())
}
