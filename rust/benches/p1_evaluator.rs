//! P1 (§Perf): evaluator hot-path throughput. Batch-size sweep of the PJRT
//! (JAX+Pallas AOT) path — the L1/L2 optimisation target — against the
//! pure-Rust twin, plus the replication wrapper's batching gain.

use std::sync::Arc;

use molers::bench::Bench;
use molers::evolution::{AntSimEvaluator, Evaluator, ReplicatedEvaluator};
use molers::runtime::{ArtifactManifest, PjrtEvaluator};

fn main() {
    let mut b = Bench::new("p1_evaluator").warmup(1).samples(5);

    let rust_sim = AntSimEvaluator::new();
    let mut s = 0u32;
    b.case("rust_sim_single", || {
        s += 1;
        rust_sim.evaluate(&[50.0, 10.0], s).unwrap()
    });

    if !ArtifactManifest::available() {
        println!("(artifacts not built; pjrt sweep skipped)");
        return;
    }
    let pjrt = PjrtEvaluator::from_default_artifacts(1).expect("pjrt");

    for &batch in &[1usize, 8, 32, 64] {
        let jobs: Vec<(Vec<f64>, u32)> = (0..batch)
            .map(|i| (vec![125.0, 30.0 + i as f64, 10.0], 7000 + i as u32))
            .collect();
        let m = b.case(&format!("pjrt_batch{batch}"), || {
            pjrt.evaluate_batch(&jobs).unwrap()
        });
        let per_eval = m.median_s() / batch as f64;
        b.metric(
            &format!("pjrt_batch{batch}_per_eval"),
            per_eval * 1e3,
            "ms/eval",
        );
    }

    // the replicated evaluator leans on evaluate_batch: its 5 seeds should
    // cost well under 5x a single evaluation
    let single = {
        let mut s = 100u32;
        b.case("pjrt_single_again", || {
            s += 1;
            pjrt.evaluate(&[50.0, 10.0], s).unwrap()
        })
        .median_s()
    };
    let replicated = ReplicatedEvaluator::new(Arc::new(pjrt), 5);
    let mut s2 = 0u32;
    let five = b
        .case("pjrt_replicated5", || {
            s2 += 1;
            replicated.evaluate(&[50.0, 10.0], s2).unwrap()
        })
        .median_s();
    b.metric("replication5_cost_ratio", five / single, "x (ideal < 5)");
}
