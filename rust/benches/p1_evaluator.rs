//! P1 (§Perf): evaluator hot-path throughput. The pure-Rust ant twin,
//! serial vs pooled batch evaluation (the §Perf tentpole's >2× multicore
//! claim is measured here), then the PJRT (JAX+Pallas AOT) batch-size
//! sweep when artifacts are built.
//!
//! Writes `BENCH_p1_evaluator.json` next to the working directory (or
//! `$BENCH_OUT_DIR`).

use std::sync::Arc;

use molers::bench::Bench;
use molers::evolution::{
    AntSimEvaluator, Evaluator, PooledEvaluator, ReplicatedEvaluator, RowsView,
};
use molers::runtime::{ArtifactManifest, PjrtEvaluator};

fn main() {
    let mut b = Bench::new("p1_evaluator").warmup(1).samples(5);

    let rust_sim = AntSimEvaluator::new();
    let mut s = 0u32;
    b.case("rust_sim_single", || {
        s += 1;
        rust_sim.evaluate(&[50.0, 10.0], s).unwrap()
    });

    // serial vs pooled batch on the Rust twin: same jobs, same results,
    // the only difference is the ThreadPool fan-out
    let threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4);
    let batch: Vec<(Vec<f64>, u32)> = (0..32)
        .map(|i| (vec![30.0 + f64::from(i), 10.0], 9000 + i))
        .collect();
    let serial_s = {
        let serial = AntSimEvaluator::fast();
        b.case("rust_sim_batch32_serial", || {
            serial.evaluate_batch(&batch).unwrap()
        })
        .median_s()
    };
    let pooled_s = {
        let pooled =
            PooledEvaluator::with_threads(Arc::new(AntSimEvaluator::fast()), threads);
        b.case("rust_sim_batch32_pooled", || {
            pooled.evaluate_batch(&batch).unwrap()
        })
        .median_s()
    };
    b.metric("pool_threads", threads as f64, "threads");
    b.metric(
        "batch32_pool_speedup",
        serial_s / pooled_s,
        "x (acceptance: > 2 on 4 cores)",
    );

    // the columnar rows API (§Perf tentpole): same batch as a contiguous
    // matrix, workers writing disjoint preallocated objective rows
    let rows_pooled_s = {
        let pooled =
            PooledEvaluator::with_threads(Arc::new(AntSimEvaluator::fast()), threads);
        let data: Vec<f64> = batch.iter().flat_map(|(g, _)| g.clone()).collect();
        let seeds: Vec<u32> = batch.iter().map(|(_, s)| *s).collect();
        let mut out = vec![0.0; batch.len() * 3];
        b.case("rust_sim_batch32_rows_pooled", || {
            pooled
                .evaluate_rows(RowsView::new(&data, 2), &seeds, &mut out)
                .unwrap()
        })
        .median_s()
    };
    b.metric("batch32_rows_over_tuples", pooled_s / rows_pooled_s, "x");

    // the replication wrapper flattens genomes x seeds into one inner
    // batch; pooled underneath, its 5 seeds cost well under 5x a single
    let replicated_pooled = ReplicatedEvaluator::new(
        Arc::new(PooledEvaluator::with_threads(
            Arc::new(AntSimEvaluator::fast()),
            threads,
        )),
        5,
    );
    let single_fast_s = {
        let fast = AntSimEvaluator::fast();
        let mut s = 500u32;
        b.case("rust_sim_single_fast", || {
            s += 1;
            fast.evaluate(&[50.0, 10.0], s).unwrap()
        })
        .median_s()
    };
    let five_s = {
        let mut s = 0u32;
        b.case("rust_sim_replicated5_pooled", || {
            s += 1;
            replicated_pooled.evaluate(&[50.0, 10.0], s).unwrap()
        })
        .median_s()
    };
    b.metric(
        "replication5_pooled_cost_ratio",
        five_s / single_fast_s,
        "x (ideal << 5)",
    );

    if !ArtifactManifest::available() {
        println!("(artifacts not built; pjrt sweep skipped)");
        if let Err(e) = b.write_json() {
            eprintln!("could not write bench json: {e}");
        }
        return;
    }
    let pjrt = PjrtEvaluator::from_default_artifacts(1).expect("pjrt");

    for &batch in &[1usize, 8, 32, 64] {
        let jobs: Vec<(Vec<f64>, u32)> = (0..batch)
            .map(|i| (vec![125.0, 30.0 + i as f64, 10.0], 7000 + i as u32))
            .collect();
        let m = b.case(&format!("pjrt_batch{batch}"), || {
            pjrt.evaluate_batch(&jobs).unwrap()
        });
        let per_eval = m.median_s() / batch as f64;
        b.metric(
            &format!("pjrt_batch{batch}_per_eval"),
            per_eval * 1e3,
            "ms/eval",
        );
    }

    // the replicated evaluator leans on evaluate_batch: its 5 seeds should
    // cost well under 5x a single evaluation
    let single = {
        let mut s = 100u32;
        b.case("pjrt_single_again", || {
            s += 1;
            pjrt.evaluate(&[50.0, 10.0], s).unwrap()
        })
        .median_s()
    };
    let replicated = ReplicatedEvaluator::new(Arc::new(pjrt), 5);
    let mut s2 = 0u32;
    let five = b
        .case("pjrt_replicated5", || {
            s2 += 1;
            replicated.evaluate(&[50.0, 10.0], s2).unwrap()
        })
        .median_s();
    b.metric("replication5_cost_ratio", five / single, "x (ideal < 5)");
    if let Err(e) = b.write_json() {
        eprintln!("could not write bench json: {e}");
    }
}
