//! P3 (§tentpole): broker makespan on a 10k-job wave, healthy vs a
//! 20%-failing backend mix, across dispatch policies.
//!
//! Fleet: a 48-node PBS cluster at reference speed plus a 48-node SGE
//! cluster whose nodes are 2.5× slower. In the failing mix the fast
//! cluster additionally drops 20% of submissions (FlakyEnv), so the
//! broker must detect, re-route and pay resubmission latency. Jobs are
//! submitted in waves (as the GA engines do), which is what lets the
//! EWMA policy learn per-backend throughput between waves; round-robin
//! keeps splitting evenly and eats the slow cluster's makespan.
//!
//! Acceptance (ISSUE 2): EWMA beats round-robin makespan on the failing
//! mix — recorded as `failing20_rr_over_ewma` in `BENCH_p3_broker.json`
//! (> 1 means EWMA wins).
//!
//! Knobs: `P3_BROKER_JOBS` (default 10000; CI smoke uses fewer),
//! `P3_BROKER_WAVE` (default 500), `BENCH_OUT_DIR`.

use std::sync::Arc;

use molers::bench::Bench;
use molers::broker::{policy, Broker, FlakyEnv, SpeculationConfig};
use molers::core::Context;
use molers::dsl::ClosureTask;
use molers::environment::cluster::{BatchEnvironment, InfraModel, SimCluster};
use molers::environment::{Environment, Job};
use molers::exec::ThreadPool;
use molers::gridscale::shell::Flavor;
use molers::gridscale::SgeAdapter;

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

const JOB_COST_S: f64 = 10.0;
const FAST_NODES: usize = 48;
const SLOW_NODES: usize = 48;
const SLOW_FACTOR: f64 = 2.5;
const FAILURE_RATE: f64 = 0.2;

fn fleet(
    pool: &Arc<ThreadPool>,
    policy_name: &str,
    failing: bool,
    speculative: bool,
    seed: u64,
) -> Broker {
    let fast: Arc<dyn Environment> = {
        let pbs = Arc::new(BatchEnvironment::pbs(FAST_NODES, Arc::clone(pool), seed));
        if failing {
            Arc::new(FlakyEnv::new(pbs, FAILURE_RATE, seed ^ 0xFA11))
        } else {
            pbs
        }
    };
    let slow: Arc<dyn Environment> = Arc::new(BatchEnvironment::new(
        format!("sge-slow({SLOW_NODES})"),
        Arc::new(SgeAdapter),
        Flavor::Sge,
        SimCluster::homogeneous(SLOW_NODES, SLOW_FACTOR),
        InfraModel::cluster(),
        Arc::clone(pool),
        seed ^ 0x510,
    ));
    let builder = Broker::builder(format!("p3[{policy_name}]"))
        .backend(fast, FAST_NODES)
        .backend(slow, SLOW_NODES)
        .policy(policy::by_name(policy_name).expect("known policy"));
    if speculative {
        builder
            .speculation(SpeculationConfig {
                quantile: 0.95,
                min_samples: 64,
            })
            .build()
            .unwrap()
    } else {
        builder.no_speculation().build().unwrap()
    }
}

/// Push `jobs` cost-10s jobs through the broker in waves, draining each
/// wave before the next (the engines' shape). Returns the virtual
/// makespan.
fn run_campaign(broker: &Broker, jobs: usize, wave: usize) -> f64 {
    let task = Arc::new(ClosureTask::new("unit", |_: &Context| Ok(Context::new())).cost(JOB_COST_S));
    let mut remaining = jobs;
    while remaining > 0 {
        let k = remaining.min(wave);
        let handles: Vec<_> = (0..k)
            .map(|_| broker.submit(Job::new(Arc::clone(&task) as _, Context::new())))
            .collect();
        for h in handles {
            h.wait().expect("broker must rescue every job");
        }
        remaining -= k;
    }
    broker.stats().virtual_makespan
}

fn main() {
    let jobs = env_usize("P3_BROKER_JOBS", 10_000);
    let wave = env_usize("P3_BROKER_WAVE", 500).max(1);
    let threads = std::thread::available_parallelism()
        .map(|t| t.get())
        .unwrap_or(4);
    let pool = Arc::new(ThreadPool::new(threads));
    println!(
        "{jobs} jobs x {JOB_COST_S}s in waves of {wave}; fleet: pbs {FAST_NODES}@1.0 \
         + sge {SLOW_NODES}@{SLOW_FACTOR} (failing mix: {}% loss on pbs)",
        (FAILURE_RATE * 100.0) as u32
    );

    let mut b = Bench::new("p3_broker").warmup(0).samples(1);
    let mut makespans: Vec<(String, f64)> = Vec::new();

    for (mix, failing) in [("healthy", false), ("failing20", true)] {
        for pol in ["roundrobin", "least", "ewma"] {
            let broker = fleet(&pool, pol, failing, false, 7);
            let mut makespan = 0.0;
            b.case(&format!("{mix}_{pol}_wall"), || {
                makespan = run_campaign(&broker, jobs, wave);
            });
            let s = broker.stats();
            assert_eq!(s.completed as usize, jobs, "{mix}/{pol} lost jobs");
            b.metric(&format!("{mix}_{pol}_makespan"), makespan, "virtual s");
            if failing {
                b.metric(
                    &format!("{mix}_{pol}_reroutes"),
                    broker.counters().reroutes as f64,
                    "jobs",
                );
            }
            makespans.push((format!("{mix}_{pol}"), makespan));
        }
    }

    let get = |k: &str| {
        makespans
            .iter()
            .find(|(n, _)| n == k)
            .map(|(_, v)| *v)
            .unwrap_or(f64::NAN)
    };
    b.metric(
        "healthy_rr_over_ewma",
        get("healthy_roundrobin") / get("healthy_ewma"),
        "x (> 1 = ewma wins)",
    );
    b.metric(
        "failing20_rr_over_ewma",
        get("failing20_roundrobin") / get("failing20_ewma"),
        "x (acceptance: > 1)",
    );

    // straggler cloning on top of EWMA, failing mix
    {
        let broker = fleet(&pool, "ewma", true, true, 7);
        let makespan = run_campaign(&broker, jobs, wave);
        let c = broker.counters();
        b.metric("failing20_ewma_spec_makespan", makespan, "virtual s");
        b.metric("speculative_launched", c.speculative_launched as f64, "jobs");
        b.metric("speculative_wins", c.speculative_wins as f64, "jobs");
    }

    if let Err(e) = b.write_json() {
        eprintln!("could not write bench json: {e}");
    }
}
