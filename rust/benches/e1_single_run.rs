//! E1 (Listing 2): latency of a single model execution — the unit the
//! paper's "~36 s on a grid core" cost model builds on. Compares the PJRT
//! (JAX+Pallas AOT) backend against the pure-Rust twin, plus the workflow
//! engine's per-job overhead on top.

use std::sync::Arc;

use molers::bench::Bench;
use molers::evolution::{AntSimEvaluator, Evaluator};
use molers::prelude::*;
use molers::runtime::{ArtifactManifest, PjrtEvaluator};

fn main() {
    let mut b = Bench::new("e1_single_run").warmup(1).samples(7);

    if ArtifactManifest::available() {
        let pjrt = PjrtEvaluator::from_default_artifacts(1).expect("pjrt");
        let mut seed = 0u32;
        b.case("pjrt_eval_1000ticks", || {
            seed = seed.wrapping_add(1);
            pjrt.evaluate(&[125.0, 50.0, 10.0], seed).unwrap()
        });
    } else {
        println!("(artifacts not built; skipping pjrt case)");
    }

    let rust_sim = AntSimEvaluator::new();
    let mut seed = 0u32;
    b.case("rust_sim_eval_1000ticks", || {
        seed = seed.wrapping_add(1);
        rust_sim.evaluate(&[50.0, 10.0], seed).unwrap()
    });

    // workflow-engine overhead: the same evaluation as a single-capsule
    // puzzle (Listing 2 shape) on a local environment
    let (evaluator, _) = molers::runtime::best_available_evaluator(1);
    let seed_val = val_u32("seed");
    let food1 = val_f64("food1");
    let mut n = 0u32;
    b.case("workflow_single_task", || {
        n = n.wrapping_add(1);
        let ev = Arc::clone(&evaluator);
        let f1 = food1.clone();
        let sv = seed_val.clone();
        let task = ClosureTask::new("ants", move |ctx: &Context| {
            let fit = ev.evaluate(&[125.0, 50.0, 10.0], ctx.get(&sv)?)?;
            Ok(Context::new().with(&f1, fit[0]))
        })
        .input(&seed_val)
        .output(&food1);
        let builder = PuzzleBuilder::new();
        builder.task(task);
        let init = Context::new().with(&seed_val, n);
        let p = builder.build_with(&init).unwrap();
        MoleExecution::new(p, Arc::new(LocalEnvironment::new(1)), u64::from(n))
            .start_with(init)
            .unwrap()
    });
}
