//! E4 (Listing 5 + §4.6 headline): the island model on the simulated EGI.
//!
//! The paper's claim: 2,000 concurrent 1-hour islands evaluate a 200,000-
//! individual population in one hour — i.e. sustained throughput of
//! 200,000 evaluations per hour of virtual grid time. We run scaled
//! configurations with REAL evaluations, measure virtual throughput, and
//! check the linear-scaling shape that underlies the extrapolation.

use std::sync::Arc;

use molers::bench::Bench;
use molers::environment::egi::EgiEnvironment;
use molers::environment::Environment;
use molers::evolution::{IslandConfig, IslandSteadyGA, Nsga2Config};
use molers::exec::ThreadPool;
use molers::metrics::throughput_per_hour;
use molers::prelude::*;
use molers::runtime::best_available_evaluator;

fn config(mu: usize) -> Nsga2Config {
    let d = val_f64("gDiffusionRate");
    let e = val_f64("gEvaporationRate");
    let m1 = val_f64("med1");
    let m2 = val_f64("med2");
    let m3 = val_f64("med3");
    Nsga2Config::new(mu, &[(&d, 0.0, 99.0), (&e, 0.0, 99.0)], &[&m1, &m2, &m3], 0.01)
        .unwrap()
}

fn main() {
    let mut b = Bench::new("e4_island").warmup(0).samples(1);
    let (evaluator, kind) = best_available_evaluator(2);
    println!("backend: {kind}");

    let mut results = Vec::new();
    for &islands in &[8usize, 16, 32] {
        let pool = Arc::new(ThreadPool::default_size());
        let env = EgiEnvironment::new("biomed", islands, pool, 11);
        let ga = IslandSteadyGA::new(
            config(200),
            IslandConfig {
                concurrent_islands: islands,
                // paper-shaped islands: 100 evaluations x 36 s nominal =
                // one virtual hour per island (Listing 5's Timed(1 hour)),
                // one island per slot
                total_evaluations: islands as u64 * 100,
                island_sample: 50,
                evals_per_island: 100,
            },
            Arc::clone(&evaluator),
        );
        let mut out = None;
        b.case(&format!("islands_{islands}_real"), || {
            out = Some(ga.run(&env, 5, None).unwrap());
        });
        let r = out.unwrap();
        let tput = throughput_per_hour(r.evaluations, r.virtual_makespan);
        b.metric(
            &format!("islands_{islands}_virtual_tput"),
            tput,
            "evals/virtual-hour",
        );
        b.metric(
            &format!("islands_{islands}_extrapolated_2000"),
            tput * 2000.0 / islands as f64,
            "evals/hour (paper: 200000)",
        );
        // the paper's islands are *timed* (1 h each): a slow worker simply
        // evaluates less, so stragglers never stretch the wall hour. Our
        // fixed-eval islands overrun on slow nodes, which deflates the
        // makespan-based number. Sustained throughput (per-slot busy time)
        // is the closer mirror of "200,000 evaluated in one hour":
        let stats = env.stats();
        let busy_per_slot = stats.virtual_cpu_s / islands as f64;
        let sustained = throughput_per_hour(r.evaluations, busy_per_slot);
        b.metric(
            &format!("islands_{islands}_sustained_2000"),
            sustained * 2000.0 / islands as f64,
            "evals/hour sustained (paper: 200000)",
        );
        results.push((islands, tput));
    }

    // the headline's underlying shape: throughput grows ~linearly in islands
    let (i0, t0) = results[0];
    let (i1, t1) = results[results.len() - 1];
    let scaling = (t1 / t0) / (i1 as f64 / i0 as f64);
    b.metric("scaling_efficiency", scaling * 100.0, "% of linear");
    assert!(
        scaling > 0.5,
        "island throughput should scale near-linearly, got {scaling:.2}"
    );
}
