//! A1 (§2.2): the same 64-evaluation workload on every environment the
//! paper lists, switched by one line. Reports each environment's virtual
//! makespan — the latency/queueing trade-offs that motivate choosing an
//! environment "matched with the application's characteristics".

use std::sync::Arc;

use molers::bench::Bench;
use molers::environment::cluster::BatchEnvironment;
use molers::environment::egi::EgiEnvironment;
use molers::environment::local::LocalEnvironment;
use molers::environment::ssh::SshEnvironment;
use molers::environment::{run_all, Environment, Job};
use molers::exec::ThreadPool;
use molers::prelude::*;

fn main() {
    let mut b = Bench::new("a1_environments").warmup(0).samples(1);
    const JOBS: usize = 64;
    const NODES: usize = 16;

    let x = val_f64("x");
    let task = Arc::new(
        ClosureTask::new("model", {
            let x = x.clone();
            move |ctx: &Context| Ok(Context::new().with(&x, ctx.get(&x).unwrap_or(0.0)))
        })
        .cost(36.0), // one paper-scale NetLogo run
    );

    let pool = Arc::new(ThreadPool::default_size());
    let envs: Vec<Arc<dyn Environment>> = vec![
        Arc::new(LocalEnvironment::with_pool(Arc::clone(&pool))),
        Arc::new(SshEnvironment::new("calc01", NODES, Arc::clone(&pool), 1)),
        Arc::new(BatchEnvironment::pbs(NODES, Arc::clone(&pool), 2)),
        Arc::new(BatchEnvironment::slurm(NODES, Arc::clone(&pool), 3)),
        Arc::new(BatchEnvironment::sge(NODES, Arc::clone(&pool), 4)),
        Arc::new(BatchEnvironment::oar(NODES, Arc::clone(&pool), 5)),
        Arc::new(BatchEnvironment::condor(NODES, Arc::clone(&pool), 6)),
        Arc::new(EgiEnvironment::new("biomed", NODES, Arc::clone(&pool), 7)),
    ];

    println!(
        "\n{JOBS} jobs x 36 s nominal on {NODES} nodes; ideal exec = {} s\n",
        36 * JOBS / NODES
    );
    for env in &envs {
        let jobs: Vec<Job> = (0..JOBS)
            .map(|i| {
                Job::new(
                    Arc::clone(&task) as Arc<dyn molers::dsl::Task>,
                    Context::new().with(&x, i as f64),
                )
            })
            .collect();
        let mut makespan = 0.0f64;
        b.case(&format!("submit_{}", env.name()), || {
            let results = run_all(env.as_ref(), jobs_clone(&jobs, &x, &task));
            makespan = results
                .into_iter()
                .map(|r| r.unwrap().1.virtual_end)
                .fold(0.0, f64::max);
        });
        let stats = env.stats();
        b.metric(
            &format!("{}_virtual_makespan", env.name()),
            makespan,
            "s",
        );
        if stats.resubmissions > 0 {
            b.metric(
                &format!("{}_resubmissions", env.name()),
                stats.resubmissions as f64,
                "jobs",
            );
        }
    }
}

fn jobs_clone(
    jobs: &[Job],
    x: &molers::core::Val<f64>,
    task: &Arc<molers::dsl::ClosureTask>,
) -> Vec<Job> {
    jobs.iter()
        .enumerate()
        .map(|(i, j)| {
            Job::new(
                Arc::clone(task) as Arc<dyn molers::dsl::Task>,
                Context::new().with(x, i as f64),
            )
            .released_at(j.virtual_release)
        })
        .collect()
}
