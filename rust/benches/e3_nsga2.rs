//! E3 (Listing 4): generational NSGA-II calibration of the ant model.
//! Scaled from the paper's (mu=10, lambda=10, 100 generations) to a bench-
//! friendly generation count; reports end-to-end time, evaluation
//! throughput, and the Pareto-front shape (the compromise between the
//! three food sources the paper predicts).

use std::sync::Arc;

use molers::bench::Bench;
use molers::evolution::{GenerationalGA, Nsga2Config, ReplicatedEvaluator};
use molers::prelude::*;
use molers::runtime::best_available_evaluator;

fn main() {
    let mut b = Bench::new("e3_nsga2").warmup(0).samples(3);
    let (base, kind) = best_available_evaluator(2);
    println!("backend: {kind}");

    let d = val_f64("gDiffusionRate");
    let e = val_f64("gEvaporationRate");
    let m1 = val_f64("med1");
    let m2 = val_f64("med2");
    let m3 = val_f64("med3");
    let config = Nsga2Config::new(
        10,
        &[(&d, 0.0, 99.0), (&e, 0.0, 99.0)],
        &[&m1, &m2, &m3],
        0.01,
    )
    .unwrap();

    let env = LocalEnvironment::new(
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
    );

    // paper-shaped run, generations scaled 100 -> 10 for the bench
    let evaluator = Arc::new(ReplicatedEvaluator::new(Arc::clone(&base), 3));
    let ga = GenerationalGA::new(config.clone(), evaluator, 10);
    let mut seed = 0u64;
    let mut last = None;
    b.case("mu10_lambda10_10gens_3reps", || {
        seed += 1;
        let r = ga.run(&env, 10, seed).unwrap();
        last = Some(r.evaluations);
        r
    });
    if let Some(evals) = last {
        b.metric("evaluations_per_run", evals as f64, "evals");
    }

    // Pareto-shape check the paper predicts: a compromise front, with the
    // near source (f1) emptying no later than the far source (f3)
    let ga_front = GenerationalGA::new(
        config,
        Arc::new(ReplicatedEvaluator::new(base, 3)),
        10,
    );
    let result = ga_front.run(&env, 15, 7).unwrap();
    let ok_order = result
        .pareto_front
        .iter()
        .filter(|i| i.objectives[0] <= i.objectives[2])
        .count();
    b.metric(
        "front_points_near_before_far",
        ok_order as f64 / result.pareto_front.len().max(1) as f64 * 100.0,
        "%",
    );
    b.metric("front_size", result.pareto_front.len() as f64, "points");
}
