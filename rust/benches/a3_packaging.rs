//! A3 (§3): the packaging comparison behind OpenMOLE's CDE → CARE switch.
//! Re-execution success rate of (no packaging | CDE | CARE) across a
//! heterogeneous simulated grid fleet, plus carball pack/parse throughput.

use molers::bench::Bench;
use molers::care::{
    reexec::{fleet_success_rate, Packager, RemoteHost},
    Archive, Dependency, KernelVersion, Manifest,
};
use molers::prelude::Rng;

fn netlogo_manifest(packaged_on: KernelVersion) -> Manifest {
    Manifest::new(
        "ants",
        "java -jar netlogo.jar --headless --model ants.nlogo",
        packaged_on,
    )
    .with(Dependency::lib("/lib/x86_64/libc.so.6", "2.17"))
    .with(Dependency::lib("/lib/x86_64/libz.so.1", "1.2.8"))
    .with(Dependency::interpreter("/usr/bin/java", "1.8.0_45"))
    .with(Dependency::data("/opt/models/ants.nlogo"))
    .with(Dependency::data("/opt/netlogo/netlogo.jar"))
}

fn main() {
    let mut b = Bench::new("a3_packaging").warmup(1).samples(5);

    // fleet: 1000 heterogeneous grid workers
    let app_new = netlogo_manifest(KernelVersion(3, 10, 0)); // modern desktop
    let app_sl = netlogo_manifest(KernelVersion::SCIENTIFIC_LINUX); // §3.1 rule
    let mut rng = Rng::new(42);
    let fleet: Vec<RemoteHost> = (0..1000)
        .map(|i| RemoteHost::random_grid_worker(i, &app_new, &mut rng))
        .collect();

    println!("\nre-execution success over {} simulated grid workers:", fleet.len());
    for (label, app) in [("packaged_on_3.10", &app_new), ("packaged_on_2.6.32", &app_sl)] {
        for packager in [Packager::None, Packager::Cde, Packager::Care] {
            let rate = fleet_success_rate(app, packager, &fleet);
            b.metric(&format!("{label}/{packager:?}"), rate * 100.0, "% success");
        }
    }
    // the paper's two claims, asserted:
    assert_eq!(
        fleet_success_rate(&app_new, Packager::Care, &fleet),
        1.0,
        "CARE must re-execute everywhere (syscall emulation)"
    );
    assert!(
        fleet_success_rate(&app_new, Packager::Cde, &fleet)
            < fleet_success_rate(&app_sl, Packager::Cde, &fleet),
        "CDE should benefit from the old-kernel packaging rule of thumb"
    );

    // carball mechanics
    let archive = Archive::pack(app_new.clone(), true);
    b.metric("archive_size", archive.size_bytes() as f64, "bytes");
    b.case("pack", || Archive::pack(app_new.clone(), true));
    let bytes = archive.to_bytes();
    b.case("serialize", || archive.to_bytes());
    b.case("parse", || Archive::from_bytes(&bytes).unwrap());
}
