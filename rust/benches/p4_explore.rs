//! P4 (§Exploration): plain design of experiments at the paper's
//! calibration scale. PR 3 proved a 200k-individual GA wave runs
//! allocation-free; this bench pins the same property for plain sweeps —
//! the workload the paper's title actually leads with. A steady-state
//! *explore wave* (clear the design matrix, regenerate the sampling,
//! evaluate every row through `evaluate_rows`) must perform **zero** heap
//! allocations, measured by the same counting global allocator as
//! `p2_scale` (`explore_wave_allocations`, acceptance 0, gated in CI).
//!
//! Knobs: `P4_EXPLORE_N` (design rows, default 200000; CI smoke uses a
//! small value), `P4_EXPLORE_CHUNK` (rows per evaluation chunk, default
//! 4096), `BENCH_OUT_DIR`.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use molers::bench::Bench;
use molers::core::val_f64;
use molers::evolution::{Evaluator, PooledEvaluator, RowsView, Zdt1Evaluator};
use molers::exploration::{row_seed, LhsSampling, SampleMatrix, Sampling, SobolSampling};
use molers::util::Rng;

/// Counting global allocator (see `p2_scale`): the zero-allocation claim
/// is measured, not asserted.
struct CountingAlloc;

static ALLOCATIONS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() {
    let n = env_usize("P4_EXPLORE_N", 200_000);
    let chunk = env_usize("P4_EXPLORE_CHUNK", 4096);
    let dim = 6;
    let threads = std::thread::available_parallelism()
        .map(|t| t.get())
        .unwrap_or(4);
    println!("design: {n} rows x {dim} dims, chunk {chunk}, {threads} threads");

    let mut b = Bench::new("p4_explore").warmup(1).samples(3);

    let vals: Vec<_> = (0..dim).map(|d| val_f64(&format!("x{d}"))).collect();
    let spec: Vec<_> = vals.iter().map(|v| (v, 0.0, 1.0)).collect();
    let lhs = LhsSampling::new(&spec, n);
    let sobol = SobolSampling::new(&spec, n);
    let serial = Zdt1Evaluator { dim };
    let pooled = PooledEvaluator::with_threads(Arc::new(Zdt1Evaluator { dim }), threads);

    // stage 1: design generation into a recycled matrix
    let mut design = SampleMatrix::new(lhs.columns());
    let mut rng = Rng::new(150_604_182);
    let lhs_s = {
        let m = b.case("sample_lhs", || {
            design.clear();
            lhs.sample_into(&mut design, &mut rng).unwrap();
        });
        m.median_s()
    };
    b.metric("samples_per_s_lhs", n as f64 / lhs_s, "rows/s");

    let mut sobol_design = SampleMatrix::new(sobol.columns());
    let sobol_s = {
        let m = b.case("sample_sobol", || {
            sobol_design.clear();
            sobol.sample_into(&mut sobol_design, &mut rng).unwrap();
        });
        m.median_s()
    };
    b.metric("samples_per_s_sobol", n as f64 / sobol_s, "rows/s");

    // stage 2: the full explore wave — regenerate the design, evaluate
    // every row in chunk-sized evaluate_rows calls into preallocated
    // objective rows. One matrix + one objective buffer, recycled forever.
    let seeds: Vec<u32> = (0..n).map(|r| row_seed(42, r)).collect();
    let mut objectives = vec![0.0f64; n * 2];
    let wave = |design: &mut SampleMatrix,
                rng: &mut Rng,
                objectives: &mut [f64],
                eval: &dyn Evaluator| {
        design.clear();
        lhs.sample_into(design, rng).unwrap();
        let mut lo = 0usize;
        while lo < n {
            let hi = (lo + chunk).min(n);
            eval.evaluate_rows(
                RowsView::new(design.rows_slice(lo, hi), dim),
                &seeds[lo..hi],
                &mut objectives[lo * 2..hi * 2],
            )
            .unwrap();
            lo = hi;
        }
    };

    let wave_serial_s = {
        let m = b.case("explore_wave", || {
            wave(&mut design, &mut rng, &mut objectives, &serial)
        });
        m.median_s()
    };
    // count allocations across pure steady-state waves (outside b.case,
    // whose own bookkeeping allocates)
    let before = ALLOCATIONS.load(Ordering::Relaxed);
    for _ in 0..3 {
        wave(&mut design, &mut rng, &mut objectives, &serial);
    }
    let wave_allocs = ALLOCATIONS.load(Ordering::Relaxed) - before;
    b.metric(
        "explore_wave_allocations",
        wave_allocs as f64,
        "allocs in 3 steady-state explore waves (acceptance: 0)",
    );
    b.metric("explore_rows_per_s", n as f64 / wave_serial_s, "rows/s");
    b.metric("explore_wave_s", wave_serial_s, "s");

    // parallel wave: same shape, workers writing disjoint objective rows
    let wave_pooled_s = {
        let m = b.case("explore_wave_pooled", || {
            wave(&mut design, &mut rng, &mut objectives, &pooled)
        });
        m.median_s()
    };
    b.metric("explore_pool_speedup", wave_serial_s / wave_pooled_s, "x");
    b.metric("explore_rows", n as f64, "rows");

    if let Err(e) = b.write_json() {
        eprintln!("could not write bench json: {e}");
    }
}
