//! A2 (§4.6): why islands? "The optimisation as we've done so far is not
//! perfectly suited for this kind of remote environments. In this case,
//! we'll use the Island model."
//!
//! Same evaluation budget, same simulated EGI: the generational GA pays
//! grid brokering latency on EVERY evaluation wave and synchronises each
//! generation; the island model pays it once per island. The virtual
//! makespans should differ by a large factor — the paper's implicit claim.

use std::sync::Arc;

use molers::bench::Bench;
use molers::environment::egi::EgiEnvironment;
use molers::evolution::{
    GenerationalGA, IslandConfig, IslandSteadyGA, Nsga2Config, Zdt1Evaluator,
};
use molers::exec::ThreadPool;
use molers::prelude::*;

fn config(mu: usize) -> Nsga2Config {
    let x0 = val_f64("x0");
    let x1 = val_f64("x1");
    let f1 = val_f64("f1");
    let f2 = val_f64("f2");
    Nsga2Config::new(mu, &[(&x0, 0.0, 1.0), (&x1, 0.0, 1.0)], &[&f1, &f2], 0.0).unwrap()
}

fn main() {
    let mut b = Bench::new("a2_island_vs_generational").warmup(0).samples(1);
    const BUDGET: u64 = 640; // evaluations
    const NODES: usize = 16;
    // fast analytic fitness so the bench isolates coordination costs;
    // nominal cost 1 s/eval on the virtual grid
    let evaluator = Arc::new(Zdt1Evaluator { dim: 2 });

    // generational: mu=16, lambda=16 -> 39 waves of 16 evals + init
    let pool = Arc::new(ThreadPool::default_size());
    let env_gen = EgiEnvironment::new("biomed", NODES, Arc::clone(&pool), 21);
    let ga = GenerationalGA::new(config(16), Arc::clone(&evaluator) as _, 16);
    let mut gen_makespan = 0.0;
    b.case("generational_640evals", || {
        let r = ga.run(&env_gen, (BUDGET / 16 - 1) as u32, 1).unwrap();
        gen_makespan = r.virtual_makespan;
    });

    // islands: same budget, 16 concurrent islands of 40 evals each
    let env_isl = EgiEnvironment::new("biomed", NODES, Arc::clone(&pool), 22);
    let island = IslandSteadyGA::new(
        config(16),
        IslandConfig {
            concurrent_islands: NODES,
            total_evaluations: BUDGET,
            island_sample: 8,
            evals_per_island: 40,
        },
        Arc::clone(&evaluator) as _,
    );
    let mut isl_makespan = 0.0;
    b.case("island_640evals", || {
        let r = island.run(&env_isl, 1, None).unwrap();
        isl_makespan = r.virtual_makespan;
    });

    b.metric("generational_virtual_makespan", gen_makespan, "s");
    b.metric("island_virtual_makespan", isl_makespan, "s");
    b.metric("island_speedup", gen_makespan / isl_makespan, "x");
    assert!(
        isl_makespan < gen_makespan,
        "islands must beat generational on a high-latency grid \
         ({isl_makespan} vs {gen_makespan})"
    );
}
