//! P7 (§Out-of-core tentpole): the streaming explore wave against a
//! memory-budgeted [`RowStore`]. The same block loop the streaming sweep
//! runs — regenerate a sobol window with `sample_into_block`, evaluate
//! it, `write_rows` the objectives into the store, then fold every block
//! back out in strict row order with `copy_rows` — is timed twice: once
//! over the contiguous in-RAM backing, once over the chunk-paged spill
//! backing under a budget far below the result-set size. Gated in CI:
//! `spill_overhead` (spilled / in-RAM wall time, acceptance ≤ 1.5×) and
//! `spill_wave_allocations` (heap allocations across steady-state spilled
//! waves, acceptance 0 — the slot arena is recycled, page-outs serialise
//! through one retained byte buffer).
//!
//! Knobs: `P7_N` (design rows, default 200000; CI smoke uses a small
//! value), `P7_CHUNK` (rows per block, default 4096), `P7_BUDGET`
//! (resident bytes for the spilled store, default 4 MiB),
//! `BENCH_OUT_DIR`.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use molers::bench::Bench;
use molers::core::val_f64;
use molers::evolution::{Evaluator, RowsView, Zdt1Evaluator};
use molers::exploration::{row_seed, RowStore, SampleMatrix, Sampling, SobolSampling};
use molers::util::Rng;

/// Counting global allocator (see `p2_scale`): the zero-allocation claim
/// is measured, not asserted.
struct CountingAlloc;

static ALLOCATIONS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() {
    let n = env_usize("P7_N", 200_000);
    let chunk = env_usize("P7_CHUNK", 4096).max(1);
    let budget = env_usize("P7_BUDGET", 4 << 20) as u64;
    let dim = 4;
    let n_obj = 2;
    println!(
        "design: {n} rows x {dim} dims, block {chunk}, budget {budget} B \
         (result set {} B)",
        n * n_obj * 8
    );

    let mut b = Bench::new("p7_outofcore").warmup(1).samples(3);

    let vals: Vec<_> = (0..dim).map(|d| val_f64(&format!("x{d}"))).collect();
    let spec: Vec<_> = vals.iter().map(|v| (v, 0.0, 1.0)).collect();
    let sobol = SobolSampling::new(&spec, n);
    let eval = Zdt1Evaluator { dim };
    let seeds: Vec<u32> = (0..n).map(|r| row_seed(42, r)).collect();

    let spill_dir = std::env::temp_dir().join(format!("molers-bench-p7-{}", std::process::id()));

    // the streaming wave: window-sampled design, block evaluation,
    // write_rows into the store, then an ordered copy_rows fold-back —
    // every buffer recycled across waves
    let wave = |store: &mut RowStore,
                window: &mut SampleMatrix,
                obj: &mut Vec<f64>,
                read: &mut Vec<f64>,
                rng: &mut Rng|
     -> f64 {
        store.clear();
        store.grow_rows(n);
        let mut lo = 0usize;
        while lo < n {
            let hi = (lo + chunk).min(n);
            window.clear();
            sobol.sample_into_block(window, lo..hi, rng).unwrap();
            obj.clear();
            obj.resize((hi - lo) * n_obj, 0.0);
            eval.evaluate_rows(
                RowsView::new(window.rows_slice(0, hi - lo), dim),
                &seeds[lo..hi],
                &mut obj[..],
            )
            .unwrap();
            store.write_rows(lo, obj);
            lo = hi;
        }
        let mut acc = 0.0;
        let mut lo = 0usize;
        while lo < n {
            let hi = (lo + chunk).min(n);
            store.copy_rows(lo, hi, read);
            acc += read.iter().sum::<f64>();
            lo = hi;
        }
        acc
    };

    let mut window = SampleMatrix::new(sobol.columns());
    let mut obj = vec![0.0f64; chunk * n_obj];
    let mut read = vec![0.0f64; chunk * n_obj];
    let mut rng = Rng::new(150_604_182);

    let mut ram = RowStore::ram_with_capacity(n_obj, n);
    let ram_s = {
        let m = b.case("wave_ram", || {
            std::hint::black_box(wave(&mut ram, &mut window, &mut obj, &mut read, &mut rng));
        });
        m.median_s()
    };

    let mut spill = RowStore::spilled(n_obj, &spill_dir, budget, chunk).unwrap();
    let spill_s = {
        let m = b.case("wave_spill", || {
            std::hint::black_box(wave(&mut spill, &mut window, &mut obj, &mut read, &mut rng));
        });
        m.median_s()
    };

    // steady-state allocation count (outside b.case, whose bookkeeping
    // allocates): the spill arena is warm, so waves must be alloc-free
    let before = ALLOCATIONS.load(Ordering::Relaxed);
    for _ in 0..3 {
        std::hint::black_box(wave(&mut spill, &mut window, &mut obj, &mut read, &mut rng));
    }
    let wave_allocs = ALLOCATIONS.load(Ordering::Relaxed) - before;

    b.metric("spill_overhead", spill_s / ram_s, "x (spilled / in-RAM wave)");
    b.metric(
        "spill_wave_allocations",
        wave_allocs as f64,
        "allocs in 3 steady-state spilled waves (acceptance: 0)",
    );
    b.metric(
        "peak_resident_bytes",
        spill.peak_resident_bytes() as f64,
        "bytes resident under the budget",
    );
    b.metric("outofcore_rows_per_s", n as f64 / spill_s, "rows/s");
    b.metric("outofcore_rows", n as f64, "rows");

    drop(spill);
    let _ = std::fs::remove_dir_all(&spill_dir);

    if let Err(e) = b.write_json() {
        eprintln!("could not write bench json: {e}");
    }
}
