//! E2 (Listing 3): the 5-seed replication + median workflow. Measures the
//! full explore → model×5 → aggregate → statistic pipeline and reports the
//! stabilisation effect replication buys (spread of single evaluations vs
//! spread of medians) — the reason §4.4 exists.

use std::sync::Arc;

use molers::bench::Bench;
use molers::evolution::Evaluator;
use molers::prelude::*;
use molers::runtime::best_available_evaluator;
use molers::util::stats;

fn replication_workflow(
    evaluator: Arc<dyn Evaluator>,
    replications: usize,
    seed: u64,
) -> Context {
    let seed_val = val_u32("seed");
    let food1 = val_f64("food1");
    let med1 = val_f64("med1");
    let model = {
        let (s, f) = (seed_val.clone(), food1.clone());
        ClosureTask::new("ants", move |ctx: &Context| {
            let fit = evaluator.evaluate(&[125.0, 50.0, 10.0], ctx.get(&s)?)?;
            Ok(Context::new().with(&f, fit[0]))
        })
        .input(&seed_val)
        .output(&food1)
    };
    let stat = StatisticTask::new().statistic(&food1, &med1, Descriptor::Median);
    let b = PuzzleBuilder::new();
    replicate(&b, Arc::new(model), &seed_val, replications, Arc::new(stat));
    let result = MoleExecution::new(
        b.build().unwrap(),
        Arc::new(LocalEnvironment::new(4)),
        seed,
    )
    .start()
    .unwrap();
    result.outputs.into_iter().next().unwrap()
}

fn main() {
    let mut b = Bench::new("e2_replication").warmup(1).samples(5);
    let (evaluator, kind) = best_available_evaluator(2);
    println!("backend: {kind}");

    let mut seed = 0u64;
    b.case("replicate5_median_workflow", || {
        seed += 1;
        replication_workflow(Arc::clone(&evaluator), 5, seed)
    });

    // the scientific payoff: replication shrinks fitness noise
    let med1 = val_f64("med1");
    let singles: Vec<f64> = (0..20)
        .map(|s| evaluator.evaluate(&[125.0, 50.0, 10.0], s).unwrap()[0])
        .collect();
    let medians: Vec<f64> = (0..10)
        .map(|s| {
            replication_workflow(Arc::clone(&evaluator), 5, 1000 + s)
                .get(&med1)
                .unwrap()
        })
        .collect();
    b.metric("single_eval_stddev", stats::stddev(&singles), "ticks");
    b.metric("median5_stddev", stats::stddev(&medians), "ticks");
    assert!(
        stats::stddev(&medians) <= stats::stddev(&singles) * 1.2,
        "replication should not increase spread"
    );
}
