//! P8 (§Workload): cost of the synthetic-workload replay harness.
//!
//! Two questions, one suite:
//!
//! * **`replay_overhead`** — what does pushing a trace through the full
//!   harness (broker fleet + fair-share gate + lane threads + pacing)
//!   cost over running the exact same experiments directly, one after
//!   another? With one lane the harness adds only bookkeeping, so the
//!   ratio must stay near 1 (committed acceptance: ≤ 1.5× via
//!   `bench_gate`, loose for noisy CI runners).
//! * **concurrent replay** — a two-tenant mix over four lanes: every job
//!   must complete, and the weight-normalised Jain fairness index and
//!   evaluation throughput are recorded so a scheduling regression shows
//!   up as a metric cliff rather than a flaky test.
//!
//! Knobs: `P8_JOBS` (default 24; CI smoke uses fewer), `P8_ROWS`
//! (explore design-size ceiling, default 64), `BENCH_OUT_DIR`.

use molers::bench::Bench;
use molers::cli::{front, Args};
use molers::workflow::EnvSpec;
use molers::workload::{replay_local, ReplayConfig, ReplaySummary, TraceSpec};

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() {
    // pin the deterministic rust-sim evaluator with cheap evaluations:
    // the suite measures harness overhead, not model cost
    std::env::set_var("MOLERS_ARTIFACTS", "/nonexistent-artifacts");
    std::env::set_var("MOLERS_SIM_TICKS", "5");

    let jobs = env_usize("P8_JOBS", 24);
    let rows = env_usize("P8_ROWS", 64).max(8);
    let threads = std::thread::available_parallelism()
        .map(|t| t.get())
        .unwrap_or(4);
    let workdir = std::env::temp_dir().join(format!("molers-p8-{}", std::process::id()));
    std::fs::create_dir_all(&workdir).expect("bench workdir");
    println!("{jobs} explore jobs, rows {}..{rows}, {threads} local threads", rows / 2);

    let mut b = Bench::new("p8_workload").warmup(1).samples(3);

    let spec = TraceSpec::parse(&format!(
        "jobs={jobs};tenants=solo:1;mix=explore:1;rows={}..{rows};chunk=16",
        rows / 2
    ))
    .unwrap();
    let trace = spec.generate(8);

    // baseline: the very same experiments, run back-to-back with no
    // harness — same env capacity, same seeds, same result files
    let direct_s = b
        .case("direct_sequential", || {
            for job in &trace.jobs {
                let mut argv: Vec<String> = vec![job.run.clone()];
                argv.extend(job.argv.iter().cloned());
                argv.push("--seed".into());
                argv.push(job.seed.to_string());
                let out = workdir.join(format!("direct-{}.csv", job.idx));
                argv.push("--out".into());
                argv.push(out.to_string_lossy().into_owned());
                let args = Args::parse(argv).expect("generated argv parses");
                front::by_name(&job.run, &args)
                    .expect("generated job builds")
                    .env(EnvSpec::Single {
                        name: "local".into(),
                        nodes: threads,
                    })
                    .quiet()
                    .run()
                    .expect("direct run completes");
                let _ = std::fs::remove_file(out);
            }
        })
        .median_s();

    // the same trace through the full harness, one lane — pure overhead
    let cfg = ReplayConfig {
        envs: format!("local:{threads}"),
        lanes: 1,
        workdir: workdir.clone(),
        ..ReplayConfig::default()
    };
    let replay_s = b
        .case("replay_lane1", || {
            let records = replay_local(&trace, &cfg).expect("replay completes");
            assert!(records.iter().all(|r| r.ok), "no faults planned");
        })
        .median_s();
    b.metric(
        "replay_overhead",
        replay_s / direct_s.max(1e-9),
        "x direct sequential wall time (acceptance: <= 1.5)",
    );

    // two tenants over four lanes: completion + fairness + throughput
    let mspec = TraceSpec::parse(&format!(
        "jobs={jobs};tenants=alice:2,bob:1;mix=explore:1;rows={}..{rows};chunk=16",
        rows / 2
    ))
    .unwrap();
    let mtrace = mspec.generate(9);
    let mcfg = ReplayConfig {
        envs: format!("local:{threads}"),
        lanes: 4,
        workdir: workdir.clone(),
        ..ReplayConfig::default()
    };
    let mut summary: Option<ReplaySummary> = None;
    b.case("replay_lanes4_two_tenants", || {
        let records = replay_local(&mtrace, &mcfg).expect("replay completes");
        summary = Some(ReplaySummary::from_records(&records).with_weights(&mspec.tenants));
    });
    let s = summary.expect("case ran");
    assert_eq!(s.failed, 0, "every job completes");
    b.metric(
        "fairness_jain",
        s.fairness,
        "weight-normalised Jain index (1.0 = proportional shares)",
    );
    b.metric(
        "throughput_eval_per_s",
        s.evaluations as f64 / s.makespan_s.max(1e-9),
        "eval/s",
    );

    let _ = std::fs::remove_dir_all(&workdir);
    if let Err(e) = b.write_json() {
        eprintln!("could not write bench json: {e}");
    }
}
