//! P6 (§Durability): what does fsync-per-record durability cost?
//!
//! Two surfaces, one suite:
//!
//! * **submit-ack latency** — `Registry::submit` under
//!   `--durability always` journals and `fdatasync`s the submission
//!   before acknowledging; under `os` it only flushes. The per-ack
//!   microcosts are reported as informational metrics.
//! * **sweep throughput** — an end-to-end journaled sweep under `always`
//!   vs `os`. Checkpoints land once per chunk, so the fsync cost is
//!   amortized over chunk evaluation: the committed acceptance is
//!   **`fsync_overhead` ≤ 3×** the `os` wall time (gated in CI via
//!   `bench_gate`).
//!
//! Knobs: `P6_SUBMITS` (default 1000), `P6_N` (default 600, sweep rows;
//! CI smoke uses fewer), `BENCH_OUT_DIR`.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use molers::bench::Bench;
use molers::broker::{Durability, Journal};
use molers::environment::local::LocalEnvironment;
use molers::evolution::evaluator::Zdt1Evaluator;
use molers::prelude::*;
use molers::serve::Registry;

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("molers-p6-{}-{name}", std::process::id()))
}

/// A daemon's submission burst: open a state dir under the given policy
/// and register `count` experiments — each one journaled (and, under
/// `always`, fsync'd) before `submit` returns, exactly the serve ack
/// path.
fn submit_burst(dir: &Path, durability: Durability, count: usize) {
    let _ = std::fs::remove_dir_all(dir);
    let reg = Registry::open_with(dir, durability).unwrap();
    for _ in 0..count {
        reg.submit("bench", 1, "run", vec!["run".into()], None).unwrap();
    }
}

/// One journaled sweep: n rows in `chunk`-row blocks over a local
/// environment, checkpointing every block under the given policy.
fn run_sweep(n: usize, chunk: usize, durability: Durability, tag: &str) {
    let x = val_f64("x0");
    let y = val_f64("x1");
    let sampling = Arc::new(LhsSampling::new(&[(&x, 0.0, 1.0), (&y, 0.0, 1.0)], n));
    let out = tmp(&format!("{tag}.csv"));
    let jpath = tmp(&format!("{tag}.jsonl"));
    let writer = Arc::new(
        RowWriter::create(&out, TableFormat::Csv, &["x0", "x1", "f1", "f2"]).unwrap(),
    );
    let threads = std::thread::available_parallelism().map(|t| t.get()).unwrap_or(4);
    let env = LocalEnvironment::new(threads);
    Sweep::new(sampling, Arc::new(Zdt1Evaluator { dim: 2 }), &["f1", "f2"])
        .chunk(chunk)
        .writer(writer)
        .journal(Arc::new(Journal::create_with(&jpath, durability).unwrap()))
        .run_resumable(&env, 17, None)
        .unwrap();
}

fn main() {
    let submits = env_usize("P6_SUBMITS", 1000);
    let n = env_usize("P6_N", 600);
    let chunk = 8usize;
    println!("{submits} submit acks; {n}-row sweep in {chunk}-row chunks");

    let mut b = Bench::new("p6_durability").warmup(1).samples(3);

    let ack_dir = tmp("ack");
    let always_ack = b
        .case("submit_ack_always", || {
            submit_burst(&ack_dir, Durability::Always, submits)
        })
        .median_s();
    let os_ack = b
        .case("submit_ack_os", || submit_burst(&ack_dir, Durability::Os, submits))
        .median_s();
    b.metric(
        "submit_ack_always_us",
        always_ack / submits as f64 * 1e6,
        "us/ack (journal + fdatasync before the ack)",
    );
    b.metric(
        "submit_ack_os_us",
        os_ack / submits as f64 * 1e6,
        "us/ack (journal flush only)",
    );

    let always_s = b
        .case("sweep_always", || run_sweep(n, chunk, Durability::Always, "alw"))
        .median_s();
    let os_s = b
        .case("sweep_os", || run_sweep(n, chunk, Durability::Os, "os"))
        .median_s();
    b.metric(
        "fsync_overhead",
        always_s / os_s,
        "x os-durability sweep wall time (acceptance: <= 3.0)",
    );
    b.metric("sweep_rows_per_s_always", n as f64 / always_s.max(1e-9), "rows/s");

    for t in ["ack", "alw.csv", "alw.jsonl", "os.csv", "os.jsonl"] {
        let p = tmp(t);
        let _ = std::fs::remove_dir_all(&p);
        let _ = std::fs::remove_file(&p);
    }

    if let Err(e) = b.write_json() {
        eprintln!("could not write bench json: {e}");
    }
}
