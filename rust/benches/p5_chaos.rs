//! P5 (§Robustness): cost of the chaos harness and of surviving it.
//!
//! Two questions, one suite:
//!
//! * **`chaos_overhead`** — what does wrapping a backend in a
//!   [`FaultyEnv`] with an *empty* [`FaultPlan`] cost? The decorator sits
//!   on the submission hot path of every chaos test and of any `~plan`
//!   fleet spec, so pass-through must be free: the committed acceptance
//!   is ≤ 1.1× the bare backend (gated in CI via `bench_gate`).
//! * **chaos mix** — a fleet where one backend drops 20% of submissions
//!   and stretches 10% into stragglers, pushed through the broker with
//!   its default retry policy: every job must be rescued, and the
//!   resubmission traffic is recorded so a regression in the retry
//!   machinery (e.g. retries silently vanishing) shows up as a metric
//!   cliff rather than a flaky test.
//!
//! Knobs: `P5_CHAOS_JOBS` (default 20000; CI smoke uses fewer),
//! `BENCH_OUT_DIR`.

use std::sync::Arc;

use molers::bench::Bench;
use molers::broker::{Broker, FaultPlan, FaultyEnv};
use molers::core::Context;
use molers::dsl::ClosureTask;
use molers::environment::local::LocalEnvironment;
use molers::environment::{Environment, Job};
use molers::exec::ThreadPool;

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// Submit `jobs` trivial tasks in waves of 256 and drain each wave — the
/// engines' shape, dominated by submission + handle bookkeeping, which is
/// exactly the path the fault decorator intercepts.
fn run_jobs(env: &dyn Environment, jobs: usize) {
    let task = Arc::new(ClosureTask::new("unit", |_: &Context| Ok(Context::new())).cost(1.0));
    let mut remaining = jobs;
    while remaining > 0 {
        let k = remaining.min(256);
        let handles: Vec<_> = (0..k)
            .map(|_| env.submit(Job::new(Arc::clone(&task) as _, Context::new())))
            .collect();
        for h in handles {
            h.wait().expect("no faults planned — every job completes");
        }
        remaining -= k;
    }
}

fn main() {
    let jobs = env_usize("P5_CHAOS_JOBS", 20_000);
    let threads = std::thread::available_parallelism()
        .map(|t| t.get())
        .unwrap_or(4);
    println!("{jobs} trivial jobs, waves of 256, {threads} local threads");

    let mut b = Bench::new("p5_chaos").warmup(1).samples(3);

    // bare backend vs the same backend behind an empty fault plan
    let bare = LocalEnvironment::new(threads);
    let bare_s = b.case("bare_local", || run_jobs(&bare, jobs)).median_s();

    let wrapped = FaultyEnv::new(
        Arc::new(LocalEnvironment::new(threads)),
        FaultPlan::new(),
        0xC0DE,
    );
    let wrapped_s = b
        .case("empty_plan_passthrough", || run_jobs(&wrapped, jobs))
        .median_s();
    b.metric(
        "chaos_overhead",
        wrapped_s / bare_s,
        "x bare submission wall time (acceptance: <= 1.1)",
    );

    // chaos mix: drops + stragglers on one of two backends, default retry
    // policy — the broker must rescue every job
    let chaos_jobs = (jobs / 4).max(256);
    let pool = Arc::new(ThreadPool::new(threads));
    let broker = Broker::from_spec(
        &format!("local:{threads},local:{threads}~drop=0.2;delay=0.1:5"),
        pool,
        42,
    )
    .unwrap();
    let mut wall = 0.0;
    b.case("chaos_mix_rescue", || {
        let t0 = std::time::Instant::now();
        run_jobs(&broker, chaos_jobs);
        wall = t0.elapsed().as_secs_f64();
    });
    let s = broker.stats();
    assert_eq!(s.failed_jobs, 0, "default retry budget rescues everything");
    b.metric("chaos_mix_jobs", chaos_jobs as f64, "jobs");
    b.metric("chaos_mix_resubmissions", s.resubmissions as f64, "attempts");
    b.metric(
        "chaos_mix_reroutes",
        broker.counters().reroutes as f64,
        "jobs",
    );
    b.metric(
        "chaos_mix_rescued_per_s",
        chaos_jobs as f64 / wall.max(1e-9),
        "jobs/s",
    );

    if let Err(e) = b.write_json() {
        eprintln!("could not write bench json: {e}");
    }
}
