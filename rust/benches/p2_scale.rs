//! P2 (§Perf): the paper-scale claim. OpenMOLE's headline workload
//! evaluates a GA initialisation of 200,000 individuals in one hour
//! (arXiv:1506.04182 §4.6); the coordinator side of that wave — batch
//! evaluation, non-dominated ranking, environmental selection, variation —
//! must not be the bottleneck. PR 1 removed the ranking bottleneck; this
//! bench now pins the §Perf *columnar* engine: the same wave through
//! `PopMatrix` + `WaveArena` (`wave_reuse`), where genomes/objectives live
//! in contiguous matrices, offspring are bred in place, and — measured by
//! a counting global allocator — a steady-state wave performs **zero**
//! heap allocations. The old `population_clone` case (~24% of
//! `full_wave`) is gone because the clones themselves are gone.
//!
//! Knobs: `P2_SCALE_N` (wave size, default 200000; CI smoke uses a small
//! value), `P2_SCALE_MU` (survivors, default 200), `BENCH_OUT_DIR`.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use molers::bench::Bench;
use molers::core::val_f64;
use molers::evolution::{
    Bounds, Evaluator, NsgaScratch, Operators, PooledEvaluator, PopMatrix, RowsView,
    WaveArena, Zdt1Evaluator,
};
use molers::exec::ThreadPool;
use molers::util::Rng;

/// Counting global allocator: every `alloc`/`realloc`/`alloc_zeroed` bumps
/// a counter, which is how the `wave_reuse` zero-steady-state-allocation
/// acceptance criterion is measured rather than asserted.
struct CountingAlloc;

static ALLOCATIONS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() {
    let n = env_usize("P2_SCALE_N", 200_000);
    let mu = env_usize("P2_SCALE_MU", 200);
    let dim = 6;
    let threads = std::thread::available_parallelism()
        .map(|t| t.get())
        .unwrap_or(4);
    println!("wave: {n} individuals, mu = {mu}, {threads} threads");

    let mut b = Bench::new("p2_scale").warmup(1).samples(3);

    // the init wave's genomes + seeds (generation itself is not the claim)
    let mut rng = Rng::new(150_604_182);
    let jobs: Vec<(Vec<f64>, u32)> = (0..n)
        .map(|i| {
            let genome: Vec<f64> = (0..dim).map(|_| rng.f64()).collect();
            (genome, i as u32)
        })
        .collect();

    let pooled = PooledEvaluator::with_threads(Arc::new(Zdt1Evaluator { dim }), threads);
    let serial = Zdt1Evaluator { dim };

    // stage 1: batch evaluation — legacy tuple API, serial vs pooled
    let serial_s = b
        .case("evaluate_serial", || serial.evaluate_batch(&jobs).unwrap())
        .median_s();
    let pooled_s = b
        .case("evaluate_pooled", || pooled.evaluate_batch(&jobs).unwrap())
        .median_s();
    b.metric("evaluate_pool_speedup", serial_s / pooled_s, "x");
    // same name, same code path as the PR-1 baseline (tuple API) so the
    // cross-PR trajectory of this metric stays comparable
    b.metric("evals_per_s_pooled", n as f64 / pooled_s, "evals/s");

    // the same genomes as one columnar matrix
    let mut pop = PopMatrix::with_capacity(dim, 2, n);
    pop.set_rows(n);
    for (i, (g, _)) in jobs.iter().enumerate() {
        pop.genome_mut(i).copy_from_slice(g);
    }
    let seeds: Vec<u32> = (0..n as u32).collect();

    // stage 1b: the columnar rows API — slice views in, preallocated
    // objective rows out, workers writing disjoint slices
    let rows_s = {
        let m = b.case("evaluate_rows_pooled", || {
            let (genomes, out) = pop.rows_split_mut(0);
            pooled
                .evaluate_rows(RowsView::new(genomes, dim), &seeds, out)
                .unwrap();
        });
        m.median_s()
    };
    b.metric("evals_per_s_rows", n as f64 / rows_s, "evals/s");
    b.metric("rows_over_tuple_api", pooled_s / rows_s, "x");

    // stage 2: flat non-dominated ranking (two objectives → sweep path),
    // scratch reused across samples
    let mut scratch = NsgaScratch::default();
    let rank_s = {
        let m = b.case("rank", || scratch.sort_flat(pop.objectives(), n, 2, None));
        m.median_s()
    };
    b.metric("rank_individuals_per_s", n as f64 / rank_s, "ind/s");

    // stage 3: environmental selection to mu as survivor flags — no
    // population clone exists anymore, selection compacts in place
    let select_s = {
        let m = b.case("select_flags", || {
            scratch.select_flags_flat(pop.objectives(), n, 2, mu, None);
        });
        m.median_s()
    };
    b.metric("select_flags_s", select_s, "s");

    // the end-to-end generational wave on the arena: rank+crowd parents,
    // breed n offspring in place, evaluate them, select back down to mu.
    // One matrix + one arena, recycled forever.
    let bounds = {
        let vals: Vec<_> = (0..dim).map(|d| val_f64(&format!("x{d}"))).collect();
        let spec: Vec<_> = vals.iter().map(|v| (v, 0.0, 1.0)).collect();
        Bounds::new(&spec).unwrap()
    };
    let ops = Operators::default();
    let wave_step = |wave: &mut PopMatrix,
                     arena: &mut WaveArena,
                     rng: &mut Rng,
                     eval: &dyn Evaluator,
                     pool: Option<&ThreadPool>| {
        arena.rank_crowd(wave, pool);
        let parents = wave.len();
        wave.set_rows(parents + n);
        arena.breed_into(wave, parents, &ops, &bounds, rng, pool);
        arena.seeds.clear();
        for _ in 0..n {
            arena.seeds.push(rng.model_seed());
        }
        let (genomes, out) = wave.rows_split_mut(parents);
        eval.evaluate_rows(RowsView::new(genomes, dim), &arena.seeds, out)
            .unwrap();
        arena.select(wave, mu, pool);
    };
    let prime = |rng: &mut Rng| -> (PopMatrix, WaveArena) {
        let mut wave = PopMatrix::with_capacity(dim, 2, mu + n);
        let mut arena = WaveArena::default();
        wave.set_rows(mu);
        arena.seeds.clear();
        for i in 0..mu {
            bounds.random_into(rng, wave.genome_mut(i));
        }
        for _ in 0..mu {
            arena.seeds.push(rng.model_seed());
        }
        let (genomes, out) = wave.rows_split_mut(0);
        serial
            .evaluate_rows(RowsView::new(genomes, dim), &arena.seeds, out)
            .unwrap();
        (wave, arena)
    };

    // serial wave: this is the zero-allocation configuration
    let mut wrng = Rng::new(777);
    let (mut wave, mut arena) = prime(&mut wrng);
    let wave_serial_s = {
        let m = b.case("wave_reuse", || {
            wave_step(&mut wave, &mut arena, &mut wrng, &serial, None)
        });
        m.median_s()
    };
    // count allocations across pure steady-state waves (outside b.case,
    // whose own bookkeeping allocates)
    let before = ALLOCATIONS.load(Ordering::Relaxed);
    for _ in 0..3 {
        wave_step(&mut wave, &mut arena, &mut wrng, &serial, None);
    }
    let wave_allocs = ALLOCATIONS.load(Ordering::Relaxed) - before;
    b.metric(
        "wave_reuse_allocations",
        wave_allocs as f64,
        "allocs in 3 steady-state waves (acceptance: 0)",
    );

    // parallel wave: pooled evaluation + pooled variation/crowding
    let cpool = ThreadPool::new(threads);
    let mut prng = Rng::new(778);
    let (mut wave_p, mut arena_p) = prime(&mut prng);
    let wave_parallel_s = {
        let m = b.case("wave_parallel", || {
            wave_step(&mut wave_p, &mut arena_p, &mut prng, &pooled, Some(&cpool))
        });
        m.median_s()
    };
    // recorded from the PARALLEL wave specifically (not the min): a
    // parallelism collapse must show up in the gated metric, not hide
    // behind the serial fallback
    b.metric("full_wave_s", wave_parallel_s, "s");
    b.metric("wave_parallel_speedup", wave_serial_s / wave_parallel_s, "x");
    b.metric("wave_individuals", n as f64, "individuals");
    b.metric("survivors", mu as f64, "individuals");

    if let Err(e) = b.write_json() {
        eprintln!("could not write bench json: {e}");
    }
}
