//! P2 (§Perf): the paper-scale claim. OpenMOLE's headline workload
//! evaluates a GA initialisation of 200,000 individuals in one hour
//! (arXiv:1506.04182 §4.6); the coordinator side of that wave — batch
//! evaluation, non-dominated ranking, environmental selection — must not
//! be the bottleneck. This bench times one full 200k-individual init wave
//! with `Zdt1Evaluator` (two objectives → the O(N·logN) sweep path) and
//! writes `BENCH_p2_scale.json`.
//!
//! Knobs: `P2_SCALE_N` (wave size, default 200000; CI smoke uses a small
//! value), `P2_SCALE_MU` (survivors, default 200), `BENCH_OUT_DIR`.

use std::sync::Arc;

use molers::bench::Bench;
use molers::evolution::{
    nsga2, Evaluator, Individual, PooledEvaluator, Zdt1Evaluator,
};
use molers::util::Rng;

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() {
    let n = env_usize("P2_SCALE_N", 200_000);
    let mu = env_usize("P2_SCALE_MU", 200);
    let dim = 6;
    let threads = std::thread::available_parallelism()
        .map(|t| t.get())
        .unwrap_or(4);
    println!("wave: {n} individuals, mu = {mu}, {threads} threads");

    let mut b = Bench::new("p2_scale").warmup(1).samples(3);

    // the init wave's genomes + seeds (generation itself is not the claim)
    let mut rng = Rng::new(150_604_182);
    let jobs: Vec<(Vec<f64>, u32)> = (0..n)
        .map(|i| {
            let genome: Vec<f64> = (0..dim).map(|_| rng.f64()).collect();
            (genome, i as u32)
        })
        .collect();

    let pooled = PooledEvaluator::with_threads(Arc::new(Zdt1Evaluator { dim }), threads);
    let serial = Zdt1Evaluator { dim };

    // stage 1: batch evaluation, serial vs pooled
    let serial_s = b
        .case("evaluate_serial", || serial.evaluate_batch(&jobs).unwrap())
        .median_s();
    let mut objectives: Vec<Vec<f64>> = Vec::new();
    let pooled_s = {
        let m = b.case("evaluate_pooled", || {
            objectives = pooled.evaluate_batch(&jobs).unwrap();
        });
        m.median_s()
    };
    b.metric("evaluate_pool_speedup", serial_s / pooled_s, "x");
    b.metric("evals_per_s_pooled", n as f64 / pooled_s, "evals/s");

    let population: Vec<Individual> = jobs
        .iter()
        .zip(&objectives)
        .map(|((genome, _), objs)| Individual::new(genome.clone(), objs.clone()))
        .collect();

    // stage 2: flat non-dominated ranking (two objectives → sweep path)
    let rank_s = b
        .case("rank", || nsga2::fast_non_dominated_sort(&population))
        .median_s();
    b.metric("rank_individuals_per_s", n as f64 / rank_s, "ind/s");

    // stage 3: environmental selection down to mu (clone measured apart so
    // the select number stands alone)
    let clone_s = b.case("population_clone", || population.clone()).median_s();
    let select_s = b
        .case("clone_plus_select", || {
            nsga2::select(population.clone(), mu)
        })
        .median_s();
    b.metric("select_s_net_of_clone", (select_s - clone_s).max(0.0), "s");

    // the end-to-end wave: evaluate + individual build + rank + select
    let wave = b
        .case("full_wave", || {
            let objectives = pooled.evaluate_batch(&jobs).unwrap();
            let population: Vec<Individual> = jobs
                .iter()
                .zip(objectives)
                .map(|((genome, _), objs)| Individual::new(genome.clone(), objs))
                .collect();
            nsga2::select(population, mu)
        })
        .median_s();
    b.metric("full_wave_s", wave, "s");
    b.metric("wave_individuals", n as f64, "individuals");
    b.metric("survivors", mu as f64, "individuals");

    if let Err(e) = b.write_json() {
        eprintln!("could not write bench json: {e}");
    }
}
