//! In-crate execution substrate: a work-stealing-free but fully functional
//! thread pool with future-like job handles.
//!
//! tokio is not vendored in this image (DESIGN.md §3); the engine's needs —
//! submit closures, await results, bounded parallelism — are covered by
//! this ~200-line pool built on std threads + channels. Every execution
//! environment shares one pool sized to the machine, mirroring how
//! OpenMOLE multiplexes local resources across environments.

mod pool;

pub use pool::{JobJoin, ThreadPool};
