//! A fixed-size thread pool with join handles.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Live worker threads across every pool in the process. Lets tests assert
/// that brokering several local environments onto one shared pool does not
/// oversubscribe the machine with private per-environment pools.
static LIVE_WORKERS: AtomicUsize = AtomicUsize::new(0);

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<Queue>,
    available: Condvar,
}

struct Queue {
    jobs: std::collections::VecDeque<Job>,
    shutdown: bool,
    in_flight: usize,
}

/// Handle to a value being computed on the pool.
pub struct JobJoin<T> {
    rx: Receiver<std::thread::Result<T>>,
}

impl<T> JobJoin<T> {
    /// Block until the job finishes. Panics inside the job are surfaced as
    /// an `Err` with the panic payload message.
    pub fn join(self) -> Result<T, String> {
        match self.rx.recv() {
            Ok(Ok(v)) => Ok(v),
            Ok(Err(panic)) => Err(panic_message(panic.as_ref())),
            Err(_) => Err("worker dropped the job".to_string()),
        }
    }

    /// Non-blocking poll; returns `None` while the job is still running.
    pub fn try_join(&self) -> Option<Result<T, String>> {
        match self.rx.try_recv() {
            Ok(Ok(v)) => Some(Ok(v)),
            Ok(Err(panic)) => Some(Err(panic_message(panic.as_ref()))),
            Err(std::sync::mpsc::TryRecvError::Empty) => None,
            Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                Some(Err("worker dropped the job".to_string()))
            }
        }
    }
}

fn panic_message(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "job panicked".to_string()
    }
}

/// Fixed-size thread pool. Dropping the pool waits for queued work.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    /// Create a pool with `threads` workers (>= 1).
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(Queue {
                jobs: Default::default(),
                shutdown: false,
                in_flight: 0,
            }),
            available: Condvar::new(),
        });
        let workers = (0..threads)
            .map(|i| {
                let shared = Arc::clone(&shared);
                // counted at spawn time so live_workers() is deterministic
                // the moment the pool constructor returns
                LIVE_WORKERS.fetch_add(1, Ordering::SeqCst);
                std::thread::Builder::new()
                    .name(format!("molers-worker-{i}"))
                    .spawn(move || worker_loop(shared))
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { shared, workers }
    }

    /// Pool sized to the machine (leaving one core for the coordinator).
    pub fn default_size() -> Self {
        let n = std::thread::available_parallelism()
            .map(|n| n.get().saturating_sub(1).max(1))
            .unwrap_or(4);
        Self::new(n)
    }

    pub fn threads(&self) -> usize {
        self.workers.len()
    }

    /// Worker threads currently alive process-wide (every pool counted).
    pub fn live_workers() -> usize {
        LIVE_WORKERS.load(Ordering::SeqCst)
    }

    /// Submit a closure; returns a join handle for its result.
    pub fn submit<T, F>(&self, f: F) -> JobJoin<T>
    where
        T: Send + 'static,
        F: FnOnce() -> T + Send + 'static,
    {
        let (tx, rx): (Sender<std::thread::Result<T>>, _) = channel();
        let job: Job = Box::new(move || {
            let result = catch_unwind(AssertUnwindSafe(f));
            let _ = tx.send(result);
        });
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.jobs.push_back(job);
        }
        self.shared.available.notify_one();
        JobJoin { rx }
    }

    /// Run all closures and collect results in order.
    pub fn map<T, F>(&self, fs: Vec<F>) -> Vec<Result<T, String>>
    where
        T: Send + 'static,
        F: FnOnce() -> T + Send + 'static,
    {
        let joins: Vec<_> = fs.into_iter().map(|f| self.submit(f)).collect();
        joins.into_iter().map(|j| j.join()).collect()
    }

    /// Number of queued + running jobs.
    pub fn load(&self) -> usize {
        let q = self.shared.queue.lock().unwrap();
        q.jobs.len() + q.in_flight
    }
}

/// A raw pointer that asserts `Send` so a scoped job can carry borrowed
/// data across the pool boundary. Soundness is provided by the caller:
/// `scoped_*` joins every job before returning, so the pointee outlives
/// every dereference, and the handed-out `&mut` ranges are disjoint.
struct SendPtr<T>(*mut T);

unsafe impl<T> Send for SendPtr<T> {}

impl ThreadPool {
    /// Scoped parallel-for over uniform chunks of `data`: runs
    /// `f(chunk_index, &mut data[k*chunk_len .. ...])` for every chunk,
    /// in parallel, and returns once **all** chunks finished. The closure
    /// may borrow from the caller's stack (no `'static` bound): the join
    /// before return keeps every borrow alive for the whole execution.
    ///
    /// A panic inside any chunk is surfaced as `Err` (first message wins)
    /// after the remaining chunks have still run to completion — the
    /// buffers are left in a valid (if partially written) state and the
    /// pool survives.
    ///
    /// Deadlock note: like [`ThreadPool::submit`] + join, this blocks the
    /// calling thread. Do not call it from a worker of the same pool.
    pub fn scoped_chunks<T, F>(
        &self,
        data: &mut [T],
        chunk_len: usize,
        f: F,
    ) -> Result<(), String>
    where
        T: Send,
        F: Fn(usize, &mut [T]) + Sync,
    {
        let chunk_len = chunk_len.max(1);
        let n_chunks = data.len().div_ceil(chunk_len);
        let total = data.len();
        self.scoped_ranges(data, n_chunks, &f, |k| {
            (k * chunk_len, ((k + 1) * chunk_len).min(total))
        })
    }

    /// Scoped parallel-for over **explicit** partition boundaries:
    /// `bounds` must be non-decreasing with `bounds[0] == 0` and
    /// `bounds.last() == data.len()`; part `k` is
    /// `data[bounds[k]..bounds[k + 1]]`. Used where the natural work
    /// units are uneven (e.g. one Pareto front per part).
    pub fn scoped_parts<T, F>(
        &self,
        data: &mut [T],
        bounds: &[usize],
        f: F,
    ) -> Result<(), String>
    where
        T: Send,
        F: Fn(usize, &mut [T]) + Sync,
    {
        if bounds.len() < 2 {
            return Ok(());
        }
        assert_eq!(bounds[0], 0, "scoped_parts: bounds must start at 0");
        assert_eq!(
            *bounds.last().unwrap(),
            data.len(),
            "scoped_parts: bounds must end at data.len()"
        );
        for w in bounds.windows(2) {
            assert!(w[0] <= w[1], "scoped_parts: bounds must be non-decreasing");
        }
        let n_parts = bounds.len() - 1;
        self.scoped_ranges(data, n_parts, &f, |k| (bounds[k], bounds[k + 1]))
    }

    /// Shared engine for the scoped parallel-fors: `range_of(k)` yields
    /// the half-open element range of part `k`; ranges must be disjoint.
    fn scoped_ranges<T, F>(
        &self,
        data: &mut [T],
        n_parts: usize,
        f: &F,
        range_of: impl Fn(usize) -> (usize, usize),
    ) -> Result<(), String>
    where
        T: Send,
        F: Fn(usize, &mut [T]) + Sync,
    {
        if n_parts == 0 {
            return Ok(());
        }
        if n_parts == 1 || self.threads() == 1 {
            // nothing to fan out (or nowhere to fan it): run inline
            for k in 0..n_parts {
                let (lo, hi) = range_of(k);
                f(k, &mut data[lo..hi]);
            }
            return Ok(());
        }
        // joins every outstanding handle when dropped: the lifetime
        // erasure below is only sound if NO exit path — including an
        // unwind out of the submit loop — returns before all jobs finish
        struct JoinAll {
            handles: Vec<JobJoin<()>>,
        }
        impl Drop for JoinAll {
            fn drop(&mut self) {
                for h in self.handles.drain(..) {
                    let _ = h.join();
                }
            }
        }
        let mut guard = JoinAll {
            handles: Vec::with_capacity(n_parts),
        };
        // ONE reborrow of the buffer, hoisted out of the loop: taking a
        // fresh `as_mut_ptr()` per iteration would invalidate the
        // provenance of pointers that already-running jobs derived from
        // earlier reborrows (UB under the aliasing model). Every job's
        // pointer is a plain copy of this one.
        let base_ptr = data.as_mut_ptr();
        for k in 0..n_parts {
            let (lo, hi) = range_of(k);
            let base = SendPtr(base_ptr);
            let scoped: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                // SAFETY: `range_of` yields disjoint ranges, so no two
                // parts alias, and the `JoinAll` guard outlives every
                // dereference of the caller's `data` borrow.
                let slice =
                    unsafe { std::slice::from_raw_parts_mut(base.0.add(lo), hi - lo) };
                f(k, slice);
            });
            // SAFETY: lifetime erasure only — same fat-pointer layout. The
            // job cannot outlive the borrows it captures because every
            // handle is joined before this function returns: the normal
            // path drains `guard.handles` below, and an unwind anywhere
            // in this loop joins the already-submitted jobs in
            // `JoinAll::drop` (workers catch unwinds, so the join itself
            // always completes).
            let job: Box<dyn FnOnce() + Send + 'static> =
                unsafe { std::mem::transmute(scoped) };
            guard.handles.push(self.submit(job));
        }
        let mut first_err = None;
        for h in guard.handles.drain(..) {
            if let Err(e) = h.join() {
                first_err.get_or_insert(e);
            }
        }
        match first_err {
            None => Ok(()),
            Some(e) => Err(e),
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.shutdown = true;
        }
        self.shared.available.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(shared: Arc<Shared>) {
    // decrement on any exit path, even if a job unwinds past catch_unwind
    // (it cannot today, but the counter must never leak)
    struct Guard;
    impl Drop for Guard {
        fn drop(&mut self) {
            LIVE_WORKERS.fetch_sub(1, Ordering::SeqCst);
        }
    }
    let _guard = Guard;
    loop {
        let job = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(job) = q.jobs.pop_front() {
                    q.in_flight += 1;
                    break Some(job);
                }
                if q.shutdown {
                    break None;
                }
                q = shared.available.wait(q).unwrap();
            }
        };
        match job {
            Some(job) => {
                job();
                let mut q = shared.queue.lock().unwrap();
                q.in_flight -= 1;
            }
            None => return,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_jobs_and_returns_values() {
        let pool = ThreadPool::new(4);
        let joins: Vec<_> = (0..32).map(|i| pool.submit(move || i * 2)).collect();
        let out: Vec<usize> = joins.into_iter().map(|j| j.join().unwrap()).collect();
        assert_eq!(out, (0..32).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn parallelism_actually_happens() {
        let pool = ThreadPool::new(4);
        let running = Arc::new(AtomicUsize::new(0));
        let peak = Arc::new(AtomicUsize::new(0));
        let joins: Vec<_> = (0..8)
            .map(|_| {
                let (r, p) = (Arc::clone(&running), Arc::clone(&peak));
                pool.submit(move || {
                    let now = r.fetch_add(1, Ordering::SeqCst) + 1;
                    p.fetch_max(now, Ordering::SeqCst);
                    std::thread::sleep(std::time::Duration::from_millis(30));
                    r.fetch_sub(1, Ordering::SeqCst);
                })
            })
            .collect();
        for j in joins {
            j.join().unwrap();
        }
        assert!(peak.load(Ordering::SeqCst) >= 2);
    }

    #[test]
    fn panics_are_reported_not_fatal() {
        let pool = ThreadPool::new(2);
        let bad = pool.submit(|| panic!("boom"));
        assert_eq!(bad.join().unwrap_err(), "boom");
        // the pool still works afterwards
        assert_eq!(pool.submit(|| 7).join().unwrap(), 7);
    }

    #[test]
    fn try_join_polls() {
        let pool = ThreadPool::new(1);
        let j = pool.submit(|| {
            std::thread::sleep(std::time::Duration::from_millis(50));
            1
        });
        assert!(j.try_join().is_none());
        std::thread::sleep(std::time::Duration::from_millis(120));
        assert_eq!(j.try_join().unwrap().unwrap(), 1);
    }

    #[test]
    fn scoped_chunks_writes_disjoint_chunks_with_borrowed_state() {
        let pool = ThreadPool::new(4);
        let offset = 100usize; // borrowed by the scoped closure
        let mut data = vec![0usize; 103];
        pool.scoped_chunks(&mut data, 10, |k, chunk| {
            for (i, v) in chunk.iter_mut().enumerate() {
                *v = offset + k * 10 + i;
            }
        })
        .unwrap();
        for (i, v) in data.iter().enumerate() {
            assert_eq!(*v, offset + i);
        }
    }

    #[test]
    fn scoped_chunks_single_thread_and_single_chunk_run_inline() {
        for threads in [1, 3] {
            let pool = ThreadPool::new(threads);
            let mut data = vec![1.0f64; 7];
            pool.scoped_chunks(&mut data, 100, |_, chunk| {
                for v in chunk {
                    *v += 1.0;
                }
            })
            .unwrap();
            assert!(data.iter().all(|&v| v == 2.0));
            let mut empty: Vec<f64> = Vec::new();
            pool.scoped_chunks(&mut empty, 4, |_, _| panic!("no chunks"))
                .unwrap();
        }
    }

    #[test]
    fn scoped_chunks_panic_surfaces_as_err_and_pool_survives() {
        let pool = ThreadPool::new(2);
        let mut data = vec![0u32; 40];
        let err = pool
            .scoped_chunks(&mut data, 4, |k, chunk| {
                if k == 3 {
                    panic!("chunk 3 exploded");
                }
                chunk.fill(7);
            })
            .unwrap_err();
        assert!(err.contains("chunk 3 exploded"), "got: {err}");
        // every other chunk still ran; the pool is reusable
        assert_eq!(data.iter().filter(|&&v| v == 7).count(), 36);
        assert_eq!(pool.submit(|| 5).join().unwrap(), 5);
    }

    #[test]
    fn scoped_parts_uneven_partition() {
        let pool = ThreadPool::new(3);
        let mut data = vec![0usize; 10];
        let bounds = [0usize, 1, 1, 6, 10];
        pool.scoped_parts(&mut data, &bounds, |k, part| {
            for v in part {
                *v = k + 1;
            }
        })
        .unwrap();
        assert_eq!(data, vec![1, 3, 3, 3, 3, 3, 4, 4, 4, 4]);
        // empty bounds are a no-op
        let mut empty: [usize; 0] = [];
        pool.scoped_parts(&mut empty, &[], |_, _| {}).unwrap();
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(3);
        let fs: Vec<_> = (0..10)
            .map(|i| move || format!("r{i}"))
            .collect();
        let out = pool.map(fs);
        for (i, r) in out.iter().enumerate() {
            assert_eq!(r.as_ref().unwrap(), &format!("r{i}"));
        }
    }
}
