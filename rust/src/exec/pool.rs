//! A fixed-size thread pool with join handles.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Live worker threads across every pool in the process. Lets tests assert
/// that brokering several local environments onto one shared pool does not
/// oversubscribe the machine with private per-environment pools.
static LIVE_WORKERS: AtomicUsize = AtomicUsize::new(0);

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<Queue>,
    available: Condvar,
}

struct Queue {
    jobs: std::collections::VecDeque<Job>,
    shutdown: bool,
    in_flight: usize,
}

/// Handle to a value being computed on the pool.
pub struct JobJoin<T> {
    rx: Receiver<std::thread::Result<T>>,
}

impl<T> JobJoin<T> {
    /// Block until the job finishes. Panics inside the job are surfaced as
    /// an `Err` with the panic payload message.
    pub fn join(self) -> Result<T, String> {
        match self.rx.recv() {
            Ok(Ok(v)) => Ok(v),
            Ok(Err(panic)) => Err(panic_message(panic.as_ref())),
            Err(_) => Err("worker dropped the job".to_string()),
        }
    }

    /// Non-blocking poll; returns `None` while the job is still running.
    pub fn try_join(&self) -> Option<Result<T, String>> {
        match self.rx.try_recv() {
            Ok(Ok(v)) => Some(Ok(v)),
            Ok(Err(panic)) => Some(Err(panic_message(panic.as_ref()))),
            Err(std::sync::mpsc::TryRecvError::Empty) => None,
            Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                Some(Err("worker dropped the job".to_string()))
            }
        }
    }
}

fn panic_message(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "job panicked".to_string()
    }
}

/// Fixed-size thread pool. Dropping the pool waits for queued work.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    /// Create a pool with `threads` workers (>= 1).
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(Queue {
                jobs: Default::default(),
                shutdown: false,
                in_flight: 0,
            }),
            available: Condvar::new(),
        });
        let workers = (0..threads)
            .map(|i| {
                let shared = Arc::clone(&shared);
                // counted at spawn time so live_workers() is deterministic
                // the moment the pool constructor returns
                LIVE_WORKERS.fetch_add(1, Ordering::SeqCst);
                std::thread::Builder::new()
                    .name(format!("molers-worker-{i}"))
                    .spawn(move || worker_loop(shared))
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { shared, workers }
    }

    /// Pool sized to the machine (leaving one core for the coordinator).
    pub fn default_size() -> Self {
        let n = std::thread::available_parallelism()
            .map(|n| n.get().saturating_sub(1).max(1))
            .unwrap_or(4);
        Self::new(n)
    }

    pub fn threads(&self) -> usize {
        self.workers.len()
    }

    /// Worker threads currently alive process-wide (every pool counted).
    pub fn live_workers() -> usize {
        LIVE_WORKERS.load(Ordering::SeqCst)
    }

    /// Submit a closure; returns a join handle for its result.
    pub fn submit<T, F>(&self, f: F) -> JobJoin<T>
    where
        T: Send + 'static,
        F: FnOnce() -> T + Send + 'static,
    {
        let (tx, rx): (Sender<std::thread::Result<T>>, _) = channel();
        let job: Job = Box::new(move || {
            let result = catch_unwind(AssertUnwindSafe(f));
            let _ = tx.send(result);
        });
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.jobs.push_back(job);
        }
        self.shared.available.notify_one();
        JobJoin { rx }
    }

    /// Run all closures and collect results in order.
    pub fn map<T, F>(&self, fs: Vec<F>) -> Vec<Result<T, String>>
    where
        T: Send + 'static,
        F: FnOnce() -> T + Send + 'static,
    {
        let joins: Vec<_> = fs.into_iter().map(|f| self.submit(f)).collect();
        joins.into_iter().map(|j| j.join()).collect()
    }

    /// Number of queued + running jobs.
    pub fn load(&self) -> usize {
        let q = self.shared.queue.lock().unwrap();
        q.jobs.len() + q.in_flight
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.shutdown = true;
        }
        self.shared.available.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(shared: Arc<Shared>) {
    // decrement on any exit path, even if a job unwinds past catch_unwind
    // (it cannot today, but the counter must never leak)
    struct Guard;
    impl Drop for Guard {
        fn drop(&mut self) {
            LIVE_WORKERS.fetch_sub(1, Ordering::SeqCst);
        }
    }
    let _guard = Guard;
    loop {
        let job = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(job) = q.jobs.pop_front() {
                    q.in_flight += 1;
                    break Some(job);
                }
                if q.shutdown {
                    break None;
                }
                q = shared.available.wait(q).unwrap();
            }
        };
        match job {
            Some(job) => {
                job();
                let mut q = shared.queue.lock().unwrap();
                q.in_flight -= 1;
            }
            None => return,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_jobs_and_returns_values() {
        let pool = ThreadPool::new(4);
        let joins: Vec<_> = (0..32).map(|i| pool.submit(move || i * 2)).collect();
        let out: Vec<usize> = joins.into_iter().map(|j| j.join().unwrap()).collect();
        assert_eq!(out, (0..32).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn parallelism_actually_happens() {
        let pool = ThreadPool::new(4);
        let running = Arc::new(AtomicUsize::new(0));
        let peak = Arc::new(AtomicUsize::new(0));
        let joins: Vec<_> = (0..8)
            .map(|_| {
                let (r, p) = (Arc::clone(&running), Arc::clone(&peak));
                pool.submit(move || {
                    let now = r.fetch_add(1, Ordering::SeqCst) + 1;
                    p.fetch_max(now, Ordering::SeqCst);
                    std::thread::sleep(std::time::Duration::from_millis(30));
                    r.fetch_sub(1, Ordering::SeqCst);
                })
            })
            .collect();
        for j in joins {
            j.join().unwrap();
        }
        assert!(peak.load(Ordering::SeqCst) >= 2);
    }

    #[test]
    fn panics_are_reported_not_fatal() {
        let pool = ThreadPool::new(2);
        let bad = pool.submit(|| panic!("boom"));
        assert_eq!(bad.join().unwrap_err(), "boom");
        // the pool still works afterwards
        assert_eq!(pool.submit(|| 7).join().unwrap(), 7);
    }

    #[test]
    fn try_join_polls() {
        let pool = ThreadPool::new(1);
        let j = pool.submit(|| {
            std::thread::sleep(std::time::Duration::from_millis(50));
            1
        });
        assert!(j.try_join().is_none());
        std::thread::sleep(std::time::Duration::from_millis(120));
        assert_eq!(j.try_join().unwrap().unwrap(), 1);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(3);
        let fs: Vec<_> = (0..10)
            .map(|i| move || format!("r{i}"))
            .collect();
        let out = pool.map(fs);
        for (i, r) in out.iter().enumerate() {
            assert_eq!(r.as_ref().unwrap(), &format!("r{i}"));
        }
    }
}
