//! Middleware adapters: script generation + CLI output parsing for every
//! scheduler the paper lists (§2.2: "PBS, SGE, Slurm, OAR and Condor" plus
//! the gLite/EMI grid middleware).

use crate::error::{Error, Result};
use crate::gridscale::{JobScript, JobState, SchedulerAdapter};

fn missing(tool: &str, what: &str) -> Error {
    Error::GridScale(format!("could not parse {what} from `{tool}` output"))
}

fn hms(walltime_s: u64) -> String {
    format!(
        "{:02}:{:02}:{:02}",
        walltime_s / 3600,
        (walltime_s % 3600) / 60,
        walltime_s % 60
    )
}

// ---------------------------------------------------------------- PBS ----

/// PBS/Torque: `qsub`, `qstat -f`.
pub struct PbsAdapter;

impl SchedulerAdapter for PbsAdapter {
    fn name(&self) -> &'static str {
        "pbs"
    }

    fn script(&self, job: &JobScript) -> String {
        let mut s = String::from("#!/bin/bash\n");
        s += &format!("#PBS -N {}\n", job.name);
        s += &format!("#PBS -l walltime={}\n", hms(job.walltime_s));
        s += &format!("#PBS -l mem={}mb\n", job.memory_mb);
        if let Some(q) = &job.queue {
            s += &format!("#PBS -q {q}\n");
        }
        s += &job.command;
        s.push('\n');
        s
    }

    fn submit_command(&self, script_path: &str) -> String {
        format!("qsub {script_path}")
    }

    fn parse_submit(&self, stdout: &str) -> Result<String> {
        // qsub prints the bare id: `12345.headnode`
        let id = stdout.trim();
        if id.is_empty() {
            return Err(missing("qsub", "job id"));
        }
        Ok(id.to_string())
    }

    fn status_command(&self, job_id: &str) -> String {
        format!("qstat -f {job_id}")
    }

    fn parse_status(&self, stdout: &str) -> Result<JobState> {
        for line in stdout.lines() {
            let line = line.trim();
            if let Some(state) = line.strip_prefix("job_state = ") {
                return Ok(match state.trim() {
                    "Q" | "W" | "H" | "T" => JobState::Queued,
                    "R" | "E" => JobState::Running,
                    "C" => JobState::Done,
                    "F" => JobState::Failed,
                    other => {
                        return Err(Error::GridScale(format!(
                            "unknown PBS job_state `{other}`"
                        )))
                    }
                });
            }
        }
        Err(missing("qstat", "job_state"))
    }

    fn cancel_command(&self, job_id: &str) -> String {
        format!("qdel {job_id}")
    }
}

// -------------------------------------------------------------- Slurm ----

/// Slurm: `sbatch`, `squeue -h -j <id> -o %T` with `sacct` fallback
/// semantics (a job missing from squeue is finished).
pub struct SlurmAdapter;

impl SchedulerAdapter for SlurmAdapter {
    fn name(&self) -> &'static str {
        "slurm"
    }

    fn script(&self, job: &JobScript) -> String {
        let mut s = String::from("#!/bin/bash\n");
        s += &format!("#SBATCH --job-name={}\n", job.name);
        s += &format!("#SBATCH --time={}\n", hms(job.walltime_s));
        s += &format!("#SBATCH --mem={}M\n", job.memory_mb);
        if let Some(q) = &job.queue {
            s += &format!("#SBATCH --partition={q}\n");
        }
        s += &job.command;
        s.push('\n');
        s
    }

    fn submit_command(&self, script_path: &str) -> String {
        format!("sbatch {script_path}")
    }

    fn parse_submit(&self, stdout: &str) -> Result<String> {
        // `Submitted batch job 123`
        stdout
            .trim()
            .rsplit(' ')
            .next()
            .filter(|id| !id.is_empty() && id.chars().all(|c| c.is_ascii_digit()))
            .map(str::to_string)
            .ok_or_else(|| missing("sbatch", "job id"))
    }

    fn status_command(&self, job_id: &str) -> String {
        format!("squeue -h -j {job_id} -o %T")
    }

    fn parse_status(&self, stdout: &str) -> Result<JobState> {
        Ok(match stdout.trim() {
            "PENDING" | "CONFIGURING" => JobState::Queued,
            "RUNNING" | "COMPLETING" => JobState::Running,
            "COMPLETED" | "" => JobState::Done, // gone from squeue = finished
            "FAILED" | "TIMEOUT" | "CANCELLED" | "NODE_FAIL" => JobState::Failed,
            other => {
                return Err(Error::GridScale(format!(
                    "unknown Slurm state `{other}`"
                )))
            }
        })
    }

    fn cancel_command(&self, job_id: &str) -> String {
        format!("scancel {job_id}")
    }
}

// ---------------------------------------------------------------- SGE ----

/// Sun Grid Engine: `qsub`, `qstat` table output.
pub struct SgeAdapter;

impl SchedulerAdapter for SgeAdapter {
    fn name(&self) -> &'static str {
        "sge"
    }

    fn script(&self, job: &JobScript) -> String {
        let mut s = String::from("#!/bin/bash\n");
        s += &format!("#$ -N {}\n", job.name);
        s += &format!("#$ -l h_rt={}\n", hms(job.walltime_s));
        s += &format!("#$ -l h_vmem={}M\n", job.memory_mb);
        if let Some(q) = &job.queue {
            s += &format!("#$ -q {q}\n");
        }
        s += &job.command;
        s.push('\n');
        s
    }

    fn submit_command(&self, script_path: &str) -> String {
        format!("qsub {script_path}")
    }

    fn parse_submit(&self, stdout: &str) -> Result<String> {
        // `Your job 4721 ("name") has been submitted`
        let tokens: Vec<&str> = stdout.split_whitespace().collect();
        tokens
            .windows(2)
            .find(|w| w[0] == "job")
            .map(|w| w[1].to_string())
            .ok_or_else(|| missing("qsub (SGE)", "job id"))
    }

    fn status_command(&self, job_id: &str) -> String {
        // (real GridScale runs plain `qstat` and filters the table row;
        // the id argument stands in for that filter)
        format!("qstat {job_id}")
    }

    fn parse_status(&self, stdout: &str) -> Result<JobState> {
        let line = stdout.trim();
        if line.is_empty() {
            return Ok(JobState::Done); // gone from qstat = finished
        }
        let state = line
            .split_whitespace()
            .nth(4)
            .ok_or_else(|| missing("qstat (SGE)", "state column"))?;
        Ok(match state {
            "qw" | "hqw" | "T" => JobState::Queued,
            "r" | "t" => JobState::Running,
            "Eqw" | "E" => JobState::Failed,
            other => {
                return Err(Error::GridScale(format!("unknown SGE state `{other}`")))
            }
        })
    }

    fn cancel_command(&self, job_id: &str) -> String {
        format!("qdel {job_id}")
    }
}

// ---------------------------------------------------------------- OAR ----

/// OAR: `oarsub`, `oarstat -s`.
pub struct OarAdapter;

impl SchedulerAdapter for OarAdapter {
    fn name(&self) -> &'static str {
        "oar"
    }

    fn script(&self, job: &JobScript) -> String {
        format!("#!/bin/bash\n{}\n", job.command)
    }

    fn submit_command(&self, script_path: &str) -> String {
        format!("oarsub -S {script_path}")
    }

    fn parse_submit(&self, stdout: &str) -> Result<String> {
        // `OAR_JOB_ID=8321`
        stdout
            .lines()
            .find_map(|l| l.trim().strip_prefix("OAR_JOB_ID="))
            .map(str::to_string)
            .ok_or_else(|| missing("oarsub", "OAR_JOB_ID"))
    }

    fn status_command(&self, job_id: &str) -> String {
        format!("oarstat -s -j {job_id}")
    }

    fn parse_status(&self, stdout: &str) -> Result<JobState> {
        // `8321: Running`
        let state = stdout
            .trim()
            .rsplit(':')
            .next()
            .map(str::trim)
            .ok_or_else(|| missing("oarstat", "state"))?;
        Ok(match state {
            "Waiting" | "toLaunch" | "Launching" | "Hold" => JobState::Queued,
            "Running" | "Finishing" => JobState::Running,
            "Terminated" => JobState::Done,
            "Error" | "Failed" => JobState::Failed,
            other => {
                return Err(Error::GridScale(format!("unknown OAR state `{other}`")))
            }
        })
    }

    fn cancel_command(&self, job_id: &str) -> String {
        format!("oardel {job_id}")
    }
}

// ------------------------------------------------------------- Condor ----

/// HTCondor: `condor_submit`, `condor_q -format %d JobStatus`.
pub struct CondorAdapter;

impl SchedulerAdapter for CondorAdapter {
    fn name(&self) -> &'static str {
        "condor"
    }

    fn script(&self, job: &JobScript) -> String {
        let mut s = String::new();
        s += "universe = vanilla\n";
        s += &format!("executable = /bin/bash\narguments = -c '{}'\n", job.command);
        s += &format!("request_memory = {}MB\n", job.memory_mb);
        s += "queue 1\n";
        s
    }

    fn submit_command(&self, script_path: &str) -> String {
        format!("condor_submit {script_path}")
    }

    fn parse_submit(&self, stdout: &str) -> Result<String> {
        // `1 job(s) submitted to cluster 42.`
        stdout
            .lines()
            .find_map(|l| l.trim().strip_prefix("1 job(s) submitted to cluster "))
            .map(|id| id.trim_end_matches('.').to_string())
            .ok_or_else(|| missing("condor_submit", "cluster id"))
    }

    fn status_command(&self, job_id: &str) -> String {
        format!("condor_q {job_id} -format %d JobStatus")
    }

    fn parse_status(&self, stdout: &str) -> Result<JobState> {
        Ok(match stdout.trim() {
            "1" => JobState::Queued,
            "2" => JobState::Running,
            "4" | "" => JobState::Done,
            "5" | "3" | "6" => JobState::Failed,
            other => {
                return Err(Error::GridScale(format!(
                    "unknown Condor JobStatus `{other}`"
                )))
            }
        })
    }

    fn cancel_command(&self, job_id: &str) -> String {
        format!("condor_rm {job_id}")
    }
}

// -------------------------------------------------------------- gLite ----

/// gLite/EMI (EGI grid, Listing 5's `EGIEnvironment("biomed")`):
/// `glite-wms-job-submit`, `glite-wms-job-status`.
pub struct GliteAdapter {
    pub virtual_organisation: String,
}

impl GliteAdapter {
    pub fn new(vo: impl Into<String>) -> Self {
        GliteAdapter {
            virtual_organisation: vo.into(),
        }
    }
}

impl SchedulerAdapter for GliteAdapter {
    fn name(&self) -> &'static str {
        "glite"
    }

    fn script(&self, job: &JobScript) -> String {
        // JDL, not a shell script
        format!(
            "[\nExecutable = \"/bin/bash\";\nArguments = \"-c '{}'\";\n\
             VirtualOrganisation = \"{}\";\nRequirements = other.GlueCEPolicyMaxWallClockTime >= {};\n\
             PerusalFileEnable = false;\n]\n",
            job.command,
            self.virtual_organisation,
            job.walltime_s / 60
        )
    }

    fn submit_command(&self, script_path: &str) -> String {
        format!(
            "glite-wms-job-submit -a --vo {} {script_path}",
            self.virtual_organisation
        )
    }

    fn parse_submit(&self, stdout: &str) -> Result<String> {
        // the WMS prints the job https URL on its own line
        stdout
            .lines()
            .map(str::trim)
            .find(|l| l.starts_with("https://"))
            .map(str::to_string)
            .ok_or_else(|| missing("glite-wms-job-submit", "job url"))
    }

    fn status_command(&self, job_id: &str) -> String {
        format!("glite-wms-job-status {job_id}")
    }

    fn parse_status(&self, stdout: &str) -> Result<JobState> {
        let status = stdout
            .lines()
            .find_map(|l| l.trim().strip_prefix("Current Status:"))
            .map(str::trim)
            .ok_or_else(|| missing("glite-wms-job-status", "Current Status"))?;
        Ok(match status {
            "Submitted" | "Waiting" => JobState::Submitted,
            "Ready" | "Scheduled" => JobState::Queued,
            "Running" => JobState::Running,
            "Done (Success)" | "Cleared" => JobState::Done,
            "Done (Exit Code !=0)" | "Aborted" | "Cancelled" => JobState::Failed,
            other => {
                return Err(Error::GridScale(format!(
                    "unknown gLite status `{other}`"
                )))
            }
        })
    }

    fn cancel_command(&self, job_id: &str) -> String {
        format!("glite-wms-job-cancel --noint {job_id}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn job() -> JobScript {
        JobScript::new("ants", "./run-model.sh")
            .walltime(4 * 3600)
            .memory(1200)
            .queue("biomed")
    }

    #[test]
    fn pbs_roundtrip() {
        let a = PbsAdapter;
        let s = a.script(&job());
        assert!(s.contains("#PBS -l walltime=04:00:00"));
        assert!(s.contains("#PBS -l mem=1200mb"));
        assert_eq!(a.parse_submit("4821.head0\n").unwrap(), "4821.head0");
        assert_eq!(
            a.parse_status("Job Id: 4821\n    job_state = R\n").unwrap(),
            JobState::Running
        );
        assert_eq!(
            a.parse_status("    job_state = Q\n").unwrap(),
            JobState::Queued
        );
    }

    #[test]
    fn slurm_roundtrip() {
        let a = SlurmAdapter;
        assert!(a.script(&job()).contains("#SBATCH --time=04:00:00"));
        assert_eq!(a.parse_submit("Submitted batch job 991\n").unwrap(), "991");
        assert_eq!(a.parse_status("RUNNING\n").unwrap(), JobState::Running);
        assert_eq!(a.parse_status("").unwrap(), JobState::Done);
        assert!(a.parse_submit("sbatch: error\n").is_err());
    }

    #[test]
    fn sge_roundtrip() {
        let a = SgeAdapter;
        assert_eq!(
            a.parse_submit("Your job 4721 (\"ants\") has been submitted\n")
                .unwrap(),
            "4721"
        );
        assert_eq!(
            a.parse_status("4721 0.5 ants user r 07/10/2026 node1 1\n")
                .unwrap(),
            JobState::Running
        );
        assert_eq!(a.parse_status("\n").unwrap(), JobState::Done);
    }

    #[test]
    fn oar_roundtrip() {
        let a = OarAdapter;
        assert_eq!(
            a.parse_submit("Generate a job key...\nOAR_JOB_ID=8321\n").unwrap(),
            "8321"
        );
        assert_eq!(
            a.parse_status("8321: Terminated\n").unwrap(),
            JobState::Done
        );
    }

    #[test]
    fn condor_roundtrip() {
        let a = CondorAdapter;
        assert_eq!(
            a.parse_submit("Submitting job(s).\n1 job(s) submitted to cluster 42.\n")
                .unwrap(),
            "42"
        );
        assert_eq!(a.parse_status("2").unwrap(), JobState::Running);
        assert_eq!(a.parse_status("4").unwrap(), JobState::Done);
    }

    #[test]
    fn glite_roundtrip() {
        let a = GliteAdapter::new("biomed");
        let jdl = a.script(&job());
        assert!(jdl.contains("VirtualOrganisation = \"biomed\""));
        let out = "Connecting to the service...\n\n\
                   https://wms01.egi.eu:9000/AbCdEf123\n";
        assert_eq!(
            a.parse_submit(out).unwrap(),
            "https://wms01.egi.eu:9000/AbCdEf123"
        );
        let status = "Status info for the Job\nCurrent Status:     Done (Success)\n";
        assert_eq!(a.parse_status(status).unwrap(), JobState::Done);
    }

    #[test]
    fn all_adapters_generate_distinct_submit_commands() {
        let adapters: Vec<Box<dyn SchedulerAdapter>> = vec![
            Box::new(PbsAdapter),
            Box::new(SlurmAdapter),
            Box::new(SgeAdapter),
            Box::new(OarAdapter),
            Box::new(CondorAdapter),
            Box::new(GliteAdapter::new("biomed")),
        ];
        let mut cmds: Vec<String> =
            adapters.iter().map(|a| a.submit_command("job.sh")).collect();
        cmds.sort();
        cmds.dedup();
        assert_eq!(cmds.len(), 5); // PBS and SGE legitimately share `qsub`
    }
}
