//! The simulated shell: implements each middleware's CLI surface against
//! the in-process cluster simulator.
//!
//! Real GridScale executes `qsub`/`squeue`/... over an SSH connection; the
//! [`SimShell`] is that connection's stand-in (DESIGN.md §3). It parses
//! the command lines the adapters build, drives
//! [`crate::environment::cluster::SimCluster`], and answers in each tool's
//! authentic output format — so the adapters' parsers are exercised on
//! both ends.

use std::sync::{Arc, Mutex};

use crate::environment::cluster::SimCluster;
use crate::error::{Error, Result};
use crate::gridscale::{CommandOutput, JobState, Shell};

/// Which CLI dialect the simulated head node speaks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Flavor {
    Pbs,
    Slurm,
    Sge,
    Oar,
    Condor,
    Glite,
}

/// A simulated head node for one cluster.
pub struct SimShell {
    pub flavor: Flavor,
    cluster: Arc<Mutex<SimCluster>>,
}

impl SimShell {
    pub fn new(flavor: Flavor, cluster: Arc<Mutex<SimCluster>>) -> Self {
        SimShell { flavor, cluster }
    }

    fn format_submit(&self, id: u64) -> String {
        match self.flavor {
            Flavor::Pbs => format!("{id}.headnode\n"),
            Flavor::Slurm => format!("Submitted batch job {id}\n"),
            Flavor::Sge => format!("Your job {id} (\"molers\") has been submitted\n"),
            Flavor::Oar => format!("Generate a job key...\nOAR_JOB_ID={id}\n"),
            Flavor::Condor => {
                format!("Submitting job(s).\n1 job(s) submitted to cluster {id}.\n")
            }
            Flavor::Glite => {
                format!(
                    "Connecting to the service...\n\n\
                     https://wms01.sim.egi.eu:9000/{id}\n"
                )
            }
        }
    }

    fn format_status(&self, id: u64, state: JobState) -> String {
        match self.flavor {
            Flavor::Pbs => {
                let code = match state {
                    JobState::Submitted | JobState::Queued => "Q",
                    JobState::Running => "R",
                    JobState::Done => "C",
                    JobState::Failed => "F",
                };
                format!("Job Id: {id}.headnode\n    job_state = {code}\n")
            }
            Flavor::Slurm => match state {
                JobState::Submitted | JobState::Queued => "PENDING\n".into(),
                JobState::Running => "RUNNING\n".into(),
                JobState::Done => String::new(), // finished jobs leave squeue
                JobState::Failed => "FAILED\n".into(),
            },
            Flavor::Sge => match state {
                JobState::Submitted | JobState::Queued => {
                    format!("{id} 0.5 molers user qw 07/10/2026 1\n")
                }
                JobState::Running => format!("{id} 0.5 molers user r 07/10/2026 node1 1\n"),
                JobState::Done => String::new(),
                JobState::Failed => format!("{id} 0.5 molers user Eqw 07/10/2026 1\n"),
            },
            Flavor::Oar => {
                let s = match state {
                    JobState::Submitted | JobState::Queued => "Waiting",
                    JobState::Running => "Running",
                    JobState::Done => "Terminated",
                    JobState::Failed => "Error",
                };
                format!("{id}: {s}\n")
            }
            Flavor::Condor => match state {
                JobState::Submitted | JobState::Queued => "1".into(),
                JobState::Running => "2".into(),
                JobState::Done => "4".into(),
                JobState::Failed => "5".into(),
            },
            Flavor::Glite => {
                let s = match state {
                    JobState::Submitted => "Submitted",
                    JobState::Queued => "Scheduled",
                    JobState::Running => "Running",
                    JobState::Done => "Done (Success)",
                    JobState::Failed => "Aborted",
                };
                format!(
                    "Status info for the Job\nCurrent Status:     {s}\n"
                )
            }
        }
    }

    fn extract_id(&self, arg: &str) -> Result<u64> {
        // accept `123`, `123.headnode`, or a gLite https URL ending in the id
        let tail = arg.rsplit('/').next().unwrap_or(arg);
        let digits: String = tail.chars().filter(|c| c.is_ascii_digit()).collect();
        digits
            .parse()
            .map_err(|_| Error::GridScale(format!("bad job id `{arg}`")))
    }
}

/// Split a command line into tokens, honouring single- and double-quoted
/// segments (`qsub -N 'ants sweep' "/data/run dir/job.sh"`): quotes
/// group characters — including whitespace — into one token and are not
/// themselves part of it. An unterminated quote is a hard error, not a
/// silently truncated command.
pub fn tokenize(command: &str) -> Result<Vec<String>> {
    let mut tokens = Vec::new();
    let mut cur = String::new();
    let mut in_token = false;
    let mut quote: Option<char> = None;
    for c in command.chars() {
        match quote {
            Some(q) if c == q => quote = None,
            Some(_) => cur.push(c),
            None => match c {
                '\'' | '"' => {
                    quote = Some(c);
                    in_token = true; // `''` is a real (empty) token
                }
                c if c.is_whitespace() => {
                    if in_token {
                        tokens.push(std::mem::take(&mut cur));
                        in_token = false;
                    }
                }
                c => {
                    cur.push(c);
                    in_token = true;
                }
            },
        }
    }
    if quote.is_some() {
        return Err(Error::GridScale(format!(
            "unterminated quote in command `{command}`"
        )));
    }
    if in_token {
        tokens.push(cur);
    }
    Ok(tokens)
}

impl Shell for SimShell {
    fn execute(&self, command: &str) -> Result<CommandOutput> {
        let tokens = tokenize(command)?;
        let tool = tokens
            .first()
            .map(String::as_str)
            .ok_or_else(|| Error::GridScale("empty command".into()))?;
        let ok = |stdout: String| {
            Ok(CommandOutput {
                status: 0,
                stdout,
                stderr: String::new(),
            })
        };
        match tool {
            "qsub" | "sbatch" | "oarsub" | "condor_submit" | "glite-wms-job-submit" => {
                let id = self.cluster.lock().unwrap().create_job();
                ok(self.format_submit(id))
            }
            "qstat" | "squeue" | "oarstat" | "condor_q" | "glite-wms-job-status" => {
                // the job id is the first non-flag argument (skipping flag values)
                let mut id_arg = None;
                let mut skip_next = false;
                for t in tokens[1..].iter().map(String::as_str) {
                    if skip_next {
                        skip_next = false;
                        continue;
                    }
                    if t.starts_with('-') {
                        skip_next = matches!(t, "-j" | "-o" | "-format" | "-f");
                        // `-f <id>` / `-j <id>` carry the id as the value
                        if matches!(t, "-j" | "-f") {
                            skip_next = false;
                        }
                        continue;
                    }
                    id_arg = Some(t);
                    break;
                }
                let id_arg =
                    id_arg.ok_or_else(|| Error::GridScale("no job id".into()))?;
                let id = self.extract_id(id_arg)?;
                let cluster = self.cluster.lock().unwrap();
                let state = cluster.state_now(id)?;
                ok(self.format_status(id, state))
            }
            "qdel" | "scancel" | "oardel" | "condor_rm" | "glite-wms-job-cancel" => {
                let id_arg = tokens
                    .iter()
                    .skip(1)
                    .map(String::as_str)
                    .find(|t| !t.starts_with('-'))
                    .ok_or_else(|| Error::GridScale("no job id".into()))?;
                let id = self.extract_id(id_arg)?;
                self.cluster.lock().unwrap().cancel(id)?;
                ok(String::new())
            }
            other => Err(Error::GridScale(format!("unknown tool `{other}`"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::environment::cluster::SimCluster;
    use crate::gridscale::{
        CondorAdapter, GliteAdapter, OarAdapter, PbsAdapter, SchedulerAdapter,
        SgeAdapter, SlurmAdapter,
    };

    fn shell(flavor: Flavor) -> SimShell {
        SimShell::new(flavor, Arc::new(Mutex::new(SimCluster::homogeneous(4, 1.0))))
    }

    fn submit_via<A: SchedulerAdapter>(adapter: &A, sh: &SimShell) -> String {
        let out = sh.execute(&adapter.submit_command("/tmp/job.sh")).unwrap();
        adapter.parse_submit(&out.stdout).unwrap()
    }

    #[test]
    fn every_dialect_roundtrips_submit_and_status() {
        // each (adapter, flavor) pair: submit → id → status → parse
        let pbs = shell(Flavor::Pbs);
        let id = submit_via(&PbsAdapter, &pbs);
        let st = pbs.execute(&PbsAdapter.status_command(&id)).unwrap();
        PbsAdapter.parse_status(&st.stdout).unwrap();

        let slurm = shell(Flavor::Slurm);
        let id = submit_via(&SlurmAdapter, &slurm);
        let st = slurm.execute(&SlurmAdapter.status_command(&id)).unwrap();
        SlurmAdapter.parse_status(&st.stdout).unwrap();

        let sge = shell(Flavor::Sge);
        let id = submit_via(&SgeAdapter, &sge);
        let st = sge.execute(&SgeAdapter.status_command(&id)).unwrap();
        SgeAdapter.parse_status(&st.stdout).unwrap();

        let oar = shell(Flavor::Oar);
        let id = submit_via(&OarAdapter, &oar);
        let st = oar.execute(&OarAdapter.status_command(&id)).unwrap();
        OarAdapter.parse_status(&st.stdout).unwrap();

        let condor = shell(Flavor::Condor);
        let id = submit_via(&CondorAdapter, &condor);
        let st = condor.execute(&CondorAdapter.status_command(&id)).unwrap();
        CondorAdapter.parse_status(&st.stdout).unwrap();

        let glite = shell(Flavor::Glite);
        let a = GliteAdapter::new("biomed");
        let id = submit_via(&a, &glite);
        assert!(id.starts_with("https://"));
        let st = glite.execute(&a.status_command(&id)).unwrap();
        a.parse_status(&st.stdout).unwrap();
    }

    #[test]
    fn unknown_tool_rejected() {
        assert!(shell(Flavor::Pbs).execute("rm -rf /").is_err());
    }

    #[test]
    fn cancel_roundtrip() {
        let sh = shell(Flavor::Slurm);
        let id = submit_via(&SlurmAdapter, &sh);
        sh.execute(&SlurmAdapter.cancel_command(&id)).unwrap();
    }

    #[test]
    fn tokenizer_splits_plain_words() {
        assert_eq!(
            tokenize("qstat -f 123.headnode").unwrap(),
            vec!["qstat", "-f", "123.headnode"]
        );
        assert_eq!(tokenize("   qdel   7  ").unwrap(), vec!["qdel", "7"]);
        assert!(tokenize("").unwrap().is_empty());
    }

    #[test]
    fn tokenizer_keeps_quoted_whitespace_together() {
        assert_eq!(
            tokenize("qsub -N 'ants sweep' \"/data/run dir/job.sh\"").unwrap(),
            vec!["qsub", "-N", "ants sweep", "/data/run dir/job.sh"]
        );
        // quote splices mid-token, opposite quote kind is literal inside
        assert_eq!(
            tokenize("echo pre'mid dle'post \"it's\"").unwrap(),
            vec!["echo", "premid dlepost", "it's"]
        );
        // an explicitly empty argument survives as an empty token
        assert_eq!(tokenize("cmd '' x").unwrap(), vec!["cmd", "", "x"]);
    }

    #[test]
    fn tokenizer_rejects_unterminated_quote() {
        let err = tokenize("qsub '/tmp/my job.sh").unwrap_err();
        assert!(err.to_string().contains("unterminated quote"));
    }

    #[test]
    fn submit_accepts_script_path_with_spaces() {
        let sh = shell(Flavor::Pbs);
        let out = sh.execute("qsub '/tmp/my job dir/run me.sh'").unwrap();
        let id = PbsAdapter.parse_submit(&out.stdout).unwrap();
        let st = sh.execute(&PbsAdapter.status_command(&id)).unwrap();
        PbsAdapter.parse_status(&st.stdout).unwrap();
    }
}
