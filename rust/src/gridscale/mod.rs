//! GridScale: the environment-access layer of the OpenMOLE ecosystem
//! (paper §2.2).
//!
//! GridScale's design decision, reproduced here literally, is to drive
//! every computing environment **through its command-line tools** rather
//! than a standard API: job submission builds a `qsub`/`sbatch`/`oarsub`/
//! `condor_submit`/`glite-wms-job-submit` invocation, and job monitoring
//! parses the corresponding status command's output. "From a higher
//! perspective, this allows OpenMOLE to work seamlessly with any computing
//! environment the user can access."
//!
//! The only simulated piece is the [`Shell`] executing those commands: the
//! real system would run them over SSH; this reproduction routes them to
//! an in-process cluster simulator ([`shell::SimShell`]) that implements
//! each middleware's CLI surface (DESIGN.md §3). Everything above the
//! shell — script generation, id extraction, state parsing — is the real
//! GridScale logic and is tested against realistic tool transcripts.

pub mod adapters;
pub mod shell;

use crate::error::Result;

/// A job description handed to a scheduler adapter.
#[derive(Debug, Clone)]
pub struct JobScript {
    pub name: String,
    /// Command to run on the node (the packaged task invocation).
    pub command: String,
    /// Requested wall time in seconds.
    pub walltime_s: u64,
    /// Requested memory in MB (`openMOLEMemory = 1200` in Listing 5).
    pub memory_mb: u64,
    /// Queue / partition / VO, middleware-dependent.
    pub queue: Option<String>,
}

impl JobScript {
    pub fn new(name: impl Into<String>, command: impl Into<String>) -> Self {
        JobScript {
            name: name.into(),
            command: command.into(),
            walltime_s: 3600,
            memory_mb: 1024,
            queue: None,
        }
    }

    pub fn walltime(mut self, s: u64) -> Self {
        self.walltime_s = s;
        self
    }

    pub fn memory(mut self, mb: u64) -> Self {
        self.memory_mb = mb;
        self
    }

    pub fn queue(mut self, q: impl Into<String>) -> Self {
        self.queue = Some(q.into());
        self
    }
}

/// Lifecycle states every middleware maps onto.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobState {
    Submitted,
    Queued,
    Running,
    Done,
    Failed,
}

/// A middleware adapter: builds submission/status/cancel command lines and
/// parses the tool outputs. One implementation per scheduler the paper
/// lists (PBS, SGE, Slurm, OAR, Condor) plus gLite for EGI.
pub trait SchedulerAdapter: Send + Sync {
    fn name(&self) -> &'static str;

    /// Render the submission script (`#PBS -l walltime=...` etc.).
    fn script(&self, job: &JobScript) -> String;

    /// The command line that submits `script_path`.
    fn submit_command(&self, script_path: &str) -> String;

    /// Extract the middleware job id from the submit tool's stdout.
    fn parse_submit(&self, stdout: &str) -> Result<String>;

    /// The command line querying one job's state.
    fn status_command(&self, job_id: &str) -> String;

    /// Parse the status tool's output into a [`JobState`].
    fn parse_status(&self, stdout: &str) -> Result<JobState>;

    /// The command line cancelling a job.
    fn cancel_command(&self, job_id: &str) -> String;
}

/// Output of a shell command (status + stdout + stderr).
#[derive(Debug, Clone, Default)]
pub struct CommandOutput {
    pub status: i32,
    pub stdout: String,
    pub stderr: String,
}

/// Something that can execute command lines — an SSH connection in real
/// GridScale, the cluster simulator here.
pub trait Shell: Send + Sync {
    fn execute(&self, command: &str) -> Result<CommandOutput>;
}

pub use adapters::{CondorAdapter, GliteAdapter, OarAdapter, PbsAdapter, SgeAdapter, SlurmAdapter};
pub use shell::{tokenize, SimShell};
