//! `EGIEnvironment("biomed")` — the European Grid Infrastructure of
//! Listing 5, as a discrete-event simulation (DESIGN.md §3): thousands of
//! heterogeneous worker nodes behind gLite-style brokering with visible
//! submission latency and failures.

use std::sync::Arc;

use crate::environment::cluster::{BatchEnvironment, InfraModel};
use crate::environment::{EnvStats, Environment, Job, JobHandle};
use crate::exec::ThreadPool;

/// The EGI environment: a thin façade over [`BatchEnvironment::glite`]
/// with grid-calibrated infrastructure parameters, mirroring
/// `EGIEnvironment("biomed", openMOLEMemory = 1200, wallTime = 4 hours)`.
pub struct EgiEnvironment {
    inner: BatchEnvironment,
}

impl EgiEnvironment {
    /// `vo` — virtual organisation; `nodes` — simulated worker slots the VO
    /// grants (the paper used 2,000 concurrent islands).
    pub fn new(vo: &str, nodes: usize, pool: Arc<ThreadPool>, seed: u64) -> Self {
        EgiEnvironment {
            inner: BatchEnvironment::glite(vo, nodes, pool, seed),
        }
    }

    /// Override the infrastructure model (failure rate, latency, walltime).
    pub fn with_infra(self, infra: InfraModel) -> Self {
        EgiEnvironment {
            inner: self.inner.with_infra(infra),
        }
    }

    pub fn nodes(&self) -> usize {
        self.inner.nodes()
    }
}

impl Environment for EgiEnvironment {
    fn name(&self) -> &str {
        self.inner.name()
    }

    fn submit(&self, job: Job) -> JobHandle {
        self.inner.submit(job)
    }

    fn stats(&self) -> EnvStats {
        self.inner.stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::Context;
    use crate::dsl::task::ClosureTask;
    use crate::environment::run_all;

    #[test]
    fn grid_throughput_scales_with_nodes() {
        // the paper's headline shape: more workers → proportionally more
        // evaluations per virtual hour
        let pool = Arc::new(ThreadPool::new(4));
        let mut makespans = Vec::new();
        for nodes in [4usize, 16] {
            let env = EgiEnvironment::new("biomed", nodes, Arc::clone(&pool), 3)
                .with_infra(InfraModel {
                    failure_rate: 0.0,
                    submit_latency_median_s: 1.0,
                    submit_latency_sigma: 0.1,
                    ..InfraModel::grid()
                });
            let t = Arc::new(ClosureTask::new("e", |c| Ok(c.clone())).cost(60.0));
            let results = run_all(
                &env,
                (0..64)
                    .map(|_| Job::new(Arc::clone(&t) as _, Context::new()))
                    .collect(),
            );
            let makespan = results
                .into_iter()
                .map(|r| r.unwrap().1.virtual_end)
                .fold(0.0, f64::max);
            makespans.push(makespan);
        }
        // 4× the nodes → makespan should shrink ~4× in expectation. The
        // bound is deliberately loose: node speeds are lognormal(σ=0.35),
        // so with only 4 nodes the slow side's mean speed can drift ~±2σ
        // (a ≈1.3× swing either way) and the 16-node pool's minimum-
        // completion-time placement adds its own variance. Requiring a
        // 1.6× improvement keeps ≈2.5σ of margin under any seed while
        // still rejecting a non-scaling scheduler (which would give ≈1×).
        assert!(
            makespans[0] > makespans[1] * 1.6,
            "no scaling: {makespans:?}"
        );
    }

    #[test]
    fn egi_reports_grid_latency() {
        let pool = Arc::new(ThreadPool::new(2));
        let env = EgiEnvironment::new("biomed", 4, pool, 5);
        let t = Arc::new(ClosureTask::new("e", |c| Ok(c.clone())).cost(10.0));
        let (_, r) = env.submit(Job::new(t, Context::new())).wait().unwrap();
        assert!(
            r.submit_delay_s > 1.0,
            "grid brokering latency should be tens of seconds, got {}",
            r.submit_delay_s
        );
    }
}
