//! The discrete-event cluster simulator and the batch environments built
//! on it (PBS / SGE / Slurm / OAR / Condor — paper §2.2).
//!
//! Real compute runs locally on the shared thread pool; the simulator
//! computes *when* the same work would have started and finished on the
//! modelled infrastructure (submission latency → queue → node execution at
//! the node's speed, with walltime enforcement and failure injection).
//! Job submission and monitoring go through the GridScale command layer
//! ([`crate::gridscale`]) against a [`SimShell`] head node, reproducing
//! OpenMOLE's CLI-driven delegation end to end.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::dsl::task::run_checked;
use crate::environment::{EnvStats, Environment, Job, JobHandle, JobReport};
use crate::error::{Error, Result};
use crate::exec::ThreadPool;
use crate::gridscale::shell::{Flavor, SimShell};
use crate::gridscale::{
    CondorAdapter, GliteAdapter, JobScript, JobState, OarAdapter, PbsAdapter,
    SchedulerAdapter, SgeAdapter, Shell, SlurmAdapter,
};
use crate::util::Rng;

/// Timing of one scheduled attempt on the simulated cluster.
#[derive(Debug, Clone, Copy)]
pub struct Scheduled {
    pub node: usize,
    pub start: f64,
    pub end: f64,
    /// True if this attempt was injected as a failure.
    pub failed: bool,
    /// True if the job was killed at its walltime limit.
    pub walltime_killed: bool,
}

struct SimJob {
    submit_t: f64,
    start_t: f64,
    end_t: f64,
    cancelled: bool,
    failed: bool,
}

/// Discrete-event state of one cluster: per-node availability plus a job
/// table for the CLI surface.
pub struct SimCluster {
    /// Execution-time multiplier per node (1.0 = reference speed).
    speeds: Vec<f64>,
    /// Virtual time at which each node becomes free.
    node_free: Vec<f64>,
    jobs: HashMap<u64, SimJob>,
    next_id: u64,
    /// Latest scheduled event (the cluster's "now" for status queries).
    pub clock: f64,
}

impl SimCluster {
    pub fn new(speeds: Vec<f64>) -> Self {
        let n = speeds.len();
        SimCluster {
            speeds,
            node_free: vec![0.0; n],
            jobs: HashMap::new(),
            next_id: 1,
            clock: 0.0,
        }
    }

    /// `n` identical nodes with the given speed multiplier.
    pub fn homogeneous(n: usize, speed: f64) -> Self {
        Self::new(vec![speed; n])
    }

    /// Heterogeneous node speeds drawn lognormally around `median_speed`
    /// (grid worker nodes differ widely — DESIGN.md §3).
    pub fn heterogeneous(n: usize, median_speed: f64, sigma: f64, rng: &mut Rng) -> Self {
        let speeds = (0..n)
            .map(|_| median_speed * rng.lognormal(0.0, sigma))
            .collect();
        Self::new(speeds)
    }

    pub fn nodes(&self) -> usize {
        self.speeds.len()
    }

    /// Register a job (the `qsub` handler).
    pub fn create_job(&mut self) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.jobs.insert(
            id,
            SimJob {
                submit_t: self.clock,
                start_t: f64::INFINITY,
                end_t: f64::INFINITY,
                cancelled: false,
                failed: false,
            },
        );
        id
    }

    /// Schedule one execution attempt: pick the earliest-free node, run
    /// for `nominal_exec_s * node_speed` from `release_t`, bounded by
    /// `walltime_s`; if `fail_at_fraction` is set the node is occupied for
    /// that fraction and the attempt fails.
    pub fn schedule(
        &mut self,
        id: u64,
        release_t: f64,
        nominal_exec_s: f64,
        walltime_s: f64,
        fail_at_fraction: Option<f64>,
    ) -> Result<Scheduled> {
        // minimum-completion-time placement (ties: lowest index, keeping
        // FIFO determinism on homogeneous clusters): heterogeneous pools
        // route work to the node that finishes it first, as batch
        // schedulers with runtime estimates / backfill effectively do
        let node = (0..self.node_free.len())
            .min_by(|&a, &b| {
                let end_a = self.node_free[a].max(release_t)
                    + nominal_exec_s * self.speeds[a];
                let end_b = self.node_free[b].max(release_t)
                    + nominal_exec_s * self.speeds[b];
                end_a.partial_cmp(&end_b).unwrap()
            })
            .ok_or_else(|| Error::EnvironmentError {
                environment: "sim-cluster".into(),
                message: "cluster has no nodes".into(),
            })?;
        let start = self.node_free[node].max(release_t);
        let full_exec = nominal_exec_s * self.speeds[node];
        let (end, failed, walltime_killed) = match fail_at_fraction {
            Some(f) => (start + full_exec * f.clamp(0.01, 1.0), true, false),
            None if full_exec > walltime_s => (start + walltime_s, false, true),
            None => (start + full_exec, false, false),
        };
        self.node_free[node] = end;
        if end > self.clock {
            self.clock = end;
        }
        if let Some(j) = self.jobs.get_mut(&id) {
            j.submit_t = j.submit_t.min(release_t);
            j.start_t = start;
            j.end_t = end;
            j.failed = failed || walltime_killed;
        }
        Ok(Scheduled {
            node,
            start,
            end,
            failed,
            walltime_killed,
        })
    }

    /// Job state at the cluster's current clock (the `qstat` handler).
    pub fn state_now(&self, id: u64) -> Result<JobState> {
        let j = self.jobs.get(&id).ok_or_else(|| Error::EnvironmentError {
            environment: "sim-cluster".into(),
            message: format!("unknown job {id}"),
        })?;
        if j.cancelled {
            return Ok(JobState::Failed);
        }
        Ok(if j.start_t.is_infinite() {
            JobState::Queued
        } else if j.end_t <= self.clock {
            if j.failed {
                JobState::Failed
            } else {
                JobState::Done
            }
        } else if j.start_t <= self.clock {
            JobState::Running
        } else {
            JobState::Queued
        })
    }

    pub fn cancel(&mut self, id: u64) -> Result<()> {
        self.jobs
            .get_mut(&id)
            .map(|j| j.cancelled = true)
            .ok_or_else(|| Error::EnvironmentError {
                environment: "sim-cluster".into(),
                message: format!("unknown job {id}"),
            })
    }
}

/// Failure / latency model for a simulated environment.
#[derive(Debug, Clone)]
pub struct InfraModel {
    /// Median submission latency (s); drawn lognormally.
    pub submit_latency_median_s: f64,
    /// Lognormal sigma of the submission latency.
    pub submit_latency_sigma: f64,
    /// Probability that one attempt fails mid-run.
    pub failure_rate: f64,
    /// Maximum resubmissions after failures.
    pub max_retries: u32,
    /// Walltime limit per job (s).
    pub walltime_s: f64,
}

impl InfraModel {
    /// A well-behaved departmental cluster.
    pub fn cluster() -> Self {
        InfraModel {
            submit_latency_median_s: 2.0,
            submit_latency_sigma: 0.5,
            failure_rate: 0.005,
            max_retries: 3,
            walltime_s: 4.0 * 3600.0,
        }
    }

    /// EGI-like: slow brokering, visible failure rate (Listing 5 uses a
    /// 4 h walltime for 1 h islands precisely because of this).
    pub fn grid() -> Self {
        InfraModel {
            submit_latency_median_s: 120.0,
            submit_latency_sigma: 1.0,
            failure_rate: 0.05,
            max_retries: 5,
            walltime_s: 4.0 * 3600.0,
        }
    }

    /// An SSH server: negligible latency, no failures.
    pub fn ssh() -> Self {
        InfraModel {
            submit_latency_median_s: 0.2,
            submit_latency_sigma: 0.2,
            failure_rate: 0.0,
            max_retries: 0,
            walltime_s: f64::INFINITY,
        }
    }
}

/// A batch-scheduler environment (PBS/SGE/Slurm/OAR/Condor) or the EGI
/// grid, over the shared simulator core.
pub struct BatchEnvironment {
    name: String,
    adapter: Arc<dyn SchedulerAdapter>,
    shell: Arc<dyn Shell>,
    cluster: Arc<Mutex<SimCluster>>,
    infra: InfraModel,
    pool: Arc<ThreadPool>,
    rng: Mutex<Rng>,
    stats: Arc<Mutex<EnvStats>>,
    queue_name: Option<String>,
}

impl BatchEnvironment {
    pub fn new(
        name: impl Into<String>,
        adapter: Arc<dyn SchedulerAdapter>,
        flavor: Flavor,
        cluster: SimCluster,
        infra: InfraModel,
        pool: Arc<ThreadPool>,
        seed: u64,
    ) -> Self {
        let cluster = Arc::new(Mutex::new(cluster));
        BatchEnvironment {
            name: name.into(),
            adapter,
            shell: Arc::new(SimShell::new(flavor, Arc::clone(&cluster))),
            cluster,
            infra,
            pool,
            rng: Mutex::new(Rng::new(seed)),
            stats: Arc::new(Mutex::new(EnvStats::default())),
            queue_name: None,
        }
    }

    /// `PBSEnvironment(...)` of the DSL.
    pub fn pbs(nodes: usize, pool: Arc<ThreadPool>, seed: u64) -> Self {
        Self::new(
            format!("pbs({nodes})"),
            Arc::new(PbsAdapter),
            Flavor::Pbs,
            SimCluster::homogeneous(nodes, 1.0),
            InfraModel::cluster(),
            pool,
            seed,
        )
    }

    pub fn slurm(nodes: usize, pool: Arc<ThreadPool>, seed: u64) -> Self {
        Self::new(
            format!("slurm({nodes})"),
            Arc::new(SlurmAdapter),
            Flavor::Slurm,
            SimCluster::homogeneous(nodes, 1.0),
            InfraModel::cluster(),
            pool,
            seed,
        )
    }

    pub fn sge(nodes: usize, pool: Arc<ThreadPool>, seed: u64) -> Self {
        Self::new(
            format!("sge({nodes})"),
            Arc::new(SgeAdapter),
            Flavor::Sge,
            SimCluster::homogeneous(nodes, 1.0),
            InfraModel::cluster(),
            pool,
            seed,
        )
    }

    pub fn oar(nodes: usize, pool: Arc<ThreadPool>, seed: u64) -> Self {
        Self::new(
            format!("oar({nodes})"),
            Arc::new(OarAdapter),
            Flavor::Oar,
            SimCluster::homogeneous(nodes, 1.0),
            InfraModel::cluster(),
            pool,
            seed,
        )
    }

    pub fn condor(nodes: usize, pool: Arc<ThreadPool>, seed: u64) -> Self {
        Self::new(
            format!("condor({nodes})"),
            Arc::new(CondorAdapter),
            Flavor::Condor,
            SimCluster::homogeneous(nodes, 1.0),
            InfraModel::cluster(),
            pool,
            seed,
        )
    }

    /// EGI over gLite with heterogeneous workers (used by
    /// [`crate::environment::egi::EgiEnvironment`]).
    pub fn glite(
        vo: &str,
        nodes: usize,
        pool: Arc<ThreadPool>,
        seed: u64,
    ) -> Self {
        let mut rng = Rng::new(seed ^ 0x9e3779b97f4a7c15);
        let cluster = SimCluster::heterogeneous(nodes, 1.0, 0.35, &mut rng);
        let mut env = Self::new(
            format!("egi:{vo}({nodes})"),
            Arc::new(GliteAdapter::new(vo)),
            Flavor::Glite,
            cluster,
            InfraModel::grid(),
            pool,
            seed,
        );
        env.queue_name = Some(vo.to_string());
        env
    }

    pub fn with_infra(mut self, infra: InfraModel) -> Self {
        self.infra = infra;
        self
    }

    pub fn nodes(&self) -> usize {
        self.cluster.lock().unwrap().nodes()
    }
}

impl Environment for BatchEnvironment {
    fn name(&self) -> &str {
        &self.name
    }

    fn submit(&self, job: Job) -> JobHandle {
        {
            self.stats.lock().unwrap().submitted += 1;
        }
        let mut rng = self.rng.lock().unwrap().fork();
        let adapter = Arc::clone(&self.adapter);
        let shell = Arc::clone(&self.shell);
        let cluster = Arc::clone(&self.cluster);
        let infra = self.infra.clone();
        let stats = Arc::clone(&self.stats);
        let env_name = self.name.clone();
        let queue = self.queue_name.clone();

        let join = self.pool.submit(move || {
            let mut run = || -> Result<(crate::core::Context, JobReport)> {
                // --- GridScale path: script → submit → parse id ------------
                let mut script = JobScript::new(
                    job.task.name().to_string(),
                    format!("./run-task.sh {}", job.task.name()),
                )
                .walltime(infra.walltime_s.min(1e9) as u64)
                .memory(1200);
                if let Some(q) = &queue {
                    script = script.queue(q.clone());
                }
                let _script_text = adapter.script(&script); // rendered as GridScale would
                let submit_out = shell.execute(&adapter.submit_command("/tmp/job.sh"))?;
                let middleware_id = adapter.parse_submit(&submit_out.stdout)?;

                // --- real compute ------------------------------------------
                let started = Instant::now();
                let result = run_checked(job.task.as_ref(), &job.context)?;
                let real = started.elapsed();
                // nominal remote duration: the task's cost hint, or the real
                // local duration if no hint is declared
                let hint = job.task.cost_hint();
                let nominal = if hint > 0.0 { hint } else { real.as_secs_f64() };

                // --- virtual schedule with failures/retries ----------------
                let sim_id = {
                    let c = cluster.lock().unwrap();
                    // the shell allocated the numeric id; recover it from the
                    // middleware id (digits of the tail)
                    let tail = middleware_id.rsplit('/').next().unwrap_or(&middleware_id);
                    let digits: String =
                        tail.chars().filter(|ch| ch.is_ascii_digit()).collect();
                    drop(c);
                    digits.parse::<u64>().unwrap_or(0)
                };
                let mut release = job.virtual_release
                    + rng.lognormal(
                        infra.submit_latency_median_s.max(1e-9).ln(),
                        infra.submit_latency_sigma,
                    );
                let submit_delay = release - job.virtual_release;
                let mut attempts = 0u32;
                let sched = loop {
                    attempts += 1;
                    let fail = rng.bool(infra.failure_rate);
                    let sched = cluster.lock().unwrap().schedule(
                        sim_id,
                        release,
                        nominal,
                        infra.walltime_s,
                        fail.then(|| rng.f64()),
                    )?;
                    if sched.walltime_killed {
                        return Err(Error::WallTimeExceeded(infra.walltime_s as u64));
                    }
                    if !sched.failed {
                        break sched;
                    }
                    // a failed attempt past the retry budget is a terminal
                    // job failure — surfaced to the caller (the broker
                    // re-routes it to another environment)
                    if attempts > infra.max_retries {
                        return Err(Error::NodeFailure {
                            node: format!("node{:04}", sched.node),
                            reason: format!(
                                "attempt {attempts} failed with no retries left \
                                 (max_retries = {})",
                                infra.max_retries
                            ),
                        });
                    }
                    {
                        let mut s = stats.lock().unwrap();
                        s.failed_attempts += 1;
                        s.resubmissions += 1;
                    }
                    // resubmit: fresh brokering latency from the failure time
                    release = sched.end
                        + rng.lognormal(
                            infra.submit_latency_median_s.max(1e-9).ln(),
                            infra.submit_latency_sigma,
                        );
                };

                // --- status poll through the CLI layer (sanity) ------------
                let status_out = shell.execute(&adapter.status_command(&middleware_id))?;
                let state = adapter.parse_status(&status_out.stdout)?;
                debug_assert!(
                    matches!(state, JobState::Done | JobState::Running),
                    "unexpected post-schedule state {state:?}"
                );

                let report = JobReport {
                    environment: env_name.clone(),
                    node: format!("node{:04}", sched.node),
                    attempts,
                    submit_delay_s: submit_delay,
                    queue_s: (sched.start - job.virtual_release - submit_delay).max(0.0),
                    exec_s: sched.end - sched.start,
                    virtual_start: sched.start,
                    virtual_end: sched.end,
                    real_exec: real,
                };
                {
                    let mut s = stats.lock().unwrap();
                    s.completed += 1;
                    s.virtual_cpu_s += report.exec_s;
                    if report.virtual_end > s.virtual_makespan {
                        s.virtual_makespan = report.virtual_end;
                    }
                }
                Ok((result, report))
            };
            match run() {
                Ok((ctx, report)) => (Ok(ctx), report),
                Err(e) => {
                    {
                        // terminal failure: the final attempt failed and
                        // nothing retried it, so it counts in both
                        // `failed_attempts` and `failed_jobs` (keeping
                        // failed_attempts == resubmissions + failed_jobs)
                        let mut s = stats.lock().unwrap();
                        s.failed_attempts += 1;
                        s.failed_jobs += 1;
                    }
                    (
                        Err(e),
                        JobReport {
                            environment: "failed".into(),
                            node: String::new(),
                            attempts: 0,
                            submit_delay_s: 0.0,
                            queue_s: 0.0,
                            exec_s: 0.0,
                            virtual_start: 0.0,
                            virtual_end: 0.0,
                            real_exec: std::time::Duration::ZERO,
                        },
                    )
                }
            }
        });
        JobHandle::from_join(join)
    }

    fn stats(&self) -> EnvStats {
        self.stats.lock().unwrap().clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{val_f64, Context};
    use crate::dsl::task::ClosureTask;
    use crate::environment::run_all;

    fn task(cost: f64) -> Arc<ClosureTask> {
        let x = val_f64("x");
        Arc::new(
            ClosureTask::new("t", {
                let x = x.clone();
                move |ctx| Ok(Context::new().with(&x, ctx.get(&x).unwrap_or(0.0) + 1.0))
            })
            .cost(cost),
        )
    }

    #[test]
    fn sim_cluster_fifo_on_one_node() {
        let mut c = SimCluster::homogeneous(1, 1.0);
        let a = c.create_job();
        let b = c.create_job();
        let s1 = c.schedule(a, 0.0, 10.0, 1e9, None).unwrap();
        let s2 = c.schedule(b, 0.0, 10.0, 1e9, None).unwrap();
        assert_eq!(s1.start, 0.0);
        assert_eq!(s2.start, 10.0); // queued behind job a
        assert_eq!(s2.end, 20.0);
    }

    #[test]
    fn sim_cluster_parallel_nodes() {
        let mut c = SimCluster::homogeneous(4, 1.0);
        let ids: Vec<u64> = (0..4).map(|_| c.create_job()).collect();
        for &id in &ids {
            let s = c.schedule(id, 0.0, 5.0, 1e9, None).unwrap();
            assert_eq!(s.start, 0.0); // all start immediately
        }
    }

    #[test]
    fn walltime_kill() {
        let mut c = SimCluster::homogeneous(1, 1.0);
        let id = c.create_job();
        let s = c.schedule(id, 0.0, 100.0, 30.0, None).unwrap();
        assert!(s.walltime_killed);
        assert_eq!(s.end, 30.0);
    }

    #[test]
    fn slow_node_takes_longer() {
        let mut c = SimCluster::new(vec![2.0]);
        let id = c.create_job();
        let s = c.schedule(id, 0.0, 10.0, 1e9, None).unwrap();
        assert_eq!(s.end - s.start, 20.0);
    }

    #[test]
    fn batch_env_executes_and_simulates() {
        let pool = Arc::new(ThreadPool::new(2));
        let env = BatchEnvironment::pbs(4, pool, 1);
        let results = run_all(
            &env,
            (0..8).map(|_| Job::new(task(10.0), Context::new())).collect(),
        );
        let mut ends = Vec::new();
        for r in results {
            let (_, report) = r.unwrap();
            assert!(report.exec_s >= 10.0 - 1e-9, "bad report: {report:?}");
            assert!(report.submit_delay_s > 0.0);
            ends.push(report.virtual_end);
        }
        // 8 jobs, 4 nodes, 10 s each → makespan at least 20 s of exec
        let makespan = ends.iter().cloned().fold(0.0, f64::max);
        assert!(makespan >= 20.0, "makespan {makespan}");
        assert_eq!(env.stats().completed, 8);
    }

    #[test]
    fn all_flavors_submit_successfully() {
        let pool = Arc::new(ThreadPool::new(2));
        let envs: Vec<BatchEnvironment> = vec![
            BatchEnvironment::pbs(2, Arc::clone(&pool), 1),
            BatchEnvironment::slurm(2, Arc::clone(&pool), 2),
            BatchEnvironment::sge(2, Arc::clone(&pool), 3),
            BatchEnvironment::oar(2, Arc::clone(&pool), 4),
            BatchEnvironment::condor(2, Arc::clone(&pool), 5),
            BatchEnvironment::glite("biomed", 8, Arc::clone(&pool), 6),
        ];
        for env in &envs {
            let (_, report) = env
                .submit(Job::new(task(1.0), Context::new()))
                .wait()
                .unwrap();
            assert!(report.virtual_end > 0.0, "{} produced no timing", env.name());
        }
    }

    #[test]
    fn walltime_exceeded_surfaces_as_error() {
        let pool = Arc::new(ThreadPool::new(1));
        let env = BatchEnvironment::pbs(1, pool, 7).with_infra(InfraModel {
            walltime_s: 5.0,
            ..InfraModel::cluster()
        });
        let err = env
            .submit(Job::new(task(100.0), Context::new()))
            .wait()
            .unwrap_err();
        assert!(matches!(err, Error::WallTimeExceeded(_)));
    }

    #[test]
    fn failure_injection_causes_resubmissions() {
        let pool = Arc::new(ThreadPool::new(2));
        let env = BatchEnvironment::glite("biomed", 16, pool, 11).with_infra(InfraModel {
            failure_rate: 0.5,
            max_retries: 10,
            ..InfraModel::grid()
        });
        let results = run_all(
            &env,
            (0..30).map(|_| Job::new(task(5.0), Context::new())).collect(),
        );
        // with 10 retries a terminal failure needs 11 failed attempts in a
        // row (p = 0.5^11); nearly every job retries its way to success,
        // and the rare terminal loss must surface as NodeFailure
        let mut ok = 0;
        for r in results {
            match r {
                Ok(_) => ok += 1,
                Err(e) => assert!(
                    matches!(e, Error::NodeFailure { .. }),
                    "unexpected error kind: {e}"
                ),
            }
        }
        assert!(ok >= 25, "only {ok}/30 jobs survived 50% failure injection");
        assert!(env.stats().resubmissions > 0, "no failures injected at 50%");
    }

    #[test]
    fn resubmission_accounting_is_consistent() {
        // §satellite: after a drained run with nonzero failure_rate the
        // counters must be mutually consistent
        let pool = Arc::new(ThreadPool::new(2));
        let env = BatchEnvironment::glite("biomed", 8, pool, 23).with_infra(InfraModel {
            failure_rate: 0.3,
            max_retries: 2,
            ..InfraModel::grid()
        });
        let results = run_all(
            &env,
            (0..60).map(|_| Job::new(task(3.0), Context::new())).collect(),
        );
        let ok = results.iter().filter(|r| r.is_ok()).count() as u64;
        let failed = results.iter().filter(|r| r.is_err()).count() as u64;
        let s = env.stats();
        assert_eq!(s.submitted, 60);
        assert_eq!(s.completed, ok);
        assert_eq!(s.failed_jobs, failed);
        assert_eq!(s.in_flight(), 0, "drained env reports in-flight work");
        assert_eq!(
            s.failed_attempts,
            s.resubmissions + s.failed_jobs,
            "every failed attempt must either be retried or terminal"
        );
        assert!(s.resubmissions > 0, "no retries at 30% failure rate");
    }

    #[test]
    fn walltime_kill_accounting() {
        let pool = Arc::new(ThreadPool::new(1));
        let env = BatchEnvironment::pbs(1, pool, 7).with_infra(InfraModel {
            walltime_s: 5.0,
            ..InfraModel::cluster()
        });
        let err = env
            .submit(Job::new(task(100.0), Context::new()))
            .wait()
            .unwrap_err();
        assert!(matches!(err, Error::WallTimeExceeded(_)));
        let s = env.stats();
        assert_eq!(s.submitted, 1);
        assert_eq!(s.completed, 0);
        assert_eq!(s.failed_jobs, 1);
        assert_eq!(s.failed_attempts, 1);
        assert_eq!(s.resubmissions, 0);
        assert_eq!(s.in_flight(), 0);
    }

    #[test]
    fn virtual_release_defers_start() {
        let pool = Arc::new(ThreadPool::new(1));
        let env = BatchEnvironment::slurm(4, pool, 13);
        let (_, r) = env
            .submit(Job::new(task(1.0), Context::new()).released_at(1000.0))
            .wait()
            .unwrap();
        assert!(r.virtual_start >= 1000.0);
    }
}
