//! `SSHEnvironment(user, host, slots)` — remote multi-core server without
//! a batch system (paper §2.2 "remote servers (through SSH)").

use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::dsl::task::run_checked;
use crate::environment::cluster::{InfraModel, SimCluster};
use crate::environment::{EnvStats, Environment, Job, JobHandle, JobReport};
use crate::exec::ThreadPool;
use crate::util::Rng;

/// GridScale's SSH server: jobs run directly (no middleware), limited by
/// the server's slot count; small connection latency per submission.
pub struct SshEnvironment {
    name: String,
    cluster: Arc<Mutex<SimCluster>>,
    infra: InfraModel,
    pool: Arc<ThreadPool>,
    rng: Mutex<Rng>,
    stats: Arc<Mutex<EnvStats>>,
}

impl SshEnvironment {
    pub fn new(host: &str, slots: usize, pool: Arc<ThreadPool>, seed: u64) -> Self {
        SshEnvironment {
            name: format!("ssh:{host}({slots})"),
            cluster: Arc::new(Mutex::new(SimCluster::homogeneous(slots, 1.0))),
            infra: InfraModel::ssh(),
            pool,
            rng: Mutex::new(Rng::new(seed)),
            stats: Arc::new(Mutex::new(EnvStats::default())),
        }
    }

    pub fn with_infra(mut self, infra: InfraModel) -> Self {
        self.infra = infra;
        self
    }
}

impl Environment for SshEnvironment {
    fn name(&self) -> &str {
        &self.name
    }

    fn submit(&self, job: Job) -> JobHandle {
        {
            self.stats.lock().unwrap().submitted += 1;
        }
        let mut rng = self.rng.lock().unwrap().fork();
        let cluster = Arc::clone(&self.cluster);
        let infra = self.infra.clone();
        let stats = Arc::clone(&self.stats);
        let env_name = self.name.clone();
        let join = self.pool.submit(move || {
            let started = Instant::now();
            let result = run_checked(job.task.as_ref(), &job.context);
            let real = started.elapsed();
            let hint = job.task.cost_hint();
            let nominal = if hint > 0.0 { hint } else { real.as_secs_f64() };
            let latency = rng.lognormal(
                infra.submit_latency_median_s.max(1e-9).ln(),
                infra.submit_latency_sigma,
            );
            let release = job.virtual_release + latency;
            let sched = {
                let mut c = cluster.lock().unwrap();
                let id = c.create_job();
                c.schedule(id, release, nominal, infra.walltime_s, None)
                    .expect("ssh cluster has slots")
            };
            let report = JobReport {
                environment: env_name,
                node: "sshd".into(),
                attempts: 1,
                submit_delay_s: latency,
                queue_s: (sched.start - release).max(0.0),
                exec_s: sched.end - sched.start,
                virtual_start: sched.start,
                virtual_end: sched.end,
                real_exec: real,
            };
            {
                // completion counts only for successful tasks (same ledger
                // invariant as LocalEnvironment: submitted == completed +
                // failed_jobs once drained)
                let mut s = stats.lock().unwrap();
                if result.is_ok() {
                    s.completed += 1;
                    s.virtual_cpu_s += report.exec_s;
                    if report.virtual_end > s.virtual_makespan {
                        s.virtual_makespan = report.virtual_end;
                    }
                } else {
                    s.failed_attempts += 1;
                    s.failed_jobs += 1;
                }
            }
            (result, report)
        });
        JobHandle::from_join(join)
    }

    fn stats(&self) -> EnvStats {
        self.stats.lock().unwrap().clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::Context;
    use crate::dsl::task::ClosureTask;
    use crate::environment::run_all;

    #[test]
    fn slots_serialise_virtual_time() {
        let pool = Arc::new(ThreadPool::new(4));
        let env = SshEnvironment::new("calc01", 1, pool, 1);
        let t = Arc::new(ClosureTask::new("c", |c| Ok(c.clone())).cost(10.0));
        let results = run_all(
            &env,
            (0..3).map(|_| Job::new(Arc::clone(&t) as _, Context::new())).collect(),
        );
        let mut ends: Vec<f64> = results
            .into_iter()
            .map(|r| r.unwrap().1.virtual_end)
            .collect();
        ends.sort_by(|a, b| a.partial_cmp(b).unwrap());
        // one slot → three 10 s jobs must span at least 30 virtual seconds
        assert!(ends[2] >= 30.0, "makespan {}", ends[2]);
    }

    #[test]
    fn failed_task_is_not_counted_completed() {
        let pool = Arc::new(ThreadPool::new(1));
        let env = SshEnvironment::new("calc01", 1, pool, 1);
        let t = Arc::new(ClosureTask::new("boom", |_| {
            Err(crate::error::Error::TaskFailed {
                task: "boom".into(),
                message: "nope".into(),
            })
        }));
        env.submit(Job::new(t, Context::new())).wait().unwrap_err();
        let s = env.stats();
        assert_eq!(s.submitted, 1);
        assert_eq!(s.completed, 0);
        assert_eq!(s.failed_jobs, 1);
        assert_eq!(s.in_flight(), 0);
    }
}
