//! `LocalEnvironment(threads = n)` — the "test small on your computer"
//! half of the paper's philosophy (§2.1).

use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::dsl::task::run_checked;
use crate::environment::{EnvStats, Environment, Job, JobHandle};
use crate::exec::ThreadPool;

/// Executes jobs directly on a local thread pool. Virtual time equals real
/// time: no submission latency, no queueing beyond pool capacity.
pub struct LocalEnvironment {
    name: String,
    pool: Arc<ThreadPool>,
    stats: Arc<Mutex<EnvStats>>,
}

impl LocalEnvironment {
    pub fn new(threads: usize) -> Self {
        LocalEnvironment {
            name: format!("local({threads})"),
            pool: Arc::new(ThreadPool::new(threads)),
            stats: Arc::new(Mutex::new(EnvStats::default())),
        }
    }

    /// Share an existing pool (environments multiplexing one machine).
    pub fn with_pool(pool: Arc<ThreadPool>) -> Self {
        LocalEnvironment {
            name: format!("local({})", pool.threads()),
            pool,
            stats: Arc::new(Mutex::new(EnvStats::default())),
        }
    }

    pub fn threads(&self) -> usize {
        self.pool.threads()
    }
}

impl Environment for LocalEnvironment {
    fn name(&self) -> &str {
        &self.name
    }

    fn submit(&self, job: Job) -> JobHandle {
        {
            self.stats.lock().unwrap().submitted += 1;
        }
        let stats = Arc::clone(&self.stats);
        let env_name = self.name.clone();
        let join = self.pool.submit(move || {
            let started = Instant::now();
            let result = run_checked(job.task.as_ref(), &job.context);
            let real = started.elapsed();
            let exec_s = real.as_secs_f64();
            let virtual_start = job.virtual_release;
            let report = crate::environment::JobReport {
                environment: env_name,
                node: "localhost".into(),
                attempts: 1,
                submit_delay_s: 0.0,
                queue_s: 0.0,
                exec_s,
                virtual_start,
                virtual_end: virtual_start + exec_s,
                real_exec: real,
            };
            {
                // count completion only when the task succeeded — a failed
                // task previously drifted the counters by landing in both
                // the error path and `completed`
                let mut s = stats.lock().unwrap();
                if result.is_ok() {
                    s.completed += 1;
                    s.virtual_cpu_s += exec_s;
                    if report.virtual_end > s.virtual_makespan {
                        s.virtual_makespan = report.virtual_end;
                    }
                } else {
                    s.failed_attempts += 1;
                    s.failed_jobs += 1;
                }
            }
            (result, report)
        });
        JobHandle::from_join(join)
    }

    fn stats(&self) -> EnvStats {
        self.stats.lock().unwrap().clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{val_f64, Context};
    use crate::dsl::task::ClosureTask;

    fn double_task() -> Arc<ClosureTask> {
        let x = val_f64("x");
        let y = val_f64("y");
        Arc::new(
            ClosureTask::new("double", {
                let (x, y) = (x.clone(), y.clone());
                move |ctx| Ok(Context::new().with(&y, ctx.get(&x)? * 2.0))
            })
            .input(&x)
            .output(&y),
        )
    }

    #[test]
    fn executes_jobs() {
        let env = LocalEnvironment::new(2);
        let x = val_f64("x");
        let y = val_f64("y");
        let h = env.submit(Job::new(double_task(), Context::new().with(&x, 21.0)));
        let (ctx, report) = h.wait().unwrap();
        assert_eq!(ctx.get(&y).unwrap(), 42.0);
        assert_eq!(report.attempts, 1);
        assert_eq!(report.node, "localhost");
    }

    #[test]
    fn stats_count_completions() {
        let env = LocalEnvironment::new(4);
        let x = val_f64("x");
        let handles: Vec<_> = (0..10)
            .map(|i| {
                env.submit(Job::new(
                    double_task(),
                    Context::new().with(&x, f64::from(i)),
                ))
            })
            .collect();
        for h in handles {
            h.wait().unwrap();
        }
        let s = env.stats();
        assert_eq!(s.submitted, 10);
        assert_eq!(s.completed, 10);
    }

    #[test]
    fn task_error_propagates() {
        let env = LocalEnvironment::new(1);
        let t = Arc::new(ClosureTask::new("boom", |_| {
            Err(crate::error::Error::TaskFailed {
                task: "boom".into(),
                message: "nope".into(),
            })
        }));
        let err = env.submit(Job::new(t, Context::new())).wait().unwrap_err();
        assert!(err.to_string().contains("nope"));
        let s = env.stats();
        assert_eq!(s.submitted, 1);
        assert_eq!(s.completed, 0, "failed task must not count as completed");
        assert_eq!(s.failed_jobs, 1);
        assert_eq!(s.in_flight(), 0);
    }
}
