//! Execution environments: where task workload is delegated (paper §2.2).
//!
//! The paper's claim is that switching a workflow from a laptop to a
//! cluster or to EGI is a one-line change. The [`Environment`] trait is
//! that line: every implementation accepts [`Job`]s and returns
//! [`JobHandle`]s, whatever the infrastructure behind it.
//!
//! ## Simulated infrastructure + real compute
//!
//! This reproduction has no gLite grid to submit to, so remote
//! environments are *discrete-event simulations* of their infrastructure
//! (submission latency, queueing, node speed, failures) wrapped around
//! *real* local execution of the task (PJRT-compiled ant model or any
//! other task). Each job therefore yields two timelines:
//!
//! * the **real** one — how long the computation actually took here;
//! * the **virtual** one — when the job would have started/finished on the
//!   simulated infrastructure. Throughput results in EXPERIMENTS.md are
//!   virtual-time numbers, which is exactly what the substitution rule in
//!   DESIGN.md §3 calls for.
//!
//! Dependencies between jobs enter the virtual timeline through
//! [`Job::virtual_release`]: a job may not start (in virtual time) before
//! its inputs existed. Drivers (generational GA, islands) set it to the
//! virtual end of the jobs they consumed.

pub mod cluster;
pub mod egi;
pub mod local;
pub mod ssh;

use std::sync::Arc;
use std::time::Duration;

use crate::core::Context;
use crate::dsl::task::Task;
use crate::error::{Error, Result};
use crate::exec::JobJoin;

/// A unit of delegated work.
pub struct Job {
    pub task: Arc<dyn Task>,
    pub context: Context,
    /// Earliest virtual time (s) this job may start on the simulated
    /// infrastructure — encodes dataflow dependencies in virtual time.
    pub virtual_release: f64,
}

impl Job {
    pub fn new(task: Arc<dyn Task>, context: Context) -> Self {
        Job {
            task,
            context,
            virtual_release: 0.0,
        }
    }

    pub fn released_at(mut self, t: f64) -> Self {
        self.virtual_release = t;
        self
    }
}

/// Where and when a job ran, in both timelines.
#[derive(Debug, Clone)]
pub struct JobReport {
    pub environment: String,
    pub node: String,
    /// 1 + number of resubmissions after simulated failures.
    pub attempts: u32,
    /// Virtual seconds spent in submission/brokering.
    pub submit_delay_s: f64,
    /// Virtual seconds spent queued before a node was free.
    pub queue_s: f64,
    /// Virtual seconds executing on the (possibly slower) remote node.
    pub exec_s: f64,
    /// Virtual timestamp at which the job started executing.
    pub virtual_start: f64,
    /// Virtual timestamp at which the job completed.
    pub virtual_end: f64,
    /// Real wall-clock the computation took locally.
    pub real_exec: Duration,
}

/// Handle to a submitted job.
pub struct JobHandle {
    join: JobJoin<(Result<Context>, JobReport)>,
}

impl JobHandle {
    pub fn from_join(join: JobJoin<(Result<Context>, JobReport)>) -> Self {
        JobHandle { join }
    }

    /// Block until the job completes.
    pub fn wait(self) -> Result<(Context, JobReport)> {
        match self.join.join() {
            Ok((Ok(ctx), report)) => Ok((ctx, report)),
            Ok((Err(e), _)) => Err(e),
            Err(panic) => Err(Error::EnvironmentError {
                environment: "<pool>".into(),
                message: format!("worker panicked: {panic}"),
            }),
        }
    }

    /// Non-blocking poll.
    pub fn try_wait(&self) -> Option<Result<(Context, JobReport)>> {
        self.join.try_join().map(|r| match r {
            Ok((Ok(ctx), report)) => Ok((ctx, report)),
            Ok((Err(e), _)) => Err(e),
            Err(panic) => Err(Error::EnvironmentError {
                environment: "<pool>".into(),
                message: format!("worker panicked: {panic}"),
            }),
        })
    }
}

/// Aggregate counters every environment maintains.
#[derive(Debug, Clone, Default)]
pub struct EnvStats {
    pub submitted: u64,
    pub completed: u64,
    pub failed_attempts: u64,
    pub resubmissions: u64,
    /// Latest virtual completion observed (the virtual makespan).
    pub virtual_makespan: f64,
    /// Total virtual core-seconds consumed.
    pub virtual_cpu_s: f64,
}

/// An execution environment (`LocalEnvironment`, `PBSEnvironment`,
/// `EGIEnvironment`, ...). Selecting one is the single-line change of
/// paper §2.2.
pub trait Environment: Send + Sync {
    fn name(&self) -> &str;
    fn submit(&self, job: Job) -> JobHandle;
    fn stats(&self) -> EnvStats;
}

/// Submit a batch and wait for everything, preserving order.
pub fn run_all(
    env: &dyn Environment,
    jobs: Vec<Job>,
) -> Vec<Result<(Context, JobReport)>> {
    let handles: Vec<JobHandle> = jobs.into_iter().map(|j| env.submit(j)).collect();
    handles.into_iter().map(JobHandle::wait).collect()
}
