//! Execution environments: where task workload is delegated (paper §2.2).
//!
//! The paper's claim is that switching a workflow from a laptop to a
//! cluster or to EGI is a one-line change. The [`Environment`] trait is
//! that line: every implementation accepts [`Job`]s and returns
//! [`JobHandle`]s, whatever the infrastructure behind it.
//!
//! ## Simulated infrastructure + real compute
//!
//! This reproduction has no gLite grid to submit to, so remote
//! environments are *discrete-event simulations* of their infrastructure
//! (submission latency, queueing, node speed, failures) wrapped around
//! *real* local execution of the task (PJRT-compiled ant model or any
//! other task). Each job therefore yields two timelines:
//!
//! * the **real** one — how long the computation actually took here;
//! * the **virtual** one — when the job would have started/finished on the
//!   simulated infrastructure. Throughput results in EXPERIMENTS.md are
//!   virtual-time numbers, which is exactly what the substitution rule in
//!   DESIGN.md §3 calls for.
//!
//! Dependencies between jobs enter the virtual timeline through
//! [`Job::virtual_release`]: a job may not start (in virtual time) before
//! its inputs existed. Drivers (generational GA, islands) set it to the
//! virtual end of the jobs they consumed.

pub mod cluster;
pub mod egi;
pub mod local;
pub mod ssh;

use std::sync::Arc;
use std::time::Duration;

use crate::core::Context;
use crate::dsl::task::Task;
use crate::error::{Error, Result};
use crate::exec::JobJoin;

/// A unit of delegated work.
pub struct Job {
    pub task: Arc<dyn Task>,
    pub context: Context,
    /// Earliest virtual time (s) this job may start on the simulated
    /// infrastructure — encodes dataflow dependencies in virtual time.
    pub virtual_release: f64,
}

impl Job {
    pub fn new(task: Arc<dyn Task>, context: Context) -> Self {
        Job {
            task,
            context,
            virtual_release: 0.0,
        }
    }

    pub fn released_at(mut self, t: f64) -> Self {
        self.virtual_release = t;
        self
    }
}

/// Where and when a job ran, in both timelines.
#[derive(Debug, Clone)]
pub struct JobReport {
    pub environment: String,
    pub node: String,
    /// 1 + number of resubmissions after simulated failures.
    pub attempts: u32,
    /// Virtual seconds spent in submission/brokering.
    pub submit_delay_s: f64,
    /// Virtual seconds spent queued before a node was free.
    pub queue_s: f64,
    /// Virtual seconds executing on the (possibly slower) remote node.
    pub exec_s: f64,
    /// Virtual timestamp at which the job started executing.
    pub virtual_start: f64,
    /// Virtual timestamp at which the job completed.
    pub virtual_end: f64,
    /// Real wall-clock the computation took locally.
    pub real_exec: Duration,
}

/// Completion behaviour behind a [`JobHandle`].
///
/// Most environments run one closure on a thread pool, but composite
/// environments (notably [`crate::broker::Broker`]) need handles that
/// re-dispatch failed attempts or race speculative copies before a result
/// is surfaced. Implementations must make `try_wait` non-blocking; once it
/// has returned `Some`, subsequent calls may return anything (callers drop
/// the handle after the first completion, matching pool-handle semantics).
pub trait JobWaiter: Send {
    /// Block until the job completes.
    fn wait(self: Box<Self>) -> Result<(Context, JobReport)>;
    /// Non-blocking poll; `None` while the job is still running.
    fn try_wait(&self) -> Option<Result<(Context, JobReport)>>;
}

enum HandleInner {
    Pool(JobJoin<(Result<Context>, JobReport)>),
    Custom(Box<dyn JobWaiter>),
}

/// Handle to a submitted job.
pub struct JobHandle {
    inner: HandleInner,
}

fn pool_result(
    r: std::result::Result<(Result<Context>, JobReport), String>,
) -> Result<(Context, JobReport)> {
    match r {
        Ok((Ok(ctx), report)) => Ok((ctx, report)),
        Ok((Err(e), _)) => Err(e),
        Err(panic) => Err(Error::EnvironmentError {
            environment: "<pool>".into(),
            message: format!("worker panicked: {panic}"),
        }),
    }
}

impl JobHandle {
    pub fn from_join(join: JobJoin<(Result<Context>, JobReport)>) -> Self {
        JobHandle {
            inner: HandleInner::Pool(join),
        }
    }

    /// Wrap a custom completion strategy (broker retry/speculation logic).
    pub fn from_waiter(waiter: Box<dyn JobWaiter>) -> Self {
        JobHandle {
            inner: HandleInner::Custom(waiter),
        }
    }

    /// An already-completed handle (used by fault injectors and tests).
    pub fn ready(result: Result<(Context, JobReport)>) -> Self {
        struct Ready(std::sync::Mutex<Option<Result<(Context, JobReport)>>>);
        impl JobWaiter for Ready {
            fn wait(self: Box<Self>) -> Result<(Context, JobReport)> {
                self.0.lock().unwrap().take().unwrap_or_else(|| {
                    Err(Error::EnvironmentError {
                        environment: "<ready>".into(),
                        message: "result already consumed".into(),
                    })
                })
            }
            fn try_wait(&self) -> Option<Result<(Context, JobReport)>> {
                self.0.lock().unwrap().take()
            }
        }
        JobHandle::from_waiter(Box::new(Ready(std::sync::Mutex::new(Some(result)))))
    }

    /// Block until the job completes.
    pub fn wait(self) -> Result<(Context, JobReport)> {
        match self.inner {
            HandleInner::Pool(join) => pool_result(join.join()),
            HandleInner::Custom(w) => w.wait(),
        }
    }

    /// Non-blocking poll.
    pub fn try_wait(&self) -> Option<Result<(Context, JobReport)>> {
        match &self.inner {
            HandleInner::Pool(join) => join.try_join().map(pool_result),
            HandleInner::Custom(w) => w.try_wait(),
        }
    }
}

/// Aggregate counters every environment maintains.
///
/// Invariant (checked by the accounting tests): once an environment is
/// drained, `submitted == completed + failed_jobs`, and
/// `failed_attempts == resubmissions + failed_jobs` — every failed attempt
/// was either retried or terminated the job.
#[derive(Debug, Clone, Default)]
pub struct EnvStats {
    pub submitted: u64,
    pub completed: u64,
    /// Individual attempts that failed (including ones later retried).
    pub failed_attempts: u64,
    /// Attempts re-queued after a failure.
    pub resubmissions: u64,
    /// Jobs that terminally failed (error surfaced to the caller).
    pub failed_jobs: u64,
    /// Attempts abandoned after a broker-enforced real-time bound expired
    /// (hung backend). Each is also counted in `failed_attempts`.
    pub timed_out_attempts: u64,
    /// Faults injected by a chaos decorator ([`crate::broker::fault`])
    /// wrapped around this environment — drops, hangs, stragglers and
    /// crash-window failures. Purely diagnostic: the injected drops and
    /// crashes are already folded into the failure counters above so the
    /// ledger invariants still reconcile.
    pub injected_faults: u64,
    /// Latest virtual completion observed (the virtual makespan).
    pub virtual_makespan: f64,
    /// Total virtual core-seconds consumed.
    pub virtual_cpu_s: f64,
}

impl EnvStats {
    /// Jobs submitted but not yet terminally resolved.
    pub fn in_flight(&self) -> u64 {
        self.submitted
            .saturating_sub(self.completed)
            .saturating_sub(self.failed_jobs)
    }
}

/// An execution environment (`LocalEnvironment`, `PBSEnvironment`,
/// `EGIEnvironment`, ...). Selecting one is the single-line change of
/// paper §2.2.
pub trait Environment: Send + Sync {
    fn name(&self) -> &str;
    fn submit(&self, job: Job) -> JobHandle;
    fn stats(&self) -> EnvStats;
}

/// Submit a batch and wait for everything, preserving order.
pub fn run_all(
    env: &dyn Environment,
    jobs: Vec<Job>,
) -> Vec<Result<(Context, JobReport)>> {
    let handles: Vec<JobHandle> = jobs.into_iter().map(|j| env.submit(j)).collect();
    handles.into_iter().map(JobHandle::wait).collect()
}
