//! # molers — an OpenMOLE-class workflow engine in Rust
//!
//! Reproduction of *"Model Exploration Using OpenMOLE — a workflow engine
//! for large scale distributed design of experiments and parameter
//! tuning"* (Reuillon, Leclaire, Passerat-Palmbach, 2015) as a
//! three-layer Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the workflow engine: typed dataflow, DSL,
//!   DAG scheduler, exploration methods, NSGA-II / island evolution, and
//!   simulated distributed environments (SSH, PBS/SGE/Slurm/OAR/Condor,
//!   EGI) behind one [`environment::Environment`] trait — multiplexed by
//!   the fault-tolerant [`broker::Broker`] (policy-driven dispatch,
//!   circuit breaking, speculative resubmission, journaled resume).
//! * **L2** — the NetLogo "Ants" model as a JAX computation, AOT-lowered
//!   to HLO text (`python/compile/model.py`).
//! * **L1** — the fused pheromone diffusion/evaporation Pallas kernel
//!   (`python/compile/kernels/diffusion.py`).
//!
//! The [`runtime`] module loads the AOT artifacts via PJRT; Python never
//! runs at workflow-execution time.
//!
//! See DESIGN.md for the full inventory and EXPERIMENTS.md for the
//! paper-vs-measured record.

pub mod bench;
pub mod broker;
pub mod care;
pub mod cli;
pub mod core;
pub mod dsl;
pub mod environment;
pub mod error;
pub mod evolution;
pub mod exec;
pub mod exploration;
pub mod gridscale;
pub mod metrics;
pub mod provenance;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod util;
pub mod workflow;
pub mod workload;

pub use error::{Error, Result};

/// Common imports for examples and downstream users.
pub mod prelude {
    pub use crate::broker::{
        Broker, DispatchPolicy, EwmaPolicy, FairShare, FaultPlan, FaultyEnv,
        FlakyEnv, Journal, LeastInFlight, RetryPolicy, RoundRobin, TenantEnv,
    };
    pub use crate::core::{
        val_f64, val_i64, val_str, val_u32, Context, Val, VarSpec, VarType,
    };
    pub use crate::dsl::{
        CaptureHook, CapsuleHandle, ClosureTask, CsvHook, DisplayHook, Hook,
        IdentityTask, Puzzle, PuzzleBuilder, RowWriter, Sink, TableFormat, Task,
        ToStringHook,
    };
    pub use crate::environment::{local::LocalEnvironment, Environment, Job};
    pub use crate::exploration::{
        replicate, ExplicitSampling, Factor, FullFactorial, LhsSampling,
        ProductSampling, SampleMatrix, Sampling, SeedSampling, SobolSampling,
        StatisticTask, Sweep, UniformSampling,
    };
    pub use crate::util::{stats::Descriptor, Rng};
    pub use crate::workflow::{
        DirectSampling, EnvSpec, Experiment, ExplorationMethod, IslandEvolution,
        MethodCtx, MethodOutcome, MoleExecution, Nsga2Evolution, Replication,
        SingleRun,
    };
    // NOTE: `crate::Result` is deliberately NOT re-exported: a glob
    // import of this prelude would otherwise shadow `std`'s two-generic
    // `Result` and break `fn main() -> Result<(), Box<dyn Error>>`
    // signatures in downstream code. Use `molers::Result` explicitly.
}
