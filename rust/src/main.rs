//! `molers` — launcher for the OpenMOLE-paper reproduction.
//!
//! Subcommands mirror the paper's A-to-Z example (§4):
//!   run        single model execution            (Listing 2)
//!   explore    distributed design of experiments (§2: large parameter sets)
//!   replicate  n-seed replication + medians      (Listing 3)
//!   calibrate  generational NSGA-II              (Listing 4)
//!   island     island NSGA-II on a remote env    (Listing 5)
//!   render     draw the ant world                (Figures 1–2)
//!   envs       show the available environments
//!
//! `--env local|ssh|pbs|slurm|sge|oar|condor|egi` is the paper's
//! one-line environment switch.

use std::sync::Arc;

use molers::broker::{journal, policy, Broker, Journal};
use molers::cli::Args;
use molers::dsl::hook::{RowWriter, TableFormat};
use molers::environment::cluster::BatchEnvironment;
use molers::environment::egi::EgiEnvironment;
use molers::environment::local::LocalEnvironment;
use molers::environment::ssh::SshEnvironment;
use molers::environment::Environment;
use molers::evolution::{
    Evaluator, GenerationalGA, IslandConfig, IslandSteadyGA, Nsga2Config,
    PooledEvaluator, ReplicatedEvaluator,
};
use molers::exec::ThreadPool;
use molers::metrics::throughput_per_hour;
use molers::prelude::*;
use molers::runtime::best_available_evaluator;
use molers::sim::{render, AntParams, AntSim};

fn environment(
    name: &str,
    nodes: usize,
    pool: Arc<ThreadPool>,
    seed: u64,
) -> Arc<dyn Environment> {
    match name {
        "local" => Arc::new(LocalEnvironment::with_pool(pool)),
        "ssh" => Arc::new(SshEnvironment::new("calc01", nodes, pool, seed)),
        "pbs" => Arc::new(BatchEnvironment::pbs(nodes, pool, seed)),
        "slurm" => Arc::new(BatchEnvironment::slurm(nodes, pool, seed)),
        "sge" => Arc::new(BatchEnvironment::sge(nodes, pool, seed)),
        "oar" => Arc::new(BatchEnvironment::oar(nodes, pool, seed)),
        "condor" => Arc::new(BatchEnvironment::condor(nodes, pool, seed)),
        "egi" => Arc::new(EgiEnvironment::new("biomed", nodes, pool, seed)),
        other => {
            eprintln!("unknown environment `{other}`; using local");
            Arc::new(LocalEnvironment::with_pool(pool))
        }
    }
}

/// Build the execution environment for a command: `--envs SPEC` (a
/// brokered fleet, with `--policy roundrobin|least|ewma`) wins over the
/// single-environment `--env NAME`. Returns the broker too (when one was
/// built) so commands can print its dispatch report.
fn environment_from_args(
    args: &Args,
    default_env: &str,
    nodes: usize,
    pool: Arc<ThreadPool>,
    seed: u64,
) -> std::result::Result<(Arc<dyn Environment>, Option<Arc<Broker>>), Box<dyn std::error::Error>>
{
    if let Some(spec) = args.get("envs") {
        let policy_name = args.get_or("policy", "ewma");
        let p = policy::by_name(policy_name).ok_or_else(|| {
            format!("unknown --policy `{policy_name}` (roundrobin|least|ewma)")
        })?;
        let mut builder = Broker::spec_builder(spec, pool, seed)?.policy(p);
        if args.flag("speculate") {
            builder = builder.speculation(molers::broker::SpeculationConfig::default());
        }
        let broker = Arc::new(builder.build()?);
        let env: Arc<dyn Environment> = Arc::clone(&broker) as Arc<dyn Environment>;
        Ok((env, Some(broker)))
    } else {
        Ok((
            environment(args.get_or("env", default_env), nodes, pool, seed),
            None,
        ))
    }
}

fn print_broker_report(b: &Broker) {
    let c = b.counters();
    println!(
        "broker[{}]: reroutes={} speculation launched={} wins={} cancelled={} \
         quarantine-trips={}",
        b.policy_name(),
        c.reroutes,
        c.speculative_launched,
        c.speculative_wins,
        c.speculative_cancelled,
        b.quarantine_trips()
    );
    for s in b.backend_snapshots() {
        println!(
            "  {:<32} completed={:<7} failed={:<5} ewma={:.1}s{}",
            s.name,
            s.completed,
            s.failed,
            s.ewma_duration_s,
            if s.quarantined { "  [quarantined]" } else { "" }
        );
    }
}

fn genome_bounds() -> (Val<f64>, Val<f64>, Vec<Val<f64>>) {
    (
        val_f64("gDiffusionRate"),
        val_f64("gEvaporationRate"),
        vec![
            val_f64("medNumberFood1"),
            val_f64("medNumberFood2"),
            val_f64("medNumberFood3"),
        ],
    )
}

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e}");
            std::process::exit(2);
        }
    };
    let result = match args.subcommand.as_deref() {
        Some("run") => cmd_run(&args),
        Some("explore") => cmd_explore(&args),
        Some("replicate") => cmd_replicate(&args),
        Some("calibrate") => cmd_calibrate(&args),
        Some("island") => cmd_island(&args),
        Some("render") => cmd_render(&args),
        Some("envs") => cmd_envs(),
        other => {
            if let Some(o) = other {
                eprintln!("unknown subcommand `{o}`\n");
            }
            eprintln!(
                "usage: molers <run|explore|replicate|calibrate|island|render|envs> [options]\n\
                 common options: --seed N --env local|ssh|pbs|slurm|sge|oar|condor|egi\n\
                 \x20          --envs local:8,pbs:32~0.2,egi:biomed:2000 (brokered fleet;\n\
                 \x20          `~p` injects failures) --policy ewma|least|roundrobin\n\
                 \x20          --speculate (clone stragglers past the p95, first finish wins)\n\
                 run:       --population 125 --diffusion 50 --evaporation 50\n\
                 explore:   --sampling lhs|sobol|uniform|factorial --n 200000 --chunk 256\n\
                 \x20          --lo 0 --hi 99 (--step 24.75 for factorial) --replications 1\n\
                 \x20          --out explore.csv --format csv|jsonl\n\
                 \x20          --journal sweep.jsonl (checkpoint) | --resume sweep.jsonl\n\
                 replicate: --replications 5\n\
                 calibrate: --mu 10 --lambda 10 --generations 100 --replications 5 \
                 --chunk 1\n\
                 \x20          --journal run.jsonl (checkpoint) | --resume run.jsonl\n\
                 island:    --islands 2000 --total-evals 200000 --sample 50 \
                 --evals-per-island 100 --nodes 2000\n\
                 \x20          --journal run.jsonl | --resume run.jsonl\n\
                 render:    --ticks 400 --out world.ppm"
            );
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

type CmdResult = std::result::Result<(), Box<dyn std::error::Error>>;

/// Listing 2: one model execution with explicit parameters.
fn cmd_run(args: &Args) -> CmdResult {
    let seed = args.u64("seed", 42)?;
    let population = args.f64("population", 125.0)?;
    let diffusion = args.f64("diffusion", 50.0)?;
    let evaporation = args.f64("evaporation", 50.0)?;
    let (evaluator, kind) = best_available_evaluator(1);
    println!("evaluator: {kind}");
    let t0 = std::time::Instant::now();
    let fit = evaluator.evaluate(&[population, diffusion, evaporation], seed as u32)?;
    println!(
        "final-ticks-food1={} final-ticks-food2={} final-ticks-food3={}  ({:?})",
        fit[0],
        fit[1],
        fit[2],
        t0.elapsed()
    );
    Ok(())
}

/// §Exploration: plain design of experiments at calibration scale — a
/// columnar sample wave fanned through the (brokered) environment in
/// `--chunk`-sized `evaluate_rows` jobs, `sample_block` journal
/// checkpoints, and a `--resume` that skips already-evaluated rows while
/// reproducing a byte-identical result file.
fn cmd_explore(args: &Args) -> CmdResult {
    let seed = args.u64("seed", 42)?;
    let n = args.usize("n", 1000)?;
    let chunk = args.usize("chunk", 256)?;
    let replications = args.usize("replications", 1)?;
    let nodes = args.usize("nodes", 8)?;
    let lo = args.f64("lo", 0.0)?;
    let hi = args.f64("hi", 99.0)?;
    let step = args.f64("step", 24.75)?;
    let out_path = args.get_or("out", "explore.csv").to_string();
    let format = match args.get("format") {
        Some("csv") => TableFormat::Csv,
        Some("jsonl") => TableFormat::Jsonl,
        Some(other) => {
            return Err(format!("unknown --format `{other}` (csv|jsonl)").into())
        }
        None if out_path.ends_with(".jsonl") => TableFormat::Jsonl,
        None => TableFormat::Csv,
    };
    let pool = Arc::new(ThreadPool::default_size());
    let (env, broker) = environment_from_args(args, "local", nodes, pool, seed)?;

    let (d, e, _) = genome_bounds();
    let sampling_name = args.get_or("sampling", "lhs").to_string();
    let sampling: Arc<dyn Sampling> = match sampling_name.as_str() {
        "lhs" => Arc::new(LhsSampling::new(&[(&d, lo, hi), (&e, lo, hi)], n)),
        "sobol" => {
            // validated here so an oversized design is a clean CLI error,
            // not the SobolSampling constructor's panic
            if n as u64 >= 1u64 << 32 {
                return Err(format!(
                    "--n {n} exceeds the Sobol sequence length (2^32 points)"
                )
                .into());
            }
            Arc::new(SobolSampling::new(&[(&d, lo, hi), (&e, lo, hi)], n))
        }
        "uniform" => {
            Arc::new(UniformSampling::multi(&[(&d, lo, hi), (&e, lo, hi)], n))
        }
        "factorial" => {
            // validated here so a bad value is a clean CLI error, not the
            // Factor constructor's panic
            if !(step.is_finite() && step > 0.0) {
                return Err(format!(
                    "--step expects a positive finite number, got `{step}`"
                )
                .into());
            }
            let levels = (hi - lo) / step;
            if !levels.is_finite() || levels >= 1e6 {
                return Err(format!(
                    "--step {step} over [{lo}, {hi}] yields ~{levels:.0} levels \
                     per factor — refusing a grid this size"
                )
                .into());
            }
            Arc::new(FullFactorial::new(vec![
                Factor::new(&d, lo, hi, step),
                Factor::new(&e, lo, hi, step),
            ]))
        }
        other => {
            return Err(format!(
                "unknown --sampling `{other}` (lhs|sobol|uniform|factorial)"
            )
            .into())
        }
    };
    if sampling_name != "factorial" && !(lo.is_finite() && hi.is_finite() && lo < hi)
    {
        return Err(format!(
            "--lo must be below --hi (both finite) for --sampling \
             {sampling_name} (got lo={lo}, hi={hi})"
        )
        .into());
    }

    let (base_eval, kind) = best_available_evaluator(2);
    println!(
        "evaluator: {kind}, environment: {}, sampling: {} ({} rows, chunk {chunk})",
        env.name(),
        sampling.name(),
        sampling.size_hint().unwrap_or(0),
    );
    let evaluator: Arc<dyn Evaluator> = if replications > 1 {
        Arc::new(ReplicatedEvaluator::new(base_eval, replications))
    } else {
        base_eval
    };

    // --resume restores sample_block checkpoints; the design regenerates
    // from the sampling configuration + seed, so a journal written under
    // ANY different design knob (sampling kind, seed, n, bounds, step,
    // replications) describes a different design — reject it up front,
    // before the output file is touched
    let objective_names = ["food1", "food2", "food3"];
    let expected_rows = sampling.size_hint().unwrap_or(0);
    let mut resume_blocks: Option<Vec<journal::SampleBlock>> = None;
    let journal_arc = if let Some(path) = args.get("resume") {
        let records = Journal::load(path)?;
        if let Some(start) = records
            .iter()
            .find(|r| r.get("kind").and_then(|k| k.as_str()) == Some("run_start"))
        {
            if let Some(s) = start.get("sampling").and_then(|v| v.as_str()) {
                if s != sampling.name() {
                    return Err(format!(
                        "--resume config mismatch: journal `{path}` was written \
                         with --sampling {s}, this run samples {}",
                        sampling.name()
                    )
                    .into());
                }
            }
            // the 64-bit seed is compared exactly (journaled as a string;
            // an f64 comparison is lossy above 2^53), with a numeric
            // fallback for journals predating seed_exact
            let seed_matches = match start.get("seed_exact").and_then(|v| v.as_str())
            {
                Some(exact) => exact == seed.to_string(),
                None => start
                    .get("seed")
                    .and_then(|v| v.as_f64())
                    .is_none_or(|was| was as u64 == seed),
            };
            if !seed_matches {
                return Err(format!(
                    "--resume config mismatch: journal `{path}` was written \
                     under a different --seed than {seed} — the designs \
                     differ, refusing to reuse its blocks"
                )
                .into());
            }
            // numeric design knobs recorded at journal creation; a knob
            // absent from an old journal is skipped, a present one must
            // match exactly
            for (key, now) in [
                ("n", expected_rows as f64),
                ("lo", lo),
                ("hi", hi),
                ("step", step),
                ("replications", replications as f64),
            ] {
                if let Some(was) = start.get(key).and_then(|v| v.as_f64()) {
                    if was != now {
                        return Err(format!(
                            "--resume config mismatch: journal `{path}` was \
                             written with {key}={was}, this run has {key}={now} \
                             — the designs differ, refusing to reuse its blocks"
                        )
                        .into());
                    }
                }
            }
        }
        let blocks = journal::sample_blocks(&records);
        // blocks must fit the design this run will generate — checked
        // before the output file is recreated, so a refused resume never
        // destroys previous partial results
        for b in &blocks {
            if b.first_row + b.objectives.len() > expected_rows
                || b.objectives.iter().any(|r| r.len() != objective_names.len())
            {
                return Err(format!(
                    "--resume journal `{path}` holds a block (rows {}..{}) that \
                     does not fit this {expected_rows}-row design — refusing to \
                     overwrite `{out_path}`",
                    b.first_row,
                    b.first_row + b.objectives.len()
                )
                .into());
            }
        }
        println!("resuming sweep: {} checkpointed blocks", blocks.len());
        resume_blocks = Some(blocks);
        Some(Arc::new(Journal::append_to(path)?))
    } else if let Some(path) = args.get("journal") {
        Some(Arc::new(Journal::create(path)?))
    } else {
        None
    };

    let mut columns: Vec<&str> = vec![d.name(), e.name()];
    columns.extend(objective_names);
    let writer = Arc::new(RowWriter::create(&out_path, format, &columns)?);
    let mut sweep = Sweep::new(sampling, evaluator, &objective_names)
        .chunk(chunk)
        .writer(writer)
        .meta("lo", molers::util::json::Json::Num(lo))
        .meta("hi", molers::util::json::Json::Num(hi))
        .meta("replications", molers::util::json::Json::Num(replications as f64));
    if sampling_name == "factorial" {
        sweep = sweep.meta("step", molers::util::json::Json::Num(step));
    }
    if let Some(j) = journal_arc {
        sweep = sweep.journal(j);
    }
    let t0 = std::time::Instant::now();
    let result = sweep.run_resumable(env.as_ref(), seed, resume_blocks.as_deref())?;
    let stats = env.stats();
    println!(
        "\nrows={} evaluated={} resumed={} wall={:?}\nvirtual makespan = {:.0} s \
         -> {:.0} evaluations/virtual-hour",
        result.rows(),
        result.evaluated,
        result.resumed,
        t0.elapsed(),
        result.virtual_makespan,
        throughput_per_hour(result.evaluated as u64, result.virtual_makespan),
    );
    println!(
        "env: submitted={} completed={} resubmissions={} failed-jobs={}",
        stats.submitted, stats.completed, stats.resubmissions, stats.failed_jobs
    );
    if let Some(b) = &broker {
        print_broker_report(b);
    }
    println!("results: {out_path}");
    Ok(())
}

/// Listing 3: replication + median through the workflow engine.
fn cmd_replicate(args: &Args) -> CmdResult {
    let seed = args.u64("seed", 42)?;
    let replications = args.usize("replications", 5)?;
    let (evaluator, kind) = best_available_evaluator(1);
    println!("evaluator: {kind}");

    let seed_val = val_u32("seed");
    let food = [val_f64("food1"), val_f64("food2"), val_f64("food3")];
    let med = [
        val_f64("medNumberFood1"),
        val_f64("medNumberFood2"),
        val_f64("medNumberFood3"),
    ];
    let diffusion = args.f64("diffusion", 50.0)?;
    let evaporation = args.f64("evaporation", 50.0)?;
    let population = args.f64("population", 125.0)?;

    let model = {
        let (seed_c, food_c) = (seed_val.clone(), food.clone());
        let ev = Arc::clone(&evaluator);
        ClosureTask::new("ants", move |ctx: &Context| {
            let s = ctx.get(&seed_c)?;
            let fit = ev.evaluate(&[population, diffusion, evaporation], s)?;
            let mut out = Context::new();
            for (f, v) in food_c.iter().zip(fit) {
                out.set(f, v);
            }
            Ok(out)
        })
        .input(&seed_val)
        .output(&food[0])
        .output(&food[1])
        .output(&food[2])
    };
    let mut stat = StatisticTask::new();
    for (f, m) in food.iter().zip(&med) {
        stat = stat.statistic(f, m, Descriptor::Median);
    }

    let mut puzzle = Puzzle::new();
    let (_, model_c, stat_c) =
        replicate(&mut puzzle, Arc::new(model), &seed_val, replications, Arc::new(stat));
    puzzle.hook(model_c, Arc::new(ToStringHook::new(&["food1", "food2", "food3"])));
    puzzle.hook(
        stat_c,
        Arc::new(ToStringHook::new(&[
            "medNumberFood1",
            "medNumberFood2",
            "medNumberFood3",
        ])),
    );
    let env: Arc<dyn Environment> = Arc::new(LocalEnvironment::new(4));
    let result = MoleExecution::new(puzzle, env, seed).start()?;
    println!("jobs={} wall={:?}", result.report.jobs, result.report.wall);
    Ok(())
}

/// Listing 4: generational NSGA-II with replication-median fitness.
fn cmd_calibrate(args: &Args) -> CmdResult {
    let seed = args.u64("seed", 42)?;
    let mu = args.usize("mu", 10)?;
    let lambda = args.usize("lambda", 10)?;
    let generations = args.usize("generations", 100)? as u32;
    let replications = args.usize("replications", 5)?;
    let nodes = args.usize("nodes", 8)?;
    // --chunk N packs N genomes per evaluation job, fanned out through the
    // pooled batch path (§Perf): worthwhile on local/ssh environments
    let chunk = args.usize("chunk", 1)?;
    let pool = Arc::new(ThreadPool::default_size());
    let (env, broker) = environment_from_args(args, "local", nodes, pool, seed)?;

    // --resume continues an interrupted journal; --journal starts one
    let mut resume = None;
    let journal_arc = if let Some(path) = args.get("resume") {
        let records = Journal::load(path)?;
        // the original run_start record carries the configuration; a
        // resumed run with a different --mu/--lambda would silently
        // corrupt the trajectory, so reject the mismatch up front
        if let Some(start) = records
            .iter()
            .find(|r| r.get("kind").and_then(|k| k.as_str()) == Some("run_start"))
        {
            for (key, got) in [("mu", mu), ("lambda", lambda)] {
                if let Some(want) =
                    start.get(key).and_then(|v| v.as_f64()).map(|v| v as usize)
                {
                    if want != got {
                        return Err(format!(
                            "--resume config mismatch: journal `{path}` was \
                             written with --{key} {want}, this run has --{key} \
                             {got}"
                        )
                        .into());
                    }
                }
            }
        }
        resume = journal::resume_state(&records);
        let Some(state) = &resume else {
            return Err(
                format!("journal `{path}` holds no generation checkpoint").into()
            );
        };
        println!(
            "resuming from generation {} ({} evaluations done)",
            state.generation, state.evaluations
        );
        Some(Arc::new(Journal::append_to(path)?))
    } else if let Some(path) = args.get("journal") {
        Some(Arc::new(Journal::create(path)?))
    } else {
        None
    };

    let (base, kind) = best_available_evaluator(2);
    println!("evaluator: {kind}, environment: {}", env.name());
    let evaluator: Arc<dyn Evaluator> = if chunk > 1 {
        // chunked jobs carry whole batches. The evaluator gets its OWN
        // worker pool: environment workers block while a chunk fans out,
        // so sharing one pool could deadlock with every worker waiting
        Arc::new(PooledEvaluator::machine_sized(Arc::new(
            ReplicatedEvaluator::new(base, replications),
        )))
    } else {
        Arc::new(ReplicatedEvaluator::new(base, replications))
    };

    let (d, e, objectives) = genome_bounds();
    let obj_refs: Vec<&Val<f64>> = objectives.iter().collect();
    let config = Nsga2Config::new(
        mu,
        &[(&d, 0.0, 99.0), (&e, 0.0, 99.0)],
        &obj_refs,
        0.01,
    )?;
    // the coordinator's own stages (variation, crowding, dominance) fan
    // out over a dedicated pool — never the environment's (whose workers
    // block while the coordinator joins)
    let mut ga = GenerationalGA::new(config, evaluator, lambda)
        .eval_chunk(chunk)
        .coordinator_pool(Arc::new(ThreadPool::default_size()))
        .on_generation(|g, pop| {
            let best: f64 = (0..pop.len())
                .map(|i| pop.objectives_row(i).iter().sum::<f64>())
                .fold(f64::INFINITY, f64::min);
            if g % 10 == 0 {
                println!("Generation {g}: best objective sum {best:.1}");
            }
        });
    if let Some(j) = journal_arc {
        ga = ga.journal(j);
    }
    let result = ga.run_resumable(env.as_ref(), generations, seed, resume)?;
    if let Some(b) = &broker {
        print_broker_report(b);
    }
    println!(
        "\nevaluations={} virtual-makespan={:.0}s pareto-front:",
        result.evaluations, result.virtual_makespan
    );
    for ind in &result.pareto_front {
        println!(
            "  diffusion={:6.2} evaporation={:6.2} -> [{:6.1} {:6.1} {:6.1}]",
            ind.genome[0],
            ind.genome[1],
            ind.objectives[0],
            ind.objectives[1],
            ind.objectives[2]
        );
    }
    Ok(())
}

/// Listing 5 + §4.6: island NSGA-II on the (simulated) EGI.
fn cmd_island(args: &Args) -> CmdResult {
    let seed = args.u64("seed", 42)?;
    let mu = args.usize("mu", 200)?;
    let islands = args.usize("islands", 64)?;
    let total = args.u64("total-evals", 6400)?;
    let sample = args.usize("sample", 50)?;
    let per_island = args.u64("evals-per-island", 100)?;
    let nodes = args.usize("nodes", islands)?;
    let replications = args.usize("replications", 1)?;
    let pool = Arc::new(ThreadPool::default_size());
    let (env, broker) = environment_from_args(args, "egi", nodes, pool, seed)?;

    let (base, kind) = best_available_evaluator(2);
    println!("evaluator: {kind}, environment: {}", env.name());
    let evaluator: Arc<dyn Evaluator> = if replications > 1 {
        Arc::new(ReplicatedEvaluator::new(base, replications))
    } else {
        base
    };

    let (d, e, objectives) = genome_bounds();
    let obj_refs: Vec<&Val<f64>> = objectives.iter().collect();
    let config = Nsga2Config::new(
        mu,
        &[(&d, 0.0, 99.0), (&e, 0.0, 99.0)],
        &obj_refs,
        0.01,
    )?;
    let mut ga = IslandSteadyGA::new(
        config,
        IslandConfig {
            concurrent_islands: islands,
            total_evaluations: total,
            island_sample: sample,
            evals_per_island: per_island,
        },
        evaluator,
    );
    if let Some(path) = args.get("resume") {
        let records = Journal::load(path)?;
        let (pop, evals) = journal::island_resume(&records).ok_or_else(|| {
            format!("journal `{path}` holds no island archive snapshot")
        })?;
        println!(
            "resuming island archive: {} individuals, {evals} evaluations done",
            pop.len()
        );
        ga = ga
            .resume_from(pop, evals)
            .journal(Arc::new(Journal::append_to(path)?));
    } else if let Some(path) = args.get("journal") {
        ga = ga.journal(Arc::new(Journal::create(path)?));
    }
    let t0 = std::time::Instant::now();
    let result = ga.run(
        env.as_ref(),
        seed,
        Some(Arc::new(|done, evals| {
            if done % 16 == 0 {
                println!("Generation {done} islands merged, {evals} evaluations");
            }
        })),
    )?;
    let stats = env.stats();
    println!(
        "\nislands={} evaluations={} wall={:?}\nvirtual makespan = {:.0} s \
         -> {:.0} evaluations/virtual-hour (paper headline: 200,000/h on 2,000 islands)",
        result.generations,
        result.evaluations,
        t0.elapsed(),
        result.virtual_makespan,
        throughput_per_hour(result.evaluations, result.virtual_makespan),
    );
    println!(
        "env: submitted={} completed={} resubmissions={} failed-jobs={}",
        stats.submitted, stats.completed, stats.resubmissions, stats.failed_jobs
    );
    if let Some(b) = &broker {
        print_broker_report(b);
    }
    println!("pareto front ({} points):", result.pareto_front.len());
    for ind in result.pareto_front.iter().take(10) {
        println!(
            "  diffusion={:6.2} evaporation={:6.2} -> [{:6.1} {:6.1} {:6.1}]",
            ind.genome[0],
            ind.genome[1],
            ind.objectives[0],
            ind.objectives[1],
            ind.objectives[2]
        );
    }
    Ok(())
}

/// Figures 1–2: render the ant world after `--ticks` steps.
fn cmd_render(args: &Args) -> CmdResult {
    let seed = args.u64("seed", 42)?;
    let ticks = args.usize("ticks", 400)?;
    let params = AntParams {
        population: args.f64("population", 125.0)?,
        diffusion_rate: args.f64("diffusion", 50.0)?,
        evaporation_rate: args.f64("evaporation", 10.0)?,
    };
    let mut sim = AntSim::new(params, seed);
    for _ in 0..ticks {
        sim.step();
    }
    if let Some(path) = args.get("out") {
        std::fs::write(path, render::ppm(&sim, 4))?;
        println!("wrote {path}");
    } else {
        println!("{}", render::ascii(&sim));
        println!(
            "tick {} remaining food per source: {:?}",
            sim.tick,
            sim.remaining()
        );
    }
    Ok(())
}

fn cmd_envs() -> CmdResult {
    println!(
        "environments (switch with --env NAME — the paper's one-line change):\n\
         \x20 local   threads on this machine (test small...)\n\
         \x20 ssh     remote multi-core server over SSH          [simulated]\n\
         \x20 pbs     PBS/Torque cluster via qsub/qstat          [simulated]\n\
         \x20 slurm   Slurm cluster via sbatch/squeue            [simulated]\n\
         \x20 sge     Sun Grid Engine via qsub/qstat             [simulated]\n\
         \x20 oar     OAR cluster via oarsub/oarstat             [simulated]\n\
         \x20 condor  HTCondor pool via condor_submit/condor_q   [simulated]\n\
         \x20 egi     EGI grid via gLite WMS (...scale for free) [simulated]"
    );
    Ok(())
}
