//! `molers` — launcher for the OpenMOLE-paper reproduction.
//!
//! Subcommands mirror the paper's A-to-Z example (§4):
//!   run        single model execution            (Listing 2)
//!   explore    distributed design of experiments (§2: large parameter sets)
//!   replicate  n-seed replication + medians      (Listing 3)
//!   calibrate  generational NSGA-II              (Listing 4)
//!   island     island NSGA-II on a remote env    (Listing 5)
//!   render     draw the ant world                (Figures 1–2)
//!   envs       show the available environments
//!   serve      multi-tenant experiment daemon    (JSONL over TCP)
//!   client     thin client for a running daemon
//!   reexec     re-run a manifest, assert byte-identical output
//!   workload   synthetic trace generator + replay harness
//!   version    crate version + git build hash
//!
//! Every run subcommand parses into one MoleDSL v2
//! `molers::workflow::Experiment` (see `cli::front`) — construction,
//! environment selection, journaling and resume validation are uniform;
//! this file only dispatches and prints.
//!
//! `--env local|ssh|pbs|slurm|sge|oar|condor|egi` is the paper's
//! one-line environment switch; an unknown name is a hard error.

use molers::broker::Broker;
use molers::cli::{front, Args};
use molers::evolution::Individual;
use molers::metrics::throughput_per_hour;
use molers::provenance;
use molers::sim::{render, AntParams, AntSim};
use molers::workflow::ExperimentReport;

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e}");
            std::process::exit(2);
        }
    };
    if args.flag("version") {
        println!("{}", provenance::build_info());
        return;
    }
    let result = match args.subcommand.as_deref() {
        Some("run") => cmd_run(&args),
        Some("explore") => cmd_explore(&args),
        Some("replicate") => cmd_replicate(&args),
        Some("calibrate") => cmd_calibrate(&args),
        Some("island") => cmd_island(&args),
        Some("render") => cmd_render(&args),
        Some("envs") => cmd_envs(),
        Some("serve") => cmd_serve(&args),
        Some("client") => cmd_client(&args),
        Some("reexec") => cmd_reexec(&args),
        Some("workload") => cmd_workload(&args),
        Some("version") => {
            println!("{}", provenance::build_info());
            Ok(())
        }
        other => {
            if let Some(o) = other {
                eprintln!("unknown subcommand `{o}`\n");
            }
            eprintln!(
                "usage: molers <run|explore|replicate|calibrate|island|render|envs|serve|\
                 client|reexec|workload|version> [options]\n\
                 common options: --seed N --env local|ssh|pbs|slurm|sge|oar|condor|egi\n\
                 \x20          --envs local:8,pbs:32~0.2,egi:biomed:2000 (brokered fleet;\n\
                 \x20          `~p` drops submissions; `~drop=0.2;hang=0.01;delay=0.1:30;\n\
                 \x20          crash=10+5` composes a seeded fault plan) \n\
                 \x20          --policy ewma|least|roundrobin\n\
                 \x20          --speculate (clone stragglers past the p95, first finish wins)\n\
                 \x20          --timeout S (real-time job deadline) --max-retries N\n\
                 \x20          --backoff S (virtual exponential backoff base)\n\
                 run:       --population 125 --diffusion 50 --evaporation 50\n\
                 explore:   --sampling lhs|sobol|uniform|factorial --n 200000 --chunk 256\n\
                 \x20          --lo 0 --hi 99 (--step 24.75 for factorial) --replications 1\n\
                 \x20          --out explore.csv --format csv|jsonl\n\
                 \x20          --journal sweep.jsonl (checkpoint) | --resume sweep.jsonl\n\
                 \x20          --durability always|batch[:N]|os (when checkpoints hit disk)\n\
                 \x20          --degraded-ok (NaN-fill rows whose retry budget is spent)\n\
                 \x20          --retry-degraded (re-evaluate degraded rows on --resume)\n\
                 \x20          --mem-budget BYTES[k|m|g] (out-of-core: stream the design in\n\
                 \x20          bounded windows, spill completed rows to disk; sobol/factorial)\n\
                 \x20          --spill-dir DIR (where spilled row chunks page; default tmp)\n\
                 replicate: --replications 5\n\
                 calibrate: --mu 10 --lambda 10 --generations 100 --replications 5 \
                 --chunk 1\n\
                 \x20          --journal run.jsonl (checkpoint) | --resume run.jsonl\n\
                 island:    --islands 2000 --total-evals 200000 --sample 50 \
                 --evals-per-island 100 --nodes 2000\n\
                 \x20          --journal run.jsonl | --resume run.jsonl\n\
                 render:    --ticks 400 --out world.ppm\n\
                 serve:     --addr 127.0.0.1:4268 --state-dir molers-serve --envs local:8\n\
                 \x20          --max-running 4 --max-queued 64 --slots 0 (0 = fleet capacity)\n\
                 \x20          --durability always|batch[:N]|os (default always: fsync\n\
                 \x20          before acknowledging) --max-conns 256 --conn-timeout 30\n\
                 client:    submit <method> [method options] --tenant NAME --weight W\n\
                 \x20          [--dedup-key K (idempotent retry)] |\n\
                 \x20          list | status --id N | watch --id N [--after-seq S] |\n\
                 \x20          cancel --id N | result --id N | ping [--retries N] |\n\
                 \x20          shutdown  (--addr HOST:PORT; exit 3 = cannot connect)\n\
                 reexec:    <run.manifest.json> [--out PATH | --keep] [--ignore-compat]\n\
                 \x20          (re-runs from the manifest alone and asserts a\n\
                 \x20          byte-identical, digest-verified result file)\n\
                 workload:  run [--trace SPEC] [--envs local:8 --policy ewma --fault PLAN\n\
                 \x20          --lanes 4] | replay --addr HOST:PORT [--poll-ms 100]\n\
                 \x20          common: --seed N --time-scale R (0 = full speed)\n\
                 \x20          --emit trace.jsonl --out records.jsonl --allow-failures\n\
                 \x20          SPEC: jobs=40;arrival=poisson:2|uniform:S|burst:N:GAP;\n\
                 \x20          tenants=alice:3,bob:1;mix=explore:0.8,calibrate:0.2;\n\
                 \x20          rows=16..256;chunk=16 (see molers::workload docs)"
            );
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        // connect-level client failures get their own exit code so
        // scripts can tell "daemon unreachable" from "request rejected"
        let connect = e
            .downcast_ref::<molers::error::Error>()
            .is_some_and(|e| matches!(
                e,
                molers::error::Error::EnvironmentError { environment, .. }
                    if environment == "client"
            ));
        std::process::exit(if connect { 3 } else { 1 });
    }
}

type CmdResult = std::result::Result<(), Box<dyn std::error::Error>>;

fn print_broker_report(b: &Broker) {
    let c = b.counters();
    println!(
        "broker[{}]: reroutes={} speculation launched={} wins={} cancelled={} \
         quarantine-trips={}",
        b.policy_name(),
        c.reroutes,
        c.speculative_launched,
        c.speculative_wins,
        c.speculative_cancelled,
        b.quarantine_trips()
    );
    for s in b.backend_snapshots() {
        println!(
            "  {:<32} completed={:<7} failed={:<5} ewma={:.1}s{}",
            s.name,
            s.completed,
            s.failed,
            s.ewma_duration_s,
            if s.quarantined { "  [quarantined]" } else { "" }
        );
    }
}

fn print_env_stats(report: &ExperimentReport) {
    let s = &report.env_stats;
    println!(
        "env: submitted={} completed={} resubmissions={} failed-jobs={} \
         timeouts={} injected-faults={}",
        s.submitted,
        s.completed,
        s.resubmissions,
        s.failed_jobs,
        s.timed_out_attempts,
        s.injected_faults
    );
    if let Some(b) = &report.broker {
        print_broker_report(b);
    }
}

fn print_pareto_front(front: &[Individual], limit: usize) {
    for ind in front.iter().take(limit) {
        println!(
            "  diffusion={:6.2} evaporation={:6.2} -> [{:6.1} {:6.1} {:6.1}]",
            ind.genome[0],
            ind.genome[1],
            ind.objectives[0],
            ind.objectives[1],
            ind.objectives[2]
        );
    }
}

/// Listing 2: one model execution with explicit parameters.
fn cmd_run(args: &Args) -> CmdResult {
    let report = front::run(args)?.run()?;
    let out = report
        .outcome
        .outputs
        .first()
        .ok_or("run produced no outputs")?;
    println!(
        "final-ticks-food1={} final-ticks-food2={} final-ticks-food3={}  ({:?})",
        out.get(&molers::core::val_f64("food1"))?,
        out.get(&molers::core::val_f64("food2"))?,
        out.get(&molers::core::val_f64("food3"))?,
        report.wall
    );
    Ok(())
}

/// §Exploration: plain design of experiments at calibration scale — a
/// columnar sample wave fanned through the (brokered) environment, with
/// `sample_block` checkpoints and byte-identical resumable results.
fn cmd_explore(args: &Args) -> CmdResult {
    let exp = front::explore(args)?;
    let report = exp.run()?;
    let o = &report.outcome;
    println!(
        "\noutcome={} rows={} evaluated={} resumed={} wall={:?}\n\
         virtual makespan = {:.0} s -> {:.0} evaluations/virtual-hour",
        o.outcome(),
        o.rows,
        o.evaluated,
        o.resumed,
        report.wall,
        o.virtual_makespan,
        throughput_per_hour(o.evaluated as u64, o.virtual_makespan),
    );
    if o.peak_resident_bytes > 0 {
        println!(
            "peak resident rows = {:.1} MiB",
            o.peak_resident_bytes as f64 / (1024.0 * 1024.0)
        );
    }
    if !o.column_stats.is_empty() {
        println!("columns (streamed; NaN excluded):");
        for c in &o.column_stats {
            println!(
                "  {:<20} n={:<8} mean={:<12.4} min={:<12.4} max={:<12.4} p50~{:.4}",
                c.name, c.count, c.mean, c.min, c.max, c.median
            );
        }
    }
    if !o.degraded.is_empty() {
        println!(
            "degraded: {} rows exhausted their retry budget (NaN objectives; \
             journaled as degraded_rows — rerun with --resume --retry-degraded \
             to re-evaluate them)",
            o.degraded.len()
        );
    }
    print_env_stats(&report);
    if let Some(path) = &o.result_path {
        println!("results: {path}");
        if let Some(m) = provenance::emit_for_cli("explore", args, &exp, path)? {
            println!("manifest: {m}  (verify with `molers reexec {m}`)");
        }
    }
    Ok(())
}

/// Listing 3: replication + median through the workflow engine.
fn cmd_replicate(args: &Args) -> CmdResult {
    let report = front::replicate(args)?.run()?;
    println!(
        "jobs={} wall={:?}",
        report.outcome.jobs, report.wall
    );
    Ok(())
}

/// Listing 4: generational NSGA-II with replication-median fitness.
fn cmd_calibrate(args: &Args) -> CmdResult {
    let exp = front::calibrate(args)?;
    let report = exp.run()?;
    let o = &report.outcome;
    print_env_stats(&report);
    println!(
        "\nevaluations={} virtual-makespan={:.0}s pareto-front:",
        o.evaluations, o.virtual_makespan
    );
    print_pareto_front(&o.pareto_front, usize::MAX);
    emit_front_manifest("calibrate", args, &exp, &o.pareto_front)?;
    Ok(())
}

/// Listing 5 + §4.6: island NSGA-II on the (simulated) EGI.
fn cmd_island(args: &Args) -> CmdResult {
    let exp = front::island(args)?;
    let report = exp.run()?;
    let o = &report.outcome;
    println!(
        "\nislands={} evaluations={} wall={:?}\nvirtual makespan = {:.0} s \
         -> {:.0} evaluations/virtual-hour (paper headline: 200,000/h on 2,000 islands)",
        o.generations,
        o.evaluations,
        report.wall,
        o.virtual_makespan,
        throughput_per_hour(o.evaluations, o.virtual_makespan),
    );
    print_env_stats(&report);
    println!("pareto front ({} points):", o.pareto_front.len());
    print_pareto_front(&o.pareto_front, 10);
    emit_front_manifest("island", args, &exp, &o.pareto_front)?;
    Ok(())
}

/// Evolution methods return their pareto front in memory; `--out` makes
/// it durable (the deterministic front-file format shared with serve and
/// reexec) and provenance-complete: the manifest digests that file.
fn emit_front_manifest(
    run: &str,
    args: &Args,
    exp: &molers::workflow::Experiment,
    front: &[Individual],
) -> CmdResult {
    let Some(path) = args.get("out") else {
        return Ok(());
    };
    provenance::write_front_file(std::path::Path::new(path), front)?;
    println!("front: {path}");
    if let Some(m) = provenance::emit_for_cli(run, args, exp, path)? {
        println!("manifest: {m}  (verify with `molers reexec {m}`)");
    }
    Ok(())
}

/// Figures 1–2: render the ant world after `--ticks` steps.
fn cmd_render(args: &Args) -> CmdResult {
    let seed = args.u64("seed", 42)?;
    let ticks = args.usize("ticks", 400)?;
    let params = AntParams {
        population: args.f64("population", 125.0)?,
        diffusion_rate: args.f64("diffusion", 50.0)?,
        evaporation_rate: args.f64("evaporation", 10.0)?,
    };
    let mut sim = AntSim::new(params, seed);
    for _ in 0..ticks {
        sim.step();
    }
    if let Some(path) = args.get("out") {
        std::fs::write(path, render::ppm(&sim, 4))?;
        println!("wrote {path}");
    } else {
        println!("{}", render::ascii(&sim));
        println!(
            "tick {} remaining food per source: {:?}",
            sim.tick,
            sim.remaining()
        );
    }
    Ok(())
}

/// `molers serve`: the multi-tenant experiment daemon (see
/// `molers::serve` for the protocol and state-directory layout).
fn cmd_serve(args: &Args) -> CmdResult {
    let cfg = molers::serve::ServeConfig::from_args(args)?;
    molers::serve::serve(cfg)?;
    Ok(())
}

/// `molers client`: one request line to a running daemon.
fn cmd_client(args: &Args) -> CmdResult {
    molers::serve::client::cmd_client(args)?;
    Ok(())
}

/// `molers reexec <manifest>`: reproduce a recorded run and assert a
/// byte-identical result (see `molers::provenance`).
fn cmd_reexec(args: &Args) -> CmdResult {
    let manifest = args.positional().first().ok_or(
        "reexec needs a manifest path: molers reexec <run.manifest.json>",
    )?;
    let r = provenance::reexec(manifest, args)?;
    println!(
        "reproduced {}: sha256:{} ({} bytes) evaluations={} \
         packaging-overhead={}% wall={:?}",
        r.run, r.sha256, r.bytes, r.evaluations, r.overhead_pct, r.wall
    );
    if let Some(p) = r.regenerated {
        println!("regenerated: {}", p.display());
    }
    Ok(())
}

/// `molers workload run|replay`: synthetic traces through the real
/// execution stack (see `molers::workload`).
fn cmd_workload(args: &Args) -> CmdResult {
    molers::workload::cmd(args)?;
    Ok(())
}

fn cmd_envs() -> CmdResult {
    println!(
        "environments (switch with --env NAME — the paper's one-line change):\n\
         \x20 local   threads on this machine (test small...)\n\
         \x20 ssh     remote multi-core server over SSH          [simulated]\n\
         \x20 pbs     PBS/Torque cluster via qsub/qstat          [simulated]\n\
         \x20 slurm   Slurm cluster via sbatch/squeue            [simulated]\n\
         \x20 sge     Sun Grid Engine via qsub/qstat             [simulated]\n\
         \x20 oar     OAR cluster via oarsub/oarstat             [simulated]\n\
         \x20 condor  HTCondor pool via condor_submit/condor_q   [simulated]\n\
         \x20 egi     EGI grid via gLite WMS (...scale for free) [simulated]"
    );
    Ok(())
}
