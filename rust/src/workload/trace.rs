//! Trace specification + seeded generation. Grammar in
//! [`crate::workload`]; determinism contract: `(spec, seed)` fully
//! determines the generated trace, byte for byte.

use crate::error::{Error, Result};
use crate::util::json::Json;
use crate::util::Rng;

/// How job releases are spaced over virtual time.
#[derive(Debug, Clone, PartialEq)]
pub enum Arrival {
    /// Fixed spacing in seconds (`uniform:0` = everything at t=0).
    Uniform { spacing_s: f64 },
    /// Poisson process: exponential inter-arrivals with the given rate
    /// (jobs per second) — the WfCommons-style heavy-traffic shape.
    Poisson { rate_per_s: f64 },
    /// Groups of `size` simultaneous releases, `gap_s` apart.
    Burst { size: usize, gap_s: f64 },
}

/// A parsed `--trace` specification.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceSpec {
    pub jobs: usize,
    pub arrival: Arrival,
    /// `(tenant, fair-share weight)` — jobs are assigned by weighted pick.
    pub tenants: Vec<(String, u64)>,
    /// `(method, weight)` job mix over `explore|calibrate|replicate`.
    pub mix: Vec<(String, f64)>,
    /// Explore design-size range, sampled log-uniformly (heavy-tailed
    /// size distributions are the realistic case).
    pub rows: (usize, usize),
    /// `--chunk` forwarded to generated explore jobs.
    pub chunk: usize,
}

impl Default for TraceSpec {
    fn default() -> Self {
        TraceSpec {
            jobs: 16,
            arrival: Arrival::Uniform { spacing_s: 0.0 },
            tenants: vec![("alice".into(), 2), ("bob".into(), 1)],
            mix: vec![("explore".into(), 1.0)],
            rows: (32, 128),
            chunk: 16,
        }
    }
}

fn bad(field: &str, got: &str) -> Error {
    Error::Config(format!("bad trace spec field `{field}`: `{got}`"))
}

impl TraceSpec {
    /// Parse `k=v;k=v;…` over the defaults. Unknown keys are hard errors
    /// (a typo'd knob must not silently generate a different workload).
    pub fn parse(s: &str) -> Result<TraceSpec> {
        let mut spec = TraceSpec::default();
        for part in s.split(';').map(str::trim).filter(|p| !p.is_empty()) {
            let (key, value) = part
                .split_once('=')
                .ok_or_else(|| bad("(entry)", part))?;
            match key.trim() {
                "jobs" => {
                    spec.jobs = value.parse().map_err(|_| bad("jobs", value))?;
                    if spec.jobs == 0 {
                        return Err(bad("jobs", value));
                    }
                }
                "arrival" => spec.arrival = parse_arrival(value)?,
                "tenants" => {
                    spec.tenants = value
                        .split(',')
                        .map(|t| {
                            let (name, w) =
                                t.split_once(':').ok_or_else(|| bad("tenants", t))?;
                            let w: u64 =
                                w.parse().map_err(|_| bad("tenants", t))?;
                            if name.is_empty() || w == 0 {
                                return Err(bad("tenants", t));
                            }
                            Ok((name.to_string(), w))
                        })
                        .collect::<Result<Vec<_>>>()?;
                    if spec.tenants.is_empty() {
                        return Err(bad("tenants", value));
                    }
                }
                "mix" => {
                    spec.mix = value
                        .split(',')
                        .map(|m| {
                            let (run, w) =
                                m.split_once(':').ok_or_else(|| bad("mix", m))?;
                            if !matches!(run, "explore" | "calibrate" | "replicate") {
                                return Err(Error::Config(format!(
                                    "trace mix method `{run}` \
                                     (explore|calibrate|replicate)"
                                )));
                            }
                            let w: f64 = w.parse().map_err(|_| bad("mix", m))?;
                            if !(w.is_finite() && w > 0.0) {
                                return Err(bad("mix", m));
                            }
                            Ok((run.to_string(), w))
                        })
                        .collect::<Result<Vec<_>>>()?;
                    if spec.mix.is_empty() {
                        return Err(bad("mix", value));
                    }
                }
                "rows" => {
                    let (lo, hi) =
                        value.split_once("..").ok_or_else(|| bad("rows", value))?;
                    let lo: usize = lo.parse().map_err(|_| bad("rows", value))?;
                    let hi: usize = hi.parse().map_err(|_| bad("rows", value))?;
                    if lo == 0 || hi < lo {
                        return Err(bad("rows", value));
                    }
                    spec.rows = (lo, hi);
                }
                "chunk" => {
                    spec.chunk = value.parse().map_err(|_| bad("chunk", value))?;
                    if spec.chunk == 0 {
                        return Err(bad("chunk", value));
                    }
                }
                other => {
                    return Err(Error::Config(format!(
                        "unknown trace spec key `{other}` \
                         (jobs|arrival|tenants|mix|rows|chunk)"
                    )))
                }
            }
        }
        Ok(spec)
    }

    /// Generate the trace: seeded, deterministic, sorted by release time.
    pub fn generate(&self, seed: u64) -> Trace {
        let mut rng = Rng::new(seed ^ 0x776f_726b_6c6f_6164); // "workload"
        let tenant_total: u64 = self.tenants.iter().map(|(_, w)| w).sum();
        let mix_total: f64 = self.mix.iter().map(|(_, w)| w).sum();
        let mut at = 0.0f64;
        let mut jobs = Vec::with_capacity(self.jobs);
        for idx in 0..self.jobs {
            // release time
            match &self.arrival {
                Arrival::Uniform { spacing_s } => {
                    if idx > 0 {
                        at += spacing_s;
                    }
                }
                Arrival::Poisson { rate_per_s } => {
                    if idx > 0 && *rate_per_s > 0.0 {
                        at += rng.exponential(1.0 / rate_per_s);
                    }
                }
                Arrival::Burst { size, gap_s } => {
                    if idx > 0 && idx % size.max(&1) == 0 {
                        at += gap_s;
                    }
                }
            }
            // weighted tenant pick
            let mut t = rng.next_u64() % tenant_total;
            let (tenant, weight) = self
                .tenants
                .iter()
                .find(|(_, w)| {
                    if t < *w {
                        true
                    } else {
                        t -= w;
                        false
                    }
                })
                .expect("weighted pick in range")
                .clone();
            // weighted method pick
            let mut m = rng.f64() * mix_total;
            let run = self
                .mix
                .iter()
                .find(|(_, w)| {
                    if m < *w {
                        true
                    } else {
                        m -= w;
                        false
                    }
                })
                .map(|(r, _)| r.clone())
                .unwrap_or_else(|| self.mix[0].0.clone());
            // log-uniform size in the rows range
            let (lo, hi) = self.rows;
            let n = if lo == hi {
                lo
            } else {
                let u = rng.range((lo as f64).ln(), (hi as f64).ln()).exp();
                (u.round() as usize).clamp(lo, hi)
            };
            let job_seed = rng.next_u64();
            let (argv, size) = method_argv(&run, n, self.chunk, &mut rng);
            jobs.push(TraceJob {
                idx,
                at_s: at,
                tenant,
                weight,
                run,
                argv,
                seed: job_seed,
                size,
            });
        }
        Trace { seed, jobs }
    }
}

fn parse_arrival(value: &str) -> Result<Arrival> {
    let mut it = value.split(':');
    let kind = it.next().unwrap_or_default();
    match kind {
        "uniform" => {
            let s: f64 = it
                .next()
                .unwrap_or("0")
                .parse()
                .map_err(|_| bad("arrival", value))?;
            if !(s.is_finite() && s >= 0.0) {
                return Err(bad("arrival", value));
            }
            Ok(Arrival::Uniform { spacing_s: s })
        }
        "poisson" => {
            let r: f64 = it
                .next()
                .ok_or_else(|| bad("arrival", value))?
                .parse()
                .map_err(|_| bad("arrival", value))?;
            if !(r.is_finite() && r > 0.0) {
                return Err(bad("arrival", value));
            }
            Ok(Arrival::Poisson { rate_per_s: r })
        }
        "burst" => {
            let size: usize = it
                .next()
                .ok_or_else(|| bad("arrival", value))?
                .parse()
                .map_err(|_| bad("arrival", value))?;
            let gap: f64 = it
                .next()
                .unwrap_or("1")
                .parse()
                .map_err(|_| bad("arrival", value))?;
            if size == 0 || !(gap.is_finite() && gap >= 0.0) {
                return Err(bad("arrival", value));
            }
            Ok(Arrival::Burst { size, gap_s: gap })
        }
        _ => Err(bad("arrival", value)),
    }
}

/// The method options one generated job submits, plus its nominal size
/// (expected evaluations) for reporting.
fn method_argv(run: &str, n: usize, chunk: usize, rng: &mut Rng) -> (Vec<String>, usize) {
    match run {
        "explore" => {
            let sampling = if rng.bool(0.5) { "lhs" } else { "sobol" };
            (
                vec![
                    "--n".into(),
                    n.to_string(),
                    "--chunk".into(),
                    chunk.to_string(),
                    "--sampling".into(),
                    sampling.into(),
                ],
                n,
            )
        }
        "calibrate" => {
            // scale generations with the size draw, keep populations small
            let generations = (n / 16).clamp(2, 8);
            (
                vec![
                    "--mu".into(),
                    "8".into(),
                    "--lambda".into(),
                    "8".into(),
                    "--generations".into(),
                    generations.to_string(),
                    "--replications".into(),
                    "1".into(),
                ],
                8 + 8 * generations,
            )
        }
        "replicate" => {
            let reps = 3 + rng.usize(5);
            (
                vec!["--replications".into(), reps.to_string()],
                reps,
            )
        }
        other => unreachable!("mix validated at parse time: `{other}`"),
    }
}

/// One generated experiment submission.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceJob {
    pub idx: usize,
    /// Virtual release time (seconds from trace start).
    pub at_s: f64,
    pub tenant: String,
    pub weight: u64,
    pub run: String,
    /// Method options (`--key value` pairs, no seed/out/env flags).
    pub argv: Vec<String>,
    /// Per-job seed (deterministically derived from the trace seed).
    pub seed: u64,
    /// Nominal size in evaluations (for reporting).
    pub size: usize,
}

impl TraceJob {
    /// One JSONL line (`--emit` format).
    pub fn to_json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        m.insert("idx".to_string(), Json::Num(self.idx as f64));
        m.insert("at_s".to_string(), Json::Num(self.at_s));
        m.insert("tenant".to_string(), Json::Str(self.tenant.clone()));
        m.insert("weight".to_string(), Json::Num(self.weight as f64));
        m.insert("run".to_string(), Json::Str(self.run.clone()));
        m.insert(
            "argv".to_string(),
            Json::Arr(self.argv.iter().cloned().map(Json::Str).collect()),
        );
        m.insert("seed_exact".to_string(), Json::Str(self.seed.to_string()));
        m.insert("size".to_string(), Json::Num(self.size as f64));
        Json::Obj(m)
    }
}

/// A generated trace: the seed it came from + its jobs in release order.
#[derive(Debug, Clone, PartialEq)]
pub struct Trace {
    pub seed: u64,
    pub jobs: Vec<TraceJob>,
}

impl Trace {
    /// The `--emit` artifact: one JSON line per job.
    pub fn to_jsonl(&self) -> String {
        let mut out = String::new();
        for j in &self.jobs {
            out.push_str(&j.to_json().to_string());
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_overrides_defaults_and_rejects_garbage() {
        let spec = TraceSpec::parse(
            "jobs=40;arrival=poisson:2;tenants=a:3,b:1;mix=explore:0.8,calibrate:0.2;\
             rows=16..256;chunk=8",
        )
        .unwrap();
        assert_eq!(spec.jobs, 40);
        assert_eq!(spec.arrival, Arrival::Poisson { rate_per_s: 2.0 });
        assert_eq!(spec.tenants, vec![("a".into(), 3), ("b".into(), 1)]);
        assert_eq!(spec.rows, (16, 256));
        assert_eq!(spec.chunk, 8);
        assert_eq!(TraceSpec::parse("").unwrap(), TraceSpec::default());

        for bad in [
            "jobs=0",
            "jobs=x",
            "arrival=warp:1",
            "arrival=poisson:-1",
            "tenants=a:0",
            "mix=island:1",
            "mix=explore:0",
            "rows=0..4",
            "rows=9..3",
            "chunk=0",
            "turbo=1",
        ] {
            assert!(TraceSpec::parse(bad).is_err(), "`{bad}` should be rejected");
        }
    }

    #[test]
    fn generation_is_deterministic_in_spec_and_seed() {
        let spec = TraceSpec::parse("jobs=24;arrival=poisson:4;rows=8..64").unwrap();
        let a = spec.generate(7);
        let b = spec.generate(7);
        assert_eq!(a, b, "same spec+seed → identical trace");
        assert_eq!(a.to_jsonl(), b.to_jsonl());
        let c = spec.generate(8);
        assert_ne!(a, c, "different seed → different trace");
    }

    #[test]
    fn generated_jobs_respect_the_spec() {
        let spec = TraceSpec::parse(
            "jobs=50;arrival=burst:10:5;tenants=x:1;mix=explore:1;rows=8..32",
        )
        .unwrap();
        let t = spec.generate(1);
        assert_eq!(t.jobs.len(), 50);
        for j in &t.jobs {
            assert_eq!(j.tenant, "x");
            assert_eq!(j.run, "explore");
            assert!((8..=32).contains(&j.size), "size {} in rows range", j.size);
            // release times: 5 bursts of 10, 5 s apart
            let burst = j.idx / 10;
            assert_eq!(j.at_s, burst as f64 * 5.0, "job {} release", j.idx);
        }
        // release order is non-decreasing for every arrival process
        let spec = TraceSpec::parse("jobs=30;arrival=poisson:3").unwrap();
        let t = spec.generate(3);
        for w in t.jobs.windows(2) {
            assert!(w[0].at_s <= w[1].at_s);
        }
    }
}
