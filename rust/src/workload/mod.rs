//! # Synthetic workload generation and replay (`molers workload`)
//!
//! The serve daemon, the broker and the fair-share gate are exercised in
//! production by *mixes* of experiments — many tenants, bursty arrivals,
//! heavy-tailed sizes — but every test and bench so far drove them with
//! hand-written job lists. This module closes that gap: a **seeded
//! generator** of synthetic experiment traces plus two replay harnesses
//! that push a trace through the real execution stack and score the
//! outcome (latency distribution, makespan, throughput, Jain fairness).
//!
//! ## Trace-spec grammar (`--trace`)
//!
//! A spec is `key=value` pairs joined by `;`. Every key is optional;
//! unknown keys are errors. Defaults in brackets.
//!
//! ```text
//! spec    := entry (';' entry)*
//! entry   := 'jobs'    '=' INT                       [16]
//!          | 'arrival' '=' arrival                   [uniform:0]
//!          | 'tenants' '=' tenant (',' tenant)*      [alice:2,bob:1]
//!          | 'mix'     '=' method (',' method)*      [explore:1]
//!          | 'rows'    '=' INT '..' INT              [32..128]
//!          | 'chunk'   '=' INT                       [16]
//! arrival := 'uniform' ':' SPACING_S                 fixed spacing
//!          | 'poisson' ':' RATE_PER_S                exponential gaps
//!          | 'burst'   ':' SIZE [':' GAP_S]          SIZE at once
//! tenant  := NAME ':' WEIGHT                         fair-share weight
//! method  := ('explore'|'calibrate'|'replicate') ':' WEIGHT
//! ```
//!
//! Example: `jobs=40;arrival=poisson:2;tenants=alice:3,bob:1;`
//! `mix=explore:0.8,calibrate:0.2;rows=16..256;chunk=16`.
//!
//! Generation is **deterministic** in `(spec, seed)`: job order, release
//! times, tenant/method assignment, per-job design sizes (log-uniform
//! over `rows`) and per-job seeds all derive from one root [`Rng`]
//! stream, so a trace can be regenerated anywhere from five words of
//! description. `--emit` writes the trace as JSONL (one job per line,
//! seeds as exact decimal strings) for archival or external replay.
//!
//! ## Replay harnesses
//!
//! * `molers workload run` — **in-process**: one brokered fleet
//!   (`--envs`, `--policy`, optional `--fault` overlay) behind a
//!   [`FairShare`](crate::broker::FairShare) gate, `--lanes` concurrent
//!   experiment runners; the serve daemon's execution shape without TCP.
//! * `molers workload replay --addr HOST:PORT` — **against a live
//!   daemon**: submits each job under its tenant/weight at its scaled
//!   release time and polls to terminal states.
//!
//! `--time-scale R` maps virtual trace seconds to real seconds (`R`
//! virtual per real; `0` = as fast as the lanes allow). Both harnesses
//! produce per-job [`JobRecord`]s (`--out` JSONL) and a
//! [`ReplaySummary`] scorecard; `benches/p8_workload.rs` tracks the
//! replay harness's overhead over direct sequential execution.
//!
//! [`Rng`]: crate::util::Rng

mod replay;
mod trace;

pub use replay::{
    overlay_faults, replay_local, replay_remote, JobRecord, ReplayConfig,
    ReplaySummary, TenantSummary,
};
pub use trace::{Arrival, Trace, TraceJob, TraceSpec};

use std::time::Duration;

use crate::cli::Args;
use crate::error::{Error, Result};

/// The `molers workload <run|replay>` subcommand: generate the trace,
/// optionally `--emit` it, replay it, print the scorecard and optionally
/// `--out` the per-job records.
pub fn cmd(args: &Args) -> Result<()> {
    let mode = args.positional().first().map(String::as_str);
    let spec_text = args.get_or("trace", "");
    let spec = TraceSpec::parse(spec_text)?;
    let seed = args.u64("seed", 42).map_err(Error::Config)?;
    let trace = spec.generate(seed);
    if let Some(path) = args.get("emit") {
        std::fs::write(path, trace.to_jsonl()).map_err(Error::Io)?;
        println!("trace: {} jobs -> {path}", trace.jobs.len());
    }
    let time_scale = args.f64("time-scale", 0.0).map_err(Error::Config)?;
    let records = match mode {
        Some("run") => {
            let workdir = std::env::temp_dir()
                .join(format!("molers-workload-{}", std::process::id()));
            std::fs::create_dir_all(&workdir).map_err(Error::Io)?;
            let cfg = ReplayConfig {
                envs: args.get_or("envs", "local:8").to_string(),
                policy: args.get_or("policy", "ewma").to_string(),
                fault: args.get("fault").map(str::to_string),
                lanes: args.usize("lanes", 4).map_err(Error::Config)?,
                time_scale,
                seed,
                workdir: workdir.clone(),
                ..ReplayConfig::default()
            };
            let records = replay_local(&trace, &cfg);
            let _ = std::fs::remove_dir_all(&workdir);
            records?
        }
        Some("replay") => {
            let addr = args.get("addr").ok_or_else(|| {
                Error::Config("workload replay needs --addr HOST:PORT".into())
            })?;
            let poll = args.u64("poll-ms", 100).map_err(Error::Config)?;
            replay_remote(&trace, addr, time_scale, Duration::from_millis(poll))?
        }
        None if args.get("emit").is_some() => return Ok(()),
        other => {
            return Err(Error::Config(format!(
                "workload expects `run` or `replay`{}",
                other.map(|o| format!(", got `{o}`")).unwrap_or_default()
            )))
        }
    };
    if let Some(path) = args.get("out") {
        let mut body = String::new();
        for r in &records {
            body.push_str(&r.to_json().to_string());
            body.push('\n');
        }
        std::fs::write(path, body).map_err(Error::Io)?;
    }
    let summary = ReplaySummary::from_records(&records).with_weights(&spec.tenants);
    print!("{summary}");
    if summary.failed > 0 && !args.flag("allow-failures") {
        return Err(Error::Config(format!(
            "{} of {} jobs failed (pass --allow-failures to score anyway)",
            summary.failed, summary.jobs
        )));
    }
    Ok(())
}
