//! Replay a generated [`Trace`] — in-process through a brokered fleet
//! behind the fair-share gate (`workload run`), or against a live
//! `molers serve` daemon over TCP (`workload replay`) — and summarise
//! per-job latency, makespan, throughput and tenant fairness.

use std::io::{BufRead, BufReader, Write as _};
use std::net::TcpStream;
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::broker::{policy, Broker, FairShare, RetryPolicy};
use crate::cli::{front, Args};
use crate::environment::Environment;
use crate::error::{Error, Result};
use crate::util::json::{self, Json};

use super::trace::{Trace, TraceJob};

/// Knobs of an in-process replay.
pub struct ReplayConfig {
    /// Fleet spec (`local:8,pbs:32`), optionally overlaid with faults.
    pub envs: String,
    pub policy: String,
    /// Fault plan (`drop=0.1;hang=0.01`) appended to every backend that
    /// does not already carry one — chaos as an overlay, not a rewrite.
    pub fault: Option<String>,
    /// Concurrent experiment lanes (the serve daemon's `max_running`
    /// analogue).
    pub lanes: usize,
    /// Virtual seconds replayed per real second; `0` = ignore release
    /// times and go as fast as the lanes allow.
    pub time_scale: f64,
    /// Broker seed (fault injection and backend simulation).
    pub seed: u64,
    /// Retry policy of the brokered fleet (deadlines, backoff) — part of
    /// the env spec a fault overlay is measured against.
    pub retry: RetryPolicy,
    /// Where explore jobs write their (discarded) result files.
    pub workdir: PathBuf,
    /// Keep per-job result files instead of deleting them on completion.
    pub keep: bool,
}

impl Default for ReplayConfig {
    fn default() -> Self {
        ReplayConfig {
            envs: "local:8".into(),
            policy: "ewma".into(),
            fault: None,
            lanes: 4,
            time_scale: 0.0,
            seed: 42,
            retry: RetryPolicy::default(),
            workdir: std::env::temp_dir(),
            keep: false,
        }
    }
}

/// Append `fault` to every backend of `spec` that has no `~plan` of its
/// own. Backends are comma-separated; a plan's own separators (`;`, `:`)
/// never collide with the backend separator.
pub fn overlay_faults(spec: &str, fault: Option<&str>) -> String {
    let Some(fault) = fault.filter(|f| !f.is_empty()) else {
        return spec.to_string();
    };
    spec.split(',')
        .map(|b| {
            if b.contains('~') {
                b.to_string()
            } else {
                format!("{b}~{fault}")
            }
        })
        .collect::<Vec<_>>()
        .join(",")
}

/// What happened to one replayed job. Times are real seconds from replay
/// start; `latency` (sojourn) is `done_s - release_s`.
#[derive(Debug, Clone)]
pub struct JobRecord {
    pub idx: usize,
    pub tenant: String,
    pub run: String,
    /// Nominal size from the trace (expected evaluations).
    pub size: usize,
    pub release_s: f64,
    pub start_s: f64,
    pub done_s: f64,
    pub evaluations: u64,
    pub ok: bool,
    pub error: Option<String>,
}

impl JobRecord {
    pub fn latency_s(&self) -> f64 {
        (self.done_s - self.release_s).max(0.0)
    }

    /// One `--out` JSONL line.
    pub fn to_json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        m.insert("idx".to_string(), Json::Num(self.idx as f64));
        m.insert("tenant".to_string(), Json::Str(self.tenant.clone()));
        m.insert("run".to_string(), Json::Str(self.run.clone()));
        m.insert("size".to_string(), Json::Num(self.size as f64));
        m.insert("release_s".to_string(), Json::Num(self.release_s));
        m.insert("start_s".to_string(), Json::Num(self.start_s));
        m.insert("done_s".to_string(), Json::Num(self.done_s));
        m.insert("latency_s".to_string(), Json::Num(self.latency_s()));
        m.insert(
            "evaluations".to_string(),
            Json::Num(self.evaluations as f64),
        );
        m.insert("ok".to_string(), Json::Bool(self.ok));
        if let Some(e) = &self.error {
            m.insert("error".to_string(), Json::Str(e.clone()));
        }
        Json::Obj(m)
    }
}

/// Replay the trace in-process: one brokered fleet + fair-share gate
/// shared by `lanes` concurrent experiment runners, exactly the serve
/// daemon's execution shape without the TCP layer. Records come back in
/// job order.
pub fn replay_local(trace: &Trace, cfg: &ReplayConfig) -> Result<Vec<JobRecord>> {
    let pool = Arc::new(crate::exec::ThreadPool::default_size());
    let spec = overlay_faults(&cfg.envs, cfg.fault.as_deref());
    let p = policy::by_name(&cfg.policy).ok_or_else(|| {
        Error::Config(format!(
            "unknown --policy `{}` (roundrobin|least|ewma)",
            cfg.policy
        ))
    })?;
    let broker = Arc::new(
        Broker::spec_builder(&spec, pool, cfg.seed)?
            .policy(p)
            .retry(cfg.retry.clone())
            .build()?,
    );
    let slots = broker
        .backend_snapshots()
        .iter()
        .map(|b| b.capacity)
        .sum::<usize>()
        .max(1);
    let fair = FairShare::new(Arc::clone(&broker) as Arc<dyn Environment>, slots);

    let t0 = Instant::now();
    let next = AtomicUsize::new(0);
    let records: Mutex<Vec<Option<JobRecord>>> =
        Mutex::new(vec![None; trace.jobs.len()]);
    let lanes = cfg.lanes.max(1);
    std::thread::scope(|s| {
        for _ in 0..lanes.min(trace.jobs.len().max(1)) {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::SeqCst);
                let Some(job) = trace.jobs.get(i) else { break };
                // pace the lane to the job's release time; a job whose
                // release has passed (all lanes busy) starts late — that
                // queueing delay is exactly what the latency metric sees
                let release_s = if cfg.time_scale > 0.0 {
                    job.at_s / cfg.time_scale
                } else {
                    0.0
                };
                let elapsed = t0.elapsed().as_secs_f64();
                if release_s > elapsed {
                    std::thread::sleep(Duration::from_secs_f64(release_s - elapsed));
                }
                let rec = run_job(job, &fair, cfg, release_s, &t0);
                records.lock().unwrap()[i] = Some(rec);
            });
        }
    });
    Ok(records
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|r| r.expect("every lane writes its slot"))
        .collect())
}

/// Build and run one trace job through the shared fair-share gate.
fn run_job(
    job: &TraceJob,
    fair: &Arc<FairShare>,
    cfg: &ReplayConfig,
    release_s: f64,
    t0: &Instant,
) -> JobRecord {
    let start_s = t0.elapsed().as_secs_f64();
    let mut argv: Vec<String> = vec![job.run.clone()];
    argv.extend(job.argv.iter().cloned());
    argv.push("--seed".into());
    argv.push(job.seed.to_string());
    let out = (job.run == "explore").then(|| {
        let p = cfg.workdir.join(format!("job-{}.csv", job.idx));
        argv.push("--out".into());
        argv.push(p.to_string_lossy().into_owned());
        p
    });
    let tenant: Arc<dyn Environment> =
        Arc::new(fair.tenant(&job.tenant, job.weight));
    let result = Args::parse(argv)
        .map_err(Error::Config)
        .and_then(|a| front::by_name(&job.run, &a))
        .map(|exp| exp.on(tenant).quiet())
        .and_then(|exp| exp.run());
    let done_s = t0.elapsed().as_secs_f64();
    if let Some(p) = out {
        if !cfg.keep {
            let _ = std::fs::remove_file(p);
        }
    }
    let (evaluations, ok, error) = match result {
        Ok(report) => (report.outcome.evaluations, true, None),
        Err(e) => (0, false, Some(e.to_string())),
    };
    JobRecord {
        idx: job.idx,
        tenant: job.tenant.clone(),
        run: job.run.clone(),
        size: job.size,
        release_s,
        start_s,
        done_s,
        evaluations,
        ok,
        error,
    }
}

/// One-shot request against a serve daemon (`addr` as `host:port`).
fn request(addr: &str, line: &str) -> Result<Json> {
    let mut stream = TcpStream::connect(addr).map_err(Error::Io)?;
    stream
        .write_all(format!("{line}\n").as_bytes())
        .map_err(Error::Io)?;
    let mut reply = String::new();
    BufReader::new(&mut stream)
        .read_line(&mut reply)
        .map_err(Error::Io)?;
    let v = json::parse(reply.trim()).map_err(|e| {
        Error::Config(format!("bad response from {addr}: {e}"))
    })?;
    if v.get("ok").and_then(Json::as_bool) == Some(true) {
        Ok(v)
    } else {
        Err(Error::Config(format!(
            "server error: {}",
            v.get("error").and_then(Json::as_str).unwrap_or("unknown")
        )))
    }
}

/// Replay the trace against a live serve daemon: submit each job at its
/// (scaled) release time under its tenant/weight, then poll `status`
/// until every experiment reaches a terminal state. Server-side start
/// times are not exposed, so `start_s` records the submission instant.
pub fn replay_remote(
    trace: &Trace,
    addr: &str,
    time_scale: f64,
    poll: Duration,
) -> Result<Vec<JobRecord>> {
    let t0 = Instant::now();
    let mut pending: Vec<(u64, usize, f64, f64)> = Vec::new(); // (id, idx, release, submit)
    let mut records: Vec<Option<JobRecord>> = vec![None; trace.jobs.len()];
    for (i, job) in trace.jobs.iter().enumerate() {
        let release_s = if time_scale > 0.0 {
            job.at_s / time_scale
        } else {
            0.0
        };
        let elapsed = t0.elapsed().as_secs_f64();
        if release_s > elapsed {
            std::thread::sleep(Duration::from_secs_f64(release_s - elapsed));
        }
        let mut options: Vec<(String, Json)> = job
            .argv
            .chunks(2)
            .filter_map(|kv| match kv {
                [k, v] => Some((
                    k.trim_start_matches("--").to_string(),
                    Json::Str(v.clone()),
                )),
                _ => None,
            })
            .collect();
        options.push(("seed".to_string(), Json::Str(job.seed.to_string())));
        let submit = Json::Obj(
            [
                ("cmd".to_string(), Json::Str("submit".into())),
                ("run".to_string(), Json::Str(job.run.clone())),
                ("tenant".to_string(), Json::Str(job.tenant.clone())),
                ("weight".to_string(), Json::Num(job.weight as f64)),
                (
                    "options".to_string(),
                    Json::Obj(options.into_iter().collect()),
                ),
                (
                    "dedup_key".to_string(),
                    Json::Str(format!("workload-{}-{}", trace.seed, job.idx)),
                ),
            ]
            .into_iter()
            .collect(),
        );
        let reply = request(addr, &submit.to_string())?;
        let id = reply
            .get("id")
            .and_then(Json::as_f64)
            .ok_or_else(|| Error::Config("submit reply missing `id`".into()))?
            as u64;
        pending.push((id, i, release_s, t0.elapsed().as_secs_f64()));
    }

    // poll round-robin until every submission is terminal
    while !pending.is_empty() {
        let mut still = Vec::with_capacity(pending.len());
        for (id, idx, release_s, submit_s) in pending {
            let status = request(addr, &format!("{{\"cmd\":\"status\",\"id\":{id}}}"))?;
            let state = status
                .get("state")
                .and_then(Json::as_str)
                .unwrap_or("unknown")
                .to_string();
            let terminal =
                matches!(state.as_str(), "done" | "degraded" | "failed" | "cancelled");
            if !terminal {
                still.push((id, idx, release_s, submit_s));
                continue;
            }
            let job = &trace.jobs[idx];
            let evaluations = status
                .get("summary")
                .and_then(|s| s.get("evaluations"))
                .and_then(Json::as_f64)
                .unwrap_or(0.0) as u64;
            let ok = matches!(state.as_str(), "done" | "degraded");
            records[idx] = Some(JobRecord {
                idx,
                tenant: job.tenant.clone(),
                run: job.run.clone(),
                size: job.size,
                release_s,
                start_s: submit_s,
                done_s: t0.elapsed().as_secs_f64(),
                evaluations,
                ok,
                error: (!ok).then(|| {
                    status
                        .get("error")
                        .and_then(Json::as_str)
                        .unwrap_or(&state)
                        .to_string()
                }),
            });
        }
        pending = still;
        if !pending.is_empty() {
            std::thread::sleep(poll);
        }
    }
    Ok(records
        .into_iter()
        .map(|r| r.expect("polled to terminal"))
        .collect())
}

/// Per-tenant share of a replay.
#[derive(Debug, Clone)]
pub struct TenantSummary {
    pub name: String,
    pub weight: u64,
    pub jobs: usize,
    pub evaluations: u64,
}

/// The replay scorecard: completion, latency distribution, makespan,
/// throughput and Jain's fairness index over weight-normalised per-tenant
/// evaluation throughput (1.0 = perfectly proportional shares).
#[derive(Debug, Clone)]
pub struct ReplaySummary {
    pub jobs: usize,
    pub ok: usize,
    pub failed: usize,
    pub makespan_s: f64,
    pub mean_latency_s: f64,
    pub p50_latency_s: f64,
    pub p95_latency_s: f64,
    pub max_latency_s: f64,
    pub evaluations: u64,
    pub fairness: f64,
    pub per_tenant: Vec<TenantSummary>,
}

impl ReplaySummary {
    pub fn from_records(records: &[JobRecord]) -> ReplaySummary {
        let mut latencies: Vec<f64> =
            records.iter().map(JobRecord::latency_s).collect();
        latencies.sort_by(|a, b| a.total_cmp(b));
        let pct = |p: f64| -> f64 {
            if latencies.is_empty() {
                return 0.0;
            }
            let i = ((latencies.len() as f64 - 1.0) * p).round() as usize;
            latencies[i.min(latencies.len() - 1)]
        };
        let mut tenants: Vec<TenantSummary> = Vec::new();
        for r in records {
            match tenants.iter_mut().find(|t| t.name == r.tenant) {
                Some(t) => {
                    t.jobs += 1;
                    t.evaluations += r.evaluations;
                }
                None => tenants.push(TenantSummary {
                    name: r.tenant.clone(),
                    weight: 1,
                    jobs: 1,
                    evaluations: r.evaluations,
                }),
            }
        }
        ReplaySummary {
            jobs: records.len(),
            ok: records.iter().filter(|r| r.ok).count(),
            failed: records.iter().filter(|r| !r.ok).count(),
            makespan_s: records.iter().map(|r| r.done_s).fold(0.0, f64::max),
            mean_latency_s: if latencies.is_empty() {
                0.0
            } else {
                latencies.iter().sum::<f64>() / latencies.len() as f64
            },
            p50_latency_s: pct(0.50),
            p95_latency_s: pct(0.95),
            max_latency_s: latencies.last().copied().unwrap_or(0.0),
            evaluations: records.iter().map(|r| r.evaluations).sum(),
            fairness: 1.0, // recomputed by with_weights
            per_tenant: tenants,
        }
    }

    /// Attach the trace's tenant weights and compute Jain's index
    /// `J = (Σx)² / (n·Σx²)` over `x_t = evaluations_t / weight_t`.
    pub fn with_weights(mut self, weights: &[(String, u64)]) -> ReplaySummary {
        for t in &mut self.per_tenant {
            if let Some((_, w)) = weights.iter().find(|(n, _)| *n == t.name) {
                t.weight = (*w).max(1);
            }
        }
        let xs: Vec<f64> = self
            .per_tenant
            .iter()
            .map(|t| t.evaluations as f64 / t.weight as f64)
            .collect();
        let n = xs.len() as f64;
        let sum: f64 = xs.iter().sum();
        let sumsq: f64 = xs.iter().map(|x| x * x).sum();
        self.fairness = if xs.len() < 2 || sumsq == 0.0 {
            1.0
        } else {
            (sum * sum) / (n * sumsq)
        };
        self
    }
}

impl std::fmt::Display for ReplaySummary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(
            f,
            "jobs: {} ok, {} failed / {} total",
            self.ok, self.failed, self.jobs
        )?;
        writeln!(
            f,
            "makespan: {:.2}s  evaluations: {}  throughput: {:.1} eval/s",
            self.makespan_s,
            self.evaluations,
            if self.makespan_s > 0.0 {
                self.evaluations as f64 / self.makespan_s
            } else {
                0.0
            }
        )?;
        writeln!(
            f,
            "latency: mean {:.2}s  p50 {:.2}s  p95 {:.2}s  max {:.2}s",
            self.mean_latency_s, self.p50_latency_s, self.p95_latency_s,
            self.max_latency_s
        )?;
        writeln!(f, "fairness (Jain, weight-normalised): {:.3}", self.fairness)?;
        for t in &self.per_tenant {
            writeln!(
                f,
                "  tenant {} (weight {}): {} jobs, {} evaluations",
                t.name, t.weight, t.jobs, t.evaluations
            )?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fault_overlay_respects_existing_plans() {
        assert_eq!(overlay_faults("local:8", None), "local:8");
        assert_eq!(
            overlay_faults("local:8,pbs:32", Some("drop=0.1;hang=0.01")),
            "local:8~drop=0.1;hang=0.01,pbs:32~drop=0.1;hang=0.01"
        );
        // a backend with its own plan is left alone
        assert_eq!(
            overlay_faults("local:8~drop=0.5,pbs:4", Some("hang=0.2")),
            "local:8~drop=0.5,pbs:4~hang=0.2"
        );
        assert_eq!(overlay_faults("local:2", Some("")), "local:2");
    }

    #[test]
    fn summary_statistics_are_correct() {
        let rec = |idx, tenant: &str, release, done, evals, ok| JobRecord {
            idx,
            tenant: tenant.into(),
            run: "explore".into(),
            size: evals as usize,
            release_s: release,
            start_s: release,
            done_s: done,
            evaluations: evals,
            ok,
            error: None,
        };
        let records = vec![
            rec(0, "a", 0.0, 2.0, 60, true),
            rec(1, "a", 1.0, 2.0, 60, true),
            rec(2, "b", 0.0, 4.0, 60, true),
            rec(3, "b", 2.0, 3.0, 0, false),
        ];
        let s = ReplaySummary::from_records(&records)
            .with_weights(&[("a".into(), 2), ("b".into(), 1)]);
        assert_eq!((s.jobs, s.ok, s.failed), (4, 3, 1));
        assert_eq!(s.makespan_s, 4.0);
        assert_eq!(s.evaluations, 180);
        // latencies: [2, 1, 4, 1] → sorted [1, 1, 2, 4]
        assert_eq!(s.mean_latency_s, 2.0);
        assert_eq!(s.p50_latency_s, 2.0);
        assert_eq!(s.max_latency_s, 4.0);
        // x_a = 120/2 = 60, x_b = 60/1 = 60 → perfectly fair
        assert!((s.fairness - 1.0).abs() < 1e-12, "{}", s.fairness);
        // starve b entirely → fairness drops to 1/n = 0.5
        let skew = vec![rec(0, "a", 0.0, 1.0, 100, true), rec(1, "b", 0.0, 1.0, 0, true)];
        let s = ReplaySummary::from_records(&skew)
            .with_weights(&[("a".into(), 1), ("b".into(), 1)]);
        assert!((s.fairness - 0.5).abs() < 1e-12, "{}", s.fairness);
    }

    #[test]
    fn job_records_serialise_to_jsonl() {
        let r = JobRecord {
            idx: 3,
            tenant: "alice".into(),
            run: "explore".into(),
            size: 32,
            release_s: 1.0,
            start_s: 1.5,
            done_s: 2.5,
            evaluations: 32,
            ok: true,
            error: None,
        };
        let line = r.to_json().to_string();
        assert!(line.contains("\"idx\":3"), "{line}");
        assert!(line.contains("\"latency_s\":1.5"), "{line}");
        assert!(!line.contains("error"), "{line}");
    }
}
