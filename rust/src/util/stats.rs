//! Statistical descriptors used by `StatisticTask` (paper §4.4) and the
//! bench harness.

/// A summary statistic over replicated model outputs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Descriptor {
    Median,
    Mean,
    Min,
    Max,
    StdDev,
    /// Median absolute deviation — robust spread estimate.
    Mad,
    /// q-quantile with 0 <= q <= 1 scaled by 100 (e.g. Quantile(90)).
    Quantile(u8),
}

impl Descriptor {
    pub fn name(&self) -> String {
        match self {
            Descriptor::Median => "median".into(),
            Descriptor::Mean => "mean".into(),
            Descriptor::Min => "min".into(),
            Descriptor::Max => "max".into(),
            Descriptor::StdDev => "stddev".into(),
            Descriptor::Mad => "mad".into(),
            Descriptor::Quantile(q) => format!("q{q}"),
        }
    }

    /// Apply the descriptor. Empty input yields NaN.
    pub fn apply(&self, xs: &[f64]) -> f64 {
        if xs.is_empty() {
            return f64::NAN;
        }
        match self {
            Descriptor::Median => median(xs),
            Descriptor::Mean => mean(xs),
            Descriptor::Min => xs.iter().cloned().fold(f64::INFINITY, f64::min),
            Descriptor::Max => xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
            Descriptor::StdDev => stddev(xs),
            Descriptor::Mad => mad(xs),
            Descriptor::Quantile(q) => quantile(xs, f64::from(*q) / 100.0),
        }
    }
}

pub fn mean(xs: &[f64]) -> f64 {
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Median with linear interpolation for even lengths.
pub fn median(xs: &[f64]) -> f64 {
    quantile(xs, 0.5)
}

/// Median absolute deviation.
pub fn mad(xs: &[f64]) -> f64 {
    let m = median(xs);
    let devs: Vec<f64> = xs.iter().map(|x| (x - m).abs()).collect();
    median(&devs)
}

/// Linear-interpolated quantile (type-7, the R/numpy default).
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    // total_cmp: a NaN sample sorts last instead of panicking (this feeds
    // the replication descriptors, which can see NaN objectives)
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(f64::total_cmp);
    if v.is_empty() {
        return f64::NAN;
    }
    let h = (v.len() - 1) as f64 * q.clamp(0.0, 1.0);
    let lo = h.floor() as usize;
    let hi = h.ceil() as usize;
    v[lo] + (h - lo as f64) * (v[hi] - v[lo])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn median_odd_even() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
    }

    #[test]
    fn quantiles() {
        let xs: Vec<f64> = (0..=100).map(f64::from).collect();
        assert_eq!(quantile(&xs, 0.0), 0.0);
        assert_eq!(quantile(&xs, 1.0), 100.0);
        assert_eq!(quantile(&xs, 0.9), 90.0);
    }

    #[test]
    fn descriptor_apply() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(Descriptor::Mean.apply(&xs), 2.5);
        assert_eq!(Descriptor::Min.apply(&xs), 1.0);
        assert_eq!(Descriptor::Max.apply(&xs), 4.0);
        assert!((Descriptor::StdDev.apply(&xs) - 1.2909944).abs() < 1e-6);
        assert!(Descriptor::Median.apply(&[]).is_nan());
    }

    #[test]
    fn mad_robust_to_outlier() {
        let xs = [1.0, 2.0, 3.0, 4.0, 1000.0];
        assert_eq!(Descriptor::Mad.apply(&xs), 1.0);
    }
}
