//! Deterministic pseudo-random number generation.
//!
//! The engine owns all stochasticity (the paper's OpenMOLE injects `seed`
//! into each model run); environments, samplings and evolutionary operators
//! all draw from [`Rng`] so every experiment is reproducible from a single
//! root seed. Implementation: xoshiro256++ seeded via splitmix64 — small,
//! fast, and dependency-free (the `rand` crate is not vendored in this
//! image; see DESIGN.md §3).

/// splitmix64 step — used for seeding and for cheap stateless hashing.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Largest float strictly below `x` (`f64::next_down` without the MSRV
/// bump). NaN and −∞ return themselves.
#[inline]
pub fn next_below(x: f64) -> f64 {
    if x.is_nan() || x == f64::NEG_INFINITY {
        return x;
    }
    let bits = x.to_bits();
    if x > 0.0 {
        f64::from_bits(bits - 1)
    } else if x == 0.0 {
        // below both +0.0 and -0.0 sits the smallest negative subnormal
        -f64::from_bits(1)
    } else {
        f64::from_bits(bits + 1)
    }
}

/// Map a unit draw `u ∈ [0, 1)` onto the **half-open** interval
/// `[lo, hi)`.
///
/// The naive `lo + u·(hi-lo)` can round *onto* `hi` even though `u < 1`
/// (e.g. `(1 - 2⁻⁵³) · 3.0 == 3.0` in f64), silently violating the
/// half-open contract every sampling documents. This mapping clamps that
/// rounding: a result that lands on or above `hi` is pulled to the
/// largest float below it (and never below `lo`). Degenerate `lo == hi`
/// yields `lo`.
#[inline]
pub fn unit_to_range(u: f64, lo: f64, hi: f64) -> f64 {
    let v = lo + u * (hi - lo);
    if v >= hi && lo < hi {
        next_below(hi).max(lo)
    } else {
        v.max(lo)
    }
}

/// xoshiro256++ PRNG. Not cryptographic; plenty for simulation workloads.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed deterministically from a single 64-bit value.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Self {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Derive an independent child stream (used to hand one RNG per island,
    /// per job, per replication...).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }

    /// The raw xoshiro256++ state — journaled so a resumed run continues
    /// the exact stream (bit-identical trajectories after `--resume`).
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator from a captured state.
    pub fn from_state(s: [u64; 4]) -> Self {
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform on the **half-open** interval `[lo, hi)` (requires
    /// `lo <= hi`; `lo == hi` yields `lo`). The contract is exact, not
    /// approximate: the underlying `lo + u·(hi-lo)` mapping is clamped
    /// via [`unit_to_range`] so floating-point rounding can never return
    /// `hi` itself — samplings and tests may rely on `value < hi`.
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        unit_to_range(self.f64(), lo, hi)
    }

    /// Uniform integer in `[0, n)` (n > 0), unbiased via rejection.
    #[inline]
    pub fn usize(&mut self, n: usize) -> usize {
        assert!(n > 0, "Rng::usize called with n == 0");
        let n = n as u64;
        let zone = u64::MAX - u64::MAX % n;
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % n) as usize;
            }
        }
    }

    /// Bernoulli draw.
    #[inline]
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn gauss(&mut self) -> f64 {
        let u1 = self.f64().max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Lognormal with the given parameters of the underlying normal.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.gauss()).exp()
    }

    /// Exponential with the given mean.
    pub fn exponential(&mut self, mean: f64) -> f64 {
        -mean * (1.0 - self.f64()).ln()
    }

    /// A fresh 32-bit model seed (the uint32 the HLO artifacts take).
    pub fn model_seed(&mut self) -> u32 {
        self.next_u64() as u32
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.usize(i + 1));
        }
    }

    /// Sample `k` distinct indices out of `n` (k <= n), in random order.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut idx = Vec::new();
        self.sample_indices_into(n, k, &mut idx);
        idx
    }

    /// [`Rng::sample_indices`] into a caller-owned buffer (identical draw
    /// order) — the columnar engines recycle the buffer across waves so
    /// steady-state sampling allocates nothing.
    pub fn sample_indices_into(&mut self, n: usize, k: usize, out: &mut Vec<usize>) {
        assert!(k <= n);
        out.clear();
        out.extend(0..n);
        self.shuffle(out);
        out.truncate(k);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let (mut a, mut b) = (Rng::new(1), Rng::new(2));
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn usize_unbiased_range() {
        let mut r = Rng::new(4);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[r.usize(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gauss_moments() {
        let mut r = Rng::new(5);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gauss()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(6);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn state_round_trip_continues_stream() {
        let mut a = Rng::new(42);
        for _ in 0..10 {
            a.next_u64();
        }
        let mut b = Rng::from_state(a.state());
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(9);
        let mut a = root.fork();
        let mut b = root.fork();
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn unit_to_range_clamps_rounding_onto_hi() {
        // the naive mapping really does land on hi — the bug being fixed:
        // 1 + (1 - 2⁻⁵³) is exactly halfway between 2 - 2⁻⁵² and 2, and
        // ties-to-even rounds it onto 2.0 even though u < 1
        let u = 1.0 - 2f64.powi(-53); // the largest value Rng::f64 returns
        assert_eq!(1.0 + u * 1.0, 2.0, "premise: rounding reaches hi");
        let v = unit_to_range(u, 1.0, 2.0);
        assert!(v < 2.0, "unit_to_range must stay below hi, got {v}");
        assert_eq!(v, next_below(2.0));
        // unaffected draws pass through exactly
        assert_eq!(unit_to_range(0.25, 2.0, 6.0), 3.0);
        assert_eq!(unit_to_range(0.0, -1.0, 1.0), -1.0);
        // degenerate interval
        assert_eq!(unit_to_range(0.9, 5.0, 5.0), 5.0);
        // negative interval: -2 + u·1 also ties onto hi = -1 and is clamped
        let w = unit_to_range(u, -2.0, -1.0);
        assert!((-2.0..-1.0).contains(&w), "negative interval: {w}");
    }

    #[test]
    fn next_below_is_the_predecessor() {
        for x in [3.0, 1.0, 1e-300, 0.0, -0.0, -1.0, -1e18, f64::INFINITY] {
            let b = next_below(x);
            assert!(b < x, "next_below({x}) = {b} not below");
            // nothing representable fits strictly between b and x
            let mid = b + (x - b) / 2.0;
            assert!(mid == b || mid == x, "gap between {b} and {x}");
        }
        assert_eq!(next_below(f64::NEG_INFINITY), f64::NEG_INFINITY);
        assert!(next_below(f64::NAN).is_nan());
    }

    #[test]
    fn range_stays_half_open() {
        // a few-ulp-wide interval makes the rounding-onto-hi case likely
        let (lo, hi) = (1.0, 1.0 + 3.0 * f64::EPSILON);
        let mut r = Rng::new(8);
        for _ in 0..10_000 {
            let v = r.range(lo, hi);
            assert!((lo..hi).contains(&v), "{v} escaped [{lo}, {hi})");
        }
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(11);
        let n = 40_000;
        let m = (0..n).map(|_| r.exponential(3.0)).sum::<f64>() / n as f64;
        assert!((m - 3.0).abs() < 0.1, "mean {m}");
    }
}
