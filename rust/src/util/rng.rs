//! Deterministic pseudo-random number generation.
//!
//! The engine owns all stochasticity (the paper's OpenMOLE injects `seed`
//! into each model run); environments, samplings and evolutionary operators
//! all draw from [`Rng`] so every experiment is reproducible from a single
//! root seed. Implementation: xoshiro256++ seeded via splitmix64 — small,
//! fast, and dependency-free (the `rand` crate is not vendored in this
//! image; see DESIGN.md §3).

/// splitmix64 step — used for seeding and for cheap stateless hashing.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256++ PRNG. Not cryptographic; plenty for simulation workloads.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed deterministically from a single 64-bit value.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Self {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Derive an independent child stream (used to hand one RNG per island,
    /// per job, per replication...).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }

    /// The raw xoshiro256++ state — journaled so a resumed run continues
    /// the exact stream (bit-identical trajectories after `--resume`).
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator from a captured state.
    pub fn from_state(s: [u64; 4]) -> Self {
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Uniform integer in `[0, n)` (n > 0), unbiased via rejection.
    #[inline]
    pub fn usize(&mut self, n: usize) -> usize {
        assert!(n > 0, "Rng::usize called with n == 0");
        let n = n as u64;
        let zone = u64::MAX - u64::MAX % n;
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % n) as usize;
            }
        }
    }

    /// Bernoulli draw.
    #[inline]
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn gauss(&mut self) -> f64 {
        let u1 = self.f64().max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Lognormal with the given parameters of the underlying normal.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.gauss()).exp()
    }

    /// Exponential with the given mean.
    pub fn exponential(&mut self, mean: f64) -> f64 {
        -mean * (1.0 - self.f64()).ln()
    }

    /// A fresh 32-bit model seed (the uint32 the HLO artifacts take).
    pub fn model_seed(&mut self) -> u32 {
        self.next_u64() as u32
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.usize(i + 1));
        }
    }

    /// Sample `k` distinct indices out of `n` (k <= n), in random order.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut idx = Vec::new();
        self.sample_indices_into(n, k, &mut idx);
        idx
    }

    /// [`Rng::sample_indices`] into a caller-owned buffer (identical draw
    /// order) — the columnar engines recycle the buffer across waves so
    /// steady-state sampling allocates nothing.
    pub fn sample_indices_into(&mut self, n: usize, k: usize, out: &mut Vec<usize>) {
        assert!(k <= n);
        out.clear();
        out.extend(0..n);
        self.shuffle(out);
        out.truncate(k);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let (mut a, mut b) = (Rng::new(1), Rng::new(2));
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn usize_unbiased_range() {
        let mut r = Rng::new(4);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[r.usize(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gauss_moments() {
        let mut r = Rng::new(5);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gauss()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(6);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn state_round_trip_continues_stream() {
        let mut a = Rng::new(42);
        for _ in 0..10 {
            a.next_u64();
        }
        let mut b = Rng::from_state(a.state());
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(9);
        let mut a = root.fork();
        let mut b = root.fork();
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(11);
        let n = 40_000;
        let m = (0..n).map(|_| r.exponential(3.0)).sum::<f64>() / n as f64;
        assert!((m - 3.0).abs() < 0.1, "mean {m}");
    }
}
