//! Dependency-free utility substrates: RNG, JSON, statistics, hashing.

pub mod hash;
pub mod json;
pub mod rng;
pub mod stats;

pub use hash::{sha256_file, sha256_hex, Sha256};
pub use json::Json;
pub use rng::Rng;
pub use stats::Descriptor;
