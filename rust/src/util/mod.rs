//! Dependency-free utility substrates: RNG, JSON, statistics.

pub mod json;
pub mod rng;
pub mod stats;

pub use json::Json;
pub use rng::Rng;
pub use stats::Descriptor;
