//! Dependency-free SHA-256 (FIPS 180-4) — the content hash behind the
//! provenance layer (ROADMAP item 5). A manifest records the digest of
//! the result file and of every journal segment; `molers reexec` proves
//! reproduction by digest equality, so the hash must be stable across
//! platforms and releases. SHA-256 is bit-for-bit specified, verified
//! here against the NIST test vectors.
//!
//! The implementation is the straightforward 64-round compression over
//! 512-bit blocks — no unsafe, no SIMD. Hashing is a vanishing fraction
//! of any experiment's cost (one pass over a result file the sweep just
//! spent a campaign producing), so clarity wins over throughput.

use std::io::Read;
use std::path::Path;

/// Round constants: fractional parts of the cube roots of the first 64
/// primes (FIPS 180-4 §4.2.2).
const K: [u32; 64] = [
    0x428a2f98, 0x71374491, 0xb5c0fbcf, 0xe9b5dba5, 0x3956c25b, 0x59f111f1,
    0x923f82a4, 0xab1c5ed5, 0xd807aa98, 0x12835b01, 0x243185be, 0x550c7dc3,
    0x72be5d74, 0x80deb1fe, 0x9bdc06a7, 0xc19bf174, 0xe49b69c1, 0xefbe4786,
    0x0fc19dc6, 0x240ca1cc, 0x2de92c6f, 0x4a7484aa, 0x5cb0a9dc, 0x76f988da,
    0x983e5152, 0xa831c66d, 0xb00327c8, 0xbf597fc7, 0xc6e00bf3, 0xd5a79147,
    0x06ca6351, 0x14292967, 0x27b70a85, 0x2e1b2138, 0x4d2c6dfc, 0x53380d13,
    0x650a7354, 0x766a0abb, 0x81c2c92e, 0x92722c85, 0xa2bfe8a1, 0xa81a664b,
    0xc24b8b70, 0xc76c51a3, 0xd192e819, 0xd6990624, 0xf40e3585, 0x106aa070,
    0x19a4c116, 0x1e376c08, 0x2748774c, 0x34b0bcb5, 0x391c0cb3, 0x4ed8aa4a,
    0x5b9cca4f, 0x682e6ff3, 0x748f82ee, 0x78a5636f, 0x84c87814, 0x8cc70208,
    0x90befffa, 0xa4506ceb, 0xbef9a3f7, 0xc67178f2,
];

/// Initial hash state: fractional parts of the square roots of the first
/// 8 primes (FIPS 180-4 §5.3.3).
const H0: [u32; 8] = [
    0x6a09e667, 0xbb67ae85, 0x3c6ef372, 0xa54ff53a, 0x510e527f, 0x9b05688c,
    0x1f83d9ab, 0x5be0cd19,
];

/// Streaming SHA-256: feed bytes in any chunking, finalize once.
pub struct Sha256 {
    state: [u32; 8],
    buf: [u8; 64],
    buf_len: usize,
    /// Total message length in bytes (the padding encodes it in bits).
    total: u64,
}

impl Default for Sha256 {
    fn default() -> Self {
        Self::new()
    }
}

impl Sha256 {
    pub fn new() -> Self {
        Sha256 {
            state: H0,
            buf: [0u8; 64],
            buf_len: 0,
            total: 0,
        }
    }

    pub fn update(&mut self, mut data: &[u8]) {
        self.total = self.total.wrapping_add(data.len() as u64);
        if self.buf_len > 0 {
            let take = (64 - self.buf_len).min(data.len());
            self.buf[self.buf_len..self.buf_len + take].copy_from_slice(&data[..take]);
            self.buf_len += take;
            data = &data[take..];
            if self.buf_len == 64 {
                let block = self.buf;
                self.compress(&block);
                self.buf_len = 0;
            }
        }
        while data.len() >= 64 {
            let (block, rest) = data.split_at(64);
            self.compress(block.try_into().unwrap());
            data = rest;
        }
        if !data.is_empty() {
            self.buf[..data.len()].copy_from_slice(data);
            self.buf_len = data.len();
        }
    }

    pub fn finalize(mut self) -> [u8; 32] {
        let bit_len = self.total.wrapping_mul(8);
        // 0x80 terminator, zero pad to 56 mod 64, then the bit length
        self.update(&[0x80]);
        while self.buf_len != 56 {
            self.update(&[0]);
        }
        // bypass update(): the length bytes must not count toward total
        self.buf[56..64].copy_from_slice(&bit_len.to_be_bytes());
        let block = self.buf;
        self.compress(&block);
        let mut out = [0u8; 32];
        for (i, w) in self.state.iter().enumerate() {
            out[4 * i..4 * i + 4].copy_from_slice(&w.to_be_bytes());
        }
        out
    }

    fn compress(&mut self, block: &[u8; 64]) {
        let mut w = [0u32; 64];
        for (i, chunk) in block.chunks_exact(4).enumerate() {
            w[i] = u32::from_be_bytes(chunk.try_into().unwrap());
        }
        for i in 16..64 {
            let s0 = w[i - 15].rotate_right(7) ^ w[i - 15].rotate_right(18) ^ (w[i - 15] >> 3);
            let s1 = w[i - 2].rotate_right(17) ^ w[i - 2].rotate_right(19) ^ (w[i - 2] >> 10);
            w[i] = w[i - 16]
                .wrapping_add(s0)
                .wrapping_add(w[i - 7])
                .wrapping_add(s1);
        }
        let [mut a, mut b, mut c, mut d, mut e, mut f, mut g, mut h] = self.state;
        for i in 0..64 {
            let s1 = e.rotate_right(6) ^ e.rotate_right(11) ^ e.rotate_right(25);
            let ch = (e & f) ^ (!e & g);
            let t1 = h
                .wrapping_add(s1)
                .wrapping_add(ch)
                .wrapping_add(K[i])
                .wrapping_add(w[i]);
            let s0 = a.rotate_right(2) ^ a.rotate_right(13) ^ a.rotate_right(22);
            let maj = (a & b) ^ (a & c) ^ (b & c);
            let t2 = s0.wrapping_add(maj);
            h = g;
            g = f;
            f = e;
            e = d.wrapping_add(t1);
            d = c;
            c = b;
            b = a;
            a = t1.wrapping_add(t2);
        }
        for (s, v) in self.state.iter_mut().zip([a, b, c, d, e, f, g, h]) {
            *s = s.wrapping_add(v);
        }
    }
}

/// One-shot digest.
pub fn sha256(data: &[u8]) -> [u8; 32] {
    let mut h = Sha256::new();
    h.update(data);
    h.finalize()
}

/// One-shot digest as lowercase hex — the form manifests record.
pub fn sha256_hex(data: &[u8]) -> String {
    to_hex(&sha256(data))
}

/// Digest a file without loading it into memory (result files can be
/// larger than RAM under `--mem-budget`). Returns `(hex_digest, bytes)`.
pub fn sha256_file(path: impl AsRef<Path>) -> std::io::Result<(String, u64)> {
    let mut f = std::fs::File::open(path)?;
    let mut h = Sha256::new();
    let mut buf = vec![0u8; 64 * 1024];
    let mut total = 0u64;
    loop {
        let n = f.read(&mut buf)?;
        if n == 0 {
            break;
        }
        total += n as u64;
        h.update(&buf[..n]);
    }
    Ok((to_hex(&h.finalize()), total))
}

fn to_hex(bytes: &[u8]) -> String {
    let mut s = String::with_capacity(bytes.len() * 2);
    for b in bytes {
        s.push_str(&format!("{b:02x}"));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    // NIST FIPS 180-4 test vectors (plus the million-'a' extension vector)
    #[test]
    fn nist_vectors() {
        assert_eq!(
            sha256_hex(b""),
            "e3b0c44298fc1c149afbf4c8996fb92427ae41e4649b934ca495991b7852b855"
        );
        assert_eq!(
            sha256_hex(b"abc"),
            "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad"
        );
        assert_eq!(
            sha256_hex(b"abcdbcdecdefdefgefghfghighijhijkijkljklmklmnlmnomnopnopq"),
            "248d6a61d20638b8e5c026930c3e6039a33ce45964ff2167f6ecedd419db06c1"
        );
    }

    #[test]
    fn million_a_vector() {
        let mut h = Sha256::new();
        let chunk = [b'a'; 1000];
        for _ in 0..1000 {
            h.update(&chunk);
        }
        assert_eq!(
            to_hex(&h.finalize()),
            "cdc76e5c9914fb9281a1c7e284d73e67f1809a48a497200e046d39ccc7112cd0"
        );
    }

    #[test]
    fn streaming_matches_one_shot_at_every_split() {
        let data: Vec<u8> = (0..257u16).map(|i| (i % 251) as u8).collect();
        let whole = sha256_hex(&data);
        // walk every split point across the 64-byte block boundary
        for cut in 0..data.len() {
            let mut h = Sha256::new();
            h.update(&data[..cut]);
            h.update(&data[cut..]);
            assert_eq!(to_hex(&h.finalize()), whole, "split at {cut}");
        }
    }

    #[test]
    fn padding_edge_lengths() {
        // 55/56/63/64 bytes straddle the one-vs-two padding-block cases;
        // cross-check each against a two-chunk streaming digest
        for len in [55usize, 56, 63, 64, 65, 119, 120, 127, 128] {
            let data = vec![0xabu8; len];
            let whole = sha256_hex(&data);
            let mut h = Sha256::new();
            h.update(&data[..len / 2]);
            h.update(&data[len / 2..]);
            assert_eq!(to_hex(&h.finalize()), whole, "len {len}");
        }
    }

    #[test]
    fn file_digest_matches_memory_digest() {
        let path = std::env::temp_dir().join(format!(
            "molers-hash-test-{}.bin",
            std::process::id()
        ));
        let data: Vec<u8> = (0..100_000u32).map(|i| (i * 31 % 251) as u8).collect();
        std::fs::write(&path, &data).unwrap();
        let (hex, bytes) = sha256_file(&path).unwrap();
        assert_eq!(hex, sha256_hex(&data));
        assert_eq!(bytes, data.len() as u64);
        let _ = std::fs::remove_file(&path);
    }
}
