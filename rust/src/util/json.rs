//! Minimal JSON parser (serde is not vendored in this image — DESIGN.md §3).
//!
//! Supports the full JSON grammar minus unicode escapes beyond BMP; more
//! than enough for `artifacts/manifest.json` and the config files the CLI
//! accepts. Strict: trailing garbage is an error.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if !n.is_finite() {
                    // bare NaN/inf is not JSON: one such value in a
                    // checkpoint line would make the whole journal
                    // unloadable. null parses back as Json::Null, which
                    // strict numeric consumers (parse_f64_arr) reject —
                    // so only that record degrades, never the file.
                    out.push_str("null");
                } else if n.fract() == 0.0 && n.abs() < 1e15 {
                    if *n == 0.0 && n.is_sign_negative() {
                        // `(-0.0) as i64` drops the sign bit; `-0` is
                        // valid JSON and round-trips it — f64 Display
                        // writes "-0" too, so journal-restored values
                        // stay byte-identical to live-written ones
                        out.push_str("-0");
                    } else {
                        out.push_str(&format!("{}", *n as i64));
                    }
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            out.push_str(&format!("\\u{:04x}", c as u32))
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Serialise back to compact JSON text (use via `.to_string()`).
impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut out = String::new();
        self.write(&mut out);
        f.write_str(&out)
    }
}

/// Parse a complete JSON document.
pub fn parse(text: &str) -> Result<Json> {
    let mut p = Parser {
        bytes: text.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters after document"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, message: &str) -> Error {
        Error::Json {
            offset: self.pos,
            message: message.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", b as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{word}`")))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self
                                .bump()
                                .and_then(|c| (c as char).to_digit(16))
                                .ok_or_else(|| self.err("bad \\u escape"))?;
                            code = code * 16 + d;
                        }
                        s.push(
                            char::from_u32(code)
                                .ok_or_else(|| self.err("invalid codepoint"))?,
                        );
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // multi-byte UTF-8: copy the sequence through
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let start = self.pos - 1;
                    let end = (start + len).min(self.bytes.len());
                    self.pos = end;
                    s.push_str(
                        std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("-2.5e2").unwrap(), Json::Num(-250.0));
        assert_eq!(parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn writes_non_finite_as_null_and_keeps_negative_zero() {
        // bare NaN/inf would corrupt a JSONL journal line; null degrades
        // only the one record (strict numeric parsers reject it)
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
        assert_eq!(Json::Num(f64::NEG_INFINITY).to_string(), "null");
        // -0.0 must survive the integer fast path with its sign bit —
        // byte-identical resume depends on journal == live formatting
        assert_eq!(Json::Num(-0.0).to_string(), "-0");
        assert_eq!(Json::Num(0.0).to_string(), "0");
        let back = parse("-0").unwrap().as_f64().unwrap();
        assert_eq!(back.to_bits(), (-0.0f64).to_bits(), "sign bit round-trips");
    }

    #[test]
    fn parses_nested() {
        let doc = r#"{"a": [1, 2, {"b": "x"}], "c": {"d": false}}"#;
        let v = parse(doc).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("c").unwrap().get("d").unwrap(),
            &Json::Bool(false)
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("nul").is_err());
    }

    #[test]
    fn roundtrips() {
        let doc = r#"{"k":[1,2.5,"s",null,true],"m":{"n":-3}}"#;
        let v = parse(doc).unwrap();
        assert_eq!(parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn parses_real_manifest_shape() {
        let doc = r#"{
          "world": 71, "max_ants": 200, "max_ticks": 1000,
          "batch_sizes": [1, 8, 32],
          "artifacts": {"ants_single": {"file": "ants_single.hlo.txt", "batch": 1,
            "inputs": [["f32", [3]], ["u32", []]]}}
        }"#;
        let v = parse(doc).unwrap();
        assert_eq!(v.get("world").unwrap().as_usize(), Some(71));
        let a = v.get("artifacts").unwrap().get("ants_single").unwrap();
        assert_eq!(a.get("file").unwrap().as_str(), Some("ants_single.hlo.txt"));
    }

    #[test]
    fn unicode_strings() {
        assert_eq!(
            parse("\"héllo ✓\"").unwrap(),
            Json::Str("héllo ✓".into())
        );
        assert_eq!(parse("\"\\u0041\"").unwrap(), Json::Str("A".into()));
    }
}
