//! Workflow execution engine (the "mole execution") and the MoleDSL v2
//! [`Experiment`] front door every launcher subcommand and example builds
//! on.

pub mod experiment;
mod scheduler;

pub use experiment::{
    single_environment, DirectSampling, EnvSpec, Experiment, ExperimentReport,
    ExplorationMethod, IslandEvolution, MethodCtx, MethodOutcome, Nsga2Evolution,
    Replication, SingleRun, ENV_NAMES,
};
pub use scheduler::{ExecutionReport, ExecutionResult, MoleExecution};
