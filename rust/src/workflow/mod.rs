//! Workflow execution engine (the "mole execution").

mod scheduler;

pub use scheduler::{ExecutionReport, ExecutionResult, MoleExecution};
