//! The mole execution engine: runs a [`Puzzle`] by propagating dataflow
//! through its transitions, delegating every task run to an
//! [`Environment`].
//!
//! Fan-out/fan-in bookkeeping uses *tickets*, as in OpenMOLE: every work
//! item carries a ticket; an explore transition mints a fresh group ticket
//! and one child per sample; an aggregate transition collects all items
//! whose nearest group ancestor matches, then resumes with the group's
//! parent ticket. Nested explorations compose naturally.

use std::collections::{HashMap, VecDeque};
use std::sync::Arc;
use std::time::Duration;

use crate::core::Context;
use crate::dsl::puzzle::{CapsuleId, Puzzle, Transition};
use crate::environment::{Environment, Job, JobHandle, JobReport};
use crate::error::{Error, Result};
use crate::exploration::matrix::SampleMatrix;
use crate::util::Rng;

/// A context waiting to run at a capsule.
struct WorkItem {
    capsule: CapsuleId,
    ctx: Context,
    ticket: u64,
    virtual_release: f64,
}

/// A columnar exploration being streamed into work items (§Exploration
/// tentpole): the design lives as one flat `f64` matrix, and per-sample
/// contexts are materialised row by row only as submission capacity frees
/// up — on the *fan-out* side a 200k-point sweep holds the matrix plus
/// the in-flight window, never 200k queued [`Context`] clones. (The
/// fan-*in* side still accumulates one result context per completed row
/// in the aggregation barrier/outputs — for matrix-in/matrix-out sweeps
/// at full scale use [`crate::exploration::Sweep`], which never leaves
/// columnar form.) Context-only samplings keep the historical
/// materialise-everything path.
struct PendingExplore {
    to: CapsuleId,
    base: Context,
    matrix: SampleMatrix,
    next_row: usize,
    group: u64,
    virtual_release: f64,
}

/// Mint the next child work item of the front streamed exploration.
fn next_streamed(
    pending: &mut VecDeque<PendingExplore>,
    tickets: &mut HashMap<u64, TicketInfo>,
    next_ticket: &mut u64,
) -> Option<WorkItem> {
    let p = pending.front_mut()?;
    let ctx = p.matrix.context_row(p.next_row, &p.base);
    let child = *next_ticket;
    *next_ticket += 1;
    tickets.insert(
        child,
        TicketInfo {
            parent: p.group,
            is_group: false,
        },
    );
    let item = WorkItem {
        capsule: p.to,
        ctx,
        ticket: child,
        virtual_release: p.virtual_release,
    };
    p.next_row += 1;
    if p.next_row == p.matrix.len() {
        pending.pop_front();
    }
    Some(item)
}

#[derive(Clone, Copy)]
struct TicketInfo {
    parent: u64,
    is_group: bool,
}

struct Barrier {
    expected: usize,
    members: Vec<Context>,
    max_virtual_end: f64,
    resume_ticket: u64,
}

/// Summary of one workflow execution.
#[derive(Debug, Clone, Default)]
pub struct ExecutionReport {
    pub jobs: u64,
    /// Max virtual completion time across all jobs (simulated makespan).
    pub virtual_makespan: f64,
    /// Sum of real execution durations.
    pub real_cpu: Duration,
    /// Real wall-clock of the whole execution.
    pub wall: Duration,
}

/// Terminal outputs plus execution metrics.
pub struct ExecutionResult {
    pub outputs: Vec<Context>,
    pub report: ExecutionReport,
}

/// Executes puzzles. `start(...)` consumes one initial context and runs the
/// graph to quiescence.
pub struct MoleExecution {
    puzzle: Puzzle,
    default_env: Arc<dyn Environment>,
    rng: Rng,
    /// Max jobs in flight at once (backpressure towards environments).
    pub max_in_flight: usize,
}

impl MoleExecution {
    pub fn new(puzzle: Puzzle, default_env: Arc<dyn Environment>, seed: u64) -> Self {
        MoleExecution {
            puzzle,
            default_env,
            rng: Rng::new(seed),
            max_in_flight: 4096,
        }
    }

    /// Run the puzzle over a brokered fleet of environments, e.g.
    /// `"local:4,pbs:32,egi:biomed:2000"` (the CLI's `--envs` flag). The
    /// broker becomes the default environment: capsule-level environment
    /// overrides still win, but everything else is dispatched, re-routed
    /// on failure and speculatively resubmitted by
    /// [`crate::broker::Broker`].
    pub fn with_envs(
        puzzle: Puzzle,
        spec: &str,
        pool: Arc<crate::exec::ThreadPool>,
        seed: u64,
    ) -> Result<Self> {
        let broker = crate::broker::Broker::from_spec(spec, pool, seed)?;
        Ok(Self::new(puzzle, Arc::new(broker), seed))
    }

    /// Run with an empty initial context.
    pub fn start(self) -> Result<ExecutionResult> {
        self.start_with(Context::new())
    }

    /// Run the puzzle to completion. Validation (shape + typed dataflow,
    /// with `init`'s variables counting as supplied) runs first, so a
    /// mis-wired puzzle is rejected before any job is submitted.
    pub fn start_with(mut self, init: Context) -> Result<ExecutionResult> {
        self.puzzle.validate_with(&init)?;
        let wall_start = std::time::Instant::now();

        let mut tickets: HashMap<u64, TicketInfo> = HashMap::new();
        let mut next_ticket: u64 = 1;
        tickets.insert(0, TicketInfo { parent: 0, is_group: false });

        let mut queue: VecDeque<WorkItem> = VecDeque::new();
        let mut pending: VecDeque<PendingExplore> = VecDeque::new();
        let mut in_flight: Vec<(WorkItem, JobHandle)> = Vec::new();
        let mut barriers: HashMap<(usize, u64), Barrier> = HashMap::new();
        let mut group_size: HashMap<u64, usize> = HashMap::new();
        let mut outputs: Vec<Context> = Vec::new();
        let mut report = ExecutionReport::default();

        queue.push_back(WorkItem {
            capsule: self.puzzle.entry_capsule(),
            ctx: init,
            ticket: 0,
            virtual_release: 0.0,
        });

        while !queue.is_empty() || !pending.is_empty() || !in_flight.is_empty() {
            // submit as much as backpressure allows: queued items first,
            // then rows streamed from columnar explorations
            while in_flight.len() < self.max_in_flight {
                let next = queue.pop_front().or_else(|| {
                    next_streamed(&mut pending, &mut tickets, &mut next_ticket)
                });
                let Some(mut item) = next else { break };
                let capsule = &self.puzzle.capsules[item.capsule.0];
                // sources run on the coordinator, just before delegation
                for source in &capsule.sources {
                    let injected = source.inject(&item.ctx)?;
                    item.ctx.merge(&injected);
                }
                let env = capsule
                    .environment
                    .as_ref()
                    .unwrap_or(&self.default_env)
                    .clone();
                let job = Job::new(Arc::clone(&capsule.task), item.ctx.clone())
                    .released_at(item.virtual_release);
                let handle = env.submit(job);
                in_flight.push((item, handle));
            }

            // poll running jobs
            let mut completed: Vec<(WorkItem, Context, JobReport)> = Vec::new();
            let mut idx = 0;
            while idx < in_flight.len() {
                match in_flight[idx].1.try_wait() {
                    Some(Ok((ctx, job_report))) => {
                        let (item, _) = in_flight.swap_remove(idx);
                        completed.push((item, ctx, job_report));
                    }
                    Some(Err(e)) => return Err(e),
                    None => idx += 1,
                }
            }
            if completed.is_empty() && !in_flight.is_empty() {
                std::thread::sleep(Duration::from_micros(200));
            }

            for (item, out_ctx, job_report) in completed {
                report.jobs += 1;
                report.real_cpu += job_report.real_exec;
                if job_report.virtual_end > report.virtual_makespan {
                    report.virtual_makespan = job_report.virtual_end;
                }

                // dataflow result visible downstream: inputs ∪ outputs
                let mut merged = item.ctx.clone();
                merged.merge(&out_ctx);

                // hooks observe the merged context
                for hook in &self.puzzle.capsules[item.capsule.0].hooks {
                    hook.process(&merged)?;
                }

                if self.puzzle.is_terminal(item.capsule) {
                    outputs.push(merged.clone());
                    continue;
                }

                let transitions: Vec<usize> = self
                    .puzzle
                    .transitions
                    .iter()
                    .enumerate()
                    .filter(|(_, t)| t.from() == item.capsule)
                    .map(|(i, _)| i)
                    .collect();

                for t_idx in transitions {
                    match &self.puzzle.transitions[t_idx] {
                        Transition::Direct { to, .. } => {
                            queue.push_back(WorkItem {
                                capsule: *to,
                                ctx: merged.clone(),
                                ticket: item.ticket,
                                virtual_release: job_report.virtual_end,
                            });
                        }
                        Transition::Explore { to, sampling, .. } => {
                            let group = next_ticket;
                            next_ticket += 1;
                            tickets.insert(
                                group,
                                TicketInfo { parent: item.ticket, is_group: true },
                            );
                            if sampling.is_columnar() {
                                // stream: keep the design columnar, mint
                                // child contexts only at submission time
                                let mut matrix =
                                    SampleMatrix::new(sampling.columns());
                                sampling.sample_into(&mut matrix, &mut self.rng)?;
                                if matrix.is_empty() {
                                    return Err(Error::InvalidWorkflow(format!(
                                        "sampling `{}` produced no samples",
                                        sampling.name()
                                    )));
                                }
                                group_size.insert(group, matrix.len());
                                pending.push_back(PendingExplore {
                                    to: *to,
                                    base: merged.clone(),
                                    matrix,
                                    next_row: 0,
                                    group,
                                    virtual_release: job_report.virtual_end,
                                });
                            } else {
                                let samples =
                                    sampling.sample(&merged, &mut self.rng);
                                group_size.insert(group, samples.len());
                                if samples.is_empty() {
                                    return Err(Error::InvalidWorkflow(format!(
                                        "sampling `{}` produced no samples",
                                        sampling.name()
                                    )));
                                }
                                for s in samples {
                                    let child = next_ticket;
                                    next_ticket += 1;
                                    tickets.insert(
                                        child,
                                        TicketInfo { parent: group, is_group: false },
                                    );
                                    queue.push_back(WorkItem {
                                        capsule: *to,
                                        ctx: s,
                                        ticket: child,
                                        virtual_release: job_report.virtual_end,
                                    });
                                }
                            }
                        }
                        Transition::Aggregate { to, .. } => {
                            // nearest enclosing group of this item's ticket
                            let group = nearest_group(&tickets, item.ticket)
                                .ok_or_else(|| {
                                    Error::InvalidWorkflow(
                                        "aggregate reached without an enclosing \
                                         exploration"
                                            .into(),
                                    )
                                })?;
                            let expected = *group_size.get(&group).unwrap_or(&0);
                            let resume_ticket = tickets[&group].parent;
                            let barrier = barriers
                                .entry((t_idx, group))
                                .or_insert_with(|| Barrier {
                                    expected,
                                    members: Vec::new(),
                                    max_virtual_end: 0.0,
                                    resume_ticket,
                                });
                            barrier.members.push(merged.clone());
                            if job_report.virtual_end > barrier.max_virtual_end {
                                barrier.max_virtual_end = job_report.virtual_end;
                            }
                            if barrier.members.len() == barrier.expected {
                                let barrier = barriers.remove(&(t_idx, group)).unwrap();
                                let agg = Context::aggregate(&barrier.members);
                                queue.push_back(WorkItem {
                                    capsule: *to,
                                    ctx: agg,
                                    ticket: barrier.resume_ticket,
                                    virtual_release: barrier.max_virtual_end,
                                });
                            }
                        }
                    }
                }
            }
        }

        if !barriers.is_empty() {
            return Err(Error::InvalidWorkflow(
                "execution finished with unfilled aggregation barriers".into(),
            ));
        }

        report.wall = wall_start.elapsed();
        Ok(ExecutionResult { outputs, report })
    }
}

fn nearest_group(tickets: &HashMap<u64, TicketInfo>, mut t: u64) -> Option<u64> {
    loop {
        let info = tickets.get(&t)?;
        if info.is_group {
            return Some(t);
        }
        if t == 0 {
            return None;
        }
        t = info.parent;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{val_f64, val_u32};
    use crate::dsl::builder::PuzzleBuilder;
    use crate::dsl::hook::CaptureHook;
    use crate::dsl::task::{ClosureTask, IdentityTask};
    use crate::environment::local::LocalEnvironment;
    use crate::exploration::sampling::{Factor, FullFactorial, SeedSampling};

    fn local() -> Arc<dyn Environment> {
        Arc::new(LocalEnvironment::new(4))
    }

    #[test]
    fn single_task_workflow() {
        let x = val_f64("x");
        let y = val_f64("y");
        let b = PuzzleBuilder::new();
        b.task(
            ClosureTask::new("sq", {
                let (x, y) = (x.clone(), y.clone());
                move |ctx| Ok(Context::new().with(&y, ctx.get(&x)?.powi(2)))
            })
            .input(&x)
            .output(&y)
            .default(&x, 5.0),
        );
        let result = MoleExecution::new(b.build().unwrap(), local(), 1)
            .start()
            .unwrap();
        assert_eq!(result.outputs.len(), 1);
        assert_eq!(result.outputs[0].get(&y).unwrap(), 25.0);
    }

    #[test]
    fn explore_aggregate_roundtrip() {
        // entry -< model (x^2) >- collect
        let x = val_f64("x");
        let y = val_f64("y");
        let b = PuzzleBuilder::new();
        let entry = b.task(IdentityTask::new("entry"));
        let model = b.task(
            ClosureTask::new("sq", {
                let (x, y) = (x.clone(), y.clone());
                move |ctx| Ok(Context::new().with(&y, ctx.get(&x)?.powi(2)))
            })
            .input(&x)
            .output(&y),
        );
        let collect = b.task(IdentityTask::new("collect"));
        let sampling = FullFactorial::new(vec![Factor::new(&x, 0.0, 3.0, 1.0)]);
        entry.explore(Arc::new(sampling), &model).aggregate(&collect);

        let result = MoleExecution::new(b.build().unwrap(), local(), 2)
            .start()
            .unwrap();
        assert_eq!(result.outputs.len(), 1);
        let mut ys = result.outputs[0].get(&y.array()).unwrap();
        ys.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(ys, vec![0.0, 1.0, 4.0, 9.0]);
        assert_eq!(result.report.jobs, 2 + 4); // entry + 4 models + collect
    }

    #[test]
    fn hooks_fire_per_job() {
        let seed = val_u32("seed");
        let b = PuzzleBuilder::new();
        let entry = b.task(IdentityTask::new("entry"));
        let model = b.task(IdentityTask::new("model"));
        let done = b.task(IdentityTask::new("done"));
        let capture = Arc::new(CaptureHook::new());
        model.hook(capture.clone());
        entry.explore(Arc::new(SeedSampling::new(&seed, 5)), &model);
        model.aggregate(&done);
        MoleExecution::new(b.build().unwrap(), local(), 3)
            .start()
            .unwrap();
        assert_eq!(capture.len(), 5);
    }

    #[test]
    fn nested_exploration() {
        // entry -< mid -< leaf >- inner_agg >- outer_agg
        let a = val_f64("a");
        let b = val_f64("b");
        let builder = PuzzleBuilder::new();
        let entry = builder.task(IdentityTask::new("entry"));
        let mid = builder.task(IdentityTask::new("mid"));
        let leaf = builder.task(IdentityTask::new("leaf"));
        let inner_agg = builder.task(IdentityTask::new("inner_agg"));
        let outer_agg = builder.task(IdentityTask::new("outer_agg"));
        entry.explore(
            Arc::new(FullFactorial::new(vec![Factor::new(&a, 0.0, 1.0, 1.0)])),
            &mid,
        );
        mid.explore(
            Arc::new(FullFactorial::new(vec![Factor::new(&b, 0.0, 2.0, 1.0)])),
            &leaf,
        );
        leaf.aggregate(&inner_agg).aggregate(&outer_agg);
        let result = MoleExecution::new(builder.build().unwrap(), local(), 4)
            .start()
            .unwrap();
        assert_eq!(result.outputs.len(), 1);
        // outer aggregation: 2 inner results, each an array of 3 b values
        let bs = result.outputs[0].get(&b.array().array()).unwrap();
        assert_eq!(bs.len(), 2);
        assert_eq!(bs[0].len(), 3);
    }

    #[test]
    fn direct_chain_propagates_virtual_time() {
        let builder = PuzzleBuilder::new();
        let a = builder.task(IdentityTask::new("a"));
        let b = builder.task(IdentityTask::new("b"));
        let c = builder.task(IdentityTask::new("c"));
        a.then(&b).then(&c);
        let result = MoleExecution::new(builder.build().unwrap(), local(), 5)
            .start()
            .unwrap();
        assert_eq!(result.report.jobs, 3);
        assert_eq!(result.outputs.len(), 1);
    }

    #[test]
    fn brokered_default_env_runs_exploration() {
        // same workflow as explore_aggregate_roundtrip, but the default
        // environment is a broker over two local backends sharing a pool
        let x = val_f64("x");
        let y = val_f64("y");
        let b = PuzzleBuilder::new();
        let entry = b.task(IdentityTask::new("entry"));
        let model = b.task(
            ClosureTask::new("sq", {
                let (x, y) = (x.clone(), y.clone());
                move |ctx| Ok(Context::new().with(&y, ctx.get(&x)?.powi(2)))
            })
            .input(&x)
            .output(&y),
        );
        let collect = b.task(IdentityTask::new("collect"));
        let sampling = FullFactorial::new(vec![Factor::new(&x, 0.0, 3.0, 1.0)]);
        entry.explore(Arc::new(sampling), &model).aggregate(&collect);

        let pool = Arc::new(crate::exec::ThreadPool::new(2));
        let exec =
            MoleExecution::with_envs(b.build().unwrap(), "local:2,local:2", pool, 2)
                .unwrap();
        let result = exec.start().unwrap();
        assert_eq!(result.outputs.len(), 1);
        let mut ys = result.outputs[0].get(&y.array()).unwrap();
        ys.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(ys, vec![0.0, 1.0, 4.0, 9.0]);
    }

    #[test]
    fn streamed_columnar_explore_respects_backpressure() {
        // a 100-row columnar exploration with only 4 submission slots:
        // contexts are minted row by row as capacity frees up, and the
        // aggregate still sees every sample exactly once
        let x = val_f64("x");
        let y = val_f64("y");
        let b = PuzzleBuilder::new();
        let entry = b.task(IdentityTask::new("entry"));
        let model = b.task(
            ClosureTask::new("double", {
                let (x, y) = (x.clone(), y.clone());
                move |ctx| Ok(Context::new().with(&y, ctx.get(&x)? * 2.0))
            })
            .input(&x)
            .output(&y),
        );
        let collect = b.task(IdentityTask::new("collect"));
        entry.explore(
            Arc::new(FullFactorial::new(vec![Factor::new(&x, 1.0, 100.0, 1.0)])),
            &model,
        );
        model.aggregate(&collect);
        let mut exec = MoleExecution::new(b.build().unwrap(), local(), 9);
        exec.max_in_flight = 4;
        let result = exec.start().unwrap();
        assert_eq!(result.report.jobs, 2 + 100);
        let mut ys = result.outputs[0].get(&y.array()).unwrap();
        ys.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(ys.len(), 100);
        assert_eq!(ys[0], 2.0);
        assert_eq!(ys[99], 200.0);
    }

    #[test]
    fn context_only_explore_still_materialises() {
        use crate::exploration::sampling::ExplicitSampling;
        let x = val_f64("x");
        let b = PuzzleBuilder::new();
        let entry = b.task(IdentityTask::new("entry"));
        let model = b.task(IdentityTask::new("model"));
        let collect = b.task(IdentityTask::new("collect"));
        let samples = ExplicitSampling::new(vec![
            Context::new().with(&x, 1.0),
            Context::new().with(&x, 2.0),
            Context::new().with(&x, 3.0),
        ]);
        entry.explore(Arc::new(samples), &model).aggregate(&collect);
        let result = MoleExecution::new(b.build().unwrap(), local(), 10)
            .start()
            .unwrap();
        let mut xs = result.outputs[0].get(&x.array()).unwrap();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(xs, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn task_failure_aborts() {
        let b = PuzzleBuilder::new();
        b.task(ClosureTask::new("bad", |_| {
            Err(Error::TaskFailed {
                task: "bad".into(),
                message: "expected".into(),
            })
        }));
        assert!(MoleExecution::new(b.build().unwrap(), local(), 6)
            .start()
            .is_err());
    }
}
