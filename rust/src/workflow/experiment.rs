//! MoleDSL v2's single experiment entry point.
//!
//! An [`Experiment`] is *model + exploration method + environments +
//! journal* — the declarative description PaPaS argues a parameter study
//! should be, with the framework deriving the execution. Every `molers`
//! subcommand and example constructs one of these instead of hand-wiring
//! environment construction, journal creation, resume validation and
//! engine plumbing (which previously existed in four inconsistent copies
//! in `main.rs`).
//!
//! The [`ExplorationMethod`] trait packages each engine —
//! [`DirectSampling`] over [`Sweep`], [`Replication`] over the puzzle
//! scheduler, [`Nsga2Evolution`] over [`GenerationalGA`],
//! [`IslandEvolution`] over [`IslandSteadyGA`], [`SingleRun`] over a
//! one-capsule puzzle — behind one uniform face:
//!
//! * **environments**: a single named environment (unknown names are a
//!   hard error listing the valid ones — a typo must not silently run a
//!   campaign on the laptop), a brokered fleet from an `--envs` spec, or
//!   any prebuilt [`Environment`];
//! * **journal / resume**: the experiment loads the journal, lets the
//!   method validate its `run_start` configuration *before* any output
//!   file is touched, then hands an append journal to the engine;
//! * **reporting**: one [`ExperimentReport`] carrying the method outcome,
//!   environment statistics and the broker (for dispatch reports).

use std::sync::Arc;
use std::time::Duration;

use crate::broker::{
    journal, policy, Broker, Durability, Journal, RetryPolicy, SpeculationConfig,
};
use crate::core::Context;
use crate::dsl::builder::PuzzleBuilder;
use crate::dsl::hook::{ColumnSummary, Hook, RowWriter, TableFormat};
use crate::dsl::task::Task;
use crate::environment::cluster::BatchEnvironment;
use crate::environment::egi::EgiEnvironment;
use crate::environment::local::LocalEnvironment;
use crate::environment::ssh::SshEnvironment;
use crate::environment::{EnvStats, Environment};
use crate::error::{Error, Result};
use crate::evolution::evaluator::Evaluator;
use crate::evolution::generational::{GenerationalGA, Nsga2Config};
use crate::evolution::genome::Individual;
use crate::evolution::island::{IslandConfig, IslandSteadyGA};
use crate::evolution::popmatrix::PopMatrix;
use crate::exec::ThreadPool;
use crate::exploration::replication::replicate;
use crate::exploration::sampling::Sampling;
use crate::exploration::sweep::{ProgressFn, Sweep};
use crate::util::json::Json;
use crate::workflow::MoleExecution;

/// The environment names [`single_environment`] accepts.
pub const ENV_NAMES: &[&str] = &[
    "local", "ssh", "pbs", "slurm", "sge", "oar", "condor", "egi",
];

/// Build one named environment. Unknown names are a **hard error** — a
/// typo'd `--env` must not quietly fall back to running the campaign on
/// the local machine.
pub fn single_environment(
    name: &str,
    nodes: usize,
    pool: Arc<ThreadPool>,
    seed: u64,
) -> Result<Arc<dyn Environment>> {
    Ok(match name {
        "local" => Arc::new(LocalEnvironment::with_pool(pool)),
        "ssh" => Arc::new(SshEnvironment::new("calc01", nodes, pool, seed)),
        "pbs" => Arc::new(BatchEnvironment::pbs(nodes, pool, seed)),
        "slurm" => Arc::new(BatchEnvironment::slurm(nodes, pool, seed)),
        "sge" => Arc::new(BatchEnvironment::sge(nodes, pool, seed)),
        "oar" => Arc::new(BatchEnvironment::oar(nodes, pool, seed)),
        "condor" => Arc::new(BatchEnvironment::condor(nodes, pool, seed)),
        "egi" => Arc::new(EgiEnvironment::new("biomed", nodes, pool, seed)),
        other => {
            return Err(Error::Config(format!(
                "unknown environment `{other}` — valid names: {}",
                ENV_NAMES.join(", ")
            )))
        }
    })
}

/// Where an experiment runs.
#[derive(Clone)]
pub enum EnvSpec {
    /// One named environment (`--env NAME`, `--nodes N`).
    Single { name: String, nodes: usize },
    /// A brokered fleet (`--envs local:8,pbs:32~0.2`, `--policy`,
    /// `--speculate`, `--timeout`/`--max-retries`/`--backoff`).
    Fleet {
        spec: String,
        policy: String,
        speculate: bool,
        /// Retry/deadline overrides; `None` keeps [`RetryPolicy::default`].
        retry: Option<RetryPolicy>,
    },
    /// Any prebuilt environment (examples, tests, custom brokers).
    Provided(Arc<dyn Environment>),
}

impl Default for EnvSpec {
    fn default() -> Self {
        EnvSpec::Single {
            name: "local".into(),
            nodes: 8,
        }
    }
}

/// Everything a method needs to run: the environment, an open journal
/// (append-positioned on resume), the loaded resume records (already
/// validated by [`ExplorationMethod::validate_resume`]) and the seed.
pub struct MethodCtx<'a> {
    pub env: Arc<dyn Environment>,
    pub journal: Option<Arc<Journal>>,
    pub resume: Option<&'a [Json]>,
    pub seed: u64,
    /// Incremental completion observer (`molers serve` streams these to
    /// watching clients). Methods report their natural unit of progress:
    /// sweeps report rows, evolutions report generations or evaluations.
    pub progress: Option<ProgressFn>,
}

/// What a method produced — the union of the engines' results; fields a
/// method does not populate stay at their defaults.
#[derive(Default)]
pub struct MethodOutcome {
    pub evaluations: u64,
    pub virtual_makespan: f64,
    /// Jobs executed through the workflow scheduler (puzzle methods).
    pub jobs: u64,
    /// Islands merged / generations run, when the engine counts them.
    pub generations: u32,
    pub pareto_front: Vec<Individual>,
    /// Terminal workflow outputs (puzzle methods).
    pub outputs: Vec<Context>,
    /// Sweep bookkeeping.
    pub rows: usize,
    pub evaluated: usize,
    pub resumed: usize,
    /// Rows that exhausted their retry budget and carry NaN objectives
    /// (`--degraded-ok`), ascending.
    pub degraded: Vec<usize>,
    /// Result file, when the method streams one.
    pub result_path: Option<String>,
    /// High-water mark of resident row-storage bytes (sweep methods; 0
    /// when the method does not track it).
    pub peak_resident_bytes: u64,
    /// Per-column streaming summary of the result file (sweep methods).
    pub column_stats: Vec<ColumnSummary>,
}

impl MethodOutcome {
    /// `"complete"` when every row carries real results, `"degraded"`
    /// when some rows exhausted their retry budget under `--degraded-ok`.
    pub fn outcome(&self) -> &'static str {
        if self.degraded.is_empty() {
            "complete"
        } else {
            "degraded"
        }
    }
}

/// One engine behind the uniform experiment face.
pub trait ExplorationMethod {
    fn name(&self) -> &'static str;

    /// One-line description printed before the run (evaluator backend,
    /// sampling, ...). Empty = print nothing.
    fn describe(&self) -> String {
        String::new()
    }

    /// Whether this method writes checkpoints into a journal. When
    /// false, [`Experiment::run`] refuses a `--journal` request instead
    /// of truncating a file the method would never write to (the user
    /// would otherwise believe the run is checkpointed).
    fn supports_journal(&self) -> bool {
        false
    }

    /// Validate a `--resume` journal's records against this method's
    /// configuration. Runs before any journal is opened for append and
    /// before any output file is touched, so a refused resume never
    /// destroys previous results. The default refuses: resuming a method
    /// that cannot restore state would silently restart it.
    fn validate_resume(&self, records: &[Json], seed: u64, path: &str) -> Result<()> {
        let _ = (records, seed);
        Err(Error::Config(format!(
            "`{}` does not support --resume (journal `{path}`)",
            self.name()
        )))
    }

    fn run(&self, ctx: MethodCtx<'_>) -> Result<MethodOutcome>;
}

/// Report of one experiment run.
pub struct ExperimentReport {
    pub outcome: MethodOutcome,
    pub env_name: String,
    pub env_stats: EnvStats,
    /// The broker, when the experiment built one from a fleet spec.
    pub broker: Option<Arc<Broker>>,
    pub wall: Duration,
}

/// The single entry point: model + method + environments + journal.
pub struct Experiment {
    method: Box<dyn ExplorationMethod>,
    env: EnvSpec,
    journal: Option<String>,
    resume: Option<String>,
    durability: Durability,
    seed: u64,
    quiet: bool,
    progress: Option<ProgressFn>,
}

impl Experiment {
    pub fn new(method: Box<dyn ExplorationMethod>) -> Self {
        Experiment {
            method,
            env: EnvSpec::default(),
            journal: None,
            resume: None,
            durability: Durability::Os,
            seed: 42,
            quiet: false,
            progress: None,
        }
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn env(mut self, env: EnvSpec) -> Self {
        self.env = env;
        self
    }

    /// Run on a prebuilt environment (shorthand for
    /// [`EnvSpec::Provided`]).
    pub fn on(mut self, env: Arc<dyn Environment>) -> Self {
        self.env = EnvSpec::Provided(env);
        self
    }

    /// Checkpoint to a fresh journal at `path`.
    pub fn journal(mut self, path: impl Into<String>) -> Self {
        self.journal = Some(path.into());
        self
    }

    /// Resume from the journal at `path` (validated against the method's
    /// configuration, then appended to).
    pub fn resume(mut self, path: impl Into<String>) -> Self {
        self.resume = Some(path.into());
        self
    }

    /// How eagerly checkpoint records reach stable storage (see
    /// [`Durability`]). Default: [`Durability::Os`] — the historical
    /// behaviour, flush-to-OS per record.
    pub fn durability(mut self, d: Durability) -> Self {
        self.durability = d;
        self
    }

    /// Suppress the description line (library/tests use).
    pub fn quiet(mut self) -> Self {
        self.quiet = true;
        self
    }

    /// Observe incremental completion (`(done, total)` in the method's
    /// natural unit — see [`MethodCtx::progress`]).
    pub fn on_progress(mut self, f: ProgressFn) -> Self {
        self.progress = Some(f);
        self
    }

    /// The resolved environment specification — what a provenance
    /// manifest records so `molers reexec` can rebuild the same fleet.
    pub fn env_spec(&self) -> &EnvSpec {
        &self.env
    }

    /// The effective seed (`--seed` or the default) — recorded in the
    /// provenance manifest and re-injected verbatim at reexec time.
    pub fn seed_value(&self) -> u64 {
        self.seed
    }

    /// Execute: build the environment, validate + open the journal, run
    /// the method, collect the report.
    pub fn run(&self) -> Result<ExperimentReport> {
        let (env, broker): (Arc<dyn Environment>, Option<Arc<Broker>>) = match &self.env
        {
            EnvSpec::Single { name, nodes } => (
                single_environment(
                    name,
                    *nodes,
                    Arc::new(ThreadPool::default_size()),
                    self.seed,
                )?,
                None,
            ),
            EnvSpec::Fleet {
                spec,
                policy: policy_name,
                speculate,
                retry,
            } => {
                let p = policy::by_name(policy_name).ok_or_else(|| {
                    Error::Config(format!(
                        "unknown --policy `{policy_name}` (roundrobin|least|ewma)"
                    ))
                })?;
                let pool = Arc::new(ThreadPool::default_size());
                let mut builder = Broker::spec_builder(spec, pool, self.seed)?.policy(p);
                if *speculate {
                    builder = builder.speculation(SpeculationConfig::default());
                }
                if let Some(r) = retry {
                    builder = builder.retry(r.clone());
                }
                let broker = Arc::new(builder.build()?);
                (Arc::clone(&broker) as Arc<dyn Environment>, Some(broker))
            }
            EnvSpec::Provided(e) => (Arc::clone(e), None),
        };

        if !self.quiet {
            let d = self.method.describe();
            if !d.is_empty() {
                println!("{d}, environment: {}", env.name());
            }
        }

        if self.journal.is_some() && !self.method.supports_journal() {
            return Err(Error::Config(format!(
                "`{}` does not write checkpoints — remove --journal",
                self.method.name()
            )));
        }
        if self.journal.is_some() && self.resume.is_some() {
            // silently appending to the resume journal while ignoring the
            // requested one would scatter checkpoints invisibly
            return Err(Error::Config(
                "--journal and --resume are mutually exclusive: a resumed \
                 run appends its checkpoints to the resume journal"
                    .into(),
            ));
        }
        // resume records load + validate BEFORE any journal/output is
        // opened for writing (or the segment history rewritten). Both
        // layouts load: a legacy single-file journal and a rolled
        // multi-segment one (`exp.jsonl`, `exp.1.jsonl`, ...).
        let records: Option<Vec<Json>> = match &self.resume {
            Some(path) => {
                let records = Journal::load_segmented(path)?;
                self.method.validate_resume(&records, self.seed, path)?;
                Some(records)
            }
            None => None,
        };
        let journal = match (&self.resume, &self.journal) {
            (Some(path), _) => {
                // validated: fold a multi-segment history into one
                // compacted snapshot, then append (and keep rolling)
                // from the surviving segment
                Journal::compact_segments(path)?;
                Some(Arc::new(Journal::append_to_rolling(
                    path,
                    self.durability,
                    journal::DEFAULT_ROLL_EVERY,
                )?))
            }
            (None, Some(path)) => Some(Arc::new(Journal::create_rolling(
                path,
                self.durability,
                journal::DEFAULT_ROLL_EVERY,
            )?)),
            (None, None) => None,
        };

        let t0 = std::time::Instant::now();
        let outcome = self.method.run(MethodCtx {
            env: Arc::clone(&env),
            journal,
            resume: records.as_deref(),
            seed: self.seed,
            progress: self.progress.clone(),
        })?;
        Ok(ExperimentReport {
            outcome,
            env_name: env.name().to_string(),
            env_stats: env.stats(),
            broker,
            wall: t0.elapsed(),
        })
    }
}

// ---------------------------------------------------------------------
// the five methods
// ---------------------------------------------------------------------

/// Paper Listing 2: one model execution with explicit parameters, run as
/// a one-capsule puzzle so even `molers run` goes through the DSL and its
/// build-time validation.
pub struct SingleRun {
    pub evaluator: Arc<dyn Evaluator>,
    /// Backend label for the description line ("rust-sim", "pjrt", ...).
    pub kind: String,
    pub population: f64,
    pub diffusion: f64,
    pub evaporation: f64,
    /// Hooks observing the model capsule.
    pub hooks: Vec<Arc<dyn Hook>>,
}

impl ExplorationMethod for SingleRun {
    fn name(&self) -> &'static str {
        "run"
    }

    fn describe(&self) -> String {
        format!("evaluator: {}", self.kind)
    }

    fn run(&self, ctx: MethodCtx<'_>) -> Result<MethodOutcome> {
        use crate::core::{val_f64, val_u32};
        use crate::dsl::task::ClosureTask;

        let g_population = val_f64("gPopulation");
        let g_diffusion = val_f64("gDiffusionRate");
        let g_evaporation = val_f64("gEvaporationRate");
        let seed = val_u32("seed");
        let food = [val_f64("food1"), val_f64("food2"), val_f64("food3")];

        let model = {
            let (gp, gd, ge, s, f) = (
                g_population.clone(),
                g_diffusion.clone(),
                g_evaporation.clone(),
                seed.clone(),
                food.clone(),
            );
            let evaluator = Arc::clone(&self.evaluator);
            ClosureTask::new("ants", move |c: &Context| {
                let fit = evaluator.evaluate(
                    &[c.get(&gp)?, c.get(&gd)?, c.get(&ge)?],
                    c.get(&s)?,
                )?;
                let mut out = Context::new();
                for (fv, v) in f.iter().zip(fit) {
                    out.set(fv, v);
                }
                Ok(out)
            })
            .input(&g_population)
            .input(&g_diffusion)
            .input(&g_evaporation)
            .input(&seed)
            .default(&g_population, self.population)
            .default(&g_diffusion, self.diffusion)
            .default(&g_evaporation, self.evaporation)
            .default(&seed, ctx.seed as u32)
            .output(&food[0])
            .output(&food[1])
            .output(&food[2])
        };

        let builder = PuzzleBuilder::new();
        let capsule = builder.task(model);
        for h in &self.hooks {
            capsule.hook(Arc::clone(h));
        }
        let progress = ctx.progress.clone();
        let result = MoleExecution::new(builder.build()?, ctx.env, ctx.seed).start()?;
        if let Some(p) = &progress {
            p(1, 1);
        }
        Ok(MethodOutcome {
            evaluations: 1,
            virtual_makespan: result.report.virtual_makespan,
            jobs: result.report.jobs,
            outputs: result.outputs,
            ..MethodOutcome::default()
        })
    }
}

/// §Exploration: a plain design of experiments at scale — the PR-4
/// columnar [`Sweep`] fanned through the environment in chunked
/// `evaluate_rows` jobs, with `sample_block` checkpoints and byte-stable
/// resumable results.
pub struct DirectSampling {
    pub sampling: Arc<dyn Sampling>,
    pub evaluator: Arc<dyn Evaluator>,
    pub kind: String,
    /// Design column names, in sampling order (result file header).
    pub design_columns: Vec<String>,
    pub objective_names: Vec<String>,
    pub chunk: usize,
    pub out_path: String,
    pub format: TableFormat,
    /// Extra `run_start` fields the sampling cannot introspect (bounds,
    /// step, replications) — validated on resume.
    pub meta: Vec<(String, Json)>,
    /// `--degraded-ok`: NaN-fill chunks whose retry budget is exhausted
    /// instead of aborting the campaign.
    pub degraded_ok: bool,
    /// `--retry-degraded`: on resume, re-evaluate restored degraded rows
    /// instead of keeping their NaN placeholders.
    pub retry_degraded: bool,
    /// `--mem-budget`: cap on resident row-storage bytes. Switches the
    /// sweep to the out-of-core streaming engine (chunk-paged objective
    /// spill + block-regenerated design). Deliberately NOT a resume
    /// knob: budgets bound memory, not the design, so a journal written
    /// under any budget (or none) resumes under any other.
    pub mem_budget: Option<u64>,
    /// `--spill-dir`: where the streaming engine pages objective chunks
    /// (default: the system temp dir). Implies streaming mode.
    pub spill_dir: Option<String>,
}

impl DirectSampling {
    /// Numeric design knobs a resume must match: `n` plus every numeric
    /// value in [`DirectSampling::meta`].
    fn resume_knobs(&self) -> Vec<(String, f64)> {
        let mut knobs = vec![(
            "n".to_string(),
            self.sampling.size_hint().unwrap_or(0) as f64,
        )];
        for (k, v) in &self.meta {
            if let Json::Num(x) = v {
                knobs.push((k.clone(), *x));
            }
        }
        knobs
    }
}

impl ExplorationMethod for DirectSampling {
    fn name(&self) -> &'static str {
        "explore"
    }

    fn supports_journal(&self) -> bool {
        true
    }

    fn describe(&self) -> String {
        format!(
            "evaluator: {}, sampling: {} ({} rows, chunk {})",
            self.kind,
            self.sampling.name(),
            self.sampling.size_hint().unwrap_or(0),
            self.chunk
        )
    }

    /// The design regenerates from `(sampling, seed)`: a journal written
    /// under ANY different design knob (sampling kind, seed, n, bounds,
    /// step, replications) describes a different design — reject it up
    /// front, before the output file is touched.
    fn validate_resume(&self, records: &[Json], seed: u64, path: &str) -> Result<()> {
        if let Some(start) = records
            .iter()
            .find(|r| r.get("kind").and_then(|k| k.as_str()) == Some("run_start"))
        {
            if let Some(s) = start.get("sampling").and_then(|v| v.as_str()) {
                if s != self.sampling.name() {
                    return Err(Error::Config(format!(
                        "--resume config mismatch: journal `{path}` was written \
                         with --sampling {s}, this run samples {}",
                        self.sampling.name()
                    )));
                }
            }
            // the 64-bit seed is compared exactly (journaled as a string;
            // an f64 comparison is lossy above 2^53), with a numeric
            // fallback for journals predating seed_exact
            let seed_matches = match start.get("seed_exact").and_then(|v| v.as_str()) {
                Some(exact) => exact == seed.to_string(),
                None => start
                    .get("seed")
                    .and_then(|v| v.as_f64())
                    .is_none_or(|was| was as u64 == seed),
            };
            if !seed_matches {
                return Err(Error::Config(format!(
                    "--resume config mismatch: journal `{path}` was written \
                     under a different --seed than {seed} — the designs \
                     differ, refusing to reuse its blocks"
                )));
            }
            // numeric design knobs recorded at journal creation; a knob
            // absent from an old journal is skipped, a present one must
            // match exactly
            for (key, now) in self.resume_knobs() {
                if let Some(was) = start.get(&key).and_then(|v| v.as_f64()) {
                    if was != now {
                        return Err(Error::Config(format!(
                            "--resume config mismatch: journal `{path}` was \
                             written with {key}={was}, this run has {key}={now} \
                             — the designs differ, refusing to reuse its blocks"
                        )));
                    }
                }
            }
        }
        // events must fit the design this run will generate — checked
        // before the output file is recreated, so a refused resume never
        // destroys previous partial results. Deliberately the SAME parse
        // `run` uses (`journal::sweep_events`): the fit check and the
        // restore must accept exactly the same records, and paying one
        // extra parse at resume startup is nothing next to a divergence
        // that truncates the output file and then rejects a block.
        let expected_rows = self.sampling.size_hint().unwrap_or(0);
        for ev in journal::sweep_events(records) {
            match ev {
                journal::SweepEvent::Block(b) => {
                    if b.first_row + b.objectives.len() > expected_rows
                        || b
                            .objectives
                            .iter()
                            .any(|r| r.len() != self.objective_names.len())
                    {
                        return Err(Error::Config(format!(
                            "--resume journal `{path}` holds a block (rows \
                             {}..{}) that does not fit this {expected_rows}-row \
                             design — refusing to overwrite `{}`",
                            b.first_row,
                            b.first_row + b.objectives.len(),
                            self.out_path
                        )));
                    }
                }
                journal::SweepEvent::Degraded(d) => {
                    if d.rows.iter().any(|&r| r >= expected_rows) {
                        return Err(Error::Config(format!(
                            "--resume journal `{path}` holds degraded rows past \
                             this {expected_rows}-row design — refusing to \
                             overwrite `{}`",
                            self.out_path
                        )));
                    }
                }
            }
        }
        Ok(())
    }

    fn run(&self, ctx: MethodCtx<'_>) -> Result<MethodOutcome> {
        let resume_events = ctx.resume.map(journal::sweep_events);
        if let Some(events) = &resume_events {
            let degraded: usize = events
                .iter()
                .filter(|e| matches!(e, journal::SweepEvent::Degraded(_)))
                .count();
            if degraded > 0 {
                println!(
                    "resuming sweep: {} checkpointed records ({degraded} degraded)",
                    events.len()
                );
            } else {
                println!("resuming sweep: {} checkpointed blocks", events.len());
            }
        }
        let columns: Vec<&str> = self
            .design_columns
            .iter()
            .chain(self.objective_names.iter())
            .map(String::as_str)
            .collect();
        let writer = Arc::new(RowWriter::create(&self.out_path, self.format, &columns)?);
        let objective_names: Vec<&str> =
            self.objective_names.iter().map(String::as_str).collect();
        let mut sweep = Sweep::new(
            Arc::clone(&self.sampling),
            Arc::clone(&self.evaluator),
            &objective_names,
        )
        .chunk(self.chunk)
        .writer(Arc::clone(&writer))
        .degraded_ok(self.degraded_ok)
        .retry_degraded(self.retry_degraded)
        .mem_budget(self.mem_budget)
        .spill_dir(self.spill_dir.clone().map(std::path::PathBuf::from));
        for (k, v) in &self.meta {
            sweep = sweep.meta(k, v.clone());
        }
        if let Some(p) = ctx.progress.clone() {
            sweep = sweep.on_progress(p);
        }
        if let Some(j) = ctx.journal {
            sweep = sweep.journal(j);
        }
        let result =
            sweep.run_resumable(ctx.env.as_ref(), ctx.seed, resume_events.as_deref())?;
        Ok(MethodOutcome {
            evaluations: result.evaluated as u64,
            virtual_makespan: result.virtual_makespan,
            rows: result.rows(),
            evaluated: result.evaluated,
            resumed: result.resumed,
            degraded: result.degraded,
            result_path: Some(self.out_path.clone()),
            peak_resident_bytes: result.peak_resident_bytes,
            column_stats: writer.stats(),
            ..MethodOutcome::default()
        })
    }
}

/// Paper Listing 3 / §4.4: replicate a stochastic model under `n`
/// independent seeds and summarise through a statistic task — the
/// `entry -< model >- statistic` puzzle.
pub struct Replication {
    pub model: Arc<dyn Task>,
    pub seed_val: crate::core::Val<u32>,
    pub replications: usize,
    pub statistic: Arc<dyn Task>,
    pub kind: String,
    pub model_hooks: Vec<Arc<dyn Hook>>,
    pub statistic_hooks: Vec<Arc<dyn Hook>>,
}

impl ExplorationMethod for Replication {
    fn name(&self) -> &'static str {
        "replicate"
    }

    fn describe(&self) -> String {
        format!(
            "evaluator: {}, replications: {}",
            self.kind, self.replications
        )
    }

    fn run(&self, ctx: MethodCtx<'_>) -> Result<MethodOutcome> {
        let builder = PuzzleBuilder::new();
        let (_, model_c, stat_c) = replicate(
            &builder,
            Arc::clone(&self.model),
            &self.seed_val,
            self.replications,
            Arc::clone(&self.statistic),
        );
        for h in &self.model_hooks {
            model_c.hook(Arc::clone(h));
        }
        for h in &self.statistic_hooks {
            stat_c.hook(Arc::clone(h));
        }
        let progress = ctx.progress.clone();
        let result = MoleExecution::new(builder.build()?, ctx.env, ctx.seed).start()?;
        if let Some(p) = &progress {
            p(self.replications as u64, self.replications as u64);
        }
        Ok(MethodOutcome {
            evaluations: self.replications as u64,
            virtual_makespan: result.report.virtual_makespan,
            jobs: result.report.jobs,
            outputs: result.outputs,
            ..MethodOutcome::default()
        })
    }
}

/// Paper Listing 4: generational NSGA-II over the columnar population
/// engine, with journaled bit-identical resume.
pub struct Nsga2Evolution {
    pub config: Nsga2Config,
    pub lambda: usize,
    pub generations: u32,
    pub eval_chunk: usize,
    pub evaluator: Arc<dyn Evaluator>,
    pub kind: String,
    pub on_generation: Option<Arc<dyn Fn(u32, &PopMatrix) + Send + Sync>>,
}

impl ExplorationMethod for Nsga2Evolution {
    fn name(&self) -> &'static str {
        "calibrate"
    }

    fn supports_journal(&self) -> bool {
        true
    }

    fn describe(&self) -> String {
        format!("evaluator: {}", self.kind)
    }

    /// The journal stores the trajectory, not the configuration: a
    /// resumed run with a different `--mu`/`--lambda` would silently
    /// corrupt it, so reject the mismatch up front.
    fn validate_resume(&self, records: &[Json], _seed: u64, path: &str) -> Result<()> {
        if let Some(start) = records
            .iter()
            .find(|r| r.get("kind").and_then(|k| k.as_str()) == Some("run_start"))
        {
            for (key, got) in [("mu", self.config.mu), ("lambda", self.lambda)] {
                if let Some(want) =
                    start.get(key).and_then(|v| v.as_f64()).map(|v| v as usize)
                {
                    if want != got {
                        return Err(Error::Config(format!(
                            "--resume config mismatch: journal `{path}` was \
                             written with --{key} {want}, this run has --{key} \
                             {got}"
                        )));
                    }
                }
            }
        }
        if journal::resume_state(records).is_none() {
            return Err(Error::Config(format!(
                "journal `{path}` holds no generation checkpoint"
            )));
        }
        Ok(())
    }

    fn run(&self, ctx: MethodCtx<'_>) -> Result<MethodOutcome> {
        let resume = ctx.resume.and_then(journal::resume_state);
        if let Some(state) = &resume {
            println!(
                "resuming from generation {} ({} evaluations done)",
                state.generation, state.evaluations
            );
        }
        // the coordinator's own stages (variation, crowding, dominance)
        // fan out over a dedicated pool — never the environment's (whose
        // workers block while the coordinator joins)
        let mut ga = GenerationalGA::new(
            self.config.clone(),
            Arc::clone(&self.evaluator),
            self.lambda,
        )
        .eval_chunk(self.eval_chunk)
        .coordinator_pool(Arc::new(ThreadPool::default_size()));
        if self.on_generation.is_some() || ctx.progress.is_some() {
            let cb = self.on_generation.clone();
            let progress = ctx.progress.clone();
            let total = self.generations as u64;
            ga = ga.on_generation(move |g, pop| {
                if let Some(f) = &cb {
                    f(g, pop);
                }
                if let Some(p) = &progress {
                    p(g as u64, total);
                }
            });
        }
        if let Some(j) = ctx.journal {
            ga = ga.journal(j);
        }
        let result =
            ga.run_resumable(ctx.env.as_ref(), self.generations, ctx.seed, resume)?;
        Ok(MethodOutcome {
            evaluations: result.evaluations,
            virtual_makespan: result.virtual_makespan,
            generations: result.generations,
            pareto_front: result.pareto_front,
            ..MethodOutcome::default()
        })
    }
}

/// Paper Listing 5 + §4.6: the island model — asynchronous steady-state
/// NSGA-II islands merging into a global archive, at grid scale.
pub struct IslandEvolution {
    pub config: Nsga2Config,
    pub islands: IslandConfig,
    pub evaluator: Arc<dyn Evaluator>,
    pub kind: String,
    pub on_island: Option<Arc<dyn Fn(u64, u64) + Send + Sync>>,
}

impl ExplorationMethod for IslandEvolution {
    fn name(&self) -> &'static str {
        "island"
    }

    fn supports_journal(&self) -> bool {
        true
    }

    fn describe(&self) -> String {
        format!("evaluator: {}", self.kind)
    }

    fn validate_resume(&self, records: &[Json], _seed: u64, path: &str) -> Result<()> {
        if journal::island_resume(records).is_none() {
            return Err(Error::Config(format!(
                "journal `{path}` holds no island archive snapshot"
            )));
        }
        Ok(())
    }

    fn run(&self, ctx: MethodCtx<'_>) -> Result<MethodOutcome> {
        let mut ga = IslandSteadyGA::new(
            self.config.clone(),
            self.islands.clone(),
            Arc::clone(&self.evaluator),
        );
        if let Some(records) = ctx.resume {
            // presence was proven by validate_resume
            let (pop, evals) = journal::island_resume(records).ok_or_else(|| {
                Error::Config("resume journal lost its archive snapshot".into())
            })?;
            println!(
                "resuming island archive: {} individuals, {evals} evaluations done",
                pop.len()
            );
            ga = ga.resume_from(pop, evals);
        }
        if let Some(j) = ctx.journal {
            ga = ga.journal(j);
        }
        let on_island: Option<Arc<dyn Fn(u64, u64) + Send + Sync>> =
            if self.on_island.is_some() || ctx.progress.is_some() {
                let cb = self.on_island.clone();
                let progress = ctx.progress.clone();
                let total = self.islands.total_evaluations;
                Some(Arc::new(move |done, evals| {
                    if let Some(f) = &cb {
                        f(done, evals);
                    }
                    if let Some(p) = &progress {
                        p(evals.min(total), total);
                    }
                }))
            } else {
                None
            };
        let result = ga.run(ctx.env.as_ref(), ctx.seed, on_island)?;
        Ok(MethodOutcome {
            evaluations: result.evaluations,
            virtual_makespan: result.virtual_makespan,
            generations: result.generations,
            pareto_front: result.pareto_front,
            ..MethodOutcome::default()
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::val_f64;
    use crate::evolution::evaluator::Zdt1Evaluator;
    use crate::exploration::sampling::LhsSampling;

    fn lhs2(n: usize) -> Arc<dyn Sampling> {
        let x0 = val_f64("x0");
        let x1 = val_f64("x1");
        Arc::new(LhsSampling::new(&[(&x0, 0.0, 1.0), (&x1, 0.0, 1.0)], n))
    }

    fn explore_method(out: &std::path::Path) -> DirectSampling {
        DirectSampling {
            sampling: lhs2(10),
            evaluator: Arc::new(Zdt1Evaluator { dim: 2 }),
            kind: "zdt1".into(),
            design_columns: vec!["x0".into(), "x1".into()],
            objective_names: vec!["f1".into(), "f2".into()],
            chunk: 4,
            out_path: out.to_string_lossy().into_owned(),
            format: TableFormat::Csv,
            meta: vec![
                ("lo".into(), Json::Num(0.0)),
                ("hi".into(), Json::Num(1.0)),
                ("replications".into(), Json::Num(1.0)),
            ],
            degraded_ok: false,
            retry_degraded: false,
            mem_budget: None,
            spill_dir: None,
        }
    }

    #[test]
    fn unknown_environment_is_a_hard_error() {
        let pool = Arc::new(ThreadPool::new(1));
        let err = single_environment("slrum", 4, pool, 1).unwrap_err().to_string();
        assert!(err.contains("unknown environment `slrum`"), "{err}");
        assert!(err.contains("slurm"), "lists valid names: {err}");
    }

    #[test]
    fn experiment_runs_a_sweep_end_to_end() {
        let dir = std::env::temp_dir();
        let out = dir.join(format!("molers-exp-{}.csv", std::process::id()));
        let report = Experiment::new(Box::new(explore_method(&out)))
            .env(EnvSpec::Single {
                name: "local".into(),
                nodes: 2,
            })
            .seed(11)
            .quiet()
            .run()
            .unwrap();
        assert_eq!(report.outcome.rows, 10);
        assert_eq!(report.outcome.evaluated, 10);
        assert_eq!(report.env_stats.completed, report.env_stats.submitted);
        let text = std::fs::read_to_string(&out).unwrap();
        assert_eq!(text.lines().count(), 11, "header + 10 rows");
        let _ = std::fs::remove_file(&out);
    }

    #[test]
    fn experiment_refuses_mismatched_resume_before_touching_output() {
        let dir = std::env::temp_dir();
        let out = dir.join(format!("molers-exp-keep-{}.csv", std::process::id()));
        let journal = dir.join(format!("molers-exp-j-{}.jsonl", std::process::id()));
        std::fs::write(&out, "precious partial results\n").unwrap();
        std::fs::write(
            &journal,
            "{\"kind\":\"run_start\",\"run\":\"explore\",\"seed\":1,\
             \"sampling\":\"Sobol\",\"n\":10}\n",
        )
        .unwrap();
        let err = Experiment::new(Box::new(explore_method(&out)))
            .seed(1)
            .quiet()
            .resume(journal.to_string_lossy().into_owned())
            .run()
            .unwrap_err()
            .to_string();
        assert!(err.contains("config mismatch"), "{err}");
        assert_eq!(
            std::fs::read_to_string(&out).unwrap(),
            "precious partial results\n",
            "refused resume must not touch the output file"
        );
        let _ = std::fs::remove_file(&out);
        let _ = std::fs::remove_file(&journal);
    }

    #[test]
    fn fleet_spec_builds_a_broker() {
        let dir = std::env::temp_dir();
        let out = dir.join(format!("molers-exp-fleet-{}.csv", std::process::id()));
        let report = Experiment::new(Box::new(explore_method(&out)))
            .env(EnvSpec::Fleet {
                spec: "local:2,local:2".into(),
                policy: "roundrobin".into(),
                speculate: false,
                retry: None,
            })
            .seed(3)
            .quiet()
            .run()
            .unwrap();
        assert!(report.broker.is_some());
        assert_eq!(report.outcome.evaluated, 10);
        let _ = std::fs::remove_file(&out);
    }

    #[test]
    fn journal_is_refused_by_methods_that_never_write_one() {
        use crate::evolution::evaluator::AntSimEvaluator;
        let dir = std::env::temp_dir();
        let path = dir.join(format!("molers-exp-nj-{}.jsonl", std::process::id()));
        std::fs::write(&path, "precious existing journal\n").unwrap();
        let err = Experiment::new(Box::new(SingleRun {
            evaluator: Arc::new(AntSimEvaluator::fast()),
            kind: "rust-sim".into(),
            population: 125.0,
            diffusion: 50.0,
            evaporation: 50.0,
            hooks: Vec::new(),
        }))
        .journal(path.to_string_lossy().into_owned())
        .quiet()
        .run()
        .unwrap_err()
        .to_string();
        assert!(err.contains("does not write checkpoints"), "{err}");
        assert_eq!(
            std::fs::read_to_string(&path).unwrap(),
            "precious existing journal\n",
            "refused --journal must not truncate the file"
        );
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn journal_plus_resume_is_rejected() {
        let dir = std::env::temp_dir();
        let out = dir.join(format!("molers-exp-jr-{}.csv", std::process::id()));
        let err = Experiment::new(Box::new(explore_method(&out)))
            .journal("/tmp/new.jsonl")
            .resume("/tmp/old.jsonl")
            .quiet()
            .run()
            .unwrap_err()
            .to_string();
        assert!(err.contains("mutually exclusive"), "{err}");
    }

    #[test]
    fn unknown_policy_is_rejected() {
        let dir = std::env::temp_dir();
        let out = dir.join(format!("molers-exp-pol-{}.csv", std::process::id()));
        let err = Experiment::new(Box::new(explore_method(&out)))
            .env(EnvSpec::Fleet {
                spec: "local:2".into(),
                policy: "fastest".into(),
                speculate: false,
                retry: None,
            })
            .quiet()
            .run()
            .unwrap_err()
            .to_string();
        assert!(err.contains("unknown --policy"), "{err}");
    }
}
