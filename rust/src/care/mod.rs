//! CARE/CDE-style application packaging (paper §3).
//!
//! The paper's §3 problem: shipping an application to heterogeneous remote
//! hosts fails when dependencies are missing or mismatched. Its solution:
//! package the application *with* everything it touched during a probe
//! run (CDE), upgraded to CARE which additionally **emulates missing
//! syscalls** so archives built on new kernels re-execute on old ones.
//!
//! This module reproduces that decision logic as an executable model:
//! dependency capture ([`manifest`]), archive assembly ([`archive`]) and
//! re-execution compatibility checking ([`reexec`]), which the packaging
//! benches (`a3_packaging`) and `SystemExecTask` exercise.

pub mod archive;
pub mod manifest;
pub mod reexec;

pub use archive::Archive;
pub use manifest::{Dependency, DependencyKind, KernelVersion, Manifest};
pub use reexec::{fleet_success_rate, reexecute, Packager, ReexecOutcome, RemoteHost};
