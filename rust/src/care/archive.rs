//! Self-contained re-executable archives ("carballs").
//!
//! A minimal binary container format standing in for CARE's archives: a
//! header, the manifest, and the packed file entries. Implemented from
//! scratch (no tar crate in the image) with enough rigour to round-trip
//! byte-exactly — the property that makes re-execution reproducible.

use crate::care::manifest::{Dependency, DependencyKind, KernelVersion, Manifest};
use crate::error::{Error, Result};

const MAGIC: &[u8; 8] = b"CARBALL1";

/// A packed file entry.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Entry {
    pub path: String,
    pub contents: Vec<u8>,
}

/// An in-memory re-executable archive.
#[derive(Debug, Clone)]
pub struct Archive {
    pub manifest: Manifest,
    pub entries: Vec<Entry>,
    /// CARE mode: ships the syscall-emulation shim (PRoot); CDE mode does
    /// not — the §3.2 distinction.
    pub syscall_emulation: bool,
}

impl Archive {
    /// Pack a manifest: one entry per dependency plus the launcher.
    pub fn pack(manifest: Manifest, syscall_emulation: bool) -> Self {
        let mut entries: Vec<Entry> = manifest
            .dependencies
            .iter()
            .map(|d| Entry {
                path: d.path.clone(),
                contents: synth_contents(d),
            })
            .collect();
        entries.push(Entry {
            path: "./re-execute.sh".into(),
            contents: format!("#!/bin/sh\nexec {}\n", manifest.command).into_bytes(),
        });
        Archive {
            manifest,
            entries,
            syscall_emulation,
        }
    }

    pub fn size_bytes(&self) -> usize {
        self.entries.iter().map(|e| e.path.len() + e.contents.len()).sum()
    }

    /// Serialise to the carball wire format.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.size_bytes() + 256);
        out.extend_from_slice(MAGIC);
        out.push(u8::from(self.syscall_emulation));
        let k = &self.manifest.packaged_on;
        out.extend_from_slice(&[k.0 as u8, k.1 as u8, (k.2 & 0xff) as u8]);
        write_str(&mut out, &self.manifest.application);
        write_str(&mut out, &self.manifest.command);
        out.extend_from_slice(&(self.manifest.dependencies.len() as u32).to_le_bytes());
        for d in &self.manifest.dependencies {
            out.push(match d.kind {
                DependencyKind::SharedLibrary => 0,
                DependencyKind::Interpreter => 1,
                DependencyKind::DataFile => 2,
                DependencyKind::Executable => 3,
            });
            write_str(&mut out, &d.path);
            write_str(&mut out, d.version.as_deref().unwrap_or(""));
        }
        out.extend_from_slice(&(self.entries.len() as u32).to_le_bytes());
        for e in &self.entries {
            write_str(&mut out, &e.path);
            out.extend_from_slice(&(e.contents.len() as u64).to_le_bytes());
            out.extend_from_slice(&e.contents);
        }
        out
    }

    /// Parse the carball wire format.
    pub fn from_bytes(data: &[u8]) -> Result<Self> {
        let mut r = Reader { data, pos: 0 };
        if r.take(8)? != MAGIC {
            return Err(Error::Packaging("bad magic".into()));
        }
        let syscall_emulation = r.take(1)?[0] != 0;
        let kv = r.take(3)?;
        let packaged_on = KernelVersion(kv[0].into(), kv[1].into(), kv[2].into());
        let application = r.string()?;
        let command = r.string()?;
        let mut manifest = Manifest::new(application, command, packaged_on);
        let n_deps = r.u32()?;
        for _ in 0..n_deps {
            let kind = match r.take(1)?[0] {
                0 => DependencyKind::SharedLibrary,
                1 => DependencyKind::Interpreter,
                2 => DependencyKind::DataFile,
                3 => DependencyKind::Executable,
                k => return Err(Error::Packaging(format!("bad dep kind {k}"))),
            };
            let path = r.string()?;
            let version = r.string()?;
            manifest.record(Dependency {
                kind,
                path,
                version: if version.is_empty() { None } else { Some(version) },
            });
        }
        let n_entries = r.u32()?;
        let mut entries = Vec::with_capacity(n_entries as usize);
        for _ in 0..n_entries {
            let path = r.string()?;
            let len = r.u64()? as usize;
            let contents = r.take(len)?.to_vec();
            entries.push(Entry { path, contents });
        }
        Ok(Archive {
            manifest,
            entries,
            syscall_emulation,
        })
    }
}

/// Deterministic stand-in contents for a captured dependency.
fn synth_contents(d: &Dependency) -> Vec<u8> {
    format!(
        "{:?} {} {}",
        d.kind,
        d.path,
        d.version.as_deref().unwrap_or("-")
    )
    .into_bytes()
}

fn write_str(out: &mut Vec<u8>, s: &str) {
    out.extend_from_slice(&(s.len() as u32).to_le_bytes());
    out.extend_from_slice(s.as_bytes());
}

struct Reader<'a> {
    data: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.data.len() {
            return Err(Error::Packaging("truncated archive".into()));
        }
        let s = &self.data[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn string(&mut self) -> Result<String> {
        let len = self.u32()? as usize;
        String::from_utf8(self.take(len)?.to_vec())
            .map_err(|_| Error::Packaging("invalid utf-8 in archive".into()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest() -> Manifest {
        Manifest::new(
            "ants",
            "netlogo-headless.sh --model ants.nlogo",
            KernelVersion(3, 10, 0),
        )
        .with(Dependency::lib("/lib/libc.so.6", "2.17"))
        .with(Dependency::interpreter("/usr/bin/java", "1.8"))
        .with(Dependency::data("/opt/ants.nlogo"))
    }

    #[test]
    fn pack_includes_all_dependencies_and_launcher() {
        let a = Archive::pack(manifest(), true);
        assert_eq!(a.entries.len(), 4);
        assert!(a.entries.iter().any(|e| e.path == "./re-execute.sh"));
    }

    #[test]
    fn wire_format_roundtrips() {
        let a = Archive::pack(manifest(), true);
        let bytes = a.to_bytes();
        let b = Archive::from_bytes(&bytes).unwrap();
        assert_eq!(b.manifest.application, "ants");
        assert_eq!(b.manifest.packaged_on, KernelVersion(3, 10, 0));
        assert_eq!(b.manifest.dependencies, a.manifest.dependencies);
        assert_eq!(b.entries, a.entries);
        assert!(b.syscall_emulation);
    }

    #[test]
    fn detects_corruption() {
        let a = Archive::pack(manifest(), false);
        let mut bytes = a.to_bytes();
        bytes.truncate(bytes.len() / 2);
        assert!(Archive::from_bytes(&bytes).is_err());
        assert!(Archive::from_bytes(b"NOTMAGIC rest").is_err());
    }
}
