//! Re-execution compatibility: the §3 decision logic, executable.
//!
//! * **Bare execution** (no packaging): fails wherever a dependency is
//!   missing or deployed at a different version — including the "silent
//!   error" case the paper warns about (same library, different version,
//!   different results).
//! * **CDE packaging**: dependencies ship with the app, but the archive
//!   only runs on hosts whose kernel is **at least as new** as the
//!   packaging host's (no emulation) — hence §3.1's 2.6.32 rule of thumb.
//! * **CARE packaging**: additionally emulates missing syscalls, so new →
//!   old kernel re-execution succeeds (at a small overhead).

use crate::care::archive::Archive;
use crate::care::manifest::{KernelVersion, Manifest};
use crate::util::Rng;

/// A remote execution host with its own software environment.
#[derive(Debug, Clone)]
pub struct RemoteHost {
    pub name: String,
    pub kernel: KernelVersion,
    /// (path, version) of deployed software; absent path = missing.
    pub deployed: Vec<(String, String)>,
}

impl RemoteHost {
    pub fn new(name: impl Into<String>, kernel: KernelVersion) -> Self {
        RemoteHost {
            name: name.into(),
            kernel,
            deployed: Vec::new(),
        }
    }

    pub fn with_software(mut self, path: &str, version: &str) -> Self {
        self.deployed.push((path.into(), version.into()));
        self
    }

    /// A random grid worker: heterogeneous kernels and spotty deployments
    /// (the paper: "the larger the pool of distributed machines, the more
    /// heterogeneous they are likely to be").
    pub fn random_grid_worker(idx: usize, app: &Manifest, rng: &mut Rng) -> Self {
        let kernels = [
            KernelVersion(2, 6, 18),
            KernelVersion(2, 6, 32),
            KernelVersion(3, 2, 0),
            KernelVersion(3, 10, 0),
            KernelVersion(4, 4, 0),
        ];
        let mut host = RemoteHost::new(
            format!("wn{idx:04}.sim.egi.eu"),
            kernels[rng.usize(kernels.len())],
        );
        for dep in &app.dependencies {
            if let Some(v) = &dep.version {
                let r = rng.f64();
                if r < 0.5 {
                    host = host.with_software(&dep.path, v); // matching deploy
                } else if r < 0.75 {
                    host = host.with_software(&dep.path, &format!("{v}-other"));
                } // else missing entirely
            } else if rng.bool(0.3) {
                host = host.with_software(&dep.path, "present");
            }
        }
        host
    }

    fn lookup(&self, path: &str) -> Option<&str> {
        self.deployed
            .iter()
            .find(|(p, _)| p == path)
            .map(|(_, v)| v.as_str())
    }
}

/// Outcome of attempting to run the application on a host.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ReexecOutcome {
    /// Ran and produced the reference results.
    Success {
        /// Relative runtime overhead (1.0 = native).
        overhead: u32, // percent
    },
    /// Hard failure: a dependency was missing.
    MissingDependency(String),
    /// Hard failure: archive needs a newer kernel than the host has.
    KernelTooOld {
        host: KernelVersion,
        required: KernelVersion,
    },
    /// Ran, but a version-skewed dependency silently changed the results —
    /// the Provenance-breaking case of §3.1.
    SilentError(String),
}

impl ReexecOutcome {
    pub fn is_success(&self) -> bool {
        matches!(self, ReexecOutcome::Success { .. })
    }

    /// Success *and* correct (silent errors "run" but are wrong).
    pub fn is_correct(&self) -> bool {
        self.is_success()
    }
}

/// Packaging strategies compared by bench `a3_packaging`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Packager {
    /// Ship nothing; rely on the host's deployment.
    None,
    /// CDE: archive, no syscall emulation.
    Cde,
    /// CARE: archive + syscall emulation.
    Care,
}

/// Attempt re-execution of `manifest` on `host` under `packager`.
pub fn reexecute(manifest: &Manifest, packager: Packager, host: &RemoteHost) -> ReexecOutcome {
    match packager {
        Packager::None => {
            // every dependency must be deployed at the exact version
            for dep in &manifest.dependencies {
                match (host.lookup(&dep.path), &dep.version) {
                    (None, _) => {
                        return ReexecOutcome::MissingDependency(dep.path.clone())
                    }
                    (Some(have), Some(want)) if have != want => {
                        return ReexecOutcome::SilentError(format!(
                            "{}: host has {have}, app needs {want}",
                            dep.path
                        ))
                    }
                    _ => {}
                }
            }
            ReexecOutcome::Success { overhead: 0 }
        }
        Packager::Cde | Packager::Care => {
            let archive = Archive::pack(manifest.clone(), packager == Packager::Care);
            // dependencies travel with the archive — only the kernel matters
            if !archive.syscall_emulation && host.kernel < manifest.packaged_on {
                return ReexecOutcome::KernelTooOld {
                    host: host.kernel,
                    required: manifest.packaged_on,
                };
            }
            let overhead = if archive.syscall_emulation && host.kernel < manifest.packaged_on
            {
                8 // PRoot-style emulation cost on the old-kernel path
            } else {
                2 // ptrace interposition baseline
            };
            ReexecOutcome::Success { overhead }
        }
    }
}

/// Run the packaging comparison over a fleet: fraction of correct
/// re-executions per strategy (the a3 bench's headline number).
pub fn fleet_success_rate(
    manifest: &Manifest,
    packager: Packager,
    hosts: &[RemoteHost],
) -> f64 {
    if hosts.is_empty() {
        return 0.0;
    }
    let ok = hosts
        .iter()
        .filter(|h| reexecute(manifest, packager, h).is_correct())
        .count();
    ok as f64 / hosts.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::care::manifest::Dependency;

    fn app() -> Manifest {
        Manifest::new("ants", "./ants", KernelVersion(3, 10, 0))
            .with(Dependency::lib("/lib/libc.so.6", "2.17"))
            .with(Dependency::interpreter("/usr/bin/java", "1.8"))
    }

    #[test]
    fn bare_execution_fails_on_missing_dep() {
        let host = RemoteHost::new("h", KernelVersion(3, 10, 0))
            .with_software("/lib/libc.so.6", "2.17"); // java missing
        assert!(matches!(
            reexecute(&app(), Packager::None, &host),
            ReexecOutcome::MissingDependency(p) if p == "/usr/bin/java"
        ));
    }

    #[test]
    fn bare_execution_silent_error_on_version_skew() {
        let host = RemoteHost::new("h", KernelVersion(3, 10, 0))
            .with_software("/lib/libc.so.6", "2.28")
            .with_software("/usr/bin/java", "1.8");
        assert!(matches!(
            reexecute(&app(), Packager::None, &host),
            ReexecOutcome::SilentError(_)
        ));
    }

    #[test]
    fn cde_fails_new_to_old_kernel() {
        // packaged on 3.10, host runs 2.6.32 — the exact §3.2 limitation
        let host = RemoteHost::new("old", KernelVersion(2, 6, 32));
        assert!(matches!(
            reexecute(&app(), Packager::Cde, &host),
            ReexecOutcome::KernelTooOld { .. }
        ));
    }

    #[test]
    fn care_succeeds_new_to_old_kernel_with_overhead() {
        let host = RemoteHost::new("old", KernelVersion(2, 6, 32));
        match reexecute(&app(), Packager::Care, &host) {
            ReexecOutcome::Success { overhead } => assert!(overhead > 2),
            other => panic!("CARE should emulate: {other:?}"),
        }
    }

    #[test]
    fn cde_ok_old_to_new_kernel() {
        let mut m = app();
        m.packaged_on = KernelVersion::SCIENTIFIC_LINUX; // the rule of thumb
        let host = RemoteHost::new("new", KernelVersion(4, 4, 0));
        assert!(reexecute(&m, Packager::Cde, &host).is_success());
    }

    #[test]
    fn bare_execution_succeeds_on_exact_deployment() {
        // every dependency deployed at the exact recorded version → native run
        let host = RemoteHost::new("twin", KernelVersion(3, 10, 0))
            .with_software("/lib/libc.so.6", "2.17")
            .with_software("/usr/bin/java", "1.8");
        assert_eq!(
            reexecute(&app(), Packager::None, &host),
            ReexecOutcome::Success { overhead: 0 }
        );
    }

    #[test]
    fn cde_same_or_newer_kernel_pays_ptrace_baseline() {
        // no emulation needed: ptrace interposition only, never the PRoot cost
        let same = RemoteHost::new("same", KernelVersion(3, 10, 0));
        assert_eq!(
            reexecute(&app(), Packager::Cde, &same),
            ReexecOutcome::Success { overhead: 2 }
        );
        let newer = RemoteHost::new("newer", KernelVersion(4, 4, 0));
        assert_eq!(
            reexecute(&app(), Packager::Care, &newer),
            ReexecOutcome::Success { overhead: 2 }
        );
    }

    #[test]
    fn care_emulation_costs_more_than_interposition() {
        let old = RemoteHost::new("old", KernelVersion(2, 6, 32));
        let new = RemoteHost::new("new", KernelVersion(4, 4, 0));
        let emulated = match reexecute(&app(), Packager::Care, &old) {
            ReexecOutcome::Success { overhead } => overhead,
            other => panic!("expected success: {other:?}"),
        };
        let native = match reexecute(&app(), Packager::Care, &new) {
            ReexecOutcome::Success { overhead } => overhead,
            other => panic!("expected success: {other:?}"),
        };
        assert!(emulated > native, "{emulated} vs {native}");
    }

    #[test]
    fn data_file_dependency_is_presence_only() {
        // a DataFile has no version: any deployed copy satisfies bare
        // execution, absence is still a hard failure
        let m = Manifest::new("ants", "./ants", KernelVersion(3, 10, 0))
            .with(Dependency::data("/data/landscape.csv"));
        let with = RemoteHost::new("h", KernelVersion(3, 10, 0))
            .with_software("/data/landscape.csv", "whatever");
        assert!(reexecute(&m, Packager::None, &with).is_success());
        let without = RemoteHost::new("h", KernelVersion(3, 10, 0));
        assert!(matches!(
            reexecute(&m, Packager::None, &without),
            ReexecOutcome::MissingDependency(p) if p == "/data/landscape.csv"
        ));
    }

    #[test]
    fn fleet_ranking_care_ge_cde_gt_none() {
        let m = app();
        let mut rng = crate::util::Rng::new(7);
        let fleet: Vec<RemoteHost> = (0..200)
            .map(|i| RemoteHost::random_grid_worker(i, &m, &mut rng))
            .collect();
        let none = fleet_success_rate(&m, Packager::None, &fleet);
        let cde = fleet_success_rate(&m, Packager::Cde, &fleet);
        let care = fleet_success_rate(&m, Packager::Care, &fleet);
        assert_eq!(care, 1.0, "CARE must succeed everywhere");
        assert!(cde < care, "CDE blocked by old kernels");
        assert!(none < cde, "bare execution worst: {none} vs {cde}");
    }
}
