//! Dependency capture: what a probe run of the application touched.

use std::collections::BTreeSet;

/// Kinds of runtime dependency CDE/CARE capture by tracing the probe run.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum DependencyKind {
    /// Shared library (`.so`) resolved by the dynamic linker.
    SharedLibrary,
    /// Interpreter (python, java, netlogo, ...).
    Interpreter,
    /// Data file opened at runtime.
    DataFile,
    /// Another executable spawned by the application.
    Executable,
}

/// One captured dependency.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct Dependency {
    pub kind: DependencyKind,
    pub path: String,
    /// Version string if the tracer could determine one.
    pub version: Option<String>,
}

impl Dependency {
    pub fn lib(path: &str, version: &str) -> Self {
        Dependency {
            kind: DependencyKind::SharedLibrary,
            path: path.into(),
            version: Some(version.into()),
        }
    }

    pub fn data(path: &str) -> Self {
        Dependency {
            kind: DependencyKind::DataFile,
            path: path.into(),
            version: None,
        }
    }

    pub fn interpreter(path: &str, version: &str) -> Self {
        Dependency {
            kind: DependencyKind::Interpreter,
            path: path.into(),
            version: Some(version.into()),
        }
    }
}

/// Linux kernel version, ordered — the compatibility axis of §3.2 (CDE
/// archives only re-execute on kernels at least as old as the packaging
/// host's; CARE lifts this by syscall emulation).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct KernelVersion(pub u16, pub u16, pub u16);

impl KernelVersion {
    /// The "rule of thumb" packaging kernel of §3.1: Scientific Linux /
    /// CentOS era 2.6.32.
    pub const SCIENTIFIC_LINUX: KernelVersion = KernelVersion(2, 6, 32);

    pub fn parse(s: &str) -> Option<Self> {
        let mut it = s.trim().split('.');
        let a = it.next()?.parse().ok()?;
        let b = it.next()?.parse().ok()?;
        let c = it
            .next()
            .and_then(|p| p.split('-').next())
            .and_then(|p| p.parse().ok())
            .unwrap_or(0);
        Some(KernelVersion(a, b, c))
    }
}

impl std::fmt::Display for KernelVersion {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}.{}.{}", self.0, self.1, self.2)
    }
}

/// The package manifest: everything a probe run touched, plus the
/// packaging host's kernel (which determines CDE compatibility).
#[derive(Debug, Clone)]
pub struct Manifest {
    pub application: String,
    pub command: String,
    pub dependencies: BTreeSet<Dependency>,
    pub packaged_on: KernelVersion,
}

impl Manifest {
    pub fn new(
        application: impl Into<String>,
        command: impl Into<String>,
        packaged_on: KernelVersion,
    ) -> Self {
        Manifest {
            application: application.into(),
            command: command.into(),
            dependencies: BTreeSet::new(),
            packaged_on,
        }
    }

    /// Record a dependency observed during the probe run.
    pub fn record(&mut self, dep: Dependency) {
        self.dependencies.insert(dep);
    }

    pub fn with(mut self, dep: Dependency) -> Self {
        self.record(dep);
        self
    }

    pub fn libraries(&self) -> impl Iterator<Item = &Dependency> {
        self.dependencies
            .iter()
            .filter(|d| d.kind == DependencyKind::SharedLibrary)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kernel_versions_order() {
        assert!(KernelVersion(2, 6, 32) < KernelVersion(3, 2, 0));
        assert!(KernelVersion(4, 19, 0) < KernelVersion(5, 4, 0));
        assert_eq!(KernelVersion::parse("5.4.0-42-generic"), Some(KernelVersion(5, 4, 0)));
        assert_eq!(KernelVersion::parse("2.6.32"), Some(KernelVersion(2, 6, 32)));
        assert_eq!(KernelVersion::parse("junk"), None);
    }

    #[test]
    fn manifest_deduplicates() {
        let mut m = Manifest::new("ants", "netlogo-headless.sh ants.nlogo",
                                  KernelVersion(3, 10, 0));
        m.record(Dependency::lib("/lib/libc.so.6", "2.17"));
        m.record(Dependency::lib("/lib/libc.so.6", "2.17"));
        m.record(Dependency::data("/opt/model/ants.nlogo"));
        assert_eq!(m.dependencies.len(), 2);
        assert_eq!(m.libraries().count(), 1);
    }
}
