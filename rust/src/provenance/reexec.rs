//! `molers reexec <manifest>`: re-run an experiment from its manifest
//! alone and assert byte-identical output. Semantics in
//! [`crate::provenance`]; every failure is a named
//! [`Error::Provenance`] — tampering, fleet drift or a non-reproducing
//! digest can never look like success.

use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use crate::care::{
    self, Dependency, DependencyKind, KernelVersion, Packager, ReexecOutcome,
    RemoteHost,
};
use crate::cli::{front, Args};
use crate::error::{Error, Result};
use crate::util::hash;

use super::manifest::{write_front_file, EnvDesc, RunManifest};

/// How a reexec was asked to behave (all flags of the subcommand).
#[derive(Default)]
pub struct ReexecOptions {
    /// Keep the regenerated file here instead of a scratch path.
    pub out: Option<String>,
    /// Keep the scratch file even on success.
    pub keep: bool,
    /// Downgrade compat failures to warnings — the digest assertion
    /// remains the arbiter.
    pub ignore_compat: bool,
}

impl ReexecOptions {
    pub fn from_args(args: &Args) -> ReexecOptions {
        ReexecOptions {
            out: args.get("out").map(str::to_string),
            keep: args.flag("keep"),
            ignore_compat: args.flag("ignore-compat"),
        }
    }
}

/// What a successful reexec proved.
pub struct ReexecReport {
    pub run: String,
    /// The digest both files share.
    pub sha256: String,
    pub bytes: u64,
    /// Where the regenerated file lives (`None` when it was a scratch
    /// file removed after the successful comparison).
    pub regenerated: Option<PathBuf>,
    /// Care-modelled packaging overhead (percent; 0 for bare reexec).
    pub overhead_pct: u32,
    pub evaluations: u64,
    pub wall: Duration,
}

/// Re-execute the run described by `manifest_path`. `args` is the full
/// `reexec` command line: `--out`/`--keep`/`--ignore-compat` plus any
/// env-override flags, which are *checked* against the manifest (a
/// different fleet is a named error, not a silent relocation).
pub fn reexec(manifest_path: &str, args: &Args) -> Result<ReexecReport> {
    let started = Instant::now();
    let opts = ReexecOptions::from_args(args);
    let m = RunManifest::load(manifest_path)?;
    let dir = Path::new(manifest_path)
        .parent()
        .filter(|p| !p.as_os_str().is_empty())
        .map(Path::to_path_buf)
        .unwrap_or_else(|| PathBuf::from("."));

    // 1. tamper check — the recorded result, when still present, must
    //    digest to what the manifest claims
    let original = dir.join(&m.result.path);
    if original.exists() {
        let (hex, bytes) = hash::sha256_file(&original).map_err(Error::Io)?;
        if hex != m.result.sha256 {
            return Err(Error::Provenance {
                kind: "result-tampered",
                message: format!(
                    "`{}` digests sha256:{hex} ({bytes} bytes) but its manifest \
                     records sha256:{} ({} bytes) — the file changed after the run",
                    original.display(),
                    m.result.sha256,
                    m.result.bytes
                ),
            });
        }
    }

    // 2. env-fleet + build compatibility via the care decision logic
    let overhead_pct = match compat_check(&m, args) {
        Ok(o) => o,
        Err(e) if opts.ignore_compat => {
            eprintln!("warning: {e} (--ignore-compat: digest assertion decides)");
            0
        }
        Err(e) => return Err(e),
    };

    // 3. re-run from the manifest alone: recorded argv + seed + env,
    //    scratch output, no journal
    let scratch = match &opts.out {
        Some(p) => PathBuf::from(p),
        None => std::env::temp_dir().join(format!(
            "molers-reexec-{}-{}",
            std::process::id(),
            m.result.path
        )),
    };
    let _ = std::fs::remove_file(&scratch);
    let mut argv: Vec<String> = vec![m.run.clone()];
    argv.extend(m.argv.iter().cloned());
    argv.push("--seed".into());
    argv.push(m.seed.to_string());
    if m.run == "explore" {
        // the sweep streams its own result file; evolution methods get a
        // front file written below from the returned pareto front
        argv.push("--out".into());
        argv.push(scratch.to_string_lossy().into_owned());
    }
    let rerun = Args::parse(argv).map_err(Error::Config)?;
    let exp = front::by_name(&m.run, &rerun)?
        .env(m.env.to_env_spec())
        .quiet();
    let report = exp.run()?;
    let regenerated = match &report.outcome.result_path {
        Some(p) => PathBuf::from(p),
        None => {
            write_front_file(&scratch, &report.outcome.pareto_front)?;
            scratch.clone()
        }
    };

    // 4. the digest assertion — byte-identical or a named failure
    let (hex, bytes) = hash::sha256_file(&regenerated).map_err(Error::Io)?;
    if hex != m.result.sha256 {
        return Err(Error::Provenance {
            kind: "digest-mismatch",
            message: format!(
                "reexec of `{manifest_path}` produced sha256:{hex} ({bytes} bytes) \
                 at `{}`, manifest records sha256:{} ({} bytes) — regenerated file \
                 kept for diffing",
                regenerated.display(),
                m.result.sha256,
                m.result.bytes
            ),
        });
    }
    let keep = opts.out.is_some() || opts.keep;
    if !keep {
        let _ = std::fs::remove_file(&regenerated);
    }
    Ok(ReexecReport {
        run: m.run,
        sha256: hex,
        bytes,
        regenerated: keep.then_some(regenerated),
        overhead_pct,
        evaluations: report.outcome.evaluations,
        wall: started.elapsed(),
    })
}

/// Model the manifest as a [`care::Manifest`] — the molers build and the
/// env fleet are the "dependencies" of the result — and check it against
/// the current host with [`care::reexecute`]. Returns the modelled
/// overhead on success, a named provenance error otherwise.
fn compat_check(m: &RunManifest, args: &Args) -> Result<u32> {
    let packager = match m.packager.as_str() {
        "none" => Packager::None,
        "cde" => Packager::Cde,
        "care" => Packager::Care,
        other => {
            return Err(Error::Provenance {
                kind: "manifest-malformed",
                message: format!("unknown packager `{other}` (none|cde|care)"),
            })
        }
    };
    // unparseable kernel strings (non-Linux hosts) collapse both sides to
    // 0.0.0: the kernel axis is skipped, never a spurious failure
    let (packaged_on, current) = match (
        KernelVersion::parse(&m.host_kernel),
        KernelVersion::parse(&super::host_kernel()),
    ) {
        (Some(p), Some(c)) => (p, c),
        _ => (KernelVersion(0, 0, 0), KernelVersion(0, 0, 0)),
    };
    let app = care::Manifest::new("molers", format!("molers {}", m.run), packaged_on)
        .with(Dependency {
            kind: DependencyKind::Executable,
            path: "bin:molers".into(),
            version: Some(m.build.id()),
        })
        .with(Dependency {
            kind: DependencyKind::DataFile,
            path: "env:fleet".into(),
            version: Some(m.env.canonical()),
        });
    let effective = effective_env(m, args)?;
    let host = RemoteHost::new("reexec-host", current)
        .with_software("bin:molers", &super::build_info().id())
        .with_software("env:fleet", &effective.canonical());
    match care::reexecute(&app, packager, &host) {
        ReexecOutcome::Success { overhead } => Ok(overhead),
        ReexecOutcome::SilentError(msg) if msg.starts_with("bin:molers") => {
            Err(Error::Provenance {
                kind: "build-mismatch",
                message: format!(
                    "this binary is not the build that produced the result \
                     ({msg}) — results would not be comparable; rebuild the \
                     recorded version or pass --ignore-compat"
                ),
            })
        }
        ReexecOutcome::SilentError(msg) => Err(Error::Provenance {
            kind: "env-fleet-mismatch",
            message: format!(
                "{msg} — reexec runs on the recorded fleet (drop the env \
                 override flags or pass --ignore-compat)"
            ),
        }),
        ReexecOutcome::MissingDependency(path) => Err(Error::Provenance {
            kind: "missing-dependency",
            message: format!("`{path}` is not available on this host"),
        }),
        ReexecOutcome::KernelTooOld { host, required } => Err(Error::Provenance {
            kind: "kernel-too-old",
            message: format!(
                "manifest was packaged on kernel {required} without syscall \
                 emulation; this host runs {host}"
            ),
        }),
    }
}

/// The fleet this reexec would run on: the manifest's, unless the user
/// passed env-override flags — those are interpreted exactly as the
/// original subcommand would have ([`front::env_spec`]) and then
/// *compared*, not silently applied.
fn effective_env(m: &RunManifest, args: &Args) -> Result<EnvDesc> {
    let overridden = ["env", "envs", "nodes", "policy", "timeout", "max-retries", "backoff"]
        .iter()
        .any(|k| args.get(k).is_some())
        || args.flag("speculate");
    if !overridden {
        return Ok(m.env.clone());
    }
    let (default_env, default_nodes) = match &m.env {
        EnvDesc::Single { name, nodes } => (name.clone(), *nodes),
        EnvDesc::Fleet { .. } => ("local".to_string(), 8),
    };
    let nodes = args.usize("nodes", default_nodes).map_err(Error::Config)?;
    let spec = front::env_spec(args, &default_env, nodes)?;
    EnvDesc::from_spec(&spec).ok_or_else(|| Error::Config(
        "env override did not resolve to a recordable spec".into(),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::provenance::manifest::{BuildInfo, FileDigest};

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(str::to_string)).unwrap()
    }

    fn manifest(env: EnvDesc) -> RunManifest {
        RunManifest {
            run: "explore".into(),
            argv: vec!["--n".into(), "8".into()],
            seed: 7,
            build: crate::provenance::build_info(),
            host_kernel: crate::provenance::host_kernel(),
            packager: "none".into(),
            env,
            result: FileDigest {
                path: "x.csv".into(),
                sha256: "00".repeat(32),
                bytes: 0,
            },
            journal: Vec::new(),
        }
    }

    #[test]
    fn compat_accepts_same_build_same_fleet() {
        let m = manifest(EnvDesc::Single {
            name: "local".into(),
            nodes: 2,
        });
        assert_eq!(compat_check(&m, &parse("reexec m.json")).unwrap(), 0);
        // a redundant override equal to the record is also fine
        assert!(compat_check(&m, &parse("reexec m.json --env local --nodes 2")).is_ok());
    }

    #[test]
    fn env_override_mismatch_is_named() {
        let m = manifest(EnvDesc::Single {
            name: "local".into(),
            nodes: 2,
        });
        let err = compat_check(&m, &parse("reexec m.json --envs local:4"))
            .unwrap_err()
            .to_string();
        assert!(err.starts_with("provenance error [env-fleet-mismatch]"), "{err}");
    }

    #[test]
    fn build_mismatch_is_named() {
        let mut m = manifest(EnvDesc::Single {
            name: "local".into(),
            nodes: 2,
        });
        m.build = BuildInfo {
            crate_version: "0.0.0-other".into(),
            git_hash: "deadbee".into(),
        };
        let err = compat_check(&m, &parse("reexec m.json")).unwrap_err().to_string();
        assert!(err.starts_with("provenance error [build-mismatch]"), "{err}");
    }

    #[test]
    fn cde_kernel_rule_applies_to_manifests() {
        // a cde-packaged manifest recorded on a (fictional) newer kernel
        // must refuse to reexec on this older host — the §3.1 rule
        let mut m = manifest(EnvDesc::Single {
            name: "local".into(),
            nodes: 2,
        });
        m.packager = "cde".into();
        m.host_kernel = "9999.0.0".into();
        let err = compat_check(&m, &parse("reexec m.json")).unwrap_err().to_string();
        assert!(err.starts_with("provenance error [kernel-too-old]"), "{err}");
        // care emulates its way through the same gap
        m.packager = "care".into();
        let overhead = compat_check(&m, &parse("reexec m.json")).unwrap();
        assert!(overhead > 0, "emulation is modelled as non-free");
    }
}
