//! The run-manifest data model: build/env description, file digests,
//! JSON (de)serialisation and atomic emission. Grammar in [`crate::provenance`].

use std::collections::BTreeMap;
use std::path::Path;

use crate::broker::{journal, RetryPolicy};
use crate::cli::{front, Args};
use crate::error::{Error, Result};
use crate::evolution::genome::Individual;
use crate::util::hash;
use crate::util::json::{self, Json};
use crate::workflow::experiment::{EnvSpec, Experiment};

/// `kind` field of every run manifest.
pub const MANIFEST_KIND: &str = "molers-run-manifest";
/// Current manifest schema version.
pub const MANIFEST_VERSION: u64 = 1;

/// The build that produced a result: crate version + baked-in git hash.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BuildInfo {
    pub crate_version: String,
    pub git_hash: String,
}

impl BuildInfo {
    /// The single-string build id manifests compare (`0.1.0+4f2a91c`).
    pub fn id(&self) -> String {
        format!("{}+{}", self.crate_version, self.git_hash)
    }
}

impl std::fmt::Display for BuildInfo {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "molers {} (git {})", self.crate_version, self.git_hash)
    }
}

/// A file pinned by content digest. `path` is a bare file name resolved
/// relative to the manifest's directory.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FileDigest {
    pub path: String,
    pub sha256: String,
    pub bytes: u64,
}

impl FileDigest {
    /// Digest `full_path`, recording only its file name.
    pub fn of(full_path: &Path) -> Result<FileDigest> {
        let (sha256, bytes) = hash::sha256_file(full_path).map_err(Error::Io)?;
        Ok(FileDigest {
            path: full_path
                .file_name()
                .map(|n| n.to_string_lossy().into_owned())
                .unwrap_or_else(|| full_path.to_string_lossy().into_owned()),
            sha256,
            bytes,
        })
    }

    fn to_json(&self) -> Json {
        obj(vec![
            ("path", Json::Str(self.path.clone())),
            ("sha256", Json::Str(self.sha256.clone())),
            ("bytes", Json::Num(self.bytes as f64)),
        ])
    }

    fn from_json(v: &Json) -> Result<FileDigest> {
        Ok(FileDigest {
            path: str_field(v, "path")?,
            sha256: str_field(v, "sha256")?,
            bytes: num_field(v, "bytes")? as u64,
        })
    }
}

/// The environment a run executed on, in manifest-recordable form —
/// everything `molers reexec` needs to rebuild the same [`EnvSpec`],
/// and everything the compat check compares. [`EnvSpec::Provided`] has
/// no spec to record, so library runs on hand-built environments emit
/// no manifest.
#[derive(Debug, Clone, PartialEq)]
pub enum EnvDesc {
    Single {
        name: String,
        nodes: usize,
    },
    Fleet {
        spec: String,
        policy: String,
        speculate: bool,
        retry: Option<RetryPolicy>,
    },
}

impl EnvDesc {
    pub fn from_spec(spec: &EnvSpec) -> Option<EnvDesc> {
        match spec {
            EnvSpec::Single { name, nodes } => Some(EnvDesc::Single {
                name: name.clone(),
                nodes: *nodes,
            }),
            EnvSpec::Fleet {
                spec,
                policy,
                speculate,
                retry,
            } => Some(EnvDesc::Fleet {
                spec: spec.clone(),
                policy: policy.clone(),
                speculate: *speculate,
                retry: retry.clone(),
            }),
            EnvSpec::Provided(_) => None,
        }
    }

    pub fn to_env_spec(&self) -> EnvSpec {
        match self {
            EnvDesc::Single { name, nodes } => EnvSpec::Single {
                name: name.clone(),
                nodes: *nodes,
            },
            EnvDesc::Fleet {
                spec,
                policy,
                speculate,
                retry,
            } => EnvSpec::Fleet {
                spec: spec.clone(),
                policy: policy.clone(),
                speculate: *speculate,
                retry: retry.clone(),
            },
        }
    }

    /// One canonical string per distinct fleet configuration — the
    /// "version" of the `env:fleet` dependency in the care compat check,
    /// so any drift (spec, policy, speculation, retry numbers) surfaces
    /// as a version skew.
    pub fn canonical(&self) -> String {
        match self {
            EnvDesc::Single { name, nodes } => format!("single:{name}:{nodes}"),
            EnvDesc::Fleet {
                spec,
                policy,
                speculate,
                retry,
            } => {
                let retry = match retry {
                    None => "default".to_string(),
                    Some(r) => format!(
                        "{}:{}:{}:{}:{}:{}",
                        r.max_attempts,
                        r.attempt_timeout_s,
                        r.job_deadline_s,
                        r.backoff_base_s,
                        r.backoff_max_s,
                        r.jitter
                    ),
                };
                format!(
                    "fleet:{spec}|policy={policy}|speculate={}|retry={retry}",
                    if *speculate { "on" } else { "off" }
                )
            }
        }
    }

    fn to_json(&self) -> Json {
        match self {
            EnvDesc::Single { name, nodes } => obj(vec![
                ("mode", Json::Str("single".into())),
                ("name", Json::Str(name.clone())),
                ("nodes", Json::Num(*nodes as f64)),
            ]),
            EnvDesc::Fleet {
                spec,
                policy,
                speculate,
                retry,
            } => obj(vec![
                ("mode", Json::Str("fleet".into())),
                ("spec", Json::Str(spec.clone())),
                ("policy", Json::Str(policy.clone())),
                ("speculate", Json::Bool(*speculate)),
                (
                    "retry",
                    match retry {
                        None => Json::Null,
                        Some(r) => obj(vec![
                            ("max_attempts", Json::Num(r.max_attempts as f64)),
                            ("attempt_timeout_s", Json::Num(r.attempt_timeout_s)),
                            ("job_deadline_s", Json::Num(r.job_deadline_s)),
                            ("backoff_base_s", Json::Num(r.backoff_base_s)),
                            ("backoff_max_s", Json::Num(r.backoff_max_s)),
                            ("jitter", Json::Num(r.jitter)),
                        ]),
                    },
                ),
            ]),
        }
    }

    fn from_json(v: &Json) -> Result<EnvDesc> {
        match str_field(v, "mode")?.as_str() {
            "single" => Ok(EnvDesc::Single {
                name: str_field(v, "name")?,
                nodes: num_field(v, "nodes")? as usize,
            }),
            "fleet" => {
                let retry = match v.get("retry") {
                    None | Some(Json::Null) => None,
                    Some(r) => Some(RetryPolicy {
                        max_attempts: num_field(r, "max_attempts")? as u32,
                        attempt_timeout_s: num_field(r, "attempt_timeout_s")?,
                        job_deadline_s: num_field(r, "job_deadline_s")?,
                        backoff_base_s: num_field(r, "backoff_base_s")?,
                        backoff_max_s: num_field(r, "backoff_max_s")?,
                        jitter: num_field(r, "jitter")?,
                    }),
                };
                Ok(EnvDesc::Fleet {
                    spec: str_field(v, "spec")?,
                    policy: str_field(v, "policy")?,
                    speculate: matches!(v.get("speculate"), Some(Json::Bool(true))),
                    retry,
                })
            }
            other => Err(malformed(format!("unknown env mode `{other}`"))),
        }
    }
}

/// One complete run manifest — see the grammar in [`crate::provenance`].
#[derive(Debug, Clone)]
pub struct RunManifest {
    pub run: String,
    /// Method-configuration argv (env/persistence/seed/out stripped).
    pub argv: Vec<String>,
    pub seed: u64,
    pub build: BuildInfo,
    /// Kernel release of the recording host.
    pub host_kernel: String,
    /// `none` | `cde` | `care` — how the reexec compat check models
    /// dependency shipping. Emitted manifests record `none` (exact-match
    /// provenance); `cde`/`care` exercise the kernel rule in tests.
    pub packager: String,
    pub env: EnvDesc,
    pub result: FileDigest,
    pub journal: Vec<FileDigest>,
}

impl RunManifest {
    /// Digest the result file (and any journal segments) and assemble a
    /// manifest for the current build on the current host.
    pub fn describe(
        run: &str,
        argv: Vec<String>,
        seed: u64,
        env: EnvDesc,
        result_path: &str,
        journal_base: Option<&str>,
    ) -> Result<RunManifest> {
        let result = FileDigest::of(Path::new(result_path))?;
        let mut journal_digests = Vec::new();
        if let Some(base) = journal_base {
            for (_, seg) in journal::journal_segments(Path::new(base)) {
                journal_digests.push(FileDigest::of(&seg)?);
            }
        }
        Ok(RunManifest {
            run: run.to_string(),
            argv,
            seed,
            build: super::build_info(),
            host_kernel: super::host_kernel(),
            packager: "none".to_string(),
            env,
            result,
            journal: journal_digests,
        })
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("kind", Json::Str(MANIFEST_KIND.into())),
            ("version", Json::Num(MANIFEST_VERSION as f64)),
            ("run", Json::Str(self.run.clone())),
            (
                "argv",
                Json::Arr(self.argv.iter().cloned().map(Json::Str).collect()),
            ),
            // decimal string: a u64 seed does not survive an f64 Num
            ("seed_exact", Json::Str(self.seed.to_string())),
            (
                "build",
                obj(vec![
                    ("crate_version", Json::Str(self.build.crate_version.clone())),
                    ("git_hash", Json::Str(self.build.git_hash.clone())),
                ]),
            ),
            ("host_kernel", Json::Str(self.host_kernel.clone())),
            ("packager", Json::Str(self.packager.clone())),
            ("env", self.env.to_json()),
            ("result", self.result.to_json()),
            (
                "journal",
                Json::Arr(self.journal.iter().map(FileDigest::to_json).collect()),
            ),
        ])
    }

    pub fn from_json(v: &Json) -> Result<RunManifest> {
        let kind = str_field(v, "kind")?;
        if kind != MANIFEST_KIND {
            return Err(malformed(format!(
                "kind `{kind}` is not `{MANIFEST_KIND}`"
            )));
        }
        let version = num_field(v, "version")? as u64;
        if version != MANIFEST_VERSION {
            return Err(malformed(format!(
                "manifest version {version} (this build understands {MANIFEST_VERSION})"
            )));
        }
        let argv = v
            .get("argv")
            .and_then(Json::as_arr)
            .ok_or_else(|| malformed("missing `argv` array".into()))?
            .iter()
            .map(|a| {
                a.as_str()
                    .map(str::to_string)
                    .ok_or_else(|| malformed("non-string argv entry".into()))
            })
            .collect::<Result<Vec<_>>>()?;
        let seed = str_field(v, "seed_exact")?
            .parse::<u64>()
            .map_err(|_| malformed("`seed_exact` is not a u64".into()))?;
        let build_v = v
            .get("build")
            .ok_or_else(|| malformed("missing `build`".into()))?;
        let env_v = v
            .get("env")
            .ok_or_else(|| malformed("missing `env`".into()))?;
        let result_v = v
            .get("result")
            .ok_or_else(|| malformed("missing `result`".into()))?;
        let journal = match v.get("journal") {
            None => Vec::new(),
            Some(j) => j
                .as_arr()
                .ok_or_else(|| malformed("`journal` is not an array".into()))?
                .iter()
                .map(FileDigest::from_json)
                .collect::<Result<Vec<_>>>()?,
        };
        Ok(RunManifest {
            run: str_field(v, "run")?,
            argv,
            seed,
            build: BuildInfo {
                crate_version: str_field(build_v, "crate_version")?,
                git_hash: str_field(build_v, "git_hash")?,
            },
            host_kernel: str_field(v, "host_kernel")?,
            packager: str_field(v, "packager")?,
            env: EnvDesc::from_json(env_v)?,
            result: FileDigest::from_json(result_v)?,
            journal,
        })
    }

    /// Load + parse, every failure a named `[manifest-malformed]` error.
    pub fn load(path: &str) -> Result<RunManifest> {
        let text = std::fs::read_to_string(path).map_err(|e| {
            malformed(format!("cannot read `{path}`: {e}"))
        })?;
        let v = json::parse(&text)
            .map_err(|e| malformed(format!("`{path}`: {e}")))?;
        RunManifest::from_json(&v)
    }

    /// Write atomically (temp + fsync + rename): a crash mid-write never
    /// leaves a half manifest next to a complete result.
    pub fn write(&self, path: &str) -> Result<()> {
        let mut text = self.to_json().to_string();
        text.push('\n');
        journal::atomic_write(path, text.as_bytes())
    }
}

/// Where the CLI puts a run's manifest: next to the result file.
pub fn manifest_path_for(result_path: &str) -> String {
    format!("{result_path}.manifest.json")
}

/// Write the deterministic pareto-front result file evolution methods
/// advertise under `--out`: one `{"genome":…,"objectives":…}` line per
/// pareto point, no timestamps or wall times — the digestable artifact
/// `molers reexec` asserts against. Shared by the CLI fronts and
/// `molers serve` so both produce byte-identical files for equal fronts.
pub fn write_front_file(path: &Path, front: &[Individual]) -> Result<()> {
    let mut out = String::new();
    for ind in front {
        let line = obj(vec![
            (
                "genome",
                Json::Arr(ind.genome.iter().map(|&g| Json::Num(g)).collect()),
            ),
            (
                "objectives",
                Json::Arr(ind.objectives.iter().map(|&o| Json::Num(o)).collect()),
            ),
        ]);
        out.push_str(&line.to_string());
        out.push('\n');
    }
    journal::atomic_write(path, out.as_bytes())
}

/// Emit the manifest for a CLI run that produced `result_path`: derive
/// the recorded argv/env/seed from the parsed invocation and the built
/// experiment, write `<result_path>.manifest.json`, return its path.
/// Runs on a [`EnvSpec::Provided`] environment have nothing recordable
/// and return `Ok(None)`.
pub fn emit_for_cli(
    run: &str,
    args: &Args,
    exp: &Experiment,
    result_path: &str,
) -> Result<Option<String>> {
    let Some(env) = EnvDesc::from_spec(exp.env_spec()) else {
        return Ok(None);
    };
    let argv = front::provenance_argv(args);
    let journal_base = args.get("resume").or_else(|| args.get("journal"));
    let m = RunManifest::describe(
        run,
        argv,
        exp.seed_value(),
        env,
        result_path,
        journal_base,
    )?;
    let path = manifest_path_for(result_path);
    m.write(&path)?;
    Ok(Some(path))
}

fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(
        fields
            .into_iter()
            .map(|(k, v)| (k.to_string(), v))
            .collect::<BTreeMap<_, _>>(),
    )
}

fn malformed(message: String) -> Error {
    Error::Provenance {
        kind: "manifest-malformed",
        message,
    }
}

fn str_field(v: &Json, key: &str) -> Result<String> {
    v.get(key)
        .and_then(Json::as_str)
        .map(str::to_string)
        .ok_or_else(|| malformed(format!("missing or non-string `{key}`")))
}

fn num_field(v: &Json, key: &str) -> Result<f64> {
    v.get(key)
        .and_then(Json::as_f64)
        .ok_or_else(|| malformed(format!("missing or non-numeric `{key}`")))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> RunManifest {
        RunManifest {
            run: "explore".into(),
            argv: vec!["--n".into(), "64".into(), "--chunk".into(), "16".into()],
            seed: u64::MAX - 7, // exercise the above-2^53 range
            build: BuildInfo {
                crate_version: "0.1.0".into(),
                git_hash: "4f2a91c".into(),
            },
            host_kernel: "6.18.5-fc".into(),
            packager: "none".into(),
            env: EnvDesc::Fleet {
                spec: "local:8,pbs:32~drop=0.2".into(),
                policy: "ewma".into(),
                speculate: true,
                retry: Some(RetryPolicy::default()),
            },
            result: FileDigest {
                path: "sweep.csv".into(),
                sha256: "ab".repeat(32),
                bytes: 4096,
            },
            journal: vec![FileDigest {
                path: "sweep.jsonl".into(),
                sha256: "cd".repeat(32),
                bytes: 512,
            }],
        }
    }

    #[test]
    fn manifest_json_roundtrips_exactly() {
        let m = sample();
        let back = RunManifest::from_json(&m.to_json()).unwrap();
        assert_eq!(back.run, m.run);
        assert_eq!(back.argv, m.argv);
        assert_eq!(back.seed, m.seed, "u64 seed survives via seed_exact string");
        assert_eq!(back.build, m.build);
        assert_eq!(back.env, m.env);
        assert_eq!(back.result, m.result);
        assert_eq!(back.journal, m.journal);
        // serialisation is canonical (BTreeMap key order): stable bytes
        assert_eq!(back.to_json().to_string(), m.to_json().to_string());
    }

    #[test]
    fn from_json_names_every_malformation() {
        for (doc, needle) in [
            ("{}", "missing or non-string `kind`"),
            (r#"{"kind":"other"}"#, "is not `molers-run-manifest`"),
            (
                r#"{"kind":"molers-run-manifest","version":9}"#,
                "manifest version 9",
            ),
        ] {
            let err = RunManifest::from_json(&json::parse(doc).unwrap()).unwrap_err();
            let msg = err.to_string();
            assert!(
                msg.starts_with("provenance error [manifest-malformed]"),
                "{msg}"
            );
            assert!(msg.contains(needle), "`{doc}` → {msg}");
        }
    }

    #[test]
    fn env_desc_roundtrips_and_canonicalises() {
        let single = EnvDesc::Single {
            name: "pbs".into(),
            nodes: 32,
        };
        assert_eq!(single.canonical(), "single:pbs:32");
        assert_eq!(EnvDesc::from_json(&single.to_json()).unwrap(), single);

        let fleet = EnvDesc::Fleet {
            spec: "local:4~0.2".into(),
            policy: "least".into(),
            speculate: false,
            retry: None,
        };
        assert_eq!(EnvDesc::from_json(&fleet.to_json()).unwrap(), fleet);
        // distinct configurations → distinct canonical strings
        let mut other = fleet.clone();
        if let EnvDesc::Fleet { retry, .. } = &mut other {
            *retry = Some(RetryPolicy::default());
        }
        assert_ne!(fleet.canonical(), other.canonical());
        // EnvSpec round-trip preserves the canonical form
        let back = EnvDesc::from_spec(&fleet.to_env_spec()).unwrap();
        assert_eq!(back.canonical(), fleet.canonical());
    }

    #[test]
    fn front_file_is_deterministic_and_digestable() {
        let dir = std::env::temp_dir();
        let p1 = dir.join(format!("molers-front-a-{}.jsonl", std::process::id()));
        let p2 = dir.join(format!("molers-front-b-{}.jsonl", std::process::id()));
        let front = vec![Individual::new(vec![1.5, 2.0], vec![0.25, -0.0])];
        write_front_file(&p1, &front).unwrap();
        write_front_file(&p2, &front).unwrap();
        let (d1, _) = hash::sha256_file(&p1).unwrap();
        let (d2, _) = hash::sha256_file(&p2).unwrap();
        assert_eq!(d1, d2, "equal fronts digest identically");
        let text = std::fs::read_to_string(&p1).unwrap();
        assert_eq!(
            text,
            "{\"genome\":[1.5,2],\"objectives\":[0.25,-0]}\n",
            "no wall times or timestamps in the provenance artifact"
        );
        let _ = std::fs::remove_file(&p1);
        let _ = std::fs::remove_file(&p2);
    }
}
