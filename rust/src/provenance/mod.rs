//! Provenance-complete runs: every experiment that produces a result
//! file also produces a **run manifest** proving where the result came
//! from, and `molers reexec <manifest>` re-runs the experiment *from the
//! manifest alone* and asserts byte-identical output (ROADMAP item 5;
//! the retrospective-provenance queries of arXiv:1311.4610 — "which
//! configuration produced this file?", "can it be reproduced here?" —
//! become greppable JSON plus one command).
//!
//! # Manifest JSON grammar
//!
//! One JSON object, written atomically (temp + fsync + rename) next to
//! the result file it describes (`<result>.manifest.json` from the CLI,
//! `exp-N.manifest.json` under a `molers serve` state dir):
//!
//! ```json
//! {
//!   "kind": "molers-run-manifest",
//!   "version": 1,
//!   "run": "explore",
//!   "argv": ["--chunk", "16", "--n", "64"],
//!   "seed_exact": "7",
//!   "build": {"crate_version": "0.1.0", "git_hash": "4f2a91c"},
//!   "host_kernel": "6.18.5",
//!   "packager": "none",
//!   "env": {"mode": "single", "name": "local", "nodes": 8},
//!   "result": {"path": "sweep.csv", "sha256": "9f86d08…", "bytes": 4096},
//!   "journal": [{"path": "sweep.jsonl", "sha256": "a665a4…", "bytes": 512}]
//! }
//! ```
//!
//! * `argv` holds **method configuration only** — environment selection,
//!   persistence flags, `--seed` and `--out` are stripped (see
//!   [`crate::cli::front::provenance_argv`]) and recorded structurally,
//!   so a reexec never touches the original journal or output.
//! * `seed_exact` is a decimal string: a u64 does not survive a JSON
//!   `Num` (f64) round-trip above 2⁵³.
//! * `env` is either `{"mode":"single","name":…,"nodes":N}` or
//!   `{"mode":"fleet","spec":…,"policy":…,"speculate":bool,"retry":…}`
//!   where `retry` is `null` (defaults) or the full
//!   [`RetryPolicy`](crate::broker::RetryPolicy) field set — fault plans
//!   ride inside `spec` (`local:8,pbs:32~drop=0.2`) exactly as typed.
//! * `result.path` and `journal[].path` are file names resolved relative
//!   to the manifest's own directory, so a results directory can be
//!   archived or moved wholesale.
//! * `sha256` digests are computed by the dependency-free
//!   [`crate::util::hash`] implementation (NIST-vector tested).
//!
//! # Reexec semantics
//!
//! `molers reexec <manifest>` performs, in order:
//!
//! 1. **Tamper check** — if the recorded result file still exists, its
//!    digest must match; otherwise the run fails with the named error
//!    `provenance error [result-tampered]`.
//! 2. **Compatibility check** — the env fleet + build recorded in the
//!    manifest are modelled as a [`care::Manifest`](crate::care::Manifest)
//!    (the molers build and the fleet spec are "dependencies" of the
//!    result) and checked against the current host with
//!    [`care::reexecute`](crate::care::reexecute): a different build is
//!    `[build-mismatch]` (the silent-error case of §3.1 — same command,
//!    different binary, different bytes), a different fleet requested via
//!    override flags is `[env-fleet-mismatch]`, and a `cde`-packaged
//!    manifest on an older kernel is `[kernel-too-old]`.
//! 3. **Re-run** — the experiment is rebuilt through the same CLI front
//!    as the original invocation (`front::by_name`), with the recorded
//!    env spec and seed, writing to a scratch output path. No journal is
//!    created or read.
//! 4. **Digest assertion** — the regenerated file's SHA-256 must equal
//!    `result.sha256` byte for byte, else `[digest-mismatch]` (the
//!    regenerated file is kept for forensic diffing).
//!
//! All failures are named [`Error::Provenance`](crate::error::Error)
//! variants — a provenance violation is never a silent success.

mod manifest;
mod reexec;

pub use manifest::{
    emit_for_cli, manifest_path_for, write_front_file, BuildInfo, EnvDesc, FileDigest,
    RunManifest, MANIFEST_KIND, MANIFEST_VERSION,
};
pub use reexec::{reexec, ReexecOptions, ReexecReport};

/// Crate version + git hash of the running binary. The git hash is baked
/// in at compile time via `MOLERS_GIT_HASH` (CI exports it; local builds
/// without it report `unknown`), so every manifest pins the exact build
/// that produced its result.
pub fn build_info() -> BuildInfo {
    BuildInfo {
        crate_version: env!("CARGO_PKG_VERSION").to_string(),
        git_hash: option_env!("MOLERS_GIT_HASH").unwrap_or("unknown").to_string(),
    }
}

/// The kernel release of the machine we are running on (records into
/// manifests; compared by the CDE/CARE kernel rule at reexec time).
/// `unknown` off Linux — the compat check treats an unparseable kernel
/// as "skip the kernel axis", never as a spurious failure.
pub fn host_kernel() -> String {
    std::fs::read_to_string("/proc/sys/kernel/osrelease")
        .map(|s| s.trim().to_string())
        .unwrap_or_else(|_| "unknown".to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_info_is_populated() {
        let b = build_info();
        assert!(!b.crate_version.is_empty());
        assert!(!b.git_hash.is_empty());
        // the id is what manifests and `molers --version` both print
        assert_eq!(b.id(), format!("{}+{}", b.crate_version, b.git_hash));
    }
}
