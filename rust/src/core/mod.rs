//! Typed dataflow core: variable prototypes ([`Val`]), runtime values
//! ([`Value`]) and the [`Context`] that flows between tasks.

mod context;
mod val;
pub mod variable;

pub use context::Context;
pub use val::{val_f64, val_i64, val_str, val_u32, Val, VarSpec};
pub use variable::{Value, ValueType, VarType};
