//! The dataflow context: the set of variables flowing between tasks.

use std::collections::BTreeMap;

use crate::core::val::Val;
use crate::core::variable::{Value, ValueType};
use crate::error::{Error, Result};

/// An immutable-by-convention bag of named, typed values. Tasks receive a
/// context, read their declared inputs, and return a context holding their
/// outputs; the engine merges contexts along transitions.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Context {
    vars: BTreeMap<String, Value>,
}

impl Context {
    pub fn new() -> Self {
        Self::default()
    }

    /// Builder-style insert: `Context::new().with(&seed, 42u32)`.
    pub fn with<T: ValueType>(mut self, proto: &Val<T>, value: T) -> Self {
        self.set(proto, value);
        self
    }

    pub fn set<T: ValueType>(&mut self, proto: &Val<T>, value: T) {
        self.vars.insert(proto.name().to_string(), value.into_value());
    }

    pub fn set_raw(&mut self, name: &str, value: Value) {
        self.vars.insert(name.to_string(), value);
    }

    /// Typed read; error if absent or wrong type.
    pub fn get<T: ValueType>(&self, proto: &Val<T>) -> Result<T> {
        let v = self
            .vars
            .get(proto.name())
            .ok_or_else(|| Error::MissingVariable(proto.name().to_string()))?;
        T::from_value(v).ok_or_else(|| Error::TypeMismatch {
            name: proto.name().to_string(),
            expected: T::TYPE_NAME,
            actual: v.type_name(),
        })
    }

    pub fn get_raw(&self, name: &str) -> Option<&Value> {
        self.vars.get(name)
    }

    pub fn contains(&self, name: &str) -> bool {
        self.vars.contains_key(name)
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.vars.keys().map(String::as_str)
    }

    pub fn len(&self) -> usize {
        self.vars.len()
    }

    pub fn is_empty(&self) -> bool {
        self.vars.is_empty()
    }

    /// Merge `other` into `self`; `other` wins on conflicts (downstream
    /// tasks see the freshest write, as in OpenMOLE's dataflow).
    pub fn merge(&mut self, other: &Context) {
        for (k, v) in &other.vars {
            self.vars.insert(k.clone(), v.clone());
        }
    }

    /// Keep only the named variables (used to narrow a context to a task's
    /// declared inputs).
    pub fn filtered(&self, names: &[&str]) -> Context {
        let mut out = Context::new();
        for n in names {
            if let Some(v) = self.vars.get(*n) {
                out.vars.insert((*n).to_string(), v.clone());
            }
        }
        out
    }

    /// Fan-in: collapse many contexts into one by turning each variable
    /// into a `List` of its per-context values (OpenMOLE's aggregation when
    /// an exploration closes). Variables missing from any context are
    /// dropped.
    pub fn aggregate(contexts: &[Context]) -> Context {
        let mut out = Context::new();
        if contexts.is_empty() {
            return out;
        }
        'vars: for name in contexts[0].vars.keys() {
            let mut list = Vec::with_capacity(contexts.len());
            for c in contexts {
                match c.vars.get(name) {
                    Some(v) => list.push(v.clone()),
                    None => continue 'vars,
                }
            }
            out.vars.insert(name.clone(), Value::List(list));
        }
        out
    }

    /// Render `name=value` pairs (ToStringHook).
    pub fn display(&self) -> String {
        self.vars
            .iter()
            .map(|(k, v)| format!("{k}={}", v.display()))
            .collect::<Vec<_>>()
            .join(", ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::val::{val_f64, val_str, val_u32};

    #[test]
    fn set_get_roundtrip() {
        let x = val_f64("x");
        let ctx = Context::new().with(&x, 2.5);
        assert_eq!(ctx.get(&x).unwrap(), 2.5);
    }

    #[test]
    fn missing_variable_is_error() {
        let x = val_f64("x");
        let err = Context::new().get(&x).unwrap_err();
        assert!(matches!(err, Error::MissingVariable(n) if n == "x"));
    }

    #[test]
    fn type_mismatch_is_error() {
        let s = val_str("x");
        let ctx = Context::new().with(&val_f64("x"), 1.0);
        assert!(matches!(
            ctx.get(&s).unwrap_err(),
            Error::TypeMismatch { .. }
        ));
    }

    #[test]
    fn merge_last_writer_wins() {
        let x = val_f64("x");
        let y = val_f64("y");
        let mut a = Context::new().with(&x, 1.0);
        let b = Context::new().with(&x, 2.0).with(&y, 3.0);
        a.merge(&b);
        assert_eq!(a.get(&x).unwrap(), 2.0);
        assert_eq!(a.get(&y).unwrap(), 3.0);
    }

    #[test]
    fn aggregate_builds_arrays() {
        let f = val_f64("food1");
        let ctxs: Vec<Context> = (0..4)
            .map(|i| Context::new().with(&f, f64::from(i)))
            .collect();
        let agg = Context::aggregate(&ctxs);
        assert_eq!(
            agg.get(&f.array()).unwrap(),
            vec![0.0, 1.0, 2.0, 3.0]
        );
    }

    #[test]
    fn aggregate_drops_partial_variables() {
        let f = val_f64("f");
        let g = val_f64("g");
        let a = Context::new().with(&f, 1.0).with(&g, 1.0);
        let b = Context::new().with(&f, 2.0);
        let agg = Context::aggregate(&[a, b]);
        assert!(agg.contains("f"));
        assert!(!agg.contains("g"));
    }

    #[test]
    fn filtered_narrows() {
        let ctx = Context::new()
            .with(&val_f64("a"), 1.0)
            .with(&val_u32("b"), 2);
        let narrow = ctx.filtered(&["a"]);
        assert!(narrow.contains("a") && !narrow.contains("b"));
    }
}
