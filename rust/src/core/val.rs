//! Typed variable prototypes — the `Val[T]` of OpenMOLE's DSL.
//!
//! A [`Val<T>`] is a named, typed key into the dataflow [`Context`]. Tasks
//! declare their inputs/outputs as prototypes; the engine checks presence
//! and type at the task boundary, which is what lets workflows fail fast
//! instead of silently mis-wiring (paper §2.1: the DSL "denotes all the
//! types and data used within the workflow").
//!
//! [`Context`]: crate::core::Context

use std::marker::PhantomData;

use crate::core::variable::{ValueType, VarType};

/// Name + (optional) static type of one declared task variable — the
/// erased form of a [`Val<T>`] that task interfaces expose for build-time
/// wiring validation. `ty: None` marks a name-only declaration (legacy
/// string interfaces): presence is still checked, the type is not.
#[derive(Debug, Clone, PartialEq)]
pub struct VarSpec {
    pub name: String,
    pub ty: Option<VarType>,
}

impl VarSpec {
    /// Fully typed spec from a prototype.
    pub fn typed<T: ValueType>(v: &Val<T>) -> Self {
        VarSpec {
            name: v.name().to_string(),
            ty: Some(T::var_type()),
        }
    }

    /// Name-only spec (type unknown — presence-checked only).
    pub fn untyped(name: impl Into<String>) -> Self {
        VarSpec {
            name: name.into(),
            ty: None,
        }
    }

    /// Typed spec from a name and an explicit type.
    pub fn of(name: impl Into<String>, ty: VarType) -> Self {
        VarSpec {
            name: name.into(),
            ty: Some(ty),
        }
    }
}

/// A named, typed dataflow variable prototype.
///
/// Cloning is cheap; prototypes are identified by name, so two `Val<f64>`
/// with the same name refer to the same slot.
#[derive(Debug)]
pub struct Val<T> {
    name: String,
    _ty: PhantomData<fn() -> T>,
}

impl<T> Clone for Val<T> {
    fn clone(&self) -> Self {
        Val {
            name: self.name.clone(),
            _ty: PhantomData,
        }
    }
}

impl<T: ValueType> Val<T> {
    /// Declare a prototype, e.g. `let food1: Val<f64> = Val::new("food1");`
    pub fn new(name: impl Into<String>) -> Self {
        Val {
            name: name.into(),
            _ty: PhantomData,
        }
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// The prototype for the array of `T` produced when an exploration or
    /// replication fans results back in (OpenMOLE's `toArray` semantics).
    pub fn array(&self) -> Val<Vec<T>> {
        Val::new(self.name.clone())
    }
}

impl<T> PartialEq for Val<T> {
    fn eq(&self, other: &Self) -> bool {
        self.name == other.name
    }
}
impl<T> Eq for Val<T> {}

/// Convenience constructors for the common prototypes.
pub fn val_f64(name: &str) -> Val<f64> {
    Val::new(name)
}
pub fn val_i64(name: &str) -> Val<i64> {
    Val::new(name)
}
pub fn val_u32(name: &str) -> Val<u32> {
    Val::new(name)
}
pub fn val_str(name: &str) -> Val<String> {
    Val::new(name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn equality_is_by_name() {
        let a: Val<f64> = Val::new("x");
        let b: Val<f64> = Val::new("x");
        let c: Val<f64> = Val::new("y");
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn array_prototype_keeps_name() {
        let a: Val<f64> = Val::new("food1");
        assert_eq!(a.array().name(), "food1");
    }
}
