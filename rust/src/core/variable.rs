//! Dynamic values flowing through the dataflow.
//!
//! OpenMOLE's dataflow is typed via Scala generics (`Val[Double]`); here a
//! closed `Value` enum plays the role of the runtime representation while
//! [`crate::core::Val`] carries the static type.

/// Static type of a dataflow variable — the validation-time mirror of
/// [`Value`]'s runtime tags. `Val<T>` prototypes report theirs through
/// [`ValueType::var_type`], which is what lets [`crate::dsl::Puzzle`]
/// prove a workflow's wiring *before* any job is submitted (MoleDSL v2).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum VarType {
    F64,
    I64,
    U32,
    Bool,
    Str,
    /// Homogeneous array of the element type (fan-ins produce these).
    List(Box<VarType>),
}

impl VarType {
    /// Would a declared input of type `self` accept a supplied value of
    /// type `supplied`? Mirrors the numeric widening of
    /// [`ValueType::from_value`] (`f64` reads `i64`/`u32`, `i64` reads
    /// `u32`, `u32` reads fitting `i64`), element-wise through lists.
    pub fn accepts(&self, supplied: &VarType) -> bool {
        use VarType::*;
        match (self, supplied) {
            (a, b) if a == b => true,
            (F64, I64 | U32) => true,
            (I64, U32) => true,
            // u32 reads an i64 when it fits; statically plausible, the
            // runtime still range-checks
            (U32, I64) => true,
            (List(a), List(b)) => a.accepts(b),
            _ => false,
        }
    }
}

impl std::fmt::Display for VarType {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            VarType::F64 => write!(f, "f64"),
            VarType::I64 => write!(f, "i64"),
            VarType::U32 => write!(f, "u32"),
            VarType::Bool => write!(f, "bool"),
            VarType::Str => write!(f, "string"),
            VarType::List(t) => write!(f, "list<{t}>"),
        }
    }
}

/// A value carried by the dataflow.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    F64(f64),
    I64(i64),
    U32(u32),
    Bool(bool),
    Str(String),
    /// Homogeneous array (exploration fan-ins produce these).
    List(Vec<Value>),
}

impl Value {
    pub fn type_name(&self) -> &'static str {
        match self {
            Value::F64(_) => "f64",
            Value::I64(_) => "i64",
            Value::U32(_) => "u32",
            Value::Bool(_) => "bool",
            Value::Str(_) => "string",
            Value::List(_) => "list",
        }
    }

    /// Static type of this value, when it can be named. `None` only for
    /// an empty list, whose element type is unknowable — validation
    /// treats such a variable as present-but-untyped rather than
    /// guessing (a wrong guess would manufacture false mismatches).
    pub fn var_type(&self) -> Option<VarType> {
        match self {
            Value::F64(_) => Some(VarType::F64),
            Value::I64(_) => Some(VarType::I64),
            Value::U32(_) => Some(VarType::U32),
            Value::Bool(_) => Some(VarType::Bool),
            Value::Str(_) => Some(VarType::Str),
            Value::List(xs) => xs
                .first()
                .and_then(Value::var_type)
                .map(|t| VarType::List(Box::new(t))),
        }
    }

    /// Render for hooks (`ToStringHook`, CSV writers).
    pub fn display(&self) -> String {
        match self {
            Value::F64(v) => format!("{v}"),
            Value::I64(v) => format!("{v}"),
            Value::U32(v) => format!("{v}"),
            Value::Bool(v) => format!("{v}"),
            Value::Str(v) => v.clone(),
            Value::List(v) => {
                let inner: Vec<String> = v.iter().map(Value::display).collect();
                format!("[{}]", inner.join(", "))
            }
        }
    }
}

/// Conversion between Rust types and dataflow [`Value`]s.
pub trait ValueType: Sized + Clone {
    const TYPE_NAME: &'static str;
    /// The static [`VarType`] of this Rust type (drives build-time
    /// dataflow validation).
    fn var_type() -> VarType;
    fn into_value(self) -> Value;
    fn from_value(v: &Value) -> Option<Self>;
}

impl ValueType for f64 {
    const TYPE_NAME: &'static str = "f64";
    fn var_type() -> VarType {
        VarType::F64
    }
    fn into_value(self) -> Value {
        Value::F64(self)
    }
    fn from_value(v: &Value) -> Option<Self> {
        match v {
            Value::F64(x) => Some(*x),
            Value::I64(x) => Some(*x as f64),
            Value::U32(x) => Some(f64::from(*x)),
            _ => None,
        }
    }
}

impl ValueType for i64 {
    const TYPE_NAME: &'static str = "i64";
    fn var_type() -> VarType {
        VarType::I64
    }
    fn into_value(self) -> Value {
        Value::I64(self)
    }
    fn from_value(v: &Value) -> Option<Self> {
        match v {
            Value::I64(x) => Some(*x),
            Value::U32(x) => Some(i64::from(*x)),
            _ => None,
        }
    }
}

impl ValueType for u32 {
    const TYPE_NAME: &'static str = "u32";
    fn var_type() -> VarType {
        VarType::U32
    }
    fn into_value(self) -> Value {
        Value::U32(self)
    }
    fn from_value(v: &Value) -> Option<Self> {
        match v {
            Value::U32(x) => Some(*x),
            Value::I64(x) => u32::try_from(*x).ok(),
            _ => None,
        }
    }
}

impl ValueType for bool {
    const TYPE_NAME: &'static str = "bool";
    fn var_type() -> VarType {
        VarType::Bool
    }
    fn into_value(self) -> Value {
        Value::Bool(self)
    }
    fn from_value(v: &Value) -> Option<Self> {
        match v {
            Value::Bool(x) => Some(*x),
            _ => None,
        }
    }
}

impl ValueType for String {
    const TYPE_NAME: &'static str = "string";
    fn var_type() -> VarType {
        VarType::Str
    }
    fn into_value(self) -> Value {
        Value::Str(self)
    }
    fn from_value(v: &Value) -> Option<Self> {
        match v {
            Value::Str(x) => Some(x.clone()),
            _ => None,
        }
    }
}

impl<T: ValueType> ValueType for Vec<T> {
    const TYPE_NAME: &'static str = "list";
    fn var_type() -> VarType {
        VarType::List(Box::new(T::var_type()))
    }
    fn into_value(self) -> Value {
        Value::List(self.into_iter().map(ValueType::into_value).collect())
    }
    fn from_value(v: &Value) -> Option<Self> {
        match v {
            Value::List(xs) => xs.iter().map(T::from_value).collect(),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        assert_eq!(f64::from_value(&3.5f64.into_value()), Some(3.5));
        assert_eq!(i64::from_value(&7i64.into_value()), Some(7));
        assert_eq!(u32::from_value(&9u32.into_value()), Some(9));
        assert_eq!(bool::from_value(&true.into_value()), Some(true));
        assert_eq!(
            String::from_value(&"x".to_string().into_value()),
            Some("x".to_string())
        );
    }

    #[test]
    fn numeric_widening() {
        // i64/u32 read back as f64 (exploration samplings emit f64)
        assert_eq!(f64::from_value(&Value::I64(4)), Some(4.0));
        assert_eq!(f64::from_value(&Value::U32(4)), Some(4.0));
        // but not bool/str
        assert_eq!(f64::from_value(&Value::Bool(true)), None);
    }

    #[test]
    fn roundtrip_lists() {
        let v = vec![1.0, 2.0, 3.0].into_value();
        assert_eq!(Vec::<f64>::from_value(&v), Some(vec![1.0, 2.0, 3.0]));
        let nested = vec![vec![1.0], vec![2.0]].into_value();
        assert_eq!(
            Vec::<Vec<f64>>::from_value(&nested),
            Some(vec![vec![1.0], vec![2.0]])
        );
    }

    #[test]
    fn var_type_acceptance_mirrors_from_value() {
        use VarType::*;
        assert!(F64.accepts(&I64) && F64.accepts(&U32) && F64.accepts(&F64));
        assert!(I64.accepts(&U32) && U32.accepts(&I64));
        assert!(!I64.accepts(&F64) && !F64.accepts(&Bool) && !Str.accepts(&F64));
        let lf = List(Box::new(F64));
        let lu = List(Box::new(U32));
        assert!(lf.accepts(&lu), "list widening is element-wise");
        assert!(!lu.accepts(&lf));
        assert!(!lf.accepts(&F64), "scalar is not a list");
        assert_eq!(lf.to_string(), "list<f64>");
    }

    #[test]
    fn value_var_type_matches_prototype() {
        assert_eq!(Value::F64(1.0).var_type(), Some(VarType::F64));
        assert_eq!(
            vec![1.0, 2.0].into_value().var_type(),
            Some(VarType::List(Box::new(VarType::F64)))
        );
        assert_eq!(Value::List(Vec::new()).var_type(), None, "empty list");
        assert_eq!(<Vec<Vec<u32>>>::var_type().to_string(), "list<list<u32>>");
    }

    #[test]
    fn display_forms() {
        assert_eq!(Value::F64(2.5).display(), "2.5");
        assert_eq!(
            vec![1.0, 2.0].into_value().display(),
            "[1, 2]"
        );
    }
}
