//! Dynamic values flowing through the dataflow.
//!
//! OpenMOLE's dataflow is typed via Scala generics (`Val[Double]`); here a
//! closed `Value` enum plays the role of the runtime representation while
//! [`crate::core::Val`] carries the static type.

/// A value carried by the dataflow.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    F64(f64),
    I64(i64),
    U32(u32),
    Bool(bool),
    Str(String),
    /// Homogeneous array (exploration fan-ins produce these).
    List(Vec<Value>),
}

impl Value {
    pub fn type_name(&self) -> &'static str {
        match self {
            Value::F64(_) => "f64",
            Value::I64(_) => "i64",
            Value::U32(_) => "u32",
            Value::Bool(_) => "bool",
            Value::Str(_) => "string",
            Value::List(_) => "list",
        }
    }

    /// Render for hooks (`ToStringHook`, CSV writers).
    pub fn display(&self) -> String {
        match self {
            Value::F64(v) => format!("{v}"),
            Value::I64(v) => format!("{v}"),
            Value::U32(v) => format!("{v}"),
            Value::Bool(v) => format!("{v}"),
            Value::Str(v) => v.clone(),
            Value::List(v) => {
                let inner: Vec<String> = v.iter().map(Value::display).collect();
                format!("[{}]", inner.join(", "))
            }
        }
    }
}

/// Conversion between Rust types and dataflow [`Value`]s.
pub trait ValueType: Sized + Clone {
    const TYPE_NAME: &'static str;
    fn into_value(self) -> Value;
    fn from_value(v: &Value) -> Option<Self>;
}

impl ValueType for f64 {
    const TYPE_NAME: &'static str = "f64";
    fn into_value(self) -> Value {
        Value::F64(self)
    }
    fn from_value(v: &Value) -> Option<Self> {
        match v {
            Value::F64(x) => Some(*x),
            Value::I64(x) => Some(*x as f64),
            Value::U32(x) => Some(f64::from(*x)),
            _ => None,
        }
    }
}

impl ValueType for i64 {
    const TYPE_NAME: &'static str = "i64";
    fn into_value(self) -> Value {
        Value::I64(self)
    }
    fn from_value(v: &Value) -> Option<Self> {
        match v {
            Value::I64(x) => Some(*x),
            Value::U32(x) => Some(i64::from(*x)),
            _ => None,
        }
    }
}

impl ValueType for u32 {
    const TYPE_NAME: &'static str = "u32";
    fn into_value(self) -> Value {
        Value::U32(self)
    }
    fn from_value(v: &Value) -> Option<Self> {
        match v {
            Value::U32(x) => Some(*x),
            Value::I64(x) => u32::try_from(*x).ok(),
            _ => None,
        }
    }
}

impl ValueType for bool {
    const TYPE_NAME: &'static str = "bool";
    fn into_value(self) -> Value {
        Value::Bool(self)
    }
    fn from_value(v: &Value) -> Option<Self> {
        match v {
            Value::Bool(x) => Some(*x),
            _ => None,
        }
    }
}

impl ValueType for String {
    const TYPE_NAME: &'static str = "string";
    fn into_value(self) -> Value {
        Value::Str(self)
    }
    fn from_value(v: &Value) -> Option<Self> {
        match v {
            Value::Str(x) => Some(x.clone()),
            _ => None,
        }
    }
}

impl<T: ValueType> ValueType for Vec<T> {
    const TYPE_NAME: &'static str = "list";
    fn into_value(self) -> Value {
        Value::List(self.into_iter().map(ValueType::into_value).collect())
    }
    fn from_value(v: &Value) -> Option<Self> {
        match v {
            Value::List(xs) => xs.iter().map(T::from_value).collect(),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        assert_eq!(f64::from_value(&3.5f64.into_value()), Some(3.5));
        assert_eq!(i64::from_value(&7i64.into_value()), Some(7));
        assert_eq!(u32::from_value(&9u32.into_value()), Some(9));
        assert_eq!(bool::from_value(&true.into_value()), Some(true));
        assert_eq!(
            String::from_value(&"x".to_string().into_value()),
            Some("x".to_string())
        );
    }

    #[test]
    fn numeric_widening() {
        // i64/u32 read back as f64 (exploration samplings emit f64)
        assert_eq!(f64::from_value(&Value::I64(4)), Some(4.0));
        assert_eq!(f64::from_value(&Value::U32(4)), Some(4.0));
        // but not bool/str
        assert_eq!(f64::from_value(&Value::Bool(true)), None);
    }

    #[test]
    fn roundtrip_lists() {
        let v = vec![1.0, 2.0, 3.0].into_value();
        assert_eq!(Vec::<f64>::from_value(&v), Some(vec![1.0, 2.0, 3.0]));
        let nested = vec![vec![1.0], vec![2.0]].into_value();
        assert_eq!(
            Vec::<Vec<f64>>::from_value(&nested),
            Some(vec![vec![1.0], vec![2.0]])
        );
    }

    #[test]
    fn display_forms() {
        assert_eq!(Value::F64(2.5).display(), "2.5");
        assert_eq!(
            vec![1.0, 2.0].into_value().display(),
            "[1, 2]"
        );
    }
}
