//! `Replicate(model, seedFactor, statistic)` — the stochasticity-management
//! pattern of paper §4.4: run the model under several independent seeds and
//! summarise the outputs.

use std::sync::Arc;

use crate::core::Val;
use crate::dsl::puzzle::{CapsuleId, Puzzle};
use crate::dsl::task::{IdentityTask, Task};
use crate::exploration::sampling::SeedSampling;

/// Wire `entry -< model >- statistic` into `puzzle`, exploring `n`
/// independent seeds. Returns (entry, model, statistic) capsule ids so the
/// caller can attach hooks or environments.
pub fn replicate(
    puzzle: &mut Puzzle,
    model: Arc<dyn Task>,
    seed: &Val<u32>,
    n: usize,
    statistic: Arc<dyn Task>,
) -> (CapsuleId, CapsuleId, CapsuleId) {
    let entry = puzzle.capsule(Arc::new(IdentityTask::new("replicate-entry")));
    let model_c = puzzle.capsule(model);
    let stat_c = puzzle.capsule(statistic);
    puzzle.explore(entry, Arc::new(SeedSampling::new(seed, n)), model_c);
    puzzle.aggregate(model_c, stat_c);
    puzzle.entry(entry);
    (entry, model_c, stat_c)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{val_f64, val_u32, Context};
    use crate::dsl::task::ClosureTask;
    use crate::environment::local::LocalEnvironment;
    use crate::exploration::statistics::StatisticTask;
    use crate::util::stats::Descriptor;
    use crate::workflow::MoleExecution;

    #[test]
    fn replication_with_median() {
        let seed = val_u32("seed");
        let out = val_f64("out");
        let med = val_f64("med");
        // model output = seed mod 7 — deterministic per seed, varied across
        let model = ClosureTask::new("m", {
            let (seed, out) = (seed.clone(), out.clone());
            move |ctx| {
                let s = ctx.get(&seed)?;
                Ok(Context::new().with(&out, f64::from(s % 7)))
            }
        })
        .input(&seed)
        .output(&out);
        let stat = StatisticTask::new().statistic(&out, &med, Descriptor::Median);

        let mut p = Puzzle::new();
        replicate(&mut p, Arc::new(model), &seed, 5, Arc::new(stat));
        let result = MoleExecution::new(p, Arc::new(LocalEnvironment::new(4)), 42)
            .start()
            .unwrap();
        assert_eq!(result.outputs.len(), 1);
        let m = result.outputs[0].get(&med).unwrap();
        assert!((0.0..7.0).contains(&m));
        assert_eq!(result.report.jobs, 1 + 5 + 1);
    }
}
