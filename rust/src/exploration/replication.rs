//! `Replicate(model, seedFactor, statistic)` — the stochasticity-management
//! pattern of paper §4.4: run the model under several independent seeds and
//! summarise the outputs.

use std::sync::Arc;

use crate::core::Val;
use crate::dsl::builder::{CapsuleHandle, PuzzleBuilder};
use crate::dsl::task::{IdentityTask, Task};
use crate::exploration::sampling::SeedSampling;

/// Wire `entry -< model >- statistic` into `builder`, exploring `n`
/// independent seeds. Returns the (entry, model, statistic) handles so the
/// caller can attach hooks or environments before building. The entry
/// becomes the builder's entry capsule.
pub fn replicate(
    builder: &PuzzleBuilder,
    model: Arc<dyn Task>,
    seed: &Val<u32>,
    n: usize,
    statistic: Arc<dyn Task>,
) -> (CapsuleHandle, CapsuleHandle, CapsuleHandle) {
    let entry = builder.task(IdentityTask::new("replicate-entry"));
    let model_c = builder.capsule(model);
    let stat_c = builder.capsule(statistic);
    entry.explore(Arc::new(SeedSampling::new(seed, n)), &model_c);
    model_c.aggregate(&stat_c);
    entry.entry();
    (entry, model_c, stat_c)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{val_f64, val_u32, Context};
    use crate::dsl::task::ClosureTask;
    use crate::environment::local::LocalEnvironment;
    use crate::exploration::statistics::StatisticTask;
    use crate::util::stats::Descriptor;
    use crate::workflow::MoleExecution;

    #[test]
    fn replication_with_median() {
        let seed = val_u32("seed");
        let out = val_f64("out");
        let med = val_f64("med");
        // model output = seed mod 7 — deterministic per seed, varied across
        let model = ClosureTask::new("m", {
            let (seed, out) = (seed.clone(), out.clone());
            move |ctx| {
                let s = ctx.get(&seed)?;
                Ok(Context::new().with(&out, f64::from(s % 7)))
            }
        })
        .input(&seed)
        .output(&out);
        let stat = StatisticTask::new().statistic(&out, &med, Descriptor::Median);

        let b = PuzzleBuilder::new();
        replicate(&b, Arc::new(model), &seed, 5, Arc::new(stat));
        let result = MoleExecution::new(
            b.build().unwrap(),
            Arc::new(LocalEnvironment::new(4)),
            42,
        )
        .start()
        .unwrap();
        assert_eq!(result.outputs.len(), 1);
        let m = result.outputs[0].get(&med).unwrap();
        assert!((0.0..7.0).contains(&m));
        assert_eq!(result.report.jobs, 1 + 5 + 1);
    }
}
