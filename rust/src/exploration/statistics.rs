//! `StatisticTask` — aggregate replicated outputs with statistical
//! descriptors (paper §4.4, Listing 3).

use crate::core::{Context, Val, VarSpec, VarType};
use crate::dsl::task::Task;
use crate::error::Result;
use crate::util::stats::Descriptor;

/// One aggregation rule: `statistics += (food1, medNumberFood1, median)`.
struct Rule {
    input: String,
    output: String,
    descriptor: Descriptor,
}

/// Computes summary statistics over array variables produced by a
/// replication's aggregation barrier.
pub struct StatisticTask {
    name: String,
    rules: Vec<Rule>,
}

impl StatisticTask {
    pub fn new() -> Self {
        StatisticTask {
            name: "statistic".into(),
            rules: Vec::new(),
        }
    }

    /// `statistics += (input, output, descriptor)`.
    pub fn statistic(
        mut self,
        input: &Val<f64>,
        output: &Val<f64>,
        descriptor: Descriptor,
    ) -> Self {
        self.rules.push(Rule {
            input: input.name().to_string(),
            output: output.name().to_string(),
            descriptor,
        });
        self
    }
}

impl Default for StatisticTask {
    fn default() -> Self {
        Self::new()
    }
}

impl Task for StatisticTask {
    fn name(&self) -> &str {
        &self.name
    }

    fn input_specs(&self) -> Vec<VarSpec> {
        // each rule consumes the array an aggregation barrier produced
        self.rules
            .iter()
            .map(|r| VarSpec::of(&r.input, VarType::List(Box::new(VarType::F64))))
            .collect()
    }

    fn output_specs(&self) -> Vec<VarSpec> {
        self.rules
            .iter()
            .map(|r| VarSpec::of(&r.output, VarType::F64))
            .collect()
    }

    fn cost_hint(&self) -> f64 {
        0.0
    }

    fn run(&self, ctx: &Context) -> Result<Context> {
        let mut out = Context::new();
        for rule in &self.rules {
            let xs: Vec<f64> = ctx.get(&Val::<Vec<f64>>::new(rule.input.clone()))?;
            out.set(
                &Val::<f64>::new(rule.output.clone()),
                rule.descriptor.apply(&xs),
            );
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::val_f64;
    use crate::core::ValueType;
    use crate::dsl::task::run_checked;

    #[test]
    fn computes_medians() {
        let food1 = val_f64("food1");
        let med1 = val_f64("medFood1");
        let t = StatisticTask::new().statistic(&food1, &med1, Descriptor::Median);
        let mut ctx = Context::new();
        ctx.set_raw("food1", vec![5.0, 1.0, 3.0].into_value());
        let out = run_checked(&t, &ctx).unwrap();
        assert_eq!(out.get(&med1).unwrap(), 3.0);
    }

    #[test]
    fn multiple_rules() {
        let f = val_f64("f");
        let m = val_f64("mean_f");
        let s = val_f64("sd_f");
        let t = StatisticTask::new()
            .statistic(&f, &m, Descriptor::Mean)
            .statistic(&f, &s, Descriptor::StdDev);
        let mut ctx = Context::new();
        ctx.set_raw("f", vec![2.0, 4.0].into_value());
        let out = run_checked(&t, &ctx).unwrap();
        assert_eq!(out.get(&m).unwrap(), 3.0);
        assert!(out.get(&s).unwrap() > 0.0);
    }

    #[test]
    fn missing_array_is_error() {
        let f = val_f64("f");
        let m = val_f64("m");
        let t = StatisticTask::new().statistic(&f, &m, Descriptor::Median);
        assert!(run_checked(&t, &Context::new()).is_err());
    }
}
