//! The distributed design-of-experiments engine (§Exploration tentpole):
//! fan a columnar sample wave through any [`Environment`] — typically the
//! [`Broker`](crate::broker::Broker) — in `chunk`-sized
//! [`Evaluator::evaluate_rows`] jobs.
//!
//! What the paper promises for plain parameter sweeps, not just
//! calibration: submission, failover and restarts are the platform's
//! problem. A [`Sweep`]
//!
//! * regenerates its design deterministically from `(sampling, seed)` —
//!   the journal never stores the design, only evaluated objectives;
//! * derives each row's model seed from `(seed, row)` via
//!   [`row_seed`], so results are independent of chunking, dispatch
//!   order, broker re-routing and resume;
//! * checkpoints every completed chunk as a `sample_block` journal record
//!   (see [`journal::sample_block_record`]);
//! * streams results **in row order** through an optional
//!   [`RowWriter`] — completed out-of-order blocks wait in the objective
//!   matrix until the row cursor reaches them, so the output file is a
//!   pure function of the design and is byte-identical between an
//!   uninterrupted run and a kill + `--resume` (resume rewrites the file
//!   from the journaled prefix, then continues);
//! * optionally **degrades instead of aborting** ([`Sweep::degraded_ok`],
//!   the CLI's `--degraded-ok`): a chunk whose retry budget is exhausted
//!   is recorded as a `degraded_rows` journal record, its rows emit
//!   NaN/null objectives, and the sweep carries on to a `degraded` (not
//!   failed) outcome. On resume, degraded rows stay NaN unless
//!   [`Sweep::retry_degraded`] (`--retry-degraded`) re-opens them.
//!
//! §Out-of-core: with [`Sweep::mem_budget`]/[`Sweep::spill_dir`]
//! (`--mem-budget`/`--spill-dir`) the sweep runs a **bounded-window
//! streaming loop** instead of materialising the design. The sampling
//! must support [`Sampling::sample_into_block`] (Sobol, factorial): each
//! chunk's design rows are regenerated on demand into a recycled window
//! matrix, completed objectives land in a chunk-paged spilled
//! [`RowStore`] whose resident set is capped by the budget, and the
//! in-order drain regenerates each block once more when the row cursor
//! reaches it — so a 10M-row campaign holds O(budget) resident bytes, and
//! every invariant above (byte-identical resume, position-pure seeds,
//! chunking independence) holds unchanged because both modes write the
//! same journal records and the same result file.

use std::collections::VecDeque;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::broker::journal::{self, Journal, SweepEvent};
use crate::core::Context;
use crate::dsl::hook::RowWriter;
use crate::dsl::task::ClosureTask;
use crate::environment::{Environment, Job, JobHandle};
use crate::error::{Error, Result};
use crate::evolution::evaluator::{Evaluator, RowsView};
use crate::exploration::matrix::SampleMatrix;
use crate::exploration::rowstore::RowStore;
use crate::exploration::sampling::Sampling;
use crate::util::json::Json;
use crate::util::rng::{splitmix64, Rng};

/// Incremental completion callback `(done_rows, total_rows)` — invoked
/// once after the resume restore pass and after every settled chunk
/// (evaluated or degraded). `molers serve` streams these to watching
/// clients; callbacks must be cheap and must not block.
pub type ProgressFn = Arc<dyn Fn(u64, u64) + Send + Sync>;

/// The model seed of design row `row` under sweep seed `seed` — a pure
/// function, so any subset of rows can be (re-)evaluated in any order, on
/// any backend, in any chunking, and produce identical objectives.
pub fn row_seed(seed: u64, row: usize) -> u32 {
    let mut s = seed ^ (row as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    splitmix64(&mut s) as u32
}

/// Outcome of a sweep.
///
/// In streaming (out-of-core) mode the result set is never held: `design`
/// is an empty matrix (columns only) and `objectives` is empty — the
/// result file written through the [`RowWriter`] is the product, and the
/// counters/`peak_resident_bytes` summarise the run.
pub struct SweepResult {
    /// The (regenerated) design; columns-only in streaming mode.
    pub design: SampleMatrix,
    /// Row-major objective matrix, `design.len() × n_obj` (empty in
    /// streaming mode).
    pub objectives: Vec<f64>,
    /// Rows evaluated by this run.
    pub evaluated: usize,
    /// Rows restored from journal checkpoints instead of re-evaluated.
    pub resumed: usize,
    /// Rows restored from `degraded_rows` records (NaN objectives, not
    /// re-evaluated) — a subset of the rows in `degraded`.
    pub resumed_degraded: usize,
    /// Every row (ascending) whose objectives are NaN because its retry
    /// budget was exhausted, in this run or a restored one.
    pub degraded: Vec<usize>,
    /// Latest virtual completion across checkpointed and fresh blocks.
    pub virtual_makespan: f64,
    /// High-water mark of resident row-storage bytes (design + objectives
    /// in the default mode; spilled-store arena + window matrices in
    /// streaming mode).
    pub peak_resident_bytes: u64,
    /// Total design rows — equals `design.len()` in the default mode, and
    /// carries the count in streaming mode where the design is not held.
    total_rows: usize,
}

impl SweepResult {
    pub fn rows(&self) -> usize {
        self.total_rows
    }

    /// `"complete"` when every row has real objectives, `"degraded"` when
    /// some rows exhausted their retry budget.
    pub fn outcome(&self) -> &'static str {
        if self.degraded.is_empty() {
            "complete"
        } else {
            "degraded"
        }
    }

    pub fn objectives_row(&self, i: usize) -> &[f64] {
        let n_obj = self.objectives.len() / self.design.len().max(1);
        &self.objectives[i * n_obj..(i + 1) * n_obj]
    }
}

/// Builder + driver for one distributed sweep.
pub struct Sweep {
    sampling: Arc<dyn Sampling>,
    evaluator: Arc<dyn Evaluator>,
    objective_names: Vec<String>,
    chunk: usize,
    journal: Option<Arc<Journal>>,
    writer: Option<Arc<RowWriter>>,
    max_in_flight: usize,
    meta: Vec<(String, Json)>,
    degraded_ok: bool,
    retry_degraded: bool,
    progress: Option<ProgressFn>,
    mem_budget: Option<u64>,
    spill_dir: Option<PathBuf>,
}

/// Default resident budget when only `--spill-dir` is given: 256 MiB.
const DEFAULT_MEM_BUDGET: u64 = 256 << 20;

impl Sweep {
    pub fn new(
        sampling: Arc<dyn Sampling>,
        evaluator: Arc<dyn Evaluator>,
        objective_names: &[&str],
    ) -> Self {
        Sweep {
            sampling,
            evaluator,
            objective_names: objective_names.iter().map(|s| s.to_string()).collect(),
            chunk: 256,
            journal: None,
            writer: None,
            max_in_flight: 4096,
            meta: Vec::new(),
            degraded_ok: false,
            retry_degraded: false,
            progress: None,
            mem_budget: None,
            spill_dir: None,
        }
    }

    /// Record an extra key/value pair in the journal's `run_start` —
    /// design parameters the sampling object cannot introspect (bounds,
    /// factorial step, replications), which a `--resume` must validate
    /// against before trusting the journal's blocks.
    pub fn meta(mut self, key: &str, value: Json) -> Self {
        self.meta.push((key.to_string(), value));
        self
    }

    /// Design rows per environment job (`--chunk`).
    pub fn chunk(mut self, chunk: usize) -> Self {
        self.chunk = chunk.max(1);
        self
    }

    /// Checkpoint completed blocks to `journal`.
    pub fn journal(mut self, journal: Arc<Journal>) -> Self {
        self.journal = Some(journal);
        self
    }

    /// Stream results (design columns then objective columns, row order)
    /// through `writer`.
    pub fn writer(mut self, writer: Arc<RowWriter>) -> Self {
        self.writer = Some(writer);
        self
    }

    /// Backpressure: jobs in flight at once.
    pub fn max_in_flight(mut self, n: usize) -> Self {
        self.max_in_flight = n.max(1);
        self
    }

    /// Degrade instead of aborting (`--degraded-ok`): a chunk whose retry
    /// budget is exhausted journals its rows as `degraded_rows`, emits
    /// NaN objectives for them and the sweep keeps going.
    pub fn degraded_ok(mut self, yes: bool) -> Self {
        self.degraded_ok = yes;
        self
    }

    /// On resume, re-evaluate restored `degraded_rows` instead of keeping
    /// their NaN placeholders (`--retry-degraded`).
    pub fn retry_degraded(mut self, yes: bool) -> Self {
        self.retry_degraded = yes;
        self
    }

    /// Observe incremental completion — see [`ProgressFn`].
    pub fn on_progress(mut self, f: ProgressFn) -> Self {
        self.progress = Some(f);
        self
    }

    /// Cap resident row storage at `bytes` (`--mem-budget`), switching the
    /// sweep into the bounded-window streaming mode (see the module docs).
    /// `None` leaves the default fully-materialised mode unless
    /// [`Sweep::spill_dir`] is set.
    pub fn mem_budget(mut self, bytes: Option<u64>) -> Self {
        self.mem_budget = bytes;
        self
    }

    /// Directory for the objective store's spill file (`--spill-dir`);
    /// setting it switches the sweep into streaming mode (with the
    /// default 256 MiB budget unless [`Sweep::mem_budget`] tightens it).
    /// `None` with a budget set spills under the system temp dir.
    pub fn spill_dir(mut self, dir: Option<PathBuf>) -> Self {
        self.spill_dir = dir;
        self
    }

    /// Run the whole design on `env`.
    pub fn run(&self, env: &dyn Environment, seed: u64) -> Result<SweepResult> {
        self.run_resumable(env, seed, None)
    }

    /// Run, optionally skipping rows already settled by a previous
    /// (killed) run whose journal yielded `resume` events (see
    /// [`journal::sweep_events`]): `sample_block` rows restore their
    /// objectives, `degraded_rows` restore NaN placeholders (kept unless
    /// [`Sweep::retry_degraded`]), applied in write order so a later
    /// successful retry supersedes an earlier degradation. The sweep's
    /// configuration (sampling, seed, evaluator) must match the original
    /// run — the journal stores objectives, not the design.
    pub fn run_resumable(
        &self,
        env: &dyn Environment,
        seed: u64,
        resume: Option<&[SweepEvent]>,
    ) -> Result<SweepResult> {
        let n_obj = self.evaluator.objectives();
        if n_obj != self.objective_names.len() {
            return Err(Error::Evolution(format!(
                "evaluator produces {n_obj} objectives, sweep names {}",
                self.objective_names.len()
            )));
        }
        if !self.sampling.is_columnar() {
            return Err(Error::InvalidWorkflow(format!(
                "sweep needs a columnar sampling; `{}` is context-only",
                self.sampling.name()
            )));
        }
        if self.mem_budget.is_some() || self.spill_dir.is_some() {
            return self.run_streaming(env, seed, resume, n_obj);
        }

        // the design regenerates deterministically from (sampling, seed)
        let mut design = SampleMatrix::new(self.sampling.columns());
        self.sampling.sample_into(&mut design, &mut Rng::new(seed))?;
        let n = design.len();
        if n == 0 {
            return Err(Error::InvalidWorkflow(format!(
                "sampling `{}` produced no samples",
                self.sampling.name()
            )));
        }
        let dim = design.dim();
        let mut objectives = vec![0.0f64; n * n_obj];
        let mut done = vec![false; n];
        let mut degraded = vec![false; n];
        let mut clock = 0.0f64;

        // restore journaled events in write order (any historical
        // chunking): last write wins, so a block that retried a formerly
        // degraded row clears its NaN placeholder
        if let Some(events) = resume {
            for ev in events {
                match ev {
                    SweepEvent::Block(b) => {
                        for (k, row_objs) in b.objectives.iter().enumerate() {
                            let r = b.first_row + k;
                            if r >= n || row_objs.len() != n_obj {
                                return Err(Error::InvalidWorkflow(format!(
                                    "journal block (row {r}, {} objectives) does not \
                                     fit this design ({n} rows, {n_obj} objectives) — \
                                     was the journal written by a different sweep?",
                                    row_objs.len()
                                )));
                            }
                            objectives[r * n_obj..(r + 1) * n_obj]
                                .copy_from_slice(row_objs);
                            done[r] = true;
                            degraded[r] = false;
                        }
                        clock = clock.max(b.clock);
                    }
                    SweepEvent::Degraded(d) => {
                        if self.retry_degraded {
                            continue; // re-open the rows for evaluation
                        }
                        for &r in &d.rows {
                            if r >= n {
                                return Err(Error::InvalidWorkflow(format!(
                                    "journal degraded row {r} does not fit this \
                                     design ({n} rows) — was the journal written by \
                                     a different sweep?"
                                )));
                            }
                            objectives[r * n_obj..(r + 1) * n_obj].fill(f64::NAN);
                            done[r] = true;
                            degraded[r] = true;
                        }
                        clock = clock.max(d.clock);
                    }
                }
            }
        }
        let resumed_degraded = degraded.iter().filter(|&&d| d).count();
        let resumed = done.iter().filter(|&&d| d).count() - resumed_degraded;
        let mut done_rows = resumed + resumed_degraded;
        if let Some(p) = &self.progress {
            p(done_rows as u64, n as u64);
        }

        if let Some(j) = &self.journal {
            let mut fields = vec![
                ("sampling", Json::Str(self.sampling.name().into())),
                // the run_start "seed" field is a lossy f64; the design
                // depends on every bit of the u64, so record it exactly
                // for resume validation
                ("seed_exact", Json::Str(seed.to_string())),
                ("n", Json::Num(n as f64)),
                ("chunk", Json::Num(self.chunk as f64)),
                ("resumed_rows", Json::Num(resumed as f64)),
                ("resumed_degraded", Json::Num(resumed_degraded as f64)),
            ];
            fields.extend(self.meta.iter().map(|(k, v)| (k.as_str(), v.clone())));
            j.append(&journal::run_start(
                if resume.is_some() { "explore-resume" } else { "explore" },
                seed,
                fields,
            ))?;
        }
        if let Some(w) = &self.writer {
            if w.columns().len() != dim + n_obj {
                return Err(Error::InvalidWorkflow(format!(
                    "result writer has {} columns, sweep produces {} (design) + \
                     {n_obj} (objectives)",
                    w.columns().len(),
                    dim
                )));
            }
        }

        // in-order incremental results: the cursor only advances over done
        // rows, so the file is always a prefix of the final result
        let mut cursor = 0usize;
        let mut row_buf: Vec<f64> = Vec::with_capacity(dim + n_obj);
        self.drain_ready(&design, &objectives, &done, &mut cursor, n_obj, &mut row_buf)?;

        // chunk grid over the not-yet-done rows; a block with any pending
        // row is resubmitted whole (done rows inside it re-evaluate to
        // identical values — per-row seeds are position-pure)
        let mut pending: VecDeque<(usize, usize)> = VecDeque::new();
        let mut lo = 0usize;
        while lo < n {
            let hi = (lo + self.chunk).min(n);
            if done[lo..hi].iter().any(|d| !d) {
                pending.push_back((lo, hi));
            }
            lo = hi;
        }

        type Slot = Arc<Mutex<Option<Vec<f64>>>>;
        let mut in_flight: Vec<(usize, usize, Slot, JobHandle)> = Vec::new();
        let mut evaluated = 0usize;
        let cost = self.evaluator.nominal_cost_s();

        while !pending.is_empty() || !in_flight.is_empty() {
            // submit as much as backpressure allows
            while in_flight.len() < self.max_in_flight {
                let Some((lo, hi)) = pending.pop_front() else { break };
                let rows_n = hi - lo;
                let chunk_genomes = design.rows_slice(lo, hi).to_vec();
                let chunk_seeds: Vec<u32> =
                    (lo..hi).map(|r| row_seed(seed, r)).collect();
                let evaluator = Arc::clone(&self.evaluator);
                let slot: Slot = Arc::new(Mutex::new(None));
                let out_slot = Arc::clone(&slot);
                let task = ClosureTask::new("explore", move |_ctx: &Context| {
                    let mut objs = vec![0.0; rows_n * n_obj];
                    evaluator.evaluate_rows(
                        RowsView::new(&chunk_genomes, dim),
                        &chunk_seeds,
                        &mut objs,
                    )?;
                    *out_slot.lock().unwrap() = Some(objs);
                    Ok(Context::new())
                })
                .cost(cost * rows_n as f64);
                let handle = env.submit(Job::new(Arc::new(task), Context::new()));
                in_flight.push((lo, hi, slot, handle));
            }

            // poll; drain every completed block
            let mut progressed = false;
            let mut idx = 0;
            while idx < in_flight.len() {
                match in_flight[idx].3.try_wait() {
                    None => {
                        idx += 1;
                        continue;
                    }
                    Some(Err(e)) => {
                        if !self.degraded_ok {
                            return Err(e);
                        }
                        // graceful degradation: the chunk's retry budget is
                        // spent — journal the exact failed row set, emit NaN
                        // placeholders and carry on
                        progressed = true;
                        let (lo, hi, _slot, _) = in_flight.swap_remove(idx);
                        let mut failed_rows = Vec::new();
                        for r in lo..hi {
                            if !done[r] {
                                objectives[r * n_obj..(r + 1) * n_obj]
                                    .fill(f64::NAN);
                                done[r] = true;
                                degraded[r] = true;
                                failed_rows.push(r);
                            }
                        }
                        if let Some(j) = &self.journal {
                            if !failed_rows.is_empty() {
                                j.append(&journal::degraded_rows_record(
                                    &failed_rows,
                                    clock,
                                    &e.to_string(),
                                ))?;
                            }
                        }
                        done_rows += failed_rows.len();
                        if let Some(p) = &self.progress {
                            p(done_rows as u64, n as u64);
                        }
                        self.drain_ready(
                            &design,
                            &objectives,
                            &done,
                            &mut cursor,
                            n_obj,
                            &mut row_buf,
                        )?;
                    }
                    Some(Ok((_ctx, report))) => {
                        progressed = true;
                        let (lo, hi, slot, _) = in_flight.swap_remove(idx);
                        let objs = slot.lock().unwrap().take().ok_or_else(|| {
                            Error::Evolution(
                                "explore chunk produced no results".into(),
                            )
                        })?;
                        // restored-degraded rows keep their NaN placeholder
                        // (the writer may have streamed it already); the
                        // journal checkpoints only the rows we actually keep
                        let mut newly = 0usize;
                        for (k, r) in (lo..hi).enumerate() {
                            if degraded[r] {
                                continue;
                            }
                            objectives[r * n_obj..(r + 1) * n_obj]
                                .copy_from_slice(&objs[k * n_obj..(k + 1) * n_obj]);
                            if !done[r] {
                                done[r] = true;
                                evaluated += 1;
                                newly += 1;
                            }
                        }
                        done_rows += newly;
                        if let Some(p) = &self.progress {
                            p(done_rows as u64, n as u64);
                        }
                        clock = clock.max(report.virtual_end);
                        if let Some(j) = &self.journal {
                            // one record per contiguous non-degraded run —
                            // a single lo..hi record in the common case
                            let mut start = lo;
                            for r in lo..=hi {
                                if r == hi || degraded[r] {
                                    if r > start {
                                        j.append(&journal::sample_block_record(
                                            start,
                                            n_obj,
                                            &objs[(start - lo) * n_obj
                                                ..(r - lo) * n_obj],
                                            report.virtual_end,
                                        ))?;
                                    }
                                    start = r + 1;
                                }
                            }
                        }
                        self.drain_ready(
                            &design,
                            &objectives,
                            &done,
                            &mut cursor,
                            n_obj,
                            &mut row_buf,
                        )?;
                    }
                }
            }
            if !progressed && !in_flight.is_empty() {
                std::thread::sleep(Duration::from_micros(200));
            }
        }

        debug_assert_eq!(cursor, n, "all rows drained");
        if let Some(w) = &self.writer {
            w.flush()?;
        }
        if let Some(j) = &self.journal {
            j.append(&journal::env_stats_record(env.name(), &env.stats()))?;
            j.append(&journal::run_end(evaluated as u64, clock))?;
        }
        let degraded_rows: Vec<usize> = degraded
            .iter()
            .enumerate()
            .filter_map(|(r, &d)| d.then_some(r))
            .collect();
        let peak_resident_bytes =
            (design.peak_resident_bytes()).max((design.len() * dim * 8) as u64)
                + (objectives.capacity() * 8) as u64;
        Ok(SweepResult {
            design,
            objectives,
            evaluated,
            resumed,
            resumed_degraded,
            degraded: degraded_rows,
            virtual_makespan: clock,
            peak_resident_bytes,
            total_rows: n,
        })
    }

    /// §Out-of-core bounded-window streaming loop: same contract as the
    /// default path in [`Sweep::run_resumable`] — same journal records,
    /// same byte-identical result file — but the design is regenerated
    /// block by block ([`Sampling::sample_into_block`]) and completed
    /// objectives land in a chunk-paged spilled [`RowStore`], so resident
    /// row storage stays bounded by the `--mem-budget` regardless of `n`.
    fn run_streaming(
        &self,
        env: &dyn Environment,
        seed: u64,
        resume: Option<&[SweepEvent]>,
        n_obj: usize,
    ) -> Result<SweepResult> {
        if !self.sampling.supports_blocks() {
            return Err(Error::Config(format!(
                "--mem-budget/--spill-dir need a block-capable sampling \
                 (sobol, factorial); `{}` only exists as a whole design",
                self.sampling.name()
            )));
        }
        let n = self.sampling.size_hint().ok_or_else(|| {
            Error::Config(format!(
                "--mem-budget/--spill-dir need a sampling with a known \
                 size; `{}` reports none",
                self.sampling.name()
            ))
        })?;
        if n == 0 {
            return Err(Error::InvalidWorkflow(format!(
                "sampling `{}` produced no samples",
                self.sampling.name()
            )));
        }
        let columns = self.sampling.columns();
        let dim = columns.len();
        let mem_budget = self.mem_budget.unwrap_or(DEFAULT_MEM_BUDGET);
        let tmp_dir;
        let spill_dir = match &self.spill_dir {
            Some(d) => d.as_path(),
            None => {
                tmp_dir = std::env::temp_dir();
                tmp_dir.as_path()
            }
        };

        let mut st = StreamState {
            sampling: self.sampling.as_ref(),
            writer: self.writer.as_deref(),
            objectives: RowStore::spilled(n_obj, spill_dir, mem_budget, self.chunk)?,
            done: BitVec::new(n),
            degraded: BitVec::new(n),
            cursor: 0,
            n,
            chunk: self.chunk,
            drain_window: SampleMatrix::new(columns),
            drain_lo: usize::MAX,
            obj_buf: Vec::new(),
            row_buf: Vec::with_capacity(dim + n_obj),
            flat_buf: Vec::new(),
            rng: Rng::new(seed),
        };
        st.objectives.grow_rows(n);
        let nan_row = vec![f64::NAN; n_obj];
        let mut clock = 0.0f64;

        // restore journaled events in write order — identical semantics to
        // the default path, writing through the paged store
        if let Some(events) = resume {
            for ev in events {
                match ev {
                    SweepEvent::Block(b) => {
                        st.flat_buf.clear();
                        for (k, row_objs) in b.objectives.iter().enumerate() {
                            let r = b.first_row + k;
                            if r >= n || row_objs.len() != n_obj {
                                return Err(Error::InvalidWorkflow(format!(
                                    "journal block (row {r}, {} objectives) does not \
                                     fit this design ({n} rows, {n_obj} objectives) — \
                                     was the journal written by a different sweep?",
                                    row_objs.len()
                                )));
                            }
                            st.flat_buf.extend_from_slice(row_objs);
                        }
                        st.objectives.write_rows(b.first_row, &st.flat_buf);
                        for k in 0..b.objectives.len() {
                            st.done.set(b.first_row + k);
                            st.degraded.unset(b.first_row + k);
                        }
                        clock = clock.max(b.clock);
                    }
                    SweepEvent::Degraded(d) => {
                        if self.retry_degraded {
                            continue; // re-open the rows for evaluation
                        }
                        for &r in &d.rows {
                            if r >= n {
                                return Err(Error::InvalidWorkflow(format!(
                                    "journal degraded row {r} does not fit this \
                                     design ({n} rows) — was the journal written by \
                                     a different sweep?"
                                )));
                            }
                            st.objectives.write_rows(r, &nan_row);
                            st.done.set(r);
                            st.degraded.set(r);
                        }
                        clock = clock.max(d.clock);
                    }
                }
            }
        }
        let resumed_degraded = st.degraded.count();
        let resumed = st.done.count() - resumed_degraded;
        let mut done_rows = st.done.count();
        if let Some(p) = &self.progress {
            p(done_rows as u64, n as u64);
        }

        if let Some(j) = &self.journal {
            let mut fields = vec![
                ("sampling", Json::Str(self.sampling.name().into())),
                ("seed_exact", Json::Str(seed.to_string())),
                ("n", Json::Num(n as f64)),
                ("chunk", Json::Num(self.chunk as f64)),
                ("resumed_rows", Json::Num(resumed as f64)),
                ("resumed_degraded", Json::Num(resumed_degraded as f64)),
            ];
            fields.extend(self.meta.iter().map(|(k, v)| (k.as_str(), v.clone())));
            j.append(&journal::run_start(
                if resume.is_some() { "explore-resume" } else { "explore" },
                seed,
                fields,
            ))?;
        }
        if let Some(w) = &self.writer {
            if w.columns().len() != dim + n_obj {
                return Err(Error::InvalidWorkflow(format!(
                    "result writer has {} columns, sweep produces {} (design) + \
                     {n_obj} (objectives)",
                    w.columns().len(),
                    dim
                )));
            }
        }
        st.drain()?;

        // chunk grid over the not-yet-done rows
        let mut pending: VecDeque<(usize, usize)> = VecDeque::new();
        let mut lo = 0usize;
        while lo < n {
            let hi = (lo + self.chunk).min(n);
            if (lo..hi).any(|r| !st.done.get(r)) {
                pending.push_back((lo, hi));
            }
            lo = hi;
        }

        // the bounded window: in-flight chunks hold owned genome +
        // objective copies, so their count is capped by the budget too
        let bytes_per_block = (self.chunk * (dim + n_obj) * 8).max(1);
        let window_blocks = (mem_budget as usize / bytes_per_block)
            .clamp(2, self.max_in_flight.max(2));

        type Slot = Arc<Mutex<Option<Vec<f64>>>>;
        let mut in_flight: Vec<(usize, usize, Slot, JobHandle)> = Vec::new();
        let mut evaluated = 0usize;
        let cost = self.evaluator.nominal_cost_s();
        let mut sub_window = SampleMatrix::new(self.sampling.columns());
        let mut sub_rng = Rng::new(seed);

        while !pending.is_empty() || !in_flight.is_empty() {
            while in_flight.len() < window_blocks {
                let Some((lo, hi)) = pending.pop_front() else { break };
                let rows_n = hi - lo;
                sub_window.clear();
                self.sampling
                    .sample_into_block(&mut sub_window, lo..hi, &mut sub_rng)?;
                let chunk_genomes = sub_window.data().to_vec();
                let chunk_seeds: Vec<u32> = (lo..hi).map(|r| row_seed(seed, r)).collect();
                let evaluator = Arc::clone(&self.evaluator);
                let slot: Slot = Arc::new(Mutex::new(None));
                let out_slot = Arc::clone(&slot);
                let task = ClosureTask::new("explore", move |_ctx: &Context| {
                    let mut objs = vec![0.0; rows_n * n_obj];
                    evaluator.evaluate_rows(
                        RowsView::new(&chunk_genomes, dim),
                        &chunk_seeds,
                        &mut objs,
                    )?;
                    *out_slot.lock().unwrap() = Some(objs);
                    Ok(Context::new())
                })
                .cost(cost * rows_n as f64);
                let handle = env.submit(Job::new(Arc::new(task), Context::new()));
                in_flight.push((lo, hi, slot, handle));
            }

            let mut progressed = false;
            let mut idx = 0;
            while idx < in_flight.len() {
                match in_flight[idx].3.try_wait() {
                    None => {
                        idx += 1;
                        continue;
                    }
                    Some(Err(e)) => {
                        if !self.degraded_ok {
                            return Err(e);
                        }
                        progressed = true;
                        let (lo, hi, _slot, _) = in_flight.swap_remove(idx);
                        let mut failed_rows = Vec::new();
                        for r in lo..hi {
                            if !st.done.get(r) {
                                st.objectives.write_rows(r, &nan_row);
                                st.done.set(r);
                                st.degraded.set(r);
                                failed_rows.push(r);
                            }
                        }
                        if let Some(j) = &self.journal {
                            if !failed_rows.is_empty() {
                                j.append(&journal::degraded_rows_record(
                                    &failed_rows,
                                    clock,
                                    &e.to_string(),
                                ))?;
                            }
                        }
                        done_rows += failed_rows.len();
                        if let Some(p) = &self.progress {
                            p(done_rows as u64, n as u64);
                        }
                        st.drain()?;
                    }
                    Some(Ok((_ctx, report))) => {
                        progressed = true;
                        let (lo, hi, slot, _) = in_flight.swap_remove(idx);
                        let objs = slot.lock().unwrap().take().ok_or_else(|| {
                            Error::Evolution("explore chunk produced no results".into())
                        })?;
                        // store + journal one segment per contiguous
                        // non-degraded run (restored-degraded rows keep
                        // their NaN placeholder)
                        let mut start = lo;
                        for r in lo..=hi {
                            if r == hi || st.degraded.get(r) {
                                if r > start {
                                    let seg =
                                        &objs[(start - lo) * n_obj..(r - lo) * n_obj];
                                    st.objectives.write_rows(start, seg);
                                    if let Some(j) = &self.journal {
                                        j.append(&journal::sample_block_record(
                                            start,
                                            n_obj,
                                            seg,
                                            report.virtual_end,
                                        ))?;
                                    }
                                }
                                start = r + 1;
                            }
                        }
                        let mut newly = 0usize;
                        for r in lo..hi {
                            if !st.degraded.get(r) && !st.done.get(r) {
                                st.done.set(r);
                                evaluated += 1;
                                newly += 1;
                            }
                        }
                        done_rows += newly;
                        if let Some(p) = &self.progress {
                            p(done_rows as u64, n as u64);
                        }
                        clock = clock.max(report.virtual_end);
                        st.drain()?;
                    }
                }
            }
            if !progressed && !in_flight.is_empty() {
                std::thread::sleep(Duration::from_micros(200));
            }
        }

        debug_assert_eq!(st.cursor, n, "all rows drained");
        if let Some(w) = &self.writer {
            w.flush()?;
        }
        if let Some(j) = &self.journal {
            j.append(&journal::env_stats_record(env.name(), &env.stats()))?;
            j.append(&journal::run_end(evaluated as u64, clock))?;
        }
        let degraded_rows: Vec<usize> =
            (0..n).filter(|&r| st.degraded.get(r)).collect();
        let peak_resident_bytes = st.objectives.peak_resident_bytes()
            + ((sub_window.capacity_floats() + st.drain_window.capacity_floats()) * 8) as u64;
        Ok(SweepResult {
            design: SampleMatrix::new(self.sampling.columns()),
            objectives: Vec::new(),
            evaluated,
            resumed,
            resumed_degraded,
            degraded: degraded_rows,
            virtual_makespan: clock,
            peak_resident_bytes,
            total_rows: n,
        })
    }

    /// Write every done row the cursor has reached, in row order.
    fn drain_ready(
        &self,
        design: &SampleMatrix,
        objectives: &[f64],
        done: &[bool],
        cursor: &mut usize,
        n_obj: usize,
        row_buf: &mut Vec<f64>,
    ) -> Result<()> {
        let Some(w) = &self.writer else {
            while *cursor < done.len() && done[*cursor] {
                *cursor += 1;
            }
            return Ok(());
        };
        let mut wrote = false;
        while *cursor < done.len() && done[*cursor] {
            let r = *cursor;
            row_buf.clear();
            row_buf.extend_from_slice(design.row(r));
            row_buf.extend_from_slice(&objectives[r * n_obj..(r + 1) * n_obj]);
            w.append_row(row_buf)?;
            *cursor += 1;
            wrote = true;
        }
        if wrote {
            w.flush()?;
        }
        Ok(())
    }
}

/// Minimal bit vector for the streaming sweep's per-row done/degraded
/// flags — one bit per row, so a 10M-row campaign spends ~2.5 MB on
/// bookkeeping instead of two 10 MB `Vec<bool>`s.
struct BitVec {
    words: Vec<u64>,
    ones: usize,
}

impl BitVec {
    fn new(n: usize) -> Self {
        BitVec { words: vec![0; n.div_ceil(64)], ones: 0 }
    }

    fn get(&self, i: usize) -> bool {
        (self.words[i / 64] & (1u64 << (i % 64))) != 0
    }

    fn set(&mut self, i: usize) {
        let w = &mut self.words[i / 64];
        let m = 1u64 << (i % 64);
        if *w & m == 0 {
            *w |= m;
            self.ones += 1;
        }
    }

    fn unset(&mut self, i: usize) {
        let w = &mut self.words[i / 64];
        let m = 1u64 << (i % 64);
        if *w & m != 0 {
            *w &= !m;
            self.ones -= 1;
        }
    }

    fn count(&self) -> usize {
        self.ones
    }
}

/// Mutable state of one streaming sweep: the spilled objective store, the
/// per-row flags, and the in-order drain cursor with its recycled window.
///
/// The drain regenerates each block's design at most once per visit (the
/// window is keyed by block start), so steady-state draining costs one
/// `sample_into_block` per block plus paged reads from the objective
/// store — never a whole-design materialisation.
struct StreamState<'a> {
    sampling: &'a dyn Sampling,
    writer: Option<&'a RowWriter>,
    objectives: RowStore,
    done: BitVec,
    degraded: BitVec,
    cursor: usize,
    n: usize,
    chunk: usize,
    drain_window: SampleMatrix,
    /// First row resident in `drain_window`; `usize::MAX` = nothing cached.
    drain_lo: usize,
    obj_buf: Vec<f64>,
    row_buf: Vec<f64>,
    flat_buf: Vec<f64>,
    rng: Rng,
}

impl StreamState<'_> {
    /// Advance the in-order cursor over done rows, regenerating each
    /// drained block's design once and appending design + objective rows
    /// to the writer. Without a writer this only advances the cursor.
    fn drain(&mut self) -> Result<()> {
        let Some(w) = self.writer else {
            while self.cursor < self.n && self.done.get(self.cursor) {
                self.cursor += 1;
            }
            return Ok(());
        };
        let mut wrote = false;
        while self.cursor < self.n && self.done.get(self.cursor) {
            let r = self.cursor;
            let blk_lo = r - r % self.chunk;
            let blk_hi = (blk_lo + self.chunk).min(self.n);
            if self.drain_lo != blk_lo {
                self.drain_window.clear();
                self.sampling.sample_into_block(
                    &mut self.drain_window,
                    blk_lo..blk_hi,
                    &mut self.rng,
                )?;
                self.drain_lo = blk_lo;
            }
            self.objectives.copy_rows(r, r + 1, &mut self.obj_buf);
            self.row_buf.clear();
            self.row_buf.extend_from_slice(self.drain_window.row(r - blk_lo));
            self.row_buf.extend_from_slice(&self.obj_buf);
            w.append_row(&self.row_buf)?;
            self.cursor += 1;
            wrote = true;
        }
        if wrote {
            w.flush()?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::broker::fault::{FaultPlan, FaultyEnv};
    use crate::broker::journal::{DegradedRows, SampleBlock};
    use crate::core::val_f64;
    use crate::environment::local::LocalEnvironment;
    use crate::evolution::evaluator::{CountingEvaluator, Zdt1Evaluator};
    use crate::exploration::sampling::{ExplicitSampling, LhsSampling, SobolSampling};

    fn lhs3(n: usize) -> Arc<dyn Sampling> {
        let x0 = val_f64("x0");
        let x1 = val_f64("x1");
        let x2 = val_f64("x2");
        Arc::new(LhsSampling::new(
            &[(&x0, 0.0, 1.0), (&x1, 0.0, 1.0), (&x2, 0.0, 1.0)],
            n,
        ))
    }

    #[test]
    fn sweep_evaluates_every_row_once() {
        let env = LocalEnvironment::new(4);
        let counting = Arc::new(CountingEvaluator::new(Zdt1Evaluator { dim: 3 }));
        let sweep = Sweep::new(lhs3(97), Arc::clone(&counting) as _, &["f1", "f2"])
            .chunk(16);
        let result = sweep.run(&env, 42).unwrap();
        assert_eq!(result.rows(), 97);
        assert_eq!(result.evaluated, 97);
        assert_eq!(result.resumed, 0);
        assert_eq!(counting.count(), 97);
        // objectives agree with a direct evaluation under the same seeds
        let serial = Zdt1Evaluator { dim: 3 };
        for i in [0usize, 13, 96] {
            let want = serial
                .evaluate(result.design.row(i), row_seed(42, i))
                .unwrap();
            assert_eq!(result.objectives_row(i), want.as_slice(), "row {i}");
        }
    }

    #[test]
    fn sweep_is_chunking_independent() {
        let env = LocalEnvironment::new(4);
        let run = |chunk: usize| {
            Sweep::new(lhs3(41), Arc::new(Zdt1Evaluator { dim: 3 }), &["f1", "f2"])
                .chunk(chunk)
                .run(&env, 7)
                .unwrap()
        };
        let a = run(1);
        let b = run(8);
        let c = run(64);
        assert_eq!(a.objectives, b.objectives, "chunk 1 vs 8");
        assert_eq!(a.objectives, c.objectives, "chunk 1 vs 64");
    }

    #[test]
    fn resume_skips_restored_rows() {
        let env = LocalEnvironment::new(2);
        let full = Sweep::new(
            lhs3(30),
            Arc::new(Zdt1Evaluator { dim: 3 }),
            &["f1", "f2"],
        )
        .chunk(10)
        .run(&env, 5)
        .unwrap();

        // pretend the first two blocks were journaled before a kill
        let events: Vec<SweepEvent> = (0..2)
            .map(|k| {
                SweepEvent::Block(SampleBlock {
                    first_row: k * 10,
                    objectives: (k * 10..(k + 1) * 10)
                        .map(|r| full.objectives_row(r).to_vec())
                        .collect(),
                    clock: 50.0,
                })
            })
            .collect();
        let counting = Arc::new(CountingEvaluator::new(Zdt1Evaluator { dim: 3 }));
        let resumed = Sweep::new(lhs3(30), Arc::clone(&counting) as _, &["f1", "f2"])
            .chunk(10)
            .run_resumable(&env, 5, Some(&events))
            .unwrap();
        assert_eq!(resumed.resumed, 20);
        assert_eq!(resumed.evaluated, 10);
        assert_eq!(counting.count(), 10, "restored rows must not re-evaluate");
        assert_eq!(resumed.objectives, full.objectives);
        assert!(resumed.virtual_makespan >= 50.0);
    }

    #[test]
    fn resume_tolerates_a_different_chunk_grid() {
        let env = LocalEnvironment::new(2);
        let full = Sweep::new(
            lhs3(25),
            Arc::new(Zdt1Evaluator { dim: 3 }),
            &["f1", "f2"],
        )
        .chunk(7)
        .run(&env, 9)
        .unwrap();
        // one journaled block that straddles the new grid
        let events = [SweepEvent::Block(SampleBlock {
            first_row: 3,
            objectives: (3..12).map(|r| full.objectives_row(r).to_vec()).collect(),
            clock: 1.0,
        })];
        let resumed = Sweep::new(
            lhs3(25),
            Arc::new(Zdt1Evaluator { dim: 3 }),
            &["f1", "f2"],
        )
        .chunk(4)
        .run_resumable(&env, 9, Some(&events))
        .unwrap();
        assert_eq!(resumed.objectives, full.objectives);
        assert_eq!(resumed.resumed, 9);
    }

    #[test]
    fn sweep_rejects_context_only_samplings_and_foreign_journals() {
        let env = LocalEnvironment::new(1);
        let explicit = Arc::new(ExplicitSampling::new(vec![Context::new()]));
        assert!(Sweep::new(explicit, Arc::new(Zdt1Evaluator { dim: 3 }), &["f1", "f2"])
            .run(&env, 1)
            .is_err());

        let events = [SweepEvent::Block(SampleBlock {
            first_row: 90,
            objectives: vec![vec![1.0, 2.0]; 20],
            clock: 0.0,
        })];
        let err = Sweep::new(lhs3(10), Arc::new(Zdt1Evaluator { dim: 3 }), &["f1", "f2"])
            .run_resumable(&env, 1, Some(&events))
            .unwrap_err();
        assert!(
            err.to_string().contains("does not fit"),
            "unexpected error: {err}"
        );
    }

    #[test]
    fn degraded_ok_turns_exhausted_chunks_into_nan_rows() {
        // crash the second submission (rows 10..20) terminally
        let plan = FaultPlan::new().crash_window(1, 1);
        let make_env =
            || FaultyEnv::new(Arc::new(LocalEnvironment::new(2)), plan.clone(), 0xC0);

        // without the flag the failure aborts the sweep
        let err = Sweep::new(lhs3(30), Arc::new(Zdt1Evaluator { dim: 3 }), &["f1", "f2"])
            .chunk(10)
            .run(&make_env(), 5)
            .unwrap_err();
        assert!(
            err.to_string().contains("crash window"),
            "unexpected error: {err}"
        );

        let result = Sweep::new(lhs3(30), Arc::new(Zdt1Evaluator { dim: 3 }), &["f1", "f2"])
            .chunk(10)
            .degraded_ok(true)
            .run(&make_env(), 5)
            .unwrap();
        assert_eq!(result.outcome(), "degraded");
        assert_eq!(result.degraded, (10..20).collect::<Vec<_>>());
        assert_eq!(result.evaluated, 20);
        for r in 0..30 {
            let nan = result.objectives_row(r).iter().all(|v| v.is_nan());
            assert_eq!(nan, (10..20).contains(&r), "row {r}");
        }
    }

    #[test]
    fn resume_keeps_degraded_rows_unless_retry_requested() {
        let env = LocalEnvironment::new(2);
        let full = Sweep::new(lhs3(30), Arc::new(Zdt1Evaluator { dim: 3 }), &["f1", "f2"])
            .chunk(10)
            .run(&env, 5)
            .unwrap();
        let events = vec![
            SweepEvent::Block(SampleBlock {
                first_row: 0,
                objectives: (0..10).map(|r| full.objectives_row(r).to_vec()).collect(),
                clock: 1.0,
            }),
            SweepEvent::Degraded(DegradedRows {
                rows: (10..20).collect(),
                clock: 2.0,
            }),
        ];

        let counting = Arc::new(CountingEvaluator::new(Zdt1Evaluator { dim: 3 }));
        let resumed = Sweep::new(lhs3(30), Arc::clone(&counting) as _, &["f1", "f2"])
            .chunk(10)
            .run_resumable(&env, 5, Some(&events))
            .unwrap();
        assert_eq!(resumed.resumed, 10);
        assert_eq!(resumed.resumed_degraded, 10);
        assert_eq!(resumed.evaluated, 10);
        assert_eq!(counting.count(), 10, "degraded rows must not re-evaluate");
        assert_eq!(resumed.outcome(), "degraded");
        assert!(resumed.objectives_row(12).iter().all(|v| v.is_nan()));

        // --retry-degraded re-opens them on a healthy environment
        let counting = Arc::new(CountingEvaluator::new(Zdt1Evaluator { dim: 3 }));
        let retried = Sweep::new(lhs3(30), Arc::clone(&counting) as _, &["f1", "f2"])
            .chunk(10)
            .retry_degraded(true)
            .run_resumable(&env, 5, Some(&events))
            .unwrap();
        assert_eq!(counting.count(), 20);
        assert_eq!(retried.outcome(), "complete");
        assert_eq!(retried.objectives, full.objectives);
    }

    #[test]
    fn later_block_supersedes_earlier_degradation() {
        let env = LocalEnvironment::new(2);
        let full = Sweep::new(lhs3(30), Arc::new(Zdt1Evaluator { dim: 3 }), &["f1", "f2"])
            .chunk(10)
            .run(&env, 5)
            .unwrap();
        // a retry after a degradation journals a fresh block: last write wins
        let events = vec![
            SweepEvent::Degraded(DegradedRows {
                rows: vec![0, 1, 2],
                clock: 1.0,
            }),
            SweepEvent::Block(SampleBlock {
                first_row: 0,
                objectives: (0..10).map(|r| full.objectives_row(r).to_vec()).collect(),
                clock: 2.0,
            }),
        ];
        let resumed = Sweep::new(lhs3(30), Arc::new(Zdt1Evaluator { dim: 3 }), &["f1", "f2"])
            .chunk(10)
            .run_resumable(&env, 5, Some(&events))
            .unwrap();
        assert_eq!(resumed.resumed, 10);
        assert_eq!(resumed.resumed_degraded, 0);
        assert_eq!(resumed.outcome(), "complete");
        assert_eq!(resumed.objectives, full.objectives);
    }

    #[test]
    fn sobol_sweep_is_reproducible_across_runs() {
        let env = LocalEnvironment::new(2);
        let x = val_f64("x0");
        let y = val_f64("x1");
        let make = || {
            let s: Arc<dyn Sampling> = Arc::new(SobolSampling::new(
                &[(&x, 0.0, 1.0), (&y, 0.0, 1.0)],
                33,
            ));
            Sweep::new(s, Arc::new(Zdt1Evaluator { dim: 2 }), &["f1", "f2"]).chunk(5)
        };
        let a = make().run(&env, 3).unwrap();
        let b = make().run(&env, 3).unwrap();
        assert_eq!(a.design.data(), b.design.data());
        assert_eq!(a.objectives, b.objectives);
    }

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!(
            "molers-sweep-stream-{}-{name}",
            std::process::id()
        ))
    }

    #[test]
    fn streaming_sweep_writes_a_byte_identical_result_file() {
        use crate::dsl::hook::TableFormat;
        let env = LocalEnvironment::new(2);
        let x = val_f64("x0");
        let y = val_f64("x1");
        let sampling = || -> Arc<dyn Sampling> {
            Arc::new(SobolSampling::new(&[(&x, 0.0, 1.0), (&y, 0.0, 1.0)], 103))
        };
        let cols = ["x0", "x1", "f1", "f2"];

        let plain_out = tmp("plain.csv");
        let plain_writer =
            Arc::new(RowWriter::create(&plain_out, TableFormat::Csv, &cols).unwrap());
        let plain = Sweep::new(sampling(), Arc::new(Zdt1Evaluator { dim: 2 }), &["f1", "f2"])
            .chunk(16)
            .writer(Arc::clone(&plain_writer))
            .run(&env, 11)
            .unwrap();

        // a budget of one chunk of objectives: everything pages through the
        // spill file, yet the result file must not change by one byte
        let spill_dir = tmp("spill");
        let stream_out = tmp("stream.csv");
        let stream_writer =
            Arc::new(RowWriter::create(&stream_out, TableFormat::Csv, &cols).unwrap());
        let streamed = Sweep::new(sampling(), Arc::new(Zdt1Evaluator { dim: 2 }), &["f1", "f2"])
            .chunk(16)
            .writer(Arc::clone(&stream_writer))
            .mem_budget(Some(16 * 2 * 8))
            .spill_dir(Some(spill_dir.clone()))
            .run(&env, 11)
            .unwrap();
        assert_eq!(streamed.rows(), 103);
        assert_eq!(streamed.evaluated, 103);
        assert_eq!(streamed.outcome(), plain.outcome());
        assert!(streamed.peak_resident_bytes > 0);

        let plain_bytes = std::fs::read(&plain_out).unwrap();
        let stream_bytes = std::fs::read(&stream_out).unwrap();
        assert_eq!(plain_bytes, stream_bytes, "spilled run diverged");
        let _ = std::fs::remove_file(&plain_out);
        let _ = std::fs::remove_file(&stream_out);
        let _ = std::fs::remove_dir_all(&spill_dir);
    }

    #[test]
    fn streaming_sweep_resumes_and_degrades_like_the_default_path() {
        let env = LocalEnvironment::new(2);
        let x = val_f64("x0");
        let y = val_f64("x1");
        let sampling = || -> Arc<dyn Sampling> {
            Arc::new(SobolSampling::new(&[(&x, 0.0, 1.0), (&y, 0.0, 1.0)], 30))
        };
        let spill_dir = tmp("resume-spill");
        let stream = |events: Option<&[SweepEvent]>, counting: &Arc<CountingEvaluator<Zdt1Evaluator>>| {
            Sweep::new(sampling(), Arc::clone(counting) as _, &["f1", "f2"])
                .chunk(10)
                .mem_budget(Some(10 * 2 * 8))
                .spill_dir(Some(spill_dir.clone()))
                .run_resumable(&env, 5, events)
                .unwrap()
        };

        let full_eval = Arc::new(CountingEvaluator::new(Zdt1Evaluator { dim: 2 }));
        let full = Sweep::new(sampling(), Arc::clone(&full_eval) as _, &["f1", "f2"])
            .chunk(10)
            .run(&env, 5)
            .unwrap();

        let events = vec![
            SweepEvent::Block(SampleBlock {
                first_row: 0,
                objectives: (0..10).map(|r| full.objectives_row(r).to_vec()).collect(),
                clock: 1.0,
            }),
            SweepEvent::Degraded(DegradedRows {
                rows: (10..20).collect(),
                clock: 2.0,
            }),
        ];
        let counting = Arc::new(CountingEvaluator::new(Zdt1Evaluator { dim: 2 }));
        let resumed = stream(Some(&events), &counting);
        assert_eq!(resumed.resumed, 10);
        assert_eq!(resumed.resumed_degraded, 10);
        assert_eq!(resumed.evaluated, 10);
        assert_eq!(counting.count(), 10, "restored rows must not re-evaluate");
        assert_eq!(resumed.outcome(), "degraded");
        assert_eq!(resumed.degraded, (10..20).collect::<Vec<_>>());

        // a sequential sampling cannot stream: the error names the limit
        let err = Sweep::new(lhs3(10), Arc::new(Zdt1Evaluator { dim: 3 }), &["f1", "f2"])
            .mem_budget(Some(1 << 20))
            .run(&env, 1)
            .unwrap_err();
        assert!(
            err.to_string().contains("block-capable"),
            "unexpected error: {err}"
        );
        let _ = std::fs::remove_dir_all(&spill_dir);
    }
}
