//! The distributed design-of-experiments engine (§Exploration tentpole):
//! fan a columnar sample wave through any [`Environment`] — typically the
//! [`Broker`](crate::broker::Broker) — in `chunk`-sized
//! [`Evaluator::evaluate_rows`] jobs.
//!
//! What the paper promises for plain parameter sweeps, not just
//! calibration: submission, failover and restarts are the platform's
//! problem. A [`Sweep`]
//!
//! * regenerates its design deterministically from `(sampling, seed)` —
//!   the journal never stores the design, only evaluated objectives;
//! * derives each row's model seed from `(seed, row)` via
//!   [`row_seed`], so results are independent of chunking, dispatch
//!   order, broker re-routing and resume;
//! * checkpoints every completed chunk as a `sample_block` journal record
//!   (see [`journal::sample_block_record`]);
//! * streams results **in row order** through an optional
//!   [`RowWriter`] — completed out-of-order blocks wait in the objective
//!   matrix until the row cursor reaches them, so the output file is a
//!   pure function of the design and is byte-identical between an
//!   uninterrupted run and a kill + `--resume` (resume rewrites the file
//!   from the journaled prefix, then continues);
//! * optionally **degrades instead of aborting** ([`Sweep::degraded_ok`],
//!   the CLI's `--degraded-ok`): a chunk whose retry budget is exhausted
//!   is recorded as a `degraded_rows` journal record, its rows emit
//!   NaN/null objectives, and the sweep carries on to a `degraded` (not
//!   failed) outcome. On resume, degraded rows stay NaN unless
//!   [`Sweep::retry_degraded`] (`--retry-degraded`) re-opens them.

use std::collections::VecDeque;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::broker::journal::{self, Journal, SweepEvent};
use crate::core::Context;
use crate::dsl::hook::RowWriter;
use crate::dsl::task::ClosureTask;
use crate::environment::{Environment, Job, JobHandle};
use crate::error::{Error, Result};
use crate::evolution::evaluator::{Evaluator, RowsView};
use crate::exploration::matrix::SampleMatrix;
use crate::exploration::sampling::Sampling;
use crate::util::json::Json;
use crate::util::rng::{splitmix64, Rng};

/// Incremental completion callback `(done_rows, total_rows)` — invoked
/// once after the resume restore pass and after every settled chunk
/// (evaluated or degraded). `molers serve` streams these to watching
/// clients; callbacks must be cheap and must not block.
pub type ProgressFn = Arc<dyn Fn(u64, u64) + Send + Sync>;

/// The model seed of design row `row` under sweep seed `seed` — a pure
/// function, so any subset of rows can be (re-)evaluated in any order, on
/// any backend, in any chunking, and produce identical objectives.
pub fn row_seed(seed: u64, row: usize) -> u32 {
    let mut s = seed ^ (row as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    splitmix64(&mut s) as u32
}

/// Outcome of a sweep.
pub struct SweepResult {
    /// The (regenerated) design.
    pub design: SampleMatrix,
    /// Row-major objective matrix, `design.len() × n_obj`.
    pub objectives: Vec<f64>,
    /// Rows evaluated by this run.
    pub evaluated: usize,
    /// Rows restored from journal checkpoints instead of re-evaluated.
    pub resumed: usize,
    /// Rows restored from `degraded_rows` records (NaN objectives, not
    /// re-evaluated) — a subset of the rows in `degraded`.
    pub resumed_degraded: usize,
    /// Every row (ascending) whose objectives are NaN because its retry
    /// budget was exhausted, in this run or a restored one.
    pub degraded: Vec<usize>,
    /// Latest virtual completion across checkpointed and fresh blocks.
    pub virtual_makespan: f64,
}

impl SweepResult {
    pub fn rows(&self) -> usize {
        self.design.len()
    }

    /// `"complete"` when every row has real objectives, `"degraded"` when
    /// some rows exhausted their retry budget.
    pub fn outcome(&self) -> &'static str {
        if self.degraded.is_empty() {
            "complete"
        } else {
            "degraded"
        }
    }

    pub fn objectives_row(&self, i: usize) -> &[f64] {
        let n_obj = self.objectives.len() / self.design.len().max(1);
        &self.objectives[i * n_obj..(i + 1) * n_obj]
    }
}

/// Builder + driver for one distributed sweep.
pub struct Sweep {
    sampling: Arc<dyn Sampling>,
    evaluator: Arc<dyn Evaluator>,
    objective_names: Vec<String>,
    chunk: usize,
    journal: Option<Arc<Journal>>,
    writer: Option<Arc<RowWriter>>,
    max_in_flight: usize,
    meta: Vec<(String, Json)>,
    degraded_ok: bool,
    retry_degraded: bool,
    progress: Option<ProgressFn>,
}

impl Sweep {
    pub fn new(
        sampling: Arc<dyn Sampling>,
        evaluator: Arc<dyn Evaluator>,
        objective_names: &[&str],
    ) -> Self {
        Sweep {
            sampling,
            evaluator,
            objective_names: objective_names.iter().map(|s| s.to_string()).collect(),
            chunk: 256,
            journal: None,
            writer: None,
            max_in_flight: 4096,
            meta: Vec::new(),
            degraded_ok: false,
            retry_degraded: false,
            progress: None,
        }
    }

    /// Record an extra key/value pair in the journal's `run_start` —
    /// design parameters the sampling object cannot introspect (bounds,
    /// factorial step, replications), which a `--resume` must validate
    /// against before trusting the journal's blocks.
    pub fn meta(mut self, key: &str, value: Json) -> Self {
        self.meta.push((key.to_string(), value));
        self
    }

    /// Design rows per environment job (`--chunk`).
    pub fn chunk(mut self, chunk: usize) -> Self {
        self.chunk = chunk.max(1);
        self
    }

    /// Checkpoint completed blocks to `journal`.
    pub fn journal(mut self, journal: Arc<Journal>) -> Self {
        self.journal = Some(journal);
        self
    }

    /// Stream results (design columns then objective columns, row order)
    /// through `writer`.
    pub fn writer(mut self, writer: Arc<RowWriter>) -> Self {
        self.writer = Some(writer);
        self
    }

    /// Backpressure: jobs in flight at once.
    pub fn max_in_flight(mut self, n: usize) -> Self {
        self.max_in_flight = n.max(1);
        self
    }

    /// Degrade instead of aborting (`--degraded-ok`): a chunk whose retry
    /// budget is exhausted journals its rows as `degraded_rows`, emits
    /// NaN objectives for them and the sweep keeps going.
    pub fn degraded_ok(mut self, yes: bool) -> Self {
        self.degraded_ok = yes;
        self
    }

    /// On resume, re-evaluate restored `degraded_rows` instead of keeping
    /// their NaN placeholders (`--retry-degraded`).
    pub fn retry_degraded(mut self, yes: bool) -> Self {
        self.retry_degraded = yes;
        self
    }

    /// Observe incremental completion — see [`ProgressFn`].
    pub fn on_progress(mut self, f: ProgressFn) -> Self {
        self.progress = Some(f);
        self
    }

    /// Run the whole design on `env`.
    pub fn run(&self, env: &dyn Environment, seed: u64) -> Result<SweepResult> {
        self.run_resumable(env, seed, None)
    }

    /// Run, optionally skipping rows already settled by a previous
    /// (killed) run whose journal yielded `resume` events (see
    /// [`journal::sweep_events`]): `sample_block` rows restore their
    /// objectives, `degraded_rows` restore NaN placeholders (kept unless
    /// [`Sweep::retry_degraded`]), applied in write order so a later
    /// successful retry supersedes an earlier degradation. The sweep's
    /// configuration (sampling, seed, evaluator) must match the original
    /// run — the journal stores objectives, not the design.
    pub fn run_resumable(
        &self,
        env: &dyn Environment,
        seed: u64,
        resume: Option<&[SweepEvent]>,
    ) -> Result<SweepResult> {
        let n_obj = self.evaluator.objectives();
        if n_obj != self.objective_names.len() {
            return Err(Error::Evolution(format!(
                "evaluator produces {n_obj} objectives, sweep names {}",
                self.objective_names.len()
            )));
        }
        if !self.sampling.is_columnar() {
            return Err(Error::InvalidWorkflow(format!(
                "sweep needs a columnar sampling; `{}` is context-only",
                self.sampling.name()
            )));
        }

        // the design regenerates deterministically from (sampling, seed)
        let mut design = SampleMatrix::new(self.sampling.columns());
        self.sampling.sample_into(&mut design, &mut Rng::new(seed))?;
        let n = design.len();
        if n == 0 {
            return Err(Error::InvalidWorkflow(format!(
                "sampling `{}` produced no samples",
                self.sampling.name()
            )));
        }
        let dim = design.dim();
        let mut objectives = vec![0.0f64; n * n_obj];
        let mut done = vec![false; n];
        let mut degraded = vec![false; n];
        let mut clock = 0.0f64;

        // restore journaled events in write order (any historical
        // chunking): last write wins, so a block that retried a formerly
        // degraded row clears its NaN placeholder
        if let Some(events) = resume {
            for ev in events {
                match ev {
                    SweepEvent::Block(b) => {
                        for (k, row_objs) in b.objectives.iter().enumerate() {
                            let r = b.first_row + k;
                            if r >= n || row_objs.len() != n_obj {
                                return Err(Error::InvalidWorkflow(format!(
                                    "journal block (row {r}, {} objectives) does not \
                                     fit this design ({n} rows, {n_obj} objectives) — \
                                     was the journal written by a different sweep?",
                                    row_objs.len()
                                )));
                            }
                            objectives[r * n_obj..(r + 1) * n_obj]
                                .copy_from_slice(row_objs);
                            done[r] = true;
                            degraded[r] = false;
                        }
                        clock = clock.max(b.clock);
                    }
                    SweepEvent::Degraded(d) => {
                        if self.retry_degraded {
                            continue; // re-open the rows for evaluation
                        }
                        for &r in &d.rows {
                            if r >= n {
                                return Err(Error::InvalidWorkflow(format!(
                                    "journal degraded row {r} does not fit this \
                                     design ({n} rows) — was the journal written by \
                                     a different sweep?"
                                )));
                            }
                            objectives[r * n_obj..(r + 1) * n_obj].fill(f64::NAN);
                            done[r] = true;
                            degraded[r] = true;
                        }
                        clock = clock.max(d.clock);
                    }
                }
            }
        }
        let resumed_degraded = degraded.iter().filter(|&&d| d).count();
        let resumed = done.iter().filter(|&&d| d).count() - resumed_degraded;
        let mut done_rows = resumed + resumed_degraded;
        if let Some(p) = &self.progress {
            p(done_rows as u64, n as u64);
        }

        if let Some(j) = &self.journal {
            let mut fields = vec![
                ("sampling", Json::Str(self.sampling.name().into())),
                // the run_start "seed" field is a lossy f64; the design
                // depends on every bit of the u64, so record it exactly
                // for resume validation
                ("seed_exact", Json::Str(seed.to_string())),
                ("n", Json::Num(n as f64)),
                ("chunk", Json::Num(self.chunk as f64)),
                ("resumed_rows", Json::Num(resumed as f64)),
                ("resumed_degraded", Json::Num(resumed_degraded as f64)),
            ];
            fields.extend(self.meta.iter().map(|(k, v)| (k.as_str(), v.clone())));
            j.append(&journal::run_start(
                if resume.is_some() { "explore-resume" } else { "explore" },
                seed,
                fields,
            ))?;
        }
        if let Some(w) = &self.writer {
            if w.columns().len() != dim + n_obj {
                return Err(Error::InvalidWorkflow(format!(
                    "result writer has {} columns, sweep produces {} (design) + \
                     {n_obj} (objectives)",
                    w.columns().len(),
                    dim
                )));
            }
        }

        // in-order incremental results: the cursor only advances over done
        // rows, so the file is always a prefix of the final result
        let mut cursor = 0usize;
        let mut row_buf: Vec<f64> = Vec::with_capacity(dim + n_obj);
        self.drain_ready(&design, &objectives, &done, &mut cursor, n_obj, &mut row_buf)?;

        // chunk grid over the not-yet-done rows; a block with any pending
        // row is resubmitted whole (done rows inside it re-evaluate to
        // identical values — per-row seeds are position-pure)
        let mut pending: VecDeque<(usize, usize)> = VecDeque::new();
        let mut lo = 0usize;
        while lo < n {
            let hi = (lo + self.chunk).min(n);
            if done[lo..hi].iter().any(|d| !d) {
                pending.push_back((lo, hi));
            }
            lo = hi;
        }

        type Slot = Arc<Mutex<Option<Vec<f64>>>>;
        let mut in_flight: Vec<(usize, usize, Slot, JobHandle)> = Vec::new();
        let mut evaluated = 0usize;
        let cost = self.evaluator.nominal_cost_s();

        while !pending.is_empty() || !in_flight.is_empty() {
            // submit as much as backpressure allows
            while in_flight.len() < self.max_in_flight {
                let Some((lo, hi)) = pending.pop_front() else { break };
                let rows_n = hi - lo;
                let chunk_genomes = design.rows_slice(lo, hi).to_vec();
                let chunk_seeds: Vec<u32> =
                    (lo..hi).map(|r| row_seed(seed, r)).collect();
                let evaluator = Arc::clone(&self.evaluator);
                let slot: Slot = Arc::new(Mutex::new(None));
                let out_slot = Arc::clone(&slot);
                let task = ClosureTask::new("explore", move |_ctx: &Context| {
                    let mut objs = vec![0.0; rows_n * n_obj];
                    evaluator.evaluate_rows(
                        RowsView::new(&chunk_genomes, dim),
                        &chunk_seeds,
                        &mut objs,
                    )?;
                    *out_slot.lock().unwrap() = Some(objs);
                    Ok(Context::new())
                })
                .cost(cost * rows_n as f64);
                let handle = env.submit(Job::new(Arc::new(task), Context::new()));
                in_flight.push((lo, hi, slot, handle));
            }

            // poll; drain every completed block
            let mut progressed = false;
            let mut idx = 0;
            while idx < in_flight.len() {
                match in_flight[idx].3.try_wait() {
                    None => {
                        idx += 1;
                        continue;
                    }
                    Some(Err(e)) => {
                        if !self.degraded_ok {
                            return Err(e);
                        }
                        // graceful degradation: the chunk's retry budget is
                        // spent — journal the exact failed row set, emit NaN
                        // placeholders and carry on
                        progressed = true;
                        let (lo, hi, _slot, _) = in_flight.swap_remove(idx);
                        let mut failed_rows = Vec::new();
                        for r in lo..hi {
                            if !done[r] {
                                objectives[r * n_obj..(r + 1) * n_obj]
                                    .fill(f64::NAN);
                                done[r] = true;
                                degraded[r] = true;
                                failed_rows.push(r);
                            }
                        }
                        if let Some(j) = &self.journal {
                            if !failed_rows.is_empty() {
                                j.append(&journal::degraded_rows_record(
                                    &failed_rows,
                                    clock,
                                    &e.to_string(),
                                ))?;
                            }
                        }
                        done_rows += failed_rows.len();
                        if let Some(p) = &self.progress {
                            p(done_rows as u64, n as u64);
                        }
                        self.drain_ready(
                            &design,
                            &objectives,
                            &done,
                            &mut cursor,
                            n_obj,
                            &mut row_buf,
                        )?;
                    }
                    Some(Ok((_ctx, report))) => {
                        progressed = true;
                        let (lo, hi, slot, _) = in_flight.swap_remove(idx);
                        let objs = slot.lock().unwrap().take().ok_or_else(|| {
                            Error::Evolution(
                                "explore chunk produced no results".into(),
                            )
                        })?;
                        // restored-degraded rows keep their NaN placeholder
                        // (the writer may have streamed it already); the
                        // journal checkpoints only the rows we actually keep
                        let mut newly = 0usize;
                        for (k, r) in (lo..hi).enumerate() {
                            if degraded[r] {
                                continue;
                            }
                            objectives[r * n_obj..(r + 1) * n_obj]
                                .copy_from_slice(&objs[k * n_obj..(k + 1) * n_obj]);
                            if !done[r] {
                                done[r] = true;
                                evaluated += 1;
                                newly += 1;
                            }
                        }
                        done_rows += newly;
                        if let Some(p) = &self.progress {
                            p(done_rows as u64, n as u64);
                        }
                        clock = clock.max(report.virtual_end);
                        if let Some(j) = &self.journal {
                            // one record per contiguous non-degraded run —
                            // a single lo..hi record in the common case
                            let mut start = lo;
                            for r in lo..=hi {
                                if r == hi || degraded[r] {
                                    if r > start {
                                        j.append(&journal::sample_block_record(
                                            start,
                                            n_obj,
                                            &objs[(start - lo) * n_obj
                                                ..(r - lo) * n_obj],
                                            report.virtual_end,
                                        ))?;
                                    }
                                    start = r + 1;
                                }
                            }
                        }
                        self.drain_ready(
                            &design,
                            &objectives,
                            &done,
                            &mut cursor,
                            n_obj,
                            &mut row_buf,
                        )?;
                    }
                }
            }
            if !progressed && !in_flight.is_empty() {
                std::thread::sleep(Duration::from_micros(200));
            }
        }

        debug_assert_eq!(cursor, n, "all rows drained");
        if let Some(w) = &self.writer {
            w.flush()?;
        }
        if let Some(j) = &self.journal {
            j.append(&journal::env_stats_record(env.name(), &env.stats()))?;
            j.append(&journal::run_end(evaluated as u64, clock))?;
        }
        let degraded_rows: Vec<usize> = degraded
            .iter()
            .enumerate()
            .filter_map(|(r, &d)| d.then_some(r))
            .collect();
        Ok(SweepResult {
            design,
            objectives,
            evaluated,
            resumed,
            resumed_degraded,
            degraded: degraded_rows,
            virtual_makespan: clock,
        })
    }

    /// Write every done row the cursor has reached, in row order.
    fn drain_ready(
        &self,
        design: &SampleMatrix,
        objectives: &[f64],
        done: &[bool],
        cursor: &mut usize,
        n_obj: usize,
        row_buf: &mut Vec<f64>,
    ) -> Result<()> {
        let Some(w) = &self.writer else {
            while *cursor < done.len() && done[*cursor] {
                *cursor += 1;
            }
            return Ok(());
        };
        let mut wrote = false;
        while *cursor < done.len() && done[*cursor] {
            let r = *cursor;
            row_buf.clear();
            row_buf.extend_from_slice(design.row(r));
            row_buf.extend_from_slice(&objectives[r * n_obj..(r + 1) * n_obj]);
            w.append_row(row_buf)?;
            *cursor += 1;
            wrote = true;
        }
        if wrote {
            w.flush()?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::broker::fault::{FaultPlan, FaultyEnv};
    use crate::broker::journal::{DegradedRows, SampleBlock};
    use crate::core::val_f64;
    use crate::environment::local::LocalEnvironment;
    use crate::evolution::evaluator::{CountingEvaluator, Zdt1Evaluator};
    use crate::exploration::sampling::{ExplicitSampling, LhsSampling, SobolSampling};

    fn lhs3(n: usize) -> Arc<dyn Sampling> {
        let x0 = val_f64("x0");
        let x1 = val_f64("x1");
        let x2 = val_f64("x2");
        Arc::new(LhsSampling::new(
            &[(&x0, 0.0, 1.0), (&x1, 0.0, 1.0), (&x2, 0.0, 1.0)],
            n,
        ))
    }

    #[test]
    fn sweep_evaluates_every_row_once() {
        let env = LocalEnvironment::new(4);
        let counting = Arc::new(CountingEvaluator::new(Zdt1Evaluator { dim: 3 }));
        let sweep = Sweep::new(lhs3(97), Arc::clone(&counting) as _, &["f1", "f2"])
            .chunk(16);
        let result = sweep.run(&env, 42).unwrap();
        assert_eq!(result.rows(), 97);
        assert_eq!(result.evaluated, 97);
        assert_eq!(result.resumed, 0);
        assert_eq!(counting.count(), 97);
        // objectives agree with a direct evaluation under the same seeds
        let serial = Zdt1Evaluator { dim: 3 };
        for i in [0usize, 13, 96] {
            let want = serial
                .evaluate(result.design.row(i), row_seed(42, i))
                .unwrap();
            assert_eq!(result.objectives_row(i), want.as_slice(), "row {i}");
        }
    }

    #[test]
    fn sweep_is_chunking_independent() {
        let env = LocalEnvironment::new(4);
        let run = |chunk: usize| {
            Sweep::new(lhs3(41), Arc::new(Zdt1Evaluator { dim: 3 }), &["f1", "f2"])
                .chunk(chunk)
                .run(&env, 7)
                .unwrap()
        };
        let a = run(1);
        let b = run(8);
        let c = run(64);
        assert_eq!(a.objectives, b.objectives, "chunk 1 vs 8");
        assert_eq!(a.objectives, c.objectives, "chunk 1 vs 64");
    }

    #[test]
    fn resume_skips_restored_rows() {
        let env = LocalEnvironment::new(2);
        let full = Sweep::new(
            lhs3(30),
            Arc::new(Zdt1Evaluator { dim: 3 }),
            &["f1", "f2"],
        )
        .chunk(10)
        .run(&env, 5)
        .unwrap();

        // pretend the first two blocks were journaled before a kill
        let events: Vec<SweepEvent> = (0..2)
            .map(|k| {
                SweepEvent::Block(SampleBlock {
                    first_row: k * 10,
                    objectives: (k * 10..(k + 1) * 10)
                        .map(|r| full.objectives_row(r).to_vec())
                        .collect(),
                    clock: 50.0,
                })
            })
            .collect();
        let counting = Arc::new(CountingEvaluator::new(Zdt1Evaluator { dim: 3 }));
        let resumed = Sweep::new(lhs3(30), Arc::clone(&counting) as _, &["f1", "f2"])
            .chunk(10)
            .run_resumable(&env, 5, Some(&events))
            .unwrap();
        assert_eq!(resumed.resumed, 20);
        assert_eq!(resumed.evaluated, 10);
        assert_eq!(counting.count(), 10, "restored rows must not re-evaluate");
        assert_eq!(resumed.objectives, full.objectives);
        assert!(resumed.virtual_makespan >= 50.0);
    }

    #[test]
    fn resume_tolerates_a_different_chunk_grid() {
        let env = LocalEnvironment::new(2);
        let full = Sweep::new(
            lhs3(25),
            Arc::new(Zdt1Evaluator { dim: 3 }),
            &["f1", "f2"],
        )
        .chunk(7)
        .run(&env, 9)
        .unwrap();
        // one journaled block that straddles the new grid
        let events = [SweepEvent::Block(SampleBlock {
            first_row: 3,
            objectives: (3..12).map(|r| full.objectives_row(r).to_vec()).collect(),
            clock: 1.0,
        })];
        let resumed = Sweep::new(
            lhs3(25),
            Arc::new(Zdt1Evaluator { dim: 3 }),
            &["f1", "f2"],
        )
        .chunk(4)
        .run_resumable(&env, 9, Some(&events))
        .unwrap();
        assert_eq!(resumed.objectives, full.objectives);
        assert_eq!(resumed.resumed, 9);
    }

    #[test]
    fn sweep_rejects_context_only_samplings_and_foreign_journals() {
        let env = LocalEnvironment::new(1);
        let explicit = Arc::new(ExplicitSampling::new(vec![Context::new()]));
        assert!(Sweep::new(explicit, Arc::new(Zdt1Evaluator { dim: 3 }), &["f1", "f2"])
            .run(&env, 1)
            .is_err());

        let events = [SweepEvent::Block(SampleBlock {
            first_row: 90,
            objectives: vec![vec![1.0, 2.0]; 20],
            clock: 0.0,
        })];
        let err = Sweep::new(lhs3(10), Arc::new(Zdt1Evaluator { dim: 3 }), &["f1", "f2"])
            .run_resumable(&env, 1, Some(&events))
            .unwrap_err();
        assert!(
            err.to_string().contains("does not fit"),
            "unexpected error: {err}"
        );
    }

    #[test]
    fn degraded_ok_turns_exhausted_chunks_into_nan_rows() {
        // crash the second submission (rows 10..20) terminally
        let plan = FaultPlan::new().crash_window(1, 1);
        let make_env =
            || FaultyEnv::new(Arc::new(LocalEnvironment::new(2)), plan.clone(), 0xC0);

        // without the flag the failure aborts the sweep
        let err = Sweep::new(lhs3(30), Arc::new(Zdt1Evaluator { dim: 3 }), &["f1", "f2"])
            .chunk(10)
            .run(&make_env(), 5)
            .unwrap_err();
        assert!(
            err.to_string().contains("crash window"),
            "unexpected error: {err}"
        );

        let result = Sweep::new(lhs3(30), Arc::new(Zdt1Evaluator { dim: 3 }), &["f1", "f2"])
            .chunk(10)
            .degraded_ok(true)
            .run(&make_env(), 5)
            .unwrap();
        assert_eq!(result.outcome(), "degraded");
        assert_eq!(result.degraded, (10..20).collect::<Vec<_>>());
        assert_eq!(result.evaluated, 20);
        for r in 0..30 {
            let nan = result.objectives_row(r).iter().all(|v| v.is_nan());
            assert_eq!(nan, (10..20).contains(&r), "row {r}");
        }
    }

    #[test]
    fn resume_keeps_degraded_rows_unless_retry_requested() {
        let env = LocalEnvironment::new(2);
        let full = Sweep::new(lhs3(30), Arc::new(Zdt1Evaluator { dim: 3 }), &["f1", "f2"])
            .chunk(10)
            .run(&env, 5)
            .unwrap();
        let events = vec![
            SweepEvent::Block(SampleBlock {
                first_row: 0,
                objectives: (0..10).map(|r| full.objectives_row(r).to_vec()).collect(),
                clock: 1.0,
            }),
            SweepEvent::Degraded(DegradedRows {
                rows: (10..20).collect(),
                clock: 2.0,
            }),
        ];

        let counting = Arc::new(CountingEvaluator::new(Zdt1Evaluator { dim: 3 }));
        let resumed = Sweep::new(lhs3(30), Arc::clone(&counting) as _, &["f1", "f2"])
            .chunk(10)
            .run_resumable(&env, 5, Some(&events))
            .unwrap();
        assert_eq!(resumed.resumed, 10);
        assert_eq!(resumed.resumed_degraded, 10);
        assert_eq!(resumed.evaluated, 10);
        assert_eq!(counting.count(), 10, "degraded rows must not re-evaluate");
        assert_eq!(resumed.outcome(), "degraded");
        assert!(resumed.objectives_row(12).iter().all(|v| v.is_nan()));

        // --retry-degraded re-opens them on a healthy environment
        let counting = Arc::new(CountingEvaluator::new(Zdt1Evaluator { dim: 3 }));
        let retried = Sweep::new(lhs3(30), Arc::clone(&counting) as _, &["f1", "f2"])
            .chunk(10)
            .retry_degraded(true)
            .run_resumable(&env, 5, Some(&events))
            .unwrap();
        assert_eq!(counting.count(), 20);
        assert_eq!(retried.outcome(), "complete");
        assert_eq!(retried.objectives, full.objectives);
    }

    #[test]
    fn later_block_supersedes_earlier_degradation() {
        let env = LocalEnvironment::new(2);
        let full = Sweep::new(lhs3(30), Arc::new(Zdt1Evaluator { dim: 3 }), &["f1", "f2"])
            .chunk(10)
            .run(&env, 5)
            .unwrap();
        // a retry after a degradation journals a fresh block: last write wins
        let events = vec![
            SweepEvent::Degraded(DegradedRows {
                rows: vec![0, 1, 2],
                clock: 1.0,
            }),
            SweepEvent::Block(SampleBlock {
                first_row: 0,
                objectives: (0..10).map(|r| full.objectives_row(r).to_vec()).collect(),
                clock: 2.0,
            }),
        ];
        let resumed = Sweep::new(lhs3(30), Arc::new(Zdt1Evaluator { dim: 3 }), &["f1", "f2"])
            .chunk(10)
            .run_resumable(&env, 5, Some(&events))
            .unwrap();
        assert_eq!(resumed.resumed, 10);
        assert_eq!(resumed.resumed_degraded, 0);
        assert_eq!(resumed.outcome(), "complete");
        assert_eq!(resumed.objectives, full.objectives);
    }

    #[test]
    fn sobol_sweep_is_reproducible_across_runs() {
        let env = LocalEnvironment::new(2);
        let x = val_f64("x0");
        let y = val_f64("x1");
        let make = || {
            let s: Arc<dyn Sampling> = Arc::new(SobolSampling::new(
                &[(&x, 0.0, 1.0), (&y, 0.0, 1.0)],
                33,
            ));
            Sweep::new(s, Arc::new(Zdt1Evaluator { dim: 2 }), &["f1", "f2"]).chunk(5)
        };
        let a = make().run(&env, 3).unwrap();
        let b = make().run(&env, 3).unwrap();
        assert_eq!(a.design.data(), b.design.data());
        assert_eq!(a.objectives, b.objectives);
    }
}
