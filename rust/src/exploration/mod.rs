//! Model exploration: samplings (DoE), the columnar sample engine,
//! broker-distributed sweeps, replication, statistics.

pub mod matrix;
pub mod replication;
pub mod rowstore;
pub mod sampling;
pub mod statistics;
pub mod sweep;

pub use matrix::{Column, ColumnKind, SampleMatrix};
pub use rowstore::RowStore;
pub use replication::replicate;
pub use sampling::{
    ExplicitSampling, Factor, FullFactorial, LhsSampling, ProductSampling,
    Sampling, SeedSampling, SobolSampling, UniformSampling, SOBOL_MAX_DIM,
};
pub use statistics::StatisticTask;
pub use sweep::{row_seed, Sweep, SweepResult};
