//! Model exploration: samplings (DoE), replication, statistics.

pub mod replication;
pub mod sampling;
pub mod statistics;

pub use replication::replicate;
pub use sampling::{
    ExplicitSampling, Factor, FullFactorial, LhsSampling, ProductSampling,
    Sampling, SeedSampling, UniformSampling,
};
pub use statistics::StatisticTask;
