//! Pluggable row storage (§Out-of-core tentpole): a [`RowStore`] owns the
//! row-major `f64` rows behind [`SampleMatrix`](crate::exploration::SampleMatrix)
//! and the explore result path, with two backings:
//!
//! * **Ram** — one contiguous `Vec<f64>`, exactly the layout every PR-4
//!   hot path was built on. `clear`/`grow_rows` never release capacity, so
//!   the zero-allocation steady-state wave discipline is unchanged.
//! * **Spill** — a chunk-paged, file-backed store under `--spill-dir` with
//!   a `--mem-budget` resident cap. Rows are grouped into fixed-size
//!   chunks; at most `max(2, mem_budget / chunk_bytes)` chunks are
//!   resident at a time in **arena-recycled** slot buffers (allocated once
//!   on first use, never freed, never reallocated), so after warm-up a
//!   spilled wave performs zero heap allocations — page-outs serialise
//!   through one recycled byte buffer into a single scratch file that is
//!   deleted on drop. Least-recently-used chunks are evicted first; clean
//!   chunks are dropped without I/O, dirty chunks are written back at
//!   `chunk_index × chunk_bytes` so the file is positionally addressable
//!   and never compacted.
//!
//! The store tracks a **resident-bytes high-water mark**
//! ([`RowStore::peak_resident_bytes`]) — the observability hook behind the
//! `peak-resident-bytes` line in every end-of-run summary and the serve
//! `status` fleet object. The spill file is scratch, not durability:
//! crash recovery still comes from the checkpoint journal + positionally
//! pure regeneration, which is why the file can be unlinked on drop.
//!
//! Contiguous accessors (`data`, `rows_slice`, `row`, `row_mut`) are only
//! valid on the Ram backing and panic on Spill with a clear message; the
//! streaming paths use the block API ([`RowStore::write_rows`] /
//! [`RowStore::copy_rows`]) which works on either backing.

use std::fs::File;
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use crate::error::{Error, Result};

/// Default rows per spill chunk when the caller has no natural block size.
pub const DEFAULT_ROWS_PER_CHUNK: usize = 4096;

/// Monotone scratch-file counter: spill files are
/// `rowstore-{pid}-{counter}.bin`, unique within and across stores of one
/// process.
static SPILL_COUNTER: AtomicU64 = AtomicU64::new(0);

/// One resident chunk buffer. `data` is allocated once at
/// `rows_per_chunk × width` and recycled for every chunk this slot ever
/// holds.
#[derive(Debug)]
struct Slot {
    data: Vec<f64>,
    chunk: usize,
    dirty: bool,
    last_use: u64,
}

#[derive(Debug)]
struct Spill {
    path: PathBuf,
    file: File,
    rows_per_chunk: usize,
    /// Resident cap: at most this many slots are ever allocated.
    cap: usize,
    slots: Vec<Slot>,
    /// chunk index → resident slot index (capacity retained across `clear`).
    chunk_slot: Vec<Option<u32>>,
    /// chunk has been written to the spill file at least once (unwritten
    /// chunks page in as zeros, matching `Vec::resize` semantics).
    on_disk: Vec<bool>,
    /// Recycled serialisation buffer, `chunk_bytes` long.
    byte_buf: Vec<u8>,
    tick: u64,
    resident_bytes: u64,
    peak_resident_bytes: u64,
}

#[derive(Debug)]
enum Backing {
    Ram(Vec<f64>),
    Spill(Box<Spill>),
}

/// Row-major `f64` row storage with a pluggable backing — see the module
/// docs for the Ram/Spill contract.
#[derive(Debug)]
pub struct RowStore {
    width: usize,
    rows: usize,
    backing: Backing,
    /// Ram-backing high-water mark (Spill tracks its own).
    ram_peak_bytes: u64,
}

impl Clone for RowStore {
    /// The Ram backing clones like the `Vec<f64>` it wraps; a spilled
    /// store cannot be cloned (the scratch file is single-owner) and
    /// panics — no streaming path ever clones row storage.
    fn clone(&self) -> Self {
        match &self.backing {
            Backing::Ram(data) => RowStore {
                width: self.width,
                rows: self.rows,
                backing: Backing::Ram(data.clone()),
                ram_peak_bytes: self.ram_peak_bytes,
            },
            Backing::Spill(_) => panic!("RowStore: the spilled backing cannot be cloned"),
        }
    }
}

impl RowStore {
    /// Contiguous in-RAM backing (the default, and the only backing that
    /// supports the contiguous slice accessors).
    pub fn ram(width: usize) -> Self {
        RowStore {
            width,
            rows: 0,
            backing: Backing::Ram(Vec::new()),
            ram_peak_bytes: 0,
        }
    }

    /// In-RAM backing with capacity for `rows` rows preallocated.
    pub fn ram_with_capacity(width: usize, rows: usize) -> Self {
        let data = Vec::with_capacity(rows * width);
        let peak = (data.capacity() * 8) as u64;
        RowStore {
            width,
            rows: 0,
            backing: Backing::Ram(data),
            ram_peak_bytes: peak,
        }
    }

    /// Chunk-paged file-backed backing: rows are paged to a scratch file
    /// under `spill_dir`, keeping at most `max(2, mem_budget / chunk_bytes)`
    /// chunks of `rows_per_chunk` rows resident. A zero-width store never
    /// touches the filesystem (there are no bytes to spill) and degrades
    /// to the Ram backing.
    pub fn spilled(
        width: usize,
        spill_dir: &Path,
        mem_budget: u64,
        rows_per_chunk: usize,
    ) -> Result<Self> {
        if width == 0 {
            return Ok(RowStore::ram(0));
        }
        let rows_per_chunk = rows_per_chunk.max(1);
        std::fs::create_dir_all(spill_dir).map_err(|e| {
            Error::EnvironmentError(format!(
                "cannot create spill dir {}: {e}",
                spill_dir.display()
            ))
        })?;
        let name = format!(
            "rowstore-{}-{}.bin",
            std::process::id(),
            SPILL_COUNTER.fetch_add(1, Ordering::Relaxed)
        );
        let path = spill_dir.join(name);
        let file = std::fs::OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(&path)
            .map_err(|e| {
                Error::EnvironmentError(format!(
                    "cannot create spill file {}: {e}",
                    path.display()
                ))
            })?;
        let chunk_bytes = (rows_per_chunk * width * 8) as u64;
        let cap = ((mem_budget / chunk_bytes) as usize).max(2);
        Ok(RowStore {
            width,
            rows: 0,
            backing: Backing::Spill(Box::new(Spill {
                path,
                file,
                rows_per_chunk,
                cap,
                slots: Vec::new(),
                chunk_slot: Vec::new(),
                on_disk: Vec::new(),
                byte_buf: Vec::new(),
                tick: 0,
                resident_bytes: 0,
                peak_resident_bytes: 0,
            })),
            ram_peak_bytes: 0,
        })
    }

    /// Floats per row.
    pub fn width(&self) -> usize {
        self.width
    }

    /// Number of rows.
    pub fn len(&self) -> usize {
        self.rows
    }

    pub fn is_empty(&self) -> bool {
        self.rows == 0
    }

    pub fn is_spilled(&self) -> bool {
        matches!(self.backing, Backing::Spill(_))
    }

    /// Bytes of row storage currently resident in RAM.
    pub fn resident_bytes(&self) -> u64 {
        match &self.backing {
            Backing::Ram(data) => (data.capacity() * 8) as u64,
            Backing::Spill(s) => s.resident_bytes,
        }
    }

    /// High-water mark of [`RowStore::resident_bytes`] over the store's
    /// lifetime — the per-run memory observability number.
    pub fn peak_resident_bytes(&self) -> u64 {
        match &self.backing {
            Backing::Ram(_) => self.ram_peak_bytes.max(self.resident_bytes()),
            Backing::Spill(s) => s.peak_resident_bytes,
        }
    }

    /// Float capacity of the retained arena (Ram: the vec's capacity;
    /// Spill: the sum of the allocated slot buffers) — lets callers assert
    /// the clear-and-regrow path never reallocates.
    pub fn capacity_floats(&self) -> usize {
        match &self.backing {
            Backing::Ram(data) => data.capacity(),
            Backing::Spill(s) => s.slots.iter().map(|sl| sl.data.len()).sum(),
        }
    }

    /// Drop all rows, keeping every retained buffer (Ram capacity, spill
    /// slot arena, chunk maps) for the next wave.
    pub fn clear(&mut self) {
        self.rows = 0;
        match &mut self.backing {
            Backing::Ram(data) => data.clear(),
            Backing::Spill(s) => {
                for slot in &mut s.slots {
                    slot.chunk = usize::MAX;
                    slot.dirty = false;
                }
                s.chunk_slot.clear();
                s.on_disk.clear();
            }
        }
    }

    /// Append `n` zero-filled rows; returns the index of the first new
    /// row. Reuses retained capacity.
    pub fn grow_rows(&mut self, n: usize) -> usize {
        let first = self.rows;
        self.rows += n;
        match &mut self.backing {
            Backing::Ram(data) => {
                data.resize(self.rows * self.width, 0.0);
                self.ram_peak_bytes = self.ram_peak_bytes.max((data.capacity() * 8) as u64);
            }
            Backing::Spill(s) => {
                let chunks = self.rows.div_ceil(s.rows_per_chunk);
                if s.chunk_slot.len() < chunks {
                    s.chunk_slot.resize(chunks, None);
                    s.on_disk.resize(chunks, false);
                }
            }
        }
        first
    }

    /// Append one row.
    pub fn push_row(&mut self, row: &[f64]) {
        debug_assert_eq!(row.len(), self.width);
        let first = self.grow_rows(1);
        self.write_rows(first, row);
    }

    /// Overwrite the contiguous rows starting at `first_row` with
    /// `data` (`data.len()` must be a whole number of rows, all of which
    /// must already exist). Works on either backing; on Spill this is the
    /// paged write path.
    pub fn write_rows(&mut self, first_row: usize, data: &[f64]) {
        if self.width == 0 {
            debug_assert!(data.is_empty());
            return;
        }
        debug_assert_eq!(data.len() % self.width, 0);
        let n = data.len() / self.width;
        assert!(
            first_row + n <= self.rows,
            "RowStore::write_rows: rows {first_row}..{} out of bounds (len {})",
            first_row + n,
            self.rows
        );
        match &mut self.backing {
            Backing::Ram(ram) => {
                let lo = first_row * self.width;
                ram[lo..lo + data.len()].copy_from_slice(data);
            }
            Backing::Spill(s) => {
                let width = self.width;
                let mut row = first_row;
                let mut off = 0;
                while row < first_row + n {
                    let chunk = row / s.rows_per_chunk;
                    let chunk_lo = chunk * s.rows_per_chunk;
                    let in_chunk = row - chunk_lo;
                    let take = (s.rows_per_chunk - in_chunk).min(first_row + n - row);
                    // a write covering the whole chunk needs no page-in
                    let whole = in_chunk == 0 && take == s.rows_per_chunk;
                    let slot = s.slot_for_chunk(chunk, width, !whole);
                    let buf = &mut s.slots[slot].data[in_chunk * width..(in_chunk + take) * width];
                    buf.copy_from_slice(&data[off..off + take * width]);
                    s.slots[slot].dirty = true;
                    row += take;
                    off += take * width;
                }
            }
        }
    }

    /// Copy rows `lo..hi` into `out` (resized to `(hi - lo) × width`).
    /// Works on either backing; on Spill this is the paged read path and
    /// `out` is the caller's recycled buffer.
    pub fn copy_rows(&mut self, lo: usize, hi: usize, out: &mut Vec<f64>) {
        assert!(lo <= hi && hi <= self.rows, "RowStore::copy_rows: rows {lo}..{hi} out of bounds");
        out.clear();
        out.resize((hi - lo) * self.width, 0.0);
        if self.width == 0 {
            return;
        }
        match &mut self.backing {
            Backing::Ram(ram) => {
                out.copy_from_slice(&ram[lo * self.width..hi * self.width]);
            }
            Backing::Spill(s) => {
                let width = self.width;
                let mut row = lo;
                let mut off = 0;
                while row < hi {
                    let chunk = row / s.rows_per_chunk;
                    let chunk_lo = chunk * s.rows_per_chunk;
                    let in_chunk = row - chunk_lo;
                    let take = (s.rows_per_chunk - in_chunk).min(hi - row);
                    let slot = s.slot_for_chunk(chunk, width, true);
                    let buf = &s.slots[slot].data[in_chunk * width..(in_chunk + take) * width];
                    out[off..off + take * width].copy_from_slice(buf);
                    row += take;
                    off += take * width;
                }
            }
        }
    }

    fn ram(&self) -> &Vec<f64> {
        match &self.backing {
            Backing::Ram(data) => data,
            Backing::Spill(_) => panic!(
                "RowStore: contiguous slice access requires the in-RAM backing \
                 (spilled stores are read through copy_rows)"
            ),
        }
    }

    fn ram_mut(&mut self) -> &mut Vec<f64> {
        match &mut self.backing {
            Backing::Ram(data) => data,
            Backing::Spill(_) => panic!(
                "RowStore: contiguous slice access requires the in-RAM backing \
                 (spilled stores are written through write_rows)"
            ),
        }
    }

    /// The whole store, row-major. **Ram backing only.**
    pub fn data(&self) -> &[f64] {
        self.ram()
    }

    /// Rows `lo..hi` as one contiguous slice. **Ram backing only.**
    pub fn rows_slice(&self, lo: usize, hi: usize) -> &[f64] {
        &self.ram()[lo * self.width..hi * self.width]
    }

    /// Row `i`. **Ram backing only.**
    pub fn row(&self, i: usize) -> &[f64] {
        &self.ram()[i * self.width..(i + 1) * self.width]
    }

    /// Row `i`, mutable. **Ram backing only.**
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        let w = self.width;
        &mut self.ram_mut()[i * w..(i + 1) * w]
    }
}

impl Spill {
    /// Resident slot holding `chunk`, paging it in (or zero-filling, for a
    /// chunk never written to disk) after evicting the least-recently-used
    /// slot when the arena is at its cap. `need_load` is false when the
    /// caller is about to overwrite the whole chunk.
    fn slot_for_chunk(&mut self, chunk: usize, width: usize, need_load: bool) -> usize {
        self.tick += 1;
        if let Some(slot) = self.chunk_slot[chunk] {
            let slot = slot as usize;
            self.slots[slot].last_use = self.tick;
            return slot;
        }
        let chunk_floats = self.rows_per_chunk * width;
        let slot = if self.slots.len() < self.cap {
            // arena growth: counted once per slot, never again
            self.slots.push(Slot {
                data: vec![0.0; chunk_floats],
                chunk: usize::MAX,
                dirty: false,
                last_use: 0,
            });
            if self.byte_buf.is_empty() {
                self.byte_buf = vec![0u8; chunk_floats * 8];
            }
            self.resident_bytes = (self.slots.len() * chunk_floats * 8 + self.byte_buf.len()) as u64;
            self.peak_resident_bytes = self.peak_resident_bytes.max(self.resident_bytes);
            self.slots.len() - 1
        } else {
            let victim = self
                .slots
                .iter()
                .enumerate()
                .min_by_key(|(_, s)| s.last_use)
                .map(|(i, _)| i)
                .expect("spill arena has at least two slots");
            let old_chunk = self.slots[victim].chunk;
            if old_chunk != usize::MAX {
                if self.slots[victim].dirty {
                    self.write_chunk(victim, old_chunk);
                }
                self.chunk_slot[old_chunk] = None;
            }
            victim
        };
        self.slots[slot].chunk = chunk;
        self.slots[slot].dirty = false;
        self.slots[slot].last_use = self.tick;
        self.chunk_slot[chunk] = Some(slot as u32);
        if need_load && self.on_disk[chunk] {
            self.read_chunk(slot, chunk);
        } else {
            self.slots[slot].data.fill(0.0);
        }
        slot
    }

    fn write_chunk(&mut self, slot: usize, chunk: usize) {
        let data = &self.slots[slot].data;
        for (i, v) in data.iter().enumerate() {
            self.byte_buf[i * 8..(i + 1) * 8].copy_from_slice(&v.to_le_bytes());
        }
        let offset = (chunk * self.byte_buf.len()) as u64;
        self.file
            .seek(SeekFrom::Start(offset))
            .and_then(|_| self.file.write_all(&self.byte_buf))
            .unwrap_or_else(|e| {
                panic!("RowStore: spill write to {} failed: {e}", self.path.display())
            });
        self.on_disk[chunk] = true;
    }

    fn read_chunk(&mut self, slot: usize, chunk: usize) {
        let offset = (chunk * self.byte_buf.len()) as u64;
        self.file
            .seek(SeekFrom::Start(offset))
            .and_then(|_| self.file.read_exact(&mut self.byte_buf))
            .unwrap_or_else(|e| {
                panic!("RowStore: spill read from {} failed: {e}", self.path.display())
            });
        let mut eight = [0u8; 8];
        for (i, v) in self.slots[slot].data.iter_mut().enumerate() {
            eight.copy_from_slice(&self.byte_buf[i * 8..(i + 1) * 8]);
            *v = f64::from_le_bytes(eight);
        }
    }
}

impl Drop for Spill {
    fn drop(&mut self) {
        // scratch, not durability — recovery comes from the journal
        let _ = std::fs::remove_file(&self.path);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("molers-rowstore-{}-{name}", std::process::id()));
        let _ = std::fs::create_dir_all(&d);
        d
    }

    /// Deterministic pseudo-random fill so spill/ram equivalence covers
    /// non-trivial patterns without an RNG dependency.
    fn v(row: usize, col: usize) -> f64 {
        ((row * 31 + col * 7 + 1) as f64).sin() * 1e3
    }

    #[test]
    fn spill_round_trips_like_ram() {
        let dir = tmp_dir("roundtrip");
        let width = 3;
        let rows = 257; // many chunks of 16, plus a partial tail
        let mut ram = RowStore::ram(width);
        // budget of 2 chunks forces constant eviction traffic
        let mut spill = RowStore::spilled(width, &dir, 2 * 16 * width as u64 * 8, 16).unwrap();
        ram.grow_rows(rows);
        spill.grow_rows(rows);
        assert!(spill.is_spilled() && !ram.is_spilled());

        // interleaved writes, deliberately out of order and chunk-straddling
        let mut buf = Vec::new();
        for start in [200, 0, 96, 15, 250, 48] {
            let n = (rows - start).min(23);
            buf.clear();
            for r in start..start + n {
                for c in 0..width {
                    buf.push(v(r, c));
                }
            }
            ram.write_rows(start, &buf);
            spill.write_rows(start, &buf);
        }

        let (mut a, mut b) = (Vec::new(), Vec::new());
        for (lo, hi) in [(0, rows), (10, 20), (90, 130), (255, 257), (5, 5)] {
            ram.copy_rows(lo, hi, &mut a);
            spill.copy_rows(lo, hi, &mut b);
            assert_eq!(a, b, "rows {lo}..{hi} must match the ram backing");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn resident_bytes_stay_under_the_budget() {
        let dir = tmp_dir("budget");
        let (width, rpc) = (4, 8);
        let chunk_bytes = (rpc * width * 8) as u64;
        let budget = 3 * chunk_bytes;
        let mut s = RowStore::spilled(width, &dir, budget, rpc).unwrap();
        s.grow_rows(40 * rpc);
        let mut buf = vec![1.5; rpc * width];
        for chunk in 0..40 {
            s.write_rows(chunk * rpc, &buf);
        }
        for chunk in (0..40).rev() {
            s.copy_rows(chunk * rpc, chunk * rpc + 1, &mut buf);
            assert_eq!(buf[0], 1.5);
        }
        // arena = cap slots + one chunk-sized byte buffer
        assert!(s.peak_resident_bytes() <= budget + chunk_bytes);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn clear_and_regrow_keeps_the_arena() {
        let dir = tmp_dir("reuse");
        let mut s = RowStore::spilled(2, &dir, 4 * 8 * 2 * 8, 8).unwrap();
        s.grow_rows(64);
        let mut buf = vec![2.0; 8 * 2];
        for chunk in 0..8 {
            s.write_rows(chunk * 8, &buf);
        }
        let cap = s.capacity_floats();
        assert!(cap > 0);
        s.clear();
        assert!(s.is_empty());
        s.grow_rows(64);
        // rows grown after clear read back as zeros, like Vec::resize
        s.copy_rows(30, 34, &mut buf);
        assert!(buf.iter().all(|&x| x == 0.0));
        for chunk in 0..8 {
            s.write_rows(chunk * 8, &[3.0; 16]);
        }
        assert_eq!(s.capacity_floats(), cap, "clear+regrow must not grow the arena");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn drop_removes_the_spill_file() {
        let dir = tmp_dir("drop");
        let path = {
            let mut s = RowStore::spilled(1, &dir, 1024, 4).unwrap();
            s.grow_rows(64);
            s.write_rows(0, &[1.0; 64]);
            // force a page-out so the file definitely exists with content
            let mut buf = Vec::new();
            s.copy_rows(60, 64, &mut buf);
            match &s.backing {
                Backing::Spill(sp) => sp.path.clone(),
                Backing::Ram(_) => unreachable!(),
            }
        };
        assert!(!path.exists(), "spill scratch must be unlinked on drop");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn zero_width_spill_degrades_to_ram() {
        let dir = tmp_dir("zerow");
        let mut s = RowStore::spilled(0, &dir, 1024, 4).unwrap();
        assert!(!s.is_spilled());
        s.grow_rows(5);
        assert_eq!(s.len(), 5);
        let mut out = vec![9.0];
        s.copy_rows(0, 5, &mut out);
        assert!(out.is_empty());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    #[should_panic(expected = "contiguous slice access")]
    fn contiguous_access_panics_on_spill() {
        let dir = tmp_dir("panic");
        let mut s = RowStore::spilled(1, &dir, 1024, 4).unwrap();
        s.grow_rows(1);
        let _ = s.data();
    }
}
