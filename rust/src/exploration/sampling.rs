//! Samplings: generators of parameter-set contexts (the paper's "generic
//! tools to explore large parameter sets", §2).

use std::sync::Arc;

use crate::core::{Context, Val};
use crate::util::Rng;

/// A design of experiments: expands one context into many.
pub trait Sampling: Send + Sync {
    fn name(&self) -> &str;

    /// Produce the sample contexts. Each is merged over the incoming
    /// context by the engine before fan-out.
    fn sample(&self, base: &Context, rng: &mut Rng) -> Vec<Context>;
}

/// One factor of a full-factorial design: `x in (lo to hi by step)`.
#[derive(Clone)]
pub struct Factor {
    pub name: String,
    pub lo: f64,
    pub hi: f64,
    pub step: f64,
}

impl Factor {
    pub fn new(v: &Val<f64>, lo: f64, hi: f64, step: f64) -> Self {
        assert!(step > 0.0, "factor step must be positive");
        Factor {
            name: v.name().to_string(),
            lo,
            hi,
            step,
        }
    }

    fn levels(&self) -> Vec<f64> {
        let mut out = Vec::new();
        let mut x = self.lo;
        let eps = self.step * 1e-9;
        while x <= self.hi + eps {
            out.push(x.min(self.hi));
            x += self.step;
        }
        out
    }
}

/// Cartesian product of factor levels (`DirectSampling` x-product).
pub struct FullFactorial {
    factors: Vec<Factor>,
}

impl FullFactorial {
    pub fn new(factors: Vec<Factor>) -> Self {
        FullFactorial { factors }
    }

    pub fn size(&self) -> usize {
        self.factors.iter().map(|f| f.levels().len()).product()
    }
}

impl Sampling for FullFactorial {
    fn name(&self) -> &str {
        "FullFactorial"
    }

    fn sample(&self, base: &Context, _rng: &mut Rng) -> Vec<Context> {
        let levels: Vec<Vec<f64>> = self.factors.iter().map(Factor::levels).collect();
        let mut out = vec![base.clone()];
        for (f, ls) in self.factors.iter().zip(&levels) {
            let mut next = Vec::with_capacity(out.len() * ls.len());
            for ctx in &out {
                for &v in ls {
                    let mut c = ctx.clone();
                    c.set(&Val::<f64>::new(f.name.clone()), v);
                    next.push(c);
                }
            }
            out = next;
        }
        out
    }
}

/// `x in UniformDistribution[Double]() take n` over given bounds.
pub struct UniformSampling {
    name: String,
    lo: f64,
    hi: f64,
    n: usize,
}

impl UniformSampling {
    pub fn new(v: &Val<f64>, lo: f64, hi: f64, n: usize) -> Self {
        UniformSampling {
            name: v.name().to_string(),
            lo,
            hi,
            n,
        }
    }
}

impl Sampling for UniformSampling {
    fn name(&self) -> &str {
        "UniformSampling"
    }

    fn sample(&self, base: &Context, rng: &mut Rng) -> Vec<Context> {
        (0..self.n)
            .map(|_| {
                base.clone()
                    .with(&Val::<f64>::new(self.name.clone()), rng.range(self.lo, self.hi))
            })
            .collect()
    }
}

/// Latin Hypercube over several dimensions: space-filling DoE.
pub struct LhsSampling {
    dims: Vec<(String, f64, f64)>,
    n: usize,
}

impl LhsSampling {
    pub fn new(dims: &[(&Val<f64>, f64, f64)], n: usize) -> Self {
        LhsSampling {
            dims: dims
                .iter()
                .map(|(v, lo, hi)| (v.name().to_string(), *lo, *hi))
                .collect(),
            n,
        }
    }
}

impl Sampling for LhsSampling {
    fn name(&self) -> &str {
        "LHS"
    }

    fn sample(&self, base: &Context, rng: &mut Rng) -> Vec<Context> {
        // one shuffled stratum assignment per dimension
        let mut strata: Vec<Vec<usize>> = Vec::with_capacity(self.dims.len());
        for _ in &self.dims {
            let mut idx: Vec<usize> = (0..self.n).collect();
            rng.shuffle(&mut idx);
            strata.push(idx);
        }
        (0..self.n)
            .map(|i| {
                let mut c = base.clone();
                for (d, (name, lo, hi)) in self.dims.iter().enumerate() {
                    let stratum = strata[d][i] as f64;
                    let u = (stratum + rng.f64()) / self.n as f64;
                    c.set(&Val::<f64>::new(name.clone()), lo + u * (hi - lo));
                }
                c
            })
            .collect()
    }
}

/// `seed in (UniformDistribution[Int]() take n)` — the replication
/// sampling of paper §4.4: n independent model seeds.
pub struct SeedSampling {
    name: String,
    n: usize,
}

impl SeedSampling {
    pub fn new(v: &Val<u32>, n: usize) -> Self {
        SeedSampling {
            name: v.name().to_string(),
            n,
        }
    }
}

impl Sampling for SeedSampling {
    fn name(&self) -> &str {
        "SeedSampling"
    }

    fn sample(&self, base: &Context, rng: &mut Rng) -> Vec<Context> {
        (0..self.n)
            .map(|_| {
                base.clone()
                    .with(&Val::<u32>::new(self.name.clone()), rng.model_seed())
            })
            .collect()
    }
}

/// Explicit list of contexts (CSV-style sampling).
pub struct ExplicitSampling {
    contexts: Vec<Context>,
}

impl ExplicitSampling {
    pub fn new(contexts: Vec<Context>) -> Self {
        ExplicitSampling { contexts }
    }
}

impl Sampling for ExplicitSampling {
    fn name(&self) -> &str {
        "ExplicitSampling"
    }

    fn sample(&self, base: &Context, _rng: &mut Rng) -> Vec<Context> {
        self.contexts
            .iter()
            .map(|c| {
                let mut m = base.clone();
                m.merge(c);
                m
            })
            .collect()
    }
}

/// Cartesian product of two samplings (`x` combinator of the DSL).
pub struct ProductSampling {
    a: Arc<dyn Sampling>,
    b: Arc<dyn Sampling>,
}

impl ProductSampling {
    pub fn new(a: Arc<dyn Sampling>, b: Arc<dyn Sampling>) -> Self {
        ProductSampling { a, b }
    }
}

impl Sampling for ProductSampling {
    fn name(&self) -> &str {
        "ProductSampling"
    }

    fn sample(&self, base: &Context, rng: &mut Rng) -> Vec<Context> {
        let left = self.a.sample(base, rng);
        let mut out = Vec::new();
        for l in &left {
            for r in self.b.sample(l, rng) {
                out.push(r);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{val_f64, val_u32};

    #[test]
    fn full_factorial_covers_grid() {
        let x = val_f64("x");
        let y = val_f64("y");
        let s = FullFactorial::new(vec![
            Factor::new(&x, 0.0, 1.0, 0.5),
            Factor::new(&y, 0.0, 1.0, 1.0),
        ]);
        let mut rng = Rng::new(0);
        let samples = s.sample(&Context::new(), &mut rng);
        assert_eq!(samples.len(), 6); // 3 x-levels, 2 y-levels
        assert_eq!(s.size(), 6);
        assert!(samples
            .iter()
            .any(|c| c.get(&x).unwrap() == 1.0 && c.get(&y).unwrap() == 0.0));
    }

    #[test]
    fn uniform_respects_bounds() {
        let x = val_f64("x");
        let s = UniformSampling::new(&x, 10.0, 20.0, 100);
        let mut rng = Rng::new(1);
        for c in s.sample(&Context::new(), &mut rng) {
            let v = c.get(&x).unwrap();
            assert!((10.0..20.0).contains(&v));
        }
    }

    #[test]
    fn lhs_stratifies_each_dimension() {
        let x = val_f64("x");
        let s = LhsSampling::new(&[(&x, 0.0, 1.0)], 10);
        let mut rng = Rng::new(2);
        let samples = s.sample(&Context::new(), &mut rng);
        // exactly one sample per decile
        let mut seen = [false; 10];
        for c in &samples {
            let v = c.get(&x).unwrap();
            let bin = ((v * 10.0) as usize).min(9);
            assert!(!seen[bin], "two samples in decile {bin}");
            seen[bin] = true;
        }
    }

    #[test]
    fn seed_sampling_unique_seeds() {
        let seed = val_u32("seed");
        let s = SeedSampling::new(&seed, 50);
        let mut rng = Rng::new(3);
        let seeds: Vec<u32> = s
            .sample(&Context::new(), &mut rng)
            .iter()
            .map(|c| c.get(&seed).unwrap())
            .collect();
        let mut dedup = seeds.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), seeds.len());
    }

    #[test]
    fn product_is_cartesian() {
        let x = val_f64("x");
        let y = val_f64("y");
        let s = ProductSampling::new(
            Arc::new(FullFactorial::new(vec![Factor::new(&x, 0.0, 1.0, 1.0)])),
            Arc::new(FullFactorial::new(vec![Factor::new(&y, 0.0, 2.0, 1.0)])),
        );
        let mut rng = Rng::new(4);
        assert_eq!(s.sample(&Context::new(), &mut rng).len(), 6);
    }

    #[test]
    fn sampling_preserves_base_context(){
        let x = val_f64("x");
        let z = val_f64("z");
        let s = UniformSampling::new(&x, 0.0, 1.0, 3);
        let mut rng = Rng::new(5);
        let base = Context::new().with(&z, 9.0);
        for c in s.sample(&base, &mut rng) {
            assert_eq!(c.get(&z).unwrap(), 9.0);
        }
    }
}
