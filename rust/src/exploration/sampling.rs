//! Samplings: generators of parameter designs (the paper's "generic
//! tools to explore large parameter sets", §2).
//!
//! §Exploration tentpole: the primary product of a sampling is a columnar
//! [`SampleMatrix`] written through the streaming
//! [`Sampling::sample_into`] API — contiguous `f64` columns, scratch
//! recycled through the matrix's arena, zero steady-state allocations.
//! The historical `Vec<Context>` path ([`Sampling::sample`]) survives as a
//! thin edge adapter over the matrix for the DSL; context-only samplings
//! (e.g. [`ExplicitSampling`]) keep overriding it directly and report no
//! columns.
//!
//! Bound semantics: every continuous sampling draws from the **half-open**
//! interval `[lo, hi)` (see [`Rng::range`]); stratified samplings (LHS,
//! Sobol) clamp the floating-point mapping so a value can never round up
//! onto `hi`.

use std::ops::Range;
use std::sync::Arc;

use crate::core::{Context, Val};
use crate::error::{Error, Result};
use crate::exploration::matrix::{Column, ColumnKind, SampleMatrix};
use crate::util::rng::unit_to_range;
use crate::util::Rng;

/// A design of experiments: expands one context into many samples.
///
/// Columnar samplings implement [`Sampling::columns`] +
/// [`Sampling::sample_into`] and inherit the context path; context-only
/// samplings override [`Sampling::sample`] and report no columns.
pub trait Sampling: Send + Sync {
    fn name(&self) -> &str;

    /// Column spec of the columnar path. Empty means the sampling is
    /// context-only and callers must go through [`Sampling::sample`].
    fn columns(&self) -> Vec<Column> {
        Vec::new()
    }

    /// Whether the streaming matrix path is available.
    fn is_columnar(&self) -> bool {
        !self.columns().is_empty()
    }

    /// Number of rows one [`Sampling::sample_into`] call appends, when it
    /// is known without sampling (drives preallocation and progress).
    fn size_hint(&self) -> Option<usize> {
        None
    }

    /// Streaming columnar path: append the whole design to `out`, whose
    /// columns must match [`Sampling::columns`]. Implementations draw
    /// scratch space from the matrix's arena so steady-state waves
    /// (`clear` + `sample_into`) allocate nothing.
    fn sample_into(&self, out: &mut SampleMatrix, rng: &mut Rng) -> Result<()> {
        let _ = (out, rng);
        Err(Error::InvalidWorkflow(format!(
            "sampling `{}` has no columnar path",
            self.name()
        )))
    }

    /// Whether [`Sampling::sample_into_block`] is implemented — true for
    /// samplings whose row `i` is a pure function of `i` (Sobol's
    /// gray-code state is reconstructible at any index, a factorial grid
    /// is a mixed-radix decode), false for sequential-RNG designs (LHS,
    /// uniform) that only exist as a whole.
    fn supports_blocks(&self) -> bool {
        false
    }

    /// Block-ranged columnar path (§Out-of-core): append rows
    /// `rows.start..rows.end` *of the full design* to `out`, bit-identical
    /// to the same rows of one whole-design [`Sampling::sample_into`]
    /// call. Because `row_seed` is position-pure too, a streaming sweep
    /// can regenerate any window of a 10M-row design without ever
    /// materialising it.
    fn sample_into_block(
        &self,
        out: &mut SampleMatrix,
        rows: Range<usize>,
        rng: &mut Rng,
    ) -> Result<()> {
        let _ = (out, rows, rng);
        Err(Error::InvalidWorkflow(format!(
            "sampling `{}` has no block-ranged path",
            self.name()
        )))
    }

    /// Produce the sample contexts — the DSL edge adapter. Each sample is
    /// the incoming context with the design columns merged over it. The
    /// default routes through [`Sampling::sample_into`], so both paths
    /// produce identical designs from the same RNG stream (pinned by the
    /// `prop_sample_into_matches_context_path` property test).
    fn sample(&self, base: &Context, rng: &mut Rng) -> Vec<Context> {
        sample_via_matrix(self, base, rng)
    }
}

/// The matrix→contexts edge adapter shared by the trait default and any
/// columnar `sample` override (an override cannot call the trait default
/// back): run `sample_into`, materialise contexts over `base`.
pub fn sample_via_matrix<S: Sampling + ?Sized>(
    sampling: &S,
    base: &Context,
    rng: &mut Rng,
) -> Vec<Context> {
    let mut m = SampleMatrix::new(sampling.columns());
    match sampling.sample_into(&mut m, rng) {
        Ok(()) => m.to_contexts(base),
        Err(e) => {
            // this signature cannot carry an error; surface the cause
            // before the caller reports an empty design
            eprintln!("sampling `{}` failed: {e}", sampling.name());
            Vec::new()
        }
    }
}

/// One factor of a full-factorial design: `x in (lo to hi by step)`.
#[derive(Clone)]
pub struct Factor {
    pub name: String,
    pub lo: f64,
    pub hi: f64,
    pub step: f64,
}

impl Factor {
    pub fn new(v: &Val<f64>, lo: f64, hi: f64, step: f64) -> Self {
        assert!(step > 0.0, "factor step must be positive");
        Factor {
            name: v.name().to_string(),
            lo,
            hi,
            step,
        }
    }

    /// Grid membership predicate shared by [`Factor::level_count`] and
    /// [`Factor::level`]: level `i` exists iff `lo + i·step ≤ hi + eps`.
    #[inline]
    fn on_grid(&self, i: usize) -> bool {
        self.lo + i as f64 * self.step <= self.hi + self.step * 1e-9
    }

    /// Number of grid levels, computed in O(1) without materialising
    /// them. Closed-form estimate corrected against the exact
    /// [`Factor::on_grid`] predicate, so it agrees with
    /// [`Factor::level`]/`sample` for every range — including long ones
    /// like `(0 to 1000 by 0.1)` where the historical `x += step`
    /// accumulation drifted off-grid and could gain or lose a level.
    pub fn level_count(&self) -> usize {
        if self.hi < self.lo {
            return 0;
        }
        if !self.step.is_finite() || !(self.hi - self.lo).is_finite() {
            // degenerate inputs (infinite step or range): exactly one
            // well-defined level, `lo` — and no correction loop to hang in
            return 1;
        }
        let est = ((self.hi - self.lo) / self.step).floor().max(0.0);
        if est >= 9.0e15 {
            // beyond exact-integer f64 territory (and any materialisable
            // design) there is no ±1 to correct, and the cast/loop
            // arithmetic below would saturate or fail to terminate
            return est.min(usize::MAX as f64) as usize;
        }
        let mut k = est as usize;
        while self.on_grid(k + 1) {
            k += 1;
        }
        while k > 0 && !self.on_grid(k) {
            k -= 1;
        }
        k + 1
    }

    /// Level `i` as `lo + i·step` — direct indexing, no accumulated
    /// floating-point error — clamped to `hi` so the top level never
    /// overshoots the bound by rounding.
    pub fn level(&self, i: usize) -> f64 {
        (self.lo + i as f64 * self.step).min(self.hi)
    }

    fn levels(&self) -> Vec<f64> {
        (0..self.level_count()).map(|i| self.level(i)).collect()
    }
}

/// Cartesian product of factor levels (`DirectSampling` x-product). The
/// last factor varies fastest, matching the DSL's nested-loop reading.
pub struct FullFactorial {
    factors: Vec<Factor>,
}

impl FullFactorial {
    pub fn new(factors: Vec<Factor>) -> Self {
        FullFactorial { factors }
    }

    /// Total design size, counted without allocating any level vector —
    /// exactly `sample().len()` by construction (both sides use
    /// [`Factor::level_count`]). Saturates instead of overflowing for
    /// absurd grids.
    pub fn size(&self) -> usize {
        self.factors
            .iter()
            .fold(1usize, |acc, f| acc.saturating_mul(f.level_count()))
    }
}

impl Sampling for FullFactorial {
    fn name(&self) -> &str {
        "FullFactorial"
    }

    fn columns(&self) -> Vec<Column> {
        self.factors.iter().map(|f| Column::f64(&f.name)).collect()
    }

    fn size_hint(&self) -> Option<usize> {
        Some(self.size())
    }

    fn sample_into(&self, out: &mut SampleMatrix, rng: &mut Rng) -> Result<()> {
        self.sample_into_block(out, 0..self.size(), rng)
    }

    fn supports_blocks(&self) -> bool {
        true
    }

    /// Row `r` of a factorial grid is a mixed-radix decode of `r` — any
    /// block of the design regenerates independently, bit-identical to the
    /// whole-design path (which is this method over `0..size()`).
    fn sample_into_block(
        &self,
        out: &mut SampleMatrix,
        rows: Range<usize>,
        _rng: &mut Rng,
    ) -> Result<()> {
        out.check_columns_iter(
            self.factors.iter().map(|f| (f.name.as_str(), ColumnKind::F64)),
            self.name(),
        )?;
        // per-factor level counts in the matrix's index scratch
        let mut counts = std::mem::take(&mut out.idx_scratch);
        counts.clear();
        counts.extend(self.factors.iter().map(Factor::level_count));
        let total = counts.iter().fold(1usize, |acc, &c| acc.saturating_mul(c));
        if rows.end > total {
            out.idx_scratch = counts;
            return Err(Error::InvalidWorkflow(format!(
                "block {}..{} out of range: `{}` design has {total} rows",
                rows.start,
                rows.end,
                self.name()
            )));
        }
        let start = out.grow_rows(rows.len());
        for (w, r) in rows.enumerate() {
            let row = out.row_mut(start + w);
            // mixed-radix decode, last factor least significant (fastest)
            let mut rem = r;
            for d in (0..self.factors.len()).rev() {
                row[d] = self.factors[d].level(rem % counts[d]);
                rem /= counts[d];
            }
        }
        out.idx_scratch = counts;
        Ok(())
    }
}

/// Independent uniform draws over one or more dimensions:
/// `x in UniformDistribution[Double]() take n`. Values are uniform on the
/// half-open `[lo, hi)` (see [`Rng::range`]).
pub struct UniformSampling {
    dims: Vec<(String, f64, f64)>,
    n: usize,
}

impl UniformSampling {
    /// Single-variable form (the DSL's common case).
    pub fn new(v: &Val<f64>, lo: f64, hi: f64, n: usize) -> Self {
        Self::multi(&[(v, lo, hi)], n)
    }

    /// Joint uniform cloud over several dimensions: `n` samples, each a
    /// fresh draw per dimension (row-major draw order).
    pub fn multi(dims: &[(&Val<f64>, f64, f64)], n: usize) -> Self {
        UniformSampling {
            dims: dims
                .iter()
                .map(|(v, lo, hi)| (v.name().to_string(), *lo, *hi))
                .collect(),
            n,
        }
    }
}

impl Sampling for UniformSampling {
    fn name(&self) -> &str {
        "UniformSampling"
    }

    fn columns(&self) -> Vec<Column> {
        self.dims.iter().map(|(n, _, _)| Column::f64(n)).collect()
    }

    fn size_hint(&self) -> Option<usize> {
        Some(self.n)
    }

    fn sample_into(&self, out: &mut SampleMatrix, rng: &mut Rng) -> Result<()> {
        out.check_columns_iter(
            self.dims.iter().map(|(n, _, _)| (n.as_str(), ColumnKind::F64)),
            self.name(),
        )?;
        let start = out.grow_rows(self.n);
        for i in 0..self.n {
            let row = out.row_mut(start + i);
            for (d, (_, lo, hi)) in self.dims.iter().enumerate() {
                row[d] = rng.range(*lo, *hi);
            }
        }
        Ok(())
    }
}

/// Latin Hypercube over several dimensions: space-filling DoE. Each
/// dimension is split into `n` strata, each stratum hit exactly once;
/// values stay strictly below `hi` (the `lo + u·(hi-lo)` mapping is
/// clamped so rounding can never push a top-stratum jitter onto the
/// bound).
pub struct LhsSampling {
    dims: Vec<(String, f64, f64)>,
    n: usize,
}

impl LhsSampling {
    pub fn new(dims: &[(&Val<f64>, f64, f64)], n: usize) -> Self {
        LhsSampling {
            dims: dims
                .iter()
                .map(|(v, lo, hi)| (v.name().to_string(), *lo, *hi))
                .collect(),
            n,
        }
    }
}

impl Sampling for LhsSampling {
    fn name(&self) -> &str {
        "LHS"
    }

    fn columns(&self) -> Vec<Column> {
        self.dims.iter().map(|(n, _, _)| Column::f64(n)).collect()
    }

    fn size_hint(&self) -> Option<usize> {
        Some(self.n)
    }

    fn sample_into(&self, out: &mut SampleMatrix, rng: &mut Rng) -> Result<()> {
        out.check_columns_iter(
            self.dims.iter().map(|(n, _, _)| (n.as_str(), ColumnKind::F64)),
            self.name(),
        )?;
        let start = out.grow_rows(self.n);
        // column-major: one shuffled stratum assignment per dimension,
        // the single index scratch recycled across dimensions and waves
        let mut strata = std::mem::take(&mut out.idx_scratch);
        for (d, (_, lo, hi)) in self.dims.iter().enumerate() {
            strata.clear();
            strata.extend(0..self.n);
            rng.shuffle(&mut strata);
            for i in 0..self.n {
                let u = (strata[i] as f64 + rng.f64()) / self.n as f64;
                out.row_mut(start + i)[d] = unit_to_range(u, *lo, *hi);
            }
        }
        out.idx_scratch = strata;
        Ok(())
    }
}

/// Direction-number table for [`SobolSampling`] dimensions 2..=16:
/// `(degree s, coefficients a, initial m values)` from the standard
/// Joe–Kuo "new-joe-kuo-6" set. Dimension 1 is the van der Corput
/// sequence and needs no entry.
const SOBOL_DIRECTIONS: &[(u32, u32, &[u32])] = &[
    (1, 0, &[1]),
    (2, 1, &[1, 3]),
    (3, 1, &[1, 3, 1]),
    (3, 2, &[1, 1, 1]),
    (4, 1, &[1, 1, 3, 3]),
    (4, 4, &[1, 3, 5, 13]),
    (5, 2, &[1, 1, 5, 5, 17]),
    (5, 4, &[1, 1, 5, 5, 5]),
    (5, 7, &[1, 1, 7, 11, 19]),
    (5, 11, &[1, 1, 5, 1, 1]),
    (5, 13, &[1, 1, 1, 3, 11]),
    (5, 14, &[1, 3, 5, 5, 31]),
    (6, 1, &[1, 3, 3, 9, 7, 49]),
    (6, 13, &[1, 1, 1, 15, 21, 21]),
    (6, 16, &[1, 3, 1, 13, 27, 49]),
];

/// Highest supported Sobol dimensionality (the vendored direction-number
/// table; extend [`SOBOL_DIRECTIONS`] to go further).
pub const SOBOL_MAX_DIM: usize = SOBOL_DIRECTIONS.len() + 1;

const SOBOL_BITS: usize = 32;

/// 32-bit direction vectors of Sobol dimension `dim_index` (0-based).
fn sobol_direction_vectors(dim_index: usize) -> [u32; SOBOL_BITS] {
    let mut v = [0u32; SOBOL_BITS];
    if dim_index == 0 {
        for (k, slot) in v.iter_mut().enumerate() {
            *slot = 1u32 << (31 - k);
        }
        return v;
    }
    let (s, a, m) = SOBOL_DIRECTIONS[dim_index - 1];
    let s = s as usize;
    for k in 0..s {
        v[k] = m[k] << (31 - k);
    }
    for k in s..SOBOL_BITS {
        v[k] = v[k - s] ^ (v[k - s] >> s);
        for i in 1..s {
            if (a >> (s - 1 - i)) & 1 == 1 {
                v[k] ^= v[k - i];
            }
        }
    }
    v
}

/// Sobol low-discrepancy sampling (§Exploration): the first `n` points of
/// the Joe–Kuo Sobol sequence mapped onto the given boxes. Deterministic —
/// the sequence ignores the RNG, so a design depends only on `(dims, n)`
/// and two runs of the same sweep agree point for point. Gray-code
/// generation: point `i` flips one direction vector per dimension, so a
/// full design is O(n·dim) with zero steady-state allocations (per-dim
/// state lives in the matrix's scratch arena).
pub struct SobolSampling {
    dims: Vec<(String, f64, f64)>,
    n: usize,
    directions: Vec<[u32; SOBOL_BITS]>,
}

impl SobolSampling {
    /// Panics if `dims` exceeds [`SOBOL_MAX_DIM`] (the vendored
    /// direction-number table).
    pub fn new(dims: &[(&Val<f64>, f64, f64)], n: usize) -> Self {
        assert!(
            dims.len() <= SOBOL_MAX_DIM,
            "SobolSampling supports at most {SOBOL_MAX_DIM} dimensions, got {}",
            dims.len()
        );
        assert!(
            (n as u64) < (1u64 << SOBOL_BITS),
            "SobolSampling supports at most 2^{SOBOL_BITS} points"
        );
        SobolSampling {
            dims: dims
                .iter()
                .map(|(v, lo, hi)| (v.name().to_string(), *lo, *hi))
                .collect(),
            n,
            directions: (0..dims.len()).map(sobol_direction_vectors).collect(),
        }
    }
}

impl Sampling for SobolSampling {
    fn name(&self) -> &str {
        "Sobol"
    }

    fn columns(&self) -> Vec<Column> {
        self.dims.iter().map(|(n, _, _)| Column::f64(n)).collect()
    }

    fn size_hint(&self) -> Option<usize> {
        Some(self.n)
    }

    fn sample_into(&self, out: &mut SampleMatrix, rng: &mut Rng) -> Result<()> {
        self.sample_into_block(out, 0..self.n, rng)
    }

    fn supports_blocks(&self) -> bool {
        true
    }

    /// Sobol state at index `i` is the XOR of direction vectors `v[k]`
    /// over the set bits `k` of `gray(i) = i ^ (i >> 1)` — so any block
    /// seeks to its first row in O(dim · 32) and then gray-steps, emitting
    /// exactly the rows the whole-design path (this method over `0..n`)
    /// would.
    fn sample_into_block(
        &self,
        out: &mut SampleMatrix,
        rows: Range<usize>,
        _rng: &mut Rng,
    ) -> Result<()> {
        out.check_columns_iter(
            self.dims.iter().map(|(n, _, _)| (n.as_str(), ColumnKind::F64)),
            self.name(),
        )?;
        if rows.end > self.n {
            return Err(Error::InvalidWorkflow(format!(
                "block {}..{} out of range: `{}` design has {} rows",
                rows.start,
                rows.end,
                self.name(),
                self.n
            )));
        }
        let start = out.grow_rows(rows.len());
        let mut state = std::mem::take(&mut out.u64_scratch);
        state.clear();
        state.resize(self.dims.len(), 0);
        const SCALE: f64 = 1.0 / (1u64 << SOBOL_BITS) as f64;
        let first = rows.start;
        // seek: fold in v[k] for every set bit k of gray(first)
        let g = (first as u64) ^ ((first as u64) >> 1);
        for k in 0..SOBOL_BITS {
            if (g >> k) & 1 == 1 {
                for (x, v) in state.iter_mut().zip(&self.directions) {
                    *x ^= u64::from(v[k]);
                }
            }
        }
        for (w, i) in rows.enumerate() {
            if i > first {
                // Gray-code step: flip direction vector c, where c is the
                // index of the lowest set bit of i (= the first zero bit
                // of i-1, per Joe–Kuo)
                let c = i.trailing_zeros() as usize;
                for (x, v) in state.iter_mut().zip(&self.directions) {
                    *x ^= u64::from(v[c]);
                }
            }
            let row = out.row_mut(start + w);
            for (d, (_, lo, hi)) in self.dims.iter().enumerate() {
                row[d] = unit_to_range(state[d] as f64 * SCALE, *lo, *hi);
            }
        }
        out.u64_scratch = state;
        Ok(())
    }
}

/// `seed in (UniformDistribution[Int]() take n)` — the replication
/// sampling of paper §4.4: n independent model seeds.
pub struct SeedSampling {
    name: String,
    n: usize,
}

impl SeedSampling {
    pub fn new(v: &Val<u32>, n: usize) -> Self {
        SeedSampling {
            name: v.name().to_string(),
            n,
        }
    }
}

impl Sampling for SeedSampling {
    fn name(&self) -> &str {
        "SeedSampling"
    }

    fn columns(&self) -> Vec<Column> {
        vec![Column::u32(&self.name)]
    }

    fn size_hint(&self) -> Option<usize> {
        Some(self.n)
    }

    fn sample_into(&self, out: &mut SampleMatrix, rng: &mut Rng) -> Result<()> {
        out.check_columns_iter(
            std::iter::once((self.name.as_str(), ColumnKind::U32)),
            self.name(),
        )?;
        let start = out.grow_rows(self.n);
        for i in 0..self.n {
            // u32 round-trips exactly through the f64 cell
            out.row_mut(start + i)[0] = f64::from(rng.model_seed());
        }
        Ok(())
    }
}

/// Explicit list of contexts (CSV-style sampling). Context-only: the
/// values may be of any type, so there is no columnar path.
pub struct ExplicitSampling {
    contexts: Vec<Context>,
}

impl ExplicitSampling {
    pub fn new(contexts: Vec<Context>) -> Self {
        ExplicitSampling { contexts }
    }
}

impl Sampling for ExplicitSampling {
    fn name(&self) -> &str {
        "ExplicitSampling"
    }

    fn sample(&self, base: &Context, _rng: &mut Rng) -> Vec<Context> {
        self.contexts
            .iter()
            .map(|c| {
                let mut m = base.clone();
                m.merge(c);
                m
            })
            .collect()
    }
}

/// Variables `sampled` defines beyond (or differently from) `base` — what
/// a fixed right-hand design contributes to each product row.
fn context_diff(sampled: &Context, base: &Context) -> Context {
    let mut out = Context::new();
    for name in sampled.names() {
        let v = sampled.get_raw(name).expect("name yielded by iterator");
        if base.get_raw(name) != Some(v) {
            out.set_raw(name, v.clone());
        }
    }
    out
}

/// Cartesian product of two samplings (`a x b`, the DSL's combinator).
///
/// OpenMOLE semantics: **both operand designs are sampled once**, then
/// crossed — `lhs x uniform` pairs every LHS point with the *same* fixed
/// uniform design. (The historical implementation re-drew the right-hand
/// sampling for every left element, so a stochastic right side produced a
/// fresh design per left row — not a Cartesian product of two designs.
/// Pinned by the `product_right_design_is_fixed` regression test.)
pub struct ProductSampling {
    a: Arc<dyn Sampling>,
    b: Arc<dyn Sampling>,
}

impl ProductSampling {
    pub fn new(a: Arc<dyn Sampling>, b: Arc<dyn Sampling>) -> Self {
        ProductSampling { a, b }
    }
}

impl Sampling for ProductSampling {
    fn name(&self) -> &str {
        "ProductSampling"
    }

    /// Columnar iff both operands are; a context-only operand forces the
    /// whole product onto the context path.
    fn columns(&self) -> Vec<Column> {
        let a = self.a.columns();
        let b = self.b.columns();
        if a.is_empty() || b.is_empty() {
            return Vec::new();
        }
        a.into_iter().chain(b).collect()
    }

    fn size_hint(&self) -> Option<usize> {
        // checked, not saturating: an overflowing hint is better reported
        // as "unknown" than as a plausible wrapped number
        self.a.size_hint()?.checked_mul(self.b.size_hint()?)
    }

    fn sample_into(&self, out: &mut SampleMatrix, rng: &mut Rng) -> Result<()> {
        let columns = self.columns();
        if columns.is_empty() {
            return Err(Error::InvalidWorkflow(format!(
                "sampling `{}` has no columnar path (context-only operand)",
                self.name()
            )));
        }
        out.check_columns(&columns, self.name())?;
        // each operand design sampled exactly once (left first), then
        // crossed left-major. Temporary operand matrices: the product is
        // a combinator, not a steady-state wave generator.
        let mut ma = SampleMatrix::new(self.a.columns());
        self.a.sample_into(&mut ma, rng)?;
        let mut mb = SampleMatrix::new(self.b.columns());
        self.b.sample_into(&mut mb, rng)?;
        let (ca, cb) = (ma.dim(), mb.dim());
        let start = out.grow_rows(ma.len() * mb.len());
        for i in 0..ma.len() {
            for j in 0..mb.len() {
                let row = out.row_mut(start + i * mb.len() + j);
                row[..ca].copy_from_slice(ma.row(i));
                row[ca..ca + cb].copy_from_slice(mb.row(j));
            }
        }
        Ok(())
    }

    fn sample(&self, base: &Context, rng: &mut Rng) -> Vec<Context> {
        if self.is_columnar() {
            // the shared adapter — this override exists only for the
            // context-only fallback below
            return sample_via_matrix(self, base, rng);
        }
        // context fallback (an operand is context-only): the right design
        // is still sampled ONCE against the base context; only what it
        // defines beyond the base is merged over every left sample
        let left = self.a.sample(base, rng);
        let right: Vec<Context> = self
            .b
            .sample(base, rng)
            .iter()
            .map(|r| context_diff(r, base))
            .collect();
        let mut out = Vec::with_capacity(left.len() * right.len());
        for l in &left {
            for r in &right {
                let mut c = l.clone();
                c.merge(r);
                out.push(c);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{val_f64, val_u32};

    #[test]
    fn full_factorial_covers_grid() {
        let x = val_f64("x");
        let y = val_f64("y");
        let s = FullFactorial::new(vec![
            Factor::new(&x, 0.0, 1.0, 0.5),
            Factor::new(&y, 0.0, 1.0, 1.0),
        ]);
        let mut rng = Rng::new(0);
        let samples = s.sample(&Context::new(), &mut rng);
        assert_eq!(samples.len(), 6); // 3 x-levels, 2 y-levels
        assert_eq!(s.size(), 6);
        assert!(samples
            .iter()
            .any(|c| c.get(&x).unwrap() == 1.0 && c.get(&y).unwrap() == 0.0));
    }

    #[test]
    fn factor_levels_do_not_drift_on_long_ranges() {
        // the historical `x += step` accumulation drifted off-grid on long
        // ranges; `lo + i·step` indexing must hit every level exactly
        let x = val_f64("x");
        let f = Factor::new(&x, 0.0, 1000.0, 0.1);
        assert_eq!(f.level_count(), 10_001);
        let levels = f.levels();
        assert_eq!(levels.len(), 10_001);
        for (i, &v) in levels.iter().enumerate() {
            assert_eq!(v, (i as f64 * 0.1).min(1000.0), "level {i} off-grid");
        }
        assert_eq!(*levels.last().unwrap(), 1000.0);
    }

    #[test]
    fn factor_size_agrees_with_sample_for_awkward_ranges() {
        let x = val_f64("x");
        let y = val_f64("y");
        for (lo, hi, step) in [
            (0.0, 1000.0, 0.1),
            (0.0, 99.0, 24.75),
            (0.1, 0.3, 0.1),
            (-1.0, 1.0, 0.3),
            (0.0, 0.0, 1.0), // degenerate: single level
            (5.0, 4.0, 1.0), // empty range
        ] {
            let f = Factor::new(&x, lo, hi, step);
            assert_eq!(
                f.level_count(),
                f.levels().len(),
                "count vs levels for ({lo}, {hi}, {step})"
            );
            let s = FullFactorial::new(vec![
                Factor::new(&x, lo, hi, step),
                Factor::new(&y, 0.0, 1.0, 0.5),
            ]);
            let mut rng = Rng::new(1);
            assert_eq!(
                s.size(),
                s.sample(&Context::new(), &mut rng).len(),
                "size vs sample for ({lo}, {hi}, {step})"
            );
        }
    }

    #[test]
    fn uniform_respects_documented_half_open_bounds() {
        let x = val_f64("x");
        let s = UniformSampling::new(&x, 10.0, 20.0, 100);
        let mut rng = Rng::new(1);
        for c in s.sample(&Context::new(), &mut rng) {
            let v = c.get(&x).unwrap();
            // [lo, hi) is the documented contract of Rng::range
            assert!((10.0..20.0).contains(&v));
        }
    }

    #[test]
    fn multi_uniform_draws_joint_rows() {
        let x = val_f64("x");
        let y = val_f64("y");
        let s = UniformSampling::multi(&[(&x, 0.0, 1.0), (&y, 5.0, 6.0)], 40);
        let mut rng = Rng::new(2);
        let samples = s.sample(&Context::new(), &mut rng);
        assert_eq!(samples.len(), 40);
        for c in &samples {
            assert!((0.0..1.0).contains(&c.get(&x).unwrap()));
            assert!((5.0..6.0).contains(&c.get(&y).unwrap()));
        }
    }

    #[test]
    fn lhs_stratifies_each_dimension() {
        let x = val_f64("x");
        let s = LhsSampling::new(&[(&x, 0.0, 1.0)], 10);
        let mut rng = Rng::new(2);
        let samples = s.sample(&Context::new(), &mut rng);
        // exactly one sample per decile
        let mut seen = [false; 10];
        for c in &samples {
            let v = c.get(&x).unwrap();
            let bin = ((v * 10.0) as usize).min(9);
            assert!(!seen[bin], "two samples in decile {bin}");
            seen[bin] = true;
        }
    }

    #[test]
    fn lhs_never_reaches_the_upper_bound() {
        // the `lo + u·(hi-lo)` mapping is clamped: even the top stratum's
        // jitter must stay strictly below `hi`
        let x = val_f64("x");
        let y = val_f64("y");
        let mut rng = Rng::new(3);
        let s = LhsSampling::new(&[(&x, 0.0, 3.0), (&y, -2.0, -1.0)], 257);
        let mut m = SampleMatrix::new(s.columns());
        s.sample_into(&mut m, &mut rng).unwrap();
        for i in 0..m.len() {
            let row = m.row(i);
            assert!((0.0..3.0).contains(&row[0]), "x = {} out of [0, 3)", row[0]);
            assert!(
                (-2.0..-1.0).contains(&row[1]),
                "y = {} out of [-2, -1)",
                row[1]
            );
        }
    }

    #[test]
    fn sobol_first_points_match_the_reference_sequence() {
        let x = val_f64("x");
        let y = val_f64("y");
        let s = SobolSampling::new(&[(&x, 0.0, 1.0), (&y, 0.0, 1.0)], 4);
        let mut m = SampleMatrix::new(s.columns());
        s.sample_into(&mut m, &mut Rng::new(0)).unwrap();
        // the canonical 2-D Joe–Kuo sequence
        assert_eq!(m.row(0), &[0.0, 0.0]);
        assert_eq!(m.row(1), &[0.5, 0.5]);
        assert_eq!(m.row(2), &[0.75, 0.25]);
        assert_eq!(m.row(3), &[0.25, 0.75]);
    }

    #[test]
    fn sobol_is_a_binary_net_in_every_dimension() {
        // the first 2^k Sobol points hit each dyadic interval of width
        // 2^-k exactly once, in every 1-D projection — the low-discrepancy
        // property factorial/LHS designs cannot give at this density
        let vals: Vec<Val<f64>> = (0..5).map(|d| val_f64(&format!("x{d}"))).collect();
        let spec: Vec<(&Val<f64>, f64, f64)> =
            vals.iter().map(|v| (v, 0.0, 1.0)).collect();
        let n = 64;
        let s = SobolSampling::new(&spec, n);
        let mut m = SampleMatrix::new(s.columns());
        s.sample_into(&mut m, &mut Rng::new(0)).unwrap();
        for d in 0..vals.len() {
            let mut seen = vec![false; n];
            for i in 0..n {
                let bin = (m.row(i)[d] * n as f64) as usize;
                assert!(!seen[bin], "dim {d}: two points in bin {bin}");
                seen[bin] = true;
            }
        }
    }

    #[test]
    fn sobol_is_deterministic_across_rng_seeds() {
        let x = val_f64("x");
        let s = SobolSampling::new(&[(&x, 0.0, 99.0)], 100);
        let mut a = SampleMatrix::new(s.columns());
        let mut b = SampleMatrix::new(s.columns());
        s.sample_into(&mut a, &mut Rng::new(1)).unwrap();
        s.sample_into(&mut b, &mut Rng::new(999)).unwrap();
        assert_eq!(a.data(), b.data(), "Sobol designs depend only on (dims, n)");
    }

    #[test]
    fn sobol_blocks_match_the_whole_design() {
        // the block seek (XOR of v[k] over gray(first)'s set bits) must be
        // bit-identical to gray-stepping from the origin
        let x = val_f64("x");
        let y = val_f64("y");
        let z = val_f64("z");
        let s = SobolSampling::new(&[(&x, 0.0, 1.0), (&y, -3.0, 5.0), (&z, 10.0, 11.0)], 100);
        assert!(s.supports_blocks());
        let mut whole = SampleMatrix::new(s.columns());
        s.sample_into(&mut whole, &mut Rng::new(0)).unwrap();
        let mut rng = Rng::new(7);
        for (lo, hi) in [(0, 1), (1, 7), (7, 64), (63, 65), (64, 100), (99, 100), (42, 42)] {
            let mut block = SampleMatrix::new(s.columns());
            s.sample_into_block(&mut block, lo..hi, &mut rng).unwrap();
            assert_eq!(block.len(), hi - lo);
            assert_eq!(
                block.data(),
                whole.rows_slice(lo, hi),
                "block {lo}..{hi} diverged from the whole design"
            );
        }
        assert!(s.sample_into_block(&mut SampleMatrix::new(s.columns()), 90..101, &mut rng).is_err());
    }

    #[test]
    fn factorial_blocks_match_the_whole_design() {
        let x = val_f64("x");
        let y = val_f64("y");
        let s = FullFactorial::new(vec![
            Factor::new(&x, 0.0, 1.0, 0.25),
            Factor::new(&y, 0.0, 6.0, 1.0),
        ]);
        assert!(s.supports_blocks());
        let n = s.size();
        assert_eq!(n, 35);
        let mut whole = SampleMatrix::new(s.columns());
        s.sample_into(&mut whole, &mut Rng::new(0)).unwrap();
        let mut rng = Rng::new(8);
        for (lo, hi) in [(0, 5), (5, 6), (6, 20), (20, 35), (34, 35)] {
            let mut block = SampleMatrix::new(s.columns());
            s.sample_into_block(&mut block, lo..hi, &mut rng).unwrap();
            assert_eq!(block.data(), whole.rows_slice(lo, hi), "block {lo}..{hi}");
        }
        assert!(s.sample_into_block(&mut SampleMatrix::new(s.columns()), 30..36, &mut rng).is_err());
    }

    #[test]
    fn sequential_samplings_refuse_the_block_path() {
        let x = val_f64("x");
        let lhs = LhsSampling::new(&[(&x, 0.0, 1.0)], 8);
        assert!(!lhs.supports_blocks());
        let mut m = SampleMatrix::new(lhs.columns());
        let err = lhs.sample_into_block(&mut m, 0..4, &mut Rng::new(0));
        assert!(err.is_err(), "LHS designs only exist as a whole");
        assert!(!UniformSampling::new(&x, 0.0, 1.0, 4).supports_blocks());
    }

    #[test]
    fn seed_sampling_unique_seeds() {
        let seed = val_u32("seed");
        let s = SeedSampling::new(&seed, 50);
        let mut rng = Rng::new(3);
        let seeds: Vec<u32> = s
            .sample(&Context::new(), &mut rng)
            .iter()
            .map(|c| c.get(&seed).unwrap())
            .collect();
        let mut dedup = seeds.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), seeds.len());
    }

    #[test]
    fn product_is_cartesian() {
        let x = val_f64("x");
        let y = val_f64("y");
        let s = ProductSampling::new(
            Arc::new(FullFactorial::new(vec![Factor::new(&x, 0.0, 1.0, 1.0)])),
            Arc::new(FullFactorial::new(vec![Factor::new(&y, 0.0, 2.0, 1.0)])),
        );
        let mut rng = Rng::new(4);
        assert_eq!(s.sample(&Context::new(), &mut rng).len(), 6);
        assert_eq!(s.size_hint(), Some(6));
    }

    #[test]
    fn product_right_design_is_fixed() {
        // regression (OpenMOLE `x` semantics): a stochastic right-hand
        // sampling is drawn ONCE — every left element is paired with the
        // same right design, not a fresh redraw per left element
        let x = val_f64("x");
        let y = val_f64("y");
        let s = ProductSampling::new(
            Arc::new(FullFactorial::new(vec![Factor::new(&x, 0.0, 2.0, 1.0)])),
            Arc::new(UniformSampling::new(&y, 0.0, 1.0, 4)),
        );
        let mut rng = Rng::new(5);
        let samples = s.sample(&Context::new(), &mut rng);
        assert_eq!(samples.len(), 12);
        let block: Vec<f64> = samples[0..4].iter().map(|c| c.get(&y).unwrap()).collect();
        for left in 1..3 {
            let other: Vec<f64> = samples[left * 4..(left + 1) * 4]
                .iter()
                .map(|c| c.get(&y).unwrap())
                .collect();
            assert_eq!(block, other, "left block {left} saw a redrawn right design");
        }
    }

    #[test]
    fn product_context_fallback_keeps_right_design_fixed() {
        // same semantics through the context-only fallback (explicit left)
        let x = val_f64("x");
        let y = val_f64("y");
        let left = ExplicitSampling::new(vec![
            Context::new().with(&x, 1.0),
            Context::new().with(&x, 2.0),
        ]);
        let s = ProductSampling::new(
            Arc::new(left),
            Arc::new(UniformSampling::new(&y, 0.0, 1.0, 3)),
        );
        assert!(!s.is_columnar());
        let mut rng = Rng::new(6);
        let samples = s.sample(&Context::new(), &mut rng);
        assert_eq!(samples.len(), 6);
        let first: Vec<f64> = samples[0..3].iter().map(|c| c.get(&y).unwrap()).collect();
        let second: Vec<f64> = samples[3..6].iter().map(|c| c.get(&y).unwrap()).collect();
        assert_eq!(first, second);
        // left values survive the merge of the fixed right design
        assert_eq!(samples[0].get(&x).unwrap(), 1.0);
        assert_eq!(samples[3].get(&x).unwrap(), 2.0);
    }

    #[test]
    fn sampling_preserves_base_context() {
        let x = val_f64("x");
        let z = val_f64("z");
        let s = UniformSampling::new(&x, 0.0, 1.0, 3);
        let mut rng = Rng::new(5);
        let base = Context::new().with(&z, 9.0);
        for c in s.sample(&base, &mut rng) {
            assert_eq!(c.get(&z).unwrap(), 9.0);
        }
    }

    #[test]
    fn columnar_flags_are_accurate() {
        let x = val_f64("x");
        let seed = val_u32("seed");
        assert!(UniformSampling::new(&x, 0.0, 1.0, 2).is_columnar());
        assert!(LhsSampling::new(&[(&x, 0.0, 1.0)], 2).is_columnar());
        assert!(SobolSampling::new(&[(&x, 0.0, 1.0)], 2).is_columnar());
        assert!(SeedSampling::new(&seed, 2).is_columnar());
        assert!(FullFactorial::new(vec![Factor::new(&x, 0.0, 1.0, 1.0)]).is_columnar());
        assert!(!ExplicitSampling::new(vec![Context::new()]).is_columnar());
    }
}
