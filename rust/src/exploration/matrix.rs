//! Columnar sample storage (§Exploration tentpole): a design of
//! experiments is a [`SampleMatrix`] — one contiguous row-major `f64`
//! matrix whose columns are the sampled variables — instead of a
//! `Vec<Context>` of per-sample clones. This is the exploration twin of
//! [`crate::evolution::popmatrix::PopMatrix`]: same memory layout, same
//! arena discipline (`clear`/`grow_rows` never release capacity, scratch
//! buffers live with the matrix and are recycled wave after wave), so a
//! steady-state sample wave — clear, regenerate the design, evaluate —
//! performs **zero** heap allocations (measured by the counting global
//! allocator in `cargo bench --bench p4_explore`).
//!
//! The `Context` representation survives only at the DSL edges:
//! [`SampleMatrix::context_row`] materialises one sample as a context when
//! a workflow capsule actually needs it, which is how the scheduler
//! streams a 200k-row design without ever holding 200k cloned contexts.
//!
//! Since the out-of-core refactor the matrix owns its rows through a
//! [`RowStore`] instead of a raw `Vec<f64>`: the default backing is the
//! same contiguous in-RAM vector as before (every accessor below is
//! unchanged), but [`SampleMatrix::spilled`] builds a matrix whose rows
//! page to disk under a `--mem-budget` resident cap — read and written
//! through the block API ([`SampleMatrix::write_rows`] /
//! [`SampleMatrix::copy_rows`]), which is how a 10M-row campaign fits in
//! fixed memory.

use std::path::Path;

use crate::core::{Context, Value};
use crate::error::{Error, Result};
use crate::exploration::rowstore::RowStore;

/// Runtime type of one design column. Values are stored as `f64` either
/// way (`u32` round-trips exactly through `f64`); the kind decides what a
/// context edge materialises — [`SeedSampling`](crate::exploration::SeedSampling)
/// columns must surface as the `u32` model seeds tasks declare.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ColumnKind {
    F64,
    U32,
}

/// Name + kind of one design column.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Column {
    pub name: String,
    pub kind: ColumnKind,
}

impl Column {
    pub fn f64(name: impl Into<String>) -> Self {
        Column {
            name: name.into(),
            kind: ColumnKind::F64,
        }
    }

    pub fn u32(name: impl Into<String>) -> Self {
        Column {
            name: name.into(),
            kind: ColumnKind::U32,
        }
    }
}

/// A design of experiments as a row-major matrix: row `i` is sample `i`,
/// column `d` is the `d`-th sampled variable. Mutation never releases
/// capacity, and the embedded scratch buffers let samplings (LHS strata
/// shuffles, Sobol per-dimension state) run allocation-free once the
/// matrix has been through one wave.
#[derive(Debug, Clone)]
pub struct SampleMatrix {
    columns: Vec<Column>,
    store: RowStore,
    /// Index scratch (LHS stratum shuffles, factorial level counts) —
    /// recycled across dimensions and waves.
    pub idx_scratch: Vec<usize>,
    /// Integer-state scratch (Sobol per-dimension sequence state).
    pub u64_scratch: Vec<u64>,
}

impl SampleMatrix {
    pub fn new(columns: Vec<Column>) -> Self {
        let store = RowStore::ram(columns.len());
        SampleMatrix {
            columns,
            store,
            idx_scratch: Vec::new(),
            u64_scratch: Vec::new(),
        }
    }

    pub fn with_capacity(columns: Vec<Column>, rows: usize) -> Self {
        let store = RowStore::ram_with_capacity(columns.len(), rows);
        SampleMatrix {
            columns,
            store,
            idx_scratch: Vec::new(),
            u64_scratch: Vec::new(),
        }
    }

    /// Matrix whose rows page to a scratch file under `spill_dir`, keeping
    /// at most `mem_budget` bytes of row storage resident (see
    /// [`RowStore::spilled`]). Contiguous accessors panic on this backing;
    /// use [`SampleMatrix::write_rows`] / [`SampleMatrix::copy_rows`].
    pub fn spilled(
        columns: Vec<Column>,
        spill_dir: &Path,
        mem_budget: u64,
        rows_per_chunk: usize,
    ) -> Result<Self> {
        let store = RowStore::spilled(columns.len(), spill_dir, mem_budget, rows_per_chunk)?;
        Ok(SampleMatrix {
            columns,
            store,
            idx_scratch: Vec::new(),
            u64_scratch: Vec::new(),
        })
    }

    pub fn columns(&self) -> &[Column] {
        &self.columns
    }

    /// Column names in order (result-file headers).
    pub fn column_names(&self) -> impl Iterator<Item = &str> {
        self.columns.iter().map(|c| c.name.as_str())
    }

    /// Number of columns.
    pub fn dim(&self) -> usize {
        self.columns.len()
    }

    /// Number of sample rows.
    pub fn len(&self) -> usize {
        self.store.len()
    }

    pub fn is_empty(&self) -> bool {
        self.store.is_empty()
    }

    /// `true` when rows live in the chunk-paged file-backed store.
    pub fn is_spilled(&self) -> bool {
        self.store.is_spilled()
    }

    /// Float capacity of the retained row arena (asserts the
    /// clear-and-regrow wave discipline never reallocates).
    pub fn capacity_floats(&self) -> usize {
        self.store.capacity_floats()
    }

    /// High-water mark of resident row-storage bytes.
    pub fn peak_resident_bytes(&self) -> u64 {
        self.store.peak_resident_bytes()
    }

    /// Drop all rows, keeping capacity (and scratch) for the next wave.
    pub fn clear(&mut self) {
        self.store.clear();
    }

    /// Append `n` zero-filled rows (about to be written by a sampling);
    /// returns the index of the first new row. Reuses capacity.
    pub fn grow_rows(&mut self, n: usize) -> usize {
        self.store.grow_rows(n)
    }

    /// Append one row.
    pub fn push_row(&mut self, row: &[f64]) {
        debug_assert_eq!(row.len(), self.dim());
        self.store.push_row(row);
    }

    pub fn row(&self, i: usize) -> &[f64] {
        self.store.row(i)
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        self.store.row_mut(i)
    }

    /// Rows `lo..hi` as one contiguous row-major slice — the shape an
    /// `evaluate_rows` chunk job consumes.
    pub fn rows_slice(&self, lo: usize, hi: usize) -> &[f64] {
        self.store.rows_slice(lo, hi)
    }

    /// The whole matrix, row-major.
    pub fn data(&self) -> &[f64] {
        self.store.data()
    }

    /// Overwrite contiguous rows starting at `first_row` — works on either
    /// backing (the spill-safe write path).
    pub fn write_rows(&mut self, first_row: usize, data: &[f64]) {
        self.store.write_rows(first_row, data);
    }

    /// Copy rows `lo..hi` into the caller's recycled buffer — works on
    /// either backing (the spill-safe read path).
    pub fn copy_rows(&mut self, lo: usize, hi: usize, out: &mut Vec<f64>) {
        self.store.copy_rows(lo, hi, out);
    }

    /// Materialise row `i` as a context merged over `base` (the DSL edge:
    /// one cloned context per *submitted* job, never per design row).
    pub fn context_row(&self, i: usize, base: &Context) -> Context {
        let mut ctx = base.clone();
        for (c, &v) in self.columns.iter().zip(self.row(i)) {
            let value = match c.kind {
                ColumnKind::F64 => Value::F64(v),
                ColumnKind::U32 => Value::U32(v as u32),
            };
            ctx.set_raw(&c.name, value);
        }
        ctx
    }

    /// Materialise the whole design as contexts (legacy edge adapter —
    /// allocates one context per row; the streaming paths never call it).
    pub fn to_contexts(&self, base: &Context) -> Vec<Context> {
        (0..self.len()).map(|i| self.context_row(i, base)).collect()
    }

    /// Error unless `expected` describes this matrix's columns (the
    /// contract every `sample_into` implementation checks before writing).
    pub fn check_columns(&self, expected: &[Column], sampling: &str) -> Result<()> {
        self.check_columns_iter(
            expected.iter().map(|c| (c.name.as_str(), c.kind)),
            sampling,
        )
    }

    /// Allocation-free twin of [`SampleMatrix::check_columns`]: samplings
    /// on the steady-state wave path stream their column spec instead of
    /// building a `Vec<Column>` per call (only the error path formats).
    pub fn check_columns_iter<'a>(
        &self,
        expected: impl ExactSizeIterator<Item = (&'a str, ColumnKind)> + Clone,
        sampling: &str,
    ) -> Result<()> {
        let ok = expected.len() == self.columns.len()
            && expected
                .clone()
                .zip(&self.columns)
                .all(|((name, kind), c)| c.name == name && c.kind == kind);
        if ok {
            return Ok(());
        }
        Err(Error::InvalidWorkflow(format!(
            "sampling `{sampling}` writes columns {:?}, matrix has {:?}",
            expected.map(|(n, _)| n).collect::<Vec<_>>(),
            self.columns.iter().map(|c| c.name.as_str()).collect::<Vec<_>>(),
        )))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{val_f64, val_u32};

    fn xy() -> Vec<Column> {
        vec![Column::f64("x"), Column::u32("s")]
    }

    #[test]
    fn rows_round_trip() {
        let mut m = SampleMatrix::new(xy());
        m.push_row(&[0.5, 7.0]);
        m.push_row(&[1.5, 9.0]);
        assert_eq!(m.len(), 2);
        assert_eq!(m.dim(), 2);
        assert_eq!(m.row(1), &[1.5, 9.0]);
        assert_eq!(m.rows_slice(0, 2), &[0.5, 7.0, 1.5, 9.0]);
    }

    #[test]
    fn context_row_respects_column_kinds() {
        let mut m = SampleMatrix::new(xy());
        m.push_row(&[2.5, 4294967295.0]); // u32::MAX round-trips through f64
        let base = Context::new().with(&val_f64("z"), 9.0);
        let ctx = m.context_row(0, &base);
        assert_eq!(ctx.get(&val_f64("x")).unwrap(), 2.5);
        assert_eq!(ctx.get(&val_u32("s")).unwrap(), u32::MAX);
        assert_eq!(ctx.get(&val_f64("z")).unwrap(), 9.0, "base preserved");
    }

    #[test]
    fn clear_and_grow_reuse_capacity() {
        let mut m = SampleMatrix::new(xy());
        let first = m.grow_rows(8);
        assert_eq!(first, 0);
        assert_eq!(m.len(), 8);
        m.row_mut(7)[0] = 3.0;
        let cap = m.capacity_floats();
        m.clear();
        assert!(m.is_empty());
        let first = m.grow_rows(8);
        assert_eq!(first, 0);
        assert_eq!(m.row(7)[0], 0.0, "grown rows are zeroed");
        assert_eq!(m.capacity_floats(), cap, "clear+grow must not reallocate");
    }

    #[test]
    fn spilled_matrix_round_trips_rows_through_the_block_api() {
        let dir = std::env::temp_dir().join(format!("molers-matrix-spill-{}", std::process::id()));
        let mut m = SampleMatrix::spilled(xy(), &dir, 4 * 2 * 8, 4).unwrap();
        assert!(m.is_spilled());
        m.grow_rows(10);
        m.write_rows(6, &[1.5, 7.0, 2.5, 9.0]);
        let mut buf = Vec::new();
        m.copy_rows(6, 8, &mut buf);
        assert_eq!(buf, &[1.5, 7.0, 2.5, 9.0]);
        m.copy_rows(0, 1, &mut buf);
        assert_eq!(buf, &[0.0, 0.0], "unwritten rows read as zeros");
        assert!(m.peak_resident_bytes() > 0);
        drop(m);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn check_columns_rejects_mismatch() {
        let m = SampleMatrix::new(xy());
        assert!(m.check_columns(&xy(), "s").is_ok());
        assert!(m.check_columns(&[Column::f64("x")], "s").is_err());
        assert!(m
            .check_columns(&[Column::f64("x"), Column::f64("s")], "s")
            .is_err());
    }

    #[test]
    fn zero_column_matrix_counts_rows() {
        // a FullFactorial with no factors still yields one (empty) sample
        let mut m = SampleMatrix::new(Vec::new());
        m.grow_rows(1);
        assert_eq!(m.len(), 1);
        assert_eq!(m.row(0), &[] as &[f64]);
        let ctx = m.context_row(0, &Context::new());
        assert!(ctx.is_empty());
    }
}
