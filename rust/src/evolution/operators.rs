//! Real-coded variation operators: SBX crossover and polynomial mutation —
//! the standard NSGA-II operator suite (Deb et al. 2002).

use crate::evolution::genome::Bounds;
use crate::util::Rng;

/// Operator parameters. Defaults match the canonical NSGA-II settings.
#[derive(Debug, Clone)]
pub struct Operators {
    /// SBX distribution index (larger = children closer to parents).
    pub eta_crossover: f64,
    /// Polynomial-mutation distribution index.
    pub eta_mutation: f64,
    /// Per-gene crossover probability once a pair is selected.
    pub p_crossover: f64,
    /// Per-gene mutation probability; `None` = 1/dim.
    pub p_mutation: Option<f64>,
}

impl Default for Operators {
    fn default() -> Self {
        Operators {
            eta_crossover: 15.0,
            eta_mutation: 20.0,
            p_crossover: 0.9,
            p_mutation: None,
        }
    }
}

/// Genes closer than this are treated as identical by SBX (no crossover).
const SBX_EPSILON: f64 = 1e-14;

/// The SBX spread factor for one uniform draw `u` — shared by the AoS
/// [`Operators::sbx`] and the columnar [`Operators::breed_into`] so the
/// two paths cannot drift apart.
#[inline]
fn sbx_beta(u: f64, eta: f64) -> f64 {
    if u <= 0.5 {
        (2.0 * u).powf(1.0 / (eta + 1.0))
    } else {
        (1.0 / (2.0 * (1.0 - u))).powf(1.0 / (eta + 1.0))
    }
}

impl Operators {
    /// Simulated binary crossover: produce two children from two parents.
    pub fn sbx(
        &self,
        a: &[f64],
        b: &[f64],
        bounds: &Bounds,
        rng: &mut Rng,
    ) -> (Vec<f64>, Vec<f64>) {
        let mut c1 = a.to_vec();
        let mut c2 = b.to_vec();
        if rng.f64() < self.p_crossover {
            for i in 0..a.len() {
                if rng.f64() < 0.5 && (a[i] - b[i]).abs() > SBX_EPSILON {
                    let beta = sbx_beta(rng.f64(), self.eta_crossover);
                    c1[i] = 0.5 * ((1.0 + beta) * a[i] + (1.0 - beta) * b[i]);
                    c2[i] = 0.5 * ((1.0 - beta) * a[i] + (1.0 + beta) * b[i]);
                }
            }
        }
        bounds.clamp(&mut c1);
        bounds.clamp(&mut c2);
        (c1, c2)
    }

    /// Polynomial mutation in place.
    pub fn mutate(&self, genome: &mut [f64], bounds: &Bounds, rng: &mut Rng) {
        let pm = self
            .p_mutation
            .unwrap_or(1.0 / genome.len().max(1) as f64);
        for i in 0..genome.len() {
            if rng.f64() < pm {
                let (lo, hi) = (bounds.lo[i], bounds.hi[i]);
                let span = hi - lo;
                let u: f64 = rng.f64();
                let delta = if u < 0.5 {
                    (2.0 * u).powf(1.0 / (self.eta_mutation + 1.0)) - 1.0
                } else {
                    1.0 - (2.0 * (1.0 - u)).powf(1.0 / (self.eta_mutation + 1.0))
                };
                genome[i] += delta * span;
            }
        }
        bounds.clamp(genome);
    }

    /// Full offspring pipeline: crossover two parents, mutate, return one
    /// child (the second is discarded, matching OpenMOLE's steady flow).
    pub fn breed(
        &self,
        a: &[f64],
        b: &[f64],
        bounds: &Bounds,
        rng: &mut Rng,
    ) -> Vec<f64> {
        let (mut c1, c2) = self.sbx(a, b, bounds, rng);
        if rng.bool(0.5) {
            c1 = c2;
        }
        let mut child = c1;
        self.mutate(&mut child, bounds, rng);
        child
    }

    /// Allocation-free breed for the columnar engine: writes the child
    /// into `out` (len == genome dim) without materialising either SBX
    /// sibling. The which-child coin is drawn *first* so only the chosen
    /// one is ever computed; per-gene the SBX draws are identical for both
    /// children, so the child distribution matches [`Operators::breed`]
    /// (the draw order differs — this operator is fed per-chunk forked
    /// streams, never the historical main stream).
    pub fn breed_into(
        &self,
        a: &[f64],
        b: &[f64],
        bounds: &Bounds,
        rng: &mut Rng,
        out: &mut [f64],
    ) {
        debug_assert_eq!(a.len(), out.len());
        debug_assert_eq!(b.len(), out.len());
        let second = rng.bool(0.5);
        out.copy_from_slice(if second { b } else { a });
        if rng.f64() < self.p_crossover {
            for i in 0..out.len() {
                if rng.f64() < 0.5 && (a[i] - b[i]).abs() > SBX_EPSILON {
                    let beta = sbx_beta(rng.f64(), self.eta_crossover);
                    out[i] = if second {
                        0.5 * ((1.0 - beta) * a[i] + (1.0 + beta) * b[i])
                    } else {
                        0.5 * ((1.0 + beta) * a[i] + (1.0 - beta) * b[i])
                    };
                }
            }
        }
        bounds.clamp(out);
        self.mutate(out, bounds, rng);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::val_f64;

    fn bounds() -> Bounds {
        let x = val_f64("x");
        let y = val_f64("y");
        Bounds::new(&[(&x, 0.0, 10.0), (&y, -5.0, 5.0)]).unwrap()
    }

    #[test]
    fn sbx_children_in_bounds() {
        let b = bounds();
        let ops = Operators::default();
        let mut rng = Rng::new(1);
        for _ in 0..200 {
            let p1 = b.random(&mut rng);
            let p2 = b.random(&mut rng);
            let (c1, c2) = ops.sbx(&p1, &p2, &b, &mut rng);
            assert!(b.contains(&c1), "{c1:?}");
            assert!(b.contains(&c2), "{c2:?}");
        }
    }

    #[test]
    fn sbx_centred_on_parents() {
        // children's mean ≈ parents' mean (SBX property)
        let b = bounds();
        let ops = Operators {
            p_crossover: 1.0,
            ..Default::default()
        };
        let mut rng = Rng::new(2);
        let p1 = vec![3.0, 1.0];
        let p2 = vec![7.0, -1.0];
        let mut sum = 0.0;
        let n = 2000;
        for _ in 0..n {
            let (c1, c2) = ops.sbx(&p1, &p2, &b, &mut rng);
            sum += c1[0] + c2[0];
        }
        let mean = sum / (2.0 * n as f64);
        assert!((mean - 5.0).abs() < 0.15, "mean {mean}");
    }

    #[test]
    fn mutation_stays_in_bounds_and_perturbs() {
        let b = bounds();
        let ops = Operators {
            p_mutation: Some(1.0),
            ..Default::default()
        };
        let mut rng = Rng::new(3);
        let mut changed = 0;
        for _ in 0..100 {
            let mut g = b.random(&mut rng);
            let orig = g.clone();
            ops.mutate(&mut g, &b, &mut rng);
            assert!(b.contains(&g));
            if g != orig {
                changed += 1;
            }
        }
        assert!(changed > 90);
    }

    #[test]
    fn breed_produces_valid_child() {
        let b = bounds();
        let ops = Operators::default();
        let mut rng = Rng::new(4);
        let p1 = b.random(&mut rng);
        let p2 = b.random(&mut rng);
        let c = ops.breed(&p1, &p2, &b, &mut rng);
        assert_eq!(c.len(), 2);
        assert!(b.contains(&c));
    }

    #[test]
    fn breed_into_respects_bounds_and_varies() {
        let b = bounds();
        let ops = Operators::default();
        let mut rng = Rng::new(5);
        let mut child = vec![0.0; 2];
        let mut changed = 0;
        for _ in 0..200 {
            let p1 = b.random(&mut rng);
            let p2 = b.random(&mut rng);
            ops.breed_into(&p1, &p2, &b, &mut rng, &mut child);
            assert!(b.contains(&child), "{child:?}");
            if child != p1 && child != p2 {
                changed += 1;
            }
        }
        assert!(changed > 100, "breed_into barely varied: {changed}/200");
    }

    #[test]
    fn breed_into_mean_centred_like_breed() {
        // the zero-allocation operator must keep SBX's parent-centred
        // child distribution
        let b = bounds();
        let ops = Operators {
            p_crossover: 1.0,
            p_mutation: Some(0.0),
            ..Default::default()
        };
        let mut rng = Rng::new(6);
        let p1 = vec![3.0, 1.0];
        let p2 = vec![7.0, -1.0];
        let mut child = vec![0.0; 2];
        let n = 4000;
        let mut sum = 0.0;
        for _ in 0..n {
            ops.breed_into(&p1, &p2, &b, &mut rng, &mut child);
            sum += child[0];
        }
        let mean = sum / n as f64;
        assert!((mean - 5.0).abs() < 0.2, "mean {mean}");
    }
}
