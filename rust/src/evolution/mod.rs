//! Evolutionary model calibration (paper §4): NSGA-II with stochastic
//! re-evaluation, generational and steady-state drivers, and the island
//! model for grid-scale distribution.

pub mod evaluator;
pub mod generational;
pub mod genome;
pub mod island;
pub mod nsga2;
pub mod operators;
pub mod steady;

pub use evaluator::{
    AntSimEvaluator, CountingEvaluator, Evaluator, PooledEvaluator,
    ReplicatedEvaluator, SphereEvaluator, Zdt1Evaluator,
};
pub use nsga2::Fronts;
pub use generational::{eval_task, EvolutionResult, GenerationalGA, Nsga2Config};
pub use genome::{Bounds, Individual};
pub use island::{IslandConfig, IslandSteadyGA};
pub use operators::Operators;
pub use steady::{SteadyStateGA, Termination};
