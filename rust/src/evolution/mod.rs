//! Evolutionary model calibration (paper §4): NSGA-II with stochastic
//! re-evaluation, generational and steady-state drivers, and the island
//! model for grid-scale distribution.
//!
//! §Perf: populations live in the columnar [`PopMatrix`] (contiguous
//! row-major genome/objective matrices + a metadata strip); every engine
//! recycles a [`WaveArena`] so steady-state waves allocate nothing. The
//! AoS [`Individual`] remains the interchange type at the edges (results,
//! journal parsing, seeding) and [`reference`] retains the pre-columnar
//! algorithms as a test oracle.

pub mod evaluator;
pub mod generational;
pub mod genome;
pub mod island;
pub mod nsga2;
pub mod operators;
pub mod popmatrix;
pub mod reference;
pub mod steady;

pub use evaluator::{
    AntSimEvaluator, CountingEvaluator, Evaluator, PooledEvaluator,
    ReplicatedEvaluator, RowsView, SphereEvaluator, Zdt1Evaluator,
};
pub use generational::{eval_task, EvolutionResult, GenerationalGA, Nsga2Config};
pub use genome::{Bounds, Individual};
pub use island::{IslandConfig, IslandSteadyGA};
pub use nsga2::{Fronts, NsgaScratch};
pub use operators::Operators;
pub use popmatrix::{PopMatrix, WaveArena};
pub use steady::{SteadyStateGA, Termination};
