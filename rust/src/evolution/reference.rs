//! Verbatim AoS reference implementations of NSGA-II ranking, crowding and
//! environmental selection — the pre-columnar `Vec<Individual>` algorithms,
//! retained as a test oracle (same role `sim/reference.rs` plays for the
//! simulation kernel). The property tests in `tests/proptests.rs` pin the
//! columnar [`WaveArena`](crate::evolution::popmatrix::WaveArena) selection
//! to these on randomized populations, NaN objectives and duplicate-fitness
//! ties included.
//!
//! Deliberately naive and allocation-heavy: direct pairwise
//! [`Individual::dominates`] peeling (the textbook definition) and the
//! original stable-sort crowding. Never call from production paths.
//!
//! One caveat the oracle inherits from the historical code: crowding here
//! orders raw objective values, while the columnar kernels canonicalise
//! `-0.0 → +0.0` first. The two agree on every input that does not mix
//! `-0.0` and `+0.0` in one objective column; generators avoid that corner
//! (the columnar behaviour for it is pinned separately in `nsga2::tests`).

use crate::evolution::genome::Individual;

/// Pareto fronts by the direct definition: repeatedly peel the set of
/// individuals not dominated by any remaining individual.
pub fn pareto_fronts(pop: &[Individual]) -> Vec<Vec<usize>> {
    let n = pop.len();
    let mut remaining: Vec<usize> = (0..n).collect();
    let mut fronts = Vec::new();
    while !remaining.is_empty() {
        let mut front: Vec<usize> = remaining
            .iter()
            .copied()
            .filter(|&i| !remaining.iter().any(|&j| pop[j].dominates(&pop[i])))
            .collect();
        if front.is_empty() {
            // NaN dominance cycles can leave a remainder in which every
            // individual is dominated by another remaining one; park them
            // all in one final front (matching the columnar fallback)
            front = remaining.clone();
        }
        remaining.retain(|i| !front.contains(i));
        fronts.push(front);
    }
    fronts
}

/// Crowding distance of one front — the original stable-sort AoS
/// implementation (Deb 2002 §III-B).
pub fn crowding_distance(pop: &[Individual], front: &[usize]) -> Vec<f64> {
    let m = front.len();
    let mut dist = vec![0.0f64; m];
    if m == 0 {
        return dist;
    }
    if m <= 2 {
        return vec![f64::INFINITY; m];
    }
    let n_obj = pop[front[0]].objectives.len();
    let mut order: Vec<usize> = Vec::with_capacity(m);
    for obj in 0..n_obj {
        order.clear();
        order.extend(0..m);
        order.sort_by(|&a, &b| {
            pop[front[a]].objectives[obj].total_cmp(&pop[front[b]].objectives[obj])
        });
        let lo = pop[front[order[0]]].objectives[obj];
        let hi = pop[front[order[m - 1]]].objectives[obj];
        dist[order[0]] = f64::INFINITY;
        dist[order[m - 1]] = f64::INFINITY;
        let range = hi - lo;
        if range.is_nan() || range <= 0.0 {
            continue;
        }
        for w in 1..m - 1 {
            let prev = pop[front[order[w - 1]]].objectives[obj];
            let next = pop[front[order[w + 1]]].objectives[obj];
            dist[order[w]] += (next - prev) / range;
        }
    }
    dist
}

/// Environmental selection — the original AoS elitist truncation: whole
/// fronts while they fit, then the overflowing front by crowding distance
/// (stable sort, descending).
pub fn select(pop: Vec<Individual>, mu: usize) -> Vec<Individual> {
    if pop.len() <= mu {
        return pop;
    }
    let fronts = pareto_fronts(&pop);
    let mut flags = vec![false; pop.len()];
    let mut kept = 0usize;
    for front in &fronts {
        if kept + front.len() <= mu {
            for &i in front {
                flags[i] = true;
            }
            kept += front.len();
            if kept == mu {
                break;
            }
        } else {
            let d = crowding_distance(&pop, front);
            let mut order: Vec<usize> = (0..front.len()).collect();
            order.sort_by(|&a, &b| d[b].total_cmp(&d[a]));
            for &w in order.iter().take(mu - kept) {
                flags[front[w]] = true;
            }
            break;
        }
    }
    pop.into_iter()
        .zip(flags)
        .filter_map(|(ind, keep)| keep.then_some(ind))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::evolution::nsga2;

    fn ind(objs: &[f64]) -> Individual {
        Individual::new(vec![], objs.to_vec())
    }

    #[test]
    fn oracle_agrees_with_production_kernels_on_basics() {
        let pop = vec![
            ind(&[1.0, 4.0]),
            ind(&[2.0, 2.0]),
            ind(&[4.0, 1.0]),
            ind(&[3.0, 4.0]),
            ind(&[4.0, 3.0]),
            ind(&[5.0, 5.0]),
        ];
        let want = pareto_fronts(&pop);
        let got = nsga2::fast_non_dominated_sort(&pop);
        assert_eq!(got.len(), want.len());
        for (k, f) in want.iter().enumerate() {
            let mut a = got.front(k).to_vec();
            a.sort_unstable();
            let mut b = f.clone();
            b.sort_unstable();
            assert_eq!(a, b, "front {k}");
        }
        for mu in 1..pop.len() {
            assert_eq!(
                select(pop.clone(), mu),
                nsga2::select(pop.clone(), mu),
                "mu = {mu}"
            );
        }
    }

    #[test]
    fn oracle_partitions_under_nan_cycles() {
        let pop = vec![
            ind(&[0.0, 5.0, f64::NAN]),
            ind(&[f64::NAN, 0.0, 5.0]),
            ind(&[5.0, f64::NAN, 0.0]),
        ];
        let fronts = pareto_fronts(&pop);
        let total: usize = fronts.iter().map(Vec::len).sum();
        assert_eq!(total, 3);
    }
}
