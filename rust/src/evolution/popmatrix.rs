//! Columnar population storage (§Perf tentpole): genomes and objectives
//! live in contiguous row-major `f64` matrices with a small per-row
//! metadata strip (evaluation counts), replacing the AoS
//! `Vec<Individual>` whose per-individual `Vec<f64>` allocations dominated
//! the 200k-individual wave of bench `p2_scale` (the `population_clone`
//! case was ~24% of `full_wave`). PaPaS (arXiv:1807.09632) makes the same
//! observation for parameter studies at scale: once scheduling is solved,
//! the framework's own per-task data handling becomes the bottleneck.
//!
//! [`PopMatrix`] is the storage; [`WaveArena`] owns every scratch buffer a
//! generational wave needs (NSGA-II kernels, per-wave seeds, variation RNG
//! forks, gather/return buffers) and is recycled wave after wave — in
//! steady state a full evaluate → rank → select → breed cycle allocates
//! **nothing** (pinned by the `wave_reuse` case of `cargo bench --bench
//! p2_scale`, which counts allocations with a counting global allocator).

use crate::error::{Error, Result};
use crate::evolution::genome::{Bounds, Individual};
use crate::evolution::nsga2::{self, NsgaScratch};
use crate::evolution::operators::Operators;
use crate::exec::ThreadPool;
use crate::util::Rng;

/// Offspring bred per variation chunk. Fixed (never derived from the
/// thread count) so the chunk → RNG-fork mapping, and therefore the whole
/// trajectory, is identical on any machine and with or without a pool.
pub const VARIATION_CHUNK: usize = 64;

/// A population as two row-major matrices plus a metadata strip.
///
/// Row `i` is one individual: `genome(i)` (dim columns), `objectives(i)`
/// (n_obj columns), `evals(i)` (the §4.5 re-evaluation count). All
/// mutation is in place; `clear`/`set_rows`/`retain_flags` never release
/// capacity, so a matrix cycled by an engine reaches a high-water mark and
/// stops allocating.
#[derive(Debug, Clone, PartialEq)]
pub struct PopMatrix {
    dim: usize,
    n_obj: usize,
    rows: usize,
    genomes: Vec<f64>,
    objectives: Vec<f64>,
    evals: Vec<u32>,
}

impl PopMatrix {
    pub fn new(dim: usize, n_obj: usize) -> Self {
        PopMatrix {
            dim,
            n_obj,
            rows: 0,
            genomes: Vec::new(),
            objectives: Vec::new(),
            evals: Vec::new(),
        }
    }

    pub fn with_capacity(dim: usize, n_obj: usize, rows: usize) -> Self {
        PopMatrix {
            dim,
            n_obj,
            rows: 0,
            genomes: Vec::with_capacity(rows * dim),
            objectives: Vec::with_capacity(rows * n_obj),
            evals: Vec::with_capacity(rows),
        }
    }

    /// Build from AoS individuals (journal resume, seeded starts).
    pub fn from_individuals(pop: &[Individual], dim: usize, n_obj: usize) -> Result<Self> {
        let mut m = PopMatrix::with_capacity(dim, n_obj, pop.len());
        for ind in pop {
            if ind.genome.len() != dim || ind.objectives.len() != n_obj {
                return Err(Error::Evolution(format!(
                    "individual shape ({}, {}) does not match matrix ({dim}, {n_obj})",
                    ind.genome.len(),
                    ind.objectives.len()
                )));
            }
            m.push_row(&ind.genome, &ind.objectives, ind.evaluations);
        }
        Ok(m)
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn n_obj(&self) -> usize {
        self.n_obj
    }

    pub fn len(&self) -> usize {
        self.rows
    }

    pub fn is_empty(&self) -> bool {
        self.rows == 0
    }

    /// Drop all rows, keeping capacity.
    pub fn clear(&mut self) {
        self.rows = 0;
        self.genomes.clear();
        self.objectives.clear();
        self.evals.clear();
    }

    /// Grow (zero-filled genomes/objectives, `evals = 1`) or shrink to
    /// exactly `rows` rows, reusing capacity. Growing stages rows whose
    /// genomes are about to be written by variation or initialisation.
    pub fn set_rows(&mut self, rows: usize) {
        self.rows = rows;
        self.genomes.resize(rows * self.dim, 0.0);
        self.objectives.resize(rows * self.n_obj, 0.0);
        self.evals.resize(rows, 1);
    }

    /// Append one evaluated row.
    pub fn push_row(&mut self, genome: &[f64], objectives: &[f64], evals: u32) {
        debug_assert_eq!(genome.len(), self.dim);
        debug_assert_eq!(objectives.len(), self.n_obj);
        self.genomes.extend_from_slice(genome);
        self.objectives.extend_from_slice(objectives);
        self.evals.push(evals);
        self.rows += 1;
    }

    /// Append a copy of `other`'s row `i`.
    pub fn push_row_from(&mut self, other: &PopMatrix, i: usize) {
        debug_assert_eq!(self.dim, other.dim);
        debug_assert_eq!(self.n_obj, other.n_obj);
        self.push_row(other.genome(i), other.objectives_row(i), other.evals(i));
    }

    pub fn genome(&self, i: usize) -> &[f64] {
        &self.genomes[i * self.dim..(i + 1) * self.dim]
    }

    pub fn genome_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.genomes[i * self.dim..(i + 1) * self.dim]
    }

    pub fn objectives_row(&self, i: usize) -> &[f64] {
        &self.objectives[i * self.n_obj..(i + 1) * self.n_obj]
    }

    pub fn objectives_row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.objectives[i * self.n_obj..(i + 1) * self.n_obj]
    }

    /// The whole genome matrix, row-major.
    pub fn genomes(&self) -> &[f64] {
        &self.genomes
    }

    /// The whole objectives matrix, row-major — what the flat NSGA-II
    /// kernels consume directly.
    pub fn objectives(&self) -> &[f64] {
        &self.objectives
    }

    /// Mutable objective rows `first_row..` — the preallocated output an
    /// evaluation wave writes into.
    pub fn objectives_tail_mut(&mut self, first_row: usize) -> &mut [f64] {
        &mut self.objectives[first_row * self.n_obj..]
    }

    pub fn evals(&self, i: usize) -> u32 {
        self.evals[i]
    }

    pub fn set_evals(&mut self, i: usize, evals: u32) {
        self.evals[i] = evals;
    }

    /// Genome rows split at `row`: `(rows 0..row, rows row..)`. Lets
    /// variation read parents while writing offspring in the same matrix.
    pub fn split_genomes_at_mut(&mut self, row: usize) -> (&[f64], &mut [f64]) {
        let (head, tail) = self.genomes.split_at_mut(row * self.dim);
        (&*head, tail)
    }

    /// Rows `first_row..` as `(genome rows, mutable objective rows)` —
    /// the shape an evaluation wave consumes: slice views in,
    /// preallocated objective rows out.
    pub fn rows_split_mut(&mut self, first_row: usize) -> (&[f64], &mut [f64]) {
        (
            &self.genomes[first_row * self.dim..],
            &mut self.objectives[first_row * self.n_obj..],
        )
    }

    /// Merge a re-evaluation into row `i`: running average of objectives
    /// (§4.5's defence against over-evaluated stochastic individuals) —
    /// the columnar twin of [`Individual::absorb_reevaluation`].
    pub fn absorb_reevaluation(&mut self, i: usize, fresh: &[f64]) {
        let n = f64::from(self.evals[i]);
        for (o, f) in self.objectives_row_mut(i).iter_mut().zip(fresh) {
            *o = (*o * n + f) / (n + 1.0);
        }
        self.evals[i] += 1;
    }

    /// Stable in-place compaction: keep exactly the rows whose flag is
    /// set, preserving order. `memmove` within the existing buffers —
    /// no allocation, no row clones.
    pub fn retain_flags(&mut self, flags: &[bool]) {
        debug_assert_eq!(flags.len(), self.rows);
        let mut w = 0usize;
        for (r, &keep) in flags.iter().enumerate() {
            if keep {
                if w != r {
                    self.genomes
                        .copy_within(r * self.dim..(r + 1) * self.dim, w * self.dim);
                    self.objectives.copy_within(
                        r * self.n_obj..(r + 1) * self.n_obj,
                        w * self.n_obj,
                    );
                    self.evals[w] = self.evals[r];
                }
                w += 1;
            }
        }
        self.rows = w;
        self.genomes.truncate(w * self.dim);
        self.objectives.truncate(w * self.n_obj);
        self.evals.truncate(w);
    }

    /// One row as an AoS individual (allocates — results/journal edges).
    pub fn individual(&self, i: usize) -> Individual {
        Individual {
            genome: self.genome(i).to_vec(),
            objectives: self.objectives_row(i).to_vec(),
            evaluations: self.evals(i),
        }
    }

    /// The whole population as AoS individuals (allocates — final
    /// results only, never inside the wave loop).
    pub fn to_individuals(&self) -> Vec<Individual> {
        (0..self.rows).map(|i| self.individual(i)).collect()
    }
}

/// Every reusable buffer one generational wave needs: the NSGA-II scratch
/// (fronts, ranks, crowding, survivor flags), per-wave evaluation seeds,
/// deterministic per-chunk variation RNG forks, and gather/return buffers
/// for re-evaluation waves. Engines keep one arena alive across all
/// generations — ping-pong with the population matrix means zero
/// steady-state allocation.
#[derive(Default)]
pub struct WaveArena {
    pub nsga: NsgaScratch,
    /// Per-genome model seeds of the current evaluation wave.
    pub seeds: Vec<u32>,
    /// One forked RNG per variation chunk (see [`VARIATION_CHUNK`]).
    pub rng_forks: Vec<Rng>,
    /// Gathered genome rows for a re-evaluation wave.
    pub genome_buf: Vec<f64>,
    /// Objective rows returned by a re-evaluation wave.
    pub obj_buf: Vec<f64>,
    /// Sampled row indices for a re-evaluation wave.
    pub idx_buf: Vec<usize>,
}

impl WaveArena {
    /// Rank + crowding of every row (tournament input), into `self.nsga`.
    pub fn rank_crowd(&mut self, matrix: &PopMatrix, pool: Option<&ThreadPool>) {
        self.nsga
            .rank_crowd_flat(matrix.objectives(), matrix.len(), matrix.n_obj(), pool);
    }

    /// Environmental selection in place: keep the best `mu` rows of
    /// `matrix` by (front rank, crowding distance), preserving row order —
    /// identical survivor set to [`nsga2::select`] by construction.
    pub fn select(&mut self, matrix: &mut PopMatrix, mu: usize, pool: Option<&ThreadPool>) {
        if matrix.len() <= mu {
            return;
        }
        self.nsga.select_flags_flat(
            matrix.objectives(),
            matrix.len(),
            matrix.n_obj(),
            mu,
            pool,
        );
        matrix.retain_flags(self.nsga.flags());
    }

    /// Breed offspring directly into `matrix` rows `n_parents..`: each
    /// [`VARIATION_CHUNK`]-row chunk gets its own RNG stream forked from
    /// `rng` (chunk boundaries are fixed, so results are machine- and
    /// pool-independent), picks parents by binary tournament on the
    /// rank/crowding computed by the last [`WaveArena::rank_crowd`], and
    /// writes SBX + polynomial-mutation children straight into the
    /// offspring genome rows. With a pool the chunks run in parallel.
    ///
    /// Caller contract: `matrix.set_rows(n_parents + lambda)` first, and
    /// `rank_crowd` was computed over the `n_parents` parent rows.
    pub fn breed_into(
        &mut self,
        matrix: &mut PopMatrix,
        n_parents: usize,
        ops: &Operators,
        bounds: &Bounds,
        rng: &mut Rng,
        pool: Option<&ThreadPool>,
    ) {
        let count = matrix.len() - n_parents;
        if count == 0 || n_parents == 0 {
            return;
        }
        let dim = matrix.dim();
        let n_chunks = count.div_ceil(VARIATION_CHUNK);
        self.rng_forks.clear();
        for _ in 0..n_chunks {
            self.rng_forks.push(rng.fork());
        }
        let rank = self.nsga.rank();
        let crowd = self.nsga.crowd();
        debug_assert!(rank.len() >= n_parents, "rank_crowd must cover the parents");
        let forks = &self.rng_forks;
        let (parents, offspring) = matrix.split_genomes_at_mut(n_parents);
        let breed_chunk = |k: usize, chunk: &mut [f64]| {
            // the fork is cloned, not consumed: chunk results depend only
            // on (chunk index, position), never on scheduling
            let mut rng = forks[k].clone();
            for child in chunk.chunks_exact_mut(dim) {
                let a = nsga2::tournament_idx(n_parents, rank, crowd, &mut rng);
                let b = nsga2::tournament_idx(n_parents, rank, crowd, &mut rng);
                ops.breed_into(
                    &parents[a * dim..(a + 1) * dim],
                    &parents[b * dim..(b + 1) * dim],
                    bounds,
                    &mut rng,
                    child,
                );
            }
        };
        match pool.filter(|p| p.threads() > 1 && count >= 2 * VARIATION_CHUNK) {
            Some(p) => p
                .scoped_chunks(offspring, VARIATION_CHUNK * dim, breed_chunk)
                .expect("variation must not panic"),
            None => {
                for k in 0..n_chunks {
                    let lo = k * VARIATION_CHUNK * dim;
                    let hi = ((k + 1) * VARIATION_CHUNK * dim).min(offspring.len());
                    breed_chunk(k, &mut offspring[lo..hi]);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::val_f64;

    fn bounds() -> Bounds {
        let x = val_f64("x");
        let y = val_f64("y");
        Bounds::new(&[(&x, 0.0, 1.0), (&y, 0.0, 1.0)]).unwrap()
    }

    fn sample_matrix() -> PopMatrix {
        let mut m = PopMatrix::new(2, 2);
        m.push_row(&[0.1, 0.2], &[1.0, 4.0], 1);
        m.push_row(&[0.3, 0.4], &[2.0, 2.0], 2);
        m.push_row(&[0.5, 0.6], &[4.0, 1.0], 1);
        m.push_row(&[0.7, 0.8], &[5.0, 5.0], 3);
        m
    }

    #[test]
    fn rows_round_trip_through_individuals() {
        let m = sample_matrix();
        let pop = m.to_individuals();
        assert_eq!(pop.len(), 4);
        assert_eq!(pop[1].genome, vec![0.3, 0.4]);
        assert_eq!(pop[1].objectives, vec![2.0, 2.0]);
        assert_eq!(pop[1].evaluations, 2);
        let back = PopMatrix::from_individuals(&pop, 2, 2).unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn from_individuals_rejects_shape_mismatch() {
        let pop = vec![Individual::new(vec![0.5], vec![1.0, 2.0])];
        assert!(PopMatrix::from_individuals(&pop, 2, 2).is_err());
        assert!(PopMatrix::from_individuals(&pop, 1, 1).is_err());
        assert!(PopMatrix::from_individuals(&pop, 1, 2).is_ok());
    }

    #[test]
    fn retain_flags_compacts_in_order_without_allocating() {
        let mut m = sample_matrix();
        let cap = (
            m.genomes.capacity(),
            m.objectives.capacity(),
            m.evals.capacity(),
        );
        m.retain_flags(&[true, false, true, false]);
        assert_eq!(m.len(), 2);
        assert_eq!(m.genome(0), &[0.1, 0.2]);
        assert_eq!(m.genome(1), &[0.5, 0.6]);
        assert_eq!(m.objectives_row(1), &[4.0, 1.0]);
        assert_eq!(m.evals(0), 1);
        assert_eq!(
            cap,
            (
                m.genomes.capacity(),
                m.objectives.capacity(),
                m.evals.capacity()
            ),
            "compaction must not reallocate"
        );
    }

    #[test]
    fn absorb_reevaluation_matches_individual_twin() {
        let mut m = sample_matrix();
        let mut ind = m.individual(1);
        m.absorb_reevaluation(1, &[4.0, 6.0]);
        ind.absorb_reevaluation(&[4.0, 6.0]);
        assert_eq!(m.individual(1), ind);
    }

    #[test]
    fn set_rows_grows_with_fresh_metadata_and_shrinks() {
        let mut m = sample_matrix();
        m.set_rows(6);
        assert_eq!(m.len(), 6);
        assert_eq!(m.genome(5), &[0.0, 0.0]);
        assert_eq!(m.evals(5), 1);
        m.set_rows(2);
        assert_eq!(m.len(), 2);
        assert_eq!(m.genome(1), &[0.3, 0.4]);
    }

    #[test]
    fn arena_select_matches_aos_select() {
        let m = sample_matrix();
        let mut arena = WaveArena::default();
        for mu in 1..=4 {
            let mut cm = m.clone();
            arena.select(&mut cm, mu, None);
            let want = nsga2::select(m.to_individuals(), mu);
            assert_eq!(cm.to_individuals(), want, "mu = {mu}");
        }
    }

    #[test]
    fn breed_into_is_deterministic_and_pool_independent() {
        let b = bounds();
        let ops = Operators::default();
        let pool = ThreadPool::new(4);
        let run = |pool: Option<&ThreadPool>| -> Vec<f64> {
            let mut m = PopMatrix::new(2, 2);
            let mut rng = Rng::new(99);
            for i in 0..8 {
                m.push_row(
                    &[f64::from(i) * 0.1, 1.0 - f64::from(i) * 0.1],
                    &[f64::from(i), 8.0 - f64::from(i)],
                    1,
                );
            }
            let mut arena = WaveArena::default();
            arena.rank_crowd(&m, None);
            m.set_rows(8 + 300); // several variation chunks
            arena.breed_into(&mut m, 8, &ops, &b, &mut rng, pool);
            m.genomes()[8 * 2..].to_vec()
        };
        let serial = run(None);
        let pooled = run(Some(&pool));
        assert_eq!(serial, pooled, "variation must not depend on the pool");
        assert_eq!(serial.len(), 300 * 2);
        // children respect bounds
        for child in serial.chunks_exact(2) {
            assert!(b.contains(child), "{child:?}");
        }
        // and are not all identical (variation actually varies)
        assert!(serial.chunks_exact(2).any(|c| c != &serial[0..2]));
    }

    #[test]
    fn objectives_tail_mut_is_the_offspring_out_buffer() {
        let mut m = sample_matrix();
        m.set_rows(6);
        let tail = m.objectives_tail_mut(4);
        assert_eq!(tail.len(), 2 * 2);
        tail.copy_from_slice(&[9.0, 8.0, 7.0, 6.0]);
        assert_eq!(m.objectives_row(4), &[9.0, 8.0]);
        assert_eq!(m.objectives_row(5), &[7.0, 6.0]);
        assert_eq!(m.objectives_row(3), &[5.0, 5.0], "parents untouched");
    }
}
