//! Genomes and bounds for the real-coded GA (paper §4.5: each input is a
//! `Val` with variation bounds, e.g. `gDiffusionRate -> (0.0, 99.0)`).

use crate::core::Val;
use crate::error::{Error, Result};
use crate::util::Rng;

/// Box constraints of the search space, with the variable names they bind
/// (used to build evaluation contexts and result files).
#[derive(Debug, Clone)]
pub struct Bounds {
    pub names: Vec<String>,
    pub lo: Vec<f64>,
    pub hi: Vec<f64>,
}

impl Bounds {
    /// `inputs = Seq(gDiffusionRate -> (0.0, 99.0), ...)`.
    pub fn new(inputs: &[(&Val<f64>, f64, f64)]) -> Result<Self> {
        if inputs.is_empty() {
            return Err(Error::Evolution("empty genome bounds".into()));
        }
        for (v, lo, hi) in inputs {
            if !(lo < hi) {
                return Err(Error::Evolution(format!(
                    "bad bounds for {}: ({lo}, {hi})",
                    v.name()
                )));
            }
        }
        Ok(Bounds {
            names: inputs.iter().map(|(v, _, _)| v.name().to_string()).collect(),
            lo: inputs.iter().map(|(_, lo, _)| *lo).collect(),
            hi: inputs.iter().map(|(_, _, hi)| *hi).collect(),
        })
    }

    pub fn dim(&self) -> usize {
        self.lo.len()
    }

    /// Uniform random genome inside the box.
    pub fn random(&self, rng: &mut Rng) -> Vec<f64> {
        (0..self.dim())
            .map(|i| rng.range(self.lo[i], self.hi[i]))
            .collect()
    }

    /// Uniform random genome written into a preallocated row (identical
    /// draw order to [`Bounds::random`] — the columnar init path).
    pub fn random_into(&self, rng: &mut Rng, out: &mut [f64]) {
        debug_assert_eq!(out.len(), self.dim());
        for (i, g) in out.iter_mut().enumerate() {
            *g = rng.range(self.lo[i], self.hi[i]);
        }
    }

    /// Clamp a genome into the box.
    pub fn clamp(&self, genome: &mut [f64]) {
        for (i, g) in genome.iter_mut().enumerate() {
            *g = g.clamp(self.lo[i], self.hi[i]);
        }
    }

    pub fn contains(&self, genome: &[f64]) -> bool {
        genome.len() == self.dim()
            && genome
                .iter()
                .enumerate()
                .all(|(i, g)| (self.lo[i]..=self.hi[i]).contains(g))
    }
}

/// An evaluated individual.
#[derive(Debug, Clone, PartialEq)]
pub struct Individual {
    pub genome: Vec<f64>,
    /// Minimised objective values.
    pub objectives: Vec<f64>,
    /// How many times this individual was (re-)evaluated — the paper's
    /// `reevaluate = 0.01` machinery tracks this to kill lucky evaluations.
    pub evaluations: u32,
}

impl Individual {
    pub fn new(genome: Vec<f64>, objectives: Vec<f64>) -> Self {
        Individual {
            genome,
            objectives,
            evaluations: 1,
        }
    }

    /// Pareto dominance (all ≤, at least one <) for minimisation.
    pub fn dominates(&self, other: &Individual) -> bool {
        let mut strictly = false;
        for (a, b) in self.objectives.iter().zip(&other.objectives) {
            if a > b {
                return false;
            }
            if a < b {
                strictly = true;
            }
        }
        strictly
    }

    /// Merge a re-evaluation: running average of objectives (§4.5's
    /// defence against over-evaluated stochastic individuals).
    pub fn absorb_reevaluation(&mut self, fresh: &[f64]) {
        let n = f64::from(self.evaluations);
        for (o, f) in self.objectives.iter_mut().zip(fresh) {
            *o = (*o * n + f) / (n + 1.0);
        }
        self.evaluations += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::val_f64;

    fn bounds() -> Bounds {
        let d = val_f64("d");
        let e = val_f64("e");
        Bounds::new(&[(&d, 0.0, 99.0), (&e, 0.0, 99.0)]).unwrap()
    }

    #[test]
    fn random_genomes_inside_box() {
        let b = bounds();
        let mut rng = Rng::new(1);
        for _ in 0..100 {
            assert!(b.contains(&b.random(&mut rng)));
        }
    }

    #[test]
    fn clamp_pulls_back() {
        let b = bounds();
        let mut g = vec![-5.0, 120.0];
        b.clamp(&mut g);
        assert_eq!(g, vec![0.0, 99.0]);
    }

    #[test]
    fn rejects_bad_bounds() {
        let d = val_f64("d");
        assert!(Bounds::new(&[(&d, 5.0, 5.0)]).is_err());
        assert!(Bounds::new(&[]).is_err());
    }

    #[test]
    fn dominance() {
        let a = Individual::new(vec![], vec![1.0, 2.0]);
        let b = Individual::new(vec![], vec![2.0, 3.0]);
        let c = Individual::new(vec![], vec![0.5, 4.0]);
        assert!(a.dominates(&b));
        assert!(!b.dominates(&a));
        assert!(!a.dominates(&c) && !c.dominates(&a)); // incomparable
        assert!(!a.dominates(&a));
    }

    #[test]
    fn reevaluation_averages() {
        let mut a = Individual::new(vec![], vec![10.0]);
        a.absorb_reevaluation(&[20.0]);
        assert_eq!(a.objectives, vec![15.0]);
        assert_eq!(a.evaluations, 2);
        a.absorb_reevaluation(&[15.0]);
        assert_eq!(a.objectives, vec![15.0]);
    }
}
