//! NSGA-II (Deb et al. 2002) — the multi-objective engine of paper §4.5:
//! fast non-dominated sort, crowding distance, environmental selection and
//! binary tournament.
//!
//! §Perf tentpole (columnar engine): every kernel runs on a **flat
//! objectives matrix** (`n` rows × `m` columns, row-major `&[f64]`) through
//! a reusable [`NsgaScratch`] — no per-call buffer growth in steady state,
//! so ranking + selecting a 200k-individual wave (bench `p2_scale`)
//! allocates nothing after the first wave. The ubiquitous two-objective
//! case takes an O(N·logN) sweep (Jensen 2003-style staircase binary
//! search); the >2-objective dominance passes can fan out over an
//! [`exec::ThreadPool`](crate::exec::ThreadPool). All float orderings use
//! `f64::total_cmp`: a NaN objective ranks worst instead of panicking.
//!
//! The historical `Vec<Individual>` entry points remain as thin wrappers
//! over the flat kernels, so the AoS and columnar paths cannot diverge.
//! (An *independent* AoS oracle for property tests lives in
//! [`crate::evolution::reference`].)

use crate::evolution::genome::Individual;
use crate::exec::ThreadPool;
use crate::util::Rng;

/// Below this population size a pool fan-out costs more than it saves.
const PARALLEL_MIN_N: usize = 512;

/// Pareto fronts in CSR layout: `order` lists population indices front by
/// front, `starts[k]..starts[k + 1]` delimits front `k`. Replaces the old
/// `Vec<Vec<usize>>` (one heap allocation per front, reallocation churn
/// while peeling) with two flat buffers.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Fronts {
    order: Vec<usize>,
    /// Front boundaries; always `starts[0] == 0` and
    /// `starts.last() == order.len()`.
    starts: Vec<usize>,
}

impl Default for Fronts {
    fn default() -> Self {
        Fronts {
            order: Vec::new(),
            starts: vec![0],
        }
    }
}

impl Fronts {
    /// Number of fronts.
    pub fn len(&self) -> usize {
        self.starts.len() - 1
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The population indices of front `k` (0 = non-dominated).
    pub fn front(&self, k: usize) -> &[usize] {
        &self.order[self.starts[k]..self.starts[k + 1]]
    }

    /// Front 0, if the population was non-empty.
    pub fn first(&self) -> Option<&[usize]> {
        if self.is_empty() {
            None
        } else {
            Some(self.front(0))
        }
    }

    /// Iterate fronts in rank order.
    pub fn iter(&self) -> impl Iterator<Item = &[usize]> {
        (0..self.len()).map(move |k| self.front(k))
    }

    /// All indices, front-major (the flat `order` buffer).
    pub fn indices(&self) -> &[usize] {
        &self.order
    }
}

impl std::ops::Index<usize> for Fronts {
    type Output = [usize];

    fn index(&self, k: usize) -> &[usize] {
        self.front(k)
    }
}

/// Pairwise Pareto dominance on two objective rows (minimisation):
/// `(a_dominates_b, b_dominates_a)`. NaN comparisons are false on both
/// sides, matching [`Individual::dominates`].
#[inline]
fn pair_dominance(a: &[f64], b: &[f64]) -> (bool, bool) {
    let mut a_not_worse = true;
    let mut b_not_worse = true;
    let mut a_better_somewhere = false;
    let mut b_better_somewhere = false;
    for (x, y) in a.iter().zip(b) {
        if x < y {
            a_better_somewhere = true;
            b_not_worse = false;
        } else if y < x {
            b_better_somewhere = true;
            a_not_worse = false;
        }
    }
    (
        a_not_worse && a_better_somewhere,
        b_not_worse && b_better_somewhere,
    )
}

/// Crowding distances of one front (Deb 2002 §III-B) on the flat matrix:
/// `obj` holds the **canonicalised** full population rows, `front` the
/// member indices, `dist` (len == front.len()) receives the distances.
/// `order` is a caller-provided index scratch. NaN-safe: orderings use
/// `total_cmp`; a NaN-poisoned objective range contributes nothing.
fn crowding_front_into(
    obj: &[f64],
    m: usize,
    front: &[usize],
    dist: &mut [f64],
    order: &mut Vec<usize>,
) {
    let k = front.len();
    debug_assert_eq!(dist.len(), k);
    if k == 0 {
        return;
    }
    if k <= 2 {
        dist.fill(f64::INFINITY);
        return;
    }
    dist.fill(0.0);
    for o in 0..m {
        order.clear();
        order.extend(0..k);
        // unstable sort with the index as final tiebreak == the stable
        // sort of 0..k the AoS implementation used, without its merge
        // buffer allocation
        order.sort_unstable_by(|&a, &b| {
            obj[front[a] * m + o]
                .total_cmp(&obj[front[b] * m + o])
                .then(a.cmp(&b))
        });
        let val = |w: usize| obj[front[order[w]] * m + o];
        let lo = val(0);
        let hi = val(k - 1);
        dist[order[0]] = f64::INFINITY;
        dist[order[k - 1]] = f64::INFINITY;
        let range = hi - lo;
        if range.is_nan() || range <= 0.0 {
            // zero range, or a NaN objective poisoned the bounds: no
            // discriminating information along this objective
            continue;
        }
        for w in 1..k - 1 {
            dist[order[w]] += (val(w + 1) - val(w - 1)) / range;
        }
    }
}

/// Reusable state for the flat NSGA-II kernels. One of these lives in a
/// [`WaveArena`](crate::evolution::popmatrix::WaveArena) and is recycled
/// wave after wave: every buffer is `clear()`ed, never dropped, so steady
/// state allocates nothing.
#[derive(Default)]
pub struct NsgaScratch {
    /// Canonicalised copy of the caller's objective rows (`-0.0 → +0.0`,
    /// so `total_cmp`-based orderings agree with numeric dominance).
    canon: Vec<f64>,
    /// Interleaved per-row counters: `counts[2i]` = how many rows dominate
    /// `i` (consumed by the peel), `counts[2i + 1]` = how many rows `i`
    /// dominates (adjacency row lengths).
    counts: Vec<usize>,
    offsets: Vec<usize>,
    adjacency: Vec<usize>,
    bounds_buf: Vec<usize>,
    /// Two-objective sweep buffers.
    sorted: Vec<usize>,
    tails: Vec<(f64, f64)>,
    rank_buf: Vec<usize>,
    cursor: Vec<usize>,
    /// Crowding / selection buffers.
    order: Vec<usize>,
    front_dist: Vec<f64>,
    sel_order: Vec<usize>,
    /// Outputs of the last `sort_flat` / `rank_crowd_flat` /
    /// `select_flags_flat` call.
    fronts: Fronts,
    rank: Vec<usize>,
    crowd: Vec<f64>,
    flags: Vec<bool>,
}

impl NsgaScratch {
    /// Fronts computed by the last `sort_flat`-family call.
    pub fn fronts(&self) -> &Fronts {
        &self.fronts
    }

    /// Per-individual front index from the last `rank_crowd_flat`.
    pub fn rank(&self) -> &[usize] {
        &self.rank
    }

    /// Per-individual crowding distance from the last `rank_crowd_flat`.
    pub fn crowd(&self) -> &[f64] {
        &self.crowd
    }

    /// Per-individual survivor flags from the last `select_flags_flat`.
    pub fn flags(&self) -> &[bool] {
        &self.flags
    }

    /// Fast non-dominated sort of `n` rows × `m` objectives into
    /// `self.fronts()`. Two objectives (and no NaN) take the O(N·logN)
    /// staircase sweep; anything else the flat-CSR variant of Deb's
    /// O(M·N²) algorithm, whose dominance passes fan out over `pool`
    /// when one is given and the population is large enough.
    pub fn sort_flat(&mut self, obj: &[f64], n: usize, m: usize, pool: Option<&ThreadPool>) {
        self.fronts.order.clear();
        self.fronts.starts.clear();
        self.fronts.starts.push(0);
        if n == 0 {
            self.canon.clear();
            return;
        }
        debug_assert_eq!(obj.len(), n * m, "objectives matrix shape");
        // `+ 0.0` canonicalises -0.0 to +0.0 (and nothing else): dominance
        // treats the two zeros as equal, but the orderings below use
        // `total_cmp`, which ranks -0.0 < +0.0 and would break the
        // staircase invariant (a later point dominating an earlier tail)
        self.canon.clear();
        self.canon.extend(obj.iter().map(|v| v + 0.0));
        let has_nan = self.canon.iter().any(|v| v.is_nan());
        let canon = std::mem::take(&mut self.canon);
        if m == 2 && !has_nan {
            self.sort_two_objective(&canon, n);
        } else {
            self.sort_general(&canon, n, m.max(1), pool);
        }
        self.canon = canon;
    }

    /// Two-objective O(N·logN) sweep: process points in (f1, f2) order and
    /// binary-search the staircase of front tails. A point is dominated by
    /// front `k` iff it is dominated by the front's most recently assigned
    /// point (the one with minimal f2), and domination by front `k`
    /// implies domination by front `k - 1` (transitivity), so the first
    /// non-dominating front is found by binary search.
    fn sort_two_objective(&mut self, obj: &[f64], n: usize) {
        let sorted = &mut self.sorted;
        sorted.clear();
        sorted.extend(0..n);
        sorted.sort_unstable_by(|&a, &b| {
            obj[2 * a]
                .total_cmp(&obj[2 * b])
                .then(obj[2 * a + 1].total_cmp(&obj[2 * b + 1]))
                .then(a.cmp(&b))
        });

        let rank = &mut self.rank_buf;
        rank.clear();
        rank.resize(n, 0);
        // (f2, f1) of the last point assigned to each front
        let tails = &mut self.tails;
        tails.clear();
        for &i in sorted.iter() {
            let (f1, f2) = (obj[2 * i], obj[2 * i + 1]);
            let dominated_by = |k: usize| {
                let (t2, t1) = tails[k];
                // the tail q has q.f1 <= f1 (sweep order); strictness must
                // hold in at least one objective
                t2 < f2 || (t2 == f2 && t1 < f1)
            };
            let (mut lo, mut hi) = (0usize, tails.len());
            while lo < hi {
                let mid = (lo + hi) / 2;
                if dominated_by(mid) {
                    lo = mid + 1;
                } else {
                    hi = mid;
                }
            }
            rank[i] = lo;
            if lo == tails.len() {
                tails.push((f2, f1));
            } else {
                tails[lo] = (f2, f1);
            }
        }

        // bucket ranks into CSR, index-ascending within each front
        let n_fronts = tails.len();
        let starts = &mut self.fronts.starts;
        starts.clear();
        starts.resize(n_fronts + 1, 0);
        for &r in rank.iter() {
            starts[r + 1] += 1;
        }
        for k in 0..n_fronts {
            starts[k + 1] += starts[k];
        }
        let cursor = &mut self.cursor;
        cursor.clear();
        cursor.extend_from_slice(starts);
        let order = &mut self.fronts.order;
        order.clear();
        order.resize(n, 0);
        for (i, &r) in rank.iter().enumerate() {
            order[cursor[r]] = i;
            cursor[r] += 1;
        }
    }

    /// Deb's algorithm on flat buffers: two O(N²) dominance passes build a
    /// CSR "dominates" adjacency, then fronts are peeled by layered BFS
    /// directly into the output buffer. Each pass computes whole rows
    /// independently, so with a pool the rows fan out over the workers
    /// (disjoint count / adjacency slices — no synchronisation).
    fn sort_general(&mut self, obj: &[f64], n: usize, m: usize, pool: Option<&ThreadPool>) {
        let row = |i: usize| &obj[i * m..(i + 1) * m];
        let pool = pool.filter(|p| p.threads() > 1 && n >= PARALLEL_MIN_N);
        let rows_per_chunk = match pool {
            Some(p) => n.div_ceil(p.threads() * 4).max(32),
            None => n,
        };

        // pass 1: per-row domination counts. The parallel version computes
        // whole rows independently (disjoint count slices, ~2× the pair
        // checks, amortised across workers); the serial version keeps the
        // classic triangular pass that visits each unordered pair once.
        let counts = &mut self.counts;
        counts.clear();
        counts.resize(2 * n, 0);
        match pool {
            Some(p) => {
                let fill_counts = |first_row: usize, chunk: &mut [usize]| {
                    for (r, pair) in chunk.chunks_exact_mut(2).enumerate() {
                        let i = first_row + r;
                        let (mut dominated_by, mut dominates) = (0usize, 0usize);
                        for j in 0..n {
                            if j == i {
                                continue;
                            }
                            let (i_dom, j_dom) = pair_dominance(row(i), row(j));
                            if i_dom {
                                dominates += 1;
                            } else if j_dom {
                                dominated_by += 1;
                            }
                        }
                        pair[0] = dominated_by;
                        pair[1] = dominates;
                    }
                };
                p.scoped_chunks(counts, rows_per_chunk * 2, |k, chunk| {
                    fill_counts(k * rows_per_chunk, chunk)
                })
                .expect("dominance pass must not panic");
            }
            None => {
                for i in 0..n {
                    for j in (i + 1)..n {
                        let (i_dom, j_dom) = pair_dominance(row(i), row(j));
                        if i_dom {
                            counts[2 * i + 1] += 1;
                            counts[2 * j] += 1;
                        } else if j_dom {
                            counts[2 * j + 1] += 1;
                            counts[2 * i] += 1;
                        }
                    }
                }
            }
        }

        // CSR offsets, then pass 2 fills the adjacency rows in place
        let offsets = &mut self.offsets;
        offsets.clear();
        offsets.resize(n + 1, 0);
        for i in 0..n {
            offsets[i + 1] = offsets[i] + self.counts[2 * i + 1];
        }
        let adjacency = &mut self.adjacency;
        adjacency.clear();
        adjacency.resize(self.offsets[n], 0);
        let offsets = &self.offsets;
        match pool {
            Some(p) => {
                // per-row fill: row i's adjacency slice is disjoint, so
                // row blocks fan out over the workers
                let fill_adjacency =
                    |first_row: usize, last_row: usize, chunk: &mut [usize]| {
                        let base = offsets[first_row];
                        for i in first_row..last_row {
                            let mut c = offsets[i] - base;
                            for j in 0..n {
                                if j == i {
                                    continue;
                                }
                                let (i_dom, _) = pair_dominance(row(i), row(j));
                                if i_dom {
                                    chunk[c] = j;
                                    c += 1;
                                }
                            }
                        }
                    };
                let bounds = &mut self.bounds_buf;
                bounds.clear();
                let mut r = 0;
                while r < n {
                    bounds.push(offsets[r]);
                    r += rows_per_chunk;
                }
                bounds.push(offsets[n]);
                p.scoped_parts(adjacency, bounds, |k, chunk| {
                    let first = k * rows_per_chunk;
                    fill_adjacency(first, (first + rows_per_chunk).min(n), chunk)
                })
                .expect("adjacency pass must not panic");
            }
            None => {
                // triangular fill, one visit per unordered pair; per-row
                // write cursors land entries in exactly the same ascending
                // order the per-row scan produces
                let cursor = &mut self.cursor;
                cursor.clear();
                cursor.extend_from_slice(&offsets[..n]);
                for i in 0..n {
                    for j in (i + 1)..n {
                        let (i_dom, j_dom) = pair_dominance(row(i), row(j));
                        if i_dom {
                            adjacency[cursor[i]] = j;
                            cursor[i] += 1;
                        } else if j_dom {
                            adjacency[cursor[j]] = i;
                            cursor[j] += 1;
                        }
                    }
                }
            }
        }

        // peel fronts: the output buffer doubles as the BFS queue
        let counts = &mut self.counts;
        let adjacency = &self.adjacency;
        let order = &mut self.fronts.order;
        let starts = &mut self.fronts.starts;
        order.extend((0..n).filter(|&i| counts[2 * i] == 0));
        let mut begin = 0;
        while begin < order.len() {
            let end = order.len();
            starts.push(end);
            for idx in begin..end {
                let i = order[idx];
                for &j in &adjacency[offsets[i]..offsets[i + 1]] {
                    counts[2 * j] -= 1;
                    if counts[2 * j] == 0 {
                        order.push(j);
                    }
                }
            }
            begin = end;
        }
        if order.len() < n {
            // NaN-induced dominance "cycles" (a beats b beats c beats a,
            // each through a different non-NaN objective) can strand
            // individuals with counts that never reach zero. Park them in
            // one final front so fronts always partition the population.
            order.extend((0..n).filter(|&i| counts[2 * i] > 0));
            starts.push(order.len());
        }
        // normalise every front to ascending population index: the peel
        // lists members in BFS-traversal order, which would make crowding
        // tie-breaks on duplicate fitness depend on adjacency order. The
        // sweep path is index-ascending by construction; match it (and
        // the AoS reference oracle) here.
        for k in 0..self.fronts.len() {
            let (lo, hi) = (self.fronts.starts[k], self.fronts.starts[k + 1]);
            self.fronts.order[lo..hi].sort_unstable();
        }
    }

    /// Fronts + per-individual (rank, crowding distance) — what binary
    /// tournament consumes. With a pool, per-front crowding fans out
    /// (fronts are disjoint slices of the front-major distance buffer).
    pub fn rank_crowd_flat(&mut self, obj: &[f64], n: usize, m: usize, pool: Option<&ThreadPool>) {
        self.sort_flat(obj, n, m, pool);
        self.rank.clear();
        self.rank.resize(n, 0);
        self.crowd.clear();
        self.crowd.resize(n, 0.0);
        self.front_dist.clear();
        self.front_dist.resize(self.fronts.order.len(), 0.0);
        let parallel = pool
            .filter(|p| p.threads() > 1 && n >= PARALLEL_MIN_N && self.fronts.len() > 1);
        match parallel {
            Some(p) => {
                let fronts = &self.fronts;
                let canon = &self.canon;
                p.scoped_parts(&mut self.front_dist, &fronts.starts, |k, dist| {
                    // a small per-front index scratch: only the parallel
                    // path pays this allocation, the serial path reuses
                    // `self.order`
                    let mut order = Vec::new();
                    crowding_front_into(canon, m, fronts.front(k), dist, &mut order);
                })
                .expect("crowding pass must not panic");
            }
            None => {
                for k in 0..self.fronts.len() {
                    let (lo, hi) = (self.fronts.starts[k], self.fronts.starts[k + 1]);
                    crowding_front_into(
                        &self.canon,
                        m,
                        self.fronts.front(k),
                        &mut self.front_dist[lo..hi],
                        &mut self.order,
                    );
                }
            }
        }
        for k in 0..self.fronts.len() {
            let lo = self.fronts.starts[k];
            for (w, &i) in self.fronts.front(k).iter().enumerate() {
                self.rank[i] = k;
                self.crowd[i] = self.front_dist[lo + w];
            }
        }
    }

    /// Environmental selection on the flat matrix: compute survivor flags
    /// for the best `mu` of `n` rows by (front rank, crowding distance) —
    /// the elitist step of NSGA-II. Returns the flags slice
    /// (`flags[i] == true` ⇔ row `i` survives).
    pub fn select_flags_flat(
        &mut self,
        obj: &[f64],
        n: usize,
        m: usize,
        mu: usize,
        pool: Option<&ThreadPool>,
    ) -> &[bool] {
        self.flags.clear();
        self.flags.resize(n, false);
        if n <= mu {
            self.flags.fill(true);
            return &self.flags;
        }
        self.sort_flat(obj, n, m, pool);
        let mut kept = 0usize;
        for k in 0..self.fronts.len() {
            let front = self.fronts.front(k);
            if kept + front.len() <= mu {
                for &i in front {
                    self.flags[i] = true;
                }
                kept += front.len();
                if kept == mu {
                    break;
                }
            } else {
                // the overflowing front: truncate by crowding, most
                // isolated first, stable on the front-local index
                self.front_dist.clear();
                self.front_dist.resize(front.len(), 0.0);
                crowding_front_into(
                    &self.canon,
                    m,
                    front,
                    &mut self.front_dist,
                    &mut self.order,
                );
                let sel = &mut self.sel_order;
                sel.clear();
                sel.extend(0..front.len());
                let dist = &self.front_dist;
                sel.sort_unstable_by(|&a, &b| dist[b].total_cmp(&dist[a]).then(a.cmp(&b)));
                for &w in sel.iter().take(mu - kept) {
                    self.flags[front[w]] = true;
                }
                break;
            }
        }
        &self.flags
    }
}

// --------------------------------------------------------------- wrappers
// Historical `Vec<Individual>` entry points, delegating to the flat
// kernels above (one implementation, two views).

/// Flatten a population's objectives into a row-major matrix.
fn flatten(pop: &[Individual]) -> (Vec<f64>, usize) {
    let m = pop.first().map_or(0, |i| i.objectives.len());
    let mut obj = Vec::with_capacity(pop.len() * m);
    for ind in pop {
        debug_assert_eq!(
            ind.objectives.len(),
            m,
            "heterogeneous objective counts in one population"
        );
        obj.extend_from_slice(&ind.objectives);
    }
    (obj, m)
}

/// Fast non-dominated sort: partition indices into Pareto fronts
/// (front 0 = non-dominated).
pub fn fast_non_dominated_sort(pop: &[Individual]) -> Fronts {
    let (obj, m) = flatten(pop);
    let mut scratch = NsgaScratch::default();
    scratch.sort_flat(&obj, pop.len(), m, None);
    scratch.fronts
}

/// Crowding distance of each member of one front (Deb 2002 §III-B).
/// NaN-safe: objective orderings use `total_cmp`.
pub fn crowding_distance(pop: &[Individual], front: &[usize]) -> Vec<f64> {
    let k = front.len();
    let mut dist = vec![0.0f64; k];
    if k == 0 {
        return dist;
    }
    let m = pop[front[0]].objectives.len();
    // front-local canonicalised matrix (the flat kernel indexes rows by
    // the `front` slice, so hand it rows 0..k and the identity front)
    let mut obj = Vec::with_capacity(k * m);
    for &i in front {
        obj.extend(pop[i].objectives.iter().map(|v| v + 0.0));
    }
    let identity: Vec<usize> = (0..k).collect();
    let mut order = Vec::new();
    crowding_front_into(&obj, m, &identity, &mut dist, &mut order);
    dist
}

/// Rank (front index) and crowding for every individual.
pub fn rank_and_crowding(pop: &[Individual]) -> (Vec<usize>, Vec<f64>) {
    let (obj, m) = flatten(pop);
    let mut scratch = NsgaScratch::default();
    scratch.rank_crowd_flat(&obj, pop.len(), m, None);
    (scratch.rank, scratch.crowd)
}

/// Environmental selection: keep the best `mu` individuals by
/// (front rank, crowding distance) — the elitist step of NSGA-II.
pub fn select(pop: Vec<Individual>, mu: usize) -> Vec<Individual> {
    if pop.len() <= mu {
        return pop;
    }
    let (obj, m) = flatten(&pop);
    let mut scratch = NsgaScratch::default();
    scratch.select_flags_flat(&obj, pop.len(), m, mu, None);
    pop.into_iter()
        .zip(&scratch.flags)
        .filter_map(|(ind, &keep)| keep.then_some(ind))
        .collect()
}

/// Binary tournament on (rank, crowding) over row indices — the columnar
/// parent-selection operator. Draws two uniform indices from `rng` exactly
/// like the historical AoS tournament.
pub fn tournament_idx(n: usize, rank: &[usize], crowd: &[f64], rng: &mut Rng) -> usize {
    let a = rng.usize(n);
    let b = rng.usize(n);
    if rank[a] < rank[b] {
        a
    } else if rank[b] < rank[a] {
        b
    } else if crowd[a] >= crowd[b] {
        a
    } else {
        b
    }
}

/// Binary tournament on (rank, crowding): the parent-selection operator.
pub fn tournament<'a>(
    pop: &'a [Individual],
    rank: &[usize],
    crowd: &[f64],
    rng: &mut Rng,
) -> &'a Individual {
    &pop[tournament_idx(pop.len(), rank, crowd, rng)]
}

/// The Pareto front (front 0) of a population.
pub fn pareto_front(pop: &[Individual]) -> Vec<Individual> {
    fast_non_dominated_sort(pop)
        .first()
        .map(|f| f.iter().map(|&i| pop[i].clone()).collect())
        .unwrap_or_default()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ind(objs: &[f64]) -> Individual {
        Individual::new(vec![], objs.to_vec())
    }

    /// Reference implementation: direct pairwise `dominates` checks.
    fn naive_fronts(pop: &[Individual]) -> Vec<Vec<usize>> {
        let n = pop.len();
        let mut remaining: Vec<usize> = (0..n).collect();
        let mut fronts = Vec::new();
        while !remaining.is_empty() {
            let front: Vec<usize> = remaining
                .iter()
                .copied()
                .filter(|&i| {
                    !remaining.iter().any(|&j| pop[j].dominates(&pop[i]))
                })
                .collect();
            remaining.retain(|i| !front.contains(i));
            fronts.push(front);
        }
        fronts
    }

    fn assert_fronts_match(pop: &[Individual]) {
        let got = fast_non_dominated_sort(pop);
        let want = naive_fronts(pop);
        assert_eq!(got.len(), want.len(), "front count");
        for (k, want_front) in want.iter().enumerate() {
            let mut got_front = got[k].to_vec();
            got_front.sort_unstable();
            let mut want_front = want_front.clone();
            want_front.sort_unstable();
            assert_eq!(got_front, want_front, "front {k}");
        }
    }

    #[test]
    fn sorts_into_fronts() {
        // front 0: (1,4), (2,2), (4,1); front 1: (3,4), (4,3); front 2: (5,5)
        let pop = vec![
            ind(&[1.0, 4.0]),
            ind(&[2.0, 2.0]),
            ind(&[4.0, 1.0]),
            ind(&[3.0, 4.0]),
            ind(&[4.0, 3.0]),
            ind(&[5.0, 5.0]),
        ];
        let fronts = fast_non_dominated_sort(&pop);
        assert_eq!(fronts.len(), 3);
        let mut f0 = fronts[0].to_vec();
        f0.sort_unstable();
        assert_eq!(f0, vec![0, 1, 2]);
        assert_eq!(fronts[2].to_vec(), vec![5]);
    }

    #[test]
    fn two_objective_sweep_matches_pairwise_reference() {
        // randomised cross-check of the O(N logN) path against the naive
        // definition, duplicates included
        let mut rng = Rng::new(0xF00D);
        for _case in 0..60 {
            let n = 1 + rng.usize(60);
            let mut pop: Vec<Individual> = (0..n)
                .map(|_| {
                    ind(&[
                        f64::from(rng.usize(8) as u32),
                        f64::from(rng.usize(8) as u32),
                    ])
                })
                .collect();
            // sprinkle exact duplicates
            if n > 2 {
                let dup = pop[0].objectives.clone();
                pop[n / 2].objectives = dup;
            }
            assert_fronts_match(&pop);
        }
    }

    #[test]
    fn three_objective_general_path_matches_reference() {
        let mut rng = Rng::new(0xBEEF);
        for _ in 0..40 {
            let n = 1 + rng.usize(40);
            let pop: Vec<Individual> = (0..n)
                .map(|_| {
                    ind(&[
                        f64::from(rng.usize(5) as u32),
                        f64::from(rng.usize(5) as u32),
                        f64::from(rng.usize(5) as u32),
                    ])
                })
                .collect();
            assert_fronts_match(&pop);
        }
    }

    #[test]
    fn parallel_general_sort_matches_serial() {
        // the pooled dominance passes must agree with the serial ones on
        // a population large enough to clear the PARALLEL_MIN_N gate
        let pool = ThreadPool::new(4);
        let mut rng = Rng::new(0x9A9A);
        let n = 700;
        let m = 3;
        let obj: Vec<f64> = (0..n * m)
            .map(|_| f64::from(rng.usize(6) as u32))
            .collect();
        let mut serial = NsgaScratch::default();
        serial.sort_flat(&obj, n, m, None);
        let mut parallel = NsgaScratch::default();
        parallel.sort_flat(&obj, n, m, Some(&pool));
        assert_eq!(serial.fronts(), parallel.fronts());
        // crowding too
        serial.rank_crowd_flat(&obj, n, m, None);
        parallel.rank_crowd_flat(&obj, n, m, Some(&pool));
        assert_eq!(serial.rank(), parallel.rank());
        assert_eq!(serial.crowd(), parallel.crowd());
    }

    #[test]
    fn scratch_reuse_is_stateless_between_calls() {
        // a big call followed by a small one must not leak stale state
        let mut scratch = NsgaScratch::default();
        let mut rng = Rng::new(31);
        let big: Vec<f64> = (0..64 * 3).map(|_| rng.f64()).collect();
        scratch.rank_crowd_flat(&big, 64, 3, None);
        let small = [1.0, 4.0, 2.0, 2.0, 4.0, 1.0, 5.0, 5.0];
        scratch.select_flags_flat(&small, 4, 2, 3, None);
        assert_eq!(scratch.flags(), &[true, true, true, false]);
        scratch.sort_flat(&small, 4, 2, None);
        assert_eq!(scratch.fronts().len(), 2);
        assert_eq!(scratch.fronts().front(1), &[3]);
    }

    #[test]
    fn crowding_prefers_extremes() {
        let pop = vec![
            ind(&[0.0, 4.0]),
            ind(&[1.0, 3.0]),
            ind(&[2.0, 2.0]),
            ind(&[3.0, 1.0]),
            ind(&[4.0, 0.0]),
        ];
        let front: Vec<usize> = (0..5).collect();
        let d = crowding_distance(&pop, &front);
        assert!(d[0].is_infinite() && d[4].is_infinite());
        assert!(d[1] > 0.0 && d[2] > 0.0 && d[3] > 0.0);
        assert!(d[1].is_finite());
    }

    #[test]
    fn select_keeps_first_front_whole_when_it_fits() {
        let pop = vec![
            ind(&[1.0, 4.0]),
            ind(&[2.0, 2.0]),
            ind(&[4.0, 1.0]),
            ind(&[5.0, 5.0]),
            ind(&[6.0, 6.0]),
        ];
        let kept = select(pop, 3);
        assert_eq!(kept.len(), 3);
        // the three front-0 points survive
        let objs: Vec<&[f64]> = kept.iter().map(|i| i.objectives.as_slice()).collect();
        assert!(objs.contains(&[1.0, 4.0].as_slice()));
        assert!(objs.contains(&[2.0, 2.0].as_slice()));
        assert!(objs.contains(&[4.0, 1.0].as_slice()));
    }

    #[test]
    fn select_truncates_by_crowding() {
        // one big front of 5, keep 3: extremes must survive
        let pop = vec![
            ind(&[0.0, 4.0]),
            ind(&[1.0, 3.0]),
            ind(&[1.9, 2.1]), // most crowded middle point
            ind(&[3.0, 1.0]),
            ind(&[4.0, 0.0]),
        ];
        let kept = select(pop, 3);
        let objs: Vec<&[f64]> = kept.iter().map(|i| i.objectives.as_slice()).collect();
        assert!(objs.contains(&[0.0, 4.0].as_slice()));
        assert!(objs.contains(&[4.0, 0.0].as_slice()));
    }

    #[test]
    fn pareto_front_extraction() {
        let pop = vec![ind(&[1.0, 1.0]), ind(&[2.0, 2.0])];
        let front = pareto_front(&pop);
        assert_eq!(front.len(), 1);
        assert_eq!(front[0].objectives, vec![1.0, 1.0]);
    }

    #[test]
    fn tournament_prefers_lower_rank() {
        let pop = vec![ind(&[1.0, 1.0]), ind(&[5.0, 5.0])];
        let (rank, crowd) = rank_and_crowding(&pop);
        let mut rng = Rng::new(3);
        for _ in 0..20 {
            let w = tournament(&pop, &rank, &crowd, &mut rng);
            // winner is never strictly dominated by the loser
            assert!(!pop[1].dominates(w) || w.objectives == pop[1].objectives);
        }
    }

    #[test]
    fn identical_objectives_no_infinite_loop() {
        let pop = vec![ind(&[1.0, 1.0]); 6];
        let fronts = fast_non_dominated_sort(&pop);
        assert_eq!(fronts.len(), 1);
        assert_eq!(fronts[0].len(), 6);
        let kept = select(pop, 3);
        assert_eq!(kept.len(), 3);
    }

    #[test]
    fn empty_population_yields_no_fronts() {
        let fronts = fast_non_dominated_sort(&[]);
        assert!(fronts.is_empty());
        assert_eq!(fronts.len(), 0);
        assert!(pareto_front(&[]).is_empty());
    }

    #[test]
    fn nan_objectives_do_not_panic_and_rank_worst() {
        // regression: `partial_cmp(..).unwrap()` used to panic here
        let pop = vec![
            ind(&[f64::NAN, 1.0]),
            ind(&[0.5, 0.5]),
            ind(&[0.2, 0.9]),
            ind(&[0.9, f64::NAN]),
            ind(&[0.1, 1.1]),
        ];
        let fronts = fast_non_dominated_sort(&pop);
        let total: usize = fronts.iter().map(<[usize]>::len).sum();
        assert_eq!(total, pop.len(), "fronts must still partition");
        let (rank, crowd) = rank_and_crowding(&pop);
        assert_eq!(rank.len(), 5);
        assert_eq!(crowd.len(), 5);
        let kept = select(pop.clone(), 3);
        assert_eq!(kept.len(), 3, "selection must still truncate to mu");
        // a fully-NaN front member must not displace finite solutions from
        // a *better* front: the finite mutually-nondominated points stay
        let finite_kept = kept
            .iter()
            .filter(|i| i.objectives.iter().all(|v| v.is_finite()))
            .count();
        assert!(finite_kept >= 2, "kept {kept:?}");
    }

    #[test]
    fn nan_crowding_distance_never_panics_or_poisons() {
        let pop = vec![
            ind(&[0.0, 1.0]),
            ind(&[f64::NAN, 0.5]),
            ind(&[0.5, f64::NAN]),
            ind(&[1.0, 0.0]),
        ];
        let front: Vec<usize> = (0..4).collect();
        let d = crowding_distance(&pop, &front);
        assert_eq!(d.len(), 4);
        // a NaN range skips the objective rather than spreading NaN
        assert!(d.iter().all(|v| !v.is_nan()), "distances {d:?}");
    }

    #[test]
    fn negative_zero_objectives_rank_like_positive_zero() {
        // regression (review finding): total_cmp orders -0.0 < +0.0, so an
        // uncanonicalised sweep put the dominated (-0.0, 5.0) into front 0
        let pop = vec![ind(&[-0.0, 5.0]), ind(&[0.0, 1.0])];
        let fronts = fast_non_dominated_sort(&pop);
        assert_eq!(fronts.len(), 2, "(0.0, 1.0) dominates (-0.0, 5.0)");
        assert_eq!(fronts[0].to_vec(), vec![1]);
        assert_eq!(fronts[1].to_vec(), vec![0]);
        assert_fronts_match(&pop);
    }

    #[test]
    fn nan_dominance_cycle_still_partitions() {
        // x beats z, z beats y, y beats x — each through a different
        // non-NaN objective. No count ever reaches zero, so the peel
        // strands all three; the fallback front must catch them.
        let pop = vec![
            ind(&[0.0, 5.0, f64::NAN]),
            ind(&[f64::NAN, 0.0, 5.0]),
            ind(&[5.0, f64::NAN, 0.0]),
        ];
        let fronts = fast_non_dominated_sort(&pop);
        let total: usize = fronts.iter().map(<[usize]>::len).sum();
        assert_eq!(total, 3, "cycle members must not vanish");
        let kept = select(pop, 2);
        assert_eq!(kept.len(), 2);
    }

    #[test]
    fn large_two_objective_wave_ranks_quickly() {
        // smoke-scale version of bench p2_scale: 20k points through the
        // sweep path plus a select — finishes in well under a second
        let mut rng = Rng::new(7);
        let pop: Vec<Individual> = (0..20_000)
            .map(|_| ind(&[rng.f64(), rng.f64()]))
            .collect();
        let fronts = fast_non_dominated_sort(&pop);
        let total: usize = fronts.iter().map(<[usize]>::len).sum();
        assert_eq!(total, pop.len());
        let kept = select(pop, 200);
        assert_eq!(kept.len(), 200);
    }

    #[test]
    fn tournament_idx_matches_aos_tournament() {
        let pop = vec![
            ind(&[1.0, 1.0]),
            ind(&[2.0, 3.0]),
            ind(&[0.5, 4.0]),
            ind(&[5.0, 5.0]),
        ];
        let (rank, crowd) = rank_and_crowding(&pop);
        let mut rng_a = Rng::new(77);
        let mut rng_b = Rng::new(77);
        for _ in 0..50 {
            let w_idx = tournament_idx(pop.len(), &rank, &crowd, &mut rng_a);
            let w_ref = tournament(&pop, &rank, &crowd, &mut rng_b);
            assert!(std::ptr::eq(w_ref, &pop[w_idx]), "same winner, same stream");
        }
    }
}
