//! NSGA-II (Deb et al. 2002) — the multi-objective engine of paper §4.5:
//! fast non-dominated sort, crowding distance, environmental selection and
//! binary tournament.

use crate::evolution::genome::Individual;
use crate::util::Rng;

/// Fast non-dominated sort: partition indices into Pareto fronts
/// (front 0 = non-dominated).
pub fn fast_non_dominated_sort(pop: &[Individual]) -> Vec<Vec<usize>> {
    let n = pop.len();
    let mut dominated_by: Vec<Vec<usize>> = vec![Vec::new(); n]; // i dominates these
    let mut domination_count = vec![0usize; n];
    let mut fronts: Vec<Vec<usize>> = vec![Vec::new()];

    for i in 0..n {
        for j in 0..n {
            if i == j {
                continue;
            }
            if pop[i].dominates(&pop[j]) {
                dominated_by[i].push(j);
            } else if pop[j].dominates(&pop[i]) {
                domination_count[i] += 1;
            }
        }
        if domination_count[i] == 0 {
            fronts[0].push(i);
        }
    }

    let mut k = 0;
    while !fronts[k].is_empty() {
        let mut next = Vec::new();
        for &i in &fronts[k] {
            for &j in &dominated_by[i] {
                domination_count[j] -= 1;
                if domination_count[j] == 0 {
                    next.push(j);
                }
            }
        }
        fronts.push(next);
        k += 1;
    }
    fronts.pop(); // drop the trailing empty front
    fronts
}

/// Crowding distance of each member of one front (Deb 2002 §III-B).
pub fn crowding_distance(pop: &[Individual], front: &[usize]) -> Vec<f64> {
    let m = front.len();
    let mut dist = vec![0.0f64; m];
    if m == 0 {
        return dist;
    }
    if m <= 2 {
        return vec![f64::INFINITY; m];
    }
    let n_obj = pop[front[0]].objectives.len();
    for obj in 0..n_obj {
        let mut order: Vec<usize> = (0..m).collect();
        order.sort_by(|&a, &b| {
            pop[front[a]].objectives[obj]
                .partial_cmp(&pop[front[b]].objectives[obj])
                .unwrap()
        });
        let lo = pop[front[order[0]]].objectives[obj];
        let hi = pop[front[order[m - 1]]].objectives[obj];
        dist[order[0]] = f64::INFINITY;
        dist[order[m - 1]] = f64::INFINITY;
        let range = hi - lo;
        if range <= 0.0 {
            continue;
        }
        for w in 1..m - 1 {
            let prev = pop[front[order[w - 1]]].objectives[obj];
            let next = pop[front[order[w + 1]]].objectives[obj];
            dist[order[w]] += (next - prev) / range;
        }
    }
    dist
}

/// Rank (front index) and crowding for every individual.
pub fn rank_and_crowding(pop: &[Individual]) -> (Vec<usize>, Vec<f64>) {
    let fronts = fast_non_dominated_sort(pop);
    let mut rank = vec![0usize; pop.len()];
    let mut crowd = vec![0.0f64; pop.len()];
    for (r, front) in fronts.iter().enumerate() {
        let d = crowding_distance(pop, front);
        for (k, &i) in front.iter().enumerate() {
            rank[i] = r;
            crowd[i] = d[k];
        }
    }
    (rank, crowd)
}

/// Environmental selection: keep the best `mu` individuals by
/// (front rank, crowding distance) — the elitist step of NSGA-II.
pub fn select(pop: Vec<Individual>, mu: usize) -> Vec<Individual> {
    if pop.len() <= mu {
        return pop;
    }
    let fronts = fast_non_dominated_sort(&pop);
    let mut keep: Vec<usize> = Vec::with_capacity(mu);
    for front in &fronts {
        if keep.len() + front.len() <= mu {
            keep.extend_from_slice(front);
        } else {
            let d = crowding_distance(&pop, front);
            let mut order: Vec<usize> = (0..front.len()).collect();
            order.sort_by(|&a, &b| d[b].partial_cmp(&d[a]).unwrap());
            for &k in order.iter().take(mu - keep.len()) {
                keep.push(front[k]);
            }
            break;
        }
    }
    let mut flags = vec![false; pop.len()];
    for &i in &keep {
        flags[i] = true;
    }
    pop.into_iter()
        .zip(flags)
        .filter_map(|(ind, keep)| keep.then_some(ind))
        .collect()
}

/// Binary tournament on (rank, crowding): the parent-selection operator.
pub fn tournament<'a>(
    pop: &'a [Individual],
    rank: &[usize],
    crowd: &[f64],
    rng: &mut Rng,
) -> &'a Individual {
    let a = rng.usize(pop.len());
    let b = rng.usize(pop.len());
    let better = if rank[a] < rank[b] {
        a
    } else if rank[b] < rank[a] {
        b
    } else if crowd[a] >= crowd[b] {
        a
    } else {
        b
    };
    &pop[better]
}

/// The Pareto front (front 0) of a population.
pub fn pareto_front(pop: &[Individual]) -> Vec<Individual> {
    fast_non_dominated_sort(pop)
        .first()
        .map(|f| f.iter().map(|&i| pop[i].clone()).collect())
        .unwrap_or_default()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ind(objs: &[f64]) -> Individual {
        Individual::new(vec![], objs.to_vec())
    }

    #[test]
    fn sorts_into_fronts() {
        // front 0: (1,4), (2,2), (4,1); front 1: (3,4), (4,3); front 2: (5,5)
        let pop = vec![
            ind(&[1.0, 4.0]),
            ind(&[2.0, 2.0]),
            ind(&[4.0, 1.0]),
            ind(&[3.0, 4.0]),
            ind(&[4.0, 3.0]),
            ind(&[5.0, 5.0]),
        ];
        let fronts = fast_non_dominated_sort(&pop);
        assert_eq!(fronts.len(), 3);
        let mut f0 = fronts[0].clone();
        f0.sort_unstable();
        assert_eq!(f0, vec![0, 1, 2]);
        assert_eq!(fronts[2], vec![5]);
    }

    #[test]
    fn crowding_prefers_extremes() {
        let pop = vec![
            ind(&[0.0, 4.0]),
            ind(&[1.0, 3.0]),
            ind(&[2.0, 2.0]),
            ind(&[3.0, 1.0]),
            ind(&[4.0, 0.0]),
        ];
        let front: Vec<usize> = (0..5).collect();
        let d = crowding_distance(&pop, &front);
        assert!(d[0].is_infinite() && d[4].is_infinite());
        assert!(d[1] > 0.0 && d[2] > 0.0 && d[3] > 0.0);
        assert!(d[1].is_finite());
    }

    #[test]
    fn select_keeps_first_front_whole_when_it_fits() {
        let pop = vec![
            ind(&[1.0, 4.0]),
            ind(&[2.0, 2.0]),
            ind(&[4.0, 1.0]),
            ind(&[5.0, 5.0]),
            ind(&[6.0, 6.0]),
        ];
        let kept = select(pop, 3);
        assert_eq!(kept.len(), 3);
        // the three front-0 points survive
        let objs: Vec<&[f64]> = kept.iter().map(|i| i.objectives.as_slice()).collect();
        assert!(objs.contains(&[1.0, 4.0].as_slice()));
        assert!(objs.contains(&[2.0, 2.0].as_slice()));
        assert!(objs.contains(&[4.0, 1.0].as_slice()));
    }

    #[test]
    fn select_truncates_by_crowding() {
        // one big front of 5, keep 3: extremes must survive
        let pop = vec![
            ind(&[0.0, 4.0]),
            ind(&[1.0, 3.0]),
            ind(&[1.9, 2.1]), // most crowded middle point
            ind(&[3.0, 1.0]),
            ind(&[4.0, 0.0]),
        ];
        let kept = select(pop, 3);
        let objs: Vec<&[f64]> = kept.iter().map(|i| i.objectives.as_slice()).collect();
        assert!(objs.contains(&[0.0, 4.0].as_slice()));
        assert!(objs.contains(&[4.0, 0.0].as_slice()));
    }

    #[test]
    fn pareto_front_extraction() {
        let pop = vec![ind(&[1.0, 1.0]), ind(&[2.0, 2.0])];
        let front = pareto_front(&pop);
        assert_eq!(front.len(), 1);
        assert_eq!(front[0].objectives, vec![1.0, 1.0]);
    }

    #[test]
    fn tournament_prefers_lower_rank() {
        let pop = vec![ind(&[1.0, 1.0]), ind(&[5.0, 5.0])];
        let (rank, crowd) = rank_and_crowding(&pop);
        let mut rng = Rng::new(3);
        for _ in 0..20 {
            let w = tournament(&pop, &rank, &crowd, &mut rng);
            // winner is never strictly dominated by the loser
            assert!(!pop[1].dominates(w) || w.objectives == pop[1].objectives);
        }
    }

    #[test]
    fn identical_objectives_no_infinite_loop() {
        let pop = vec![ind(&[1.0, 1.0]); 6];
        let fronts = fast_non_dominated_sort(&pop);
        assert_eq!(fronts.len(), 1);
        assert_eq!(fronts[0].len(), 6);
        let kept = select(pop, 3);
        assert_eq!(kept.len(), 3);
    }
}
