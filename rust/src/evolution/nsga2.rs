//! NSGA-II (Deb et al. 2002) — the multi-objective engine of paper §4.5:
//! fast non-dominated sort, crowding distance, environmental selection and
//! binary tournament.
//!
//! §Perf tentpole: ranking runs on **flat index buffers** over a
//! contiguous objectives matrix — no `Vec<Vec<_>>` growth in the sorting
//! loop — and the ubiquitous two-objective case takes an O(N·logN) sweep
//! (Jensen 2003-style staircase binary search) instead of the O(N²)
//! pairwise pass, so environmental selection of a 200k-individual wave
//! (bench `p2_scale`) is tractable. All float orderings use
//! `f64::total_cmp`: a NaN objective ranks worst instead of panicking.

use crate::evolution::genome::Individual;
use crate::util::Rng;

/// Pareto fronts in CSR layout: `order` lists population indices front by
/// front, `starts[k]..starts[k + 1]` delimits front `k`. Replaces the old
/// `Vec<Vec<usize>>` (one heap allocation per front, reallocation churn
/// while peeling) with two flat buffers.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Fronts {
    order: Vec<usize>,
    /// Front boundaries; always `starts[0] == 0` and
    /// `starts.last() == order.len()`.
    starts: Vec<usize>,
}

impl Fronts {
    /// Number of fronts.
    pub fn len(&self) -> usize {
        self.starts.len() - 1
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The population indices of front `k` (0 = non-dominated).
    pub fn front(&self, k: usize) -> &[usize] {
        &self.order[self.starts[k]..self.starts[k + 1]]
    }

    /// Front 0, if the population was non-empty.
    pub fn first(&self) -> Option<&[usize]> {
        if self.is_empty() {
            None
        } else {
            Some(self.front(0))
        }
    }

    /// Iterate fronts in rank order.
    pub fn iter(&self) -> impl Iterator<Item = &[usize]> {
        (0..self.len()).map(move |k| self.front(k))
    }

    /// All indices, front-major (the flat `order` buffer).
    pub fn indices(&self) -> &[usize] {
        &self.order
    }
}

impl std::ops::Index<usize> for Fronts {
    type Output = [usize];

    fn index(&self, k: usize) -> &[usize] {
        self.front(k)
    }
}

/// Pairwise Pareto dominance on two objective rows (minimisation):
/// `(a_dominates_b, b_dominates_a)`. NaN comparisons are false on both
/// sides, matching [`Individual::dominates`].
#[inline]
fn pair_dominance(a: &[f64], b: &[f64]) -> (bool, bool) {
    let mut a_not_worse = true;
    let mut b_not_worse = true;
    let mut a_better_somewhere = false;
    let mut b_better_somewhere = false;
    for (x, y) in a.iter().zip(b) {
        if x < y {
            a_better_somewhere = true;
            b_not_worse = false;
        } else if y < x {
            b_better_somewhere = true;
            a_not_worse = false;
        }
    }
    (
        a_not_worse && a_better_somewhere,
        b_not_worse && b_better_somewhere,
    )
}

/// Fast non-dominated sort: partition indices into Pareto fronts
/// (front 0 = non-dominated).
///
/// Dispatches on the objective count: the two-objective case (ZDT1 and
/// most calibration setups) uses the O(N·logN) staircase sweep; anything
/// else uses the flat-CSR variant of Deb's O(M·N²) algorithm. NaN
/// objectives force the general path (the staircase invariants assume a
/// total order consistent with dominance).
pub fn fast_non_dominated_sort(pop: &[Individual]) -> Fronts {
    let n = pop.len();
    if n == 0 {
        return Fronts {
            order: Vec::new(),
            starts: vec![0],
        };
    }
    let m = pop[0].objectives.len();
    let mut obj = Vec::with_capacity(n * m);
    for ind in pop {
        debug_assert_eq!(
            ind.objectives.len(),
            m,
            "heterogeneous objective counts in one population"
        );
        // `+ 0.0` canonicalises -0.0 to +0.0 (and nothing else): dominance
        // treats the two zeros as equal, but the sweep path sorts with
        // `total_cmp`, which orders -0.0 < +0.0 and would break the
        // staircase invariant (a later point dominating an earlier tail)
        obj.extend(ind.objectives.iter().map(|v| v + 0.0));
    }
    if m == 2 && !obj.iter().any(|v| v.is_nan()) {
        sort_two_objective(&obj, n)
    } else {
        sort_general(&obj, n, m.max(1))
    }
}

/// Deb's algorithm on flat buffers: two O(N²) passes over the contiguous
/// objectives matrix build a CSR "dominates" adjacency, then fronts are
/// peeled by layered BFS directly into the output buffer.
fn sort_general(obj: &[f64], n: usize, m: usize) -> Fronts {
    let row = |i: usize| &obj[i * m..(i + 1) * m];

    // pass 1: domination counts and out-degrees
    let mut dominated_by_count = vec![0usize; n]; // how many dominate i
    let mut dominates_count = vec![0usize; n]; // how many i dominates
    for i in 0..n {
        for j in (i + 1)..n {
            let (i_dom, j_dom) = pair_dominance(row(i), row(j));
            if i_dom {
                dominates_count[i] += 1;
                dominated_by_count[j] += 1;
            } else if j_dom {
                dominates_count[j] += 1;
                dominated_by_count[i] += 1;
            }
        }
    }

    // CSR offsets, then pass 2 fills the adjacency in place
    let mut offsets = vec![0usize; n + 1];
    for i in 0..n {
        offsets[i + 1] = offsets[i] + dominates_count[i];
    }
    let mut adjacency = vec![0usize; offsets[n]];
    let mut cursor = offsets.clone();
    for i in 0..n {
        for j in (i + 1)..n {
            let (i_dom, j_dom) = pair_dominance(row(i), row(j));
            if i_dom {
                adjacency[cursor[i]] = j;
                cursor[i] += 1;
            } else if j_dom {
                adjacency[cursor[j]] = i;
                cursor[j] += 1;
            }
        }
    }

    // peel fronts: the output buffer doubles as the BFS queue
    let mut order: Vec<usize> =
        (0..n).filter(|&i| dominated_by_count[i] == 0).collect();
    let mut starts = vec![0usize];
    let mut begin = 0;
    while begin < order.len() {
        let end = order.len();
        starts.push(end);
        for idx in begin..end {
            let i = order[idx];
            for &j in &adjacency[offsets[i]..offsets[i + 1]] {
                dominated_by_count[j] -= 1;
                if dominated_by_count[j] == 0 {
                    order.push(j);
                }
            }
        }
        begin = end;
    }
    if order.len() < n {
        // NaN-induced dominance "cycles" (a beats b beats c beats a, each
        // through a different non-NaN objective) can strand individuals
        // with counts that never reach zero. The old Vec<Vec<_>> sort
        // silently dropped them; park them in one final front instead so
        // fronts always partition the population.
        let stranded = (0..n).filter(|&i| dominated_by_count[i] > 0);
        order.extend(stranded);
        starts.push(order.len());
    }
    Fronts { order, starts }
}

/// Two-objective O(N·logN) sweep: process points in (f1, f2) order and
/// binary-search the staircase of front tails. A point is dominated by
/// front `k` iff it is dominated by the front's most recently assigned
/// point (the one with minimal f2), and domination by front `k` implies
/// domination by front `k - 1` (transitivity), so the first non-dominating
/// front is found by binary search.
fn sort_two_objective(obj: &[f64], n: usize) -> Fronts {
    let mut sorted: Vec<usize> = (0..n).collect();
    sorted.sort_unstable_by(|&a, &b| {
        obj[2 * a]
            .total_cmp(&obj[2 * b])
            .then(obj[2 * a + 1].total_cmp(&obj[2 * b + 1]))
            .then(a.cmp(&b))
    });

    let mut rank = vec![0usize; n];
    // (f2, f1) of the last point assigned to each front
    let mut tails: Vec<(f64, f64)> = Vec::new();
    for &i in &sorted {
        let (f1, f2) = (obj[2 * i], obj[2 * i + 1]);
        let dominated_by = |k: usize| {
            let (t2, t1) = tails[k];
            // the tail q has q.f1 <= f1 (sweep order); strictness must
            // hold in at least one objective
            t2 < f2 || (t2 == f2 && t1 < f1)
        };
        let (mut lo, mut hi) = (0usize, tails.len());
        while lo < hi {
            let mid = (lo + hi) / 2;
            if dominated_by(mid) {
                lo = mid + 1;
            } else {
                hi = mid;
            }
        }
        rank[i] = lo;
        if lo == tails.len() {
            tails.push((f2, f1));
        } else {
            tails[lo] = (f2, f1);
        }
    }

    // bucket ranks into CSR, index-ascending within each front
    let n_fronts = tails.len();
    let mut starts = vec![0usize; n_fronts + 1];
    for &r in &rank {
        starts[r + 1] += 1;
    }
    for k in 0..n_fronts {
        starts[k + 1] += starts[k];
    }
    let mut cursor = starts.clone();
    let mut order = vec![0usize; n];
    for (i, &r) in rank.iter().enumerate() {
        order[cursor[r]] = i;
        cursor[r] += 1;
    }
    Fronts { order, starts }
}

/// Crowding distance of each member of one front (Deb 2002 §III-B).
/// NaN-safe: objective orderings use `total_cmp`.
pub fn crowding_distance(pop: &[Individual], front: &[usize]) -> Vec<f64> {
    let m = front.len();
    let mut dist = vec![0.0f64; m];
    if m == 0 {
        return dist;
    }
    if m <= 2 {
        return vec![f64::INFINITY; m];
    }
    let n_obj = pop[front[0]].objectives.len();
    let mut order: Vec<usize> = Vec::with_capacity(m);
    for obj in 0..n_obj {
        // reset to index order so equal objective values tie-break the
        // same way on every objective (stable sort)
        order.clear();
        order.extend(0..m);
        order.sort_by(|&a, &b| {
            pop[front[a]].objectives[obj]
                .total_cmp(&pop[front[b]].objectives[obj])
        });
        let lo = pop[front[order[0]]].objectives[obj];
        let hi = pop[front[order[m - 1]]].objectives[obj];
        dist[order[0]] = f64::INFINITY;
        dist[order[m - 1]] = f64::INFINITY;
        let range = hi - lo;
        if range.is_nan() || range <= 0.0 {
            // zero range, or a NaN objective poisoned the bounds: no
            // discriminating information along this objective
            continue;
        }
        for w in 1..m - 1 {
            let prev = pop[front[order[w - 1]]].objectives[obj];
            let next = pop[front[order[w + 1]]].objectives[obj];
            dist[order[w]] += (next - prev) / range;
        }
    }
    dist
}

/// Rank (front index) and crowding for every individual.
pub fn rank_and_crowding(pop: &[Individual]) -> (Vec<usize>, Vec<f64>) {
    let fronts = fast_non_dominated_sort(pop);
    let mut rank = vec![0usize; pop.len()];
    let mut crowd = vec![0.0f64; pop.len()];
    for (r, front) in fronts.iter().enumerate() {
        let d = crowding_distance(pop, front);
        for (k, &i) in front.iter().enumerate() {
            rank[i] = r;
            crowd[i] = d[k];
        }
    }
    (rank, crowd)
}

/// Environmental selection: keep the best `mu` individuals by
/// (front rank, crowding distance) — the elitist step of NSGA-II.
pub fn select(pop: Vec<Individual>, mu: usize) -> Vec<Individual> {
    if pop.len() <= mu {
        return pop;
    }
    let fronts = fast_non_dominated_sort(&pop);
    let mut flags = vec![false; pop.len()];
    let mut kept = 0usize;
    for front in fronts.iter() {
        if kept + front.len() <= mu {
            for &i in front {
                flags[i] = true;
            }
            kept += front.len();
            if kept == mu {
                break;
            }
        } else {
            let d = crowding_distance(&pop, front);
            let mut order: Vec<usize> = (0..front.len()).collect();
            order.sort_by(|&a, &b| d[b].total_cmp(&d[a]));
            for &w in order.iter().take(mu - kept) {
                flags[front[w]] = true;
            }
            break;
        }
    }
    pop.into_iter()
        .zip(flags)
        .filter_map(|(ind, keep)| keep.then_some(ind))
        .collect()
}

/// Binary tournament on (rank, crowding): the parent-selection operator.
pub fn tournament<'a>(
    pop: &'a [Individual],
    rank: &[usize],
    crowd: &[f64],
    rng: &mut Rng,
) -> &'a Individual {
    let a = rng.usize(pop.len());
    let b = rng.usize(pop.len());
    let better = if rank[a] < rank[b] {
        a
    } else if rank[b] < rank[a] {
        b
    } else if crowd[a] >= crowd[b] {
        a
    } else {
        b
    };
    &pop[better]
}

/// The Pareto front (front 0) of a population.
pub fn pareto_front(pop: &[Individual]) -> Vec<Individual> {
    fast_non_dominated_sort(pop)
        .first()
        .map(|f| f.iter().map(|&i| pop[i].clone()).collect())
        .unwrap_or_default()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ind(objs: &[f64]) -> Individual {
        Individual::new(vec![], objs.to_vec())
    }

    /// Reference implementation: direct pairwise `dominates` checks.
    fn naive_fronts(pop: &[Individual]) -> Vec<Vec<usize>> {
        let n = pop.len();
        let mut remaining: Vec<usize> = (0..n).collect();
        let mut fronts = Vec::new();
        while !remaining.is_empty() {
            let front: Vec<usize> = remaining
                .iter()
                .copied()
                .filter(|&i| {
                    !remaining.iter().any(|&j| pop[j].dominates(&pop[i]))
                })
                .collect();
            remaining.retain(|i| !front.contains(i));
            fronts.push(front);
        }
        fronts
    }

    fn assert_fronts_match(pop: &[Individual]) {
        let got = fast_non_dominated_sort(pop);
        let want = naive_fronts(pop);
        assert_eq!(got.len(), want.len(), "front count");
        for (k, want_front) in want.iter().enumerate() {
            let mut got_front = got[k].to_vec();
            got_front.sort_unstable();
            let mut want_front = want_front.clone();
            want_front.sort_unstable();
            assert_eq!(got_front, want_front, "front {k}");
        }
    }

    #[test]
    fn sorts_into_fronts() {
        // front 0: (1,4), (2,2), (4,1); front 1: (3,4), (4,3); front 2: (5,5)
        let pop = vec![
            ind(&[1.0, 4.0]),
            ind(&[2.0, 2.0]),
            ind(&[4.0, 1.0]),
            ind(&[3.0, 4.0]),
            ind(&[4.0, 3.0]),
            ind(&[5.0, 5.0]),
        ];
        let fronts = fast_non_dominated_sort(&pop);
        assert_eq!(fronts.len(), 3);
        let mut f0 = fronts[0].to_vec();
        f0.sort_unstable();
        assert_eq!(f0, vec![0, 1, 2]);
        assert_eq!(fronts[2].to_vec(), vec![5]);
    }

    #[test]
    fn two_objective_sweep_matches_pairwise_reference() {
        // randomised cross-check of the O(N logN) path against the naive
        // definition, duplicates included
        let mut rng = Rng::new(0xF00D);
        for _case in 0..60 {
            let n = 1 + rng.usize(60);
            let mut pop: Vec<Individual> = (0..n)
                .map(|_| {
                    ind(&[
                        f64::from(rng.usize(8) as u32),
                        f64::from(rng.usize(8) as u32),
                    ])
                })
                .collect();
            // sprinkle exact duplicates
            if n > 2 {
                let dup = pop[0].objectives.clone();
                pop[n / 2].objectives = dup;
            }
            assert_fronts_match(&pop);
        }
    }

    #[test]
    fn three_objective_general_path_matches_reference() {
        let mut rng = Rng::new(0xBEEF);
        for _ in 0..40 {
            let n = 1 + rng.usize(40);
            let pop: Vec<Individual> = (0..n)
                .map(|_| {
                    ind(&[
                        f64::from(rng.usize(5) as u32),
                        f64::from(rng.usize(5) as u32),
                        f64::from(rng.usize(5) as u32),
                    ])
                })
                .collect();
            assert_fronts_match(&pop);
        }
    }

    #[test]
    fn crowding_prefers_extremes() {
        let pop = vec![
            ind(&[0.0, 4.0]),
            ind(&[1.0, 3.0]),
            ind(&[2.0, 2.0]),
            ind(&[3.0, 1.0]),
            ind(&[4.0, 0.0]),
        ];
        let front: Vec<usize> = (0..5).collect();
        let d = crowding_distance(&pop, &front);
        assert!(d[0].is_infinite() && d[4].is_infinite());
        assert!(d[1] > 0.0 && d[2] > 0.0 && d[3] > 0.0);
        assert!(d[1].is_finite());
    }

    #[test]
    fn select_keeps_first_front_whole_when_it_fits() {
        let pop = vec![
            ind(&[1.0, 4.0]),
            ind(&[2.0, 2.0]),
            ind(&[4.0, 1.0]),
            ind(&[5.0, 5.0]),
            ind(&[6.0, 6.0]),
        ];
        let kept = select(pop, 3);
        assert_eq!(kept.len(), 3);
        // the three front-0 points survive
        let objs: Vec<&[f64]> = kept.iter().map(|i| i.objectives.as_slice()).collect();
        assert!(objs.contains(&[1.0, 4.0].as_slice()));
        assert!(objs.contains(&[2.0, 2.0].as_slice()));
        assert!(objs.contains(&[4.0, 1.0].as_slice()));
    }

    #[test]
    fn select_truncates_by_crowding() {
        // one big front of 5, keep 3: extremes must survive
        let pop = vec![
            ind(&[0.0, 4.0]),
            ind(&[1.0, 3.0]),
            ind(&[1.9, 2.1]), // most crowded middle point
            ind(&[3.0, 1.0]),
            ind(&[4.0, 0.0]),
        ];
        let kept = select(pop, 3);
        let objs: Vec<&[f64]> = kept.iter().map(|i| i.objectives.as_slice()).collect();
        assert!(objs.contains(&[0.0, 4.0].as_slice()));
        assert!(objs.contains(&[4.0, 0.0].as_slice()));
    }

    #[test]
    fn pareto_front_extraction() {
        let pop = vec![ind(&[1.0, 1.0]), ind(&[2.0, 2.0])];
        let front = pareto_front(&pop);
        assert_eq!(front.len(), 1);
        assert_eq!(front[0].objectives, vec![1.0, 1.0]);
    }

    #[test]
    fn tournament_prefers_lower_rank() {
        let pop = vec![ind(&[1.0, 1.0]), ind(&[5.0, 5.0])];
        let (rank, crowd) = rank_and_crowding(&pop);
        let mut rng = Rng::new(3);
        for _ in 0..20 {
            let w = tournament(&pop, &rank, &crowd, &mut rng);
            // winner is never strictly dominated by the loser
            assert!(!pop[1].dominates(w) || w.objectives == pop[1].objectives);
        }
    }

    #[test]
    fn identical_objectives_no_infinite_loop() {
        let pop = vec![ind(&[1.0, 1.0]); 6];
        let fronts = fast_non_dominated_sort(&pop);
        assert_eq!(fronts.len(), 1);
        assert_eq!(fronts[0].len(), 6);
        let kept = select(pop, 3);
        assert_eq!(kept.len(), 3);
    }

    #[test]
    fn empty_population_yields_no_fronts() {
        let fronts = fast_non_dominated_sort(&[]);
        assert!(fronts.is_empty());
        assert_eq!(fronts.len(), 0);
        assert!(pareto_front(&[]).is_empty());
    }

    #[test]
    fn nan_objectives_do_not_panic_and_rank_worst() {
        // regression: `partial_cmp(..).unwrap()` used to panic here
        let pop = vec![
            ind(&[f64::NAN, 1.0]),
            ind(&[0.5, 0.5]),
            ind(&[0.2, 0.9]),
            ind(&[0.9, f64::NAN]),
            ind(&[0.1, 1.1]),
        ];
        let fronts = fast_non_dominated_sort(&pop);
        let total: usize = fronts.iter().map(<[usize]>::len).sum();
        assert_eq!(total, pop.len(), "fronts must still partition");
        let (rank, crowd) = rank_and_crowding(&pop);
        assert_eq!(rank.len(), 5);
        assert_eq!(crowd.len(), 5);
        let kept = select(pop.clone(), 3);
        assert_eq!(kept.len(), 3, "selection must still truncate to mu");
        // a fully-NaN front member must not displace finite solutions from
        // a *better* front: the finite mutually-nondominated points stay
        let finite_kept = kept
            .iter()
            .filter(|i| i.objectives.iter().all(|v| v.is_finite()))
            .count();
        assert!(finite_kept >= 2, "kept {kept:?}");
    }

    #[test]
    fn nan_crowding_distance_never_panics_or_poisons() {
        let pop = vec![
            ind(&[0.0, 1.0]),
            ind(&[f64::NAN, 0.5]),
            ind(&[0.5, f64::NAN]),
            ind(&[1.0, 0.0]),
        ];
        let front: Vec<usize> = (0..4).collect();
        let d = crowding_distance(&pop, &front);
        assert_eq!(d.len(), 4);
        // a NaN range skips the objective rather than spreading NaN
        assert!(d.iter().all(|v| !v.is_nan()), "distances {d:?}");
    }

    #[test]
    fn negative_zero_objectives_rank_like_positive_zero() {
        // regression (review finding): total_cmp orders -0.0 < +0.0, so an
        // uncanonicalised sweep put the dominated (-0.0, 5.0) into front 0
        let pop = vec![ind(&[-0.0, 5.0]), ind(&[0.0, 1.0])];
        let fronts = fast_non_dominated_sort(&pop);
        assert_eq!(fronts.len(), 2, "(0.0, 1.0) dominates (-0.0, 5.0)");
        assert_eq!(fronts[0].to_vec(), vec![1]);
        assert_eq!(fronts[1].to_vec(), vec![0]);
        assert_fronts_match(&pop);
    }

    #[test]
    fn nan_dominance_cycle_still_partitions() {
        // x beats z, z beats y, y beats x — each through a different
        // non-NaN objective. No count ever reaches zero, so the peel
        // strands all three; the fallback front must catch them.
        let pop = vec![
            ind(&[0.0, 5.0, f64::NAN]),
            ind(&[f64::NAN, 0.0, 5.0]),
            ind(&[5.0, f64::NAN, 0.0]),
        ];
        let fronts = fast_non_dominated_sort(&pop);
        let total: usize = fronts.iter().map(<[usize]>::len).sum();
        assert_eq!(total, 3, "cycle members must not vanish");
        let kept = select(pop, 2);
        assert_eq!(kept.len(), 2);
    }

    #[test]
    fn large_two_objective_wave_ranks_quickly() {
        // smoke-scale version of bench p2_scale: 20k points through the
        // sweep path plus a select — finishes in well under a second
        let mut rng = Rng::new(7);
        let pop: Vec<Individual> = (0..20_000)
            .map(|_| ind(&[rng.f64(), rng.f64()]))
            .collect();
        let fronts = fast_non_dominated_sort(&pop);
        let total: usize = fronts.iter().map(<[usize]>::len).sum();
        assert_eq!(total, pop.len());
        let kept = select(pop, 200);
        assert_eq!(kept.len(), 200);
    }
}
