//! `GenerationalGA(evolution)(replicateModel, lambda)` — paper §4.5,
//! Listing 4: synchronous-generation NSGA-II with stochastic-fitness
//! re-evaluation, delegated to an execution environment.

use std::sync::Arc;

use crate::broker::journal::{self, Journal, ResumeState};
use crate::core::{Context, Val};
use crate::dsl::task::ClosureTask;
use crate::environment::{Environment, Job};
use crate::error::{Error, Result};
use crate::evolution::evaluator::Evaluator;
use crate::evolution::genome::{Bounds, Individual};
use crate::evolution::nsga2;
use crate::evolution::operators::Operators;
use crate::util::json::Json;
use crate::util::Rng;

/// The `NSGA2(...)` configuration of Listing 4/5.
#[derive(Clone)]
pub struct Nsga2Config {
    /// Population size kept by environmental selection.
    pub mu: usize,
    /// Search-space bounds (genome variables + ranges).
    pub bounds: Bounds,
    /// Objective variable names (for result files/hooks).
    pub objectives: Vec<String>,
    /// Fraction of each batch spent re-evaluating current individuals
    /// (`reevaluate = 0.01`): kills over-evaluated lucky individuals.
    pub reevaluate: f64,
    /// Variation operators.
    pub operators: Operators,
}

impl Nsga2Config {
    pub fn new(
        mu: usize,
        inputs: &[(&Val<f64>, f64, f64)],
        objectives: &[&Val<f64>],
        reevaluate: f64,
    ) -> Result<Self> {
        Ok(Nsga2Config {
            mu,
            bounds: Bounds::new(inputs)?,
            objectives: objectives.iter().map(|v| v.name().to_string()).collect(),
            reevaluate,
            operators: Operators::default(),
        })
    }
}

/// Outcome of an evolution run.
#[derive(Debug, Clone)]
pub struct EvolutionResult {
    pub population: Vec<Individual>,
    pub pareto_front: Vec<Individual>,
    pub evaluations: u64,
    pub generations: u32,
    /// Virtual makespan of the whole optimisation on the environment.
    pub virtual_makespan: f64,
}

/// Wrap an [`Evaluator`] as a DSL task so evaluation jobs flow through the
/// same environments as any other workload.
///
/// The closure routes through [`Evaluator::evaluate_batch`] (a batch of
/// one) so every engine sits on the batch interface: a pooled or vmapped
/// evaluator applies its machinery uniformly, and plain evaluators fall
/// back to `evaluate` via the default implementation.
pub fn eval_task(
    evaluator: Arc<dyn Evaluator>,
    bounds: &Bounds,
    objectives: &[String],
) -> Arc<ClosureTask> {
    let names = bounds.names.clone();
    let objective_names = objectives.to_vec();
    let cost = evaluator.nominal_cost_s();
    let seed_val: Val<u32> = Val::new("seed");
    let mut task = ClosureTask::new("evaluate", move |ctx: &Context| {
        let genome: Vec<f64> = names
            .iter()
            .map(|n| ctx.get(&Val::<f64>::new(n.clone())))
            .collect::<Result<_>>()?;
        let seed: u32 = ctx.get(&Val::<u32>::new("seed"))?;
        let objs = evaluator
            .evaluate_batch(&[(genome, seed)])?
            .pop()
            .ok_or_else(|| Error::Evolution("empty evaluation batch".into()))?;
        if objs.len() != objective_names.len() {
            return Err(Error::Evolution(format!(
                "evaluator returned {} objectives, config declares {}",
                objs.len(),
                objective_names.len()
            )));
        }
        let mut out = Context::new();
        for (name, v) in objective_names.iter().zip(objs) {
            out.set(&Val::<f64>::new(name.clone()), v);
        }
        Ok(out)
    })
    .cost(cost)
    .input(&seed_val);
    for n in &bounds.names {
        task = task.input(&Val::<f64>::new(n.clone()));
    }
    Arc::new(task)
}

/// The generational driver.
pub struct GenerationalGA {
    pub config: Nsga2Config,
    pub evaluator: Arc<dyn Evaluator>,
    /// Offspring per generation (= parallelism level, Listing 4).
    pub lambda: usize,
    /// Genomes per evaluation job (§Perf tentpole). 1 — the default, and
    /// the paper's shape — submits one environment job per genome; larger
    /// values pack each job with a whole chunk evaluated through
    /// [`Evaluator::evaluate_batch`], which is how a pooled or vmapped
    /// evaluator sees enough work to use a multicore machine. Virtual cost
    /// scales with the chunk, so simulated-environment accounting stays
    /// per-evaluation.
    pub eval_chunk: usize,
    /// Called after each generation with (generation, population).
    pub on_generation: Option<Arc<dyn Fn(u32, &[Individual]) + Send + Sync>>,
    /// Optional JSONL checkpoint stream: one `generation` record per
    /// generation, enabling `--resume` after a kill (§Distribution).
    pub journal: Option<Arc<Journal>>,
}

impl GenerationalGA {
    pub fn new(config: Nsga2Config, evaluator: Arc<dyn Evaluator>, lambda: usize) -> Self {
        GenerationalGA {
            config,
            evaluator,
            lambda,
            eval_chunk: 1,
            on_generation: None,
            journal: None,
        }
    }

    /// Set the genomes-per-job packing for evaluation waves.
    pub fn eval_chunk(mut self, chunk: usize) -> Self {
        self.eval_chunk = chunk.max(1);
        self
    }

    /// Checkpoint every generation to `journal`.
    pub fn journal(mut self, journal: Arc<Journal>) -> Self {
        self.journal = Some(journal);
        self
    }

    pub fn on_generation(
        mut self,
        f: impl Fn(u32, &[Individual]) + Send + Sync + 'static,
    ) -> Self {
        self.on_generation = Some(Arc::new(f));
        self
    }

    /// Evaluate a set of genomes on the environment; returns individuals
    /// plus the latest virtual end time.
    ///
    /// Genomes are packed `eval_chunk` to a job; each job calls the
    /// evaluator's **batch** path once. Per-genome seeds are drawn up
    /// front in genome order, so results — and the RNG stream — are
    /// independent of the chunking.
    fn evaluate_wave(
        &self,
        env: &dyn Environment,
        genomes: &[Vec<f64>],
        rng: &mut Rng,
        released_at: f64,
    ) -> Result<(Vec<Individual>, f64)> {
        let n_obj = self.config.objectives.len();
        let cost = self.evaluator.nominal_cost_s();
        let chunk_len = self.eval_chunk.max(1);
        let jobs: Vec<(Vec<f64>, u32)> = genomes
            .iter()
            .map(|g| (g.clone(), rng.model_seed()))
            .collect();

        type Slot = Arc<std::sync::Mutex<Option<Vec<Vec<f64>>>>>;
        let mut submissions: Vec<(Slot, crate::environment::JobHandle)> =
            Vec::with_capacity(jobs.len().div_ceil(chunk_len));
        for chunk in jobs.chunks(chunk_len) {
            let slot: Slot = Arc::new(std::sync::Mutex::new(None));
            let evaluator = Arc::clone(&self.evaluator);
            let chunk_jobs = chunk.to_vec();
            let out_slot = Arc::clone(&slot);
            let task = ClosureTask::new("evaluate", move |_ctx: &Context| {
                let objs = evaluator.evaluate_batch(&chunk_jobs)?;
                if objs.len() != chunk_jobs.len() {
                    return Err(Error::Evolution(format!(
                        "evaluator returned {} results for a chunk of {}",
                        objs.len(),
                        chunk_jobs.len()
                    )));
                }
                for o in &objs {
                    if o.len() != n_obj {
                        return Err(Error::Evolution(format!(
                            "evaluator returned {} objectives, config declares {n_obj}",
                            o.len()
                        )));
                    }
                }
                *out_slot.lock().unwrap() = Some(objs);
                Ok(Context::new())
            })
            .cost(cost * chunk.len() as f64);
            let handle = env
                .submit(Job::new(Arc::new(task), Context::new()).released_at(released_at));
            submissions.push((slot, handle));
        }

        let mut out = Vec::with_capacity(genomes.len());
        let mut latest = released_at;
        // consume `jobs` rather than cloning each genome back out
        let mut job_iter = jobs.into_iter();
        for (slot, handle) in submissions {
            let (_ctx, report) = handle.wait()?;
            latest = latest.max(report.virtual_end);
            let objs = slot.lock().unwrap().take().ok_or_else(|| {
                Error::Evolution("evaluation chunk produced no results".into())
            })?;
            for objectives in objs {
                let (genome, _seed) = job_iter
                    .next()
                    .expect("chunk result counts were validated in the task");
                out.push(Individual::new(genome, objectives));
            }
        }
        Ok((out, latest))
    }

    fn checkpoint(
        &self,
        generation: u32,
        evaluations: u64,
        clock: f64,
        rng: &Rng,
        population: &[Individual],
    ) -> Result<()> {
        if let Some(j) = &self.journal {
            j.append(&journal::generation_record(
                generation,
                evaluations,
                clock,
                rng,
                population,
            ))?;
        }
        Ok(())
    }

    /// Run `generations` synchronous generations on `env`.
    pub fn run(
        &self,
        env: &dyn Environment,
        generations: u32,
        seed: u64,
    ) -> Result<EvolutionResult> {
        self.run_resumable(env, generations, seed, None)
    }

    /// Run, optionally continuing from a journal checkpoint.
    ///
    /// With `resume: Some(state)` the run restores the checkpointed
    /// population, virtual clock, evaluation counter and RNG state, then
    /// continues at `state.generation + 1`. The configuration (`mu`,
    /// `lambda`, bounds, operators, evaluator) must match the original
    /// run — the journal stores the trajectory, not the configuration —
    /// and when it does, the resumed run's final population is
    /// bit-identical to an uninterrupted run with the same seed.
    pub fn run_resumable(
        &self,
        env: &dyn Environment,
        generations: u32,
        seed: u64,
        resume: Option<ResumeState>,
    ) -> Result<EvolutionResult> {
        let cfg = &self.config;
        let (mut rng, mut population, mut clock, mut evaluations, first_gen) =
            match resume {
                Some(r) => {
                    if let Some(j) = &self.journal {
                        j.append(&journal::run_start(
                            "calibrate-resume",
                            seed,
                            vec![(
                                "from_generation",
                                Json::Num(f64::from(r.generation)),
                            )],
                        ))?;
                    }
                    (r.rng, r.population, r.clock, r.evaluations, r.generation + 1)
                }
                None => {
                    if let Some(j) = &self.journal {
                        j.append(&journal::run_start(
                            "calibrate",
                            seed,
                            vec![
                                ("mu", Json::Num(cfg.mu as f64)),
                                ("lambda", Json::Num(self.lambda as f64)),
                                ("generations", Json::Num(f64::from(generations))),
                            ],
                        ))?;
                    }
                    let mut rng = Rng::new(seed);
                    // initial population
                    let init: Vec<Vec<f64>> =
                        (0..cfg.mu).map(|_| cfg.bounds.random(&mut rng)).collect();
                    let (population, clock) =
                        self.evaluate_wave(env, &init, &mut rng, 0.0)?;
                    let evaluations = population.len() as u64;
                    self.checkpoint(0, evaluations, clock, &rng, &population)?;
                    (rng, population, clock, evaluations, 1)
                }
            };

        for generation in first_gen..=generations {
            // breed lambda offspring
            let (rank, crowd) = nsga2::rank_and_crowding(&population);
            let offspring: Vec<Vec<f64>> = (0..self.lambda)
                .map(|_| {
                    let a = nsga2::tournament(&population, &rank, &crowd, &mut rng);
                    let b = nsga2::tournament(&population, &rank, &crowd, &mut rng);
                    cfg.operators
                        .breed(&a.genome, &b.genome, &cfg.bounds, &mut rng)
                })
                .collect();
            let (children, t1) = self.evaluate_wave(env, &offspring, &mut rng, clock)?;
            evaluations += children.len() as u64;
            clock = t1;

            // reevaluate a fraction of the current population (Listing 4's
            // `reevaluate = 0.01`)
            let n_re = ((population.len() as f64) * cfg.reevaluate).round() as usize;
            if n_re > 0 {
                let idx = rng.sample_indices(population.len(), n_re);
                let genomes: Vec<Vec<f64>> =
                    idx.iter().map(|&i| population[i].genome.clone()).collect();
                let (fresh, t2) = self.evaluate_wave(env, &genomes, &mut rng, clock)?;
                evaluations += fresh.len() as u64;
                clock = t2;
                for (k, &i) in idx.iter().enumerate() {
                    population[i].absorb_reevaluation(&fresh[k].objectives);
                }
            }

            // elitist environmental selection
            population.extend(children);
            population = nsga2::select(population, cfg.mu);

            self.checkpoint(generation, evaluations, clock, &rng, &population)?;
            if let Some(cb) = &self.on_generation {
                cb(generation, &population);
            }
        }

        if let Some(j) = &self.journal {
            j.append(&journal::env_stats_record(env.name(), &env.stats()))?;
            j.append(&journal::run_end(evaluations, clock))?;
        }

        let pareto_front = nsga2::pareto_front(&population);
        Ok(EvolutionResult {
            population,
            pareto_front,
            evaluations,
            generations,
            virtual_makespan: clock,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::val_f64;
    use crate::environment::local::LocalEnvironment;
    use crate::evolution::evaluator::Zdt1Evaluator;

    fn zdt1_config(mu: usize) -> Nsga2Config {
        let x0 = val_f64("x0");
        let x1 = val_f64("x1");
        let x2 = val_f64("x2");
        let f1 = val_f64("f1");
        let f2 = val_f64("f2");
        Nsga2Config::new(
            mu,
            &[(&x0, 0.0, 1.0), (&x1, 0.0, 1.0), (&x2, 0.0, 1.0)],
            &[&f1, &f2],
            0.0,
        )
        .unwrap()
    }

    #[test]
    fn converges_towards_zdt1_front() {
        let env = LocalEnvironment::new(4);
        let ga = GenerationalGA::new(
            zdt1_config(16),
            Arc::new(Zdt1Evaluator { dim: 3 }),
            16,
        );
        let result = ga.run(&env, 30, 7).unwrap();
        assert_eq!(result.population.len(), 16);
        assert!(result.evaluations >= 16 * 31);
        // mean distance of front points to the true front f2 = 1 - sqrt(f1)
        let err: f64 = result
            .pareto_front
            .iter()
            .map(|i| (i.objectives[1] - (1.0 - i.objectives[0].sqrt())).abs())
            .sum::<f64>()
            / result.pareto_front.len() as f64;
        assert!(err < 0.35, "front error {err}");
    }

    #[test]
    fn deterministic_under_seed() {
        let env = LocalEnvironment::new(2);
        let ga = GenerationalGA::new(zdt1_config(8), Arc::new(Zdt1Evaluator { dim: 3 }), 8);
        let a = ga.run(&env, 5, 11).unwrap();
        let b = ga.run(&env, 5, 11).unwrap();
        let objs = |r: &EvolutionResult| -> Vec<Vec<f64>> {
            r.population.iter().map(|i| i.objectives.clone()).collect()
        };
        assert_eq!(objs(&a), objs(&b));
    }

    #[test]
    fn chunked_wave_matches_per_genome_jobs() {
        // the §Perf batch path must not change results: chunk size and
        // evaluator pooling are pure execution-shape knobs
        let objs = |r: &EvolutionResult| -> Vec<Vec<f64>> {
            r.population.iter().map(|i| i.objectives.clone()).collect()
        };
        let env = LocalEnvironment::new(4);
        let per_genome =
            GenerationalGA::new(zdt1_config(8), Arc::new(Zdt1Evaluator { dim: 3 }), 8);
        let baseline = per_genome.run(&env, 5, 11).unwrap();
        for chunk in [3, 8, 64] {
            let chunked =
                GenerationalGA::new(zdt1_config(8), Arc::new(Zdt1Evaluator { dim: 3 }), 8)
                    .eval_chunk(chunk);
            let got = chunked.run(&env, 5, 11).unwrap();
            assert_eq!(objs(&baseline), objs(&got), "chunk {chunk} diverged");
        }
        let pooled = GenerationalGA::new(
            zdt1_config(8),
            Arc::new(crate::evolution::evaluator::PooledEvaluator::with_threads(
                Arc::new(Zdt1Evaluator { dim: 3 }),
                3,
            )),
            8,
        )
        .eval_chunk(8);
        let got = pooled.run(&env, 5, 11).unwrap();
        assert_eq!(objs(&baseline), objs(&got), "pooled evaluator diverged");
    }

    #[test]
    fn generation_callback_fires() {
        let env = LocalEnvironment::new(2);
        let seen = Arc::new(std::sync::atomic::AtomicU32::new(0));
        let s2 = Arc::clone(&seen);
        let ga = GenerationalGA::new(zdt1_config(4), Arc::new(Zdt1Evaluator { dim: 3 }), 4)
            .on_generation(move |_, _| {
                s2.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
            });
        ga.run(&env, 6, 1).unwrap();
        assert_eq!(seen.load(std::sync::atomic::Ordering::SeqCst), 6);
    }

    #[test]
    fn journaled_resume_is_bit_identical() {
        let tmp = std::env::temp_dir();
        let path_full = tmp.join(format!("molers-gen-full-{}.jsonl", std::process::id()));
        let path_cut = tmp.join(format!("molers-gen-cut-{}.jsonl", std::process::id()));
        let objs = |r: &EvolutionResult| -> Vec<Vec<f64>> {
            r.population.iter().map(|i| i.objectives.clone()).collect()
        };

        let env = LocalEnvironment::new(2);
        let mut cfg = zdt1_config(8);
        cfg.reevaluate = 0.25; // exercise the reevaluation path across resume
        let uninterrupted =
            GenerationalGA::new(cfg.clone(), Arc::new(Zdt1Evaluator { dim: 3 }), 8)
                .journal(Arc::new(Journal::create(&path_full).unwrap()));
        let full = uninterrupted.run(&env, 6, 17).unwrap();

        // "kill" after generation 3: run only the first half, journaled
        let first_half =
            GenerationalGA::new(cfg.clone(), Arc::new(Zdt1Evaluator { dim: 3 }), 8)
                .journal(Arc::new(Journal::create(&path_cut).unwrap()));
        first_half.run(&env, 3, 17).unwrap();

        // resume from the journal and finish the remaining generations
        let resume = journal::load_resume(&path_cut).unwrap().expect("checkpoint");
        assert_eq!(resume.generation, 3);
        let resumed_ga =
            GenerationalGA::new(cfg, Arc::new(Zdt1Evaluator { dim: 3 }), 8)
                .journal(Arc::new(Journal::append_to(&path_cut).unwrap()));
        let resumed = resumed_ga
            .run_resumable(&env, 6, 17, Some(resume))
            .unwrap();

        assert_eq!(
            objs(&full),
            objs(&resumed),
            "kill + resume must reproduce the uninterrupted trajectory"
        );
        assert_eq!(full.evaluations, resumed.evaluations);
        let _ = std::fs::remove_file(&path_full);
        let _ = std::fs::remove_file(&path_cut);
    }

    #[test]
    fn reevaluation_consumes_budget() {
        let env = LocalEnvironment::new(2);
        let mut cfg = zdt1_config(10);
        cfg.reevaluate = 0.5;
        let ga = GenerationalGA::new(cfg, Arc::new(Zdt1Evaluator { dim: 3 }), 10);
        let r = ga.run(&env, 4, 2).unwrap();
        // init 10 + 4*(10 offspring + 5 reevals)
        assert_eq!(r.evaluations, 10 + 4 * 15);
    }
}
