//! `GenerationalGA(evolution)(replicateModel, lambda)` — paper §4.5,
//! Listing 4: synchronous-generation NSGA-II with stochastic-fitness
//! re-evaluation, delegated to an execution environment.
//!
//! §Perf tentpole: the population lives in a columnar
//! [`PopMatrix`] — parents in the head rows, each generation's offspring
//! bred **in place** into the tail rows (per-chunk deterministic RNG
//! forks, optionally parallel over a coordinator [`ThreadPool`]),
//! objectives written straight into the matrix by the wave, and
//! environmental selection compacting survivors without a single
//! individual clone. A [`WaveArena`] is recycled across generations, so
//! the coordinator's steady-state allocation is only the owned genome
//! copies that cross the environment boundary.

use std::sync::Arc;

use crate::broker::journal::{self, Journal, ResumeState};
use crate::core::{Context, Val};
use crate::dsl::task::ClosureTask;
use crate::environment::{Environment, Job};
use crate::error::{Error, Result};
use crate::evolution::evaluator::{Evaluator, RowsView};
use crate::evolution::genome::{Bounds, Individual};
use crate::evolution::nsga2;
use crate::evolution::operators::Operators;
use crate::evolution::popmatrix::{PopMatrix, WaveArena};
use crate::exec::ThreadPool;
use crate::util::json::Json;
use crate::util::Rng;

/// The `NSGA2(...)` configuration of Listing 4/5.
#[derive(Clone)]
pub struct Nsga2Config {
    /// Population size kept by environmental selection.
    pub mu: usize,
    /// Search-space bounds (genome variables + ranges).
    pub bounds: Bounds,
    /// Objective variable names (for result files/hooks).
    pub objectives: Vec<String>,
    /// Fraction of each batch spent re-evaluating current individuals
    /// (`reevaluate = 0.01`): kills over-evaluated lucky individuals.
    pub reevaluate: f64,
    /// Variation operators.
    pub operators: Operators,
}

impl Nsga2Config {
    pub fn new(
        mu: usize,
        inputs: &[(&Val<f64>, f64, f64)],
        objectives: &[&Val<f64>],
        reevaluate: f64,
    ) -> Result<Self> {
        Ok(Nsga2Config {
            mu,
            bounds: Bounds::new(inputs)?,
            objectives: objectives.iter().map(|v| v.name().to_string()).collect(),
            reevaluate,
            operators: Operators::default(),
        })
    }
}

/// Outcome of an evolution run.
#[derive(Debug, Clone)]
pub struct EvolutionResult {
    pub population: Vec<Individual>,
    pub pareto_front: Vec<Individual>,
    pub evaluations: u64,
    pub generations: u32,
    /// Virtual makespan of the whole optimisation on the environment.
    pub virtual_makespan: f64,
}

/// Wrap an [`Evaluator`] as a DSL task so evaluation jobs flow through the
/// same environments as any other workload.
///
/// The closure routes through [`Evaluator::evaluate_batch`] (a batch of
/// one) so every engine sits on the batch interface: a pooled or vmapped
/// evaluator applies its machinery uniformly, and plain evaluators fall
/// back to `evaluate` via the default implementation.
pub fn eval_task(
    evaluator: Arc<dyn Evaluator>,
    bounds: &Bounds,
    objectives: &[String],
) -> Arc<ClosureTask> {
    let names = bounds.names.clone();
    let objective_names = objectives.to_vec();
    let cost = evaluator.nominal_cost_s();
    let seed_val: Val<u32> = Val::new("seed");
    let mut task = ClosureTask::new("evaluate", move |ctx: &Context| {
        let genome: Vec<f64> = names
            .iter()
            .map(|n| ctx.get(&Val::<f64>::new(n.clone())))
            .collect::<Result<_>>()?;
        let seed: u32 = ctx.get(&Val::<u32>::new("seed"))?;
        let objs = evaluator
            .evaluate_batch(&[(genome, seed)])?
            .pop()
            .ok_or_else(|| Error::Evolution("empty evaluation batch".into()))?;
        if objs.len() != objective_names.len() {
            return Err(Error::Evolution(format!(
                "evaluator returned {} objectives, config declares {}",
                objs.len(),
                objective_names.len()
            )));
        }
        let mut out = Context::new();
        for (name, v) in objective_names.iter().zip(objs) {
            out.set(&Val::<f64>::new(name.clone()), v);
        }
        Ok(out)
    })
    .cost(cost)
    .input(&seed_val);
    for n in &bounds.names {
        task = task.input(&Val::<f64>::new(n.clone()));
    }
    Arc::new(task)
}

/// The generational driver.
pub struct GenerationalGA {
    pub config: Nsga2Config,
    pub evaluator: Arc<dyn Evaluator>,
    /// Offspring per generation (= parallelism level, Listing 4).
    pub lambda: usize,
    /// Genomes per evaluation job (§Perf tentpole). 1 — the default, and
    /// the paper's shape — submits one environment job per genome; larger
    /// values pack each job with a whole chunk evaluated through
    /// [`Evaluator::evaluate_rows`], which is how a pooled or vmapped
    /// evaluator sees enough work to use a multicore machine. Virtual cost
    /// scales with the chunk, so simulated-environment accounting stays
    /// per-evaluation.
    pub eval_chunk: usize,
    /// Called after each generation with (generation, population matrix).
    pub on_generation: Option<Arc<dyn Fn(u32, &PopMatrix) + Send + Sync>>,
    /// Optional JSONL checkpoint stream: one `generation` record per
    /// generation, enabling `--resume` after a kill (§Distribution).
    pub journal: Option<Arc<Journal>>,
    /// Optional pool for the coordinator-side parallel stages: variation,
    /// crowding distance and the >2-objective dominance passes. Results
    /// are bit-identical with or without it (chunk → RNG-fork mapping is
    /// fixed); give it a pool distinct from any the environment executes
    /// jobs on.
    pub coordinator_pool: Option<Arc<ThreadPool>>,
}

impl GenerationalGA {
    pub fn new(config: Nsga2Config, evaluator: Arc<dyn Evaluator>, lambda: usize) -> Self {
        GenerationalGA {
            config,
            evaluator,
            lambda,
            eval_chunk: 1,
            on_generation: None,
            journal: None,
            coordinator_pool: None,
        }
    }

    /// Set the genomes-per-job packing for evaluation waves.
    pub fn eval_chunk(mut self, chunk: usize) -> Self {
        self.eval_chunk = chunk.max(1);
        self
    }

    /// Checkpoint every generation to `journal`.
    pub fn journal(mut self, journal: Arc<Journal>) -> Self {
        self.journal = Some(journal);
        self
    }

    /// Fan the coordinator-side stages (variation, crowding, general
    /// dominance) out over `pool`.
    pub fn coordinator_pool(mut self, pool: Arc<ThreadPool>) -> Self {
        self.coordinator_pool = Some(pool);
        self
    }

    pub fn on_generation(
        mut self,
        f: impl Fn(u32, &PopMatrix) + Send + Sync + 'static,
    ) -> Self {
        self.on_generation = Some(Arc::new(f));
        self
    }

    /// Submit one wave of genome rows to the environment and collect the
    /// objective rows back into `out`, packing `eval_chunk` genomes per
    /// job. Each job carries owned copies of its chunk (jobs must be
    /// `'static` to cross the environment boundary) and calls the
    /// evaluator's columnar path once. Returns the latest virtual end.
    fn submit_rows_wave(
        &self,
        env: &dyn Environment,
        genomes: &[f64],
        seeds: &[u32],
        out: &mut [f64],
        released_at: f64,
    ) -> Result<f64> {
        let dim = self.config.bounds.dim();
        let n_obj = self.config.objectives.len();
        let count = seeds.len();
        debug_assert_eq!(genomes.len(), count * dim);
        debug_assert_eq!(out.len(), count * n_obj);
        if count == 0 {
            return Ok(released_at);
        }
        if self.evaluator.objectives() != n_obj {
            return Err(Error::Evolution(format!(
                "evaluator produces {} objectives, config declares {n_obj}",
                self.evaluator.objectives()
            )));
        }
        let cost = self.evaluator.nominal_cost_s();
        let chunk_len = self.eval_chunk.max(1);

        type Slot = Arc<std::sync::Mutex<Option<Vec<f64>>>>;
        let mut submissions: Vec<(usize, usize, Slot, crate::environment::JobHandle)> =
            Vec::with_capacity(count.div_ceil(chunk_len));
        let mut lo = 0usize;
        while lo < count {
            let hi = (lo + chunk_len).min(count);
            let rows_n = hi - lo;
            let chunk_genomes = genomes[lo * dim..hi * dim].to_vec();
            let chunk_seeds = seeds[lo..hi].to_vec();
            let evaluator = Arc::clone(&self.evaluator);
            let slot: Slot = Arc::new(std::sync::Mutex::new(None));
            let out_slot = Arc::clone(&slot);
            let task = ClosureTask::new("evaluate", move |_ctx: &Context| {
                let mut objs = vec![0.0; rows_n * n_obj];
                evaluator.evaluate_rows(
                    RowsView::new(&chunk_genomes, dim),
                    &chunk_seeds,
                    &mut objs,
                )?;
                *out_slot.lock().unwrap() = Some(objs);
                Ok(Context::new())
            })
            .cost(cost * rows_n as f64);
            let handle = env
                .submit(Job::new(Arc::new(task), Context::new()).released_at(released_at));
            submissions.push((lo, hi, slot, handle));
            lo = hi;
        }

        let mut latest = released_at;
        for (lo, hi, slot, handle) in submissions {
            let (_ctx, report) = handle.wait()?;
            latest = latest.max(report.virtual_end);
            let objs = slot.lock().unwrap().take().ok_or_else(|| {
                Error::Evolution("evaluation chunk produced no results".into())
            })?;
            out[lo * n_obj..hi * n_obj].copy_from_slice(&objs);
        }
        Ok(latest)
    }

    /// Evaluate matrix rows `first_row..` on the environment: seeds are
    /// drawn up front in row order (so results — and the RNG stream — are
    /// independent of the chunking), objectives land in the rows' own
    /// preallocated objective slots.
    fn evaluate_matrix_wave(
        &self,
        env: &dyn Environment,
        pop: &mut PopMatrix,
        first_row: usize,
        arena: &mut WaveArena,
        rng: &mut Rng,
        released_at: f64,
    ) -> Result<f64> {
        let count = pop.len() - first_row;
        arena.seeds.clear();
        for _ in 0..count {
            arena.seeds.push(rng.model_seed());
        }
        let (genome_rows, obj_rows) = pop.rows_split_mut(first_row);
        self.submit_rows_wave(env, genome_rows, &arena.seeds, obj_rows, released_at)
    }

    /// Re-evaluate a `reevaluate`-fraction sample of the parents and
    /// absorb the fresh objectives as running averages (Listing 4's
    /// `reevaluate = 0.01`). Returns `(evaluations spent, latest end)`;
    /// draws nothing from `rng` when the fraction rounds to zero.
    fn reevaluate_some(
        &self,
        env: &dyn Environment,
        pop: &mut PopMatrix,
        parents: usize,
        arena: &mut WaveArena,
        rng: &mut Rng,
        released_at: f64,
    ) -> Result<(u64, f64)> {
        let n_re = ((parents as f64) * self.config.reevaluate).round() as usize;
        if n_re == 0 {
            return Ok((0, released_at));
        }
        let n_obj = self.config.objectives.len();
        rng.sample_indices_into(parents, n_re, &mut arena.idx_buf);
        arena.genome_buf.clear();
        for &i in &arena.idx_buf {
            arena.genome_buf.extend_from_slice(pop.genome(i));
        }
        arena.seeds.clear();
        for _ in 0..n_re {
            arena.seeds.push(rng.model_seed());
        }
        arena.obj_buf.clear();
        arena.obj_buf.resize(n_re * n_obj, 0.0);
        let latest = self.submit_rows_wave(
            env,
            &arena.genome_buf,
            &arena.seeds,
            &mut arena.obj_buf,
            released_at,
        )?;
        for k in 0..n_re {
            let i = arena.idx_buf[k];
            pop.absorb_reevaluation(i, &arena.obj_buf[k * n_obj..(k + 1) * n_obj]);
        }
        Ok((n_re as u64, latest))
    }

    fn checkpoint(
        &self,
        generation: u32,
        evaluations: u64,
        clock: f64,
        rng: &Rng,
        population: &PopMatrix,
    ) -> Result<()> {
        if let Some(j) = &self.journal {
            j.append(&journal::generation_record_matrix(
                generation,
                evaluations,
                clock,
                rng,
                population,
            ))?;
        }
        Ok(())
    }

    /// Run `generations` synchronous generations on `env`.
    pub fn run(
        &self,
        env: &dyn Environment,
        generations: u32,
        seed: u64,
    ) -> Result<EvolutionResult> {
        self.run_resumable(env, generations, seed, None)
    }

    /// Run, optionally continuing from a journal checkpoint.
    ///
    /// With `resume: Some(state)` the run restores the checkpointed
    /// population, virtual clock, evaluation counter and RNG state, then
    /// continues at `state.generation + 1`. The configuration (`mu`,
    /// `lambda`, bounds, operators, evaluator) must match the original
    /// run — the journal stores the trajectory, not the configuration —
    /// and when it does, the resumed run's final population is
    /// bit-identical to an uninterrupted run with the same seed.
    pub fn run_resumable(
        &self,
        env: &dyn Environment,
        generations: u32,
        seed: u64,
        resume: Option<ResumeState>,
    ) -> Result<EvolutionResult> {
        let cfg = &self.config;
        let dim = cfg.bounds.dim();
        let n_obj = cfg.objectives.len();
        let pool = self.coordinator_pool.as_deref();
        let mut arena = WaveArena::default();
        let (mut rng, mut pop, mut clock, mut evaluations, first_gen) = match resume {
            Some(r) => {
                if let Some(j) = &self.journal {
                    j.append(&journal::run_start(
                        "calibrate-resume",
                        seed,
                        vec![(
                            "from_generation",
                            Json::Num(f64::from(r.generation)),
                        )],
                    ))?;
                }
                let pop = PopMatrix::from_individuals(&r.population, dim, n_obj)?;
                (r.rng, pop, r.clock, r.evaluations, r.generation + 1)
            }
            None => {
                if let Some(j) = &self.journal {
                    j.append(&journal::run_start(
                        "calibrate",
                        seed,
                        vec![
                            ("mu", Json::Num(cfg.mu as f64)),
                            ("lambda", Json::Num(self.lambda as f64)),
                            ("generations", Json::Num(f64::from(generations))),
                        ],
                    ))?;
                }
                let mut rng = Rng::new(seed);
                // initial population: random genomes straight into rows
                let mut pop =
                    PopMatrix::with_capacity(dim, n_obj, cfg.mu + self.lambda);
                pop.set_rows(cfg.mu);
                for i in 0..cfg.mu {
                    cfg.bounds.random_into(&mut rng, pop.genome_mut(i));
                }
                let clock =
                    self.evaluate_matrix_wave(env, &mut pop, 0, &mut arena, &mut rng, 0.0)?;
                let evaluations = pop.len() as u64;
                self.checkpoint(0, evaluations, clock, &rng, &pop)?;
                (rng, pop, clock, evaluations, 1)
            }
        };

        for generation in first_gen..=generations {
            // breed lambda offspring into the matrix tail: tournament on
            // the parents' (rank, crowding), SBX + mutation written in
            // place, one deterministic RNG fork per variation chunk
            arena.rank_crowd(&pop, pool);
            let parents = pop.len();
            pop.set_rows(parents + self.lambda);
            arena.breed_into(&mut pop, parents, &cfg.operators, &cfg.bounds, &mut rng, pool);
            let t1 =
                self.evaluate_matrix_wave(env, &mut pop, parents, &mut arena, &mut rng, clock)?;
            evaluations += self.lambda as u64;
            clock = t1;

            // reevaluate a fraction of the current population (Listing 4's
            // `reevaluate = 0.01`)
            let (n_re, t2) =
                self.reevaluate_some(env, &mut pop, parents, &mut arena, &mut rng, clock)?;
            evaluations += n_re;
            clock = t2;

            // elitist environmental selection, compacting in place
            arena.select(&mut pop, cfg.mu, pool);

            self.checkpoint(generation, evaluations, clock, &rng, &pop)?;
            if let Some(cb) = &self.on_generation {
                cb(generation, &pop);
            }
        }

        if let Some(j) = &self.journal {
            j.append(&journal::env_stats_record(env.name(), &env.stats()))?;
            j.append(&journal::run_end(evaluations, clock))?;
        }

        let population = pop.to_individuals();
        let pareto_front = nsga2::pareto_front(&population);
        Ok(EvolutionResult {
            population,
            pareto_front,
            evaluations,
            generations,
            virtual_makespan: clock,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::val_f64;
    use crate::environment::local::LocalEnvironment;
    use crate::evolution::evaluator::Zdt1Evaluator;

    fn zdt1_config(mu: usize) -> Nsga2Config {
        let x0 = val_f64("x0");
        let x1 = val_f64("x1");
        let x2 = val_f64("x2");
        let f1 = val_f64("f1");
        let f2 = val_f64("f2");
        Nsga2Config::new(
            mu,
            &[(&x0, 0.0, 1.0), (&x1, 0.0, 1.0), (&x2, 0.0, 1.0)],
            &[&f1, &f2],
            0.0,
        )
        .unwrap()
    }

    #[test]
    fn converges_towards_zdt1_front() {
        let env = LocalEnvironment::new(4);
        let ga = GenerationalGA::new(
            zdt1_config(16),
            Arc::new(Zdt1Evaluator { dim: 3 }),
            16,
        );
        let result = ga.run(&env, 30, 7).unwrap();
        assert_eq!(result.population.len(), 16);
        assert!(result.evaluations >= 16 * 31);
        // mean distance of front points to the true front f2 = 1 - sqrt(f1)
        let err: f64 = result
            .pareto_front
            .iter()
            .map(|i| (i.objectives[1] - (1.0 - i.objectives[0].sqrt())).abs())
            .sum::<f64>()
            / result.pareto_front.len() as f64;
        assert!(err < 0.35, "front error {err}");
    }

    #[test]
    fn deterministic_under_seed() {
        let env = LocalEnvironment::new(2);
        let ga = GenerationalGA::new(zdt1_config(8), Arc::new(Zdt1Evaluator { dim: 3 }), 8);
        let a = ga.run(&env, 5, 11).unwrap();
        let b = ga.run(&env, 5, 11).unwrap();
        let objs = |r: &EvolutionResult| -> Vec<Vec<f64>> {
            r.population.iter().map(|i| i.objectives.clone()).collect()
        };
        assert_eq!(objs(&a), objs(&b));
    }

    #[test]
    fn chunked_wave_matches_per_genome_jobs() {
        // the §Perf batch path must not change results: chunk size and
        // evaluator pooling are pure execution-shape knobs
        let objs = |r: &EvolutionResult| -> Vec<Vec<f64>> {
            r.population.iter().map(|i| i.objectives.clone()).collect()
        };
        let env = LocalEnvironment::new(4);
        let per_genome =
            GenerationalGA::new(zdt1_config(8), Arc::new(Zdt1Evaluator { dim: 3 }), 8);
        let baseline = per_genome.run(&env, 5, 11).unwrap();
        for chunk in [3, 8, 64] {
            let chunked =
                GenerationalGA::new(zdt1_config(8), Arc::new(Zdt1Evaluator { dim: 3 }), 8)
                    .eval_chunk(chunk);
            let got = chunked.run(&env, 5, 11).unwrap();
            assert_eq!(objs(&baseline), objs(&got), "chunk {chunk} diverged");
        }
        let pooled = GenerationalGA::new(
            zdt1_config(8),
            Arc::new(crate::evolution::evaluator::PooledEvaluator::with_threads(
                Arc::new(Zdt1Evaluator { dim: 3 }),
                3,
            )),
            8,
        )
        .eval_chunk(8);
        let got = pooled.run(&env, 5, 11).unwrap();
        assert_eq!(objs(&baseline), objs(&got), "pooled evaluator diverged");
    }

    #[test]
    fn coordinator_pool_does_not_change_the_trajectory() {
        // parallel variation/crowding is an execution-shape knob only:
        // per-chunk RNG forks are assigned by fixed chunk boundaries
        let objs = |r: &EvolutionResult| -> Vec<Vec<f64>> {
            r.population.iter().map(|i| i.objectives.clone()).collect()
        };
        let env = LocalEnvironment::new(2);
        let serial =
            GenerationalGA::new(zdt1_config(8), Arc::new(Zdt1Evaluator { dim: 3 }), 8);
        let baseline = serial.run(&env, 5, 13).unwrap();
        let pooled =
            GenerationalGA::new(zdt1_config(8), Arc::new(Zdt1Evaluator { dim: 3 }), 8)
                .coordinator_pool(Arc::new(ThreadPool::new(4)));
        let got = pooled.run(&env, 5, 13).unwrap();
        assert_eq!(objs(&baseline), objs(&got), "coordinator pool diverged");
    }

    #[test]
    fn generation_callback_fires() {
        let env = LocalEnvironment::new(2);
        let seen = Arc::new(std::sync::atomic::AtomicU32::new(0));
        let s2 = Arc::clone(&seen);
        let ga = GenerationalGA::new(zdt1_config(4), Arc::new(Zdt1Evaluator { dim: 3 }), 4)
            .on_generation(move |_, pop| {
                assert!(pop.len() <= 4);
                s2.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
            });
        ga.run(&env, 6, 1).unwrap();
        assert_eq!(seen.load(std::sync::atomic::Ordering::SeqCst), 6);
    }

    #[test]
    fn journaled_resume_is_bit_identical() {
        let tmp = std::env::temp_dir();
        let path_full = tmp.join(format!("molers-gen-full-{}.jsonl", std::process::id()));
        let path_cut = tmp.join(format!("molers-gen-cut-{}.jsonl", std::process::id()));
        let objs = |r: &EvolutionResult| -> Vec<Vec<f64>> {
            r.population.iter().map(|i| i.objectives.clone()).collect()
        };

        let env = LocalEnvironment::new(2);
        let mut cfg = zdt1_config(8);
        cfg.reevaluate = 0.25; // exercise the reevaluation path across resume
        let uninterrupted =
            GenerationalGA::new(cfg.clone(), Arc::new(Zdt1Evaluator { dim: 3 }), 8)
                .journal(Arc::new(Journal::create(&path_full).unwrap()));
        let full = uninterrupted.run(&env, 6, 17).unwrap();

        // "kill" after generation 3: run only the first half, journaled
        let first_half =
            GenerationalGA::new(cfg.clone(), Arc::new(Zdt1Evaluator { dim: 3 }), 8)
                .journal(Arc::new(Journal::create(&path_cut).unwrap()));
        first_half.run(&env, 3, 17).unwrap();

        // resume from the journal and finish the remaining generations
        let resume = journal::load_resume(&path_cut).unwrap().expect("checkpoint");
        assert_eq!(resume.generation, 3);
        let resumed_ga =
            GenerationalGA::new(cfg, Arc::new(Zdt1Evaluator { dim: 3 }), 8)
                .journal(Arc::new(Journal::append_to(&path_cut).unwrap()));
        let resumed = resumed_ga
            .run_resumable(&env, 6, 17, Some(resume))
            .unwrap();

        assert_eq!(
            objs(&full),
            objs(&resumed),
            "kill + resume must reproduce the uninterrupted trajectory"
        );
        assert_eq!(full.evaluations, resumed.evaluations);
        let _ = std::fs::remove_file(&path_full);
        let _ = std::fs::remove_file(&path_cut);
    }

    #[test]
    fn reevaluation_consumes_budget() {
        let env = LocalEnvironment::new(2);
        let mut cfg = zdt1_config(10);
        cfg.reevaluate = 0.5;
        let ga = GenerationalGA::new(cfg, Arc::new(Zdt1Evaluator { dim: 3 }), 10);
        let r = ga.run(&env, 4, 2).unwrap();
        // init 10 + 4*(10 offspring + 5 reevals)
        assert_eq!(r.evaluations, 10 + 4 * 15);
    }

    #[test]
    fn resume_rejects_mismatched_genome_shape() {
        let env = LocalEnvironment::new(1);
        let ga = GenerationalGA::new(zdt1_config(4), Arc::new(Zdt1Evaluator { dim: 3 }), 4);
        let bad = ResumeState {
            generation: 1,
            evaluations: 4,
            clock: 0.0,
            rng: Rng::new(1),
            population: vec![Individual::new(vec![0.5], vec![0.1, 0.2])],
        };
        assert!(ga.run_resumable(&env, 3, 1, Some(bad)).is_err());
    }
}
