//! `IslandSteadyGA(evolution, replicateModel)(islands, totalEvals, sample)`
//! — the island model of paper §4.6 and Listing 5.
//!
//! "Islands of population evolve for a while on a remote node. When an
//! island is finished, its final population is merged back into a global
//! archive. A new island is then generated until the termination criterion
//! is met." Each island is ONE remote job: its internal evaluations run on
//! the node (locally here — the evaluator is called in-process), so a
//! high-latency environment pays brokering costs once per island instead
//! of once per evaluation. That asymmetry is exactly what bench
//! `a2_island_vs_generational` measures.
//!
//! §Perf: the global archive and each island's internal population are
//! columnar [`PopMatrix`]es — sampling copies rows, merges append rows,
//! and truncation compacts in place through a per-island [`WaveArena`];
//! the per-evaluation `Vec<Individual>` rebuild of the AoS archive is
//! gone. Draw order is unchanged, so trajectories are bit-identical to
//! the AoS engine.

use std::sync::{Arc, Mutex};

use crate::broker::journal::{self, Journal};
use crate::core::Context;
use crate::dsl::task::ClosureTask;
use crate::environment::{Environment, Job, JobHandle};
use crate::error::Result;
use crate::evolution::evaluator::{Evaluator, RowsView};
use crate::evolution::generational::{EvolutionResult, Nsga2Config};
use crate::evolution::genome::Individual;
use crate::evolution::nsga2;
use crate::evolution::popmatrix::{PopMatrix, WaveArena};
use crate::util::json::Json;
use crate::util::Rng;

/// How many island merges between archive snapshots in the journal.
const ARCHIVE_SNAPSHOT_EVERY: u64 = 8;

/// Island-model configuration (Listing 5's
/// `IslandSteadyGA(evolution, replicateModel)(2000, 200000, 50)`).
#[derive(Clone)]
pub struct IslandConfig {
    /// Concurrent islands (2,000 in the paper).
    pub concurrent_islands: usize,
    /// Total evaluations across all islands (200,000 in the paper).
    pub total_evaluations: u64,
    /// Individuals sampled from the global archive per island (50).
    pub island_sample: usize,
    /// Evaluations one island performs before merging back. The paper ends
    /// islands on a 1 h walltime; with the ~36 s NetLogo evaluation that is
    /// ~100 evaluations, which is this knob's default.
    pub evals_per_island: u64,
}

impl Default for IslandConfig {
    fn default() -> Self {
        IslandConfig {
            concurrent_islands: 2000,
            total_evaluations: 200_000,
            island_sample: 50,
            evals_per_island: 100,
        }
    }
}

/// Global archive shared by all islands.
struct ArchiveState {
    population: PopMatrix,
    evaluations: u64,
    islands_completed: u64,
    /// Island ids already merged. A brokered environment may execute an
    /// island job more than once (failure re-route, speculative clone);
    /// the merge must land exactly once regardless.
    merged: std::collections::HashSet<u64>,
}

/// The island-model driver.
pub struct IslandSteadyGA {
    pub config: Nsga2Config,
    pub islands: IslandConfig,
    pub evaluator: Arc<dyn Evaluator>,
    /// Optional JSONL progress/snapshot stream (see [`journal`]).
    pub journal: Option<Arc<Journal>>,
    /// Archive + evaluations-done to continue from (journal `archive`
    /// record). Island runs are asynchronous, so resume is
    /// archive-faithful rather than bit-identical: the remaining budget
    /// continues from the checkpointed archive.
    pub resume: Option<(Vec<Individual>, u64)>,
}

impl IslandSteadyGA {
    pub fn new(
        config: Nsga2Config,
        islands: IslandConfig,
        evaluator: Arc<dyn Evaluator>,
    ) -> Self {
        IslandSteadyGA {
            config,
            islands,
            evaluator,
            journal: None,
            resume: None,
        }
    }

    /// Journal island merges and periodic archive snapshots.
    pub fn journal(mut self, journal: Arc<Journal>) -> Self {
        self.journal = Some(journal);
        self
    }

    /// Seed the archive from a journal snapshot and run only the
    /// remaining evaluation budget.
    pub fn resume_from(mut self, population: Vec<Individual>, evaluations: u64) -> Self {
        self.resume = Some((population, evaluations));
        self
    }

    /// One island's internal steady-state evolution, run to its evaluation
    /// budget. Pure function of (start population, rng) — executed inside
    /// the island's remote job, entirely on the columnar matrix.
    fn evolve_island(
        cfg: &Nsga2Config,
        evaluator: &dyn Evaluator,
        mut population: PopMatrix,
        budget: u64,
        rng: &mut Rng,
        arena: &mut WaveArena,
    ) -> Result<PopMatrix> {
        let dim = cfg.bounds.dim();
        let n_obj = cfg.objectives.len();

        // bootstrap: a fresh island draws random genomes until it can hold
        // a tournament; those evaluations are independent, so they go
        // through the evaluator's columnar batch path in one wave.
        // Genome/seed draws interleave exactly like the sequential loop
        // did, so the RNG stream — and hence the whole trajectory — is
        // unchanged.
        let bootstrap =
            (2usize.saturating_sub(population.len()) as u64).min(budget) as usize;
        let mut done: u64 = 0;
        if bootstrap > 0 {
            let first = population.len();
            population.set_rows(first + bootstrap);
            arena.seeds.clear();
            for i in 0..bootstrap {
                cfg.bounds.random_into(rng, population.genome_mut(first + i));
                arena.seeds.push(rng.model_seed());
            }
            let (genome_rows, obj_rows) = population.rows_split_mut(first);
            evaluator.evaluate_rows(
                RowsView::new(genome_rows, dim),
                &arena.seeds,
                obj_rows,
            )?;
            if population.len() > cfg.mu {
                arena.select(&mut population, cfg.mu, None);
            }
            done = bootstrap as u64;
        }

        for _ in done..budget {
            let genome = if population.len() < 2 {
                cfg.bounds.random(rng)
            } else {
                arena.rank_crowd(&population, None);
                let n = population.len();
                let a =
                    nsga2::tournament_idx(n, arena.nsga.rank(), arena.nsga.crowd(), rng);
                let b =
                    nsga2::tournament_idx(n, arena.nsga.rank(), arena.nsga.crowd(), rng);
                cfg.operators.breed(
                    population.genome(a),
                    population.genome(b),
                    &cfg.bounds,
                    rng,
                )
            };
            let seed = rng.model_seed();
            arena.obj_buf.clear();
            arena.obj_buf.resize(n_obj, 0.0);
            evaluator.evaluate_rows(
                RowsView::new(&genome, dim),
                &[seed],
                &mut arena.obj_buf,
            )?;
            population.push_row(&genome, &arena.obj_buf, 1);
            if population.len() > cfg.mu {
                arena.select(&mut population, cfg.mu, None);
            }
        }
        Ok(population)
    }

    /// Run the island model on `env`. Progress callback receives
    /// (islands completed, global evaluations).
    pub fn run(
        &self,
        env: &dyn Environment,
        seed: u64,
        on_island: Option<Arc<dyn Fn(u64, u64) + Send + Sync>>,
    ) -> Result<EvolutionResult> {
        let dim = self.config.bounds.dim();
        let n_obj = self.config.objectives.len();
        let mut rng = Rng::new(seed);
        let (start_population, evals_done) = match &self.resume {
            Some((pop, evals)) => {
                (PopMatrix::from_individuals(pop, dim, n_obj)?, *evals)
            }
            None => (PopMatrix::new(dim, n_obj), 0),
        };
        if let Some(j) = &self.journal {
            j.append(&journal::run_start(
                "island",
                seed,
                vec![
                    ("mu", Json::Num(self.config.mu as f64)),
                    (
                        "total_evaluations",
                        Json::Num(self.islands.total_evaluations as f64),
                    ),
                    ("resumed_evaluations", Json::Num(evals_done as f64)),
                ],
            ))?;
        }
        let archive = Arc::new(Mutex::new(ArchiveState {
            population: start_population,
            evaluations: evals_done,
            islands_completed: 0,
            merged: std::collections::HashSet::new(),
        }));
        let total_islands = self
            .islands
            .total_evaluations
            .saturating_sub(evals_done)
            .div_ceil(self.islands.evals_per_island);

        let make_island_task = |island_id: u64, island_rng: Rng| -> Arc<ClosureTask> {
            let cfg = self.config.clone();
            let evaluator = Arc::clone(&self.evaluator);
            let archive = Arc::clone(&archive);
            let sample = self.islands.island_sample;
            let budget = self.islands.evals_per_island;
            let on_island = on_island.clone();
            let rng_cell = Mutex::new(island_rng);
            Arc::new(
                ClosureTask::new("island", move |_ctx: &Context| {
                    let mut rng = rng_cell.lock().unwrap().clone();
                    let mut arena = WaveArena::default();
                    // sample the island's start population from the archive
                    let start: PopMatrix = {
                        let a = archive.lock().unwrap();
                        let mut m = PopMatrix::with_capacity(
                            cfg.bounds.dim(),
                            cfg.objectives.len(),
                            sample,
                        );
                        if !a.population.is_empty() {
                            let k = sample.min(a.population.len());
                            for i in rng.sample_indices(a.population.len(), k) {
                                m.push_row_from(&a.population, i);
                            }
                        }
                        m
                    };
                    let final_pop = Self::evolve_island(
                        &cfg,
                        evaluator.as_ref(),
                        start,
                        budget,
                        &mut rng,
                        &mut arena,
                    )?;
                    // merge back into the global archive — exactly once
                    // per island, even if a broker re-ran this job
                    // (failure re-route or speculative clone)
                    {
                        let mut a = archive.lock().unwrap();
                        if a.merged.insert(island_id) {
                            for i in 0..final_pop.len() {
                                a.population.push_row_from(&final_pop, i);
                            }
                            if a.population.len() > cfg.mu {
                                arena.select(&mut a.population, cfg.mu, None);
                            }
                            a.evaluations += budget;
                            a.islands_completed += 1;
                            if let Some(cb) = &on_island {
                                cb(a.islands_completed, a.evaluations);
                            }
                        }
                    }
                    Ok(Context::new())
                })
                // the island occupies its node for its whole budget
                .cost(self.evaluator.nominal_cost_s() * budget as f64),
            )
        };

        // rolling submission: keep `concurrent_islands` in flight
        let mut submitted: u64 = 0;
        let mut in_flight: Vec<JobHandle> = Vec::new();
        let mut virtual_makespan: f64 = 0.0;
        while submitted < total_islands
            && (in_flight.len() as u64) < self.islands.concurrent_islands as u64
        {
            in_flight.push(env.submit(Job::new(
                make_island_task(submitted, rng.fork()),
                Context::new(),
            )));
            submitted += 1;
        }
        while !in_flight.is_empty() {
            let mut idx = 0;
            let mut progressed = false;
            while idx < in_flight.len() {
                if let Some(result) = in_flight[idx].try_wait() {
                    let h = in_flight.swap_remove(idx);
                    drop(h);
                    let (_, report) = result?;
                    progressed = true;
                    virtual_makespan = virtual_makespan.max(report.virtual_end);
                    if let Some(j) = &self.journal {
                        // copy what the records need and release the
                        // archive before touching the disk — island
                        // merges on pool threads contend on this lock
                        let (islands_completed, evaluations, snapshot) = {
                            let a = archive.lock().unwrap();
                            let snapshot = (a.islands_completed
                                % ARCHIVE_SNAPSHOT_EVERY
                                == 0)
                                .then(|| a.population.clone());
                            (a.islands_completed, a.evaluations, snapshot)
                        };
                        j.append(&journal::island_record(
                            islands_completed,
                            evaluations,
                            report.virtual_end,
                        ))?;
                        if let Some(population) = snapshot {
                            j.append(&journal::archive_record_matrix(
                                evaluations,
                                &population,
                            ))?;
                        }
                    }
                    if submitted < total_islands {
                        // a new island is generated as soon as one returns
                        in_flight.push(env.submit(
                            Job::new(
                                make_island_task(submitted, rng.fork()),
                                Context::new(),
                            )
                            .released_at(report.virtual_end),
                        ));
                        submitted += 1;
                    }
                } else {
                    idx += 1;
                }
            }
            if !progressed && !in_flight.is_empty() {
                std::thread::sleep(std::time::Duration::from_micros(200));
            }
        }

        let state = Arc::try_unwrap(archive)
            .map_err(|_| crate::error::Error::Evolution("archive still shared".into()))?
            .into_inner()
            .unwrap();
        if let Some(j) = &self.journal {
            j.append(&journal::archive_record_matrix(
                state.evaluations,
                &state.population,
            ))?;
            j.append(&journal::env_stats_record(env.name(), &env.stats()))?;
            j.append(&journal::run_end(state.evaluations, virtual_makespan))?;
        }
        let population = state.population.to_individuals();
        let pareto_front = nsga2::pareto_front(&population);
        Ok(EvolutionResult {
            population,
            pareto_front,
            evaluations: state.evaluations,
            generations: state.islands_completed as u32,
            virtual_makespan,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::val_f64;
    use crate::environment::local::LocalEnvironment;
    use crate::evolution::evaluator::{CountingEvaluator, Zdt1Evaluator};

    fn config(mu: usize) -> Nsga2Config {
        let x0 = val_f64("x0");
        let x1 = val_f64("x1");
        let f1 = val_f64("f1");
        let f2 = val_f64("f2");
        Nsga2Config::new(mu, &[(&x0, 0.0, 1.0), (&x1, 0.0, 1.0)], &[&f1, &f2], 0.0)
            .unwrap()
    }

    #[test]
    fn completes_all_islands_and_counts_evaluations() {
        let env = LocalEnvironment::new(4);
        let counting = Arc::new(CountingEvaluator::new(Zdt1Evaluator { dim: 2 }));
        let ga = IslandSteadyGA::new(
            config(20),
            IslandConfig {
                concurrent_islands: 4,
                total_evaluations: 200,
                island_sample: 10,
                evals_per_island: 25,
            },
            Arc::clone(&counting) as _,
        );
        let r = ga.run(&env, 1, None).unwrap();
        assert_eq!(r.evaluations, 200);
        assert_eq!(counting.count(), 200);
        assert_eq!(r.generations, 8); // 200/25 islands
        assert!(r.population.len() <= 20);
    }

    #[test]
    fn archive_improves_over_time() {
        let env = LocalEnvironment::new(4);
        let ga = IslandSteadyGA::new(
            config(24),
            IslandConfig {
                concurrent_islands: 3,
                total_evaluations: 600,
                island_sample: 12,
                evals_per_island: 50,
            },
            Arc::new(Zdt1Evaluator { dim: 2 }),
        );
        let r = ga.run(&env, 2, None).unwrap();
        let err: f64 = r
            .pareto_front
            .iter()
            .map(|i| (i.objectives[1] - (1.0 - i.objectives[0].sqrt())).abs())
            .sum::<f64>()
            / r.pareto_front.len() as f64;
        assert!(err < 0.4, "front error {err}");
    }

    #[test]
    fn speculative_broker_does_not_double_merge_islands() {
        use crate::broker::{Broker, RoundRobin, SpeculationConfig};
        use crate::environment::local::LocalEnvironment as Local;
        use crate::exec::ThreadPool;

        // a broker tuned to clone virtually every job: island tasks get
        // re-executed, and the archive must still merge each island
        // exactly once
        let pool = Arc::new(ThreadPool::new(2));
        let broker = Broker::builder("spec")
            .backend(Arc::new(Local::with_pool(Arc::clone(&pool))), 2)
            .backend(Arc::new(Local::with_pool(Arc::clone(&pool))), 2)
            .policy(Box::new(RoundRobin::new()))
            .speculation(SpeculationConfig {
                quantile: 0.0,
                min_samples: 1,
            })
            .build()
            .unwrap();
        let counting = Arc::new(CountingEvaluator::new(Zdt1Evaluator { dim: 2 }));
        let ga = IslandSteadyGA::new(
            config(10),
            IslandConfig {
                concurrent_islands: 2,
                total_evaluations: 100,
                island_sample: 5,
                evals_per_island: 25,
            },
            Arc::clone(&counting) as _,
        );
        let r = ga.run(&broker, 4, None).unwrap();
        assert_eq!(
            r.evaluations, 100,
            "speculative clones must not double-count island merges"
        );
        assert_eq!(r.generations, 4);
        assert!(
            counting.count() >= 100,
            "clones do re-evaluate; only the merge is guarded"
        );
    }

    #[test]
    fn journaled_island_run_resumes_remaining_budget() {
        let path = std::env::temp_dir()
            .join(format!("molers-island-{}.jsonl", std::process::id()));
        let env = LocalEnvironment::new(2);
        let islands = IslandConfig {
            concurrent_islands: 2,
            total_evaluations: 100,
            island_sample: 5,
            evals_per_island: 25,
        };
        let ga = IslandSteadyGA::new(
            config(10),
            islands.clone(),
            Arc::new(Zdt1Evaluator { dim: 2 }),
        )
        .journal(Arc::new(Journal::create(&path).unwrap()));
        let r = ga.run(&env, 5, None).unwrap();
        assert_eq!(r.evaluations, 100);

        // the journal holds a final archive snapshot; treat it as the
        // state of a killed longer run and continue to a 200-eval budget
        let records = Journal::load(&path).unwrap();
        let (pop, evals) = journal::island_resume(&records).expect("archive snapshot");
        assert_eq!(evals, 100);
        assert!(!pop.is_empty());
        let resumed = IslandSteadyGA::new(
            config(10),
            IslandConfig {
                total_evaluations: 200,
                ..islands
            },
            Arc::new(Zdt1Evaluator { dim: 2 }),
        )
        .resume_from(pop, evals)
        .run(&env, 6, None)
        .unwrap();
        assert_eq!(resumed.evaluations, 200, "resume counts prior evaluations");
        assert_eq!(resumed.generations, 4, "only the remaining 100/25 islands ran");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn island_callback_reports_progress() {
        let env = LocalEnvironment::new(2);
        let seen = Arc::new(std::sync::atomic::AtomicU64::new(0));
        let s = Arc::clone(&seen);
        let ga = IslandSteadyGA::new(
            config(10),
            IslandConfig {
                concurrent_islands: 2,
                total_evaluations: 60,
                island_sample: 5,
                evals_per_island: 20,
            },
            Arc::new(Zdt1Evaluator { dim: 2 }),
        );
        ga.run(
            &env,
            3,
            Some(Arc::new(move |islands, _| {
                s.store(islands, std::sync::atomic::Ordering::SeqCst);
            })),
        )
        .unwrap();
        assert_eq!(seen.load(std::sync::atomic::Ordering::SeqCst), 3);
    }
}
