//! `IslandSteadyGA(evolution, replicateModel)(islands, totalEvals, sample)`
//! — the island model of paper §4.6 and Listing 5.
//!
//! "Islands of population evolve for a while on a remote node. When an
//! island is finished, its final population is merged back into a global
//! archive. A new island is then generated until the termination criterion
//! is met." Each island is ONE remote job: its internal evaluations run on
//! the node (locally here — the evaluator is called in-process), so a
//! high-latency environment pays brokering costs once per island instead
//! of once per evaluation. That asymmetry is exactly what bench
//! `a2_island_vs_generational` measures.

use std::sync::{Arc, Mutex};

use crate::core::Context;
use crate::dsl::task::ClosureTask;
use crate::environment::{Environment, Job, JobHandle};
use crate::error::Result;
use crate::evolution::evaluator::Evaluator;
use crate::evolution::generational::{EvolutionResult, Nsga2Config};
use crate::evolution::genome::Individual;
use crate::evolution::nsga2;
use crate::evolution::operators::Operators;
use crate::util::Rng;

/// Island-model configuration (Listing 5's
/// `IslandSteadyGA(evolution, replicateModel)(2000, 200000, 50)`).
#[derive(Clone)]
pub struct IslandConfig {
    /// Concurrent islands (2,000 in the paper).
    pub concurrent_islands: usize,
    /// Total evaluations across all islands (200,000 in the paper).
    pub total_evaluations: u64,
    /// Individuals sampled from the global archive per island (50).
    pub island_sample: usize,
    /// Evaluations one island performs before merging back. The paper ends
    /// islands on a 1 h walltime; with the ~36 s NetLogo evaluation that is
    /// ~100 evaluations, which is this knob's default.
    pub evals_per_island: u64,
}

impl Default for IslandConfig {
    fn default() -> Self {
        IslandConfig {
            concurrent_islands: 2000,
            total_evaluations: 200_000,
            island_sample: 50,
            evals_per_island: 100,
        }
    }
}

/// Global archive shared by all islands.
struct ArchiveState {
    population: Vec<Individual>,
    evaluations: u64,
    islands_completed: u64,
}

/// The island-model driver.
pub struct IslandSteadyGA {
    pub config: Nsga2Config,
    pub islands: IslandConfig,
    pub evaluator: Arc<dyn Evaluator>,
}

impl IslandSteadyGA {
    pub fn new(
        config: Nsga2Config,
        islands: IslandConfig,
        evaluator: Arc<dyn Evaluator>,
    ) -> Self {
        IslandSteadyGA {
            config,
            islands,
            evaluator,
        }
    }

    /// One island's internal steady-state evolution, run to its evaluation
    /// budget. Pure function of (start population, rng) — executed inside
    /// the island's remote job.
    fn evolve_island(
        cfg: &Nsga2Config,
        evaluator: &dyn Evaluator,
        mut population: Vec<Individual>,
        budget: u64,
        rng: &mut Rng,
    ) -> Result<Vec<Individual>> {
        let ops: &Operators = &cfg.operators;

        // bootstrap: a fresh island draws random genomes until it can hold
        // a tournament; those evaluations are independent, so they go
        // through the evaluator's batch path in one wave. Genome/seed
        // draws interleave exactly like the sequential loop did, so the
        // RNG stream — and hence the whole trajectory — is unchanged.
        let bootstrap =
            (2usize.saturating_sub(population.len()) as u64).min(budget) as usize;
        let mut done: u64 = 0;
        if bootstrap > 0 {
            let jobs: Vec<(Vec<f64>, u32)> = (0..bootstrap)
                .map(|_| {
                    let genome = cfg.bounds.random(rng);
                    let seed = rng.model_seed();
                    (genome, seed)
                })
                .collect();
            for (job, objectives) in jobs.iter().zip(evaluator.evaluate_batch(&jobs)?) {
                population.push(Individual::new(job.0.clone(), objectives));
            }
            if population.len() > cfg.mu {
                population = nsga2::select(population, cfg.mu);
            }
            done = bootstrap as u64;
        }

        for _ in done..budget {
            let genome = if population.len() < 2 {
                cfg.bounds.random(rng)
            } else {
                let (rank, crowd) = nsga2::rank_and_crowding(&population);
                let a = nsga2::tournament(&population, &rank, &crowd, rng);
                let b = nsga2::tournament(&population, &rank, &crowd, rng);
                ops.breed(&a.genome, &b.genome, &cfg.bounds, rng)
            };
            let objectives = evaluator.evaluate(&genome, rng.model_seed())?;
            population.push(Individual::new(genome, objectives));
            if population.len() > cfg.mu {
                population = nsga2::select(population, cfg.mu);
            }
        }
        Ok(population)
    }

    /// Run the island model on `env`. Progress callback receives
    /// (islands completed, global evaluations).
    pub fn run(
        &self,
        env: &dyn Environment,
        seed: u64,
        on_island: Option<Arc<dyn Fn(u64, u64) + Send + Sync>>,
    ) -> Result<EvolutionResult> {
        let mut rng = Rng::new(seed);
        let archive = Arc::new(Mutex::new(ArchiveState {
            population: Vec::new(),
            evaluations: 0,
            islands_completed: 0,
        }));
        let total_islands = self
            .islands
            .total_evaluations
            .div_ceil(self.islands.evals_per_island);

        let make_island_task = |island_rng: Rng| -> Arc<ClosureTask> {
            let cfg = self.config.clone();
            let evaluator = Arc::clone(&self.evaluator);
            let archive = Arc::clone(&archive);
            let sample = self.islands.island_sample;
            let budget = self.islands.evals_per_island;
            let on_island = on_island.clone();
            let rng_cell = Mutex::new(island_rng);
            Arc::new(
                ClosureTask::new("island", move |_ctx: &Context| {
                    let mut rng = rng_cell.lock().unwrap().clone();
                    // sample the island's start population from the archive
                    let start: Vec<Individual> = {
                        let a = archive.lock().unwrap();
                        if a.population.is_empty() {
                            Vec::new()
                        } else {
                            let k = sample.min(a.population.len());
                            rng.sample_indices(a.population.len(), k)
                                .into_iter()
                                .map(|i| a.population[i].clone())
                                .collect()
                        }
                    };
                    let final_pop =
                        Self::evolve_island(&cfg, evaluator.as_ref(), start, budget, &mut rng)?;
                    // merge back into the global archive
                    {
                        let mut a = archive.lock().unwrap();
                        a.population.extend(final_pop);
                        if a.population.len() > cfg.mu {
                            let pop = std::mem::take(&mut a.population);
                            a.population = nsga2::select(pop, cfg.mu);
                        }
                        a.evaluations += budget;
                        a.islands_completed += 1;
                        if let Some(cb) = &on_island {
                            cb(a.islands_completed, a.evaluations);
                        }
                    }
                    Ok(Context::new())
                })
                // the island occupies its node for its whole budget
                .cost(self.evaluator.nominal_cost_s() * budget as f64),
            )
        };

        // rolling submission: keep `concurrent_islands` in flight
        let mut submitted: u64 = 0;
        let mut in_flight: Vec<JobHandle> = Vec::new();
        let mut virtual_makespan: f64 = 0.0;
        while submitted < total_islands
            && (in_flight.len() as u64) < self.islands.concurrent_islands as u64
        {
            in_flight.push(env.submit(Job::new(make_island_task(rng.fork()), Context::new())));
            submitted += 1;
        }
        while !in_flight.is_empty() {
            let mut idx = 0;
            let mut progressed = false;
            while idx < in_flight.len() {
                if let Some(result) = in_flight[idx].try_wait() {
                    let h = in_flight.swap_remove(idx);
                    drop(h);
                    let (_, report) = result?;
                    progressed = true;
                    virtual_makespan = virtual_makespan.max(report.virtual_end);
                    if submitted < total_islands {
                        // a new island is generated as soon as one returns
                        in_flight.push(env.submit(
                            Job::new(make_island_task(rng.fork()), Context::new())
                                .released_at(report.virtual_end),
                        ));
                        submitted += 1;
                    }
                } else {
                    idx += 1;
                }
            }
            if !progressed && !in_flight.is_empty() {
                std::thread::sleep(std::time::Duration::from_micros(200));
            }
        }

        let state = Arc::try_unwrap(archive)
            .map_err(|_| crate::error::Error::Evolution("archive still shared".into()))?
            .into_inner()
            .unwrap();
        let pareto_front = nsga2::pareto_front(&state.population);
        Ok(EvolutionResult {
            population: state.population,
            pareto_front,
            evaluations: state.evaluations,
            generations: state.islands_completed as u32,
            virtual_makespan,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::val_f64;
    use crate::environment::local::LocalEnvironment;
    use crate::evolution::evaluator::{CountingEvaluator, Zdt1Evaluator};

    fn config(mu: usize) -> Nsga2Config {
        let x0 = val_f64("x0");
        let x1 = val_f64("x1");
        let f1 = val_f64("f1");
        let f2 = val_f64("f2");
        Nsga2Config::new(mu, &[(&x0, 0.0, 1.0), (&x1, 0.0, 1.0)], &[&f1, &f2], 0.0)
            .unwrap()
    }

    #[test]
    fn completes_all_islands_and_counts_evaluations() {
        let env = LocalEnvironment::new(4);
        let counting = Arc::new(CountingEvaluator::new(Zdt1Evaluator { dim: 2 }));
        let ga = IslandSteadyGA::new(
            config(20),
            IslandConfig {
                concurrent_islands: 4,
                total_evaluations: 200,
                island_sample: 10,
                evals_per_island: 25,
            },
            Arc::clone(&counting) as _,
        );
        let r = ga.run(&env, 1, None).unwrap();
        assert_eq!(r.evaluations, 200);
        assert_eq!(counting.count(), 200);
        assert_eq!(r.generations, 8); // 200/25 islands
        assert!(r.population.len() <= 20);
    }

    #[test]
    fn archive_improves_over_time() {
        let env = LocalEnvironment::new(4);
        let ga = IslandSteadyGA::new(
            config(24),
            IslandConfig {
                concurrent_islands: 3,
                total_evaluations: 600,
                island_sample: 12,
                evals_per_island: 50,
            },
            Arc::new(Zdt1Evaluator { dim: 2 }),
        );
        let r = ga.run(&env, 2, None).unwrap();
        let err: f64 = r
            .pareto_front
            .iter()
            .map(|i| (i.objectives[1] - (1.0 - i.objectives[0].sqrt())).abs())
            .sum::<f64>()
            / r.pareto_front.len() as f64;
        assert!(err < 0.4, "front error {err}");
    }

    #[test]
    fn island_callback_reports_progress() {
        let env = LocalEnvironment::new(2);
        let seen = Arc::new(std::sync::atomic::AtomicU64::new(0));
        let s = Arc::clone(&seen);
        let ga = IslandSteadyGA::new(
            config(10),
            IslandConfig {
                concurrent_islands: 2,
                total_evaluations: 60,
                island_sample: 5,
                evals_per_island: 20,
            },
            Arc::new(Zdt1Evaluator { dim: 2 }),
        );
        ga.run(
            &env,
            3,
            Some(Arc::new(move |islands, _| {
                s.store(islands, std::sync::atomic::Ordering::SeqCst);
            })),
        )
        .unwrap();
        assert_eq!(seen.load(std::sync::atomic::Ordering::SeqCst), 3);
    }
}
