//! Fitness evaluation: the bridge between the GA coordinator and the
//! model (paper §4.2's fitness function).
//!
//! Implementations:
//! * [`crate::runtime::PjrtEvaluator`] — the production path: the AOT
//!   JAX+Pallas ant model via PJRT;
//! * [`AntSimEvaluator`] — the pure-Rust twin (no artifacts needed);
//! * [`Zdt1Evaluator`] / [`SphereEvaluator`] — analytic benchmarks to test
//!   GA machinery against known Pareto fronts;
//! * [`PooledEvaluator`] — fans `evaluate_batch` out over an
//!   [`crate::exec::ThreadPool`] with deterministic result ordering (§Perf
//!   tentpole: a multicore coordinator must actually use its cores);
//! * [`ReplicatedEvaluator`] — wraps any evaluator with n-seed replication
//!   and a statistical descriptor (the paper's `replicateModel`); its
//!   batch path flattens all genomes × seeds into one inner batch so the
//!   pooled/vmapped layers see the full fan-out.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::error::{Error, Result};
use crate::exec::ThreadPool;
use crate::sim::ants::{evaluate as ant_evaluate, AntParams};
use crate::util::stats::Descriptor;

/// Maps a genome (plus a seed for stochastic models) to minimised
/// objective values.
pub trait Evaluator: Send + Sync {
    /// Number of objectives produced.
    fn objectives(&self) -> usize;

    /// Evaluate one genome under one seed.
    fn evaluate(&self, genome: &[f64], seed: u32) -> Result<Vec<f64>>;

    /// Batch evaluation; overridden by the PJRT evaluator to use the
    /// vmapped artifacts. The default loops.
    fn evaluate_batch(&self, jobs: &[(Vec<f64>, u32)]) -> Result<Vec<Vec<f64>>> {
        jobs.iter()
            .map(|(g, s)| self.evaluate(g, *s))
            .collect()
    }

    /// Nominal cost of one evaluation in remote core-seconds — feeds the
    /// environments' virtual clocks. The NetLogo ant run the paper
    /// distributes costs ~36 s on a 2015 grid core (1000 ticks).
    fn nominal_cost_s(&self) -> f64 {
        36.0
    }
}

/// Ant model via the pure-Rust twin; genome = (diffusion, evaporation),
/// population fixed at the paper's 125 (§4.2 optimises the two rates).
pub struct AntSimEvaluator {
    pub population: f64,
    pub max_ticks: u32,
}

impl AntSimEvaluator {
    pub fn new() -> Self {
        AntSimEvaluator {
            population: 125.0,
            max_ticks: 1000,
        }
    }

    /// A faster, lower-fidelity setting for tests and quick demos.
    pub fn fast() -> Self {
        AntSimEvaluator {
            population: 125.0,
            max_ticks: 250,
        }
    }
}

impl Default for AntSimEvaluator {
    fn default() -> Self {
        Self::new()
    }
}

impl Evaluator for AntSimEvaluator {
    fn objectives(&self) -> usize {
        3
    }

    fn evaluate(&self, genome: &[f64], seed: u32) -> Result<Vec<f64>> {
        let params = AntParams {
            population: self.population,
            diffusion_rate: genome.first().copied().unwrap_or(50.0),
            evaporation_rate: genome.get(1).copied().unwrap_or(50.0),
        };
        Ok(ant_evaluate(params, u64::from(seed), self.max_ticks).to_vec())
    }

    fn nominal_cost_s(&self) -> f64 {
        // scale the 36 s/1000-tick reference to this configuration
        36.0 * f64::from(self.max_ticks) / 1000.0
    }
}

/// ZDT1: two-objective benchmark with known Pareto front
/// (f2 = 1 - sqrt(f1) at g = 1). Genome in [0, 1]^n.
pub struct Zdt1Evaluator {
    pub dim: usize,
}

impl Evaluator for Zdt1Evaluator {
    fn objectives(&self) -> usize {
        2
    }

    fn evaluate(&self, genome: &[f64], _seed: u32) -> Result<Vec<f64>> {
        let f1 = genome[0];
        let g = 1.0
            + 9.0 * genome[1..].iter().sum::<f64>() / (self.dim as f64 - 1.0).max(1.0);
        let f2 = g * (1.0 - (f1 / g).sqrt());
        Ok(vec![f1, f2])
    }

    fn nominal_cost_s(&self) -> f64 {
        1.0
    }
}

/// Single-objective sphere with optional seed noise — for convergence and
/// replication tests.
pub struct SphereEvaluator {
    pub noise: f64,
}

impl Evaluator for SphereEvaluator {
    fn objectives(&self) -> usize {
        1
    }

    fn evaluate(&self, genome: &[f64], seed: u32) -> Result<Vec<f64>> {
        let base: f64 = genome.iter().map(|x| x * x).sum();
        // deterministic per-seed noise
        let mut s = u64::from(seed);
        let noise =
            (crate::util::rng::splitmix64(&mut s) as f64 / u64::MAX as f64 - 0.5)
                * 2.0
                * self.noise;
        Ok(vec![base + noise])
    }

    fn nominal_cost_s(&self) -> f64 {
        1.0
    }
}

/// Parallel batch evaluation over an [`exec::ThreadPool`](ThreadPool):
/// jobs are split into per-worker chunks, each chunk runs the inner
/// evaluator's own `evaluate_batch` (so PJRT vmapping composes), and the
/// results are reassembled **in submission order** — callers observe
/// exactly the serial semantics, faster.
///
/// A panic inside one evaluation surfaces as an `Err` from the batch; the
/// pool itself is unaffected (workers catch unwinds) and stays usable.
///
/// Deadlock note: `evaluate_batch` *blocks* until its chunks finish. Do
/// not hand it the same pool an environment executes jobs on — an
/// environment worker waiting for chunks that queue behind other blocked
/// workers can stall the whole pool. Give the evaluator its own pool
/// ([`Self::with_threads`] / [`Self::machine_sized`]).
pub struct PooledEvaluator {
    pub inner: Arc<dyn Evaluator>,
    pool: Arc<ThreadPool>,
}

impl PooledEvaluator {
    /// Share an existing pool (the usual case: one pool per machine).
    pub fn new(inner: Arc<dyn Evaluator>, pool: Arc<ThreadPool>) -> Self {
        PooledEvaluator { inner, pool }
    }

    /// Own a dedicated pool of `threads` workers.
    pub fn with_threads(inner: Arc<dyn Evaluator>, threads: usize) -> Self {
        Self::new(inner, Arc::new(ThreadPool::new(threads)))
    }

    /// Pool sized to the machine (leaving one core for the coordinator).
    pub fn machine_sized(inner: Arc<dyn Evaluator>) -> Self {
        Self::new(inner, Arc::new(ThreadPool::default_size()))
    }

    pub fn threads(&self) -> usize {
        self.pool.threads()
    }
}

impl Evaluator for PooledEvaluator {
    fn objectives(&self) -> usize {
        self.inner.objectives()
    }

    fn evaluate(&self, genome: &[f64], seed: u32) -> Result<Vec<f64>> {
        // a single evaluation gains nothing from a worker round-trip
        self.inner.evaluate(genome, seed)
    }

    fn evaluate_batch(&self, jobs: &[(Vec<f64>, u32)]) -> Result<Vec<Vec<f64>>> {
        if jobs.len() <= 1 {
            return self.inner.evaluate_batch(jobs);
        }
        // ~4 chunks per worker: large enough to amortise submission, small
        // enough to keep stragglers from idling the pool at the tail
        let chunk_len = jobs.len().div_ceil(self.pool.threads() * 4).max(1);
        let handles: Vec<_> = jobs
            .chunks(chunk_len)
            .map(|chunk| {
                let inner = Arc::clone(&self.inner);
                let chunk = chunk.to_vec();
                self.pool.submit(move || inner.evaluate_batch(&chunk))
            })
            .collect();
        let mut out = Vec::with_capacity(jobs.len());
        for handle in handles {
            let chunk_result = handle.join().map_err(|panic| {
                Error::Evolution(format!("parallel evaluation panicked: {panic}"))
            })?;
            out.extend(chunk_result?);
        }
        Ok(out)
    }

    fn nominal_cost_s(&self) -> f64 {
        self.inner.nominal_cost_s()
    }
}

/// Counts evaluations — instrumentation for tests and benches.
pub struct CountingEvaluator<E> {
    pub inner: E,
    count: AtomicU64,
}

impl<E: Evaluator> CountingEvaluator<E> {
    pub fn new(inner: E) -> Self {
        CountingEvaluator {
            inner,
            count: AtomicU64::new(0),
        }
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }
}

impl<E: Evaluator> Evaluator for CountingEvaluator<E> {
    fn objectives(&self) -> usize {
        self.inner.objectives()
    }

    fn evaluate(&self, genome: &[f64], seed: u32) -> Result<Vec<f64>> {
        self.count.fetch_add(1, Ordering::Relaxed);
        self.inner.evaluate(genome, seed)
    }

    fn nominal_cost_s(&self) -> f64 {
        self.inner.nominal_cost_s()
    }
}

/// The paper's `replicateModel`: evaluate under `n` independent seeds and
/// summarise each objective with a descriptor (median in §4.4).
pub struct ReplicatedEvaluator {
    pub inner: Arc<dyn Evaluator>,
    pub replications: usize,
    pub descriptor: Descriptor,
}

impl ReplicatedEvaluator {
    pub fn new(inner: Arc<dyn Evaluator>, replications: usize) -> Self {
        ReplicatedEvaluator {
            inner,
            replications: replications.max(1),
            descriptor: Descriptor::Median,
        }
    }
}

impl Evaluator for ReplicatedEvaluator {
    fn objectives(&self) -> usize {
        self.inner.objectives()
    }

    fn evaluate(&self, genome: &[f64], seed: u32) -> Result<Vec<f64>> {
        self.evaluate_batch(&[(genome.to_vec(), seed)])?
            .pop()
            .ok_or_else(|| Error::Evolution("empty replication batch".into()))
    }

    /// Flatten all genomes × replication seeds into **one** inner batch:
    /// a pooled or vmapped inner evaluator sees the whole fan-out at once
    /// instead of `jobs.len()` serial waves of `replications`.
    fn evaluate_batch(&self, jobs: &[(Vec<f64>, u32)]) -> Result<Vec<Vec<f64>>> {
        let reps = self.replications;
        let mut flat: Vec<(Vec<f64>, u32)> = Vec::with_capacity(jobs.len() * reps);
        for (genome, seed) in jobs {
            // derive the replication seeds from the job seed (identical
            // stream to the original per-genome implementation)
            let mut s = u64::from(*seed) | 0x5851_f42d_0000_0000;
            for _ in 0..reps {
                flat.push((genome.clone(), crate::util::rng::splitmix64(&mut s) as u32));
            }
        }
        let results = self.inner.evaluate_batch(&flat)?;
        let n_obj = self.objectives();
        let mut out = Vec::with_capacity(jobs.len());
        for rep_group in results.chunks(reps) {
            let mut per_obj: Vec<Vec<f64>> = vec![Vec::new(); n_obj];
            for objs in rep_group {
                for (o, v) in per_obj.iter_mut().zip(objs) {
                    o.push(*v);
                }
            }
            out.push(per_obj.iter().map(|o| self.descriptor.apply(o)).collect());
        }
        Ok(out)
    }

    fn nominal_cost_s(&self) -> f64 {
        self.inner.nominal_cost_s() * self.replications as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zdt1_known_values() {
        let e = Zdt1Evaluator { dim: 3 };
        // on the Pareto front (tail genes 0): f2 = 1 - sqrt(f1)
        let f = e.evaluate(&[0.25, 0.0, 0.0], 0).unwrap();
        assert!((f[0] - 0.25).abs() < 1e-12);
        assert!((f[1] - 0.5).abs() < 1e-12);
    }

    #[test]
    fn ant_sim_evaluator_three_objectives() {
        let e = AntSimEvaluator::fast();
        let f = e.evaluate(&[50.0, 10.0], 42).unwrap();
        assert_eq!(f.len(), 3);
        assert!(f.iter().all(|&t| t > 0.0 && t <= 250.0));
    }

    #[test]
    fn replication_tames_noise() {
        let noisy = Arc::new(SphereEvaluator { noise: 5.0 });
        let replicated = ReplicatedEvaluator::new(Arc::clone(&noisy) as _, 51);
        let g = vec![0.0, 0.0];
        // single evaluations swing by ±5; the 51-seed median is much tighter
        let reps: Vec<f64> = (0..20)
            .map(|s| replicated.evaluate(&g, s).unwrap()[0])
            .collect();
        let spread = reps.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
            - reps.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(spread < 4.0, "median spread {spread} not < raw ±5 noise");
    }

    #[test]
    fn counting_counts() {
        let e = CountingEvaluator::new(Zdt1Evaluator { dim: 2 });
        for i in 0..7 {
            e.evaluate(&[0.5, 0.5], i).unwrap();
        }
        assert_eq!(e.count(), 7);
    }

    #[test]
    fn replicated_cost_scales() {
        let e = ReplicatedEvaluator::new(Arc::new(Zdt1Evaluator { dim: 2 }), 5);
        assert_eq!(e.nominal_cost_s(), 5.0);
    }

    #[test]
    fn replicated_batch_matches_per_genome_evaluate() {
        let noisy = Arc::new(SphereEvaluator { noise: 2.0 });
        let replicated = ReplicatedEvaluator::new(Arc::clone(&noisy) as _, 7);
        let jobs: Vec<(Vec<f64>, u32)> = (0..9)
            .map(|i| (vec![f64::from(i) * 0.1, 0.3], 100 + i))
            .collect();
        let batch = replicated.evaluate_batch(&jobs).unwrap();
        for (job, want) in jobs.iter().zip(&batch) {
            let single = replicated.evaluate(&job.0, job.1).unwrap();
            assert_eq!(&single, want, "flattened batch diverged for {job:?}");
        }
    }

    /// Panics on a marker genome — exercises the pooled error path.
    struct ExplodingEvaluator;

    impl Evaluator for ExplodingEvaluator {
        fn objectives(&self) -> usize {
            1
        }

        fn evaluate(&self, genome: &[f64], _seed: u32) -> Result<Vec<f64>> {
            if genome[0] < 0.0 {
                panic!("negative genome reached the model");
            }
            Ok(vec![genome[0]])
        }
    }

    #[test]
    fn pooled_batch_matches_serial_order() {
        let serial = Zdt1Evaluator { dim: 3 };
        let pooled =
            PooledEvaluator::with_threads(Arc::new(Zdt1Evaluator { dim: 3 }), 4);
        let jobs: Vec<(Vec<f64>, u32)> = (0..257)
            .map(|i| {
                let x = f64::from(i) / 257.0;
                (vec![x, 1.0 - x, x * x], i)
            })
            .collect();
        let want = serial.evaluate_batch(&jobs).unwrap();
        let got = pooled.evaluate_batch(&jobs).unwrap();
        assert_eq!(want, got, "pooled results must keep submission order");
    }

    #[test]
    fn pooled_panic_surfaces_as_err_and_pool_survives() {
        let pooled = PooledEvaluator::with_threads(Arc::new(ExplodingEvaluator), 2);
        let mut jobs: Vec<(Vec<f64>, u32)> =
            (0..16).map(|i| (vec![f64::from(i)], i)).collect();
        jobs[9].0[0] = -1.0; // the mine
        let err = pooled.evaluate_batch(&jobs).unwrap_err();
        assert!(
            err.to_string().contains("panicked"),
            "unexpected error: {err}"
        );
        // the pool is not poisoned: a clean batch still works, in order
        let clean: Vec<(Vec<f64>, u32)> =
            (0..16).map(|i| (vec![f64::from(i)], i)).collect();
        let out = pooled.evaluate_batch(&clean).unwrap();
        assert_eq!(out.len(), 16);
        for (i, objs) in out.iter().enumerate() {
            assert_eq!(objs[0], i as f64);
        }
    }

    #[test]
    fn pooled_counting_counts_every_job_exactly_once() {
        let counting = Arc::new(CountingEvaluator::new(Zdt1Evaluator { dim: 2 }));
        let pooled = PooledEvaluator::with_threads(Arc::clone(&counting) as _, 3);
        let jobs: Vec<(Vec<f64>, u32)> =
            (0..50).map(|i| (vec![0.2, 0.4], i)).collect();
        pooled.evaluate_batch(&jobs).unwrap();
        assert_eq!(counting.count(), 50);
    }

    #[test]
    fn pooled_handles_tiny_batches() {
        let pooled = PooledEvaluator::with_threads(Arc::new(Zdt1Evaluator { dim: 2 }), 4);
        assert!(pooled.evaluate_batch(&[]).unwrap().is_empty());
        let one = pooled.evaluate_batch(&[(vec![0.5, 0.5], 1)]).unwrap();
        assert_eq!(one.len(), 1);
    }
}
