//! Fitness evaluation: the bridge between the GA coordinator and the
//! model (paper §4.2's fitness function).
//!
//! Implementations:
//! * [`crate::runtime::PjrtEvaluator`] — the production path: the AOT
//!   JAX+Pallas ant model via PJRT;
//! * [`AntSimEvaluator`] — the pure-Rust twin (no artifacts needed);
//! * [`Zdt1Evaluator`] / [`SphereEvaluator`] — analytic benchmarks to test
//!   GA machinery against known Pareto fronts;
//! * [`PooledEvaluator`] — fans `evaluate_batch` out over an
//!   [`crate::exec::ThreadPool`] with deterministic result ordering (§Perf
//!   tentpole: a multicore coordinator must actually use its cores);
//! * [`ReplicatedEvaluator`] — wraps any evaluator with n-seed replication
//!   and a statistical descriptor (the paper's `replicateModel`); its
//!   batch path flattens all genomes × seeds into one inner batch so the
//!   pooled/vmapped layers see the full fan-out.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::error::{Error, Result};
use crate::exec::ThreadPool;
use crate::sim::ants::{evaluate as ant_evaluate, AntParams};
use crate::util::stats::Descriptor;

/// A borrowed view of genome rows in a row-major matrix (§Perf tentpole:
/// slice views in, preallocated objective rows out). `index: None` views
/// the rows `0..data.len()/dim` directly; `index: Some(ix)` views row
/// `ix[i]` at position `i`, which lets wrappers like
/// [`ReplicatedEvaluator`] repeat one underlying genome row many times
/// **without copying it** — the historical flattening cloned every genome
/// `replications` times.
#[derive(Clone, Copy)]
pub struct RowsView<'a> {
    data: &'a [f64],
    dim: usize,
    index: Option<&'a [usize]>,
}

impl<'a> RowsView<'a> {
    /// View over all rows of a dense row-major matrix.
    pub fn new(data: &'a [f64], dim: usize) -> Self {
        debug_assert!(dim > 0, "rows need at least one column");
        debug_assert_eq!(data.len() % dim, 0, "ragged matrix");
        RowsView {
            data,
            dim,
            index: None,
        }
    }

    /// View where position `i` maps to underlying row `index[i]` (rows
    /// may repeat).
    pub fn indexed(data: &'a [f64], dim: usize, index: &'a [usize]) -> Self {
        debug_assert!(dim > 0, "rows need at least one column");
        RowsView {
            data,
            dim,
            index: Some(index),
        }
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn len(&self) -> usize {
        match self.index {
            Some(ix) => ix.len(),
            None => self.data.len() / self.dim,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The underlying matrix row id at position `i`.
    pub fn row_id(&self, i: usize) -> usize {
        match self.index {
            Some(ix) => ix[i],
            None => i,
        }
    }

    /// The genome at position `i`.
    pub fn row(&self, i: usize) -> &'a [f64] {
        let r = self.row_id(i);
        &self.data[r * self.dim..(r + 1) * self.dim]
    }

    /// Positions `lo..hi` as a sub-view (no copying).
    pub fn slice(&self, lo: usize, hi: usize) -> RowsView<'a> {
        match self.index {
            Some(ix) => RowsView {
                data: self.data,
                dim: self.dim,
                index: Some(&ix[lo..hi]),
            },
            None => RowsView {
                data: &self.data[lo * self.dim..hi * self.dim],
                dim: self.dim,
                index: None,
            },
        }
    }
}

/// Maps a genome (plus a seed for stochastic models) to minimised
/// objective values.
pub trait Evaluator: Send + Sync {
    /// Number of objectives produced.
    fn objectives(&self) -> usize;

    /// Evaluate one genome under one seed.
    fn evaluate(&self, genome: &[f64], seed: u32) -> Result<Vec<f64>>;

    /// Batch evaluation; overridden by the PJRT evaluator to use the
    /// vmapped artifacts. The default loops.
    fn evaluate_batch(&self, jobs: &[(Vec<f64>, u32)]) -> Result<Vec<Vec<f64>>> {
        jobs.iter()
            .map(|(g, s)| self.evaluate(g, *s))
            .collect()
    }

    /// Columnar batch evaluation (§Perf tentpole): genome rows in via a
    /// borrowed [`RowsView`], objective rows out into the preallocated
    /// `out` buffer (`out.len() == rows.len() * self.objectives()`).
    ///
    /// The default bridges through [`Evaluator::evaluate_batch`] so an
    /// evaluator with a batch fast path (PJRT vmap) keeps it; the
    /// in-crate evaluators override this with straight row writes that
    /// allocate nothing, which is what makes the engines' steady-state
    /// waves allocation-free.
    fn evaluate_rows(&self, rows: RowsView<'_>, seeds: &[u32], out: &mut [f64]) -> Result<()> {
        let n_obj = self.objectives();
        debug_assert_eq!(seeds.len(), rows.len());
        debug_assert_eq!(out.len(), rows.len() * n_obj);
        let jobs: Vec<(Vec<f64>, u32)> = (0..rows.len())
            .map(|i| (rows.row(i).to_vec(), seeds[i]))
            .collect();
        let results = self.evaluate_batch(&jobs)?;
        if results.len() != jobs.len() {
            return Err(Error::Evolution(format!(
                "evaluator returned {} results for {} rows",
                results.len(),
                jobs.len()
            )));
        }
        for (i, objs) in results.iter().enumerate() {
            if objs.len() != n_obj {
                return Err(Error::Evolution(format!(
                    "evaluator returned {} objectives, declared {n_obj}",
                    objs.len()
                )));
            }
            out[i * n_obj..(i + 1) * n_obj].copy_from_slice(objs);
        }
        Ok(())
    }

    /// Nominal cost of one evaluation in remote core-seconds — feeds the
    /// environments' virtual clocks. The NetLogo ant run the paper
    /// distributes costs ~36 s on a 2015 grid core (1000 ticks).
    fn nominal_cost_s(&self) -> f64 {
        36.0
    }
}

/// Ant model via the pure-Rust twin; genome = (diffusion, evaporation),
/// population fixed at the paper's 125 (§4.2 optimises the two rates).
pub struct AntSimEvaluator {
    pub population: f64,
    pub max_ticks: u32,
}

impl AntSimEvaluator {
    pub fn new() -> Self {
        AntSimEvaluator {
            population: 125.0,
            max_ticks: 1000,
        }
    }

    /// A faster, lower-fidelity setting for tests and quick demos.
    pub fn fast() -> Self {
        AntSimEvaluator {
            population: 125.0,
            max_ticks: 250,
        }
    }
}

impl Default for AntSimEvaluator {
    fn default() -> Self {
        Self::new()
    }
}

impl Evaluator for AntSimEvaluator {
    fn objectives(&self) -> usize {
        3
    }

    fn evaluate(&self, genome: &[f64], seed: u32) -> Result<Vec<f64>> {
        let params = AntParams {
            population: self.population,
            diffusion_rate: genome.first().copied().unwrap_or(50.0),
            evaporation_rate: genome.get(1).copied().unwrap_or(50.0),
        };
        Ok(ant_evaluate(params, u64::from(seed), self.max_ticks).to_vec())
    }

    fn evaluate_rows(&self, rows: RowsView<'_>, seeds: &[u32], out: &mut [f64]) -> Result<()> {
        debug_assert_eq!(out.len(), rows.len() * 3);
        for i in 0..rows.len() {
            let g = rows.row(i);
            let params = AntParams {
                population: self.population,
                diffusion_rate: g.first().copied().unwrap_or(50.0),
                evaporation_rate: g.get(1).copied().unwrap_or(50.0),
            };
            let fit = ant_evaluate(params, u64::from(seeds[i]), self.max_ticks);
            out[i * 3..(i + 1) * 3].copy_from_slice(&fit);
        }
        Ok(())
    }

    fn nominal_cost_s(&self) -> f64 {
        // scale the 36 s/1000-tick reference to this configuration
        36.0 * f64::from(self.max_ticks) / 1000.0
    }
}

/// ZDT1: two-objective benchmark with known Pareto front
/// (f2 = 1 - sqrt(f1) at g = 1). Genome in [0, 1]^n.
pub struct Zdt1Evaluator {
    pub dim: usize,
}

impl Evaluator for Zdt1Evaluator {
    fn objectives(&self) -> usize {
        2
    }

    fn evaluate(&self, genome: &[f64], _seed: u32) -> Result<Vec<f64>> {
        let f1 = genome[0];
        let g = 1.0
            + 9.0 * genome[1..].iter().sum::<f64>() / (self.dim as f64 - 1.0).max(1.0);
        let f2 = g * (1.0 - (f1 / g).sqrt());
        Ok(vec![f1, f2])
    }

    fn evaluate_rows(&self, rows: RowsView<'_>, _seeds: &[u32], out: &mut [f64]) -> Result<()> {
        debug_assert_eq!(out.len(), rows.len() * 2);
        for i in 0..rows.len() {
            let genome = rows.row(i);
            let f1 = genome[0];
            let g = 1.0
                + 9.0 * genome[1..].iter().sum::<f64>()
                    / (self.dim as f64 - 1.0).max(1.0);
            out[2 * i] = f1;
            out[2 * i + 1] = g * (1.0 - (f1 / g).sqrt());
        }
        Ok(())
    }

    fn nominal_cost_s(&self) -> f64 {
        1.0
    }
}

/// Single-objective sphere with optional seed noise — for convergence and
/// replication tests.
pub struct SphereEvaluator {
    pub noise: f64,
}

impl Evaluator for SphereEvaluator {
    fn objectives(&self) -> usize {
        1
    }

    fn evaluate(&self, genome: &[f64], seed: u32) -> Result<Vec<f64>> {
        let base: f64 = genome.iter().map(|x| x * x).sum();
        // deterministic per-seed noise
        let mut s = u64::from(seed);
        let noise =
            (crate::util::rng::splitmix64(&mut s) as f64 / u64::MAX as f64 - 0.5)
                * 2.0
                * self.noise;
        Ok(vec![base + noise])
    }

    fn evaluate_rows(&self, rows: RowsView<'_>, seeds: &[u32], out: &mut [f64]) -> Result<()> {
        debug_assert_eq!(out.len(), rows.len());
        for i in 0..rows.len() {
            let base: f64 = rows.row(i).iter().map(|x| x * x).sum();
            let mut s = u64::from(seeds[i]);
            let noise = (crate::util::rng::splitmix64(&mut s) as f64 / u64::MAX as f64
                - 0.5)
                * 2.0
                * self.noise;
            out[i] = base + noise;
        }
        Ok(())
    }

    fn nominal_cost_s(&self) -> f64 {
        1.0
    }
}

/// Parallel batch evaluation over an [`exec::ThreadPool`](ThreadPool):
/// jobs are split into per-worker chunks, each chunk runs the inner
/// evaluator's own `evaluate_batch` (so PJRT vmapping composes), and the
/// results are reassembled **in submission order** — callers observe
/// exactly the serial semantics, faster.
///
/// A panic inside one evaluation surfaces as an `Err` from the batch; the
/// pool itself is unaffected (workers catch unwinds) and stays usable.
///
/// Deadlock note: `evaluate_batch` *blocks* until its chunks finish. Do
/// not hand it the same pool an environment executes jobs on — an
/// environment worker waiting for chunks that queue behind other blocked
/// workers can stall the whole pool. Give the evaluator its own pool
/// ([`Self::with_threads`] / [`Self::machine_sized`]).
pub struct PooledEvaluator {
    pub inner: Arc<dyn Evaluator>,
    pool: Arc<ThreadPool>,
}

impl PooledEvaluator {
    /// Share an existing pool (the usual case: one pool per machine).
    pub fn new(inner: Arc<dyn Evaluator>, pool: Arc<ThreadPool>) -> Self {
        PooledEvaluator { inner, pool }
    }

    /// Own a dedicated pool of `threads` workers.
    pub fn with_threads(inner: Arc<dyn Evaluator>, threads: usize) -> Self {
        Self::new(inner, Arc::new(ThreadPool::new(threads)))
    }

    /// Pool sized to the machine (leaving one core for the coordinator).
    pub fn machine_sized(inner: Arc<dyn Evaluator>) -> Self {
        Self::new(inner, Arc::new(ThreadPool::default_size()))
    }

    pub fn threads(&self) -> usize {
        self.pool.threads()
    }
}

impl Evaluator for PooledEvaluator {
    fn objectives(&self) -> usize {
        self.inner.objectives()
    }

    fn evaluate(&self, genome: &[f64], seed: u32) -> Result<Vec<f64>> {
        // a single evaluation gains nothing from a worker round-trip
        self.inner.evaluate(genome, seed)
    }

    fn evaluate_batch(&self, jobs: &[(Vec<f64>, u32)]) -> Result<Vec<Vec<f64>>> {
        if jobs.len() <= 1 {
            return self.inner.evaluate_batch(jobs);
        }
        // ~4 chunks per worker: large enough to amortise submission, small
        // enough to keep stragglers from idling the pool at the tail
        let chunk_len = jobs.len().div_ceil(self.pool.threads() * 4).max(1);
        let handles: Vec<_> = jobs
            .chunks(chunk_len)
            .map(|chunk| {
                let inner = Arc::clone(&self.inner);
                let chunk = chunk.to_vec();
                self.pool.submit(move || inner.evaluate_batch(&chunk))
            })
            .collect();
        let mut out = Vec::with_capacity(jobs.len());
        for handle in handles {
            let chunk_result = handle.join().map_err(|panic| {
                Error::Evolution(format!("parallel evaluation panicked: {panic}"))
            })?;
            out.extend(chunk_result?);
        }
        Ok(out)
    }

    /// Columnar fan-out: the out buffer is split into per-chunk row
    /// ranges and each worker writes its own disjoint slice via the
    /// inner evaluator's `evaluate_rows` — no per-job tuples, no result
    /// reassembly, deterministic layout regardless of scheduling.
    fn evaluate_rows(&self, rows: RowsView<'_>, seeds: &[u32], out: &mut [f64]) -> Result<()> {
        let n = rows.len();
        let n_obj = self.objectives();
        debug_assert_eq!(out.len(), n * n_obj);
        if n <= 1 || self.pool.threads() == 1 {
            return self.inner.evaluate_rows(rows, seeds, out);
        }
        let chunk_rows = n.div_ceil(self.pool.threads() * 4).max(1);
        let inner = &self.inner;
        let first_err: Mutex<Option<Error>> = Mutex::new(None);
        self.pool
            .scoped_chunks(out, chunk_rows * n_obj, |k, out_chunk| {
                let lo = k * chunk_rows;
                let hi = (lo + chunk_rows).min(n);
                if let Err(e) =
                    inner.evaluate_rows(rows.slice(lo, hi), &seeds[lo..hi], out_chunk)
                {
                    let mut slot = first_err.lock().unwrap();
                    if slot.is_none() {
                        *slot = Some(e);
                    }
                }
            })
            .map_err(|panic| {
                Error::Evolution(format!("parallel evaluation panicked: {panic}"))
            })?;
        match first_err.into_inner().unwrap() {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    fn nominal_cost_s(&self) -> f64 {
        self.inner.nominal_cost_s()
    }
}

/// Counts evaluations — instrumentation for tests and benches.
pub struct CountingEvaluator<E> {
    pub inner: E,
    count: AtomicU64,
}

impl<E: Evaluator> CountingEvaluator<E> {
    pub fn new(inner: E) -> Self {
        CountingEvaluator {
            inner,
            count: AtomicU64::new(0),
        }
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }
}

impl<E: Evaluator> Evaluator for CountingEvaluator<E> {
    fn objectives(&self) -> usize {
        self.inner.objectives()
    }

    fn evaluate(&self, genome: &[f64], seed: u32) -> Result<Vec<f64>> {
        self.count.fetch_add(1, Ordering::Relaxed);
        self.inner.evaluate(genome, seed)
    }

    fn evaluate_rows(&self, rows: RowsView<'_>, seeds: &[u32], out: &mut [f64]) -> Result<()> {
        self.count.fetch_add(rows.len() as u64, Ordering::Relaxed);
        self.inner.evaluate_rows(rows, seeds, out)
    }

    fn nominal_cost_s(&self) -> f64 {
        self.inner.nominal_cost_s()
    }
}

/// The paper's `replicateModel`: evaluate under `n` independent seeds and
/// summarise each objective with a descriptor (median in §4.4).
pub struct ReplicatedEvaluator {
    pub inner: Arc<dyn Evaluator>,
    pub replications: usize,
    pub descriptor: Descriptor,
}

impl ReplicatedEvaluator {
    pub fn new(inner: Arc<dyn Evaluator>, replications: usize) -> Self {
        ReplicatedEvaluator {
            inner,
            replications: replications.max(1),
            descriptor: Descriptor::Median,
        }
    }

    /// Reduce one genome's replication results into its objective row:
    /// `value_of(rep, objective)` yields the raw values, `out_row`
    /// receives one descriptor summary per objective. The single
    /// reduction shared by every batch shape (flat rows, ragged
    /// fallback), so descriptor semantics cannot diverge between paths.
    fn reduce_reps(
        &self,
        value_of: impl Fn(usize, usize) -> f64,
        out_row: &mut [f64],
        values: &mut Vec<f64>,
    ) {
        for (o, out) in out_row.iter_mut().enumerate() {
            values.clear();
            for r in 0..self.replications {
                values.push(value_of(r, o));
            }
            *out = self.descriptor.apply(values);
        }
    }
}

impl Evaluator for ReplicatedEvaluator {
    fn objectives(&self) -> usize {
        self.inner.objectives()
    }

    fn evaluate(&self, genome: &[f64], seed: u32) -> Result<Vec<f64>> {
        self.evaluate_batch(&[(genome.to_vec(), seed)])?
            .pop()
            .ok_or_else(|| Error::Evolution("empty replication batch".into()))
    }

    /// Flatten all genomes × replication seeds into **one** inner batch —
    /// a pooled or vmapped inner evaluator sees the whole fan-out at once.
    /// Homogeneous genomes route through [`Evaluator::evaluate_rows`] with
    /// an *indexed* view, so each genome is stored once and referenced
    /// `replications` times (the historical flattening cloned it per
    /// seed: `replications × genome.len()` copies per job).
    fn evaluate_batch(&self, jobs: &[(Vec<f64>, u32)]) -> Result<Vec<Vec<f64>>> {
        let reps = self.replications;
        let n_obj = self.objectives();
        if jobs.is_empty() {
            return Ok(Vec::new());
        }
        let dim = jobs[0].0.len();
        if dim == 0 || jobs.iter().any(|(g, _)| g.len() != dim) {
            // ragged or zero-width genomes cannot share one matrix: keep
            // the historical per-rep clone path for this rare shape
            let mut flat: Vec<(Vec<f64>, u32)> = Vec::with_capacity(jobs.len() * reps);
            for (genome, seed) in jobs {
                let mut s = u64::from(*seed) | 0x5851_f42d_0000_0000;
                for _ in 0..reps {
                    flat.push((
                        genome.clone(),
                        crate::util::rng::splitmix64(&mut s) as u32,
                    ));
                }
            }
            let results = self.inner.evaluate_batch(&flat)?;
            let mut out = Vec::with_capacity(jobs.len());
            let mut values = Vec::with_capacity(reps);
            for rep_group in results.chunks(reps) {
                let mut row = vec![0.0; n_obj];
                self.reduce_reps(|r, o| rep_group[r][o], &mut row, &mut values);
                out.push(row);
            }
            return Ok(out);
        }
        let mut data = Vec::with_capacity(jobs.len() * dim);
        let mut seeds = Vec::with_capacity(jobs.len());
        for (genome, seed) in jobs {
            data.extend_from_slice(genome);
            seeds.push(*seed);
        }
        let mut out = vec![0.0; jobs.len() * n_obj];
        self.evaluate_rows(RowsView::new(&data, dim), &seeds, &mut out)?;
        Ok(out.chunks(n_obj).map(<[f64]>::to_vec).collect())
    }

    /// Columnar replication: one index entry per (genome, seed) pair —
    /// `replications` positions all pointing at the same underlying row —
    /// then a descriptor reduction straight into the caller's objective
    /// rows. Seed derivation is identical to the historical per-genome
    /// implementation, so results are bit-identical.
    fn evaluate_rows(&self, rows: RowsView<'_>, seeds: &[u32], out: &mut [f64]) -> Result<()> {
        let reps = self.replications;
        let n = rows.len();
        let n_obj = self.objectives();
        debug_assert_eq!(out.len(), n * n_obj);
        let mut index = Vec::with_capacity(n * reps);
        let mut rep_seeds = Vec::with_capacity(n * reps);
        for (i, seed) in seeds.iter().enumerate() {
            let row = rows.row_id(i);
            let mut s = u64::from(*seed) | 0x5851_f42d_0000_0000;
            for _ in 0..reps {
                index.push(row);
                rep_seeds.push(crate::util::rng::splitmix64(&mut s) as u32);
            }
        }
        let mut rep_out = vec![0.0; n * reps * n_obj];
        self.inner.evaluate_rows(
            RowsView::indexed(rows.data, rows.dim, &index),
            &rep_seeds,
            &mut rep_out,
        )?;
        let mut values = Vec::with_capacity(reps);
        for (i, out_row) in out.chunks_mut(n_obj).enumerate() {
            self.reduce_reps(
                |r, o| rep_out[(i * reps + r) * n_obj + o],
                out_row,
                &mut values,
            );
        }
        Ok(())
    }

    fn nominal_cost_s(&self) -> f64 {
        self.inner.nominal_cost_s() * self.replications as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zdt1_known_values() {
        let e = Zdt1Evaluator { dim: 3 };
        // on the Pareto front (tail genes 0): f2 = 1 - sqrt(f1)
        let f = e.evaluate(&[0.25, 0.0, 0.0], 0).unwrap();
        assert!((f[0] - 0.25).abs() < 1e-12);
        assert!((f[1] - 0.5).abs() < 1e-12);
    }

    #[test]
    fn ant_sim_evaluator_three_objectives() {
        let e = AntSimEvaluator::fast();
        let f = e.evaluate(&[50.0, 10.0], 42).unwrap();
        assert_eq!(f.len(), 3);
        assert!(f.iter().all(|&t| t > 0.0 && t <= 250.0));
    }

    #[test]
    fn replication_tames_noise() {
        let noisy = Arc::new(SphereEvaluator { noise: 5.0 });
        let replicated = ReplicatedEvaluator::new(Arc::clone(&noisy) as _, 51);
        let g = vec![0.0, 0.0];
        // single evaluations swing by ±5; the 51-seed median is much tighter
        let reps: Vec<f64> = (0..20)
            .map(|s| replicated.evaluate(&g, s).unwrap()[0])
            .collect();
        let spread = reps.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
            - reps.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(spread < 4.0, "median spread {spread} not < raw ±5 noise");
    }

    #[test]
    fn counting_counts() {
        let e = CountingEvaluator::new(Zdt1Evaluator { dim: 2 });
        for i in 0..7 {
            e.evaluate(&[0.5, 0.5], i).unwrap();
        }
        assert_eq!(e.count(), 7);
    }

    #[test]
    fn replicated_cost_scales() {
        let e = ReplicatedEvaluator::new(Arc::new(Zdt1Evaluator { dim: 2 }), 5);
        assert_eq!(e.nominal_cost_s(), 5.0);
    }

    #[test]
    fn replicated_batch_matches_per_genome_evaluate() {
        let noisy = Arc::new(SphereEvaluator { noise: 2.0 });
        let replicated = ReplicatedEvaluator::new(Arc::clone(&noisy) as _, 7);
        let jobs: Vec<(Vec<f64>, u32)> = (0..9)
            .map(|i| (vec![f64::from(i) * 0.1, 0.3], 100 + i))
            .collect();
        let batch = replicated.evaluate_batch(&jobs).unwrap();
        for (job, want) in jobs.iter().zip(&batch) {
            let single = replicated.evaluate(&job.0, job.1).unwrap();
            assert_eq!(&single, want, "flattened batch diverged for {job:?}");
        }
    }

    /// Panics on a marker genome — exercises the pooled error path.
    struct ExplodingEvaluator;

    impl Evaluator for ExplodingEvaluator {
        fn objectives(&self) -> usize {
            1
        }

        fn evaluate(&self, genome: &[f64], _seed: u32) -> Result<Vec<f64>> {
            if genome[0] < 0.0 {
                panic!("negative genome reached the model");
            }
            Ok(vec![genome[0]])
        }
    }

    #[test]
    fn pooled_batch_matches_serial_order() {
        let serial = Zdt1Evaluator { dim: 3 };
        let pooled =
            PooledEvaluator::with_threads(Arc::new(Zdt1Evaluator { dim: 3 }), 4);
        let jobs: Vec<(Vec<f64>, u32)> = (0..257)
            .map(|i| {
                let x = f64::from(i) / 257.0;
                (vec![x, 1.0 - x, x * x], i)
            })
            .collect();
        let want = serial.evaluate_batch(&jobs).unwrap();
        let got = pooled.evaluate_batch(&jobs).unwrap();
        assert_eq!(want, got, "pooled results must keep submission order");
    }

    #[test]
    fn pooled_panic_surfaces_as_err_and_pool_survives() {
        let pooled = PooledEvaluator::with_threads(Arc::new(ExplodingEvaluator), 2);
        let mut jobs: Vec<(Vec<f64>, u32)> =
            (0..16).map(|i| (vec![f64::from(i)], i)).collect();
        jobs[9].0[0] = -1.0; // the mine
        let err = pooled.evaluate_batch(&jobs).unwrap_err();
        assert!(
            err.to_string().contains("panicked"),
            "unexpected error: {err}"
        );
        // the pool is not poisoned: a clean batch still works, in order
        let clean: Vec<(Vec<f64>, u32)> =
            (0..16).map(|i| (vec![f64::from(i)], i)).collect();
        let out = pooled.evaluate_batch(&clean).unwrap();
        assert_eq!(out.len(), 16);
        for (i, objs) in out.iter().enumerate() {
            assert_eq!(objs[0], i as f64);
        }
    }

    #[test]
    fn pooled_counting_counts_every_job_exactly_once() {
        let counting = Arc::new(CountingEvaluator::new(Zdt1Evaluator { dim: 2 }));
        let pooled = PooledEvaluator::with_threads(Arc::clone(&counting) as _, 3);
        let jobs: Vec<(Vec<f64>, u32)> =
            (0..50).map(|i| (vec![0.2, 0.4], i)).collect();
        pooled.evaluate_batch(&jobs).unwrap();
        assert_eq!(counting.count(), 50);
    }

    #[test]
    fn pooled_handles_tiny_batches() {
        let pooled = PooledEvaluator::with_threads(Arc::new(Zdt1Evaluator { dim: 2 }), 4);
        assert!(pooled.evaluate_batch(&[]).unwrap().is_empty());
        let one = pooled.evaluate_batch(&[(vec![0.5, 0.5], 1)]).unwrap();
        assert_eq!(one.len(), 1);
    }

    /// rows-API results must be bit-identical to the per-genome API for
    /// every in-crate evaluator.
    fn assert_rows_match_batch(ev: &dyn Evaluator, dim: usize, n: usize) {
        let jobs: Vec<(Vec<f64>, u32)> = (0..n)
            .map(|i| {
                let x = i as f64 / n as f64;
                let genome: Vec<f64> =
                    (0..dim).map(|d| (x + d as f64 * 0.37) % 1.0).collect();
                (genome, i as u32)
            })
            .collect();
        let want = ev.evaluate_batch(&jobs).unwrap();
        let data: Vec<f64> = jobs.iter().flat_map(|(g, _)| g.clone()).collect();
        let seeds: Vec<u32> = jobs.iter().map(|(_, s)| *s).collect();
        let n_obj = ev.objectives();
        let mut out = vec![0.0; n * n_obj];
        ev.evaluate_rows(RowsView::new(&data, dim), &seeds, &mut out)
            .unwrap();
        for (i, objs) in want.iter().enumerate() {
            assert_eq!(
                &out[i * n_obj..(i + 1) * n_obj],
                objs.as_slice(),
                "row {i} diverged"
            );
        }
    }

    #[test]
    fn rows_api_matches_batch_api_for_all_evaluators() {
        assert_rows_match_batch(&Zdt1Evaluator { dim: 3 }, 3, 17);
        assert_rows_match_batch(&SphereEvaluator { noise: 2.0 }, 2, 17);
        assert_rows_match_batch(&AntSimEvaluator::fast(), 2, 3);
        assert_rows_match_batch(
            &PooledEvaluator::with_threads(Arc::new(Zdt1Evaluator { dim: 3 }), 4),
            3,
            97,
        );
        assert_rows_match_batch(
            &ReplicatedEvaluator::new(Arc::new(SphereEvaluator { noise: 1.0 }), 5),
            2,
            9,
        );
        assert_rows_match_batch(
            &CountingEvaluator::new(Zdt1Evaluator { dim: 2 }),
            2,
            11,
        );
    }

    #[test]
    fn indexed_rows_view_shares_underlying_rows() {
        let data = [0.1, 0.9, 0.5, 0.5];
        let index = [1usize, 0, 1, 1];
        let view = RowsView::indexed(&data, 2, &index);
        assert_eq!(view.len(), 4);
        assert_eq!(view.row(0), &[0.5, 0.5]);
        assert_eq!(view.row(1), &[0.1, 0.9]);
        assert_eq!(view.row_id(3), 1);
        let sub = view.slice(1, 3);
        assert_eq!(sub.len(), 2);
        assert_eq!(sub.row(0), &[0.1, 0.9]);
        assert_eq!(sub.row(1), &[0.5, 0.5]);
    }

    #[test]
    fn counting_counts_rows_exactly_once_through_pooled_rows_path() {
        let counting = Arc::new(CountingEvaluator::new(Zdt1Evaluator { dim: 2 }));
        let pooled = PooledEvaluator::with_threads(Arc::clone(&counting) as _, 3);
        let data: Vec<f64> = (0..50).flat_map(|i| vec![f64::from(i) / 50.0, 0.4]).collect();
        let seeds: Vec<u32> = (0..50).collect();
        let mut out = vec![0.0; 50 * 2];
        pooled
            .evaluate_rows(RowsView::new(&data, 2), &seeds, &mut out)
            .unwrap();
        assert_eq!(counting.count(), 50);
    }

    #[test]
    fn replicated_rows_equals_replicated_single_evaluations() {
        let replicated =
            ReplicatedEvaluator::new(Arc::new(SphereEvaluator { noise: 3.0 }), 7);
        let data = [0.2, 0.4, 0.6, 0.8, 1.0, 1.2];
        let seeds = [5u32, 6, 7];
        let mut out = vec![0.0; 3];
        replicated
            .evaluate_rows(RowsView::new(&data, 2), &seeds, &mut out)
            .unwrap();
        for i in 0..3 {
            let single = replicated
                .evaluate(&data[i * 2..(i + 1) * 2], seeds[i])
                .unwrap();
            assert_eq!(out[i], single[0], "row {i}");
        }
    }
}
