//! Steady-state NSGA-II: no generation barrier — a fixed number of
//! evaluation jobs is kept in flight, and each completion immediately
//! triggers selection + breeding of a replacement. This is what each
//! island of §4.6 runs internally, and it is also the better shape for
//! high-latency environments (no synchronisation point).
//!
//! §Perf: the population lives in a columnar
//! [`PopMatrix`](crate::evolution::popmatrix::PopMatrix); every
//! completion appends one row and truncates in place through the shared
//! [`WaveArena`] — the historical per-completion `Vec<Individual>`
//! rebuild is gone. Draw order (tournament, breed, model seeds) is
//! unchanged, so trajectories are bit-identical to the AoS engine.

use std::sync::Arc;

use crate::environment::{Environment, Job, JobHandle};
use crate::error::Result;
use crate::evolution::evaluator::Evaluator;
use crate::evolution::generational::{eval_task, EvolutionResult, Nsga2Config};
use crate::evolution::genome::Individual;
use crate::evolution::nsga2;
use crate::evolution::popmatrix::{PopMatrix, WaveArena};
use crate::util::Rng;

/// Termination criteria (`termination = 100` / `Timed(1 hour)` in the DSL).
#[derive(Debug, Clone, Copy)]
pub enum Termination {
    /// Total evaluations.
    Evaluations(u64),
    /// Virtual seconds of environment time (the paper's `Timed(1 hour)`).
    VirtualTime(f64),
}

/// The steady-state driver.
pub struct SteadyStateGA {
    pub config: Nsga2Config,
    pub evaluator: Arc<dyn Evaluator>,
    /// Concurrent evaluations kept in flight.
    pub parallelism: usize,
}

impl SteadyStateGA {
    pub fn new(
        config: Nsga2Config,
        evaluator: Arc<dyn Evaluator>,
        parallelism: usize,
    ) -> Self {
        SteadyStateGA {
            config,
            evaluator,
            parallelism: parallelism.max(1),
        }
    }

    /// Run until `termination`, starting from `initial` (random genomes
    /// fill the gap if fewer than `mu`).
    pub fn run_from(
        &self,
        env: &dyn Environment,
        termination: Termination,
        initial: Vec<Individual>,
        seed: u64,
    ) -> Result<EvolutionResult> {
        let cfg = &self.config;
        let dim = cfg.bounds.dim();
        let n_obj = cfg.objectives.len();
        let mut rng = Rng::new(seed);
        let task = eval_task(
            Arc::clone(&self.evaluator),
            &cfg.bounds,
            &cfg.objectives,
        );

        let mut population = PopMatrix::from_individuals(&initial, dim, n_obj)?;
        let mut arena = WaveArena::default();
        let mut evaluations: u64 = 0;
        let mut clock: f64 = 0.0;

        let submit = |genome: Vec<f64>,
                      rng: &mut Rng,
                      release: f64|
         -> (Vec<f64>, JobHandle) {
            let mut ctx = crate::core::Context::new();
            for (n, g) in cfg.bounds.names.iter().zip(&genome) {
                ctx.set(&crate::core::Val::<f64>::new(n.clone()), *g);
            }
            ctx.set(&crate::core::Val::<u32>::new("seed"), rng.model_seed());
            let h = env.submit(Job::new(task.clone(), ctx).released_at(release));
            (genome, h)
        };

        // prime the pipeline
        let mut in_flight: Vec<(Vec<f64>, JobHandle)> = Vec::new();
        for _ in 0..self.parallelism {
            let genome = self.next_genome(&population, &mut arena, &mut rng);
            in_flight.push(submit(genome, &mut rng, 0.0));
        }

        let done = |evaluations: u64, clock: f64| -> bool {
            match termination {
                Termination::Evaluations(n) => evaluations >= n,
                Termination::VirtualTime(t) => clock >= t,
            }
        };

        while !in_flight.is_empty() {
            // wait on completions without a barrier
            let mut idx = 0;
            let mut progressed = false;
            while idx < in_flight.len() {
                if let Some(result) = in_flight[idx].1.try_wait() {
                    let (genome, _) = in_flight.swap_remove(idx);
                    let (ctx, report) = result?;
                    progressed = true;
                    clock = clock.max(report.virtual_end);
                    // collect objective values into the arena's return
                    // buffer, then append the row in place
                    arena.obj_buf.clear();
                    for n in &cfg.objectives {
                        arena
                            .obj_buf
                            .push(ctx.get(&crate::core::Val::<f64>::new(n.clone()))?);
                    }
                    evaluations += 1;

                    // merge + truncate (steady-state elitism), in place
                    population.push_row(&genome, &arena.obj_buf, 1);
                    if population.len() > cfg.mu {
                        arena.select(&mut population, cfg.mu, None);
                    }

                    if !done(evaluations, clock) {
                        let child = self.next_genome(&population, &mut arena, &mut rng);
                        // replacement released when this slot's job ended
                        in_flight.push(submit(child, &mut rng, report.virtual_end));
                    }
                } else {
                    idx += 1;
                }
            }
            if !progressed && !in_flight.is_empty() {
                std::thread::sleep(std::time::Duration::from_micros(200));
            }
        }

        let population = population.to_individuals();
        let pareto_front = nsga2::pareto_front(&population);
        Ok(EvolutionResult {
            population,
            pareto_front,
            evaluations,
            generations: 0,
            virtual_makespan: clock,
        })
    }

    pub fn run(
        &self,
        env: &dyn Environment,
        termination: Termination,
        seed: u64,
    ) -> Result<EvolutionResult> {
        self.run_from(env, termination, Vec::new(), seed)
    }

    /// Breed from the current population, or draw randomly while it is
    /// still too small to hold a tournament. Identical draw order to the
    /// historical AoS implementation.
    fn next_genome(
        &self,
        population: &PopMatrix,
        arena: &mut WaveArena,
        rng: &mut Rng,
    ) -> Vec<f64> {
        let cfg = &self.config;
        if population.len() < 2 {
            return cfg.bounds.random(rng);
        }
        arena.rank_crowd(population, None);
        let n = population.len();
        let a = nsga2::tournament_idx(n, arena.nsga.rank(), arena.nsga.crowd(), rng);
        let b = nsga2::tournament_idx(n, arena.nsga.rank(), arena.nsga.crowd(), rng);
        cfg.operators.breed(
            population.genome(a),
            population.genome(b),
            &cfg.bounds,
            rng,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::val_f64;
    use crate::environment::local::LocalEnvironment;
    use crate::evolution::evaluator::Zdt1Evaluator;

    fn config(mu: usize) -> Nsga2Config {
        let x0 = val_f64("x0");
        let x1 = val_f64("x1");
        let f1 = val_f64("f1");
        let f2 = val_f64("f2");
        Nsga2Config::new(mu, &[(&x0, 0.0, 1.0), (&x1, 0.0, 1.0)], &[&f1, &f2], 0.0)
            .unwrap()
    }

    #[test]
    fn respects_evaluation_budget() {
        let env = LocalEnvironment::new(4);
        let ga = SteadyStateGA::new(config(10), Arc::new(Zdt1Evaluator { dim: 2 }), 4);
        let r = ga.run(&env, Termination::Evaluations(40), 1).unwrap();
        // budget reached; a few in-flight stragglers may complete
        assert!(r.evaluations >= 40 && r.evaluations < 40 + 5);
        assert!(r.population.len() <= 10);
    }

    #[test]
    fn improves_over_random() {
        let env = LocalEnvironment::new(4);
        let ga = SteadyStateGA::new(config(12), Arc::new(Zdt1Evaluator { dim: 2 }), 6);
        let r = ga.run(&env, Termination::Evaluations(300), 3).unwrap();
        let mean_f2: f64 = r
            .pareto_front
            .iter()
            .map(|i| i.objectives[1] - (1.0 - i.objectives[0].sqrt()))
            .sum::<f64>()
            / r.pareto_front.len() as f64;
        assert!(mean_f2 < 0.5, "distance to true front {mean_f2}");
    }

    #[test]
    fn virtual_time_termination() {
        let env = LocalEnvironment::new(2);
        let ga = SteadyStateGA::new(config(6), Arc::new(Zdt1Evaluator { dim: 2 }), 2);
        // local env: virtual time = real exec (µs-scale) → tiny budget stops fast
        let r = ga
            .run(&env, Termination::VirtualTime(0.001), 4)
            .unwrap();
        assert!(r.evaluations >= 2, "at least the primed jobs complete");
        assert!(r.evaluations < 10_000);
    }

    #[test]
    fn seeded_start_population_is_used() {
        let env = LocalEnvironment::new(2);
        let ga = SteadyStateGA::new(config(4), Arc::new(Zdt1Evaluator { dim: 2 }), 2);
        let elite = Individual::new(vec![0.0, 0.0], vec![0.0, 1.0]);
        let r = ga
            .run_from(&env, Termination::Evaluations(10), vec![elite.clone()], 5)
            .unwrap();
        // the seeded elite (f1=0) or a descendant keeps the front's left edge at 0-ish
        let best_f1 = r
            .pareto_front
            .iter()
            .map(|i| i.objectives[0])
            .fold(f64::INFINITY, f64::min);
        assert!(best_f1 <= 0.2, "elite lost: {best_f1}");
    }

    #[test]
    fn mismatched_seed_population_is_rejected() {
        let env = LocalEnvironment::new(1);
        let ga = SteadyStateGA::new(config(4), Arc::new(Zdt1Evaluator { dim: 2 }), 1);
        let bad = Individual::new(vec![0.0, 0.0, 0.0], vec![0.0, 1.0]);
        assert!(ga
            .run_from(&env, Termination::Evaluations(4), vec![bad], 5)
            .is_err());
    }
}
