//! Artifact manifest: what `make artifacts` produced (shapes, batch sizes,
//! file names) — parsed from `artifacts/manifest.json` with the in-crate
//! JSON parser.

use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::util::json::{self, Json};

/// One lowered HLO artifact.
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub name: String,
    pub file: PathBuf,
    /// Batch size of the vmapped fitness function (1 for the scalar one).
    pub batch: usize,
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct ArtifactManifest {
    pub dir: PathBuf,
    pub world: usize,
    pub max_ants: usize,
    pub max_ticks: usize,
    pub params: Vec<String>,
    pub objectives: Vec<String>,
    pub entries: Vec<ArtifactEntry>,
}

impl ArtifactManifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Manifest(format!(
                "cannot read {} (run `make artifacts`?): {e}",
                path.display()
            ))
        })?;
        Self::parse(&text, dir)
    }

    fn parse(text: &str, dir: PathBuf) -> Result<Self> {
        let doc = json::parse(text)?;
        let field = |name: &str| -> Result<&Json> {
            doc.get(name)
                .ok_or_else(|| Error::Manifest(format!("missing field `{name}`")))
        };
        let usize_field = |name: &str| -> Result<usize> {
            field(name)?
                .as_usize()
                .ok_or_else(|| Error::Manifest(format!("field `{name}` not a number")))
        };
        let str_list = |name: &str| -> Result<Vec<String>> {
            field(name)?
                .as_arr()
                .ok_or_else(|| Error::Manifest(format!("field `{name}` not an array")))?
                .iter()
                .map(|v| {
                    v.as_str()
                        .map(str::to_string)
                        .ok_or_else(|| Error::Manifest(format!("`{name}` has non-string")))
                })
                .collect()
        };

        let mut entries = Vec::new();
        let artifacts = field("artifacts")?
            .as_obj()
            .ok_or_else(|| Error::Manifest("`artifacts` not an object".into()))?;
        for (name, entry) in artifacts {
            let file = entry
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| Error::Manifest(format!("artifact `{name}` lacks file")))?;
            let batch = entry.get("batch").and_then(Json::as_usize).unwrap_or(0);
            entries.push(ArtifactEntry {
                name: name.clone(),
                file: dir.join(file),
                batch,
            });
        }
        entries.sort_by_key(|e| e.batch);

        Ok(ArtifactManifest {
            dir,
            world: usize_field("world")?,
            max_ants: usize_field("max_ants")?,
            max_ticks: usize_field("max_ticks")?,
            params: str_list("params")?,
            objectives: str_list("objectives")?,
            entries,
        })
    }

    /// Fitness artifacts (batch >= 1), ascending by batch size.
    pub fn fitness_entries(&self) -> impl Iterator<Item = &ArtifactEntry> {
        self.entries.iter().filter(|e| e.batch >= 1)
    }

    /// The largest fitness batch size not exceeding `n` (falls back to the
    /// smallest artifact).
    pub fn best_batch_for(&self, n: usize) -> Option<&ArtifactEntry> {
        self.fitness_entries()
            .filter(|e| e.batch <= n.max(1))
            .last()
            .or_else(|| self.fitness_entries().next())
    }

    /// Locate the default artifact directory: `$MOLERS_ARTIFACTS` or
    /// `artifacts/` relative to the working directory / crate root.
    pub fn default_dir() -> PathBuf {
        if let Ok(d) = std::env::var("MOLERS_ARTIFACTS") {
            return PathBuf::from(d);
        }
        let cwd = PathBuf::from("artifacts");
        if cwd.join("manifest.json").exists() {
            return cwd;
        }
        // crate-root fallback (tests run from target dirs)
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    /// True if artifacts exist at the default location.
    pub fn available() -> bool {
        Self::default_dir().join("manifest.json").exists()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "world": 71, "max_ants": 200, "max_ticks": 1000,
      "batch_sizes": [1, 8, 32],
      "objectives": ["final-ticks-food1", "final-ticks-food2", "final-ticks-food3"],
      "params": ["gpopulation", "gdiffusion-rate", "gevaporation-rate"],
      "artifacts": {
        "diffuse": {"file": "diffuse.hlo.txt"},
        "ants_single": {"file": "ants_single.hlo.txt", "batch": 1},
        "ants_batch8": {"file": "ants_batch8.hlo.txt", "batch": 8},
        "ants_batch32": {"file": "ants_batch32.hlo.txt", "batch": 32}
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = ArtifactManifest::parse(SAMPLE, PathBuf::from("/x")).unwrap();
        assert_eq!(m.world, 71);
        assert_eq!(m.max_ticks, 1000);
        assert_eq!(m.objectives.len(), 3);
        assert_eq!(m.fitness_entries().count(), 3);
    }

    #[test]
    fn batch_selection_picks_largest_fitting() {
        let m = ArtifactManifest::parse(SAMPLE, PathBuf::from("/x")).unwrap();
        assert_eq!(m.best_batch_for(100).unwrap().batch, 32);
        assert_eq!(m.best_batch_for(10).unwrap().batch, 8);
        assert_eq!(m.best_batch_for(3).unwrap().batch, 1);
        assert_eq!(m.best_batch_for(0).unwrap().batch, 1);
    }

    #[test]
    fn missing_fields_error() {
        assert!(ArtifactManifest::parse("{}", PathBuf::from("/x")).is_err());
    }
}
