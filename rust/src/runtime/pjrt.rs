//! The PJRT model runtime: loads the AOT HLO-text artifacts and serves
//! fitness evaluations to the coordinator.
//!
//! Two constraints shape this module, both observed empirically against
//! xla_extension 0.5.1 (see EXPERIMENTS.md §Perf):
//!
//! 1. the `xla` crate's `PjRtClient` is `Rc`-based — not `Send`;
//! 2. creating more than one `TfrtCpuClient` in a process (even
//!    sequentially) silently corrupts subsequent executions.
//!
//! So the runtime is a **process-global actor**: one service per artifact
//! directory, owning one client + the compiled executables on a dedicated
//! thread, drained through a request channel. The public [`PjrtEvaluator`]
//! handle is `Send + Sync + Clone`, implements [`Evaluator`], and batches
//! requests onto the largest fitting vmapped artifact (`ants_batch32` >
//! `ants_batch8` > `ants_single`).

use std::collections::{HashMap, VecDeque};
use std::path::PathBuf;
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

// The real `xla` crate is not vendored in this image; the stub mirrors its
// API and fails at client creation, so this whole module compiles unchanged
// and callers fall back to the pure-Rust twin (see `xla_stub` docs).
use crate::runtime::xla_stub as xla;

use crate::error::{Error, Result};
use crate::evolution::evaluator::Evaluator;
use crate::runtime::artifacts::ArtifactManifest;

struct Request {
    /// Full `[population, diffusion, evaporation]` genomes with seeds.
    jobs: Vec<(Vec<f64>, u32)>,
    reply: Sender<Result<Vec<Vec<f64>>>>,
}

/// Shared FIFO of pending requests.
type Queue = Arc<(Mutex<VecDeque<Request>>, Condvar)>;

/// One global service per artifact directory (never torn down — see module
/// docs for why clients must not be recreated).
struct Service {
    queue: Queue,
    manifest: ArtifactManifest,
}

fn services() -> &'static Mutex<HashMap<PathBuf, Arc<Service>>> {
    static SERVICES: OnceLock<Mutex<HashMap<PathBuf, Arc<Service>>>> = OnceLock::new();
    SERVICES.get_or_init(|| Mutex::new(HashMap::new()))
}

fn service_for(dir: PathBuf) -> Result<Arc<Service>> {
    let mut map = services().lock().unwrap();
    if let Some(s) = map.get(&dir) {
        return Ok(Arc::clone(s));
    }
    let manifest = ArtifactManifest::load(&dir)?;
    let queue: Queue = Arc::new((Mutex::new(VecDeque::new()), Condvar::new()));
    let (ready_tx, ready_rx) = channel::<Result<()>>();
    {
        let queue = Arc::clone(&queue);
        let manifest = manifest.clone();
        std::thread::Builder::new()
            .name("pjrt-service".into())
            .spawn(move || worker_main(queue, manifest, ready_tx))
            .map_err(|e| Error::Runtime(format!("cannot spawn pjrt worker: {e}")))?;
    }
    ready_rx
        .recv()
        .map_err(|_| Error::Runtime("pjrt worker died during startup".into()))??;
    let service = Arc::new(Service { queue, manifest });
    map.insert(dir, Arc::clone(&service));
    Ok(service)
}

/// Handle to the PJRT evaluation service. Cheap to clone; all clones share
/// the process-global service for their artifact directory.
#[derive(Clone)]
pub struct PjrtEvaluator {
    service: Arc<Service>,
    /// Default ant population when genomes carry only the two §4.2 rates.
    pub population: f64,
    nominal_cost_s: f64,
}

impl PjrtEvaluator {
    /// Connect to (or start) the service for `dir`. The `workers` argument
    /// is accepted for API stability but the service is single-client by
    /// necessity (see module docs).
    pub fn new(dir: impl Into<PathBuf>, _workers: usize) -> Result<Self> {
        let service = service_for(dir.into())?;
        // cost model: nominal remote seconds per evaluation — the ~36 s
        // NetLogo reference scaled by tick count (DESIGN.md §3)
        let nominal = 36.0 * service.manifest.max_ticks as f64 / 1000.0;
        Ok(PjrtEvaluator {
            service,
            population: 125.0,
            nominal_cost_s: nominal,
        })
    }

    /// Evaluator over the default artifact directory.
    pub fn from_default_artifacts(workers: usize) -> Result<Self> {
        Self::new(ArtifactManifest::default_dir(), workers)
    }

    pub fn manifest(&self) -> &ArtifactManifest {
        &self.service.manifest
    }
}

impl Evaluator for PjrtEvaluator {
    fn objectives(&self) -> usize {
        self.service.manifest.objectives.len()
    }

    fn evaluate(&self, genome: &[f64], seed: u32) -> Result<Vec<f64>> {
        let mut out = self.evaluate_batch(&[(genome.to_vec(), seed)])?;
        out.pop()
            .ok_or_else(|| Error::Runtime("empty batch result".into()))
    }

    fn evaluate_batch(&self, jobs: &[(Vec<f64>, u32)]) -> Result<Vec<Vec<f64>>> {
        if jobs.is_empty() {
            return Ok(Vec::new());
        }
        // normalise genomes to the full [population, diffusion, evaporation]
        let jobs: Vec<(Vec<f64>, u32)> = jobs
            .iter()
            .map(|(g, s)| {
                let full = match g.len() {
                    2 => vec![self.population, g[0], g[1]],
                    3 => g.clone(),
                    n => {
                        return Err(Error::Runtime(format!(
                            "ant genome must have 2 or 3 parameters, got {n}"
                        )))
                    }
                };
                Ok((full, *s))
            })
            .collect::<Result<_>>()?;
        let (reply, rx) = channel();
        {
            let (q, cv) = &*self.service.queue;
            q.lock().unwrap().push_back(Request { jobs, reply });
            cv.notify_one();
        }
        rx.recv()
            .map_err(|_| Error::Runtime("pjrt worker dropped request".into()))?
    }

    fn nominal_cost_s(&self) -> f64 {
        self.nominal_cost_s
    }
}

/// A compiled fitness executable of one batch size.
struct Compiled {
    batch: usize,
    exe: xla::PjRtLoadedExecutable,
    /// Measured seconds per evaluation at this batch size (§Perf item 3):
    /// vmapped artifacts are not automatically faster — on a single-core
    /// host the batch-strided scatter/gathers make them *slower* per
    /// evaluation — so the packer uses measured costs, not batch size.
    per_eval_s: f64,
}

fn worker_main(queue: Queue, manifest: ArtifactManifest, ready: Sender<Result<()>>) {
    // the single process-wide client + executables live on this thread
    let setup = (|| -> Result<Vec<Compiled>> {
        let client = xla::PjRtClient::cpu()?;
        let mut compiled = Vec::new();
        for entry in manifest.fitness_entries() {
            let proto = xla::HloModuleProto::from_text_file(
                entry.file.to_str().ok_or_else(|| {
                    Error::Runtime(format!("non-utf8 path {:?}", entry.file))
                })?,
            )?;
            let exe = client.compile(&xla::XlaComputation::from_proto(&proto))?;
            compiled.push(Compiled {
                batch: entry.batch,
                exe,
                per_eval_s: f64::INFINITY,
            });
        }
        if compiled.is_empty() {
            return Err(Error::Runtime("no fitness artifacts in manifest".into()));
        }
        // calibration: time one execution per batch size so run_jobs can
        // pack onto whatever is empirically cheapest per evaluation
        for c in &mut compiled {
            let probe: Vec<(Vec<f64>, u32)> = (0..c.batch)
                .map(|i| (vec![125.0, 50.0, 50.0], i as u32))
                .collect();
            let t0 = std::time::Instant::now();
            execute_chunk(c, &probe)?;
            c.per_eval_s = t0.elapsed().as_secs_f64() / c.batch as f64;
        }
        Ok(compiled)
    })();
    let compiled = match setup {
        Ok(c) => {
            let _ = ready.send(Ok(()));
            c
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };

    loop {
        let request = {
            let (q, cv) = &*queue;
            let mut guard = q.lock().unwrap();
            loop {
                if let Some(r) = guard.pop_front() {
                    break r;
                }
                guard = cv.wait(guard).unwrap();
            }
        };
        let result = run_jobs(&compiled, &request.jobs);
        let _ = request.reply.send(result);
    }
}

/// Execute a set of jobs, packing them onto the executables with the best
/// *measured* per-evaluation cost (§Perf item 3). Among batch sizes that
/// fit the remaining work, choose the cheapest per eval; a bigger batch is
/// only used when its calibrated cost actually wins.
fn run_jobs(compiled: &[Compiled], jobs: &[(Vec<f64>, u32)]) -> Result<Vec<Vec<f64>>> {
    let mut out: Vec<Vec<f64>> = Vec::with_capacity(jobs.len());
    let mut rest = jobs;
    while !rest.is_empty() {
        let c = compiled
            .iter()
            .filter(|c| c.batch <= rest.len())
            .min_by(|a, b| a.per_eval_s.total_cmp(&b.per_eval_s))
            .or_else(|| compiled.first()) // tail smaller than every batch
            .unwrap();
        let take = rest.len().min(c.batch);
        let (chunk, tail) = rest.split_at(take);
        out.extend(execute_chunk(c, chunk)?);
        rest = tail;
    }
    Ok(out)
}

/// Run up to `c.batch` jobs on one executable, padding the tail with the
/// last job (padding results are discarded).
fn execute_chunk(c: &Compiled, chunk: &[(Vec<f64>, u32)]) -> Result<Vec<Vec<f64>>> {
    let b = c.batch;
    let mut params = Vec::with_capacity(b * 3);
    let mut seeds: Vec<u32> = Vec::with_capacity(b);
    for i in 0..b {
        let (g, s) = &chunk[i.min(chunk.len() - 1)];
        params.extend(g.iter().map(|&x| x as f32));
        seeds.push(*s);
    }
    let result = if b == 1 {
        let p = xla::Literal::vec1(&params);
        let s = xla::Literal::scalar(seeds[0]);
        c.exe.execute::<xla::Literal>(&[p, s])?
    } else {
        let p = xla::Literal::vec1(&params).reshape(&[b as i64, 3])?;
        let s = xla::Literal::vec1(&seeds);
        c.exe.execute::<xla::Literal>(&[p, s])?
    };
    let literal = result[0][0].to_literal_sync()?.to_tuple1()?;
    let values = literal.to_vec::<f32>()?;
    let n_obj = values.len() / b;
    Ok(chunk
        .iter()
        .enumerate()
        .map(|(i, _)| {
            values[i * n_obj..(i + 1) * n_obj]
                .iter()
                .map(|&v| f64::from(v))
                .collect()
        })
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn evaluator() -> Option<PjrtEvaluator> {
        if !ArtifactManifest::available() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        Some(PjrtEvaluator::from_default_artifacts(1).unwrap())
    }

    #[test]
    fn single_eval_matches_reference_run() {
        let Some(ev) = evaluator() else { return };
        // the value verified against jax in the build smoke test
        let f = ev.evaluate(&[125.0, 50.0, 10.0], 42).unwrap();
        assert_eq!(f, vec![175.0, 493.0, 924.0]);
    }

    #[test]
    fn two_param_genome_uses_default_population() {
        let Some(ev) = evaluator() else { return };
        let f2 = ev.evaluate(&[50.0, 10.0], 42).unwrap();
        let f3 = ev.evaluate(&[125.0, 50.0, 10.0], 42).unwrap();
        assert_eq!(f2, f3);
    }

    #[test]
    fn batch_results_match_singles() {
        let Some(ev) = evaluator() else { return };
        let jobs: Vec<(Vec<f64>, u32)> = (0..5)
            .map(|i| (vec![125.0, 40.0 + f64::from(i), 10.0], 100 + i))
            .collect();
        let batch = ev.evaluate_batch(&jobs).unwrap();
        for (j, want) in jobs.iter().zip(&batch) {
            let single = ev.evaluate(&j.0, j.1).unwrap();
            assert_eq!(&single, want);
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let Some(ev) = evaluator() else { return };
        let a = ev.evaluate(&[125.0, 60.0, 20.0], 7).unwrap();
        let b = ev.evaluate(&[125.0, 60.0, 20.0], 7).unwrap();
        assert_eq!(a, b);
        let c = ev.evaluate(&[125.0, 60.0, 20.0], 8).unwrap();
        assert_ne!(a, c);
    }

    #[test]
    fn concurrent_evaluations_consistent() {
        let Some(ev) = evaluator() else { return };
        let want = ev.evaluate(&[125.0, 50.0, 10.0], 42).unwrap();
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let ev = ev.clone();
                std::thread::spawn(move || ev.evaluate(&[125.0, 50.0, 10.0], 42).unwrap())
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), want);
        }
    }

    #[test]
    fn bad_genome_length_rejected() {
        let Some(ev) = evaluator() else { return };
        assert!(ev.evaluate(&[1.0], 0).is_err());
    }
}
