//! PJRT runtime: loads `artifacts/*.hlo.txt` (the AOT-lowered JAX+Pallas
//! ant model) and serves evaluations to the L3 coordinator. Python never
//! runs here — the artifacts are self-contained HLO text.

pub mod artifacts;
pub mod pjrt;
pub mod xla_stub;

pub use artifacts::{ArtifactEntry, ArtifactManifest};
pub use pjrt::PjrtEvaluator;

use std::sync::Arc;

use crate::evolution::evaluator::{AntSimEvaluator, Evaluator};

/// The production evaluator if artifacts are built, otherwise the
/// pure-Rust twin — so every example/bench degrades gracefully.
///
/// `MOLERS_SIM_TICKS=N` overrides the rust-sim tick count (default 1000):
/// a low-fidelity knob for integration tests that drive whole CLI or
/// server runs. Deterministic for a given value, so a reference run and a
/// resumed/served run under the same setting stay byte-identical.
pub fn best_available_evaluator(workers: usize) -> (Arc<dyn Evaluator>, &'static str) {
    if ArtifactManifest::available() {
        match PjrtEvaluator::from_default_artifacts(workers) {
            Ok(ev) => return (Arc::new(ev), "pjrt"),
            Err(e) => eprintln!("pjrt unavailable ({e}); falling back to rust sim"),
        }
    }
    let mut sim = AntSimEvaluator::new();
    if let Some(ticks) = std::env::var("MOLERS_SIM_TICKS")
        .ok()
        .and_then(|v| v.trim().parse::<u32>().ok())
    {
        sim.max_ticks = ticks.max(1);
    }
    (Arc::new(sim), "rust-sim")
}
