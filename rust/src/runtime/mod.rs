//! PJRT runtime: loads `artifacts/*.hlo.txt` (the AOT-lowered JAX+Pallas
//! ant model) and serves evaluations to the L3 coordinator. Python never
//! runs here — the artifacts are self-contained HLO text.

pub mod artifacts;
pub mod pjrt;
pub mod xla_stub;

pub use artifacts::{ArtifactEntry, ArtifactManifest};
pub use pjrt::PjrtEvaluator;

use std::sync::Arc;

use crate::evolution::evaluator::{AntSimEvaluator, Evaluator};

/// The production evaluator if artifacts are built, otherwise the
/// pure-Rust twin — so every example/bench degrades gracefully.
pub fn best_available_evaluator(workers: usize) -> (Arc<dyn Evaluator>, &'static str) {
    if ArtifactManifest::available() {
        match PjrtEvaluator::from_default_artifacts(workers) {
            Ok(ev) => return (Arc::new(ev), "pjrt"),
            Err(e) => eprintln!("pjrt unavailable ({e}); falling back to rust sim"),
        }
    }
    (Arc::new(AntSimEvaluator::new()), "rust-sim")
}
