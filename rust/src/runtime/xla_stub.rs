//! Compile-time stand-in for the `xla` (PJRT bindings) crate, which is not
//! vendored in this build image (DESIGN.md §3).
//!
//! [`super::pjrt`] is written against the real crate's API surface; this
//! module mirrors exactly the slice of that surface the runtime uses, with
//! every entry point failing at *runtime* ([`PjRtClient::cpu`] returns an
//! error), so:
//!
//! * the whole PJRT code path type-checks and stays honest — when the
//!   native runtime is vendored, `pjrt.rs` switches back to `use xla;`
//!   with no other change;
//! * callers degrade gracefully: `PjrtEvaluator::new` surfaces the error,
//!   and `best_available_evaluator` falls back to the pure-Rust twin.

use std::fmt;

/// Error type mirroring `xla::Error` (only `Display` is consumed).
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>() -> Result<T> {
    Err(Error(
        "xla runtime not vendored in this build (stub backend); \
         rebuild with the native PJRT bindings to execute HLO artifacts"
            .to_string(),
    ))
}

/// Mirrors `xla::PjRtClient`.
pub struct PjRtClient;

impl PjRtClient {
    /// The real TFRT CPU client; here always an error (no native runtime).
    pub fn cpu() -> Result<Self> {
        unavailable()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }
}

/// Mirrors `xla::HloModuleProto`.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self> {
        unavailable()
    }
}

/// Mirrors `xla::XlaComputation`.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Mirrors `xla::PjRtLoadedExecutable`.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

/// Mirrors `xla::PjRtBuffer`.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

/// Mirrors `xla::Literal` (host-side tensor value).
pub struct Literal;

impl Literal {
    pub fn vec1<T>(_values: &[T]) -> Literal {
        Literal
    }

    pub fn scalar<T>(_value: T) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable()
    }

    pub fn to_tuple1(self) -> Result<Literal> {
        unavailable()
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_entry_point_reports_stub_backend() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        assert!(err.to_string().contains("not vendored"));
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        assert!(Literal::vec1(&[1.0f32]).reshape(&[1]).is_err());
    }
}
