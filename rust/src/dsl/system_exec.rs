//! `SystemExecTask` — the paper's task type for "any kind of application
//! as it would be from a command line" (§4.3), i.e. applications packaged
//! with CARE (§3.2).
//!
//! The task renders a command line from its input variables (`${var}`
//! interpolation), executes it, and exposes exit status / stdout as output
//! variables. An optional [`Archive`] models the CARE packaging step: when
//! present, execution goes through the archive's `re-execute.sh` contract
//! and a kernel-compatibility check against the (simulated) host — the
//! exact §3 failure modes, surfaced as task errors.

use std::process::Command;

use crate::care::manifest::KernelVersion;
use crate::care::reexec::{reexecute, Packager, RemoteHost, ReexecOutcome};
use crate::care::Archive;
use crate::core::{Context, Val, Value, VarSpec, VarType};
use crate::dsl::task::Task;
use crate::error::{Error, Result};

/// Runs a shell command as a task.
pub struct SystemExecTask {
    name: String,
    /// Command template; `${var}` is replaced by the input variable.
    command: String,
    inputs: Vec<String>,
    stdout_var: Option<String>,
    status_var: Option<String>,
    cost_hint: f64,
    /// CARE/CDE packaging context (None = run on the bare host).
    package: Option<(Archive, RemoteHost)>,
}

impl SystemExecTask {
    pub fn new(name: impl Into<String>, command: impl Into<String>) -> Self {
        SystemExecTask {
            name: name.into(),
            command: command.into(),
            inputs: Vec::new(),
            stdout_var: None,
            status_var: None,
            cost_hint: 1.0,
            package: None,
        }
    }

    /// Declare an input used in the command template.
    pub fn input<T: crate::core::ValueType>(mut self, v: &Val<T>) -> Self {
        self.inputs.push(v.name().to_string());
        self
    }

    /// Capture trimmed stdout into this output variable.
    pub fn stdout(mut self, v: &Val<String>) -> Self {
        self.stdout_var = Some(v.name().to_string());
        self
    }

    /// Capture the exit status into this output variable.
    pub fn status(mut self, v: &Val<i64>) -> Self {
        self.status_var = Some(v.name().to_string());
        self
    }

    pub fn cost(mut self, seconds: f64) -> Self {
        self.cost_hint = seconds;
        self
    }

    /// Attach a CARE/CDE archive + target host: execution then honours the
    /// §3 compatibility rules before the command runs.
    pub fn packaged(mut self, archive: Archive, host: RemoteHost) -> Self {
        self.package = Some((archive, host));
        self
    }

    fn render(&self, ctx: &Context) -> String {
        let mut out = self.command.clone();
        for name in &self.inputs {
            if let Some(v) = ctx.get_raw(name) {
                out = out.replace(&format!("${{{name}}}"), &v.display());
            }
        }
        out
    }
}

impl Task for SystemExecTask {
    fn name(&self) -> &str {
        &self.name
    }

    fn input_specs(&self) -> Vec<VarSpec> {
        // command placeholders render any value type: presence-checked,
        // not type-checked
        self.inputs.iter().map(VarSpec::untyped).collect()
    }

    fn output_specs(&self) -> Vec<VarSpec> {
        self.stdout_var
            .iter()
            .map(|n| VarSpec::of(n, VarType::Str))
            .chain(self.status_var.iter().map(|n| VarSpec::of(n, VarType::I64)))
            .collect()
    }

    fn cost_hint(&self) -> f64 {
        self.cost_hint
    }

    fn run(&self, ctx: &Context) -> Result<Context> {
        // packaging gate (§3): the archive must re-execute on the host
        if let Some((archive, host)) = &self.package {
            let packager = if archive.syscall_emulation {
                Packager::Care
            } else {
                Packager::Cde
            };
            match reexecute(&archive.manifest, packager, host) {
                ReexecOutcome::Success { .. } => {}
                failure => {
                    return Err(Error::TaskFailed {
                        task: self.name.clone(),
                        message: format!("re-execution failed on {}: {failure:?}", host.name),
                    })
                }
            }
        }

        let rendered = self.render(ctx);
        let output = Command::new("sh")
            .arg("-c")
            .arg(&rendered)
            .output()
            .map_err(|e| Error::TaskFailed {
                task: self.name.clone(),
                message: format!("cannot spawn `{rendered}`: {e}"),
            })?;

        let mut out = Context::new();
        if let Some(var) = &self.status_var {
            out.set_raw(var, Value::I64(i64::from(output.status.code().unwrap_or(-1))));
        } else if !output.status.success() {
            return Err(Error::TaskFailed {
                task: self.name.clone(),
                message: format!(
                    "`{rendered}` exited with {}: {}",
                    output.status,
                    String::from_utf8_lossy(&output.stderr).trim()
                ),
            });
        }
        if let Some(var) = &self.stdout_var {
            out.set_raw(
                var,
                Value::Str(String::from_utf8_lossy(&output.stdout).trim().to_string()),
            );
        }
        Ok(out)
    }
}

/// The default packaging host for simulated remote execution: an EGI-era
/// Scientific Linux worker.
pub fn scientific_linux_host(name: &str) -> RemoteHost {
    RemoteHost::new(name, KernelVersion::SCIENTIFIC_LINUX)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::care::{Dependency, Manifest};
    use crate::core::{val_f64, val_i64, val_str};
    use crate::dsl::task::run_checked;

    #[test]
    fn runs_command_and_captures_stdout() {
        let sum = val_str("sum");
        let t = SystemExecTask::new("adder", "expr 19 + 23").stdout(&sum);
        let out = run_checked(&t, &Context::new()).unwrap();
        assert_eq!(out.get(&sum).unwrap(), "42");
    }

    #[test]
    fn interpolates_input_variables() {
        let x = val_f64("x");
        let echoed = val_str("echoed");
        let t = SystemExecTask::new("echo", "echo value=${x}")
            .input(&x)
            .stdout(&echoed);
        let out = run_checked(&t, &Context::new().with(&x, 2.5)).unwrap();
        assert_eq!(out.get(&echoed).unwrap(), "value=2.5");
    }

    #[test]
    fn nonzero_exit_is_error_unless_status_captured() {
        let t = SystemExecTask::new("fail", "exit 3");
        assert!(run_checked(&t, &Context::new()).is_err());

        let code = val_i64("code");
        let t = SystemExecTask::new("fail", "exit 3").status(&code);
        let out = run_checked(&t, &Context::new()).unwrap();
        assert_eq!(out.get(&code).unwrap(), 3);
    }

    fn manifest(kernel: KernelVersion) -> Manifest {
        Manifest::new("app", "echo packaged-run", kernel)
            .with(Dependency::lib("/lib/libc.so.6", "2.17"))
    }

    #[test]
    fn care_packaged_task_runs_on_old_kernel() {
        let archive = Archive::pack(manifest(KernelVersion(4, 4, 0)), true);
        let host = scientific_linux_host("wn01"); // 2.6.32 < 4.4.0
        let outv = val_str("out");
        let t = SystemExecTask::new("packaged", "echo packaged-run")
            .stdout(&outv)
            .packaged(archive, host);
        let out = run_checked(&t, &Context::new()).unwrap();
        assert_eq!(out.get(&outv).unwrap(), "packaged-run");
    }

    #[test]
    fn cde_packaged_task_fails_on_old_kernel() {
        let archive = Archive::pack(manifest(KernelVersion(4, 4, 0)), false);
        let host = scientific_linux_host("wn02");
        let t = SystemExecTask::new("packaged", "echo never").packaged(archive, host);
        let err = run_checked(&t, &Context::new()).unwrap_err();
        assert!(err.to_string().contains("KernelTooOld"), "{err}");
    }
}
