//! Hooks: the only sanctioned side-effect channel (paper §4.3).
//!
//! Tasks are pure; hooks observe task results — display them, save Pareto
//! fronts, append CSV rows. Hooks run on the coordinator, never on remote
//! environments.

use std::fs::OpenOptions;
use std::io::Write;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

use crate::core::{Context, Val, ValueType, Value};
use crate::error::Result;

/// Observer invoked with the output context of a capsule's task.
pub trait Hook: Send + Sync {
    fn name(&self) -> &str;
    fn process(&self, ctx: &Context) -> Result<()>;
}

/// Where textual hook output goes. Defaults to stdout; tests capture.
#[derive(Clone)]
pub enum Sink {
    Stdout,
    Capture(Arc<Mutex<Vec<String>>>),
}

impl Sink {
    pub fn capture() -> (Sink, Arc<Mutex<Vec<String>>>) {
        let buf = Arc::new(Mutex::new(Vec::new()));
        (Sink::Capture(Arc::clone(&buf)), buf)
    }

    fn emit(&self, line: String) {
        match self {
            Sink::Stdout => println!("{line}"),
            Sink::Capture(buf) => buf.lock().unwrap().push(line),
        }
    }
}

/// `ToStringHook(food1, food2, food3)` — print selected variables.
pub struct ToStringHook {
    vars: Vec<String>,
    sink: Sink,
}

impl ToStringHook {
    pub fn new(vars: &[&str]) -> Self {
        ToStringHook {
            vars: vars.iter().map(|s| s.to_string()).collect(),
            sink: Sink::Stdout,
        }
    }

    pub fn of<T: ValueType>(vals: &[&Val<T>]) -> Self {
        Self::new(&vals.iter().map(|v| v.name()).collect::<Vec<_>>())
    }

    pub fn sink(mut self, sink: Sink) -> Self {
        self.sink = sink;
        self
    }
}

impl Hook for ToStringHook {
    fn name(&self) -> &str {
        "ToStringHook"
    }
    fn process(&self, ctx: &Context) -> Result<()> {
        let line = self
            .vars
            .iter()
            .map(|v| {
                let val = ctx
                    .get_raw(v)
                    .map(Value::display)
                    .unwrap_or_else(|| "<missing>".to_string());
                format!("{v}={val}")
            })
            .collect::<Vec<_>>()
            .join(", ");
        self.sink.emit(line);
        Ok(())
    }
}

/// `DisplayHook("Generation ${generation}")` — template interpolation.
pub struct DisplayHook {
    template: String,
    sink: Sink,
}

impl DisplayHook {
    pub fn new(template: impl Into<String>) -> Self {
        DisplayHook {
            template: template.into(),
            sink: Sink::Stdout,
        }
    }

    pub fn sink(mut self, sink: Sink) -> Self {
        self.sink = sink;
        self
    }

    /// Replace `${name}` with the variable's display value.
    fn render(&self, ctx: &Context) -> String {
        let mut out = String::new();
        let mut rest = self.template.as_str();
        while let Some(start) = rest.find("${") {
            out.push_str(&rest[..start]);
            let after = &rest[start + 2..];
            match after.find('}') {
                Some(end) => {
                    let name = &after[..end];
                    match ctx.get_raw(name) {
                        Some(v) => out.push_str(&v.display()),
                        None => out.push_str("<missing>"),
                    }
                    rest = &after[end + 1..];
                }
                None => {
                    out.push_str(&rest[start..]);
                    rest = "";
                }
            }
        }
        out.push_str(rest);
        out
    }
}

impl Hook for DisplayHook {
    fn name(&self) -> &str {
        "DisplayHook"
    }
    fn process(&self, ctx: &Context) -> Result<()> {
        self.sink.emit(self.render(ctx));
        Ok(())
    }
}

/// `AppendToCSVFileHook` — append one row per processed context.
pub struct CsvHook {
    path: PathBuf,
    vars: Vec<String>,
    header_written: Mutex<bool>,
}

impl CsvHook {
    pub fn new(path: impl Into<PathBuf>, vars: &[&str]) -> Self {
        CsvHook {
            path: path.into(),
            vars: vars.iter().map(|s| s.to_string()).collect(),
            header_written: Mutex::new(false),
        }
    }
}

impl Hook for CsvHook {
    fn name(&self) -> &str {
        "CsvHook"
    }
    fn process(&self, ctx: &Context) -> Result<()> {
        if let Some(dir) = self.path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = OpenOptions::new()
            .create(true)
            .append(true)
            .open(&self.path)?;
        let mut header = self.header_written.lock().unwrap();
        if !*header && f.metadata()?.len() == 0 {
            writeln!(f, "{}", self.vars.join(","))?;
        }
        *header = true;
        let row = self
            .vars
            .iter()
            .map(|v| {
                ctx.get_raw(v)
                    .map(Value::display)
                    .unwrap_or_default()
            })
            .collect::<Vec<_>>()
            .join(",");
        writeln!(f, "{row}")?;
        Ok(())
    }
}

/// Output encoding of a [`RowWriter`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TableFormat {
    /// Header line + one comma-separated row per sample.
    Csv,
    /// One `{"col":value,...}` JSON object per line (column order kept).
    Jsonl,
}

/// One column's streaming summary — what [`RowWriter::stats`] reports
/// after a run without ever holding the result set in memory.
#[derive(Debug, Clone)]
pub struct ColumnSummary {
    pub name: String,
    /// Finite observations (NaN/inf rows — e.g. degraded placeholders —
    /// are excluded from every statistic).
    pub count: u64,
    pub mean: f64,
    pub min: f64,
    pub max: f64,
    /// Median estimate: exact up to 5 observations, a P² sketch beyond.
    pub median: f64,
}

/// P² single-quantile sketch (Jain & Chlamtac 1985): five markers track
/// the running quantile estimate in O(1) state and O(1) work per
/// observation — the piece that lets a 10M-row explore report a median
/// without sorting (or even retaining) 10M values.
struct P2Quantile {
    p: f64,
    count: usize,
    /// Marker heights (sorted once the first five observations arrive).
    q: [f64; 5],
    /// Marker positions (1-based ranks).
    n: [f64; 5],
    /// Desired marker positions.
    desired: [f64; 5],
}

impl P2Quantile {
    fn new(p: f64) -> Self {
        P2Quantile {
            p,
            count: 0,
            q: [0.0; 5],
            n: [1.0, 2.0, 3.0, 4.0, 5.0],
            desired: [1.0, 1.0 + 2.0 * p, 1.0 + 4.0 * p, 3.0 + 2.0 * p, 5.0],
        }
    }

    fn observe(&mut self, x: f64) {
        if self.count < 5 {
            self.q[self.count] = x;
            self.count += 1;
            if self.count == 5 {
                self.q.sort_by(f64::total_cmp);
            }
            return;
        }
        self.count += 1;
        // locate the cell, stretching the extreme markers as needed
        let k = if x < self.q[0] {
            self.q[0] = x;
            0
        } else if x < self.q[1] {
            0
        } else if x < self.q[2] {
            1
        } else if x < self.q[3] {
            2
        } else if x <= self.q[4] {
            3
        } else {
            self.q[4] = x;
            3
        };
        for i in (k + 1)..5 {
            self.n[i] += 1.0;
        }
        let dn = [0.0, self.p / 2.0, self.p, (1.0 + self.p) / 2.0, 1.0];
        for (d, inc) in self.desired.iter_mut().zip(dn) {
            *d += inc;
        }
        // nudge the three interior markers toward their desired ranks
        for i in 1..4 {
            let d = self.desired[i] - self.n[i];
            if (d >= 1.0 && self.n[i + 1] - self.n[i] > 1.0)
                || (d <= -1.0 && self.n[i - 1] - self.n[i] < -1.0)
            {
                let s = d.signum();
                let parabolic = self.parabolic(i, s);
                self.q[i] = if self.q[i - 1] < parabolic && parabolic < self.q[i + 1] {
                    parabolic
                } else {
                    self.linear(i, s)
                };
                self.n[i] += s;
            }
        }
    }

    /// Piecewise-parabolic height estimate for marker `i` moved by `d`.
    fn parabolic(&self, i: usize, d: f64) -> f64 {
        let (q, n) = (&self.q, &self.n);
        q[i] + d / (n[i + 1] - n[i - 1])
            * ((n[i] - n[i - 1] + d) * (q[i + 1] - q[i]) / (n[i + 1] - n[i])
                + (n[i + 1] - n[i] - d) * (q[i] - q[i - 1]) / (n[i] - n[i - 1]))
    }

    fn linear(&self, i: usize, d: f64) -> f64 {
        let j = if d > 0.0 { i + 1 } else { i - 1 };
        self.q[i] + d * (self.q[j] - self.q[i]) / (self.n[j] - self.n[i])
    }

    fn value(&self) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        if self.count < 5 {
            // exact small-sample quantile (no allocation: fixed array)
            let mut v = self.q;
            let v = &mut v[..self.count];
            v.sort_by(f64::total_cmp);
            return v[((self.count - 1) as f64 * self.p).round() as usize];
        }
        self.q[2]
    }
}

/// Streaming statistics of one column: count, Welford mean, min/max and
/// the P² median sketch. Constant state, no per-row allocation.
struct ColumnStats {
    count: u64,
    mean: f64,
    min: f64,
    max: f64,
    median: P2Quantile,
}

impl ColumnStats {
    fn new() -> Self {
        ColumnStats {
            count: 0,
            mean: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            median: P2Quantile::new(0.5),
        }
    }

    fn observe(&mut self, x: f64) {
        if !x.is_finite() {
            return; // degraded NaN placeholders must not poison the run summary
        }
        self.count += 1;
        self.mean += (x - self.mean) / self.count as f64;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
        self.median.observe(x);
    }

    fn summary(&self, name: &str) -> ColumnSummary {
        ColumnSummary {
            name: name.to_string(),
            count: self.count,
            mean: if self.count == 0 { f64::NAN } else { self.mean },
            min: if self.count == 0 { f64::NAN } else { self.min },
            max: if self.count == 0 { f64::NAN } else { self.max },
            median: self.median.value(),
        }
    }
}

/// Streaming CSV/JSONL result writer (§Exploration): one line per design
/// row, written in row order through a buffered file. Two entry points:
///
/// * [`RowWriter::append_row`] — the columnar fast path the sweep engine
///   drains completed sample blocks through (a `&[f64]` row, no per-row
///   `Context`);
/// * the [`Hook`] impl — the DSL edge: each processed context contributes
///   one row, columns read as `f64` (integer values coerce).
///
/// Floats are written with the shortest round-trip representation (the
/// same `{}` formatting the journal uses), so a result file rebuilt from
/// journaled objectives is byte-identical to one written live — the
/// property `molers explore --resume` relies on.
///
/// Every appended row also folds into per-column streaming statistics
/// ([`RowWriter::stats`]) — constant memory however long the run, which
/// is what gives the out-of-core explore path an end-of-run summary
/// without retaining a single result row.
pub struct RowWriter {
    format: TableFormat,
    columns: Vec<String>,
    file: Mutex<std::io::BufWriter<std::fs::File>>,
    stats: Mutex<Vec<ColumnStats>>,
}

impl RowWriter {
    /// Create (truncating) `path` and write the CSV header when the
    /// format calls for one.
    pub fn create(
        path: impl Into<PathBuf>,
        format: TableFormat,
        columns: &[&str],
    ) -> Result<Self> {
        let path = path.into();
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        let file = std::fs::File::create(&path)?;
        let mut file = std::io::BufWriter::with_capacity(1 << 16, file);
        if format == TableFormat::Csv {
            writeln!(file, "{}", columns.join(","))?;
        }
        Ok(RowWriter {
            format,
            columns: columns.iter().map(|s| s.to_string()).collect(),
            file: Mutex::new(file),
            stats: Mutex::new(columns.iter().map(|_| ColumnStats::new()).collect()),
        })
    }

    pub fn columns(&self) -> &[String] {
        &self.columns
    }

    /// Per-column streaming summary of every row appended so far.
    pub fn stats(&self) -> Vec<ColumnSummary> {
        let stats = self.stats.lock().unwrap();
        self.columns
            .iter()
            .zip(stats.iter())
            .map(|(name, s)| s.summary(name))
            .collect()
    }

    /// Append one row; `values` must carry one value per column.
    pub fn append_row(&self, values: &[f64]) -> Result<()> {
        if values.len() != self.columns.len() {
            return Err(crate::error::Error::InvalidWorkflow(format!(
                "row has {} values for {} columns",
                values.len(),
                self.columns.len()
            )));
        }
        let mut f = self.file.lock().unwrap();
        match self.format {
            TableFormat::Csv => {
                for (i, v) in values.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                writeln!(f)?;
            }
            TableFormat::Jsonl => {
                write!(f, "{{")?;
                for (i, (name, v)) in self.columns.iter().zip(values).enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    // column names are plain identifiers; quote directly.
                    // NaN/inf are not JSON — emit null so the line stays
                    // parseable (CSV keeps the raw text form).
                    if v.is_finite() {
                        write!(f, "\"{name}\":{v}")?;
                    } else {
                        write!(f, "\"{name}\":null")?;
                    }
                }
                writeln!(f, "}}")?;
            }
        }
        drop(f);
        let mut stats = self.stats.lock().unwrap();
        for (s, &v) in stats.iter_mut().zip(values) {
            s.observe(v);
        }
        Ok(())
    }

    /// Flush buffered rows to disk (the sweep calls this after each
    /// drained block so the file trails the journal by at most a buffer).
    pub fn flush(&self) -> Result<()> {
        self.file.lock().unwrap().flush()?;
        Ok(())
    }
}

impl Hook for RowWriter {
    fn name(&self) -> &str {
        "RowWriter"
    }

    fn process(&self, ctx: &Context) -> Result<()> {
        let values: Vec<f64> = self
            .columns
            .iter()
            .map(|c| ctx.get(&Val::<f64>::new(c.clone())))
            .collect::<Result<_>>()?;
        self.append_row(&values)
    }
}

/// Collect every processed context in memory (tests + result harvesting).
#[derive(Clone, Default)]
pub struct CaptureHook {
    seen: Arc<Mutex<Vec<Context>>>,
}

impl CaptureHook {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn contexts(&self) -> Vec<Context> {
        self.seen.lock().unwrap().clone()
    }

    pub fn len(&self) -> usize {
        self.seen.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl Hook for CaptureHook {
    fn name(&self) -> &str {
        "CaptureHook"
    }
    fn process(&self, ctx: &Context) -> Result<()> {
        self.seen.lock().unwrap().push(ctx.clone());
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::val_f64;

    #[test]
    fn tostring_hook_formats() {
        let (sink, buf) = Sink::capture();
        let h = ToStringHook::new(&["a", "b"]).sink(sink);
        let ctx = Context::new().with(&val_f64("a"), 1.5);
        h.process(&ctx).unwrap();
        assert_eq!(buf.lock().unwrap()[0], "a=1.5, b=<missing>");
    }

    #[test]
    fn display_hook_interpolates() {
        let (sink, buf) = Sink::capture();
        let h = DisplayHook::new("Generation ${g} done").sink(sink);
        let ctx = Context::new().with(&val_f64("g"), 7.0);
        h.process(&ctx).unwrap();
        assert_eq!(buf.lock().unwrap()[0], "Generation 7 done");
    }

    #[test]
    fn display_hook_tolerates_unclosed_brace() {
        let (sink, buf) = Sink::capture();
        DisplayHook::new("x ${oops").sink(sink).process(&Context::new()).unwrap();
        assert_eq!(buf.lock().unwrap()[0], "x ${oops");
    }

    #[test]
    fn csv_hook_appends_with_header() {
        let dir = std::env::temp_dir().join(format!("molers-csv-{}", std::process::id()));
        let path = dir.join("out.csv");
        let _ = std::fs::remove_file(&path);
        let h = CsvHook::new(&path, &["a", "b"]);
        let a = val_f64("a");
        let b = val_f64("b");
        h.process(&Context::new().with(&a, 1.0).with(&b, 2.0)).unwrap();
        h.process(&Context::new().with(&a, 3.0).with(&b, 4.0)).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "a,b\n1,2\n3,4\n");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn row_writer_csv_bytes() {
        let path = std::env::temp_dir()
            .join(format!("molers-roww-{}.csv", std::process::id()));
        {
            let w = RowWriter::create(&path, TableFormat::Csv, &["x", "f"]).unwrap();
            w.append_row(&[0.5, 2.0]).unwrap();
            w.append_row(&[1.25, std::f64::consts::PI]).unwrap();
            w.flush().unwrap();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "x,f\n0.5,2\n1.25,3.141592653589793\n");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn row_writer_jsonl_parses_back() {
        let path = std::env::temp_dir()
            .join(format!("molers-roww-{}.jsonl", std::process::id()));
        {
            let w = RowWriter::create(&path, TableFormat::Jsonl, &["x", "f"]).unwrap();
            w.append_row(&[0.5, 2.0]).unwrap();
            w.flush().unwrap();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "{\"x\":0.5,\"f\":2}\n");
        let doc = crate::util::json::parse(text.trim()).unwrap();
        assert_eq!(doc.get("x").unwrap().as_f64(), Some(0.5));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn row_writer_rejects_ragged_rows_and_serves_as_hook() {
        let path = std::env::temp_dir()
            .join(format!("molers-roww-hook-{}.csv", std::process::id()));
        let w = RowWriter::create(&path, TableFormat::Csv, &["a", "b"]).unwrap();
        assert!(w.append_row(&[1.0]).is_err());
        let a = val_f64("a");
        let ctx = Context::new().with(&a, 1.5).with(&val_f64("b"), 2.5);
        w.process(&ctx).unwrap();
        assert!(w.process(&Context::new().with(&a, 1.0)).is_err(), "missing b");
        w.flush().unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "a,b\n1.5,2.5\n");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn row_writer_streams_column_statistics() {
        let path = std::env::temp_dir()
            .join(format!("molers-roww-stats-{}.csv", std::process::id()));
        let w = RowWriter::create(&path, TableFormat::Csv, &["x", "f"]).unwrap();
        // a deterministic but shuffled sequence: x = 0..=1000 scrambled,
        // f carries NaNs that must be excluded
        let mut xs: Vec<f64> = (0..=1000).map(f64::from).collect();
        let mut s = 12345u64;
        for i in (1..xs.len()).rev() {
            s = s.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            xs.swap(i, (s >> 33) as usize % (i + 1));
        }
        for (i, &x) in xs.iter().enumerate() {
            let f = if i % 10 == 0 { f64::NAN } else { x * 2.0 };
            w.append_row(&[x, f]).unwrap();
        }
        let stats = w.stats();
        assert_eq!(stats[0].name, "x");
        assert_eq!(stats[0].count, 1001);
        assert_eq!(stats[0].min, 0.0);
        assert_eq!(stats[0].max, 1000.0);
        assert!((stats[0].mean - 500.0).abs() < 1e-9, "mean {}", stats[0].mean);
        assert!(
            (stats[0].median - 500.0).abs() < 25.0,
            "P^2 median estimate {} too far from 500",
            stats[0].median
        );
        assert_eq!(stats[1].count, 1001 - 101, "NaN rows excluded");
        assert!(stats[1].min >= 2.0);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn column_statistics_are_exact_for_small_samples() {
        let path = std::env::temp_dir()
            .join(format!("molers-roww-small-{}.csv", std::process::id()));
        let w = RowWriter::create(&path, TableFormat::Csv, &["x"]).unwrap();
        let empty = w.stats();
        assert_eq!(empty[0].count, 0);
        assert!(empty[0].median.is_nan() && empty[0].mean.is_nan());
        for v in [5.0, 1.0, 3.0] {
            w.append_row(&[v]).unwrap();
        }
        let stats = w.stats();
        assert_eq!(stats[0].count, 3);
        assert_eq!(stats[0].min, 1.0);
        assert_eq!(stats[0].max, 5.0);
        assert_eq!(stats[0].median, 3.0, "small samples are exact");
        assert!((stats[0].mean - 3.0).abs() < 1e-12);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn capture_hook_collects() {
        let h = CaptureHook::new();
        h.process(&Context::new()).unwrap();
        h.process(&Context::new()).unwrap();
        assert_eq!(h.len(), 2);
    }
}
