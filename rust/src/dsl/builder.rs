//! MoleDSL v2: the chainable puzzle construction API.
//!
//! [`PuzzleBuilder`] replaces the index-bookkeeping `Puzzle` mutators with
//! typed [`CapsuleHandle`]s exposing the paper's combinators as methods —
//! the Rust reading of OpenMOLE's `a -- b`, `a -< b`, `b >- c`,
//! `task on env`, `task hook h` (§2.1):
//!
//! ```
//! use std::sync::Arc;
//! use molers::dsl::PuzzleBuilder;
//! use molers::dsl::IdentityTask;
//! use molers::exploration::sampling::{Factor, FullFactorial};
//! use molers::core::val_f64;
//!
//! let x = val_f64("x");
//! let b = PuzzleBuilder::new();
//! let entry = b.task(IdentityTask::new("entry"));
//! let model = b.task(IdentityTask::new("model"));
//! let collect = b.task(IdentityTask::new("collect"));
//! entry.explore(
//!     Arc::new(FullFactorial::new(vec![Factor::new(&x, 0.0, 3.0, 1.0)])),
//!     &model,
//! );
//! model.aggregate(&collect);
//! let puzzle = b.build().unwrap(); // shape + typed dataflow proven here
//! assert_eq!(puzzle.capsules.len(), 3);
//! ```
//!
//! Handles are cheap clones tied to their builder; [`PuzzleBuilder::build`]
//! runs [`Puzzle::validate`] so a mis-wired workflow is rejected at
//! construction, before any execution engine sees it.

use std::cell::RefCell;
use std::rc::Rc;
use std::sync::Arc;

use crate::core::Context;
use crate::dsl::hook::Hook;
use crate::dsl::puzzle::{CapsuleId, Puzzle};
use crate::dsl::source::Source;
use crate::dsl::task::Task;
use crate::environment::Environment;
use crate::error::Result;
use crate::exploration::sampling::Sampling;

type Shared = Rc<RefCell<Option<Puzzle>>>;

/// Builds a [`Puzzle`] through typed capsule handles. Single-threaded by
/// design (construction is coordinator work); the built [`Puzzle`] itself
/// is freely movable.
pub struct PuzzleBuilder {
    inner: Shared,
}

impl PuzzleBuilder {
    pub fn new() -> Self {
        PuzzleBuilder {
            inner: Rc::new(RefCell::new(Some(Puzzle::new()))),
        }
    }

    fn with<R>(&self, f: impl FnOnce(&mut Puzzle) -> R) -> R {
        let mut guard = self.inner.borrow_mut();
        let puzzle = guard
            .as_mut()
            .expect("PuzzleBuilder was already consumed by build()");
        f(puzzle)
    }

    /// Add a capsule wrapping `task`. The first capsule added is the
    /// default entry (override with [`CapsuleHandle::entry`]).
    pub fn task(&self, task: impl Task + 'static) -> CapsuleHandle {
        self.capsule(Arc::new(task))
    }

    /// Add a capsule from an already-shared task.
    pub fn capsule(&self, task: Arc<dyn Task>) -> CapsuleHandle {
        let id = self.with(|p| p.add_capsule(task));
        CapsuleHandle {
            inner: Rc::clone(&self.inner),
            id,
        }
    }

    /// Finish construction: validate shape and typed dataflow (empty
    /// initial context) and hand over the puzzle. Handles of this builder
    /// must not be used afterwards.
    ///
    /// The execution engine re-validates at `start_with` (it must — the
    /// deprecated `Puzzle` mutators can still hand it unvalidated
    /// graphs); the pass is O(graph), so the redundancy is deliberate:
    /// `build()` buys the fail-at-construction guarantee, the engine
    /// keeps its own.
    pub fn build(&self) -> Result<Puzzle> {
        self.build_with(&Context::new())
    }

    /// [`PuzzleBuilder::build`], validating against the initial context
    /// the execution will start with (its variables count as supplied).
    pub fn build_with(&self, init: &Context) -> Result<Puzzle> {
        let puzzle = self
            .inner
            .borrow_mut()
            .take()
            .expect("PuzzleBuilder was already consumed by build()");
        puzzle.validate_with(init)?;
        Ok(puzzle)
    }
}

impl Default for PuzzleBuilder {
    fn default() -> Self {
        Self::new()
    }
}

/// A typed reference to one capsule of a [`PuzzleBuilder`]. Clones refer
/// to the same capsule; all wiring methods return handles so chains read
/// like the paper's DSL: `entry.explore(sampling, &model);
/// model.aggregate(&stat).hook(display);`.
#[derive(Clone)]
pub struct CapsuleHandle {
    inner: Shared,
    id: CapsuleId,
}

impl CapsuleHandle {
    /// The capsule's index in the built puzzle.
    pub fn id(&self) -> CapsuleId {
        self.id
    }

    fn with<R>(&self, other: Option<&CapsuleHandle>, f: impl FnOnce(&mut Puzzle) -> R) -> R {
        if let Some(o) = other {
            assert!(
                Rc::ptr_eq(&self.inner, &o.inner),
                "capsule handles belong to different PuzzleBuilders"
            );
        }
        let mut guard = self.inner.borrow_mut();
        let puzzle = guard
            .as_mut()
            .expect("PuzzleBuilder was already consumed by build()");
        f(puzzle)
    }

    /// Plain transition: `self -- to`. Returns `to`'s handle so chains
    /// read left to right: `a.then(&b).then(&c)`.
    pub fn then(&self, to: &CapsuleHandle) -> CapsuleHandle {
        self.with(Some(to), |p| p.add_direct(self.id, to.id));
        to.clone()
    }

    /// Fan-out: `self -< to` under `sampling` — `to` runs once per sample.
    pub fn explore(&self, sampling: Arc<dyn Sampling>, to: &CapsuleHandle) -> CapsuleHandle {
        self.with(Some(to), |p| p.add_explore(self.id, sampling, to.id));
        to.clone()
    }

    /// Fan-in barrier: `self >- to` — `to` receives one context whose
    /// variables are arrays over the enclosing exploration.
    pub fn aggregate(&self, to: &CapsuleHandle) -> CapsuleHandle {
        self.with(Some(to), |p| p.add_aggregate(self.id, to.id));
        to.clone()
    }

    /// Delegate this capsule's jobs to `env` (`task on env` — the paper's
    /// one-line environment switch).
    pub fn on(&self, env: Arc<dyn Environment>) -> &Self {
        self.with(None, |p| p.set_environment(self.id, env));
        self
    }

    /// Attach an observation hook (`task hook h`).
    pub fn hook(&self, hook: Arc<dyn Hook>) -> &Self {
        self.with(None, |p| p.add_hook(self.id, hook));
        self
    }

    /// Attach a source: its variables merge into the incoming context
    /// before each run.
    pub fn source(&self, source: Arc<dyn Source>) -> &Self {
        self.with(None, |p| p.add_source(self.id, source));
        self
    }

    /// Make this capsule the entry point.
    pub fn entry(&self) -> &Self {
        self.with(None, |p| p.set_entry(self.id));
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{val_f64, val_u32, Context};
    use crate::dsl::hook::CaptureHook;
    use crate::dsl::task::{ClosureTask, IdentityTask};
    use crate::environment::local::LocalEnvironment;
    use crate::exploration::sampling::SeedSampling;
    use crate::workflow::MoleExecution;

    #[test]
    fn chains_read_like_the_paper() {
        let x = val_f64("x");
        let y = val_f64("y");
        let b = PuzzleBuilder::new();
        let square = b.task(
            ClosureTask::new("square", {
                let (x, y) = (x.clone(), y.clone());
                move |ctx| Ok(Context::new().with(&y, ctx.get(&x)?.powi(2)))
            })
            .input(&x)
            .output(&y)
            .default(&x, 5.0),
        );
        let report = b.task(IdentityTask::new("report"));
        square.then(&report);
        let puzzle = b.build().unwrap();
        let result = MoleExecution::new(puzzle, Arc::new(LocalEnvironment::new(1)), 1)
            .start()
            .unwrap();
        assert_eq!(result.outputs[0].get(&y).unwrap(), 25.0);
    }

    #[test]
    fn explore_aggregate_hook_on_roundtrip() {
        let seed = val_u32("seed");
        let b = PuzzleBuilder::new();
        let entry = b.task(IdentityTask::new("entry"));
        let model = b.task(IdentityTask::new("model"));
        let done = b.task(IdentityTask::new("done"));
        let capture = Arc::new(CaptureHook::new());
        model.hook(capture.clone()).on(Arc::new(LocalEnvironment::new(2)));
        entry.explore(Arc::new(SeedSampling::new(&seed, 4)), &model);
        model.aggregate(&done);
        entry.entry();
        let puzzle = b.build().unwrap();
        MoleExecution::new(puzzle, Arc::new(LocalEnvironment::new(2)), 7)
            .start()
            .unwrap();
        assert_eq!(capture.len(), 4);
    }

    #[test]
    fn build_rejects_miswired_puzzles() {
        let x = val_f64("x");
        let b = PuzzleBuilder::new();
        let _lonely = b.task(ClosureTask::new("needs-x", |_| Ok(Context::new())).input(&x));
        let err = b.build().unwrap_err().to_string();
        assert!(err.contains("`x`"), "{err}");
    }

    #[test]
    fn build_with_accepts_initial_context() {
        let x = val_f64("x");
        let b = PuzzleBuilder::new();
        b.task(ClosureTask::new("needs-x", |_| Ok(Context::new())).input(&x));
        assert!(b.build_with(&Context::new().with(&x, 1.0)).is_ok());
    }

    #[test]
    #[should_panic(expected = "different PuzzleBuilders")]
    fn mixing_builders_panics() {
        let a = PuzzleBuilder::new();
        let b = PuzzleBuilder::new();
        let ca = a.task(IdentityTask::new("a"));
        let cb = b.task(IdentityTask::new("b"));
        ca.then(&cb);
    }

    #[test]
    #[should_panic(expected = "already consumed")]
    fn handles_after_build_panic() {
        let b = PuzzleBuilder::new();
        let c = b.task(IdentityTask::new("a"));
        let _ = b.build().unwrap();
        c.hook(Arc::new(CaptureHook::new()));
    }
}
